"""Scenario workload generators (repro.workloads)."""

import numpy as np
import pytest

from repro.workloads import (
    SCENARIOS,
    Scenario,
    calibration_grid,
    get_scenario,
    scenario_names,
)


def test_registry_covers_the_papers_hard_regimes():
    names = scenario_names()
    for required in ("sparse_facility", "dense_user", "large_k"):
        assert required in names
    # distribution ablations present
    assert {SCENARIOS[n].distribution for n in names} >= {"road", "clustered"}
    with pytest.raises(ValueError, match="scenario must be one of"):
        get_scenario("nope")


def test_generate_matches_spec_and_is_deterministic():
    sc = get_scenario("sparse_facility")
    w1 = sc.generate(scale=0.1)
    w2 = sc.generate(scale=0.1)
    assert w1.shape == (sc.n_facilities, max(int(sc.n_users * 0.1), 64), sc.k, sc.q)
    np.testing.assert_array_equal(w1.facilities, w2.facilities)
    np.testing.assert_array_equal(w1.users, w2.users)
    assert w1.qs == w2.qs
    assert all(0 <= qi < len(w1.facilities) for qi in w1.qs)


def test_scale_floor_keeps_workloads_nonempty():
    w = get_scenario("dense_user").generate(scale=1e-9)
    assert len(w.users) == 64


@pytest.mark.parametrize(
    "distribution", ["road", "uniform", "clustered", "gaussian", "mixed"]
)
def test_distributions_stay_in_unit_square(distribution):
    w = Scenario("t", 30, 500, 4, 2, distribution=distribution, seed=3).generate()
    pts = np.concatenate([w.facilities, w.users])
    assert len(pts) == 530
    assert pts.min() >= 0.0 and pts.max() <= 1.0


def test_unknown_distribution_raises():
    with pytest.raises(ValueError, match="distribution must be"):
        Scenario("t", 10, 100, 2, 1, distribution="fractal").generate()


def test_calibration_grid_spans_axes_and_rotates_distributions():
    fast = calibration_grid(fast=True)
    full = calibration_grid(fast=False)
    assert 0 < len(fast) < len(full)
    for grid in (fast, full):
        fs = {s.n_facilities for s in grid}
        ks = {s.k for s in grid}
        qs = {s.q for s in grid}
        assert len(fs) >= 3 and len(ks) >= 3 and len(qs) >= 2
    # m-decorrelation: more than one point distribution in the grid
    assert len({s.distribution for s in fast}) >= 2
