"""The observability subsystem: spans, rings, histograms, stats views.

What is actually under test, per layer:

* :mod:`repro.obs.trace` — span nesting/attribution is explicit
  (parent seq + depth, not timestamp inference), ring wraparound keeps
  the newest window with an *exact* dropped count, a disabled tracer
  records nothing while spans still time, and the seqlock stable read
  never surfaces a torn record under a concurrent writer (the MVCC
  serving regime: readers trace while a writer thread traces its update
  pass).
* :mod:`repro.obs.metrics` — log-bucketed histogram percentiles land
  within one bucket's relative error of numpy's exact answer, signed
  histograms fold correctly for the drift gate's median |residual|.
* engine integration — ``EngineStats`` fields are live views over the
  metrics registry (the legacy contract every older test asserts), the
  continuous-query ``events_dropped``/pruned counters surface, and a
  sharded ``query_batch`` trace carries the nested
  filter/verify/shard-* structure the Chrome exporter renders.
"""

import json
import threading

import numpy as np
import pytest

from repro.obs import (
    Histogram,
    MetricsRegistry,
    SpanRing,
    Tracer,
    chrome_trace,
    set_tracer,
    span,
    spans,
    summarize,
)
from repro.obs.export import _from_chrome


@pytest.fixture
def tracer():
    """A fresh enabled tracer installed as the global one."""
    t = Tracer(capacity=1 << 10)
    prev = set_tracer(t)
    t.enable()
    yield t
    set_tracer(prev)


# ---------------------------------------------------------------- spans
def test_span_nesting_and_attribution(tracer):
    with span("batch", backend="auto", q=4):
        with span("filter", backend="grid"):
            pass
        with span("verify", backend="grid"):
            pass
    recs = spans(tracer)
    # time-ordered (parent opened first); ring order is children-first
    assert [r["name"] for r in recs] == ["batch", "filter", "verify"]
    assert [r["name"] for r in tracer.records()] == ["filter", "verify", "batch"]
    by_name = {r["name"]: r for r in recs}
    assert by_name["batch"]["depth"] == 0
    assert by_name["batch"]["parent"] == -1
    assert by_name["filter"]["depth"] == 1
    assert by_name["verify"]["depth"] == 1
    # children recorded before the parent closed: parent seq is unknown
    # at child exit only if the parent hasn't recorded yet — nesting is
    # carried by depth + the parent's *enter-time* seq (-1 for a still
    # open root), so both children agree
    assert by_name["filter"]["parent"] == by_name["verify"]["parent"]
    assert by_name["batch"]["attrs"] == {"backend": "auto", "q": 4}
    # wall-clock containment
    assert by_name["batch"]["t0"] <= by_name["filter"]["t0"]
    assert by_name["filter"]["t1"] <= by_name["batch"]["t1"]


def test_span_always_times_even_when_disabled(tracer):
    tracer.disable()
    with span("work") as sp:
        x = sum(range(1000))
    assert x > 0
    assert sp.elapsed_s > 0.0
    assert list(tracer.records()) == []


def test_span_exit_is_idempotent(tracer):
    with span("phase") as sp:
        sp.__exit__(None, None, None)  # manual early close inside `with`
        t1 = sp.t1
    assert sp.t1 == t1  # the with-exit did not restamp
    assert len(list(tracer.records())) == 1


def test_nested_sequence_parents_chain(tracer):
    with span("a"):
        pass
    with span("b") as sb:
        with span("c"):
            pass
    recs = {r["name"]: r for r in spans(tracer)}
    # `b` entered after `a` recorded; `c`'s parent is b's enter-time seq
    assert recs["c"]["depth"] == 1
    assert recs["b"]["depth"] == 0
    assert sb.seq == recs["b"]["seq"]


# ----------------------------------------------------------------- ring
def test_ring_wraparound_exact_dropped_count(tracer):
    small = Tracer(capacity=8)
    prev = set_tracer(small.enable())
    try:
        for i in range(20):
            with span("s", i=i):
                pass
        recs = sorted(small.records(), key=lambda r: r["seq"])
        assert small.dropped == 12  # 20 written - 8 kept, exactly
        assert len(recs) == 8
        # the newest window survives, in order
        assert [r["attrs"]["i"] for r in recs] == list(range(12, 20))
    finally:
        set_tracer(prev)


def test_ring_write_never_blocks():
    ring = SpanRing(tid=1, capacity=4)
    for i in range(100):
        ring.write(0, 0, float(i), float(i) + 0.5, 0, -1)
    assert ring.total == 100
    assert ring.dropped == 96


def test_threaded_writers_no_torn_records(tracer):
    """MVCC stress: reader snapshots while writer threads wrap their
    rings; every surfaced record must be internally consistent."""
    small = Tracer(capacity=64)
    prev = set_tracer(small.enable())
    stop = threading.Event()

    def writer(tid):
        i = 0
        while not stop.is_set():
            with span("w", tid=tid, i=i):
                pass
            i += 1

    try:
        threads = [threading.Thread(target=writer, args=(t,)) for t in range(3)]
        for t in threads:
            t.start()
        torn = []
        for _ in range(200):  # hammer the stable read mid-flight
            for r in small.records():
                # a torn slot would mix fields from two records: name or
                # attrs from one write, timestamps from another
                if r["name"] != "w" or r["t1"] < r["t0"] or "i" not in r["attrs"]:
                    torn.append(r)
        stop.set()
        for t in threads:
            t.join()
        assert torn == []
        # and a quiescent read agrees with the monotone totals
        total = sum(ring.total for ring in small._rings.values())
        assert total == sum(1 for _ in small.records()) + small.dropped
    finally:
        stop.set()
        set_tracer(prev)


# ----------------------------------------------------------- histograms
def test_histogram_percentiles_vs_numpy():
    rng = np.random.default_rng(0)
    xs = rng.lognormal(mean=-6.0, sigma=2.0, size=5000)
    h = Histogram()
    for x in xs:
        h.observe(float(x))
    for q in (50, 90, 99):
        exact = float(np.percentile(xs, q))
        approx = h.percentile(q)
        # log-bucketed: 20 buckets/decade => <= 10^(1/20) ~ 12% rel error
        assert approx == pytest.approx(exact, rel=0.13), q
    s = h.summary()
    assert s["count"] == len(xs)
    assert s["sum"] == pytest.approx(float(xs.sum()))
    assert s["min"] <= h.percentile(50) <= s["max"]


def test_histogram_percentile_clamped_to_observed():
    h = Histogram()
    h.observe(3e-3)
    assert h.percentile(0) == pytest.approx(3e-3, rel=0.13)
    assert h.percentile(100) == pytest.approx(3e-3, rel=0.13)


def test_signed_histogram_abs_percentile():
    h = Histogram(signed=True)
    vals = [-0.8, -0.4, 0.1, 0.2, 0.5]
    for v in vals:
        h.observe(v)
    med = h.abs_percentile(50)
    assert med == pytest.approx(0.4, rel=0.13)
    # merge keeps the signed layout
    h2 = Histogram(signed=True)
    h2.observe(-2.0)
    h2.merge(h)
    assert h2.count == 6
    assert h2.abs_percentile(100) == pytest.approx(2.0, rel=0.13)


def test_registry_views_and_snapshot():
    m = MetricsRegistry()
    m.counter("queries").inc(3)
    m.histogram("phase_s", phase="filter", backend="grid").observe(1e-3)
    m.derived("ratio", lambda: 0.5)
    snap = m.snapshot()
    assert snap["queries"] == 3
    assert snap["ratio"] == 0.5
    assert any(k.startswith("phase_s{") for k in snap)
    found = m.find("phase_s")
    assert len(found) == 1
    labels, h = found[0]
    assert labels == {"phase": "filter", "backend": "grid"}
    assert h.count == 1
    # same (name, labels) resolves to the same object
    assert m.histogram("phase_s", backend="grid", phase="filter") is h


# ------------------------------------------------- engine integration
def _small_engine(**kw):
    from repro.core.engine import RkNNConfig, RkNNEngine

    rng = np.random.default_rng(7)
    F = rng.uniform(0, 100, (50, 2))
    U = rng.uniform(0, 100, (200, 2))
    return RkNNEngine(F, U, RkNNConfig(backend=kw.pop("backend", "grid")), **kw)


def test_engine_stats_are_registry_views(tracer):
    eng = _small_engine()
    res = eng.query(3, k=2)
    assert eng.stats.n_queries == 1
    assert eng.stats.t_verify_s > 0.0
    assert eng.stats.t_filter_s == pytest.approx(res.t_filter_s)
    # the view is live: another query moves the same object's fields
    eng.query(4, k=2)
    assert eng.stats.n_queries == 2
    # and it is genuinely backed by the registry
    assert eng.metrics.counter("queries").value == 2
    snap = eng.metrics.snapshot()
    assert any(k.startswith("phase_s{") for k in snap)


def test_engine_spans_nest_filter_verify(tracer):
    eng = _small_engine()
    eng.query_batch([3, 7], k=2)
    recs = spans(tracer)
    names = [r["name"] for r in recs]
    assert "batch" in names and "filter" in names and "verify" in names
    batch = next(r for r in recs if r["name"] == "batch")
    for child in ("filter", "verify"):
        r = next(x for x in recs if x["name"] == child)
        assert r["depth"] == batch["depth"] + 1


def test_sharded_trace_has_per_shard_children(tracer):
    from repro.shard import ShardedEngine

    rng = np.random.default_rng(3)
    F = rng.uniform(0, 100, (60, 2))
    U = rng.uniform(0, 100, (400, 2))
    eng = ShardedEngine(F, U, backend="grid", shards=4)
    eng.query_batch([1, 5, 9], k=2)
    recs = spans(tracer)
    sv = [r for r in recs if r["name"] == "shard-verify"]
    assert {r["attrs"]["shard"] for r in sv} == {0, 1, 2, 3}
    verify = next(r for r in recs if r["name"] == "verify")
    assert all(r["depth"] == verify["depth"] + 1 for r in sv)
    # the Chrome exporter round-trips the same structure
    obj = chrome_trace(tracer)
    back = summarize(_from_chrome(obj))
    assert any(label.startswith("shard-verify") for label in back)
    assert obj["otherData"]["dropped_spans"] == 0
    json.dumps(obj)  # serializable as-is


def test_continuous_drop_and_prune_counters():
    from repro.dynamic import DynamicEngine

    rng = np.random.default_rng(11)
    F = rng.uniform(0, 100, (40, 2))
    U = rng.uniform(0, 100, (150, 2))
    eng = DynamicEngine(F, U, backend="grid")
    cq = eng.register_continuous(2, 2)
    # shrink the event buffer so drops are reachable in-test
    import collections

    cq._events = collections.deque(cq._events, maxlen=1)
    for i in range(6):
        eng.apply_updates(
            facility_move=(np.array([2]), rng.uniform(0, 100, (1, 2)))
        )
    assert cq.events_dropped + len(cq._events) == cq.n_events
    assert eng.stats.events_dropped == cq.events_dropped
    cq.close()
    eng.apply_updates(user_move=(np.array([0]), rng.uniform(0, 100, (1, 2))))
    assert eng.stats.continuous_pruned == 1
    # dropped counter surfaces in the flat snapshot too
    if cq.events_dropped:
        assert eng.metrics.snapshot()["continuous.events_dropped"] == (
            cq.events_dropped
        )


def test_writer_throttle_duty_gauge_idle_is_zero():
    from repro.dynamic import DynamicEngine

    rng = np.random.default_rng(13)
    F = rng.uniform(0, 100, (40, 2))
    U = rng.uniform(0, 100, (150, 2))
    eng = DynamicEngine(F, U, backend="grid")
    eng.query(1, k=2)
    eng.apply_updates(facility_move=(np.array([4]), rng.uniform(0, 100, (1, 2))))
    # no concurrent readers bumped the clock mid-update: duty must be 0
    assert eng.metrics.snapshot().get("mvcc.writer_throttle_duty", 0.0) == 0.0


def test_update_spans_recorded(tracer):
    from repro.dynamic import DynamicEngine

    rng = np.random.default_rng(17)
    F = rng.uniform(0, 100, (40, 2))
    U = rng.uniform(0, 100, (150, 2))
    eng = DynamicEngine(F, U, backend="grid")
    eng.query(1, k=2)  # standing scene -> migrate has work to do
    eng.apply_updates(facility_move=(np.array([1]), rng.uniform(0, 100, (1, 2))))
    names = {r["name"] for r in tracer.records()}
    assert "update" in names and "migrate" in names
    upd = next(r for r in tracer.records() if r["name"] == "update")
    assert upd["attrs"]["version"] == 1
