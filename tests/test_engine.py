"""The stateful RkNNEngine and its pluggable backend registry.

Covers the engine-PR acceptance surface:

* engine ↔ free-function equivalence: masks AND counts bit-identical
  across all five registered backends, single + batch + mono;
* scene-cache amortization visible in ``t_filter_s`` on the batched path;
* ``stream()`` / ``serve_stream`` re-raise producer exceptions instead of
  hanging;
* empty-batch normalization (``scenes`` is None for brute, a list for
  geometric backends, in both the empty and non-empty cases);
* registry behaviour: unknown names raise, custom backends plug in
  without touching any dispatch ladder.
"""

import numpy as np
import pytest

from repro.core.backends import (
    Backend,
    BruteBackend,
    available_backends,
    get_backend,
    register_backend,
    _REGISTRY,
)
from repro.core.brute import rknn_brute_np, rknn_mono_brute_np
from repro.core.engine import RkNNConfig, RkNNEngine
from repro.core.rknn import (
    BACKENDS,
    rknn_mono_query,
    rt_rknn_query,
    rt_rknn_query_batch,
)
from repro.launch.serve import RkNNServer


def _instance(seed, M=50, N=300):
    rng = np.random.default_rng(seed)
    return rng.random((M, 2)), rng.random((N, 2)), rng


# ---------------------------------------------------------------- equivalence
@pytest.mark.parametrize("backend", BACKENDS)
def test_engine_matches_free_functions_single_and_batch(backend):
    F, U, rng = _instance(3)
    eng = RkNNEngine(F, U, RkNNConfig(backend=backend))
    qs = [int(q) for q in rng.integers(0, len(F), 5)] + [np.array([0.4, 0.6])]
    k = 4
    batch_eng = eng.query_batch(qs, k)
    batch_free = rt_rknn_query_batch(F, U, qs, k, backend=backend)
    np.testing.assert_array_equal(batch_eng.masks, batch_free.masks)
    np.testing.assert_array_equal(batch_eng.counts, batch_free.counts)
    for i, q in enumerate(qs):
        single_eng = eng.query(q, k)
        single_free = rt_rknn_query(F, U, q, k, backend=backend)
        np.testing.assert_array_equal(single_eng.mask, single_free.mask)
        np.testing.assert_array_equal(single_eng.counts, single_free.counts)
        np.testing.assert_array_equal(batch_eng.masks[i], single_eng.mask)
        np.testing.assert_array_equal(batch_eng.counts[i], single_eng.counts)
        if not isinstance(q, np.ndarray):
            np.testing.assert_array_equal(
                single_eng.mask, rknn_brute_np(U, F, q, k)
            )


@pytest.mark.parametrize("backend", ["dense-ref", "grid", "bvh", "brute"])
def test_engine_mono_matches_free_function(backend):
    P = np.random.default_rng(17).random((60, 2))
    eng = RkNNEngine(P, P, RkNNConfig(backend=backend))
    for qi, k in ((5, 3), (20, 1)):
        a = eng.query_mono(qi, k)
        b = rknn_mono_query(P, qi, k, backend=backend)
        np.testing.assert_array_equal(a.mask, b.mask)
        np.testing.assert_array_equal(a.counts, b.counts)
        np.testing.assert_array_equal(a.mask, rknn_mono_brute_np(P, qi, k))


def test_engine_mono_from_bichromatic_engine():
    """query_mono on an engine whose users ≠ facilities runs over the
    facility set via a lazily created sub-engine — which inherits an
    explicit rect and mirrors its work into the outer engine's stats."""
    from repro.core.geometry import Rect

    F, U, _ = _instance(23)
    eng = RkNNEngine(F, U)
    res = eng.query_mono(4, 3)
    np.testing.assert_array_equal(res.mask, rknn_mono_brute_np(F, 4, 3))
    assert eng.stats.n_queries == 1 and eng.stats.t_verify_s > 0.0

    rect = Rect(-0.5, -0.5, 1.5, 1.5)
    eng_r = RkNNEngine(F, U, rect=rect)
    res_r = eng_r.query_mono(4, 3)
    assert res_r.scene.rect == rect
    np.testing.assert_array_equal(res_r.mask, rknn_mono_brute_np(F, 4, 3))


# ------------------------------------------------------------- amortization
def test_scene_cache_amortizes_batch_filter_phase():
    F, U, rng = _instance(31, M=120, N=2000)
    qs = [int(q) for q in rng.integers(0, len(F), 8)]
    eng = RkNNEngine(F, U, RkNNConfig(backend="dense-ref", batch_cache=0))
    cold = eng.query_batch(qs, 5)
    assert eng.scene_cache.misses == len(set(qs))
    warm = eng.query_batch(qs, 5)
    # hot queries skip the host scene build: cache hits, collapsed filter
    assert eng.scene_cache.hits >= len(qs)
    assert warm.t_filter_s < cold.t_filter_s
    np.testing.assert_array_equal(cold.masks, warm.masks)


def test_batch_cache_collapses_repeat_workload():
    F, U, rng = _instance(37)
    qs = [int(q) for q in rng.integers(0, len(F), 6)]
    eng = RkNNEngine(F, U, RkNNConfig(backend="grid"))
    a = eng.query_batch(qs, 4)
    b = eng.query_batch(qs, 4)
    assert eng.stats.batch_cache_hits == 1
    assert b.t_filter_s < a.t_filter_s
    np.testing.assert_array_equal(a.masks, b.masks)
    np.testing.assert_array_equal(a.counts, b.counts)
    # a different k is a different workload — no false sharing
    c = eng.query_batch(qs, 5)
    assert eng.stats.batch_cache_hits == 1
    np.testing.assert_array_equal(c.masks, rt_rknn_query_batch(F, U, qs, 5, backend="grid").masks)


def test_batch_reuses_memoized_scene_indexes():
    """Scene-cache hits carry their grid/BVH index across batches: a second
    batch with a different composition must not rebuild indexes for scenes
    it already saw (the snapshot's index memo is keyed on the scene
    object)."""
    F, U, rng = _instance(97, M=80)
    eng = RkNNEngine(F, U, RkNNConfig(backend="grid", batch_cache=0))
    eng.query_batch([1, 2, 3], 4)
    scene1 = eng.scene_cache.get_or_build(F, 1, 4, eng.rect)[0]
    memo = eng._snap.index_memo.peek(scene1)
    assert memo is not None and ("grid", eng.config.grid_g) in memo
    idx_before = memo[("grid", eng.config.grid_g)]
    res = eng.query_batch([1, 5], 4)  # new composition, scene 1 cached
    memo = eng._snap.index_memo.peek(scene1)
    assert memo[("grid", eng.config.grid_g)] is idx_before
    np.testing.assert_array_equal(
        res.masks, rt_rknn_query_batch(F, U, [1, 5], 4, backend="grid").masks
    )


def test_pad_bucket_is_sticky_power_of_two():
    F, U, rng = _instance(41, M=80)
    eng = RkNNEngine(F, U)
    eng.query_batch([0, 1, 2], 3)
    b1 = eng._pad_bucket
    assert b1 & (b1 - 1) == 0  # power of two
    eng.query_batch([3, 4], 2)
    assert eng._pad_bucket >= b1  # never shrinks → jit traces are reused


# ------------------------------------------------------------------ stream
def test_stream_matches_batch_and_counts_stats():
    F, U, rng = _instance(43)
    eng = RkNNEngine(F, U)
    batches = [np.array([1, 2, 3]), np.array([4, 5])]
    seen = {}
    for i, (qb, masks) in enumerate(eng.stream(batches, 4)):
        assert qb is batches[i]  # the original batch object is yielded back
        for qi, m in zip(qb, masks):
            seen[int(qi)] = m
    assert eng.stats.n_queries == 5
    for qi, m in seen.items():
        np.testing.assert_array_equal(m, rknn_brute_np(U, F, qi, 4))


def test_stream_reraises_producer_exception():
    F, U, _ = _instance(47)
    eng = RkNNEngine(F, U)

    def bad_batches():
        yield [0, 1]
        raise RuntimeError("batch source failed")

    stream = eng.stream(bad_batches(), 3)
    next(stream)  # first batch is fine
    with pytest.raises(RuntimeError, match="batch source failed"):
        for _ in stream:
            pass


def test_serve_stream_alias_reraises_producer_exception():
    import warnings

    F, U, _ = _instance(53)
    with warnings.catch_warnings():
        # once-per-process deprecation; asserted in test_dynamic — don't
        # leak it into tier-1 output when this test triggers it first
        warnings.simplefilter("ignore", DeprecationWarning)
        server = RkNNServer(F, U)

    def bad_batches():
        raise ValueError("upstream queue died")
        yield  # pragma: no cover

    with pytest.raises(ValueError, match="upstream queue died"):
        for _ in server.serve_stream(bad_batches(), 3):
            pass


def test_stream_bad_query_index_reraises():
    """A failing scene build inside the producer thread must surface."""
    F, U, _ = _instance(59, M=20)
    eng = RkNNEngine(F, U)
    with pytest.raises(IndexError):
        for _ in eng.stream([[0], [len(F) + 5]], 3):
            pass


# ------------------------------------------------------- empty-batch contract
@pytest.mark.parametrize("backend", BACKENDS)
def test_empty_batch_normalized(backend):
    F, U, _ = _instance(61, M=20)
    empty = rt_rknn_query_batch(F, U, [], 3, backend=backend)
    assert empty.masks.shape == (0, len(U))
    assert empty.counts.shape == (0, len(U))
    assert empty.counts.dtype == np.int32
    nonempty = rt_rknn_query_batch(F, U, [0, 1], 3, backend=backend)
    if backend == "brute":
        # geometry-free: never a scenes list, empty or not
        assert empty.scenes is None and nonempty.scenes is None
        assert nonempty.per_query(0).scene is None
    else:
        assert empty.scenes == [] and len(nonempty.scenes) == 2
        assert nonempty.per_query(0).scene is nonempty.scenes[0]


# ------------------------------------------------------------------ registry
def test_get_backend_unknown_raises():
    with pytest.raises(ValueError, match="backend must be one of"):
        get_backend("voxel")
    with pytest.raises(ValueError):
        RkNNEngine(np.zeros((4, 2)), np.zeros((4, 2)), RkNNConfig(backend="voxel"))
    with pytest.raises(ValueError):
        rt_rknn_query(np.random.rand(5, 2), np.random.rand(9, 2), 0, 1, backend="nope")


def test_builtin_registration_order():
    builtin = (
        "dense",
        "dense-ref",
        "grid",
        "grid-pallas",
        "grid-pallas-ref",
        "bvh",
        "brute",
    )
    assert available_backends()[: len(builtin)] == builtin
    assert BACKENDS == builtin


def test_dense_prepare_batch_pads_from_real_tris():
    """With ``req.mp`` unset (the direct-protocol path), the stacked
    ``[Q, Mp, 3, 3]`` tensor is sized from the REAL triangle counts — a
    scene pre-padded to a big static shape must not inflate the batch."""
    import jax.numpy as jnp

    from repro.core.backends import BatchRequest
    from repro.core.geometry import Rect
    from repro.core.scene import build_scene

    F, U, rng = _instance(77, M=30)
    rect = Rect.from_points(F, U)
    scenes = [
        build_scene(F, q, 3, rect, pad_to=1024, users_hint=U) for q in (0, 1)
    ]
    assert all(s.tris.shape[0] == 1024 for s in scenes)
    assert max(s.n_tris for s in scenes) <= 128
    b = get_backend("dense-ref")
    req = BatchRequest(
        xs=jnp.asarray(U[:, 0], jnp.float32),
        ys=jnp.asarray(U[:, 1], jnp.float32),
        k=3,
        rect=rect,
        scenes=scenes,
    )
    prepared = b.prepare_batch(req)
    assert prepared.shape == (2, 128, 3, 3)  # _next_pad(max n_tris), not 1024
    counts = b.count_batch(req, prepared)
    # and the tighter pad changes nothing: same counts as the padded stack
    wide = b.count_batch(
        req, b.prepare_batch(BatchRequest(
            xs=req.xs, ys=req.ys, k=3, rect=rect, scenes=scenes, mp=1024,
        ))
    )
    np.testing.assert_array_equal(counts, wide)


def test_custom_backend_plugs_into_engine():
    calls = {"n": 0}

    @register_backend
    class CountingBrute(BruteBackend):
        name = "brute-counting"

        def count(self, req):
            calls["n"] += 1
            return super().count(req)

    try:
        assert "brute-counting" in available_backends()
        F, U, _ = _instance(67)
        eng = RkNNEngine(F, U, RkNNConfig(backend="brute-counting"))
        res = eng.query(2, 3)
        assert calls["n"] == 1
        np.testing.assert_array_equal(res.mask, rknn_brute_np(U, F, 2, 3))
        assert res.backend == "brute-counting"
    finally:
        _REGISTRY.pop("brute-counting", None)


def test_backend_protocol_defaults():
    class Noop(Backend):
        name = "noop-test"

    b = Noop()
    assert b.build_index(None) is None
    assert b.prepare_batch(None) is None
    with pytest.raises(NotImplementedError):
        b.count(None)
    with pytest.raises(NotImplementedError):
        b.count_batch(None, None)


# ------------------------------------------------------------------ mesh path
def test_engine_mesh_sharded_dense_dispatch():
    """With a mesh, dense-ref batch/stream dispatch goes through the pjit'd
    step (users sharded over data axes, queries over 'model') and stays
    bit-identical to the meshless engine."""
    from repro.launch.mesh import make_mesh_for_devices

    F, U, rng = _instance(83, M=40, N=257)
    mesh = make_mesh_for_devices(1, model_axis=1)
    eng_mesh = RkNNEngine(F, U, mesh=mesh)
    eng_plain = RkNNEngine(F, U)
    qs = [int(q) for q in rng.integers(0, len(F), 4)]
    a = eng_mesh.query_batch(qs, 5)
    b = eng_plain.query_batch(qs, 5)
    np.testing.assert_array_equal(a.masks, b.masks)
    np.testing.assert_array_equal(a.counts, b.counts)
    for qi in qs:
        np.testing.assert_array_equal(
            a.masks[qs.index(qi)], rknn_brute_np(U, F, qi, 5)
        )
    # stream goes through the same sharded dispatch
    for qb, masks in eng_mesh.stream([qs], 5):
        np.testing.assert_array_equal(masks, b.masks)


def test_engine_mesh_shards_grid_and_bvh_dispatch():
    """The grid and bvh batched dispatches shard the same way dense-ref
    does (users over data axes, queries over 'model') and stay
    bit-identical to the meshless engine — including when N is not a
    multiple of the DP degree (sentinel users sliced off)."""
    from repro.launch.mesh import make_mesh_for_devices

    F, U, rng = _instance(89, M=40, N=257)
    mesh = make_mesh_for_devices(1, model_axis=1)
    eng_mesh = RkNNEngine(F, U, mesh=mesh)
    eng_plain = RkNNEngine(F, U)
    qs = [int(q) for q in rng.integers(0, len(F), 4)]
    for backend in ("grid", "bvh"):
        a = eng_mesh.query_batch(qs, 5, backend=backend)
        b = eng_plain.query_batch(qs, 5, backend=backend)
        np.testing.assert_array_equal(a.masks, b.masks)
        np.testing.assert_array_equal(a.counts, b.counts)
        for i, qi in enumerate(qs):
            np.testing.assert_array_equal(a.masks[i], rknn_brute_np(U, F, qi, 5))
        # the sharded jitted step was actually built and used
        assert any(key[0] == backend for key in eng_mesh._mesh_steps)
    # brute stays single-device (no sharded step registered)
    eng_mesh.query_batch(qs, 5, backend="brute")
    assert not any(key[0] == "brute" for key in eng_mesh._mesh_steps)


# ------------------------------------------------------------ kernel wrappers
def test_batched_ref_user_chunking_is_exact():
    """The user-chunked batched oracle path (large N) matches the unchunked
    one bit-for-bit, including when N is not a multiple of the chunk."""
    from repro.kernels.ops import _raycast_batch_ref_chunked, raycast_count_batch

    rng = np.random.default_rng(79)
    xs = rng.random(101).astype(np.float32)
    ys = rng.random(101).astype(np.float32)
    F, _, _ = _instance(79, M=12)
    from repro.core.scene import build_scene

    scenes = [build_scene(F, qi, 3) for qi in (0, 1, 2)]
    coeffs = np.stack([s.coeffs for s in scenes]).astype(np.float32)
    full = np.asarray(raycast_count_batch(xs, ys, coeffs, backend="ref"))
    chunked = np.asarray(_raycast_batch_ref_chunked(xs, ys, coeffs, chunk=16))
    np.testing.assert_array_equal(full, chunked)


# ---------------------------------------------------------------- rect edges
def test_engine_handles_out_of_hull_point_queries():
    """A query point outside the facility∪user hull extends the domain rect
    for that call only (bit-compatible with the old per-call rect)."""
    F, U, _ = _instance(71)
    eng = RkNNEngine(F, U)
    q_out = np.array([1.5, 1.7])
    res = eng.query(q_out, 4)
    np.testing.assert_array_equal(res.mask, rknn_brute_np(U, F, q_out, 4))
    free = rt_rknn_query(F, U, q_out, 4)
    np.testing.assert_array_equal(res.mask, free.mask)
    np.testing.assert_array_equal(res.counts, free.counts)
    # shared rect unchanged for subsequent in-hull queries
    res_in = eng.query(0, 4)
    np.testing.assert_array_equal(res_in.mask, rknn_brute_np(U, F, 0, 4))


def test_explicit_rect_is_respected():
    from repro.core.geometry import Rect

    F, U, _ = _instance(73)
    rect = Rect(-1.0, -1.0, 2.0, 2.0)
    eng = RkNNEngine(F, U, rect=rect)
    res = eng.query(1, 3)
    assert res.scene.rect == rect
    free = rt_rknn_query(F, U, 1, 3, rect=rect)
    np.testing.assert_array_equal(res.counts, free.counts)
