"""End-to-end correctness of the RT-RkNN formulation (Lemma 3.4 etc.).

* Equivalence: ``hit-count < k  ⟺  brute-force rank < k`` for every
  backend (dense kernel, dense ref, grid, BVH-with-early-exit, brute).
* Pruning neutrality: InfZone-style and conservative pruning never change
  the answer set vs non-pruned scenes.
* Backend agreement on raw counts (where early exit doesn't saturate).
* Monochromatic reduction.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 environment: replay over a fixed seed sweep
    from tests._hyp import given, settings, strategies as st

from repro.core.brute import rknn_brute_np, rknn_mono_brute_np
from repro.core.bvh import build_bvh, bvh_hit_counts
from repro.core.geometry import Rect, points_in_tris_np
from repro.core.grid import build_grid, grid_hit_counts_jnp
from repro.core.rknn import BACKENDS, rknn_mono_query, rt_rknn_query
from repro.core.scene import build_scene

RECT = Rect(0.0, 0.0, 1.0, 1.0)


def _instance(seed, M=60, N=400):
    rng = np.random.default_rng(seed)
    return rng.random((M, 2)), rng.random((N, 2)), rng


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("seed,k", [(0, 1), (1, 3), (2, 10), (3, 25)])
def test_backends_match_brute(backend, seed, k):
    F, U, rng = _instance(seed)
    qi = int(rng.integers(0, len(F)))
    res = rt_rknn_query(F, U, qi, k, backend=backend)
    truth = rknn_brute_np(U, F, qi, k)
    np.testing.assert_array_equal(res.mask, truth)


@pytest.mark.parametrize("strategy", ["infzone", "conservative", "none"])
def test_pruning_neutrality(strategy):
    for seed in range(8):
        F, U, rng = _instance(seed, M=100, N=500)
        k = int(rng.integers(1, 12))
        qi = int(rng.integers(0, len(F)))
        res = rt_rknn_query(F, U, qi, k, backend="dense-ref", strategy=strategy)
        np.testing.assert_array_equal(res.mask, rknn_brute_np(U, F, qi, k))


def test_pruning_reduces_occluders():
    F, U, rng = _instance(11, M=1000, N=100)
    qi = 0
    pruned = build_scene(F, qi, 10, RECT, strategy="infzone")
    full = build_scene(F, qi, 10, RECT, strategy="none")
    assert pruned.n_occluders < full.n_occluders / 5  # paper Table 3 regime


@given(st.integers(0, 10_000), st.integers(1, 12))
@settings(max_examples=40, deadline=None)
def test_equivalence_property(seed, k):
    """Lemma 3.4 as a hypothesis property over random instances."""
    rng = np.random.default_rng(seed)
    F = rng.random((int(rng.integers(5, 80)), 2))
    U = rng.random((200, 2))
    qi = int(rng.integers(0, len(F)))
    sc = build_scene(F, qi, k, RECT, strategy="none")
    hits = points_in_tris_np(U, sc.coeffs.astype(np.float64)).sum(axis=1)
    np.testing.assert_array_equal(hits < k, rknn_brute_np(U, F, qi, k))


def test_grid_and_bvh_counts_equal_dense():
    F, U, rng = _instance(5, M=120, N=600)
    qi = 7
    sc = build_scene(F, qi, 6, RECT, strategy="infzone")
    dense = points_in_tris_np(U, sc.coeffs.astype(np.float64)).sum(axis=1)
    g = build_grid(sc.tris[: sc.n_tris], sc.coeffs[: sc.n_tris], RECT, G=48)
    gc = np.asarray(
        grid_hit_counts_jnp(U[:, 0], U[:, 1], g.base, g.lists, g.coeffs, RECT, 48)
    )
    np.testing.assert_array_equal(gc, dense)
    bvh = build_bvh(sc.tris[: sc.n_tris])
    bc = np.asarray(
        bvh_hit_counts(
            U[:, 0], U[:, 1], bvh.left, bvh.right, bvh.bbox, sc.coeffs[: sc.n_tris]
        )
    )
    np.testing.assert_array_equal(bc, dense)


def test_bvh_early_exit_saturates_at_k():
    F, U, rng = _instance(6, M=80, N=300)
    qi = 2
    k = 4
    sc = build_scene(F, qi, k, RECT, strategy="none")
    bvh = build_bvh(sc.tris[: sc.n_tris])
    counts = np.asarray(
        bvh_hit_counts(
            U[:, 0], U[:, 1], bvh.left, bvh.right, bvh.bbox, sc.coeffs[: sc.n_tris], k=k
        )
    )
    assert counts.max() <= k
    np.testing.assert_array_equal(counts < k, rknn_brute_np(U, F, qi, k))


@pytest.mark.parametrize("backend", ["dense-ref", "brute", "grid", "bvh"])
def test_monochromatic(backend):
    rng = np.random.default_rng(9)
    for _ in range(5):
        P = rng.random((70, 2))
        qi = int(rng.integers(0, 70))
        k = int(rng.integers(1, 6))
        res = rknn_mono_query(P, qi, k, backend=backend)
        np.testing.assert_array_equal(res.mask, rknn_mono_brute_np(P, qi, k))


@pytest.mark.parametrize("backend", ["dense-ref", "grid", "bvh"])
def test_mono_counts_self_hit_corrected(backend):
    """Regression for the mono off-by-one: ``counts`` must be self-hit
    corrected (number of OTHER points strictly closer than q), so that
    ``mask == counts < k`` and ``counts[mask]`` equal the mono brute ranks
    exactly (outside the mask they may sit at a saturated lower bound)."""
    rng = np.random.default_rng(21)
    P = rng.random((60, 2))
    qi, k = 5, 4
    res = rknn_mono_query(P, qi, k, backend=backend)
    # brute rank oracle: #others strictly closer to p than q is (a != p, q)
    q = P[qi]
    d2q = np.sum((P - q) ** 2, axis=1)
    d2 = np.sum((P[:, None, :] - P[None, :, :]) ** 2, axis=-1)
    closer = d2 < d2q[:, None]
    np.fill_diagonal(closer, False)
    closer[:, qi] = False
    want = closer.sum(axis=1)
    np.testing.assert_array_equal(res.mask, rknn_mono_brute_np(P, qi, k))
    np.testing.assert_array_equal(res.counts[res.mask], want[res.mask])
    mask_from_counts = res.counts < k
    mask_from_counts[qi] = False
    np.testing.assert_array_equal(res.mask, mask_from_counts)
    assert np.all(res.counts[~res.mask] >= 0)  # never negative after correction


def test_query_point_not_in_facility_set():
    """q may be an arbitrary point (bichromatic with external query)."""
    F, U, rng = _instance(12)
    q = np.array([0.37, 0.61])
    res = rt_rknn_query(F, U, q, 5, backend="dense-ref")
    truth = rknn_brute_np(U, F, q, 5)
    np.testing.assert_array_equal(res.mask, truth)


def test_k_one_and_k_huge():
    F, U, rng = _instance(13, M=30)
    qi = 3
    res1 = rt_rknn_query(F, U, qi, 1, backend="dense-ref")
    np.testing.assert_array_equal(res1.mask, rknn_brute_np(U, F, qi, 1))
    res2 = rt_rknn_query(F, U, qi, len(F) + 5, backend="dense-ref")
    assert res2.mask.all()  # k >= |F| accepts everyone
