"""Property tests for the geometric core (hypothesis-driven).

The central invariant (paper Def. 3.1): for any facility pair, the occluder
triangles' coverage *inside the domain* equals the bisector's invalid
half-plane.  Plus edge-function/clip/area unit checks.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 environment: replay over a fixed seed sweep
    from tests._hyp import given, settings, strategies as st

from repro.core.geometry import (
    Rect,
    bisector,
    clip_polygon_halfplane,
    edge_coeffs,
    ensure_ccw,
    points_in_tris_np,
    polygon_area,
    signed_area,
)
from repro.core.occluders import occluder_triangles

RECT = Rect(0.0, 0.0, 1.0, 1.0)
coord = st.floats(0.01, 0.99, allow_nan=False, allow_infinity=False)


@st.composite
def facility_pair(draw):
    ax, ay = draw(coord), draw(coord)
    qx, qy = draw(coord), draw(coord)
    # keep the pair separated so the bisector is well-conditioned
    if abs(ax - qx) + abs(ay - qy) < 1e-3:
        qx = min(0.99, qx + 0.1)
    return np.array([ax, ay]), np.array([qx, qy])


@given(facility_pair(), st.integers(0, 10_000))
@settings(max_examples=200, deadline=None)
def test_occluder_equals_invalid_halfplane(pair, seed):
    a, q = pair
    tris = occluder_triangles(a, q, RECT)
    rng = np.random.default_rng(seed)
    pts = RECT.sample(rng, 256)
    n, c = bisector(a, q)
    margin = 1e-9 * (1 + abs(c))
    strict_invalid = pts @ n - c < -margin
    strict_valid = pts @ n - c > margin
    if len(tris):
        inside = points_in_tris_np(pts, edge_coeffs(tris)).any(axis=1)
    else:
        inside = np.zeros(len(pts), bool)
    assert not np.any(strict_invalid & ~inside), "invalid-side point not covered"
    assert not np.any(strict_valid & inside), "valid-side point wrongly covered"


@pytest.mark.parametrize(
    "a,q",
    [
        ((0.2, 0.5), (0.8, 0.5)),  # vertical bisector (case c)
        ((0.5, 0.1), (0.5, 0.9)),  # horizontal bisector (case d)
        ((0.3, 0.3), (0.3, 0.8)),
        ((0.1, 0.1), (0.9, 0.9)),  # diagonal, extended case likely
        ((0.45, 0.5), (0.55, 0.5)),
    ],
)
def test_axis_aligned_and_diagonal_cases(a, q):
    a, q = np.asarray(a, float), np.asarray(q, float)
    tris = occluder_triangles(a, q, RECT)
    assert 1 <= len(tris) <= 2
    rng = np.random.default_rng(0)
    pts = RECT.sample(rng, 4096)
    n, c = bisector(a, q)
    inv = pts @ n - c < -1e-12
    val = pts @ n - c > 1e-12
    inside = points_in_tris_np(pts, edge_coeffs(tris)).any(axis=1)
    assert not np.any(inv & ~inside) and not np.any(val & inside)


def test_degenerate_pair_empty():
    a = np.array([0.5, 0.5])
    assert len(occluder_triangles(a, a, RECT)) == 0


def test_edge_coeffs_orientation_invariance():
    tri = np.array([[[0.1, 0.1], [0.9, 0.2], [0.4, 0.8]]])
    rng = np.random.default_rng(3)
    pts = RECT.sample(rng, 512)
    inside_ccw = points_in_tris_np(pts, edge_coeffs(ensure_ccw(tri)))
    flipped = tri[:, ::-1, :]
    inside_flip = points_in_tris_np(pts, edge_coeffs(ensure_ccw(flipped)))
    np.testing.assert_array_equal(inside_ccw, inside_flip)
    assert signed_area(ensure_ccw(flipped))[0] > 0


@given(facility_pair())
@settings(max_examples=100, deadline=None)
def test_clip_area_consistency(pair):
    """Shoelace area of the clipped invalid polygon equals MC estimate."""
    a, q = pair
    n, c = bisector(a, q)
    poly = clip_polygon_halfplane(RECT.as_polygon(), n, c)  # p.n <= c side
    area = abs(polygon_area(poly))
    rng = np.random.default_rng(0)
    pts = RECT.sample(rng, 20_000)
    mc = float(np.mean(pts @ n - c < 0))
    assert abs(area - mc) < 0.02


def test_degenerate_triangle_coeffs_never_inside():
    tri = np.array([[[0.5, 0.5], [0.5, 0.5], [0.5, 0.5]]])
    cf = edge_coeffs(tri)
    pts = np.array([[0.5, 0.5], [0.1, 0.9]])
    assert not points_in_tris_np(pts, cf).any()
