"""The persistence layer (ISSUE 10): ``rknn-store/1`` save / warm-restore.

Covers the PR acceptance surface:

* **crash-mid-write recovery** (satellite bugfix): stranded ``step_*.tmp``
  leftovers and manifests listing lost leaf files are *skipped* by the
  newest-complete-step fallback, and an explicitly requested incomplete
  step raises a clear ``FileNotFoundError`` instead of a bare np.load
  crash;
* **round-trip property**: random scenarios × every registered concrete
  backend × shards {1, 4} — save → restore → query is bit-identical to
  the cold engine (masks, counts, mono), including after an
  ``apply_updates`` stream on top of the restored snapshot;
* **cross-process restore**: a fresh interpreter (different hash salt —
  the in-memory ``SceneCache.fingerprint`` is *not* portable) restores
  the store and serves identical masks without rebuilding a scene;
* **partial invalidation**: per-category fingerprints — a user-set
  change invalidates dataset/scenes but the (data-independent,
  hardware-keyed) planner profile survives; a store is never trusted
  across a schema change;
* **MVCC hot-adopt**: ``engine.restore(dir)`` on a live engine publishes
  the store as version N+1 via the atomic swap;
* **observability**: restore emits ``persist.restore_s`` /
  ``persist.bytes`` metrics and ``/snapshot`` reports the active store.
"""

import http.client
import json
import os
import shutil
import subprocess
import sys

import numpy as np
import pytest

from repro.checkpoint.store import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
    save_state,
)
from repro.core.backends import concrete_backends
from repro.core.engine import RkNNConfig, RkNNEngine
from repro.dynamic import DynamicEngine
from repro.persist import SCHEMA, expected_fingerprints
from repro.planner.profiles import (
    PlannerProfile,
    get_active_profile,
    hardware_fingerprint,
    set_active_profile,
)
from repro.shard.engine import ShardedEngine


def _instance(seed, M=40, N=250):
    rng = np.random.default_rng(seed)
    F = rng.uniform(0.0, 100.0, (M, 2))
    U = rng.uniform(0.0, 100.0, (N, 2))
    return F, U, rng


def _results(eng, queries, k):
    return [eng.query(q, k) for q in queries]


def _same(a, b):
    return bool(
        np.array_equal(np.asarray(a.mask), np.asarray(b.mask))
        and np.array_equal(np.asarray(a.counts), np.asarray(b.counts))
    )


@pytest.fixture(autouse=True)
def _no_active_profile():
    """Persist tests manipulate the process-global planner profile."""
    prev = get_active_profile()
    set_active_profile(None)
    yield
    set_active_profile(prev)


# ------------------------------------------------------- crash-mid-write
def test_crash_mid_write_recovery(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"w": np.arange(12.0).reshape(3, 4), "b": np.zeros(3)}
    save_checkpoint(d, 0, tree)
    tree2 = {"w": tree["w"] + 1, "b": tree["b"] + 1}
    save_checkpoint(d, 1, tree2)

    # crash scenario A: a stranded .tmp dir from a save that died mid-write
    os.makedirs(os.path.join(d, "step_000000000002.tmp"))
    # crash scenario B: step 3's manifest exists but a leaf was lost
    save_checkpoint(d, 3, tree2)
    victim = os.path.join(d, "step_000000000003")
    leaf = json.load(open(os.path.join(victim, "manifest.json")))["leaves"]["w"]["file"]
    os.remove(os.path.join(victim, leaf))

    # newest *complete* step wins; neither leftover trips the reader
    assert latest_step(d) == 1
    restored, manifest = restore_checkpoint(d, tree)
    assert manifest["step"] == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]), tree2["w"])

    # explicitly asking for the incomplete step names the missing leaf
    with pytest.raises(FileNotFoundError, match="incomplete"):
        restore_checkpoint(d, tree, step=3)

    # the state-store reader obeys the same completeness contract
    save_state(d, 5, {"c": {"fingerprint": "x", "meta": {},
                            "arrays": {"a": np.ones(4)}}}, schema=SCHEMA)
    folder = os.path.join(d, "step_000000000005")
    os.remove(os.path.join(folder, "c__a.npy"))
    assert latest_step(d) == 1


# ------------------------------------------------- round-trip property
@pytest.mark.parametrize("backend", concrete_backends())
@pytest.mark.parametrize("n_shards", [1, 4])
def test_roundtrip_bit_identical(tmp_path, backend, n_shards):
    """save → restore → query ≡ cold, per backend × shard count,
    including after an update stream on the restored snapshot."""
    F, U, rng = _instance(seed=7 + n_shards)
    cfg = RkNNConfig(backend=backend, grid_g=16)
    queries, k = [0, 3, 11], 6

    cold = ShardedEngine(F, U, cfg, shards=n_shards)
    want = _results(cold, queries, k)
    d = str(tmp_path / "store")
    cold.save_state(d)

    warm = ShardedEngine(
        F, U, RkNNConfig(backend=backend, grid_g=16, warm_store=d),
        shards=n_shards,
    )
    cats = warm.persist_info["categories"]
    assert cats["dataset"]["status"] == "restored"
    from repro.core.backends import get_backend

    if get_backend(backend).uses_scene:
        assert cats["scenes"]["status"] == "restored"
    got = _results(warm, queries, k)
    assert all(_same(c, w) for c, w in zip(want, got))
    # the cached working set really was adopted: zero scene rebuilds
    assert warm._snap.scene_cache.misses == 0

    # mono path rides the same restored state
    assert _same(cold.query_mono(queries[0], k), warm.query_mono(queries[0], k))

    # updates on top of the restored snapshot stay cold-equivalent
    ins = rng.uniform(0.0, 100.0, (3, 2))
    mv = rng.choice(len(U), 10, replace=False)
    pts = rng.uniform(0.0, 100.0, (10, 2))
    for eng in (cold, warm):
        eng.apply_updates(facility_insert=ins, user_move=(mv, pts))
    assert all(_same(c, w) for c, w in zip(
        _results(cold, queries, k), _results(warm, queries, k)))


# --------------------------------------------------- cross-process restore
def test_cross_process_restore(tmp_path):
    """A fresh interpreter (fresh hash salt) restores the store and
    serves identical masks with zero scene rebuilds — proves no salted
    in-memory fingerprint leaked into the manifest."""
    F, U, _ = _instance(seed=11)
    d = str(tmp_path / "store")
    eng = RkNNEngine(F, U, RkNNConfig(backend="grid", grid_g=16))
    want = np.stack([np.asarray(r.mask) for r in _results(eng, [0, 2, 5], 8)])
    eng.save_state(d)
    np.save(tmp_path / "F.npy", F)
    np.save(tmp_path / "U.npy", U)

    prog = f"""
import numpy as np
from repro.core.engine import RkNNConfig, RkNNEngine
F = np.load({str(tmp_path / 'F.npy')!r}); U = np.load({str(tmp_path / 'U.npy')!r})
eng = RkNNEngine(F, U, RkNNConfig(backend="grid", grid_g=16, warm_store={d!r}))
cats = eng.persist_info["categories"]
assert cats["scenes"]["status"] == "restored", cats
masks = np.stack([np.asarray(eng.query(q, 8).mask) for q in (0, 2, 5)])
assert eng._snap.scene_cache.misses == 0, "restored working set was rebuilt"
np.save({str(tmp_path / 'warm_masks.npy')!r}, masks)
"""
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("PYTHONHASHSEED", None)  # a fresh random salt is the point
    r = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True, env=env
    )
    assert r.returncode == 0, r.stderr[-2000:]
    got = np.load(tmp_path / "warm_masks.npy")
    np.testing.assert_array_equal(got, want)


# --------------------------------------------------- partial invalidation
def test_partial_invalidation_user_change(tmp_path):
    """Per-category fingerprints: a user-set change invalidates the
    data-keyed categories but the hardware-keyed planner profile is
    adopted untouched."""
    F, U, rng = _instance(seed=13)
    set_active_profile(
        PlannerProfile(hardware=hardware_fingerprint(), source="test", models={})
    )
    eng = RkNNEngine(F, U, RkNNConfig(backend="grid", grid_g=16))
    _results(eng, [0, 1], 6)
    d = str(tmp_path / "store")
    eng.save_state(d)
    assert "planner" in eng.persist_info["categories"]

    set_active_profile(None)
    U2 = rng.uniform(0.0, 150.0, (len(U) + 40, 2))  # moves the hull rect too
    warm = RkNNEngine(F, U2, RkNNConfig(backend="grid", grid_g=16, warm_store=d))
    cats = warm.persist_info["categories"]
    assert cats["planner"]["status"] == "restored"
    assert get_active_profile() is not None
    assert cats["dataset"]["status"] == "stale"
    assert cats["scenes"]["status"] == "stale"
    # stale scene category really was NOT adopted
    assert len(warm._snap.scene_cache) == 0

    # an installed profile is never clobbered by a restore
    marker = PlannerProfile(
        hardware=hardware_fingerprint(), source="operator", models={}
    )
    set_active_profile(marker)
    warm2 = RkNNEngine(F, U, RkNNConfig(backend="grid", grid_g=16, warm_store=d))
    assert warm2.persist_info["categories"]["planner"]["status"] == "skipped"
    assert get_active_profile() is marker


def test_schema_mismatch_rejected(tmp_path):
    F, U, _ = _instance(seed=17)
    d = str(tmp_path / "store")
    eng = RkNNEngine(F, U, RkNNConfig(backend="grid", grid_g=16))
    eng.query(0, 6)
    eng.save_state(d)
    folder = os.path.join(d, f"step_{0:012d}")
    m = json.load(open(os.path.join(folder, "manifest.json")))
    m["schema"] = "rknn-store/999"
    json.dump(m, open(os.path.join(folder, "manifest.json"), "w"))
    warm = RkNNEngine(F, U, RkNNConfig(backend="grid", grid_g=16, warm_store=d))
    assert "error" in warm.persist_info  # refused wholesale, engine still cold
    assert warm.query(0, 6) is not None


def test_expected_fingerprints_move_with_data():
    F, U, rng = _instance(seed=19)
    eng = RkNNEngine(F, U, RkNNConfig(backend="grid", grid_g=16))
    base = expected_fingerprints(eng, eng._snap)
    eng2 = RkNNEngine(F, rng.uniform(0, 100, U.shape), RkNNConfig(backend="grid", grid_g=16))
    moved = expected_fingerprints(eng2, eng2._snap)
    assert moved["dataset"] != base["dataset"]
    assert moved["kernel"] != base["kernel"]
    assert moved["planner"] == base["planner"]  # data-independent


# --------------------------------------------------------- MVCC hot-adopt
def test_hot_adopt_publishes_next_version(tmp_path):
    F, U, rng = _instance(seed=23)
    d = str(tmp_path / "store")
    src = DynamicEngine(F, U, RkNNConfig(backend="grid", grid_g=16))
    want = _results(src, [0, 4], 6)
    src.save_state(d)

    live = DynamicEngine(
        rng.uniform(0, 100, (20, 2)), rng.uniform(0, 100, (80, 2)),
        RkNNConfig(backend="grid", grid_g=16),
    )
    live.query(0, 4)
    v0 = live._snap.version
    info = live.restore(d)
    assert info["mode"] == "hot-adopt"
    assert live._snap.version == v0 + 1  # published as MVCC N+1
    got = _results(live, [0, 4], 6)
    assert all(_same(c, w) for c, w in zip(want, got))


# ----------------------------------------------------------- observability
def test_persist_metrics_and_snapshot_endpoint(tmp_path):
    F, U, _ = _instance(seed=29)
    d = str(tmp_path / "store")
    eng = RkNNEngine(F, U, RkNNConfig(backend="grid", grid_g=16))
    _results(eng, [0, 1], 6)
    eng.save_state(d)
    assert eng.metrics.find("persist.bytes")

    warm = DynamicEngine(F, U, RkNNConfig(backend="grid", grid_g=16, warm_store=d))
    assert warm.metrics.find("persist.restore_s")
    srv = warm.serve_obs(port=0)
    try:
        conn = http.client.HTTPConnection("127.0.0.1", srv.port, timeout=5)
        conn.request("GET", "/snapshot")
        payload = json.loads(conn.getresponse().read())
        assert payload["persist"]["schema"] == SCHEMA
        assert payload["persist"]["store"] == os.path.abspath(d)
        assert payload["persist"]["categories"]["scenes"]["status"] == "restored"
    finally:
        srv.close()


# ------------------------------------------------------------------- CLI
def test_cli_inspect_and_verify(tmp_path, capsys):
    from repro.persist.__main__ import main

    F, U, _ = _instance(seed=31)
    d = str(tmp_path / "store")
    eng = RkNNEngine(F, U, RkNNConfig(backend="grid", grid_g=16))
    _results(eng, [0, 1, 2], 6)
    eng.save_state(d)

    assert main(["--inspect", d]) == 0
    out = capsys.readouterr().out
    assert SCHEMA in out and "scenes" in out and "fresh" in out

    assert main(["--verify", d]) == 0
    out = capsys.readouterr().out
    assert "bit-identical" in out

    # a mutated store fails verification (exit 1, mismatch reported)
    folder = os.path.join(d, f"step_{0:012d}")
    m = json.load(open(os.path.join(folder, "manifest.json")))
    # invert every stored edge test — scene rows AND the packed grid
    # planes the backend actually casts against
    victims = [m["categories"]["scenes"]["arrays"]["coeffs"]["file"]] + [
        v["file"]
        for key, v in m["categories"].get("indexes", {}).get("arrays", {}).items()
        if key.endswith("coeffs")
    ]
    for fn in victims:
        arr = np.load(os.path.join(folder, fn))
        np.save(os.path.join(folder, fn), -arr)
    rc = main(["--verify", d])
    out = capsys.readouterr().out
    assert rc == 1 and "MISMATCH" in out
