"""End-to-end behaviour tests for the whole system.

These stitch the layers together the way the examples do: RkNN query →
serving stream → fault-tolerant training run, each verified against ground
truth rather than just "doesn't crash".
"""

import numpy as np
import pytest

from repro.core import rt_rknn_query
from repro.core.brute import rknn_brute_np
from repro.data.spatial import facility_user_split, road_network_points
from repro.launch.serve import RkNNServer
from repro.launch.train import train_main


@pytest.fixture(scope="module")
def city():
    pts = road_network_points(20_000, seed=11)
    return facility_user_split(pts, 200, seed=11)


def test_end_to_end_query_all_backends(city):
    F, U = city
    truth = rknn_brute_np(U, F, 17, 8)
    for backend in ("dense", "dense-ref", "grid", "bvh", "brute"):
        res = rt_rknn_query(F, U, 17, 8, backend=backend)
        np.testing.assert_array_equal(res.mask, truth)


def test_serving_stream_end_to_end(city):
    F, U = city
    server = RkNNServer(F, U)
    rng = np.random.default_rng(0)
    queries = rng.integers(0, len(F), 8)
    batches = [queries[:4], queries[4:]]
    seen = {}
    for qb, masks in server.serve_stream(batches, k=5):
        for qi, m in zip(qb, masks):
            seen[int(qi)] = m
    assert len(seen) == len(set(queries.tolist()))
    for qi in list(seen)[:3]:
        np.testing.assert_array_equal(seen[qi], rknn_brute_np(U, F, qi, 5))
    assert server.stats.n_queries == 8


def test_server_query_batch_matches_single_queries(city):
    F, U = city
    server = RkNNServer(F, U)
    masks = server.query_batch([3, 9, 40], k=10)
    for i, qi in enumerate([3, 9, 40]):
        np.testing.assert_array_equal(masks[i], rknn_brute_np(U, F, qi, 10))


@pytest.mark.slow
def test_training_end_to_end_loss_decreases(tmp_path):
    out = train_main(
        "starcoder2_3b",
        steps=30,
        batch=4,
        seq=64,
        reduced=True,
        reduced_overrides=dict(n_layers=2, d_model=64, vocab=256, head_dim=16),
        ckpt_dir=str(tmp_path),
        save_every=10,
        lr=3e-3,
    )
    assert out["steps"] == 30
    assert out["last_loss"] < out["first_loss"]
    assert any(e.startswith("save:step_30") for e in out["events"])
