"""Batch-vs-loop equivalence for the batched multi-query RkNN engine.

``rt_rknn_query_batch`` must produce bit-identical masks to looping
``rt_rknn_query`` per query, on every backend — including at distance
ties, where the float32 ``>= 0`` edge-function convention decides
membership and both paths must decide it the same way.
"""

import numpy as np
import pytest

from repro.core.brute import rknn_brute_np
from repro.core.rknn import BACKENDS, rt_rknn_query, rt_rknn_query_batch


def _instance(seed, M=50, N=300):
    rng = np.random.default_rng(seed)
    return rng.random((M, 2)), rng.random((N, 2)), rng


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("seed,k", [(0, 1), (1, 4), (2, 9)])
def test_batch_matches_loop(backend, seed, k):
    F, U, rng = _instance(seed)
    qs = [int(q) for q in rng.integers(0, len(F), 6)]
    batch = rt_rknn_query_batch(F, U, qs, k, backend=backend)
    assert batch.masks.shape == (len(qs), len(U))
    assert batch.counts.shape == (len(qs), len(U))
    for i, qi in enumerate(qs):
        single = rt_rknn_query(F, U, qi, k, backend=backend)
        np.testing.assert_array_equal(batch.masks[i], single.mask)
        np.testing.assert_array_equal(batch.masks[i], rknn_brute_np(U, F, qi, k))


@pytest.mark.parametrize("backend", BACKENDS)
def test_batch_mixed_index_and_point_queries(backend):
    F, U, rng = _instance(7)
    qs = [3, np.array([0.25, 0.75]), 11, np.array([0.6, 0.4])]
    batch = rt_rknn_query_batch(F, U, qs, 5, backend=backend)
    for i, q in enumerate(qs):
        single = rt_rknn_query(F, U, q, 5, backend=backend)
        np.testing.assert_array_equal(batch.masks[i], single.mask)


@pytest.mark.parametrize("backend", BACKENDS)
def test_batch_boundary_tie(backend):
    """User exactly equidistant to q and a competitor (coordinates exactly
    representable in float32), pinning the ``>= 0`` edge-function tie
    semantics: whatever a backend decides, batch and loop must agree."""
    F = np.array([[0.25, 0.5], [0.75, 0.5], [0.125, 0.875], [0.875, 0.125]])
    # U[0] is on the q=F[0] / F[1] bisector (x = 0.5); U[3] strictly inside
    # F[1]'s half-plane; others well away from any bisector
    U = np.array([[0.5, 0.5], [0.5, 0.25], [0.25, 0.25], [0.625, 0.5]])
    for k in (1, 2):
        batch = rt_rknn_query_batch(F, U, [0, 1], k, backend=backend)
        for i, qi in enumerate([0, 1]):
            single = rt_rknn_query(F, U, qi, k, backend=backend)
            np.testing.assert_array_equal(batch.masks[i], single.mask)


def test_batch_tie_dense_matches_ref():
    """The Pallas kernel and the jnp oracle share one f32 tie convention."""
    F = np.array([[0.25, 0.5], [0.75, 0.5], [0.125, 0.875]])
    U = np.array([[0.5, 0.5], [0.5, 0.75], [0.375, 0.5]])
    a = rt_rknn_query_batch(F, U, [0, 1, 2], 1, backend="dense")
    b = rt_rknn_query_batch(F, U, [0, 1, 2], 1, backend="dense-ref")
    np.testing.assert_array_equal(a.counts, b.counts)
    np.testing.assert_array_equal(a.masks, b.masks)


def test_batch_empty_and_edge_cases():
    F, U, rng = _instance(13, M=20)
    empty = rt_rknn_query_batch(F, U, [], 3)
    assert empty.masks.shape == (0, len(U)) and empty.n_queries == 0
    # k >= |F| accepts every user for every query
    big = rt_rknn_query_batch(F, U, [0, 5], len(F) + 3)
    assert big.masks.all()
    # singleton batch equals the single-query API
    one = rt_rknn_query_batch(F, U, [4], 2)
    single = rt_rknn_query(F, U, 4, 2)
    np.testing.assert_array_equal(one.masks[0], single.mask)
    np.testing.assert_array_equal(one.per_query(0).mask, single.mask)


def test_batch_scene_workers_deterministic():
    """Thread-pooled scene builds change timing, never results."""
    F, U, rng = _instance(21)
    qs = [int(q) for q in rng.integers(0, len(F), 8)]
    serial = rt_rknn_query_batch(F, U, qs, 5, scene_workers=0)
    pooled = rt_rknn_query_batch(F, U, qs, 5, scene_workers=4)
    np.testing.assert_array_equal(serial.masks, pooled.masks)
    np.testing.assert_array_equal(serial.counts, pooled.counts)


def test_batch_timing_attribution():
    """Index build belongs to the filter phase, not verification."""
    F, U, rng = _instance(31, M=120, N=800)
    qs = [int(q) for q in rng.integers(0, len(F), 4)]
    res = rt_rknn_query_batch(F, U, qs, 5, backend="grid")
    assert res.t_filter_s > 0.0 and res.t_verify_s > 0.0
    single = rt_rknn_query(F, U, qs[0], 5, backend="grid")
    assert single.t_filter_s > 0.0 and single.t_verify_s > 0.0
