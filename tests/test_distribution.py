"""Distribution-layer tests: sharding rules, mesh helpers, meshctx, and an
8-device dry-run integration test (subprocess so the forced device count
never leaks into other tests)."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.distributed.sharding import param_logical_spec
from repro.runtime.elastic import plan_remesh

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_param_logical_specs():
    assert param_logical_spec(("embed",), (50000, 768)) == ("model", "data")
    assert param_logical_spec(("groups", "0", "p0", "attn", "wq"), (30, 768, 768)) == (
        None, "data", "model",
    )
    assert param_logical_spec(("groups", "0", "p0", "attn", "wo"), (30, 768, 768)) == (
        None, "model", "data",
    )
    # MoE expert stacks keep the expert axis on 'model' (EP)
    assert param_logical_spec(("groups", "0", "p0", "moe", "w_in"), (40, 16, 6144, 10752)) == (
        None, "model", "data", None,
    )
    # norms replicated
    assert param_logical_spec(("groups", "0", "p0", "norm1", "scale"), (30, 768)) == (
        None, None,
    )


def test_mesh_helpers_small():
    from repro.launch.mesh import make_mesh_for_devices

    mesh = make_mesh_for_devices(1, model_axis=1)
    assert mesh.shape["data"] == 1 and mesh.shape["model"] == 1


def test_meshctx_noop_without_mesh():
    from repro.distributed.meshctx import constrain, get_mesh

    assert get_mesh() is None
    x = jax.numpy.ones((4, 4))
    y = constrain(x, ("data", None))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


_DRYRUN_8DEV = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro.configs.registry import get_reduced
    from repro.distributed import sharding as shd
    from repro.distributed.meshctx import active_mesh
    from repro.launch.hlo_cost import analyze_hlo
    from repro.launch.mesh import make_mesh_for_devices
    from repro.models.registry import build_model
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.steps.train import make_train_step

    mesh = make_mesh_for_devices(8, model_axis=2)
    cfg = get_reduced("%s", n_layers=2, remat="full")
    model = build_model(cfg)
    opt = AdamWConfig()
    with active_mesh(mesh):
        params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        opt_shapes = jax.eval_shape(adamw_init, params_shapes)
        state_shapes = {"params": params_shapes, "opt": opt_shapes}
        state_sh = {
            "params": shd.params_shardings(mesh, params_shapes),
            "opt": {
                "m": shd.params_shardings(mesh, opt_shapes["m"]),
                "v": shd.params_shardings(mesh, opt_shapes["v"]),
                "step": shd.replicated(mesh),
            },
        }
        batch_shapes = {
            "tokens": jax.ShapeDtypeStruct((8, 64), jax.numpy.int32),
            "labels": jax.ShapeDtypeStruct((8, 64), jax.numpy.int32),
        }
        for k, (shp, dt) in model.extras_shapes(8).items():
            batch_shapes[k] = jax.ShapeDtypeStruct(shp, dt)
        step = make_train_step(model, opt, n_microbatches=2)
        compiled = (
            jax.jit(step, in_shardings=(state_sh, shd.batch_shardings(mesh, batch_shapes)))
            .lower(state_shapes, batch_shapes)
            .compile()
        )
        cost = analyze_hlo(compiled.as_text())
        mem = compiled.memory_analysis()
        print(json.dumps(dict(
            flops=cost.flops,
            coll=cost.collective_bytes,
            n_coll=cost.collective_count,
            temp=getattr(mem, "temp_size_in_bytes", -1),
        )))
    """
)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["starcoder2_3b", "deepseek_moe_16b", "mamba2_130m"])
def test_dryrun_8dev_subprocess(arch):
    """Reduced-config train_step lowers + compiles on an 8-device mesh and
    produces nonzero loop-aware costs + collectives."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", _DRYRUN_8DEV % arch],
        capture_output=True, text=True, env=env, timeout=480,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["flops"] > 0
    assert payload["coll"] > 0 and payload["n_coll"] > 0


_ENGINE_MESH_8DEV = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.core.brute import rknn_brute_np
    from repro.core.engine import RkNNEngine
    from repro.launch.mesh import make_mesh_for_devices

    rng = np.random.default_rng(0)
    F, U = rng.random((40, 2)), rng.random((257, 2))  # 257 % dp_degree != 0
    mesh = make_mesh_for_devices(8, model_axis=2)     # data=4, model=2
    eng = RkNNEngine(F, U, mesh=mesh)
    qs = [3, 7, 11, 19]
    res = eng.query_batch(qs, 5)
    assert res.masks.shape == (4, 257), res.masks.shape
    for i, qi in enumerate(qs):
        assert np.array_equal(res.masks[i], rknn_brute_np(U, F, qi, 5)), qi
    print("OK")
    """
)


@pytest.mark.slow
def test_engine_mesh_8dev_subprocess():
    """The engine's pjit'd dense-ref dispatch on a real 8-device (host
    platform) mesh: users sharded over 'data' with sentinel padding (the
    user count is not a multiple of the DP degree), queries over 'model' —
    masks exact vs the brute oracle."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", _ENGINE_MESH_8DEV],
        capture_output=True, text=True, env=env, timeout=480,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert out.stdout.strip().endswith("OK")


def test_rknn_serve_lowering_small_mesh():
    """The paper-workload serve step lowers on a small mesh in-process."""
    from repro.launch.mesh import make_mesh_for_devices
    from repro.launch.serve import lower_rknn_serve

    mesh = make_mesh_for_devices(1, model_axis=1)
    compiled = lower_rknn_serve(mesh, n_users=1024, q_batch=4, m_pad=128)
    assert compiled.cost_analysis() is not None


def test_elastic_remesh_device_arrays():
    from repro.runtime.elastic import build_remesh

    plan = plan_remesh(1, prefer_model=1, global_batch=8)
    mesh = build_remesh(plan)
    assert mesh.devices.size == 1
