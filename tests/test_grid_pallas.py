"""``grid-pallas`` / ``grid-pallas-ref`` backend properties (ISSUE 5).

The cell-bucketed Pallas backends must be count-identical to the ``grid``
oracle backend everywhere: across the paper's scenario workloads and batch
sizes, on degenerate scenes (empty occluder sets), on saturated cells
(``base >= k`` — the grid-granular early exit), and after
``refit_index`` (including the incremental plane re-pack).  Registration
is registry-only: the engine, planner, and dynamic subsystem pick the
backends up with zero edits outside their classes.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.backends import concrete_backends, get_backend
from repro.core.backends import QueryRequest
from repro.core.engine import RkNNConfig, RkNNEngine
from repro.core.geometry import Rect, edge_coeffs
from repro.core.scene import build_scene
from repro.dynamic import DynamicEngine
from repro.workloads import SCENARIOS, facility_jitter

PALLAS_BACKENDS = ("grid-pallas", "grid-pallas-ref")
RECT = Rect(0.0, 0.0, 1.0, 1.0)


def test_registered_via_registry_only():
    """The whole integration surface is the registry: concrete, scene-
    using, engine-validatable — no dispatch ladder anywhere to extend."""
    for name in PALLAS_BACKENDS:
        b = get_backend(name)
        assert b.uses_scene and not b.is_meta
        assert name in concrete_backends()
    # planner prior prices them, so `auto` can route to them uncalibrated
    from repro.planner.profiles import builtin_profile

    assert set(PALLAS_BACKENDS) <= set(builtin_profile().models)
    # timed harnesses (calibration, sweeps) share one exclusion source:
    # on this CPU container the interpret-mode kernel is a correctness
    # tool, the ref execution is the timed one
    from repro.core.backends import timeable_backends
    from repro.kernels.ops import pallas_interpret_default

    if pallas_interpret_default():
        assert "grid-pallas-ref" in timeable_backends()
        assert "grid-pallas" not in timeable_backends()
        assert "dense" not in timeable_backends()


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_scenarios_count_identical_to_grid_oracle(scenario):
    """Property: on every paper regime at Q ∈ {1, 16, 64}, the bucketed
    jnp execution returns counts AND masks bit-identical to the jnp grid
    oracle.  One engine serves every (backend, Q) pair and the Q=64 query
    list prefixes the smaller ones, so each scene is built exactly once
    (scene cache + per-scene index memo)."""
    w = SCENARIOS[scenario].generate(0.02)
    rng = np.random.default_rng(64)
    qs = [int(i) for i in rng.integers(0, len(w.facilities), 64)]
    eng = RkNNEngine(w.facilities, w.users, RkNNConfig(backend="grid"))
    for q_n in (64, 16, 1):
        want = eng.query_batch(qs[:q_n], w.k)
        got = eng.query_batch(qs[:q_n], w.k, backend="grid-pallas-ref")
        np.testing.assert_array_equal(got.counts, want.counts, err_msg=str(q_n))
        np.testing.assert_array_equal(got.masks, want.masks, err_msg=str(q_n))


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_scenarios_pallas_kernel_matches_oracle(scenario):
    """The interpret-mode Pallas kernel covers every scenario too, on a
    user subsample at Q ∈ {1, 16}: interpret execution copies each
    operand once per program instance, so full-size sweeps belong to the
    compiled TPU path — the math under test is identical."""
    w = SCENARIOS[scenario].generate(0.02)
    rng = np.random.default_rng(16)
    users = w.users[: 200]
    qs = [int(i) for i in rng.integers(0, len(w.facilities), 16)]
    eng = RkNNEngine(w.facilities, users, RkNNConfig(backend="grid"))
    for q_n in (16, 1):
        want = eng.query_batch(qs[:q_n], w.k)
        got = eng.query_batch(qs[:q_n], w.k, backend="grid-pallas")
        np.testing.assert_array_equal(got.counts, want.counts, err_msg=str(q_n))
        np.testing.assert_array_equal(got.masks, want.masks, err_msg=str(q_n))


def test_empty_cell_lists_and_empty_scene():
    """A one-facility snapshot builds an empty occluder scene (no
    competitors): every cell list is empty, counts are all zero, every
    user is a member."""
    rng = np.random.default_rng(0)
    F = rng.random((1, 2))
    U = rng.random((300, 2))
    for name in PALLAS_BACKENDS + ("grid",):
        res = RkNNEngine(F, U, RkNNConfig(backend=name)).query(0, 3)
        assert res.scene.n_tris == 0
        np.testing.assert_array_equal(res.counts, np.zeros(len(U), np.int32))
        assert res.mask.all()


def test_saturated_cells_match_oracle():
    """Non-pruned dense scenes saturate cells (``base >= k``) — the
    grid-granular early exit the paper's Table 3 regime exercises; counts
    must still be exact."""
    rng = np.random.default_rng(3)
    F = rng.random((250, 2))
    U = rng.random((800, 2))
    k = 5
    eng = RkNNEngine(F, U, RkNNConfig(backend="grid", strategy="none", grid_g=16))
    want = eng.query_batch([0, 7], k)
    grid_b = get_backend("grid")
    g = grid_b.build_index(want.scenes[0], grid_g=16)
    assert g.base.max() >= k  # the regime is actually present
    for name in PALLAS_BACKENDS:
        got = eng.query_batch([0, 7], k, backend=name)
        np.testing.assert_array_equal(got.counts, want.counts, err_msg=name)


@pytest.mark.parametrize("name", PALLAS_BACKENDS)
def test_refit_index_incremental_replane(name):
    """``refit_index`` adapts the grid AND incrementally re-packs only the
    touched cells' coefficient planes — bit-identical to a fresh pack, and
    count-identical to a cold-built index."""
    from repro.kernels.grid_raycast import pack_cell_coeff_planes

    rng = np.random.default_rng(11)
    F = rng.random((60, 2))
    U = rng.random((500, 2))
    sc = build_scene(F, 0, 8, RECT, strategy="none")
    backend = get_backend(name)
    old_idx = backend.build_index(sc, grid_g=16)
    assert backend.lane_pad in old_idx._cell_planes  # packed eagerly

    changed = np.array([2, 9], np.int64)
    tris_new = sc.tris.copy()
    # an ulp-scale nudge: coefficients change but each triangle's cell
    # classification stays put, so the grid refits in place (a bigger move
    # overflows some saturated cell list of this non-pruned scene and
    # correctly falls back to a rebuild)
    tris_new[changed] = (tris_new[changed] + 1e-7).astype(np.float32)
    coeffs_new = sc.coeffs.copy()
    coeffs_new[changed] = edge_coeffs(tris_new[changed].astype(np.float64)).astype(
        np.float32
    )
    new_sc = dataclasses.replace(sc, tris=tris_new, coeffs=coeffs_new)

    new_idx, was_refit = backend.refit_index(old_idx, sc, new_sc, changed, grid_g=16)
    assert was_refit
    fresh_planes = pack_cell_coeff_planes(new_idx, lane_pad=backend.lane_pad)
    np.testing.assert_array_equal(
        new_idx._cell_planes[backend.lane_pad], fresh_planes
    )
    xs = U[:, 0].astype(np.float32)
    ys = U[:, 1].astype(np.float32)
    got = backend.count(QueryRequest(xs=xs, ys=ys, k=8, grid_g=16, scene=new_sc,
                                     index=new_idx))
    cold = backend.count(QueryRequest(xs=xs, ys=ys, k=8, grid_g=16, scene=new_sc))
    np.testing.assert_array_equal(got, cold)


@pytest.mark.parametrize("name", PALLAS_BACKENDS)
def test_dynamic_updates_stay_exact(name):
    """Post-``refit_index`` states through the real update path: a
    dynamic engine absorbing facility jitter answers bit-identically to a
    cold engine at every version."""
    rng = np.random.default_rng(21)
    F = rng.random((40, 2))
    F[:4] = [[0.0, 0.0], [0.0, 1.0], [1.0, 0.0], [1.0, 1.0]]  # pin the hull
    U = rng.random((250, 2))
    qs = [5, 9]
    dyn = DynamicEngine(F, U, RkNNConfig(backend=name))
    dyn.query_batch(qs, 4)  # warm caches so migration has work
    for batch in facility_jitter(F, steps=3, frac=0.1, seed=2,
                                 protect=np.concatenate([np.arange(4), qs])):
        dyn.apply_updates(batch)
        cold = RkNNEngine(dyn.facilities, dyn.users, RkNNConfig(backend=name))
        got = dyn.query_batch(qs, 4)
        want = cold.query_batch(qs, 4)
        np.testing.assert_array_equal(got.counts, want.counts)
        np.testing.assert_array_equal(got.masks, want.masks)


def test_bucket_cache_reused_across_batches():
    """The user→cell sort is computed once per (users, rect, G) — memoized
    on the engine snapshot — and reused by later batches over different
    query sets."""
    rng = np.random.default_rng(5)
    F = rng.random((30, 2))
    U = rng.random((400, 2))
    eng = RkNNEngine(F, U, RkNNConfig(backend="grid-pallas-ref"))
    eng.query_batch([1, 2], 4)
    memo = eng._snap.kernel_memo
    key_hits = [k for k in memo.keys() if k[0] == "gp-buckets" and k[2] == len(U)]
    assert key_hits
    marker = memo.get(key_hits[0])
    assert marker is not None
    eng.query_batch([3, 4], 4)  # different queries, same user sort
    assert memo.get(key_hits[0]) is marker
