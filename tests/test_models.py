"""Per-architecture smoke tests (reduced configs, CPU) + serving-path
consistency.

Every assigned arch: one jitted train step (finite loss, correct shapes),
one prefill + one decode step, and decode-vs-forward logit agreement (the
strongest cache-correctness check).  MoE archs run the consistency check
with a drop-free capacity factor since GShard token dropping makes outputs
batch-composition-dependent by design (see models/ffn.py).
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config, get_reduced, shape_applicable
from repro.models.registry import build_model
from repro.optim.adamw import AdamWConfig
from repro.steps.train import init_train_state, make_decode_step, make_prefill_step, make_train_step

# smoke-test sizes: S=32 exercises every cache/scan path the reduced
# configs have while keeping the 8-arch sweep well inside the tier-1 budget
B, S = 2, 32
OPT = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)


def _batch(model, key):
    cfg = model.cfg
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    for k, (shp, dt) in model.extras_shapes(B).items():
        batch[k] = jax.random.normal(key, shp, jnp.float32).astype(dt)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    state = init_train_state(model, key, OPT)
    batch = _batch(model, key)
    step = jax.jit(make_train_step(model, OPT, n_microbatches=2))
    state2, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(
            lambda p, q: float(jnp.sum(jnp.abs(p.astype(jnp.float32) - q.astype(jnp.float32)))),
            state["params"],
            state2["params"],
        ),
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    cfg = get_reduced(arch)
    if cfg.moe is not None:  # drop-free so fwd == prefill+decode is exact
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts))
        )
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    extras = {}
    for k, (shp, dt) in model.extras_shapes(B).items():
        extras[k] = jax.random.normal(key, shp, jnp.float32).astype(dt)
    logits_fwd, _ = model.forward(params, tokens, extras)
    lp, cache = model.prefill(params, tokens[:, :S], extras, pad_cache_to=S + 4)
    ld, cache2 = model.decode(params, tokens[:, S : S + 1], cache)
    scale = float(jnp.max(jnp.abs(logits_fwd))) + 1e-9
    assert float(jnp.max(jnp.abs(lp - logits_fwd[:, S - 1]))) / scale < 0.05
    assert float(jnp.max(jnp.abs(ld - logits_fwd[:, S]))) / scale < 0.05
    assert int(cache2["pos"][0]) == S + 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_two_train_steps_decrease_loss_direction(arch):
    """Not a convergence test — just that repeated steps stay finite."""
    cfg = get_reduced(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(2)
    state = init_train_state(model, key, OPT)
    batch = _batch(model, key)
    step = jax.jit(make_train_step(model, OPT))
    for _ in range(2):
        state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))


def test_full_configs_describe_and_param_counts():
    """Full configs instantiate (metadata only, no arrays) with sane sizes."""
    expect_bounds = {
        "llama3_405b": (350e9, 480e9),
        "dbrx_132b": (100e9, 165e9),
        "deepseek_moe_16b": (12e9, 25e9),
        "qwen2_7b": (6e9, 9e9),
        "nemotron4_15b": (12e9, 19e9),
        "starcoder2_3b": (2.5e9, 4.5e9),
        "chameleon_34b": (30e9, 40e9),
        "mamba2_130m": (0.1e9, 0.2e9),
    }
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        n = cfg.param_count()
        assert n > 0, arch
        if arch in expect_bounds:
            lo, hi = expect_bounds[arch]
            assert lo < n < hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_long_context_applicability_flags():
    runs = {a: shape_applicable(get_config(a), "long_500k")[0] for a in ARCH_IDS}
    assert runs["mamba2_130m"] and runs["recurrentgemma_9b"]
    assert sum(runs.values()) == 2  # everything else skips (full attention)


def test_moe_no_drop_capacity():
    from repro.configs.base import MoECfg
    from repro.models import ffn as ffn_mod

    key = jax.random.PRNGKey(0)
    cfg = MoECfg(n_experts=4, top_k=2, d_ff_expert=32)
    p = ffn_mod.init_moe(key, 64, cfg, "swiglu")
    x = jax.random.normal(key, (8, 1, 64), jnp.float32)
    y1, _ = ffn_mod.moe_ffn(p, x, cfg, "swiglu", no_drop=True)
    # processing rows independently must give identical results (no drops,
    # no cross-token coupling)
    y_rows = jnp.concatenate(
        [ffn_mod.moe_ffn(p, x[i : i + 1], cfg, "swiglu", no_drop=True)[0] for i in range(8)]
    )
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y_rows), rtol=2e-5, atol=2e-5)
