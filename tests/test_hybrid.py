"""Paper future-work features: scene cache amortization + hybrid dispatch."""

import os
import warnings

import numpy as np
import pytest

import repro.core.hybrid as hybrid_mod
from repro.core.brute import rknn_brute_np
from repro.core.hybrid import SceneCache, choose_engine, hybrid_rknn_query
from repro.data.spatial import facility_user_split, road_network_points
from repro.planner.profiles import (
    get_active_profile,
    load_runner_profile,
    set_active_profile,
)

PROFILE_STORE = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "benchmarks",
    "profiles",
)


@pytest.fixture(autouse=True)
def _no_profile_warning_leak():
    """Keep the no-profile fallback warning out of tier-1 output: every
    test in this module runs with the once-flag already spent (the
    dedicated test below resets it and asserts the warning instead)."""
    prev = hybrid_mod._warned_no_profile
    hybrid_mod._warned_no_profile = True
    yield
    hybrid_mod._warned_no_profile = prev


@pytest.fixture(scope="module")
def city():
    pts = road_network_points(30_000, seed=5)
    return facility_user_split(pts, 500, seed=5)


def test_scene_cache_hit_skips_construction(city):
    F, U = city
    cache = SceneCache(capacity=8)
    r1 = hybrid_rknn_query(F, U, 7, 10, cache=cache, force="rt")
    r2 = hybrid_rknn_query(F, U, 7, 10, cache=cache, force="rt")
    assert cache.hits == 1 and cache.misses == 1
    np.testing.assert_array_equal(r1.mask, r2.mask)
    # cached filter phase is orders of magnitude cheaper
    assert r2.t_filter_s < r1.t_filter_s / 5


def test_scene_cache_lru_eviction(city):
    F, U = city
    cache = SceneCache(capacity=2)
    for q in (1, 2, 3):  # 3 distinct scenes, capacity 2 -> q=1 evicted
        hybrid_rknn_query(F, U[:100], q, 5, cache=cache, force="rt")
    hybrid_rknn_query(F, U[:100], 1, 5, cache=cache, force="rt")
    assert cache.misses == 4 and cache.hits == 0


def test_cache_distinguishes_k_and_facility_set(city):
    F, U = city
    cache = SceneCache()
    hybrid_rknn_query(F, U[:100], 1, 5, cache=cache, force="rt")
    hybrid_rknn_query(F, U[:100], 1, 6, cache=cache, force="rt")  # different k
    F2 = F.copy()
    F2[0] += 0.01
    hybrid_rknn_query(F2, U[:100], 1, 5, cache=cache, force="rt")  # different set
    assert cache.misses == 3


def test_hybrid_both_engines_exact(city):
    F, U = city
    truth = rknn_brute_np(U, F, 11, 8)
    for force in ("rt", "slice"):
        r = hybrid_rknn_query(F, U, 11, 8, force=force)
        np.testing.assert_array_equal(r.mask, truth)
        assert r.backend == ("dense-ref" if force == "rt" else "slice")


def test_choose_engine_matches_measured_regimes():
    # our measured frontier (bench_output.txt): sparse facilities + big k
    # -> RT; dense facilities + small k -> SLICE; very large k -> RT even
    # at default density (fig9 trend)
    assert choose_engine(n_facilities=100, n_users=1_000_000, k=25) == "rt"
    assert choose_engine(n_facilities=1_000, n_users=1_200_000, k=300) == "rt"
    assert choose_engine(n_facilities=10_000, n_users=100_000, k=1) == "slice"
    assert choose_engine(n_facilities=1_000, n_users=50_000, k=1) == "slice"


def test_choose_engine_no_profile_warns_once():
    """The hard-coded-constants fallback warns exactly once per process —
    asserted here instead of leaking into tier-1 output."""
    prev_prof = get_active_profile()
    set_active_profile(None)
    hybrid_mod._warned_no_profile = False
    try:
        with pytest.warns(RuntimeWarning, match="no active planner profile"):
            choose_engine(n_facilities=100, n_users=1_000_000, k=25)
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # second call must stay silent
            choose_engine(n_facilities=100, n_users=1_000_000, k=25)
    finally:
        hybrid_mod._warned_no_profile = True
        set_active_profile(prev_prof)


def test_choose_engine_with_committed_profile_is_silent():
    """With the committed runner-class profile active, the frontier is a
    live profile lookup: no fallback warning, decisions from the store."""
    prof = load_runner_profile(PROFILE_STORE)
    if prof is None:
        pytest.skip("no committed profile for this runner class")
    prev_prof = get_active_profile()
    set_active_profile(prof)
    hybrid_mod._warned_no_profile = False
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            for f, u, k in ((100, 1_000_000, 25), (10_000, 100_000, 1)):
                assert choose_engine(f, u, k) in ("rt", "slice")
        assert not hybrid_mod._warned_no_profile  # fallback path never taken
    finally:
        hybrid_mod._warned_no_profile = True
        set_active_profile(prev_prof)


def test_hybrid_auto_dispatch_is_exact(city):
    F, U = city
    truth = rknn_brute_np(U, F, 3, 10)
    r = hybrid_rknn_query(F, U, 3, 10)
    np.testing.assert_array_equal(r.mask, truth)
