"""MVCC snapshot isolation under real thread concurrency (PR 6 tentpole).

A writer thread streams mixed update batches through
``DynamicEngine.apply_updates`` while reader threads hammer
``query_batch`` across several backends with NO coordination — no lock,
no barrier, no retry loop.  Every result self-reports the snapshot
version it was served from; the test replays each (version, backend)
pair on a cold :class:`RkNNEngine` built from the recorded arrays at
that version and requires bit-identical counts and masks.

That equality is the whole MVCC contract at once:

* **atomicity** — a result computed from a half-applied update could not
  match any recorded version's cold replay;
* **no stale mixing** — facility arrays from version N with user arrays
  from version N+1 likewise match no single version;
* **monotonic publishing** — versions observed by each reader never
  decrease (single atomic reference swap).

Readers also run with exceptions captured, so a torn internal state that
raises (rather than mis-answers) fails the test too.
"""

import threading

import numpy as np

from repro.core.engine import RkNNConfig, RkNNEngine
from repro.dynamic import DynamicEngine, UpdateBatch

#: Backends the readers rotate through: both jnp grid executions, the
#: cell-bucketed ref kernel, the BVH walker, and the geometry-free brute
#: path — every distinct read-path data dependency in the engine.
READ_BACKENDS = ("grid", "grid-pallas-ref", "dense-ref", "bvh", "brute")

N_BATCHES = 6
N_READERS = 3
K = 4
QS = [3, 11, 7, 0]


def _mixed_batches(F, U, rng):
    """Facility jitter + user drift + user churn, all index-stable for
    facilities so query ids stay comparable across versions."""
    batches = []
    for step in range(N_BATCHES):
        fb = rng.choice(len(F), size=4, replace=False)
        fb = fb[~np.isin(fb, QS)]  # keep query facilities pinned
        fm = (fb, np.clip(F[fb] + rng.normal(0, 0.05, (len(fb), 2)), 0, 1))
        # moves from the top half, deletes from the bottom: disjoint by
        # construction (a row may appear in at most one of move/delete)
        ub = 150 + rng.choice(len(U) - 150, size=10, replace=False)
        um = (ub, rng.random((10, 2)))
        if step % 2 == 0:  # user churn: delete 8, insert 8 (count stable)
            dead = np.arange(8) + 20 * step
            batches.append(
                UpdateBatch(
                    facility_move=fm, user_move=um,
                    user_delete=dead, user_insert=rng.random((8, 2)),
                )
            )
        else:
            batches.append(UpdateBatch(facility_move=fm, user_move=um))
    return batches


def test_concurrent_readers_see_single_consistent_versions():
    rng = np.random.default_rng(77)
    F = rng.random((40, 2))
    F[:4] = [[0.0, 0.0], [0.0, 1.0], [1.0, 0.0], [1.0, 1.0]]  # pin the hull
    U = rng.random((300, 2))
    dyn = DynamicEngine(F, U, RkNNConfig(backend="grid"))
    dyn.query_batch(QS, K)  # warm caches so migration has work to carry

    history = {0: (F.copy(), U.copy())}
    writer_done = threading.Event()
    errors: list[BaseException] = []
    results: list[tuple[int, str, np.ndarray, np.ndarray]] = []
    res_lock = threading.Lock()

    def writer():
        try:
            wrng = np.random.default_rng(5)
            for batch in _mixed_batches(F, U, wrng):
                dyn.apply_updates(batch)
                # sole writer: arrays are stable until OUR next apply
                history[dyn.version] = (
                    dyn.facilities.copy(), dyn.users.copy()
                )
        except BaseException as e:  # pragma: no cover - failure path
            errors.append(e)
        finally:
            writer_done.set()

    def reader(seed):
        try:
            last_seen = -1
            i = seed
            while not writer_done.is_set() or i % len(READ_BACKENDS) != 0:
                backend = READ_BACKENDS[i % len(READ_BACKENDS)]
                i += 1
                r = dyn.query_batch(QS, K, backend=backend)
                assert r.version >= last_seen, "version went backwards"
                last_seen = r.version
                with res_lock:
                    results.append(
                        (r.version, backend,
                         np.asarray(r.counts).copy(),
                         np.asarray(r.masks).copy())
                    )
        except BaseException as e:  # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=writer)] + [
        threading.Thread(target=reader, args=(s,)) for s in range(N_READERS)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=560)
    assert not any(t.is_alive() for t in threads), "deadlocked"
    assert not errors, errors
    assert len(history) == N_BATCHES + 1  # every batch published a version

    versions_seen = sorted({v for v, *_ in results})
    assert versions_seen, "readers never completed a query"
    # every result replays bit-identically on a cold engine at its version
    cold: dict[tuple[int, str], RkNNEngine] = {}
    for version, backend, counts, masks in results:
        assert version in history, f"result reports unknown version {version}"
        key = (version, backend)
        if key not in cold:
            cold[key] = RkNNEngine(
                *history[version], RkNNConfig(backend=backend)
            )
        want = cold[key].query_batch(QS, K)
        np.testing.assert_array_equal(
            counts, want.counts, err_msg=f"v{version} {backend} counts"
        )
        np.testing.assert_array_equal(
            masks, want.masks, err_msg=f"v{version} {backend} masks"
        )
    # the run actually interleaved: readers answered while versions moved
    assert len(versions_seen) >= 2, "no interleaving observed"


def test_reader_holds_old_snapshot_across_update():
    """A reference to ``engine._snap`` taken before an update keeps
    answering from the OLD arrays after the update publishes — readers
    in flight are never migrated onto the new version mid-query."""
    rng = np.random.default_rng(3)
    F = rng.random((30, 2))
    U = rng.random((200, 2))
    dyn = DynamicEngine(F, U, RkNNConfig(backend="grid"))
    old_snap = dyn._snap
    want = dyn.query_batch(QS, K)
    dyn.apply_updates(
        UpdateBatch(user_move=(np.arange(50), rng.random((50, 2))))
    )
    assert dyn.version == 1 and old_snap.version == 0
    got = dyn._query_batch(old_snap, QS, K)  # in-flight reader's view
    np.testing.assert_array_equal(got.counts, want.counts)
    np.testing.assert_array_equal(got.masks, want.masks)
    assert got.version == 0  # stamped with the version it was served from
    assert dyn.query_batch(QS, K).version == 1
