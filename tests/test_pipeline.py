"""Pipeline parallelism: numeric equality with sequential execution, and
gradient flow through the GPipe schedule (subprocess: needs >1 device)."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.distributed.pipeline import pipeline_forward
from repro.launch.mesh import make_mesh_for_devices

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _layer(p, h):
    return jnp.tanh(h @ p["w"] + p["b"])


def test_pipeline_p1_fallback_matches_sequential():
    key = jax.random.PRNGKey(0)
    L, d, B = 4, 16, 8
    params = {
        "w": jax.random.normal(key, (L, d, d)) * 0.3,
        "b": jnp.zeros((L, d)),
    }
    x = jax.random.normal(jax.random.PRNGKey(1), (B, d))
    mesh = make_mesh_for_devices(1, model_axis=1)

    # sequential reference
    h = x
    for i in range(L):
        h = _layer({"w": params["w"][i], "b": params["b"][i]}, h)

    y = pipeline_forward(_layer, params, x, mesh=mesh, n_microbatches=4, axis="model")
    np.testing.assert_allclose(np.asarray(y), np.asarray(h), rtol=1e-5, atol=1e-5)


_PP_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.distributed.pipeline import pipeline_forward, make_pp_mesh

    def layer(p, h):
        return jnp.tanh(h @ p["w"] + p["b"])

    key = jax.random.PRNGKey(0)
    L, d, B, M = 8, 16, 8, 4
    params = {"w": jax.random.normal(key, (L, d, d)) * 0.3, "b": jnp.zeros((L, d))}
    x = jax.random.normal(jax.random.PRNGKey(1), (B, d))

    h = x
    for i in range(L):
        h = layer({"w": params["w"][i], "b": params["b"][i]}, h)

    mesh = jax.make_mesh((4,), ("pipe",))
    y = pipeline_forward(layer, params, x, mesh=mesh, n_microbatches=M, axis="pipe")
    fwd_err = float(jnp.max(jnp.abs(y - h)))

    # gradients through the pipeline == gradients through sequential
    def loss_pp(params):
        return jnp.sum(pipeline_forward(layer, params, x, mesh=mesh, n_microbatches=M, axis="pipe") ** 2)

    def loss_seq(params):
        h = x
        for i in range(L):
            h = layer({"w": params["w"][i], "b": params["b"][i]}, h)
        return jnp.sum(h ** 2)

    g_pp = jax.grad(loss_pp)(params)
    g_seq = jax.grad(loss_seq)(params)
    g_err = max(
        float(jnp.max(jnp.abs(g_pp["w"] - g_seq["w"]))),
        float(jnp.max(jnp.abs(g_pp["b"] - g_seq["b"]))),
    )
    print(json.dumps({"fwd_err": fwd_err, "g_err": g_err}))
    """
)


def test_pipeline_4stage_matches_sequential_fwd_and_grad():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", _PP_SUBPROC],
        capture_output=True, text=True, env=env, timeout=480,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["fwd_err"] < 1e-5, payload
    assert payload["g_err"] < 1e-4, payload
