"""User-axis sharded serving: bit-identity to the single-process oracle.

The sharding contract (docs/API.md "Sharded serving"): per-user hit
counts are per-user independent, so partitioning the user population
over shards and scattering the per-shard slabs back through the
partition permutation must reproduce the single-process engine's counts
and masks **bit-identically** — for every registered backend, every
shard count, and across an ``apply_updates`` stream (where the COW
shard-state carry and the version-lockstep rule are what is actually
under test).
"""

import numpy as np
import pytest

from repro.core.backends import available_backends, concrete_backends
from repro.core.engine import RkNNEngine
from repro.dynamic import UpdateBatch
from repro.distributed.sharding import user_shard_bounds
from repro.shard import (
    ShardedEngine,
    assemble_counts,
    mesh_shards,
    result_sizes,
    shard_devices,
    tree_psum,
    user_mesh,
)

SHARD_COUNTS = (1, 2, 4)
K = 4


def _instance(seed, M=36, N=420):
    rng = np.random.default_rng(seed)
    F = rng.random((M, 2))
    F[:4] = [[0.0, 0.0], [0.0, 1.0], [1.0, 0.0], [1.0, 1.0]]  # pin the hull
    U = rng.random((N, 2))
    # mixed facility-index and point queries
    qs = [0, 7, np.array([0.5, 0.5]), 13, np.array([0.21, 0.77]), 5]
    return F, U, qs, rng


# ---------------------------------------------------------------------------
# the core property: bit-identity across backends x shard counts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shards", SHARD_COUNTS)
@pytest.mark.parametrize("backend", available_backends())
def test_sharded_matches_single_process(backend, shards):
    F, U, qs, _ = _instance(11)
    oracle = RkNNEngine(F, U, backend=backend).query_batch(qs, K)
    got = ShardedEngine(F, U, backend=backend, shards=shards).query_batch(qs, K)
    assert np.array_equal(oracle.masks, got.masks)
    if backend in concrete_backends():
        # the planner may legitimately split a batch differently on a
        # sharded engine (the log_s feature reprices verify), and count
        # *semantics* differ per backend — masks are the invariant there
        assert np.array_equal(
            np.asarray(oracle.counts), np.asarray(got.counts)
        )


@pytest.mark.parametrize("shards", SHARD_COUNTS)
@pytest.mark.parametrize("backend", available_backends())
def test_sharded_matches_after_update_stream(backend, shards):
    F, U, qs, rng = _instance(23)
    eng = ShardedEngine(F, U, backend=backend, shards=shards)
    eng.query_batch(qs, K)  # warm caches so the COW carry has work to do
    # moves, churn (interior inserts: hull-stable), and facility jitter —
    # every COW path: scatter, partition rebuild, restamp
    mv = 100 + rng.choice(len(U) - 100, 25, replace=False)
    eng.apply_updates(user_move=(mv, rng.random((25, 2))))
    eng.query_batch(qs, K)
    eng.apply_updates(
        UpdateBatch(
            user_insert=rng.uniform(0.2, 0.8, (12, 2)),
            user_delete=np.arange(8),
        )
    )
    fb = np.array([17, 23, 29])
    eng.apply_updates(
        facility_move=(fb, np.clip(F[fb] + 0.03, 0, 1))
    )
    got = eng.query_batch(qs, K)
    oracle = RkNNEngine(eng.facilities, eng.users, backend=backend).query_batch(
        qs, K
    )
    assert np.array_equal(oracle.masks, got.masks)
    if backend in concrete_backends():
        assert np.array_equal(
            np.asarray(oracle.counts), np.asarray(got.counts)
        )


def test_single_query_and_stream_paths_match(shards=3):
    F, U, qs, _ = _instance(5)
    oracle = RkNNEngine(F, U, backend="grid-pallas-ref")
    eng = ShardedEngine(F, U, backend="grid-pallas-ref", shards=shards)
    for q in qs:
        assert np.array_equal(oracle.query(q, K).mask, eng.query(q, K).mask)
    batches = [qs[:3], qs[3:], qs]
    ref = [m for _, m in oracle.stream(batches, K)]
    got = [m for _, m in eng.stream(batches, K)]
    for r, g in zip(ref, got):
        assert np.array_equal(r, g)


# ---------------------------------------------------------------------------
# version lockstep + COW shard-state carry
# ---------------------------------------------------------------------------


def test_shard_state_version_lockstep():
    F, U, qs, rng = _instance(7)
    eng = ShardedEngine(F, U, backend="dense-ref", shards=4)
    eng.query_batch(qs, K)
    st = eng._snap.shard_state
    assert st is not None and st.version == eng.version
    assert all(v.version == st.version for v in st.views)
    assert sum(v.n_users for v in st.views) == len(U)

    # pure move: functional scatter, same partition, new version stamp
    mv = rng.choice(len(U), 10, replace=False)
    eng.apply_updates(user_move=(mv, rng.random((10, 2))))
    st2 = eng._snap.shard_state
    assert st2 is not None and st2.version == eng.version
    assert st2.perm is st.perm  # partition carried, not rebuilt
    assert all(v.version == st2.version for v in st2.views)

    # facility-only delta: user arrays carried by reference, re-stamped
    eng.apply_updates(facility_move=(np.array([9]), np.array([[0.4, 0.4]])))
    st3 = eng._snap.shard_state
    assert st3 is not None and st3.version == eng.version
    assert st3.views[0].xs is st2.views[0].xs

    # shape change: partition is stale — rebuilt lazily on next query
    eng.apply_updates(user_insert=rng.uniform(0.3, 0.7, (6, 2)))
    assert eng._snap.shard_state is None
    eng.query_batch(qs, K)
    st4 = eng._snap.shard_state
    assert st4 is not None and st4.n_users == len(U) + 6
    assert st4.version == eng.version


def test_per_shard_stats_and_explain():
    F, U, qs, _ = _instance(3)
    eng = ShardedEngine(F, U, backend="grid-pallas-ref", shards=4)
    eng.query_batch(qs, K)
    assert len(eng.stats.shard_verify_s) == 4
    assert len(eng.stats.shard_filter_s) == 4
    assert any(t > 0 for t in eng.stats.shard_verify_s)
    assert eng.stats.shard_imbalance >= 1.0
    recs = [e for e in eng.explain() if e.get("mode") == "shard-batch"]
    assert recs, "explain() must surface shard batch records"
    rec = recs[-1]
    assert rec["shards"] == 4
    assert sum(rec["per_shard_users"]) == len(U)
    assert len(rec["per_shard_verify_s"]) == 4
    # psum-reduced result sizes match the actual masks
    got = eng.query_batch(qs, K)
    recs2 = [e for e in eng.explain() if e.get("mode") == "shard-batch"]
    assert recs2[-1]["result_sizes"] == [int(m.sum()) for m in got.masks]


def test_batch_cache_carry_across_user_churn():
    """Satellite: the prepared-batch LRU survives user insert/delete for
    backends whose prepared state is scene-only."""
    F, U, qs, rng = _instance(13)
    for backend in ("dense-ref", "grid", "bvh"):
        eng = ShardedEngine(F, U, backend=backend, shards=2)
        eng.query_batch(qs, K)
        h0 = eng.stats.batch_cache_hits
        rep = eng.apply_updates(user_insert=rng.uniform(0.2, 0.8, (9, 2)))
        assert rep.batches_carried > 0, backend
        got = eng.query_batch(qs, K)
        assert eng.stats.batch_cache_hits > h0, backend
        oracle = RkNNEngine(eng.facilities, eng.users, backend=backend)
        assert np.array_equal(oracle.query_batch(qs, K).masks, got.masks)


# ---------------------------------------------------------------------------
# mesh + reduction units
# ---------------------------------------------------------------------------


def test_user_shard_bounds_invariants():
    for n in (0, 1, 5, 97, 1000):
        for s in (1, 2, 3, 4, 7):
            b = user_shard_bounds(n, s)
            assert b[0] == 0 and b[-1] == n and len(b) == s + 1
            sizes = np.diff(b)
            assert (sizes >= 0).all() and sizes.max() - sizes.min() <= 1


def test_tree_psum_deterministic_and_exact():
    rng = np.random.default_rng(0)
    parts = [rng.integers(0, 100, 17).astype(np.int64) for _ in range(5)]
    assert np.array_equal(tree_psum(parts), np.sum(parts, axis=0))
    with pytest.raises(ValueError):
        tree_psum([])


def test_assemble_counts_roundtrip():
    rng = np.random.default_rng(1)
    n, q, s = 103, 3, 4
    full = rng.integers(0, 9, (q, n)).astype(np.int32)
    perm = rng.permutation(n)
    bounds = user_shard_bounds(n, s)
    slabs = [full[:, perm[bounds[i] : bounds[i + 1]]] for i in range(s)]
    assert np.array_equal(assemble_counts(slabs, perm, bounds, n), full)
    sizes = result_sizes(slabs, 5)
    assert np.array_equal(sizes, (full < 5).sum(axis=1))


def test_user_mesh_and_devices():
    import jax

    n_dev = len(jax.devices())
    mesh = user_mesh(n_dev)
    assert mesh_shards(mesh) == n_dev
    assert shard_devices(n_dev, mesh) == list(jax.devices())
    # oversubscription cycles without a mesh, errors with one
    devs = shard_devices(n_dev + 3)
    assert len(devs) == n_dev + 3
    with pytest.raises(ValueError):
        user_mesh(n_dev + 1)
    # the engine accepts a mesh and locks its shard count to it
    F, U, qs, _ = _instance(2, M=20, N=64)
    eng = ShardedEngine(F, U, backend="dense-ref", mesh=mesh)
    assert eng.n_shards == n_dev
    with pytest.raises(ValueError):
        ShardedEngine(F, U, backend="dense-ref", mesh=mesh, shards=n_dev + 1)
