"""Baseline algorithms (SIX / TPL / InfZone / SLICE) vs the exact oracle,
plus R-tree substrate unit tests."""

import numpy as np
import pytest

from repro.core.baselines import STRTree, infzone_rknn, six_rknn, slice_rknn, tpl_rknn
from repro.core.brute import rknn_brute_np


def _instance(seed):
    rng = np.random.default_rng(seed)
    M = int(rng.integers(15, 150))
    N = int(rng.integers(100, 700))
    k = int(rng.integers(1, 14))
    F = rng.random((M, 2)) * 10
    U = rng.random((N, 2)) * 10
    qi = int(rng.integers(0, M))
    return F, U, qi, k


@pytest.mark.parametrize("seed", range(12))
def test_six_matches_brute(seed):
    F, U, qi, k = _instance(seed)
    mask, info = six_rknn(F, U, qi, k)
    np.testing.assert_array_equal(mask, rknn_brute_np(U, F, qi, k))
    assert info["n_candidates"] >= mask.sum()


@pytest.mark.parametrize("seed", range(12))
def test_tpl_matches_brute(seed):
    F, U, qi, k = _instance(seed + 100)
    mask, info = tpl_rknn(F, U, qi, k)
    np.testing.assert_array_equal(mask, rknn_brute_np(U, F, qi, k))
    assert info["n_bisectors"] <= len(F)


@pytest.mark.parametrize("seed", range(12))
def test_infzone_matches_brute(seed):
    F, U, qi, k = _instance(seed + 200)
    mask, info = infzone_rknn(F, U, qi, k)
    np.testing.assert_array_equal(mask, rknn_brute_np(U, F, qi, k))
    # InfZone has no verification refinement: containment *is* the answer
    assert info["n_kept"] <= len(F)


@pytest.mark.parametrize("seed", range(12))
def test_slice_matches_brute(seed):
    F, U, qi, k = _instance(seed + 300)
    mask, info = slice_rknn(F, U, qi, k)
    np.testing.assert_array_equal(mask, rknn_brute_np(U, F, qi, k))


# ---- R-tree substrate ------------------------------------------------------

def test_rtree_knn_matches_sort():
    rng = np.random.default_rng(1)
    pts = rng.random((500, 2))
    tree = STRTree(pts)
    p = np.array([0.5, 0.5])
    got = [i for _, i in tree.knn(p, 10)]
    want = np.argsort(np.linalg.norm(pts - p, axis=1))[:10]
    assert set(got) == set(want.tolist())


def test_rtree_nearest_iter_order():
    rng = np.random.default_rng(2)
    pts = rng.random((300, 2))
    tree = STRTree(pts)
    p = np.array([0.2, 0.8])
    dists = [d for d, _ in tree.nearest_iter(p)]
    assert all(dists[i] <= dists[i + 1] + 1e-12 for i in range(len(dists) - 1))
    assert len(dists) == 300


def test_rtree_count_within_strict():
    rng = np.random.default_rng(3)
    pts = rng.random((400, 2))
    tree = STRTree(pts)
    p = np.array([0.4, 0.4])
    for r in (0.05, 0.2, 0.7):
        want = int(np.sum(np.linalg.norm(pts - p, axis=1) < r))
        assert tree.count_within_strict(p, r) == want
    # exclusion
    want = int(np.sum(np.linalg.norm(pts[1:] - pts[0], axis=1) < 0.3))
    assert tree.count_within_strict(pts[0], 0.3, exclude=0) == want


def test_rtree_build_time_recorded():
    tree = STRTree(np.random.default_rng(0).random((1000, 2)))
    assert tree.build_time > 0
