"""The adaptive query planner: ``auto`` backend, profiles, calibration.

Covers the planner-PR acceptance surface:

* ``auto`` mask-equivalence against every forced concrete backend
  (single + batch; masks are the query answer — raw counts are
  backend-specific diagnostics and may differ across routes);
* calibration profile save/load round-trip (versioned JSON store);
* batch-split recombination correctness on a forced mixed assignment;
* ``explain()`` / ``EngineStats`` plan surfacing;
* ``choose_engine`` profile lookup + warn-once hard-coded fallback;
* power-law fit machinery recovering known exponents.
"""

import json

import numpy as np
import pytest

import repro.core.hybrid as hybrid
from repro.core.backends import QueryRequest, available_backends, get_backend
from repro.core.brute import rknn_brute_np
from repro.core.engine import RkNNConfig, RkNNEngine
from repro.core.hybrid import choose_engine
from repro.core.rknn import BACKENDS, rt_rknn_query, rt_rknn_query_batch
from repro.planner.backend import PlannerBackend
from repro.planner.models import (
    FEATURE_NAMES,
    BackendCostModel,
    CostModel,
    WorkloadShape,
    est_scene_tris,
)
from repro.planner.profiles import (
    PROFILE_VERSION,
    PlannerProfile,
    builtin_profile,
    get_active_profile,
    load_profile,
    set_active_profile,
)


@pytest.fixture(autouse=True)
def _restore_active_profile():
    prev = get_active_profile()
    yield
    set_active_profile(prev)


def _instance(seed, M=50, N=300):
    rng = np.random.default_rng(seed)
    return rng.random((M, 2)), rng.random((N, 2)), rng


# ------------------------------------------------------------------ registry
def test_auto_registered_as_meta_backend():
    assert "auto" in available_backends()
    assert "auto" not in BACKENDS  # concrete-backend lists exclude meta
    b = get_backend("auto")
    assert b.is_meta and isinstance(b, PlannerBackend)
    assert set(b.candidates()) <= set(BACKENDS)


# -------------------------------------------------------------- equivalence
def test_auto_single_query_matches_every_forced_backend():
    F, U, rng = _instance(101)
    eng = RkNNEngine(F, U, RkNNConfig(backend="auto"))
    for q, k in ((3, 4), (int(rng.integers(0, len(F))), 2)):
        res = eng.query(q, k)
        assert res.backend in BACKENDS  # the concrete choice is reported
        truth = rknn_brute_np(U, F, q, k)
        np.testing.assert_array_equal(res.mask, truth)
        for forced in BACKENDS:
            np.testing.assert_array_equal(
                eng.query(q, k, backend=forced).mask, truth
            )


def test_auto_batch_matches_every_forced_backend():
    F, U, rng = _instance(103)
    qs = [int(q) for q in rng.integers(0, len(F), 5)] + [np.array([0.4, 0.6])]
    k = 3
    auto = rt_rknn_query_batch(F, U, qs, k, backend="auto")
    assert auto.backend == "auto"
    for forced in BACKENDS:
        forced_res = rt_rknn_query_batch(F, U, qs, k, backend=forced)
        np.testing.assert_array_equal(auto.masks, forced_res.masks)


def test_auto_empty_batch_and_one_shot_shim():
    F, U, _ = _instance(107, M=20)
    empty = rt_rknn_query_batch(F, U, [], 3, backend="auto")
    assert empty.masks.shape == (0, len(U))
    single = rt_rknn_query(F, U, 2, 3, backend="auto")
    np.testing.assert_array_equal(single.mask, rknn_brute_np(U, F, 2, 3))


def test_auto_mono_query():
    P = np.random.default_rng(109).random((40, 2))
    eng = RkNNEngine(P, P, RkNNConfig(backend="auto"))
    res = eng.query_mono(7, 3)
    from repro.core.brute import rknn_mono_brute_np

    np.testing.assert_array_equal(res.mask, rknn_mono_brute_np(P, 7, 3))


def test_auto_stream_matches_brute_oracle():
    F, U, _ = _instance(113)
    eng = RkNNEngine(F, U, RkNNConfig(backend="auto"))
    for qb, masks in eng.stream([[1, 2], [3]], 4):
        for qi, m in zip(qb, masks):
            np.testing.assert_array_equal(m, rknn_brute_np(U, F, int(qi), 4))
    assert eng.explain()[-1]["mode"] == "stream-batch"


# ------------------------------------------------------------------ explain
def test_explain_and_planner_stats():
    F, U, rng = _instance(127)
    eng = RkNNEngine(F, U, RkNNConfig(backend="auto"))
    res = eng.query(1, 3)
    plans = eng.explain()
    assert len(plans) == 1
    p = plans[0]
    assert p["mode"] == "single" and p["backend"] == res.backend
    assert p["predicted_s"] > 0 and p["observed_s"] > 0
    assert set(p["candidates"]) == set(BACKENDS)
    assert get_backend("auto").explain() == p  # planner keeps the last plan
    qs = [int(q) for q in rng.integers(0, len(F), 4)]
    eng.query_batch(qs, 3)
    p2 = eng.explain()[-1]
    assert p2["mode"] == "batch" and len(p2["assignments"]) == len(qs)
    assert sum(eng.stats.planner_decisions.values()) == 1 + len(qs)
    assert eng.stats.planner_pred_s > 0 and eng.stats.planner_obs_s > 0


def test_auto_repeat_batch_hits_plan_cache():
    F, U, rng = _instance(131)
    qs = [int(q) for q in rng.integers(0, len(F), 6)]
    eng = RkNNEngine(F, U, RkNNConfig(backend="auto"))
    a = eng.query_batch(qs, 4)
    b = eng.query_batch(qs, 4)
    assert eng.stats.batch_cache_hits >= 1
    assert eng.explain()[-1].get("plan_cache_hit")
    np.testing.assert_array_equal(a.masks, b.masks)


# ------------------------------------------------------- batch splitting
def test_batch_split_recombination_mixed_backends(monkeypatch):
    """A forced heterogeneous assignment (every concrete backend appears)
    must recombine counts into the correct per-query masks."""
    F, U, rng = _instance(137, M=60, N=400)
    qs = [int(q) for q in rng.integers(0, len(F), 8)]
    k = 4
    planner = get_backend("auto")
    rotation = ("dense-ref", "brute", "grid", "bvh")

    # pre-scene: force the geometric path so scenes are built and split
    monkeypatch.setattr(
        PlannerBackend, "rank", lambda self, shape, candidates=None: [("dense-ref", 1.0)]
    )
    monkeypatch.setattr(
        PlannerBackend,
        "assign_batch",
        lambda self, shapes, candidates=None: [
            (rotation[i % len(rotation)], 1.0) for i in range(len(shapes))
        ],
    )
    eng = RkNNEngine(F, U, RkNNConfig(backend="auto"))
    res = eng.query_batch(qs, k)
    plan = eng.explain()[-1]
    assert plan["split"] and set(plan["groups"]) == set(rotation)
    assert plan["assignments"] == [rotation[i % len(rotation)] for i in range(len(qs))]
    for i, qi in enumerate(qs):
        np.testing.assert_array_equal(res.masks[i], rknn_brute_np(U, F, qi, k))
    # recombination matches every single-backend batch too
    for forced in rotation:
        np.testing.assert_array_equal(
            res.masks, rt_rknn_query_batch(F, U, qs, k, backend=forced).masks
        )
    assert planner.explain()["groups"] == plan["groups"]


def test_assign_batch_consolidates_close_calls():
    """Splits only happen on decisive predicted savings; near-ties collapse
    to the single cheapest backend."""
    planner = PlannerBackend()
    close = PlannerProfile(
        models={
            "a": _const_model("a", 1.00),
            "b": _const_model("b", 0.99),
        }
    )
    set_active_profile(close)
    shapes = [WorkloadShape(10, 100, 2, 1, m_tris=m) for m in (5, 50, 500)]
    names = {n for n, _ in planner.assign_batch(shapes, candidates=("a", "b"))}
    assert len(names) == 1  # consolidated


def _const_model(name: str, t_s: float) -> BackendCostModel:
    coef = np.zeros(len(FEATURE_NAMES))
    coef[0] = np.log(t_s)
    return BackendCostModel(
        name=name, filter=CostModel(coef.copy() - 50), verify=CostModel(coef)
    )


# ------------------------------------------------------------------ profiles
def test_profile_save_load_roundtrip(tmp_path):
    prof = builtin_profile()
    path = str(tmp_path / "nested" / "profile.json")
    prof.save(path)
    loaded = load_profile(path)
    assert loaded.version == PROFILE_VERSION
    assert loaded.source == prof.source
    assert set(loaded.models) == set(prof.models)
    for nf, nu, k, q in ((50, 1000, 5, 1), (2000, 100000, 64, 32)):
        s = WorkloadShape(nf, nu, k, q)
        for name in prof.models:
            np.testing.assert_allclose(
                loaded.predict_s(name, s), prof.predict_s(name, s), rtol=1e-9
            )


def test_profile_version_mismatch_rejected(tmp_path):
    bad = builtin_profile().to_json()
    bad["version"] = 999
    p = tmp_path / "bad.json"
    p.write_text(json.dumps(bad))
    with pytest.raises(ValueError, match="version"):
        load_profile(str(p))


def test_profile_coef_shape_mismatch_rejected():
    obj = builtin_profile().to_json()
    obj["models"]["brute"]["verify"]["coef"] = [1.0, 2.0]
    with pytest.raises(ValueError, match="stale profile"):
        PlannerProfile.from_json(obj)


def test_profile_change_invalidates_cached_plans():
    """Activating a new profile must bump the epoch in the plan-memo key:
    hot workloads re-plan instead of replaying stale assignments."""
    F, U, rng = _instance(151)
    qs = [int(q) for q in rng.integers(0, len(F), 4)]
    eng = RkNNEngine(F, U, RkNNConfig(backend="auto"))
    eng.query_batch(qs, 3)
    eng.query_batch(qs, 3)
    assert eng.explain()[-1].get("plan_cache_hit")
    set_active_profile(builtin_profile())  # recalibration: epoch bump
    res = eng.query_batch(qs, 3)
    assert not eng.explain()[-1].get("plan_cache_hit")
    for i, qi in enumerate(qs):
        np.testing.assert_array_equal(res.masks[i], rknn_brute_np(U, F, qi, 3))


def test_foreign_hardware_profile_warns_on_load(tmp_path):
    obj = builtin_profile().to_json()
    obj["hardware"] = {"platform": "tpu", "device_kind": "TPU v9",
                       "machine": "riscv"}
    p = tmp_path / "foreign.json"
    p.write_text(json.dumps(obj))
    with pytest.warns(RuntimeWarning, match="different hardware"):
        load_profile(str(p))


def test_active_profile_set_get():
    prof = builtin_profile()
    set_active_profile(prof)
    assert get_active_profile() is prof
    set_active_profile(None)
    assert get_active_profile() is None


def test_env_var_profile_activates_on_first_use(tmp_path, monkeypatch):
    import repro.planner.profiles as profiles

    path = str(tmp_path / "env_profile.json")
    saved = builtin_profile()
    saved.save(path)
    monkeypatch.setenv("REPRO_PLANNER_PROFILE", path)
    monkeypatch.setattr(profiles, "_disk_checked", False)
    set_active_profile(None)
    prof = profiles.active_or_builtin()
    assert prof.source == saved.source and get_active_profile() is prof


def test_group_cache_distinguishes_index_from_point_query():
    """A facility-index query and a point query at the same coordinates
    build different scenes (exclude vs no exclude) — the planner's group
    LRU must not serve one the other's prepared state."""
    F, U, _ = _instance(157)
    eng = RkNNEngine(F, U, RkNNConfig(backend="auto"))
    k = 3
    a = eng.query_batch([5], k)
    b = eng.query_batch([F[5].copy()], k)
    np.testing.assert_array_equal(a.masks[0], rknn_brute_np(U, F, 5, k))
    np.testing.assert_array_equal(b.masks[0], rknn_brute_np(U, F, F[5].copy(), k))
    np.testing.assert_array_equal(
        b.masks, rt_rknn_query_batch(F, U, [F[5].copy()], k).masks
    )


# ---------------------------------------------------------------- cost models
def test_power_law_fit_recovers_exponents():
    """t = c · U · Q fits exactly in log space and extrapolates 10x out."""
    rng = np.random.default_rng(7)
    shapes = [
        WorkloadShape(int(f), int(u), int(k), int(q), m_tris=float(m))
        for f, u, k, q, m in zip(
            rng.integers(10, 1000, 24),
            rng.integers(100, 10000, 24),
            rng.integers(1, 64, 24),
            rng.integers(1, 32, 24),
            rng.integers(4, 500, 24),
        )
    ]
    times = np.array([1e-7 * s.n_users * s.q for s in shapes])
    model = CostModel.fit(shapes, times, ridge=1e-9)
    far = WorkloadShape(5000, 200_000, 128, 256, m_tris=1000.0)
    np.testing.assert_allclose(
        model.predict_s(far), 1e-7 * far.n_users * far.q, rtol=0.05
    )


def test_fit_drop_pins_feature_exponent_to_zero():
    shapes = [
        WorkloadShape(10 * (i + 1), 100 * (i + 1), i + 1, 1, m_tris=7.0 * (i + 1))
        for i in range(12)
    ]
    times = np.array([1e-6 * s.n_users for s in shapes])
    model = CostModel.fit(shapes, times, drop=("log_m",))
    assert model.coef[FEATURE_NAMES.index("log_m")] == 0.0


def test_est_scene_tris_monotone_and_capped():
    assert est_scene_tris(1000, 8) < est_scene_tris(1000, 64)
    assert est_scene_tris(5, 1000) == (5 - 1) * 3.0  # capped by |F|
    s = WorkloadShape(100, 1000, 10, 1, m_tris=17.0)
    assert s.m() == 17.0


def test_pad_waste_measured_vs_estimated():
    """``measured_pad_waste`` is the exact bucketing ratio (sparse uniform
    scatters pay block-granularity padding, dense clusters amortize it);
    ``est_pad_waste`` is the shape-only fallback the planner prices with
    before any user array exists."""
    from repro.core.geometry import Rect
    from repro.kernels.grid_raycast import measured_pad_waste
    from repro.planner.models import est_pad_waste

    rect = Rect(0.0, 0.0, 1.0, 1.0)
    rng = np.random.default_rng(9)
    sparse = rng.random((1_000, 2))  # ~1 user per occupied 64x64 cell
    dense = np.tile(rng.random((10, 2)), (100, 1))  # 10 fat cells
    pw_sparse = measured_pad_waste(sparse[:, 0], sparse[:, 1], rect, 64)
    pw_dense = measured_pad_waste(dense[:, 0], dense[:, 1], rect, 64)
    assert pw_sparse > pw_dense >= 1.0  # padding hurts sparse occupancy
    # the fallback matches the measurement in the regime it models
    assert est_pad_waste(1_000) == pytest.approx(pw_sparse, rel=0.35)
    # shape echoes: explicit measurement wins, fallback otherwise
    assert WorkloadShape(10, 500, 1, 1, pad_waste=3.5).pw() == 3.5
    assert WorkloadShape(10, 500, 1, 1).pw() == est_pad_waste(500)


def test_fit_recovers_pad_waste_exponent_and_stays_nonnegative():
    """t = c · U · pw fits the occupancy exponent when pad_waste varies
    independently of U, and the active-set constraint pins any
    physically-nonsensical negative exponent to zero instead of letting
    extrapolation invert it."""
    rng = np.random.default_rng(11)
    shapes = [
        WorkloadShape(
            int(f), int(u), int(k), 1, m_tris=9.0, pad_waste=float(pw)
        )
        for f, u, k, pw in zip(
            rng.integers(10, 1000, 30),
            rng.integers(100, 10000, 30),
            rng.integers(1, 64, 30),
            rng.uniform(1.0, 30.0, 30),
        )
    ]
    times = np.array([1e-7 * s.n_users * s.pw() for s in shapes])
    model = CostModel.fit(shapes, times, ridge=1e-9)
    assert model.coef[FEATURE_NAMES.index("log_pw")] == pytest.approx(1.0, abs=0.05)
    far = WorkloadShape(500, 50_000, 8, 1, m_tris=9.0, pad_waste=64.0)
    np.testing.assert_allclose(
        model.predict_s(far), 1e-7 * far.n_users * far.pw(), rtol=0.1
    )
    # a cost DECREASING in k would extrapolate to free work at large k;
    # the constrained fit zeroes it (and every other exponent stays >= 0)
    times_dec = np.array([1e-6 * s.n_users / s.k for s in shapes])
    model_dec = CostModel.fit(shapes, times_dec)
    assert model_dec.coef[FEATURE_NAMES.index("log_k")] == 0.0
    assert all(c >= 0.0 for c in model_dec.coef[1:])


def test_observe_converges_and_flips_gp_ref_misroute():
    """Online convergence (the BENCH_5 misroute, distilled): a profile
    that underprices ``grid`` routes everything to it; feeding the
    planner its own closed-out plans (predicted vs. the true cost, where
    ``grid-pallas-ref`` actually wins) must flip ``select()`` to
    grid-pallas-ref and SETTLE there, with the surviving prediction
    calibrated to the observed cost."""

    def const_model(name, filter_s, verify_s):
        f = np.zeros(len(FEATURE_NAMES))
        v = np.zeros(len(FEATURE_NAMES))
        f[0], v[0] = np.log(filter_s), np.log(verify_s)
        return BackendCostModel(name, CostModel(f), CostModel(v))

    set_active_profile(
        PlannerProfile(
            models={
                "grid": const_model("grid", 5e-5, 5e-5),  # underpriced
                "grid-pallas-ref": const_model("grid-pallas-ref", 2e-3, 3e-3),
            }
        )
    )
    true_s = {"grid": 8e-3, "grid-pallas-ref": 2e-3}
    planner = PlannerBackend()
    cands = ("grid", "grid-pallas-ref")
    shape = WorkloadShape(100, 5_000, 8, 16, m_tris=40.0, pad_waste=2.0)
    chosen = []
    for _ in range(40):
        choice, pred, _ = planner.select(shape, cands)
        chosen.append(choice)
        planner.observe(
            {"mode": "single", "backend": choice, "predicted_s": pred,
             "observed_s": true_s[choice]}
        )
    assert chosen[0] == "grid"  # the misprice wins at first...
    assert chosen[-10:] == ["grid-pallas-ref"] * 10  # ...then it settles
    _, pred, _ = planner.select(shape, cands)
    assert abs(np.log(pred / true_s["grid-pallas-ref"])) < 0.5  # calibrated
    assert planner.n_recal_nudges == 40


# ------------------------------------------------------------- calibration
def test_calibration_fit_and_roundtrip(tmp_path):
    """End-to-end: micro-benchmark tiny shapes, fit, save, load, predict."""
    from repro.planner.calibrate import calibrate
    from repro.workloads import Scenario

    tiny = [
        Scenario("cal_a", 25, 300, 3, 2, seed=1),
        Scenario("cal_b", 60, 600, 6, 4, distribution="uniform", seed=2),
        Scenario("cal_c", 120, 400, 4, 1, distribution="clustered", seed=3),
    ]
    prof = calibrate(
        backends=("dense-ref", "brute"),
        scenarios=tiny,
        repeats=1,
        include_slice=True,
    )
    assert set(prof.models) == {"dense-ref", "brute", "slice"}
    assert prof.version == PROFILE_VERSION and prof.source == "calibrated"
    assert prof.hardware.get("platform")
    s = WorkloadShape(100, 5000, 8, 4)
    for name in prof.models:
        t = prof.predict_s(name, s)
        assert np.isfinite(t) and t > 0
    # brute is geometry-free: its scene-size exponent is pinned to zero
    assert prof.models["brute"].verify.coef[FEATURE_NAMES.index("log_m")] == 0.0
    path = str(tmp_path / "cal.json")
    prof.save(path)
    loaded = load_profile(path)
    np.testing.assert_allclose(
        loaded.predict_s("brute", s), prof.predict_s("brute", s), rtol=1e-9
    )
    # an activated calibrated profile drives the auto backend end-to-end
    set_active_profile(loaded)
    F, U, _ = _instance(139)
    res = RkNNEngine(F, U, RkNNConfig(backend="auto")).query(1, 3)
    np.testing.assert_array_equal(res.mask, rknn_brute_np(U, F, 1, 3))


# ------------------------------------------------------------ choose_engine
def test_choose_engine_uses_active_profile():
    rigged_slice = PlannerProfile(
        models={"dense-ref": _const_model("dense-ref", 1.0),
                "slice": _const_model("slice", 1e-6)}
    )
    set_active_profile(rigged_slice)
    # RT regime under the old constants — the profile overrides it
    assert choose_engine(100, 1_000_000, 25) == "slice"
    rigged_rt = PlannerProfile(
        models={"dense-ref": _const_model("dense-ref", 1e-6),
                "slice": _const_model("slice", 1.0)}
    )
    set_active_profile(rigged_rt)
    assert choose_engine(10_000, 100_000, 1) == "rt"


def test_choose_engine_fallback_warns_once_and_keeps_frontier():
    set_active_profile(None)
    hybrid._warned_no_profile = False
    with pytest.warns(RuntimeWarning, match="no active planner profile"):
        assert choose_engine(100, 1_000_000, 25) == "rt"
    # warn-once: subsequent calls are silent and keep the old frontier
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert choose_engine(10_000, 100_000, 1) == "slice"


# ------------------------------------------------------- direct protocol use
def test_planner_direct_protocol_geometry_free():
    F, U, _ = _instance(149)
    planner = get_backend("auto")
    counts = planner.count(
        QueryRequest(
            xs=None, ys=None, k=3,
            users=U, facilities=F, q_pt=F[2], exclude=2,
        )
    )
    np.testing.assert_array_equal(counts < 3, rknn_brute_np(U, F, 2, 3))
    assert planner.explain()["mode"] == "direct-single"
