"""The production health layer (ISSUE 9).

Covers the PR's acceptance surface:

* **live endpoints under a writer stream** — ``/snapshot`` resolved
  against the MVCC snapshot exactly once per request: versions scrape
  monotone, user-only churn never tears the facility fingerprint, and
  every route answers 200 while updates publish concurrently;
* **flight recorder** — an injected writer exception produces a bundle
  with the full postmortem payload (schema, spans, metrics, engine
  config/version, exception traceback) that the CLI digests; rate
  limiting suppresses a dump storm;
* **sentinel hysteresis** — single outliers never flip health, a
  sustained shift trips after ``trip_after`` samples, recovery clears
  after ``clear_after``, and the baseline stays frozen while tripped;
  absolute ``limit`` rules trip without warmup;
* **promtext** — counters/gauges render exact sample lines, histograms
  render monotone cumulative ``_bucket{le=...}`` rows capped by ``+Inf``
  == count, and a flat snapshot re-renders as summary quantiles;
* **trend gate** — ``evaluate_trend`` over fixture artefacts: green on
  a passing latest point, red on a failing one, and latest-point-wins
  across PRs (history is context, not a verdict);
* **compile counter / intern overflow** — the jit-cache probe counts
  distinct-shape compiles; a saturated intern table surfaces exact
  overflow counts through the tracer and the process registry.
"""

import http.client
import json
import threading

import numpy as np
import pytest

from benchmarks.run import TREND_GATES, evaluate_trend
from repro.core.engine import RkNNConfig, RkNNEngine
from repro.dynamic import DynamicEngine
from repro.obs import (
    MetricsRegistry,
    Rule,
    Sentinel,
    Tracer,
    render_registries,
    render_snapshot,
    set_tracer,
    span,
)
from repro.obs.metrics import process_registry


def _small(seed=0, M=40, N=200):
    rng = np.random.default_rng(seed)
    return rng.random((M, 2)), rng.random((N, 2))


def _get(conn: http.client.HTTPConnection, route: str):
    conn.request("GET", route)
    r = conn.getresponse()
    body = r.read()
    return r.status, body


# ---------------------------------------------------------------- endpoints
def test_endpoints_under_writer_stream():
    """Every route serves while updates publish; /snapshot versions are
    monotone and user-only churn never tears the facility fingerprint
    (both fields come from ONE atomically-read snapshot)."""
    F, U = _small()
    dyn = DynamicEngine(F, U, RkNNConfig(backend="dense-ref"))
    srv = dyn.serve_obs(port=0)
    done = threading.Event()
    n_updates = 12

    def writer():
        rng = np.random.default_rng(1)
        try:
            for _ in range(n_updates):
                ids = rng.choice(len(U), 20, replace=False)
                pts = rng.random((20, 2))
                dyn.apply_updates(user_move=(ids, pts))
        finally:
            done.set()

    conn = http.client.HTTPConnection("127.0.0.1", srv.port, timeout=5)
    try:
        th = threading.Thread(target=writer, daemon=True)
        th.start()
        versions, fps, users = [], set(), set()
        while not done.is_set() or not versions:
            code, body = _get(conn, "/snapshot")
            assert code == 200
            snap = json.loads(body)
            versions.append(snap["version"])
            fps.add(snap["fingerprint"])
            users.add(snap["n_users"])
        th.join(timeout=10)
        assert versions == sorted(versions)  # monotone under the stream
        assert versions[-1] >= 1
        assert len(fps) == 1  # facilities untouched: one fingerprint only
        assert users == {len(U)}  # moves never change cardinality
        final = json.loads(_get(conn, "/snapshot")[1])
        assert final["version"] == n_updates
        assert final["device_bytes"]["total"] > 0

        code, body = _get(conn, "/metrics")
        assert code == 200 and body.startswith(b"# TYPE")
        code, body = _get(conn, "/spans?n=8")
        assert code == 200
        payload = json.loads(body)
        assert {"spans", "dropped", "intern_overflows"} <= payload.keys()
        code, body = _get(conn, "/explain")
        assert code == 200
        code, body = _get(conn, "/healthz")
        assert code == 200 and json.loads(body)["ok"] is True
        assert _get(conn, "/nope")[0] == 404
    finally:
        conn.close()
        srv.close()


# ------------------------------------------------------------------ flight
@pytest.fixture
def tracer():
    t = Tracer(capacity=1 << 10)
    prev = set_tracer(t)
    t.enable()
    yield t
    set_tracer(prev)


def test_flight_bundle_on_injected_exception(tmp_path, tracer):
    F, U = _small(seed=2)
    dyn = DynamicEngine(
        F, U,
        RkNNConfig(
            backend="dense-ref", flight_recorder=True, flight_dir=str(tmp_path)
        ),
    )
    dyn.query(0, 5)  # spans + metrics to capture
    bad = np.array([len(U) + 7])  # out-of-range id: the writer must raise
    with pytest.raises(Exception):
        dyn.apply_updates(user_move=(bad, np.zeros((1, 2))))

    bundles = sorted(tmp_path.glob("*.json"))
    assert len(bundles) == 1
    bundle = json.loads(bundles[0].read_text())
    assert bundle["schema"] == "rknn-flight/1"
    assert bundle["reason"] == "exception:apply_updates"
    assert bundle["exception"]["type"] in ("ValueError", "IndexError")
    assert any("apply_updates" in ln for ln in bundle["exception"]["traceback"])
    assert bundle["engine"]["class"] == "DynamicEngine"
    assert bundle["engine"]["n_users"] == len(U)
    assert bundle["engine"]["config"]["flight_recorder"] is True
    assert isinstance(bundle["spans"], list) and bundle["spans"]
    assert any(k.startswith("phase_s") for k in bundle["metrics"])
    assert bundle["metrics"]["queries"] >= 1

    # the CLI digests it (postmortem replay path)
    from repro.obs.__main__ import _digest_postmortem

    assert _digest_postmortem(str(bundles[0]), slowest=3) == 0

    # rate limiting: an immediate second failure is suppressed, counted
    before = process_registry().counter("flight.suppressed").value
    with pytest.raises(Exception):
        dyn.apply_updates(user_move=(bad, np.zeros((1, 2))))
    assert len(sorted(tmp_path.glob("*.json"))) == 1
    assert process_registry().counter("flight.suppressed").value == before + 1


def test_flight_context_manager_dumps_on_block_exception(tmp_path):
    from repro.obs import FlightRecorder

    F, U = _small(seed=3)
    eng = RkNNEngine(F, U, RkNNConfig(backend="dense-ref"))
    with pytest.raises(RuntimeError):
        with FlightRecorder(eng, dir=str(tmp_path), min_interval_s=0.0):
            raise RuntimeError("boom")
    assert eng.flight is None  # disarmed on exit
    [bundle] = sorted(tmp_path.glob("*.json"))
    payload = json.loads(bundle.read_text())
    assert payload["reason"] == "exception:block"
    assert payload["exception"]["message"] == "boom"


# ---------------------------------------------------------------- sentinel
def _fed_sentinel(**rule_kw):
    vals = []
    kw = dict(direction="high", warmup=4, trip_after=3, clear_after=2)
    kw.update(rule_kw)
    rule = Rule("lat", lambda: vals[-1] if vals else None, **kw)
    s = Sentinel([rule], registry=MetricsRegistry())

    def feed(v):
        vals.append(float(v))
        return s.observe()

    return s, feed


def test_sentinel_single_outlier_never_flaps():
    s, feed = _fed_sentinel()
    for _ in range(6):
        assert feed(1.0)
    assert feed(25.0)  # one GC pause / cold compile: breach, no trip
    assert feed(1.0)  # streak reset
    assert feed(25.0)
    assert feed(1.0)
    assert s.healthy
    assert s.state()["lat"]["trips"] == 0


def test_sentinel_trips_on_sustained_shift_then_clears():
    s, feed = _fed_sentinel()
    for _ in range(6):
        feed(1.0)
    baseline = s._states["lat"].mean
    assert feed(25.0)  # streak 1
    assert feed(25.0)  # streak 2
    assert not feed(25.0)  # streak 3 == trip_after: tripped
    assert not s.healthy
    assert not feed(25.0)  # persisting does NOT re-learn the baseline
    assert s._states["lat"].mean == pytest.approx(baseline)
    assert not feed(1.0)  # clear_after=2: one healthy sample isn't enough
    assert feed(1.0)  # second clears
    assert s.healthy
    assert s.state()["lat"]["trips"] == 1


def test_sentinel_low_direction_and_absolute_limit():
    # hit-ratio style rule: bad side is LOW
    s, feed = _fed_sentinel(direction="low")
    for _ in range(6):
        feed(0.9)
    for _ in range(3):
        feed(0.1)
    assert not s.healthy
    # absolute limit trips during warmup — no baseline needed
    s2, feed2 = _fed_sentinel(limit=1.5)
    for _ in range(3):
        feed2(2.0)
    assert not s2.healthy
    assert "limit" in s2.state()["lat"]["last_breach"]


def test_sentinel_skips_none_values():
    s, feed = _fed_sentinel()
    rule = Rule("quiet", lambda: None)
    s.add_rule(rule)
    for _ in range(10):
        feed(1.0)
    assert s.healthy
    assert s.state()["quiet"]["samples"] == 0


# ---------------------------------------------------------------- promtext
def test_promtext_counter_gauge_golden():
    reg = MetricsRegistry()
    reg.counter("query.count", backend="grid").inc(3)
    reg.gauge("mvcc.version_lag").set(2.0)
    lines = render_registries(reg).splitlines()
    assert "# TYPE mvcc_version_lag gauge" in lines
    assert "mvcc_version_lag 2.0" in lines
    assert "# TYPE query_count counter" in lines
    assert 'query_count{backend="grid"} 3' in lines


def test_promtext_histogram_cumulative_buckets():
    reg = MetricsRegistry()
    h = reg.histogram("phase_s", phase="filter", backend="grid")
    for v in (0.5, 0.5, 0.5, 2.0):
        h.observe(v)
    text = render_registries(reg)
    buckets = []
    for ln in text.splitlines():
        if ln.startswith("phase_s_bucket"):
            le = ln.split('le="')[1].split('"')[0]
            buckets.append((le, int(ln.rsplit(" ", 1)[1])))
    assert buckets[-1] == ("+Inf", 4)
    cums = [c for _le, c in buckets]
    assert cums == sorted(cums)  # cumulative: monotone nondecreasing
    edges = [float(le) for le, _c in buckets[:-1]]
    assert edges == sorted(edges)
    # a le=0.5-covering bucket exists with exactly the three fast samples
    assert any(c == 3 and e >= 0.5 for e, c in zip(edges, cums))
    assert "phase_s_count" in text and "phase_s_sum" in text
    assert f'phase_s_count{{backend="grid",phase="filter"}} 4' in text


def test_promtext_snapshot_rerender_quantiles():
    reg = MetricsRegistry()
    h = reg.histogram("phase_s", phase="verify")
    for v in (0.1,) * 10:
        h.observe(v)
    reg.counter("query.count").inc(7)
    text = render_snapshot(reg.snapshot())
    assert "query_count 7" in text
    assert 'quantile="0.5"' in text
    assert "phase_s_count" in text


def test_promtext_sanitizes_names_and_labels():
    reg = MetricsRegistry()
    reg.counter("weird.name-2", tag='a"b\nc').inc()
    text = render_registries(reg)
    assert "weird_name_2" in text
    assert '\\"' in text and "\\n" in text


# -------------------------------------------------------------- trend gate
def _bench_fixture(tmp_path, pr: int, ratio: float) -> str:
    rows = [
        dict(
            bench="obs_overhead",
            name="obs_overhead",
            us_per_call=1.0,
            derived=f"ratio={ratio:.3f} ok={ratio <= 1.03} off=1.0ms on=1.0ms",
        )
    ]
    path = tmp_path / f"BENCH_{pr}.json"
    path.write_text(json.dumps({"meta": {}, "rows": rows}))
    return str(path)


def test_trend_gate_green_on_passing_latest(tmp_path):
    res = evaluate_trend([_bench_fixture(tmp_path, 1, 1.01)])
    assert not res["failures"]
    assert any(ln.startswith("PASS obs-overhead") for ln in res["lines"])
    assert any(ln.startswith("SKIP") for ln in res["lines"])  # others no data


def test_trend_gate_red_on_failing_latest(tmp_path):
    res = evaluate_trend([_bench_fixture(tmp_path, 1, 1.20)])
    assert len(res["failures"]) == 1
    assert "obs-overhead" in res["failures"][0]
    assert "> max" in res["failures"][0]


def test_trend_gate_latest_point_wins(tmp_path):
    paths = [
        _bench_fixture(tmp_path, 1, 1.20),  # history: a regression...
        _bench_fixture(tmp_path, 2, 1.01),  # ...already fixed by pr2
    ]
    res = evaluate_trend(paths)
    assert not res["failures"]
    [line] = [ln for ln in res["lines"] if "obs-overhead" in ln]
    assert "latest=pr2" in line and "pr1:1.2" in line  # history still shown


def test_trend_gate_green_on_committed_trajectory():
    """The repo's own committed BENCH_*.json must grade green — this is
    the same evaluation CI runs via ``--trend --gate``."""
    res = evaluate_trend()
    assert not res["failures"], "\n".join(res["failures"])
    assert any(ln.startswith("PASS") for ln in res["lines"])


def test_trend_gate_declares_health_tolerance():
    [g] = [g for g in TREND_GATES if g["id"] == "health-overhead"]
    assert g["max"] == 1.05 and g["key"] == "ratio"


# ------------------------------------------------- compile counter / intern
def test_compile_counter_counts_distinct_shapes():
    import jax
    import jax.numpy as jnp

    from repro.obs import track_jit

    f = track_jit(jax.jit(lambda x: x * 2), "health_test_fn")
    if not hasattr(f, "__wrapped_jit__"):
        pytest.skip("jit cache-size probe unavailable on this jax")
    f(jnp.ones((3,)))
    f(jnp.ones((3,)))  # cache hit: not a compile
    f(jnp.ones((5,)))  # new shape: recompile
    found = [
        m
        for labels, m in process_registry().find("compile.count")
        if labels.get("fn") == "health_test_fn"
    ]
    assert found and found[0].value == 2
    t = [
        m
        for labels, m in process_registry().find("compile.time_s")
        if labels.get("fn") == "health_test_fn"
    ]
    assert t and t[0].value > 0.0


def test_intern_overflow_saturation_counter():
    t = Tracer(capacity=256, max_interned=4)
    prev = set_tracer(t)
    try:
        t.enable()
        for i in range(32):
            with span(f"distinct-name-{i}"):
                pass
        assert t.intern_overflows > 0
        # the process registry surfaces it as a derived gauge
        snap = process_registry().snapshot()
        assert snap["obs.intern_overflow"] == float(t.intern_overflows)
        # overflow names degrade to the sentinel slot, never crash decode
        names = {r["name"] for r in t.records()}
        assert names  # records still decodable
    finally:
        set_tracer(prev)
