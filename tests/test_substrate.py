"""Substrate tests: optimizer math, data pipeline, checkpointing,
fault-tolerance runtime, gradient compression."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.store import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.data.spatial import PAPER_DATASETS, facility_user_split, road_network_points
from repro.data.tokens import ShardedTokenPipeline, TokenPipelineConfig
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, make_schedule
from repro.runtime.compression import dequantize_int8, make_compressor, quantize_int8
from repro.runtime.driver import DeviceLoss, DriverConfig, TrainDriver
from repro.runtime.elastic import plan_remesh
from repro.runtime.watchdog import StepWatchdog


# ---- optimizer -------------------------------------------------------------

def test_adamw_matches_closed_form_step():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                      grad_clip=1e9, warmup_steps=0, total_steps=10, schedule="constant")
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.5])}
    st = adamw_init(p)
    p2, st2, m = adamw_update(p, g, st, cfg)
    # step 1: mhat = g, vhat = g^2 -> update = lr * g/(|g|+eps) = lr*sign(g)
    want = np.array([1.0, -2.0]) - 0.1 * np.sign([0.5, 0.5])
    np.testing.assert_allclose(np.asarray(p2["w"]), want, atol=1e-5)
    assert int(st2["step"]) == 1


def test_adamw_weight_decay_decoupled():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, grad_clip=1e9, warmup_steps=0,
                      total_steps=10, schedule="constant")
    p = {"w": jnp.array([2.0])}
    g = {"w": jnp.array([0.0])}
    st = adamw_init(p)
    p2, _, _ = adamw_update(p, g, st, cfg)
    # zero grad -> pure decay: w - lr*wd*w
    np.testing.assert_allclose(np.asarray(p2["w"]), [2.0 - 0.1 * 0.5 * 2.0], atol=1e-6)


def test_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110, schedule="cosine")
    s = make_schedule(cfg)
    assert float(s(jnp.array(5))) == pytest.approx(0.5, rel=1e-3)
    assert float(s(jnp.array(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(s(jnp.array(110))) == pytest.approx(0.0, abs=1e-6)


def test_grad_clip_caps_global_norm():
    cfg = AdamWConfig(grad_clip=1.0, warmup_steps=0, total_steps=1, schedule="constant")
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.full(4, 100.0)}
    _, _, metrics = adamw_update(p, g, adamw_init(p), cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0, rel=1e-4)


# ---- data ------------------------------------------------------------------

def test_token_pipeline_deterministic_and_disjoint():
    cfg = TokenPipelineConfig(vocab=1000, seq_len=32, global_batch=8, seed=7)
    a = ShardedTokenPipeline(cfg, host=0, n_hosts=2)
    b = ShardedTokenPipeline(cfg, host=1, n_hosts=2)
    a1, a2 = a.batch_at(3), a.batch_at(3)
    np.testing.assert_array_equal(a1["tokens"], a2["tokens"])  # deterministic
    b1 = b.batch_at(3)
    assert not np.array_equal(a1["tokens"], b1["tokens"])  # disjoint shards
    # labels are next-token shifted
    full = ShardedTokenPipeline(cfg).batch_at(0)
    assert full["tokens"].shape == (8, 32)
    assert full["labels"].shape == (8, 32)


def test_token_pipeline_steps_differ():
    cfg = TokenPipelineConfig(vocab=100, seq_len=16, global_batch=4)
    p = ShardedTokenPipeline(cfg)
    assert not np.array_equal(p.batch_at(0)["tokens"], p.batch_at(1)["tokens"])


def test_road_network_generator_shapes_and_structure():
    pts = road_network_points(20_000, seed=1)
    assert pts.shape == (20_000, 2)
    assert (pts >= 0).all() and (pts <= 1).all()
    # road-like: strongly non-uniform (many near-duplicate x after rounding)
    occupied = len(np.unique((pts * 50).astype(int), axis=0))
    assert occupied < 2000  # uniform would fill ~2400+ of 2500 cells
    f, u = facility_user_split(pts, 100, seed=0)
    assert len(f) == 100 and len(u) == 19_900
    assert set(PAPER_DATASETS) == {"NY", "FLA", "CAL", "E", "CTR", "USA"}


# ---- checkpoint --------------------------------------------------------------

def _tree():
    return {
        "params": {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones(3)},
        "opt": {"m": {"w": jnp.zeros((2, 3)), "b": jnp.zeros(3)}, "step": jnp.int32(5)},
    }


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 10, t)
    restored, manifest = restore_checkpoint(str(tmp_path), t)
    assert manifest["step"] == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4):
        save_checkpoint(str(tmp_path), s, t, keep=2)
    assert latest_step(str(tmp_path)) == 4
    kept = sorted(os.listdir(tmp_path))
    assert len([k for k in kept if k.startswith("step_")]) == 2


def test_checkpoint_ignores_incomplete_tmp(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    os.makedirs(tmp_path / "step_000000000999.tmp")  # simulated crash
    assert latest_step(str(tmp_path)) == 1


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    t = _tree()
    ck.save(7, t)
    ck.wait()
    restored, m = restore_checkpoint(str(tmp_path), t)
    assert m["step"] == 7


def test_restore_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"w": jnp.zeros((3, 3))})


# ---- fault tolerance ---------------------------------------------------------

def test_watchdog_flags_stragglers():
    wd = StepWatchdog(k_sigma=3.0, min_steps=4, abs_floor_s=0.0)
    for _ in range(20):
        assert not wd.observe(0.10 + np.random.default_rng(0).normal(0, 1e-4))
    assert wd.observe(1.0)  # 10x step time -> straggler
    assert wd.flags == 1
    assert wd.mean == pytest.approx(0.10, rel=0.01)  # stats not poisoned


def test_elastic_plan_prefers_model_axis():
    p = plan_remesh(256 - 5, prefer_model=16, global_batch=256)
    assert p.model == 16 and p.data == 15 and p.n_used == 240
    assert p.dropped_batch_rows == 256 - 255  # batch trimmed, not devices
    # heavy loss: model axis halves until something fits
    p2 = plan_remesh(9, prefer_model=16, global_batch=256)
    assert p2.n_used >= 8 and p2.model in (1, 2, 4, 8)


def test_driver_checkpoint_restart_and_failure_injection(tmp_path):
    calls = {"fail_armed": True}

    def init_state():
        return {"x": jnp.zeros(()), "n": jnp.int32(0)}

    def step_fn(state, batch):
        return {"x": state["x"] + batch["v"], "n": state["n"] + 1}, {"x": state["x"]}

    def batch_fn(step):
        return {"v": jnp.float32(step)}

    def inject(step):
        if step == 7 and calls["fail_armed"]:
            calls["fail_armed"] = False
            raise RuntimeError("simulated transient fault")

    drv = TrainDriver(
        str(tmp_path),
        DriverConfig(total_steps=10, save_every=5, max_retries=2),
        init_state=init_state,
        step_fn=step_fn,
        batch_fn=batch_fn,
        inject_failure=inject,
    )
    state, done = drv.run()
    assert done == 10
    # sum over 0..9 exactly once despite the crash at step 7 (restart from 5)
    assert float(state["x"]) == sum(range(10))
    assert any(e.startswith("retry1") for e in drv.events)
    assert any(e.startswith("restore:step_5") for e in drv.events)


def test_driver_device_loss_triggers_remesh(tmp_path):
    armed = {"on": True}
    seen = {}

    def inject(step):
        if step == 3 and armed["on"]:
            armed["on"] = False
            raise DeviceLoss(n_alive=200)

    def on_remesh(n_alive):
        seen["plan"] = plan_remesh(n_alive, prefer_model=16, global_batch=256)

    drv = TrainDriver(
        str(tmp_path),
        DriverConfig(total_steps=5, save_every=2),
        init_state=lambda: {"x": jnp.zeros(())},
        step_fn=lambda s, b: ({"x": s["x"] + 1}, {}),
        batch_fn=lambda i: {},
        on_remesh=on_remesh,
        inject_failure=inject,
    )
    state, done = drv.run()
    assert done == 5 and float(state["x"]) == 5
    assert seen["plan"].model == 16 and seen["plan"].n_used == 192
    assert "remesh" in drv.events


# ---- compression ---------------------------------------------------------------

def test_int8_quantization_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 1, 1000).astype(np.float32))
    q, s = quantize_int8(g)
    err = np.abs(np.asarray(dequantize_int8(q, s) - g))
    assert err.max() <= float(s) / 2 + 1e-6


def test_error_feedback_is_unbiased_over_time():
    """With a constant gradient, EF-compressed updates must average to the
    true gradient (residuals don't accumulate unboundedly)."""
    comp = make_compressor()
    g_true = {"w": jnp.asarray(np.linspace(-3e-3, 7e-3, 64), dtype=jnp.float32)}
    state = {"ef": None}
    state["ef"] = None
    total = np.zeros(64)
    st = {"ef": jax.tree.map(lambda p: jnp.zeros_like(p), g_true)}
    n = 50
    for _ in range(n):
        gq, st = comp(g_true, st)
        total += np.asarray(gq["w"])
    np.testing.assert_allclose(total / n, np.asarray(g_true["w"]), atol=5e-5)


def test_compressor_in_train_step():
    from repro.configs.registry import get_reduced
    from repro.models.registry import build_model
    from repro.steps.train import init_train_state, make_train_step

    cfg = get_reduced("starcoder2_3b", n_layers=2)
    model = build_model(cfg)
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=4)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    state["ef"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
    step = jax.jit(make_train_step(model, opt, compress_grads=make_compressor()))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    state2, m = step(state, {"tokens": tokens, "labels": tokens})
    assert np.isfinite(float(m["loss"]))
    ef_norm = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(state2["ef"]))
    assert ef_norm > 0  # residuals live in the state


# ---- 8-bit Adam (single-pod 405B fit path) ----------------------------------

def test_adamw8bit_quantize_roundtrip():
    from repro.optim.adamw8bit import dequantize_blockwise, quantize_blockwise

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 0.01, (7, 99)).astype(np.float32))
    q, s = quantize_blockwise(x, signed=True)
    back = dequantize_blockwise(q, s, x.shape, signed=True)
    err = np.abs(np.asarray(back - x))
    # per-block absmax/127 error bound
    assert err.max() <= float(s.max()) / 2 + 1e-7
    v = jnp.abs(x)
    qv, sv = quantize_blockwise(v, signed=False)
    backv = dequantize_blockwise(qv, sv, v.shape, signed=False)
    assert np.abs(np.asarray(backv - v)).max() <= float(sv.max()) / 2 + 1e-7


def test_adamw8bit_tracks_fp32_adam():
    """A quadratic toy problem converges under int8 moments within a few
    percent of fp32 AdamW (the bounded-noise argument, measured)."""
    from repro.optim.adamw8bit import adamw8bit_init, adamw8bit_update

    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, grad_clip=1e9,
                      warmup_steps=0, total_steps=200, schedule="constant")
    target = jnp.asarray(np.random.default_rng(1).normal(0, 1, 256).astype(np.float32))

    def run(update, init):
        p = {"w": jnp.zeros(256)}
        st = init(p)
        for _ in range(150):
            g = {"w": p["w"] - target}
            p, st, _ = update(p, g, st, cfg)
        return float(jnp.mean((p["w"] - target) ** 2))

    loss8 = run(adamw8bit_update, adamw8bit_init)
    loss32 = run(adamw_update, adamw_init)
    assert loss8 < 1e-2
    assert loss8 < max(loss32 * 3.0, 1e-2)


def test_adamw8bit_state_bytes():
    """The point of the exercise: optimizer state ~2.06 B/param vs 8."""
    from repro.optim.adamw8bit import adamw8bit_init

    p = {"w": jnp.zeros((1024, 1024), jnp.float32)}
    st = adamw8bit_init(p)
    n_bytes = sum(
        l.size * l.dtype.itemsize for l in jax.tree.leaves(st["m8"])
    )
    assert n_bytes / p["w"].size < 2.2  # int8 m + int8 v + scales
