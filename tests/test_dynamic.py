"""The dynamic-data subsystem (ISSUE 4).

Covers the update-PR acceptance surface:

* **cold-rebuild equivalence** (property-style): any seeded sequence of
  facility/user inserts/deletes/moves followed by ``query``/``query_batch``
  is bit-identical — masks AND counts — to a cold engine built from the
  final snapshot, across every registered concrete backend;
* the survive / refit / rebuild cache ladder actually fires (user-only
  deltas keep scenes; far facility churn keeps scenes via the pruning
  certificate; near jitter refits; everything stays correct);
* index refit units: ``refit_grid`` / ``refit_bvh`` count exactly like
  fresh builds, and the BVH quality gate rebuilds on large drift;
* continuous queries: exact masks under churn, influence-zone skips,
  change-only event streaming, handle death on query deletion;
* online planner re-calibration flips a mispriced backend choice;
* per-runner-class profile store round-trips and rejects foreign hardware;
* the ``RkNNServer`` deprecation warning fires exactly once per process.
"""

import warnings

import numpy as np
import pytest

from repro.core.backends import concrete_backends, get_backend
from repro.core.brute import rank_counts_np
from repro.core.engine import RkNNConfig, RkNNEngine
from repro.core.geometry import Rect
from repro.core.grid import build_grid, grid_hit_counts_jnp, refit_grid
from repro.core.bvh import build_bvh, bvh_hit_counts, refit_bvh
from repro.core.scene import build_scene
from repro.dynamic import DynamicEngine, UpdateBatch, apply_to_points
from repro.workloads import drifting_users, facility_churn, facility_jitter


def _instance(seed, M=50, N=300, pin_hull=True):
    rng = np.random.default_rng(seed)
    F, U = rng.random((M, 2)), rng.random((N, 2))
    if pin_hull:  # corner facilities: interior churn never moves the rect
        F[:4] = [[0.0, 0.0], [0.0, 1.0], [1.0, 0.0], [1.0, 1.0]]
    return F, U, rng


def _random_batch(rng, F, U, *, protect=()):
    """One random mixed delta against the current snapshot."""
    protected = np.asarray(sorted(protect), np.int64)
    f_cand = np.setdiff1d(np.arange(4, len(F)), protected)
    n_fm = int(rng.integers(0, 3))
    n_fd = int(rng.integers(0, 2))
    picks = rng.choice(f_cand, size=min(n_fm + n_fd, len(f_cand)), replace=False)
    fm, fd = picks[:n_fm], picks[n_fm:]
    n_um = int(rng.integers(0, 20))
    um = rng.choice(len(U), size=n_um, replace=False)
    n_ud = int(rng.integers(0, 3))
    ud = np.setdiff1d(rng.choice(len(U), size=n_ud, replace=False), um)
    return UpdateBatch(
        facility_move=(fm, np.clip(F[fm] + rng.normal(0, 0.05, (len(fm), 2)), 0, 1)),
        facility_delete=fd,
        facility_insert=rng.random((int(rng.integers(0, 2)), 2)),
        user_move=(um, np.clip(U[um] + rng.normal(0, 0.02, (len(um), 2)), 0, 1)),
        user_delete=ud,
        user_insert=rng.random((int(rng.integers(0, 3)), 2)),
    )


def _apply_shadow(F, U, batch):
    F, _ = apply_to_points(
        F, batch.facility_insert, batch.facility_delete, batch.facility_move
    )
    U, _ = apply_to_points(U, batch.user_insert, batch.user_delete, batch.user_move)
    return F, U


# ---------------------------------------------------- cold-rebuild equivalence
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_update_sequence_matches_cold_engine_all_backends(seed):
    """Property: after any update sequence, every backend's dynamic-path
    masks AND counts equal a cold engine built from the final snapshot."""
    F, U, rng = _instance(seed, M=40, N=200)
    qs = [5, 9, np.array([0.4, 0.6])]
    k = 4
    dyn = DynamicEngine(F, U, RkNNConfig(backend="dense-ref"))
    dyn.query_batch([5, 9], k)  # populate caches so migration has work
    for _ in range(4):
        batch = _random_batch(rng, dyn.facilities, dyn.users, protect=(5, 9))
        F, U = _apply_shadow(dyn.facilities, dyn.users, batch)
        dyn.apply_updates(batch)
        np.testing.assert_array_equal(dyn.facilities, F)
        np.testing.assert_array_equal(dyn.users, U)
        # interleave queries so later migrations see warm caches
        dyn.query_batch([5, 9], k)
    for backend in concrete_backends():
        cold = RkNNEngine(dyn.facilities, dyn.users, RkNNConfig(backend=backend))
        bd = dyn.query_batch(qs, k, backend=backend)
        bc = cold.query_batch(qs, k)
        np.testing.assert_array_equal(bd.masks, bc.masks, err_msg=backend)
        np.testing.assert_array_equal(bd.counts, bc.counts, err_msg=backend)
        for q in qs:
            sd = dyn.query(q, k, backend=backend)
            sc = cold.query(q, k)
            np.testing.assert_array_equal(sd.mask, sc.mask, err_msg=backend)
            np.testing.assert_array_equal(sd.counts, sc.counts, err_msg=backend)


def test_generated_streams_match_cold_engine():
    """The shipped stream generators (drift / churn / jitter) stay exact."""
    F, U, _ = _instance(7, M=60, N=250)
    qs = [6, 10]
    k = 5
    streams = (
        drifting_users(U, steps=2, frac=0.1, seed=1)
        + facility_jitter(F, steps=2, frac=0.05, seed=2, protect=np.asarray(qs))
        + facility_churn(F, steps=1, rate=0.03, seed=3, protect=np.asarray(qs))
    )
    # note: churn ids reference the snapshot the generator saw; replay the
    # same order the generator assumed (drift first mutates users only)
    dyn = DynamicEngine(F, U, RkNNConfig(backend="grid"))
    dyn.query_batch(qs, k)
    for batch in streams:
        dyn.apply_updates(batch)
        cold = RkNNEngine(dyn.facilities, dyn.users, RkNNConfig(backend="grid"))
        np.testing.assert_array_equal(
            dyn.query_batch(qs, k).masks, cold.query_batch(qs, k).masks
        )


def test_update_validation_errors():
    F, U, _ = _instance(0)
    dyn = DynamicEngine(F, U)
    with pytest.raises(IndexError):
        dyn.apply_updates(UpdateBatch(facility_delete=[len(F)]))
    with pytest.raises(ValueError):
        dyn.apply_updates(
            UpdateBatch(user_delete=[1], user_move=([1], [[0.5, 0.5]]))
        )
    with pytest.raises(ValueError):
        UpdateBatch(facility_move=([1, 2], [[0.1, 0.2]]))
    rep = dyn.apply_updates(UpdateBatch())
    assert rep.version == 1 and dyn.version == 1  # empty delta still versions


# ------------------------------------------------------- the cache ladder
def test_user_only_updates_keep_scenes_and_scatter():
    F, U, rng = _instance(11)
    dyn = DynamicEngine(F, U, RkNNConfig(backend="dense-ref"))
    qs = [5, 9, 13]
    dyn.query_batch(qs, 4)
    dyn.xs  # materialize the device arrays so the scatter path runs
    ids = rng.choice(len(U), 25, replace=False)
    pts = np.clip(U[ids] + rng.normal(0, 0.01, (25, 2)), 0.01, 0.99)
    rep = dyn.apply_updates(UpdateBatch(user_move=(ids, pts)))
    assert not rep.rect_changed
    assert rep.scenes_survived == 3 and rep.scenes_dropped == 0
    assert rep.users_scattered
    # user-only move: the prepared batch is carried, re-pointed at the
    # scattered arrays — the repeat workload skips the whole filter phase
    assert rep.batches_carried >= 1
    b0 = dyn.stats.batch_cache_hits
    r = dyn.query_batch(qs, 4)
    assert dyn.stats.batch_cache_hits == b0 + 1
    cold = RkNNEngine(dyn.facilities, dyn.users, RkNNConfig(backend="dense-ref"))
    np.testing.assert_array_equal(r.masks, cold.query_batch(qs, 4).masks)
    # a new batch composition misses the prepared LRU but the surviving
    # scenes still hit the scene cache
    h0 = dyn.scene_cache.hits
    r2 = dyn.query_batch(qs[:2], 4)
    assert dyn.scene_cache.hits == h0 + 2
    np.testing.assert_array_equal(r2.masks, cold.query_batch(qs[:2], 4).masks)


def test_far_facility_change_survives_certificate():
    """A facility inserted far outside every query's pruning certificate
    leaves all cached scenes alive (and still bit-correct)."""
    F, U, _ = _instance(13)
    # queries clustered near the origin corner, insertion at the far corner
    F[5:8] = [[0.1, 0.1], [0.12, 0.08], [0.09, 0.13]]
    dyn = DynamicEngine(F, U, RkNNConfig(backend="dense-ref"))
    qs = [5, 6, 7]
    dyn.query_batch(qs, 2)
    rep = dyn.apply_updates(UpdateBatch(facility_insert=[[0.999, 0.999]]))
    assert rep.scenes_survived == 3, rep
    cold = RkNNEngine(dyn.facilities, dyn.users, RkNNConfig(backend="dense-ref"))
    np.testing.assert_array_equal(
        dyn.query_batch(qs, 2).counts, cold.query_batch(qs, 2).counts
    )


def test_near_jitter_refits_scene_and_indexes():
    F, U, rng = _instance(17, M=80, N=400)
    for backend in ("grid", "bvh"):
        dyn = DynamicEngine(F, U, RkNNConfig(backend=backend))
        dyn.query(5, 6)
        scene = dyn._build_scene(dyn._snap, 5, 6, dyn.rect)
        kept = np.flatnonzero(scene.keep)
        kept = kept[kept >= 4][:2]  # never jitter the hull-pinning corners
        jit = dyn.facilities[kept] + 1e-4
        rep = dyn.apply_updates(UpdateBatch(facility_move=(kept, jit)))
        assert rep.scenes_refit >= 1, (backend, rep)
        assert rep.indexes_refit >= 1, (backend, rep)
        cold = RkNNEngine(dyn.facilities, dyn.users, RkNNConfig(backend=backend))
        rd, rc = dyn.query(5, 6), cold.query(5, 6)
        np.testing.assert_array_equal(rd.counts, rc.counts)
        np.testing.assert_array_equal(rd.mask, rc.mask)


def test_deleted_query_facility_drops_its_scenes_and_remaps_others():
    F, U, _ = _instance(19)
    dyn = DynamicEngine(F, U, RkNNConfig(backend="dense-ref"))
    dyn.query(10, 3)
    dyn.query(20, 3)
    rep = dyn.apply_updates(UpdateBatch(facility_delete=[10]))
    assert rep.scenes_dropped >= 1
    # old row 20 is row 19 now; equivalence against a cold engine
    cold = RkNNEngine(dyn.facilities, dyn.users, RkNNConfig(backend="dense-ref"))
    np.testing.assert_array_equal(
        dyn.query(19, 3).counts, cold.query(19, 3).counts
    )


# ---------------------------------------------------------- index refit units
def test_refit_grid_counts_match_fresh_build():
    F, U, rng = _instance(23, M=60, N=300)
    rect = Rect.from_points(F, U)
    sc = build_scene(F, 5, 8, rect)
    n = sc.n_tris
    g = build_grid(sc.tris[:n], sc.coeffs[:n], rect, G=32)
    # jitter a kept facility, rebuild its occluder rows through refit_scene
    F2 = F.copy()
    kept = np.flatnonzero(sc.keep)[0]
    F2[kept] += 1e-4
    sc2 = build_scene(F2, 5, 8, rect)
    assert sc2.n_tris == n
    changed = np.flatnonzero(
        (sc.coeffs[:n] != sc2.coeffs[:n]).reshape(n, -1).any(axis=1)
    )
    g2 = refit_grid(g, sc.tris[:n], sc.coeffs[:n], sc2.tris[:n], sc2.coeffs[:n], changed)
    assert g2 is not None and g2 is not g
    fresh = build_grid(sc2.tris[:n], sc2.coeffs[:n], rect, G=32)
    xs = U[:, 0].astype(np.float32)
    ys = U[:, 1].astype(np.float32)
    a = np.asarray(grid_hit_counts_jnp(xs, ys, g2.base, g2.lists, g2.coeffs, rect, 32))
    b = np.asarray(
        grid_hit_counts_jnp(xs, ys, fresh.base, fresh.lists, fresh.coeffs, rect, 32)
    )
    np.testing.assert_array_equal(a, b)


def test_refit_bvh_counts_match_and_quality_gate_trips():
    F, U, _ = _instance(29, M=60, N=300)
    rect = Rect.from_points(F, U)
    sc = build_scene(F, 5, 8, rect)
    n = sc.n_tris
    bvh = build_bvh(sc.tris[:n])
    jitter = sc.tris[:n] + 1e-5
    refit = refit_bvh(bvh, jitter)
    assert refit is not None
    coeffs = sc.coeffs[:n]
    xs = U[:, 0].astype(np.float32)
    ys = U[:, 1].astype(np.float32)
    fresh = build_bvh(jitter)
    a = np.asarray(
        bvh_hit_counts(xs, ys, refit.left, refit.right, refit.bbox, coeffs, k=8)
    )
    b = np.asarray(
        bvh_hit_counts(xs, ys, fresh.left, fresh.right, fresh.bbox, coeffs, k=8)
    )
    np.testing.assert_array_equal(a, b)
    # scatter the triangles far apart: box areas explode, the gate must trip
    shift = np.random.default_rng(0).uniform(-100, 100, (n, 1, 2))
    assert refit_bvh(bvh, sc.tris[:n] + shift) is None
    assert refit_bvh(bvh, jitter[:-1]) is None  # count mismatch


# ------------------------------------------------------------- continuous
def test_continuous_query_exact_under_churn():
    F, U, rng = _instance(31, M=50, N=250)
    dyn = DynamicEngine(F, U, RkNNConfig(backend="dense-ref"))
    cq = dyn.register_continuous(8, 4)
    np.testing.assert_array_equal(cq.mask, rank_counts_np(U, F, F[8], exclude=8) < 4)
    versions = []
    for _ in range(5):
        batch = _random_batch(rng, dyn.facilities, dyn.users, protect=(8,))
        dyn.apply_updates(batch)
        truth = rank_counts_np(
            dyn.users, dyn.facilities, dyn.facilities[cq.q_idx], exclude=cq.q_idx
        )
        np.testing.assert_array_equal(cq.counts, truth)  # bitwise-exact patching
        np.testing.assert_array_equal(cq.mask, truth < 4)
        versions.extend(v for v, _ in cq.poll())
    assert cq.alive and cq.version == dyn.version
    assert versions == sorted(versions)


def test_continuous_query_skips_far_updates_and_emits_on_change_only():
    F, U, _ = _instance(37)
    F[5] = [0.1, 0.1]
    U_local = np.clip(
        np.random.default_rng(1).normal(0.1, 0.03, (100, 2)), 0.0, 0.3
    )
    dyn = DynamicEngine(F, U_local, RkNNConfig(backend="dense-ref"))
    k = 1  # any adjacent facility steals q's nearest users
    cq = dyn.register_continuous(5, k)
    # far corner insert: provably outside the influence zone -> no event
    dyn.apply_updates(UpdateBatch(facility_insert=[[0.99, 0.99]]))
    assert cq.n_skipped == 1 and not cq.poll()
    # a facility dropped onto the query's doorstep must emit
    dyn.apply_updates(UpdateBatch(facility_insert=[[0.1, 0.11]]))
    events = cq.poll()
    assert len(events) == 1
    version, res = events[0]
    assert version == dyn.version and res.backend == "continuous"
    truth = rank_counts_np(dyn.users, dyn.facilities, dyn.facilities[5], exclude=5)
    np.testing.assert_array_equal(res.mask, truth < k)


def test_vectorized_dirty_test_routes_handles(monkeypatch):
    """One batched influence-zone test decides per update which handles
    run the exact patch: provably-clean handles never enter
    ``_on_update`` (counted via monkeypatch), user deltas dirty every
    handle, and results stay bitwise-exact either way."""
    from repro.dynamic.continuous import ContinuousQuery, influence_dirty_mask

    F, U, _ = _instance(45)
    F[5] = [0.1, 0.1]
    F[9] = [0.15, 0.12]
    U_local = np.clip(
        np.random.default_rng(2).normal(0.12, 0.03, (120, 2)), 0.0, 0.3
    )
    dyn = DynamicEngine(F, U_local, RkNNConfig(backend="dense-ref"))
    h1 = dyn.register_continuous(5, 2)
    h2 = dyn.register_continuous(9, 2)

    calls = []
    orig = ContinuousQuery._on_update

    def spy(self, ctx):
        calls.append(self)
        return orig(self, ctx)

    monkeypatch.setattr(ContinuousQuery, "_on_update", spy)

    # far corner insert: outside both influence zones -> neither patches
    dyn.apply_updates(UpdateBatch(facility_insert=[[0.99, 0.99]]))
    assert calls == []
    assert h1.n_skipped == 1 and h2.n_skipped == 1
    assert h1.version == dyn.version and h2.version == dyn.version

    # the batched mask agrees with the per-handle distance test
    far = np.array([[0.99, 0.99]])
    near = np.array([[0.1, 0.12]])
    assert not influence_dirty_mask([h1, h2], far).any()
    assert influence_dirty_mask([h1, h2], near).all()

    # doorstep insert: both handles take the exact patch path
    dyn.apply_updates(UpdateBatch(facility_insert=near))
    assert len(calls) == 2

    # user deltas reconcile rows/thresholds: every handle is dirty
    dyn.apply_updates(UpdateBatch(user_insert=[[0.2, 0.2]]))
    assert len(calls) == 4

    for h in (h1, h2):
        truth = rank_counts_np(
            dyn.users, dyn.facilities, dyn.facilities[h.q_idx], exclude=h.q_idx
        )
        np.testing.assert_array_equal(h.counts, truth)


def test_clean_skip_still_remaps_tracked_facility(monkeypatch):
    """A facility delete far outside a handle's influence zone takes the
    clean path but must still remap the tracked row id through the
    compaction."""
    from repro.dynamic.continuous import ContinuousQuery

    F, U, _ = _instance(46)
    F[9] = [0.1, 0.1]
    U_local = np.clip(
        np.random.default_rng(3).normal(0.1, 0.02, (80, 2)), 0.0, 0.25
    )
    dyn = DynamicEngine(F, U_local, RkNNConfig(backend="dense-ref"))
    cq = dyn.register_continuous(9, 3)
    monkeypatch.setattr(
        ContinuousQuery, "_on_update",
        lambda self, ctx: pytest.fail("clean handle entered the exact patch"),
    )
    F2 = dyn.facilities.copy()
    far_row = int(np.argmax(np.linalg.norm(F2 - [0.1, 0.1], axis=1)))
    assert far_row < 9  # deletion shifts the tracked id down
    dyn.apply_updates(UpdateBatch(facility_delete=[far_row]))
    assert cq.q_idx == 8 and cq.n_skipped == 1
    truth = rank_counts_np(
        dyn.users, dyn.facilities, dyn.facilities[8], exclude=8
    )
    np.testing.assert_array_equal(cq.counts, truth)


def test_continuous_query_dies_with_its_facility():
    F, U, _ = _instance(41)
    dyn = DynamicEngine(F, U)
    cq = dyn.register_continuous(7, 3)
    dyn.apply_updates(UpdateBatch(facility_delete=[7]))
    assert not cq.alive
    dyn.apply_updates(UpdateBatch(user_insert=[[0.5, 0.5]]))  # no crash
    assert cq not in dyn._continuous  # dead handles are dropped


def test_continuous_query_close_and_event_accounting():
    F, U, _ = _instance(42)
    dyn = DynamicEngine(F, U)
    cq = dyn.register_continuous(6, 2)
    rep = dyn.apply_updates(UpdateBatch(facility_insert=[F[6] + 1e-3]))
    assert rep.continuous_events == cq.n_events  # counter, not buffer length
    cq.close()
    assert not cq.alive and not cq.poll()
    n = cq.n_events
    dyn.apply_updates(UpdateBatch(facility_insert=[F[6] + 2e-3]))
    assert cq not in dyn._continuous and cq.n_events == n  # no longer maintained


def test_continuous_point_query_and_moved_query_facility():
    F, U, rng = _instance(43)
    dyn = DynamicEngine(F, U)
    cp = dyn.register_continuous(np.array([0.3, 0.3]), 4)
    cf = dyn.register_continuous(6, 4)
    dyn.apply_updates(UpdateBatch(facility_move=([6], [[0.7, 0.2]])))
    assert cf.n_full == 1  # its own facility moved: full recount
    t_pt = rank_counts_np(dyn.users, dyn.facilities, np.array([0.3, 0.3]))
    t_f = rank_counts_np(dyn.users, dyn.facilities, dyn.facilities[6], exclude=6)
    np.testing.assert_array_equal(cp.counts, t_pt)
    np.testing.assert_array_equal(cf.counts, t_f)


# ------------------------------------------------- online re-calibration
def test_online_recalibration_shifts_backend_choice():
    from repro.planner.models import (
        FEATURE_NAMES,
        BackendCostModel,
        CostModel,
    )
    from repro.planner.profiles import (
        PlannerProfile,
        get_active_profile,
        set_active_profile,
    )

    def const_model(name, filter_s, verify_s):
        f = np.zeros(len(FEATURE_NAMES))
        v = np.zeros(len(FEATURE_NAMES))
        f[0], v[0] = np.log(filter_s), np.log(verify_s)
        return BackendCostModel(name, CostModel(f), CostModel(v))

    F, U, _ = _instance(47)
    prev = get_active_profile()
    set_active_profile(
        PlannerProfile(
            models={
                "brute": const_model("brute", 1e-9, 1e-9),  # absurdly cheap
                "dense-ref": const_model("dense-ref", 1e-3, 2e-3),
            }
        )
    )
    try:
        eng = RkNNEngine(
            F, U, RkNNConfig(backend="auto", online_recalibration=True)
        )
        chosen = [eng.query(3, 5).backend for _ in range(80)]
        assert chosen[0] == "brute"  # the misprice wins at first...
        assert "dense-ref" in chosen  # ...until residuals correct it
        assert eng.stats.planner_recal_nudges > 0
        # off by default: a fresh engine with the flag unset never nudges
        eng2 = RkNNEngine(F, U, RkNNConfig(backend="auto"))
        eng2.query(3, 5)
        assert eng2.stats.planner_recal_nudges == 0
    finally:
        set_active_profile(prev)


# ------------------------------------------------- runner-class profiles
def test_runner_profile_store_roundtrip(tmp_path):
    from repro.planner import profiles as P

    prof = P.builtin_profile()
    import copy

    mine = copy.deepcopy(prof)
    mine.hardware = P.hardware_fingerprint()
    path = mine.save(P.runner_profile_path(str(tmp_path)))
    assert path.endswith(P.runner_class() + ".json")
    loaded = P.load_runner_profile(str(tmp_path))
    assert loaded is not None and set(loaded.models) == set(mine.models)
    # foreign hardware is rejected outright (strict, unlike load_profile)
    mine.hardware = dict(mine.hardware, device_kind="TPU v99")
    mine.save(P.runner_profile_path(str(tmp_path)))
    assert P.load_runner_profile(str(tmp_path)) is None
    assert P.load_runner_profile(str(tmp_path / "missing")) is None


# ------------------------------------------------------ deprecation (once)
def test_rknn_server_deprecation_warns_exactly_once():
    from repro.launch import serve

    F, U, _ = _instance(53)
    old = serve._deprecation_warned
    serve._deprecation_warned = False
    try:
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            serve.RkNNServer(F, U)
            serve.RkNNServer(F, U)
        dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
        assert len(dep) == 1
        assert "RkNNEngine" in str(dep[0].message)
    finally:
        serve._deprecation_warned = old


# ----------------------------------------------------------- mesh scatter
def test_dynamic_engine_with_mesh_scatters_and_stays_exact():
    from repro.launch.mesh import make_mesh_for_devices

    mesh = make_mesh_for_devices(1, model_axis=1)
    F, U, rng = _instance(59, M=40, N=256)
    dyn = DynamicEngine(F, U, RkNNConfig(backend="dense-ref"), mesh=mesh)
    qs = [5, 9, 13, 17]
    dyn.query_batch(qs, 4)
    ids = rng.choice(len(U), 16, replace=False)
    pts = np.clip(U[ids] + rng.normal(0, 0.01, (16, 2)), 0.01, 0.99)
    dyn.apply_updates(UpdateBatch(user_move=(ids, pts)))
    cold = RkNNEngine(dyn.facilities, dyn.users, RkNNConfig(backend="dense-ref"))
    np.testing.assert_array_equal(
        dyn.query_batch(qs, 4).masks, cold.query_batch(qs, 4).masks
    )
    # shape-changing delta forces the mesh re-init path
    dyn.apply_updates(UpdateBatch(user_insert=[[0.5, 0.5], [0.6, 0.6]]))
    cold = RkNNEngine(dyn.facilities, dyn.users, RkNNConfig(backend="dense-ref"))
    np.testing.assert_array_equal(
        dyn.query_batch(qs, 4).masks, cold.query_batch(qs, 4).masks
    )
