"""Minimal vendored stand-in for ``hypothesis`` (tier-1 environments only).

The build container cannot install ``hypothesis``, but the property tests
in ``test_geometry.py`` / ``test_core_rknn.py`` only use a small surface:
``@given`` over ``integers``/``floats``/``@composite`` strategies plus
``@settings(max_examples=..., deadline=...)``.  This shim replays each
property over a deterministic seed sweep (a fixed PRNG stream derived from
the test name), which keeps the properties exercised — just without
shrinking or example databases.  Import it as::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:  # tier-1 fallback
        from tests._hyp import given, settings, strategies as st

When the real ``hypothesis`` is available it wins, unchanged.
"""

from __future__ import annotations

import functools
import inspect
import types
import zlib

import numpy as np

__all__ = ["given", "settings", "strategies"]

_DEFAULT_EXAMPLES = 25
# Cap the deterministic sweep: the shim has no shrinking/coverage feedback,
# so very large max_examples just re-rolls the same PRNG stream — bound it
# to keep tier-1 runtime low while still sweeping a real distribution.
_MAX_EXAMPLES_CAP = 50


class SearchStrategy:
    """A strategy is just a function ``rng -> value`` here."""

    def __init__(self, draw_fn):
        self._draw_fn = draw_fn

    def draw(self, rng: np.random.Generator):
        return self._draw_fn(rng)


def _integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _floats(
    min_value: float,
    max_value: float,
    *,
    allow_nan: bool = False,
    allow_infinity: bool = False,
) -> SearchStrategy:
    del allow_nan, allow_infinity  # bounded draws are always finite
    return SearchStrategy(lambda rng: float(rng.uniform(min_value, max_value)))


def _composite(fn):
    """``@st.composite`` — ``fn(draw, *args)`` becomes a strategy factory."""

    @functools.wraps(fn)
    def factory(*args, **kwargs):
        def draw_value(rng):
            return fn(lambda strat: strat.draw(rng), *args, **kwargs)

        return SearchStrategy(draw_value)

    return factory


strategies = types.SimpleNamespace(
    integers=_integers, floats=_floats, composite=_composite
)


def settings(*, max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_ignored):
    """Records the example budget on the test function (deadline ignored)."""

    def deco(fn):
        fn._hyp_max_examples = max_examples
        return fn

    return deco


def given(*strats: SearchStrategy):
    """Replays the property over a deterministic per-test seed sweep."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_hyp_max_examples", None)
            if n is None:
                n = getattr(fn, "_hyp_max_examples", _DEFAULT_EXAMPLES)
            n = min(int(n), _MAX_EXAMPLES_CAP)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for example in range(n):
                drawn = tuple(s.draw(rng) for s in strats)
                try:
                    fn(*args, *drawn, **kwargs)
                except AssertionError as e:
                    raise AssertionError(
                        f"falsifying example #{example} (shim seed {seed}): "
                        f"{drawn!r}\n{e}"
                    ) from e

        # pytest must not see the strategy-filled parameters as fixtures
        # (real hypothesis also strips them from the exposed signature);
        # strategies fill the trailing params, fixtures keep the leading ones
        params = list(inspect.signature(fn).parameters.values())
        wrapper.__signature__ = inspect.Signature(params[: len(params) - len(strats)])
        del wrapper.__wrapped__
        return wrapper

    return deco
