"""Pallas kernel validation: interpret-mode vs pure-jnp oracle, swept over
shapes and dtypes (the per-kernel allclose requirement)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.brute import rank_counts_np
from repro.core.geometry import Rect, points_in_tris_np
from repro.core.scene import build_scene
from repro.kernels import ops
from repro.kernels.ref import rank_count_ref, raycast_count_ref

RECT = Rect(0.0, 0.0, 1.0, 1.0)


def _scene(seed, M, k=5):
    rng = np.random.default_rng(seed)
    F = rng.random((max(M, 2), 2))
    sc = build_scene(F, 0, k, RECT, strategy="none")
    return sc, rng


@pytest.mark.parametrize("n_users", [1, 7, 128, 1000, 4096])
@pytest.mark.parametrize("n_fac", [2, 3, 40, 130])
def test_raycast_kernel_shape_sweep(n_users, n_fac):
    sc, rng = _scene(n_users * 1000 + n_fac, n_fac)
    U = rng.random((n_users, 2)).astype(np.float32)
    got = np.asarray(
        ops.raycast_count(U[:, 0], U[:, 1], sc.coeffs, backend="pallas", interpret=True)
    )
    want = np.asarray(raycast_count_ref(U[:, 0], U[:, 1], sc.coeffs))
    np.testing.assert_array_equal(got, want)
    # and the ref itself equals the fp64 host oracle
    host = points_in_tris_np(U.astype(np.float64), sc.coeffs.astype(np.float64)).sum(1)
    np.testing.assert_array_equal(want, host)


@pytest.mark.parametrize("block", [(8, 128), (64, 128), (256, 256)])
def test_raycast_kernel_block_shapes(block):
    bu, bm = block
    sc, rng = _scene(77, 60)
    U = rng.random((500, 2)).astype(np.float32)
    got = np.asarray(
        ops.raycast_count(
            U[:, 0], U[:, 1], sc.coeffs, backend="pallas", bu=bu, bm=bm, interpret=True
        )
    )
    want = np.asarray(raycast_count_ref(U[:, 0], U[:, 1], sc.coeffs))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_raycast_kernel_dtypes(dtype):
    """Inputs of either dtype agree after the kernel's f32 cast (scenes are
    constructed in f64 and handed to devices in f32)."""
    sc, rng = _scene(5, 40)
    U = rng.random((256, 2)).astype(dtype)
    got = np.asarray(
        ops.raycast_count(U[:, 0], U[:, 1], sc.coeffs, backend="pallas", interpret=True)
    )
    want = np.asarray(raycast_count_ref(U[:, 0], U[:, 1], sc.coeffs))
    np.testing.assert_array_equal(got, want)


def _non_tie_mask(U, F, q, eps=1e-6):
    """Users with no facility at a near-tie distance to q (strict-< flips at
    1-ulp boundaries are semantically arbitrary; exclude them)."""
    d2 = np.sum((U[:, None, :] - F[None, :, :]) ** 2, axis=-1)
    d2q = np.sum((U - q) ** 2, axis=1)
    return ~np.any(np.abs(d2 - d2q[:, None]) < eps * (1.0 + d2q[:, None]), axis=1)


@pytest.mark.parametrize("n_users,n_fac", [(1, 1), (33, 9), (700, 80), (2048, 1000)])
def test_rank_count_kernel_sweep(n_users, n_fac):
    rng = np.random.default_rng(n_users + n_fac)
    U = rng.random((n_users, 2))
    F = rng.random((n_fac, 2))
    qi = int(rng.integers(0, n_fac))
    got = np.asarray(
        ops.rank_count(U, F, F[qi], exclude=qi, backend="pallas", interpret=True)
    )
    want = rank_counts_np(U, F, F[qi], exclude=qi)
    ok = _non_tie_mask(U, F, F[qi])
    np.testing.assert_array_equal(got[ok], want[ok])
    assert np.all(np.abs(got - want) <= 1)  # ties move counts by at most 1


def test_rank_count_ref_matches_kernel_padding_semantics():
    rng = np.random.default_rng(0)
    U = rng.random((100, 2)).astype(np.float32)
    F = rng.random((37, 2)).astype(np.float32)
    q = F[3]
    thr = np.sum((U - q) ** 2, axis=1).astype(np.float32)
    ref = np.asarray(rank_count_ref(U[:, 0], U[:, 1], F[:, 0], F[:, 1], thr))
    krn = np.asarray(
        ops.rank_count(U, F, q, exclude=None, backend="pallas", interpret=True)
    )
    ok = _non_tie_mask(U.astype(np.float64), F.astype(np.float64), q.astype(np.float64))
    np.testing.assert_array_equal(ref[ok], krn[ok])


# ---- grid-culled kernel (BVH analogue) --------------------------------------

def _nonpruned_scene(seed, n_fac=200):
    rng = np.random.default_rng(seed)
    F = rng.random((n_fac, 2))
    sc = build_scene(F, 0, 10, RECT, strategy="none")
    U = rng.random((2000, 2))
    return sc, U


@pytest.mark.parametrize("G,block", [(8, 128), (16, 128), (16, 256), (32, 128)])
def test_grid_raycast_kernel_matches_f32_reference(G, block):
    """Grid Pallas kernel == dense f32 reference.  (Comparison is f32-to-f32:
    the f64 host oracle can differ by measure-zero edge-test ties.)"""
    from repro.core.grid import build_grid
    from repro.kernels.grid_raycast import (
        grid_raycast_cells,
        pack_cell_coeff_planes,
        prepare_cell_buckets,
    )

    sc, U = _nonpruned_scene(G * 1000 + block)
    ref32 = np.asarray(
        raycast_count_ref(U[:, 0].astype(np.float32), U[:, 1].astype(np.float32), sc.coeffs)
    )
    g = build_grid(sc.tris[: sc.n_tris], sc.coeffs[: sc.n_tris], RECT, G=G)
    xs_s, ys_s, order, cell_map, nb = prepare_cell_buckets(U[:, 0], U[:, 1], RECT, G, block=block)
    planes = pack_cell_coeff_planes(g)
    counts = np.asarray(
        grid_raycast_cells(xs_s, ys_s, cell_map, g.base, planes, block=block, interpret=True)
    )
    ok = order >= 0
    got = np.zeros(len(U), np.int64)
    got[order[ok]] = counts[ok]
    np.testing.assert_array_equal(got, ref32)


def _buckets_reference(xs, ys, rect, G, block):
    """The pre-vectorization bucketing (per-unique-cell rescan) as oracle."""
    xs = np.asarray(xs, np.float32)
    ys = np.asarray(ys, np.float32)
    w, h = rect.width / G, rect.height / G
    cx = np.clip(np.floor((xs - rect.xmin) / w), 0, G - 1).astype(np.int64)
    cy = np.clip(np.floor((ys - rect.ymin) / h), 0, G - 1).astype(np.int64)
    cell = cx * G + cy
    order = np.argsort(cell, kind="stable")
    xs_parts, ys_parts, ord_parts, cells = [], [], [], []
    for c in np.unique(cell):
        rows = order[cell[order] == c]
        pad = (-len(rows)) % block
        xs_parts.append(np.concatenate([xs[rows], np.full(pad, 2e9, np.float32)]))
        ys_parts.append(np.concatenate([ys[rows], np.full(pad, 2e9, np.float32)]))
        ord_parts.append(np.concatenate([rows, np.full(pad, -1, np.int64)]))
        cells.extend([int(c)] * ((len(rows) + pad) // block))
    return (
        np.concatenate(xs_parts),
        np.concatenate(ys_parts),
        np.concatenate(ord_parts),
        np.asarray(cells, np.int32),
        len(cells),
    )


@pytest.mark.parametrize("n,G,block", [(1, 8, 8), (97, 8, 16), (2000, 32, 8),
                                       (500, 64, 256)])
def test_prepare_cell_buckets_matches_reference(n, G, block):
    """The searchsorted-run-boundary bucketing is bit-identical to the old
    per-unique-cell rescan."""
    from repro.kernels.grid_raycast import prepare_cell_buckets

    rng = np.random.default_rng(n + G)
    U = rng.random((n, 2))
    got = prepare_cell_buckets(U[:, 0], U[:, 1], RECT, G, block=block)
    want = _buckets_reference(U[:, 0], U[:, 1], RECT, G, block)
    for g, w in zip(got[:4], want[:4]):
        np.testing.assert_array_equal(g, w)
    assert got[4] == want[4]


def test_prepare_cell_buckets_perf_shape():
    """Perf-shape regression (the old implementation rescanned the full
    cell array once per unique cell — O(U · cells) host time inside
    ``t_filter_s``): a many-unique-cells bucketing must run in linearithmic
    time.  The budget is ~50x above the vectorized implementation's
    measured cost and ~10x below the rescan's."""
    import time

    from repro.kernels.grid_raycast import prepare_cell_buckets

    rng = np.random.default_rng(0)
    U = rng.random((300_000, 2))
    prepare_cell_buckets(U[:1000, 0], U[:1000, 1], RECT, 64, block=8)  # warm
    t0 = time.perf_counter()
    xs_s, ys_s, order, cell_map, nb = prepare_cell_buckets(
        U[:, 0], U[:, 1], RECT, 64, block=8
    )
    dt = time.perf_counter() - t0
    assert nb > 3000  # actually a many-cells shape
    assert dt < 2.0, f"bucketing took {dt:.2f}s — host rescan regression?"


def test_auto_cell_block_tracks_occupancy():
    from repro.kernels.grid_raycast import auto_cell_block

    assert auto_cell_block(100, 100) == 8  # sparse cells: minimal block
    assert auto_cell_block(4096, 16) == 256  # dense cells: capped at 256
    assert auto_cell_block(1000, 30) == 64  # mean 34 -> next pow2, clamped
    assert auto_cell_block(0, 0) == 8


@pytest.mark.parametrize("Q", [1, 3])
def test_grid_raycast_batch_kernel_matches_batch_oracle(Q):
    """Batched (q, cell-block) kernel + ref execution == the batched jnp
    grid oracle, through the shared user sort and the unsort scatter."""
    from repro.core.grid import build_grid, grid_hit_counts_batch_jnp, stack_grids
    from repro.kernels.grid_raycast import (
        pack_cell_coeff_planes,
        prepare_cell_buckets,
        unsort_cell_counts,
    )

    G = 16
    rng = np.random.default_rng(Q)
    F = rng.random((150, 2))
    U = rng.random((1200, 2))
    scenes = [build_scene(F, q, 8, RECT, strategy="none") for q in range(Q)]
    grids = [build_grid(s.tris[: s.n_tris], s.coeffs[: s.n_tris], RECT, G=G)
             for s in scenes]
    base_s, lists_s, coeffs_s = stack_grids(grids)
    want = np.asarray(
        grid_hit_counts_batch_jnp(
            U[:, 0].astype(np.float32), U[:, 1].astype(np.float32),
            base_s, lists_s, coeffs_s, RECT, G,
        )
    )
    xs_s, ys_s, order, cell_map, nb = prepare_cell_buckets(
        U[:, 0], U[:, 1], RECT, G, block=None
    )
    block = xs_s.shape[0] // nb
    for lane_pad, backend in ((1, "ref"), (8, "pallas")):
        packs = [pack_cell_coeff_planes(g, lane_pad=lane_pad) for g in grids]
        L = max(p.shape[-1] for p in packs)
        planes = np.zeros((Q,) + packs[0].shape[:-1] + (L,), np.float32)
        planes[:, :, :, 2, :] = -1.0
        for i, p in enumerate(packs):
            planes[i, ..., : p.shape[-1]] = p
        base_q = np.stack([g.base for g in grids])
        counts = np.asarray(
            ops.grid_count_cells_batch(
                xs_s, ys_s, cell_map, base_q, planes,
                block=block, backend=backend, interpret=True,
            )
        )
        got = unsort_cell_counts(counts, order, len(U))
        np.testing.assert_array_equal(got, want, err_msg=backend)


def test_grid_raycast_cells_interpret_autodetect():
    """``interpret=None`` resolves via ``pallas_interpret_default()`` (so a
    real TPU would run the compiled Mosaic kernel) and matches the
    explicit interpret=True result on this CPU container."""
    from repro.core.grid import build_grid
    from repro.kernels.grid_raycast import (
        grid_raycast_cells,
        pack_cell_coeff_planes,
        prepare_cell_buckets,
    )

    assert ops.pallas_interpret_default()  # CPU container: interpret is on
    sc, U = _nonpruned_scene(3, n_fac=80)
    g = build_grid(sc.tris[: sc.n_tris], sc.coeffs[: sc.n_tris], RECT, G=8)
    xs_s, ys_s, order, cell_map, nb = prepare_cell_buckets(
        U[:, 0], U[:, 1], RECT, 8, block=128
    )
    planes = pack_cell_coeff_planes(g)
    auto = np.asarray(
        grid_raycast_cells(xs_s, ys_s, cell_map, g.base, planes, block=128)
    )
    explicit = np.asarray(
        grid_raycast_cells(
            xs_s, ys_s, cell_map, g.base, planes, block=128, interpret=True
        )
    )
    np.testing.assert_array_equal(auto, explicit)


def test_grid_base_absorbs_fully_covering_triangles():
    """The per-cell base counter is the batched early-exit: most hits in a
    non-pruned scene come from fully-covering triangles, absorbed at zero
    per-user cost."""
    from repro.core.grid import build_grid

    sc, U = _nonpruned_scene(7)
    g = build_grid(sc.tris[: sc.n_tris], sc.coeffs[: sc.n_tris], RECT, G=16)
    assert g.base.max() > 0
    assert g.max_list < sc.n_tris  # partial lists are a strict subset
