"""Pallas kernel validation: interpret-mode vs pure-jnp oracle, swept over
shapes and dtypes (the per-kernel allclose requirement)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.brute import rank_counts_np
from repro.core.geometry import Rect, points_in_tris_np
from repro.core.scene import build_scene
from repro.kernels import ops
from repro.kernels.ref import rank_count_ref, raycast_count_ref

RECT = Rect(0.0, 0.0, 1.0, 1.0)


def _scene(seed, M, k=5):
    rng = np.random.default_rng(seed)
    F = rng.random((max(M, 2), 2))
    sc = build_scene(F, 0, k, RECT, strategy="none")
    return sc, rng


@pytest.mark.parametrize("n_users", [1, 7, 128, 1000, 4096])
@pytest.mark.parametrize("n_fac", [2, 3, 40, 130])
def test_raycast_kernel_shape_sweep(n_users, n_fac):
    sc, rng = _scene(n_users * 1000 + n_fac, n_fac)
    U = rng.random((n_users, 2)).astype(np.float32)
    got = np.asarray(
        ops.raycast_count(U[:, 0], U[:, 1], sc.coeffs, backend="pallas", interpret=True)
    )
    want = np.asarray(raycast_count_ref(U[:, 0], U[:, 1], sc.coeffs))
    np.testing.assert_array_equal(got, want)
    # and the ref itself equals the fp64 host oracle
    host = points_in_tris_np(U.astype(np.float64), sc.coeffs.astype(np.float64)).sum(1)
    np.testing.assert_array_equal(want, host)


@pytest.mark.parametrize("block", [(8, 128), (64, 128), (256, 256)])
def test_raycast_kernel_block_shapes(block):
    bu, bm = block
    sc, rng = _scene(77, 60)
    U = rng.random((500, 2)).astype(np.float32)
    got = np.asarray(
        ops.raycast_count(
            U[:, 0], U[:, 1], sc.coeffs, backend="pallas", bu=bu, bm=bm, interpret=True
        )
    )
    want = np.asarray(raycast_count_ref(U[:, 0], U[:, 1], sc.coeffs))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_raycast_kernel_dtypes(dtype):
    """Inputs of either dtype agree after the kernel's f32 cast (scenes are
    constructed in f64 and handed to devices in f32)."""
    sc, rng = _scene(5, 40)
    U = rng.random((256, 2)).astype(dtype)
    got = np.asarray(
        ops.raycast_count(U[:, 0], U[:, 1], sc.coeffs, backend="pallas", interpret=True)
    )
    want = np.asarray(raycast_count_ref(U[:, 0], U[:, 1], sc.coeffs))
    np.testing.assert_array_equal(got, want)


def _non_tie_mask(U, F, q, eps=1e-6):
    """Users with no facility at a near-tie distance to q (strict-< flips at
    1-ulp boundaries are semantically arbitrary; exclude them)."""
    d2 = np.sum((U[:, None, :] - F[None, :, :]) ** 2, axis=-1)
    d2q = np.sum((U - q) ** 2, axis=1)
    return ~np.any(np.abs(d2 - d2q[:, None]) < eps * (1.0 + d2q[:, None]), axis=1)


@pytest.mark.parametrize("n_users,n_fac", [(1, 1), (33, 9), (700, 80), (2048, 1000)])
def test_rank_count_kernel_sweep(n_users, n_fac):
    rng = np.random.default_rng(n_users + n_fac)
    U = rng.random((n_users, 2))
    F = rng.random((n_fac, 2))
    qi = int(rng.integers(0, n_fac))
    got = np.asarray(
        ops.rank_count(U, F, F[qi], exclude=qi, backend="pallas", interpret=True)
    )
    want = rank_counts_np(U, F, F[qi], exclude=qi)
    ok = _non_tie_mask(U, F, F[qi])
    np.testing.assert_array_equal(got[ok], want[ok])
    assert np.all(np.abs(got - want) <= 1)  # ties move counts by at most 1


def test_rank_count_ref_matches_kernel_padding_semantics():
    rng = np.random.default_rng(0)
    U = rng.random((100, 2)).astype(np.float32)
    F = rng.random((37, 2)).astype(np.float32)
    q = F[3]
    thr = np.sum((U - q) ** 2, axis=1).astype(np.float32)
    ref = np.asarray(rank_count_ref(U[:, 0], U[:, 1], F[:, 0], F[:, 1], thr))
    krn = np.asarray(
        ops.rank_count(U, F, q, exclude=None, backend="pallas", interpret=True)
    )
    ok = _non_tie_mask(U.astype(np.float64), F.astype(np.float64), q.astype(np.float64))
    np.testing.assert_array_equal(ref[ok], krn[ok])


# ---- grid-culled kernel (BVH analogue) --------------------------------------

def _nonpruned_scene(seed, n_fac=200):
    rng = np.random.default_rng(seed)
    F = rng.random((n_fac, 2))
    sc = build_scene(F, 0, 10, RECT, strategy="none")
    U = rng.random((2000, 2))
    return sc, U


@pytest.mark.parametrize("G,block", [(8, 128), (16, 128), (16, 256), (32, 128)])
def test_grid_raycast_kernel_matches_f32_reference(G, block):
    """Grid Pallas kernel == dense f32 reference.  (Comparison is f32-to-f32:
    the f64 host oracle can differ by measure-zero edge-test ties.)"""
    from repro.core.grid import build_grid
    from repro.kernels.grid_raycast import (
        grid_raycast_cells,
        pack_cell_coeff_planes,
        prepare_cell_buckets,
    )

    sc, U = _nonpruned_scene(G * 1000 + block)
    ref32 = np.asarray(
        raycast_count_ref(U[:, 0].astype(np.float32), U[:, 1].astype(np.float32), sc.coeffs)
    )
    g = build_grid(sc.tris[: sc.n_tris], sc.coeffs[: sc.n_tris], RECT, G=G)
    xs_s, ys_s, order, cell_map, nb = prepare_cell_buckets(U[:, 0], U[:, 1], RECT, G, block=block)
    planes = pack_cell_coeff_planes(g)
    counts = np.asarray(
        grid_raycast_cells(xs_s, ys_s, cell_map, g.base, planes, block=block, interpret=True)
    )
    ok = order >= 0
    got = np.zeros(len(U), np.int64)
    got[order[ok]] = counts[ok]
    np.testing.assert_array_equal(got, ref32)


def test_grid_base_absorbs_fully_covering_triangles():
    """The per-cell base counter is the batched early-exit: most hits in a
    non-pruned scene come from fully-covering triangles, absorbed at zero
    per-user cost."""
    from repro.core.grid import build_grid

    sc, U = _nonpruned_scene(7)
    g = build_grid(sc.tris[: sc.n_tris], sc.coeffs[: sc.n_tris], RECT, G=16)
    assert g.base.max() > 0
    assert g.max_list < sc.n_tris  # partial lists are a strict subset
