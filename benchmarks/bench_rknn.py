"""All RkNN paper artefacts (Tables 2–3, Figures 7–17) as benchmark fns.

Each ``table_*`` / ``fig_*`` function returns CSV-able rows:
``{"name", "us_per_call", "derived"}`` where ``derived`` carries the
figure-specific payload (speedups, breakdowns, occluder counts).
"""

from __future__ import annotations

import time

import numpy as np

import jax

from benchmarks.common import DEFAULT_SCALE, dataset, run_methods, timed
from repro.core.baselines import STRTree, infzone_rknn
from repro.core.bvh import build_bvh, bvh_hit_counts
from repro.core.engine import RkNNConfig, RkNNEngine
from repro.core.geometry import Rect
from repro.core.grid import build_grid, grid_hit_counts_jnp
from repro.core.rknn import rt_rknn_query, rt_rknn_query_batch
from repro.core.scene import build_scene
from repro.data.spatial import facility_user_split
from repro.kernels import ops as kops

#: Planner drift gate (scenario_sweep): the warm-sweep median
#: |ln(observed/predicted)| per assigned backend must stay under this.
#: ln 4.5 ≈ 1.5 — a cost model off by a consistent 4.5x multiple is
#: broken (stale profile, dead feature), while honest serving noise at
#: CI scale stays well inside it.
DRIFT_MEDIAN_MAX = 1.5


def _fu(name: str, n_fac: int, scale: float, seed: int = 0):
    pts = dataset(name, scale)
    return facility_user_split(pts, n_fac, seed=seed)


# ---------------------------------------------------------------- Table 2
def table2_indexing(scale: float = DEFAULT_SCALE, n_queries: int = 0) -> list[dict]:
    """Amortized user-indexing cost: R*-tree build vs plain device upload."""
    pts = dataset("USA", scale)
    tree, t_build = timed(lambda: STRTree(pts))
    jax.block_until_ready(jax.device_put(pts[:128].astype(np.float32)))  # warm up runtime
    dev, t_upload = timed(
        lambda: jax.block_until_ready(jax.device_put(pts.astype(np.float32))), repeats=3
    )
    return [
        dict(name="table2_rtree_build", us_per_call=t_build * 1e6,
             derived=f"n={len(pts)}"),
        dict(name="table2_device_upload", us_per_call=t_upload * 1e6,
             derived=f"speedup={t_build / max(t_upload, 1e-9):.0f}x"),
    ]


# ------------------------------------------------------------- Fig 7 / 8
def fig7_8_vary_k(scale: float = DEFAULT_SCALE, n_queries: int = 5) -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    for setting, n_fac in (("sparse", 100), ("default", 1000)):
        F, U = _fu("CAL", n_fac, scale)
        qs = rng.integers(0, len(F), n_queries)
        for k in (1, 5, 10, 25):
            acc, _ = run_methods(F, U, qs, k, methods=("tpl", "inf", "slice", "rt", "rt-batch"))
            base = min(acc["tpl"], acc["inf"], acc["slice"])
            rows.append(
                dict(
                    name=f"fig{'7' if setting == 'sparse' else '8'}_k{k}_{setting}_rt",
                    us_per_call=acc["rt"] * 1e6,
                    derived=(
                        f"tpl={acc['tpl']*1e3:.1f}ms inf={acc['inf']*1e3:.1f}ms "
                        f"slice={acc['slice']*1e3:.1f}ms best_base/rt={base/acc['rt']:.2f}x "
                        f"rt-batch={acc['rt-batch']*1e3:.2f}ms/q"
                    ),
                )
            )
    return rows


# ----------------------------------------------------------------- Fig 9
def fig9_large_k(scale: float = DEFAULT_SCALE, n_queries: int = 3) -> list[dict]:
    F, U = _fu("USA", 1000, scale)
    rng = np.random.default_rng(1)
    qs = rng.integers(0, len(F), n_queries)
    rows = []
    for k in (25, 50, 100, 200):
        acc, _ = run_methods(F, U, qs, k, methods=("slice", "rt"))
        rows.append(
            dict(
                name=f"fig9_k{k}_rt",
                us_per_call=acc["rt"] * 1e6,
                derived=f"slice={acc['slice']*1e3:.1f}ms slice/rt={acc['slice']/acc['rt']:.2f}x",
            )
        )
    return rows


# ---------------------------------------------------------------- Fig 10
def fig10_datasize(scale: float = DEFAULT_SCALE, n_queries: int = 3) -> list[dict]:
    rows = []
    rng = np.random.default_rng(2)
    for ds in ("NY", "FLA", "CAL", "E"):
        for setting, n_fac in (("sparse", 100), ("default", 1000)):
            F, U = _fu(ds, n_fac, scale)
            qs = rng.integers(0, len(F), n_queries)
            acc, _ = run_methods(F, U, qs, 10)
            rows.append(
                dict(
                    name=f"fig10_{ds}_{setting}_rt",
                    us_per_call=acc["rt"] * 1e6,
                    derived=(
                        f"U={len(U)} tpl={acc['tpl']*1e3:.1f} inf={acc['inf']*1e3:.1f} "
                        f"slice={acc['slice']*1e3:.1f} (ms)"
                    ),
                )
            )
    return rows


# ------------------------------------------------------------ Fig 11 / 12
def fig11_12_facility(scale: float = DEFAULT_SCALE, n_queries: int = 3) -> list[dict]:
    pts = dataset("CAL", scale)
    rng = np.random.default_rng(3)
    rows = []
    for n_fac in (100, 1000, 5000):
        if n_fac + 1000 > len(pts):
            continue
        F, U = facility_user_split(pts, n_fac, seed=1)
        qs = rng.integers(0, len(F), n_queries)
        acc, split = run_methods(F, U, qs, 10)
        f, v = split["rt"]
        rows.append(
            dict(
                name=f"fig11_F{n_fac}_rt",
                us_per_call=acc["rt"] * 1e6,
                derived=(
                    f"filter={f*1e3:.2f}ms verify={v*1e3:.2f}ms "
                    f"slice={acc['slice']*1e3:.1f}ms inf={acc['inf']*1e3:.1f}ms"
                ),
            )
        )
    return rows


# ------------------------------------------------------------ Fig 13 / 14
def fig13_14_user(scale: float = DEFAULT_SCALE, n_queries: int = 3) -> list[dict]:
    pts = dataset("USA", scale)
    rng = np.random.default_rng(4)
    rows = []
    for setting, n_fac in (("sparse", 100), ("default", 1000)):
        F, U_all = facility_user_split(pts, n_fac, seed=2)
        for frac in (0.1, 0.5, 1.0):
            U = U_all[: int(len(U_all) * frac)]
            qs = rng.integers(0, len(F), n_queries)
            acc, split = run_methods(F, U, qs, 10)
            f, v = split["rt"]
            rows.append(
                dict(
                    name=f"fig13_{setting}_U{len(U)}_rt",
                    us_per_call=acc["rt"] * 1e6,
                    derived=(
                        f"filter={f*1e3:.2f} verify={v*1e3:.2f} "
                        f"slice={acc['slice']*1e3:.1f} (ms)"
                    ),
                )
            )
    return rows


# ---------------------------------------------------------------- Fig 15
def fig15_breakdown(scale: float = DEFAULT_SCALE, n_queries: int = 3) -> list[dict]:
    F, U = _fu("USA", 1000, scale)
    rect = Rect.from_points(F, U)
    rng = np.random.default_rng(5)
    xs = U[:, 0].astype(np.float32)
    ys = U[:, 1].astype(np.float32)
    t_occ = t_idx = t_cast = t_xfer = 0.0
    for qi in rng.integers(0, len(F), n_queries):
        t0 = time.perf_counter()
        sc = build_scene(F, int(qi), 10, rect)
        t1 = time.perf_counter()
        g = build_grid(sc.tris[: sc.n_tris], sc.coeffs[: sc.n_tris], rect, G=32)
        t2 = time.perf_counter()
        _ = np.asarray(kops.raycast_count(xs, ys, sc.coeffs, backend="ref"))
        t3 = time.perf_counter()
        _ = np.asarray(jax.device_put(U.astype(np.float32)))
        t4 = time.perf_counter()
        t_occ += t1 - t0
        t_idx += t2 - t1
        t_cast += t3 - t2
        t_xfer += t4 - t3
    n = n_queries
    return [
        dict(name="fig15_occluder_construction", us_per_call=t_occ / n * 1e6, derived=""),
        dict(name="fig15_index_build_grid", us_per_call=t_idx / n * 1e6, derived="(BVH analogue)"),
        dict(name="fig15_ray_cast", us_per_call=t_cast / n * 1e6, derived=f"N={len(U)}"),
        dict(name="fig15_transfer", us_per_call=t_xfer / n * 1e6, derived=""),
    ]


# ------------------------------------------------------- Table 3 / Fig 16
def table3_fig16_occluders(scale: float = DEFAULT_SCALE, n_queries: int = 5) -> list[dict]:
    rows = []
    rng = np.random.default_rng(6)
    pts = dataset("NY", scale)
    for n_fac in (100, 1000):
        F, U = facility_user_split(pts, n_fac, seed=3)
        rect = Rect.from_points(F, U)
        qs = rng.integers(0, len(F), n_queries)
        for strat in ("infzone", "conservative", "none"):
            counts = []
            t_tot = 0.0
            for qi in qs:
                t0 = time.perf_counter()
                r = rt_rknn_query(F, U, int(qi), 10, backend="dense-ref", strategy=strat, rect=rect)
                t_tot += time.perf_counter() - t0
                counts.append(r.scene.n_occluders)
            rows.append(
                dict(
                    name=f"table3_F{n_fac}_{strat}",
                    us_per_call=t_tot / len(qs) * 1e6,
                    derived=f"avg_occluders={np.mean(counts):.1f}",
                )
            )
    return rows


# ---------------------------------------------------------------- Fig 17
def fig17_no_rt(scale: float = DEFAULT_SCALE, n_queries: int = 3) -> list[dict]:
    F, U = _fu("NY", 100, scale)
    rng = np.random.default_rng(7)
    qs = rng.integers(0, len(F), n_queries)
    t_rt = t_gpu = t_cpu = 0.0
    for qi in qs:
        t0 = time.perf_counter()
        rt_rknn_query(F, U, int(qi), 10, backend="dense-ref")
        t1 = time.perf_counter()
        # "InfZone-GPU": brute rank-count offload, no ray-cast formulation
        np.asarray(kops.rank_count(U, F, F[int(qi)], exclude=int(qi), backend="ref"))
        t2 = time.perf_counter()
        infzone_rknn(F, U, int(qi), 10)
        t3 = time.perf_counter()
        t_rt += t1 - t0
        t_gpu += t2 - t1
        t_cpu += t3 - t2
    n = n_queries
    return [
        dict(name="fig17_rt_rknn", us_per_call=t_rt / n * 1e6, derived=""),
        dict(name="fig17_infzone_device_brute", us_per_call=t_gpu / n * 1e6,
             derived=f"rt_speedup={t_gpu / max(t_rt, 1e-9):.2f}x"),
        dict(name="fig17_infzone_cpu", us_per_call=t_cpu / n * 1e6,
             derived=f"rt_speedup={t_cpu / max(t_rt, 1e-9):.2f}x"),
    ]


# ------------------------------------------- backend ablation (beyond paper)
def backends_ablation(scale: float = DEFAULT_SCALE, n_queries: int = 2) -> list[dict]:
    """BVH-faithful vs grid vs dense — the TPU-adaptation perf story."""
    F, U = _fu("NY", 1000, scale)
    rect = Rect.from_points(F, U)
    rng = np.random.default_rng(8)
    qs = [int(q) for q in rng.integers(0, len(F), n_queries)]
    xs, ys = U[:, 0].astype(np.float32), U[:, 1].astype(np.float32)
    rows = []
    sc = build_scene(F, qs[0], 10, rect)
    tris, coeffs = sc.tris[: sc.n_tris], sc.coeffs[: sc.n_tris]
    # dense
    _, t_dense = timed(lambda: np.asarray(kops.raycast_count(xs, ys, sc.coeffs, backend="ref")), repeats=3)
    # grid
    g = build_grid(tris, coeffs, rect, G=32)
    _, t_grid = timed(
        lambda: np.asarray(grid_hit_counts_jnp(xs, ys, g.base, g.lists, g.coeffs, rect, 32)),
        repeats=3,
    )
    # faithful BVH traversal (early exit k)
    bvh = build_bvh(tris)
    _, t_bvh = timed(
        lambda: np.asarray(bvh_hit_counts(xs, ys, bvh.left, bvh.right, bvh.bbox, coeffs, k=10)),
        repeats=1,
    )
    rows.append(dict(name="ablate_dense", us_per_call=t_dense * 1e6, derived=f"m={sc.n_occluders}"))
    rows.append(dict(name="ablate_grid", us_per_call=t_grid * 1e6,
                     derived=f"dense/grid={t_dense/t_grid:.2f}x maxlist={g.max_list}"))
    rows.append(dict(name="ablate_bvh_faithful", us_per_call=t_bvh * 1e6,
                     derived=f"bvh/dense={t_bvh/t_dense:.1f}x (SIMD-hostile, DESIGN §2)"))
    return rows


# ------------------------------------------ batched multi-query engine (ours)
def batch_throughput(scale: float = DEFAULT_SCALE, n_queries: int = 0) -> list[dict]:
    """Batched dispatch vs the Python query loop (the serving hot path).

    The paper's headline regime — dense users, sparse facilities — is where
    per-query overheads dominate; ``rt_rknn_query_batch`` amortizes the
    host scene builds and collapses ``Q`` device dispatches into one.
    Reported per backend at Q=16 and Q=64 on the NY workload (or a single
    sweep of ``n_queries`` when given).
    """
    F, U = _fu("NY", 1000, scale)
    rng = np.random.default_rng(10)
    rows = []
    for q_n in (n_queries,) if n_queries else (16, 64):
        qs = [int(q) for q in rng.integers(0, len(F), q_n)]
        for backend in ("dense-ref", "grid", "brute"):
            # warm the jit caches (at the real batch shape — serving reuses
            # one static Q) so both paths time steady-state dispatch
            rt_rknn_query(F, U, qs[0], 10, backend=backend)
            rt_rknn_query_batch(F, U, qs, 10, backend=backend)
            t0 = time.perf_counter()
            looped = [rt_rknn_query(F, U, qi, 10, backend=backend) for qi in qs]
            t_loop = time.perf_counter() - t0
            t0 = time.perf_counter()
            batched = rt_rknn_query_batch(F, U, qs, 10, backend=backend)
            t_batch = time.perf_counter() - t0
            assert all(
                np.array_equal(batched.masks[i], looped[i].mask) for i in range(q_n)
            )
            rows.append(
                dict(
                    name=f"batch_Q{q_n}_{backend}",
                    us_per_call=t_batch / q_n * 1e6,
                    derived=(
                        f"loop={t_loop/q_n*1e6:.0f}us/q loop/batch={t_loop/t_batch:.2f}x "
                        f"filter={batched.t_filter_s*1e3:.1f}ms verify={batched.t_verify_s*1e3:.1f}ms"
                    ),
                )
            )
    return rows


# --------------------------------------- stateful engine amortization (ours)
def engine_amortization(scale: float = DEFAULT_SCALE, n_queries: int = 0) -> list[dict]:
    """Repeated-workload amortization: the stateful engine vs cold shims.

    The serving regime the paper motivates (hot facilities queried over and
    over): the same ``Q``-query workload dispatched twice.  A cold
    ``rt_rknn_query_batch`` call rebuilds every scene both times; the
    engine's scene cache + prepared-batch LRU collapse the second call's
    host filter phase to a dictionary lookup.  Masks are asserted
    bit-identical; the engine must win on dense-ref and grid (acceptance
    criterion of the engine PR — emitted via ``--json`` for trajectory
    tracking).
    """
    F, U = _fu("NY", 1000, scale)
    rng = np.random.default_rng(11)
    q_n = n_queries or 16
    qs = [int(q) for q in rng.integers(0, len(F), q_n)]
    rows = []
    for backend in ("dense-ref", "grid"):
        # warm the global jit caches at this batch shape so both paths time
        # steady-state host work + dispatch, not XLA compilation
        rt_rknn_query_batch(F, U, qs, 10, backend=backend)
        t0 = time.perf_counter()
        cold1 = rt_rknn_query_batch(F, U, qs, 10, backend=backend)
        cold2 = rt_rknn_query_batch(F, U, qs, 10, backend=backend)
        t_cold = time.perf_counter() - t0
        eng = RkNNEngine(F, U, RkNNConfig(backend=backend))
        t0 = time.perf_counter()
        warm1 = eng.query_batch(qs, 10)
        warm2 = eng.query_batch(qs, 10)
        t_eng = time.perf_counter() - t0
        assert np.array_equal(warm1.masks, cold1.masks)
        assert np.array_equal(warm2.masks, cold2.masks)
        assert eng.stats.batch_cache_hits >= 1
        # the win (speedup > 1) is reported, not asserted — a scheduler
        # hiccup on a loaded CI box must not erase the trajectory row
        rows.append(
            dict(
                name=f"engine_repeat_Q{q_n}_{backend}",
                us_per_call=t_eng / (2 * q_n) * 1e6,
                derived=(
                    f"cold2x={t_cold*1e3:.1f}ms engine2x={t_eng*1e3:.1f}ms "
                    f"speedup={t_cold/t_eng:.2f}x win={t_eng < t_cold} "
                    f"hot_filter={warm2.t_filter_s*1e3:.2f}ms "
                    f"cold_filter={cold2.t_filter_s*1e3:.2f}ms"
                ),
            )
        )
    return rows


# ------------------------------------------- planner scenario sweep (ours)
def scenario_sweep(
    scale: float = DEFAULT_SCALE, n_queries: int = 0, backend: str = "auto"
) -> list[dict]:
    """The planner's report card: ``auto`` vs every fixed backend per regime.

    Calibrates a fast on-hardware profile, activates it, then runs every
    scenario in :mod:`repro.workloads` through a *stateful engine* per
    backend — one cold call (jit + caches), one timed warm call.  Warm is
    the serving regime the engine exists for (hot facilities queried over
    and over; scene cache + prepared-batch LRU active), and the regime
    where the paper's backend frontier is about verify cost, which is what
    the planner prices.  Acceptance criteria (ISSUE 3): per regime,
    ``backend`` (default ``auto``) is within 10% of the best fixed
    backend (``within10``); on the aggregate sweep it beats every single
    fixed backend (``beats_all``), with ``agg_ratio`` (auto total / best
    fixed total) as the noise-robust signal CI actually gates on.
    ``chosen`` surfaces the planner's ``explain()`` decisions; masks are
    asserted identical across all backends.

    The fixed set is :func:`repro.core.backends.timeable_backends` — every
    deployment backend whose wall time means something on this runtime.
    Interpret-mode kernels (``dense``, ``grid-pallas`` on CPU — flagged
    ``interpret_mode_on_cpu``) are correctness tools here; their timed
    executions are ``dense-ref`` and ``grid-pallas-ref``.  The
    ``grid`` vs ``grid-pallas-ref`` columns are the ISSUE 5 comparison:
    the gather-bound ``[Q, N, L, 3, 3]`` jnp batch against the
    cell-bucketed batch (one shared user sort + per-cell plane staging).
    """
    import collections
    import os

    from repro.core.backends import get_backend, timeable_backends
    from repro.obs import Histogram
    from repro.planner.calibrate import calibrate
    from repro.planner.profiles import (
        get_active_profile,
        load_runner_profile,
        runner_class,
        set_active_profile,
    )
    from repro.workloads import SCENARIOS

    fixed = tuple(n for n in timeable_backends() if n != backend)
    prev = get_active_profile()
    t0 = time.perf_counter()
    # a committed runner-class profile (benchmarks/profiles/<class>.json)
    # stands in for on-the-fly calibration when this machine matches it
    store = os.path.join(os.path.dirname(os.path.abspath(__file__)), "profiles")
    profile = load_runner_profile(store)
    prof_src = f"profile:{runner_class()}" if profile is not None else "calibrated"
    if profile is None:
        profile = calibrate(fast=True, repeats=2)
    t_cal = time.perf_counter() - t0
    set_active_profile(profile)
    rows = []
    try:
        contenders = fixed if backend in fixed else fixed + (backend,)
        others = tuple(b for b in fixed if b != backend)
        totals = {b: 0.0 for b in contenders}
        total_q = 0
        chosen_all: collections.Counter = collections.Counter()
        # per-assigned-backend pred-vs-obs log residuals, pooled across
        # every scenario's planner engine (the drift gate's evidence)
        drift: dict[str, Histogram] = {}
        for name, sc in SCENARIOS.items():
            w = sc.generate(scale)
            qs, k = w.qs, w.k
            times = {}
            masks = {}
            for b in contenders:
                eng = RkNNEngine(w.facilities, w.users, RkNNConfig(backend=b))
                eng.query_batch(qs, k)  # cold: jit warmup + cache fill
                for _labels, h in eng.metrics.find("planner.residual"):
                    h.reset()  # the cold call's jit-compile outlier
                best_t = np.inf
                for _ in range(3):  # best-of-3 warm calls (noise floor)
                    t0 = time.perf_counter()
                    r = eng.query_batch(qs, k)
                    best_t = min(best_t, time.perf_counter() - t0)
                times[b] = best_t
                masks[b] = r.masks
                totals[b] += times[b]
                for labels, h in eng.metrics.find("planner.residual"):
                    drift.setdefault(
                        labels["backend"], Histogram(signed=True)
                    ).merge(h)
            for b in fixed:
                assert np.array_equal(masks[backend], masks[b]), (name, b)
            plan = get_backend("auto").explain() if backend == "auto" else None
            chosen = collections.Counter(
                plan.get("assignments", [plan.get("backend", "?")])
                if plan
                else [backend]
            )
            chosen_all.update(chosen)
            total_q += len(qs)
            best = min(others or (backend,), key=lambda b: times[b])
            ratio = times[backend] / times[best]
            rows.append(
                dict(
                    name=f"scenario_{name}_{backend}",
                    us_per_call=times[backend] / len(qs) * 1e6,
                    derived=(
                        f"best={best}:{times[best]*1e3:.1f}ms "
                        f"auto/best={ratio:.2f}x within10={ratio <= 1.10} "
                        f"chosen={dict(chosen)} "
                        + " ".join(f"{b}={times[b]*1e3:.1f}ms" for b in others)
                    ),
                )
            )
        beats_all = all(totals[backend] < totals[b] for b in others)
        best_fixed = min(totals[b] for b in others) if others else totals[backend]
        agg_ratio = totals[backend] / max(best_fixed, 1e-12)
        rows.append(
            dict(
                name=f"scenario_aggregate_{backend}",
                us_per_call=totals[backend] / max(total_q, 1) * 1e6,
                derived=(
                    f"beats_all={beats_all} agg_ratio={agg_ratio:.2f} "
                    f"chosen={dict(chosen_all)} "
                    + " ".join(f"{b}={totals[b]*1e3:.0f}ms" for b in others)
                    + f" calibration={t_cal:.1f}s source={prof_src}"
                ),
            )
        )
        if drift:
            # planner drift gate: median |ln(obs/pred)| per assigned
            # backend, pooled over the whole warm sweep.  The threshold is
            # deliberately loose — it catches a cost model going wrong by
            # a multiple (stale profile, broken feature), not CI noise.
            medians = {
                n: h.abs_percentile(50)
                for n, h in sorted(drift.items())
                if h.count >= 2
            }
            worst = max(medians.values(), default=0.0)
            drift_ok = worst <= DRIFT_MEDIAN_MAX
            rows.append(
                dict(
                    name=f"planner_drift_{backend}",
                    us_per_call=0.0,
                    derived=(
                        f"drift_ok={drift_ok} worst_abs_median={worst:.2f} "
                        f"threshold={DRIFT_MEDIAN_MAX} "
                        + " ".join(
                            f"{n}={m:.2f}/n{drift[n].count}"
                            for n, m in medians.items()
                        )
                    ),
                )
            )
    finally:
        set_active_profile(prev)
    return rows


# ------------------------------------------- dynamic update streams (ours)
def update_throughput(
    scale: float = DEFAULT_SCALE, n_queries: int = 0, concurrent: bool = False
) -> list[dict]:
    """Refit vs rebuild-from-scratch under update streams (ISSUE 4).

    A standing Q-query workload is re-issued after every update step.  The
    *refit* side is one long-lived :class:`repro.dynamic.DynamicEngine`
    absorbing deltas through ``apply_updates`` (scene survival / refit /
    device-array scatter); the *rebuild* side constructs a cold
    ``RkNNEngine`` from the post-update snapshot each step — what every
    pre-dynamic caller had to do.  Masks are asserted identical step by
    step.  Streams cover the churn regimes of ``repro.workloads.updates``:
    low/high user drift, facility jitter (the scene-refit showcase), and
    facility churn.  Acceptance: refit beats rebuild at low churn
    (``win=True`` in ``derived``; committed in BENCH_4.json).

    ``concurrent=True`` measures the MVCC serving path instead (PR 6,
    committed in BENCH_6.json): query latency on one engine while a
    writer thread streams updates through it, against the same engine
    idle — see :func:`_update_concurrent`.
    """
    if concurrent:
        return _update_concurrent(scale, n_queries)
    from repro.dynamic import DynamicEngine, apply_to_points
    from repro.workloads import drifting_users, facility_churn, facility_jitter

    F, U = _fu("NY", 400, scale)
    # pin the hull with corner facilities so interior churn keeps the rect
    lo, hi = np.concatenate([F, U]).min(0), np.concatenate([F, U]).max(0)
    F = np.concatenate(
        [[[lo[0], lo[1]], [lo[0], hi[1]], [hi[0], lo[1]], [hi[0], hi[1]]], F]
    )
    rng = np.random.default_rng(12)
    q_n = n_queries or 8
    qs = [int(q) for q in rng.integers(4, len(F), q_n)]
    k = 10
    steps = 4
    streams = {
        "drift_lo": drifting_users(U, steps=steps, frac=0.01, seed=0),
        "drift_hi": drifting_users(U, steps=steps, frac=0.25, seed=1),
        # the hull-pinning corner rows 0-3 are protected alongside the
        # query ids — deleting a corner would shrink the rect and purge
        # the cache, turning the row into a rebuild measurement
        "fjitter": facility_jitter(F, steps=steps, frac=0.02, seed=2,
                                   protect=np.concatenate([np.arange(4), qs])),
        "fchurn": facility_churn(F, steps=steps, rate=0.02, seed=3,
                                 protect=np.concatenate([np.arange(4), qs])),
    }
    backend = "grid"  # index-heaviest filter phase: refit has the most to save
    rows = []
    for name, stream in streams.items():
        dyn = DynamicEngine(F, U, RkNNConfig(backend=backend))
        dyn.query_batch(qs, k)  # warm jit + caches
        t_refit = 0.0
        masks_refit = []
        t0 = time.perf_counter()
        for batch in stream:
            dyn.apply_updates(batch)
            masks_refit.append(dyn.query_batch(qs, k).masks)
        t_refit = time.perf_counter() - t0

        Fc, Uc = F.copy(), U.copy()
        t0 = time.perf_counter()
        for i, batch in enumerate(stream):
            Fc, _ = apply_to_points(
                Fc, batch.facility_insert, batch.facility_delete, batch.facility_move
            )
            Uc, _ = apply_to_points(
                Uc, batch.user_insert, batch.user_delete, batch.user_move
            )
            cold = RkNNEngine(Fc, Uc, RkNNConfig(backend=backend))
            masks = cold.query_batch(qs, k).masks
            assert np.array_equal(masks, masks_refit[i]), (name, i)
        t_rebuild = time.perf_counter() - t0

        st = dyn.update_stats
        rows.append(
            dict(
                name=f"update_{name}_{backend}",
                us_per_call=t_refit / (steps * q_n) * 1e6,
                derived=(
                    f"rebuild={t_rebuild*1e3:.1f}ms refit={t_refit*1e3:.1f}ms "
                    f"speedup={t_rebuild/max(t_refit,1e-9):.2f}x win={t_refit < t_rebuild} "
                    f"survived={st.scenes_survived} refit={st.scenes_refit} "
                    f"dropped={st.scenes_dropped} idx_refit={st.indexes_refit} "
                    f"scatters={st.user_scatters}"
                ),
            )
        )
    return rows


def _update_concurrent(scale: float, n_queries: int) -> list[dict]:
    """MVCC serving under concurrent updates (PR 6 tentpole acceptance).

    One :class:`repro.dynamic.DynamicEngine`; a writer thread streams
    alternating user-drift / facility-jitter batches through
    ``apply_updates`` while the main thread keeps issuing the standing
    query batch with no coordination whatsoever — the read path resolves
    the immutable snapshot once and never takes a lock.  The writer is
    paced at ~25ms between batches (streaming-ingest cadence): what is
    under test is that readers are never *blocked* by a writer, not that
    a writer saturating every core leaves CPU time free.  Reported:

    * idle vs concurrent p50/p99 per-call latency and the acceptance
      ratio ``within2x`` (concurrent p99 <= 2 x idle p99);
    * ``versions``: how far the writer advanced while readers ran
      (proof the measurement actually interleaved);
    * ``stale_mix``: for each distinct version a concurrent reader
      reported, its masks are replayed on a cold engine built from the
      arrays recorded at exactly that version — any half-applied or
      cross-version mix would miscompare.  Asserted zero.
    """
    import threading

    from repro.dynamic import DynamicEngine, UpdateBatch

    F, U = _fu("NY", 400, scale)
    lo, hi = np.concatenate([F, U]).min(0), np.concatenate([F, U]).max(0)
    F = np.concatenate(
        [[[lo[0], lo[1]], [lo[0], hi[1]], [hi[0], lo[1]], [hi[0], hi[1]]], F]
    )
    rng = np.random.default_rng(12)
    q_n = n_queries or 8
    qs = [int(q) for q in rng.integers(4, len(F), q_n)]
    k = 10
    backend = "grid"
    n_batches = 16
    dyn = DynamicEngine(F, U, RkNNConfig(backend=backend))
    dyn.query_batch(qs, k)  # warm jit + caches

    def measure_once():
        t0 = time.perf_counter()
        r = dyn.query_batch(qs, k)
        return time.perf_counter() - t0, int(r.version), r.masks

    history = {dyn.version: (dyn.facilities.copy(), dyn.users.copy())}
    done = threading.Event()
    writer_err: list[BaseException] = []

    def writer(n_batches, seed):
        try:
            wrng = np.random.default_rng(seed)
            for step in range(n_batches):
                if step % 2:  # user drift (5%), clipped inside the rect
                    ids = wrng.choice(len(dyn.users), size=len(dyn.users) // 20,
                                      replace=False)
                    pts = np.clip(
                        dyn.users[ids] + wrng.normal(0, 0.01, (len(ids), 2)),
                        lo, hi,
                    )
                    batch = UpdateBatch(user_move=(ids, pts))
                else:  # facility jitter (2%), corners + query ids pinned
                    cand = np.setdiff1d(np.arange(4, len(dyn.facilities)), qs)
                    ids = wrng.choice(cand, size=max(len(cand) // 50, 1),
                                      replace=False)
                    pts = np.clip(
                        dyn.facilities[ids]
                        + wrng.normal(0, 0.005, (len(ids), 2)),
                        lo, hi,
                    )
                    batch = UpdateBatch(facility_move=(ids, pts))
                dyn.apply_updates(batch)
                # sole writer: arrays are stable until OUR next apply
                history[dyn.version] = (
                    dyn.facilities.copy(), dyn.users.copy()
                )
                time.sleep(0.025)  # streaming cadence between deltas
        except BaseException as e:  # pragma: no cover - failure path
            writer_err.append(e)
        finally:
            done.set()

    def concurrent_round(n_batches, seed, min_reads):
        lats = []
        masks_at = {}  # last masks per observed version
        t = threading.Thread(target=writer, args=(n_batches, seed))
        t.start()
        while not done.is_set() or len(lats) < min_reads:
            dt, version, masks = measure_once()
            lats.append(dt)
            masks_at[version] = masks
        t.join()
        done.clear()
        assert not writer_err, writer_err
        return np.array(lats), masks_at

    # uncounted warm-up round: update-churned scene sizes can outgrow the
    # monotone pad bucket once, and that one XLA recompile belongs to
    # warm-up, not to the steady-state serving latency under measurement
    concurrent_round(4, 3, 8)

    # enough idle samples that idle p99 is a real percentile rather than
    # the sample max — the concurrent round yields hundreds of reads, and
    # comparing its p99 against a 40-sample max would bias the ratio
    idle = np.array([measure_once()[0] for _ in range(200)])
    conc, masks_at = concurrent_round(n_batches, 7, 40)

    stale_mix = 0
    for version, masks in sorted(masks_at.items()):
        cold = RkNNEngine(*history[version], RkNNConfig(backend=backend))
        if not np.array_equal(masks, cold.query_batch(qs, k).masks):
            stale_mix += 1
    assert stale_mix == 0, f"{stale_mix} versions served mixed-state answers"

    p = lambda a, q: float(np.percentile(a, q))  # noqa: E731
    within2x = p(conc, 99) <= 2.0 * p(idle, 99)
    return [
        dict(
            name=f"update_concurrent_{backend}",
            us_per_call=float(conc.mean() / q_n * 1e6),
            derived=(
                f"idle_p50={p(idle, 50)*1e3:.2f}ms idle_p99={p(idle, 99)*1e3:.2f}ms "
                f"conc_p50={p(conc, 50)*1e3:.2f}ms conc_p99={p(conc, 99)*1e3:.2f}ms "
                f"within2x={within2x} versions={dyn.version} "
                f"reads={len(conc)} checked={len(masks_at)} stale_mix={stale_mix}"
            ),
        )
    ]


# ------------------------------------------------- monochromatic (paper §4.5)
def mono_queries(scale: float = DEFAULT_SCALE, n_queries: int = 3) -> list[dict]:
    """Monochromatic RkNN (facilities querying facilities): the paper
    reports spatial pruning is MORE effective here (structured point
    relations) and RT does not surpass SLICE — we measure the same pair."""
    from repro.core.rknn import rknn_mono_query
    from repro.core.brute import rknn_mono_brute_np

    pts = dataset("NY", scale)
    P_ = pts[:2000]
    rng = np.random.default_rng(9)
    qs = [int(q) for q in rng.integers(0, len(P_), n_queries)]
    t_rt = 0.0
    for qi in qs:
        t0 = time.perf_counter()
        r = rknn_mono_query(P_, qi, 10)
        t_rt += time.perf_counter() - t0
        assert np.array_equal(r.mask, rknn_mono_brute_np(P_, qi, 10))
    return [
        dict(
            name="mono_rt_rknn",
            us_per_call=t_rt / len(qs) * 1e6,
            derived=f"P={len(P_)} k=10 exact=True (verified vs mono brute)",
        )
    ]


# ----------------------------------------- user-axis sharded serving (PR 7)
def sharded_scaling(scale: float = DEFAULT_SCALE, n_queries: int = 0) -> list[dict]:
    """Million-user scale-out: :class:`repro.shard.ShardedEngine` vs the
    single-process oracle (ISSUE 7 deliverable, committed in BENCH_7.json).

    The two verify-dominated regimes (``repro.workloads.SHARDING_REGIMES``)
    are materialized at ``20M * scale`` users (10^6 at the committed
    ``--scale 0.05``; CI smoke runs 4x10^5 at 0.02) and served warm at
    shard counts 1 / 2 / 4 on the visible device set — launch under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` for a real
    4-device mesh.  Two throughput metrics per shard count:

    * ``qps`` — end-to-end wall throughput of this host.  On a synthetic
      mesh every shard executes on the same silicon, so this isolates the
      *algorithmic* sharding win (per-shard occupied-cell + live-lane
      compaction of the packed coefficient planes: a spatially contiguous
      shard ships only its own region's cells, padded to its own longest
      candidate list).
    * ``step`` / ``mesh_qps`` — the SPMD step time, ``max`` over the
      per-shard verify walls (shards run sequentially on the synthetic
      mesh, so each per-shard wall is cleanly measurable; a real S-device
      mesh runs them concurrently and its step finishes with the slowest
      shard).  This is the scale-out number the subsystem exists for,
      and shard imbalance degrades exactly it.

    Timing is interleaved round-robin across the three engines so heap /
    frequency drift cannot correlate with shard count.  Masks AND counts
    are asserted bit-identical to a cold single-process engine per regime
    and shard count (``identical``).  Acceptance: mesh-step throughput
    improves monotonically 1 -> 4 shards on both regimes (``monotone`` in
    the per-regime ``derived``; margins are structural — the step halves
    whenever imbalance stays under 2x — unlike the single-core wall
    deltas, which for a spatially homogeneous regime are pure compaction
    and can sit inside timer noise).
    """
    from repro.shard import ShardedEngine
    from repro.workloads import sharding_scenarios

    backend = "grid-pallas-ref"  # the bucketed kernel the shards compact for
    target_users = max(int(20_000_000 * scale), 50_000)
    rows = []
    for w in sharding_scenarios(target_users):
        qs = w.qs if not n_queries else w.qs[:n_queries]
        oracle = RkNNEngine(w.facilities, w.users, RkNNConfig(backend=backend))
        oracle.query_batch(qs, w.k)  # warm: jit + scene/batch caches
        t0 = time.perf_counter()
        ref = oracle.query_batch(qs, w.k)
        t_single = time.perf_counter() - t0
        engines = {}
        for shards in (1, 2, 4):
            eng = ShardedEngine(
                w.facilities, w.users, RkNNConfig(backend=backend), shards=shards
            )
            got = eng.query_batch(qs, w.k)  # warm
            identical = np.array_equal(ref.masks, got.masks) and np.array_equal(
                np.asarray(ref.counts), np.asarray(got.counts)
            )
            assert identical, (w.name, shards)
            engines[shards] = eng
        wall = {s: np.inf for s in engines}
        step = {s: np.inf for s in engines}
        for _ in range(5):
            for shards, eng in engines.items():
                t0 = time.perf_counter()
                eng.query_batch(qs, w.k)
                wall[shards] = min(wall[shards], time.perf_counter() - t0)
                # freshest shard-batch record: this call's per-shard walls
                rec = eng.explain()[-1]
                step[shards] = min(step[shards], max(rec["per_shard_verify_s"]))
        for shards, eng in engines.items():
            rows.append(
                dict(
                    name=f"sharded_{w.name}_s{shards}",
                    us_per_call=wall[shards] / len(qs) * 1e6,
                    derived=(
                        f"users={len(w.users)} shards={shards} "
                        f"qps={len(qs)/wall[shards]:.1f} "
                        f"mesh_qps={len(qs)/step[shards]:.1f} "
                        f"step={step[shards]*1e3:.0f}ms "
                        f"speedup_vs_s1={wall[1]/wall[shards]:.2f}x "
                        f"single={t_single*1e3:.0f}ms identical=True "
                        f"imbalance={eng.stats.shard_imbalance:.2f}"
                    ),
                )
            )
        monotone = step[1] >= step[2] >= step[4]
        rows.append(
            dict(
                name=f"sharded_{w.name}_scaling",
                us_per_call=step[4] / len(qs) * 1e6,
                derived=(
                    f"users={len(w.users)} s1={step[1]*1e3:.0f}ms "
                    f"s2={step[2]*1e3:.0f}ms s4={step[4]*1e3:.0f}ms "
                    f"monotone={monotone} s1/s4={step[1]/max(step[4],1e-9):.2f}x "
                    f"wall_s1={wall[1]*1e3:.0f}ms wall_s4={wall[4]*1e3:.0f}ms "
                    f"devices={len(jax.devices())}"
                ),
            )
        )
    return rows


# --------------------------------------------- observability overhead (ours)
def obs_overhead(scale: float = DEFAULT_SCALE, n_queries: int = 0) -> list[dict]:
    """The tracing-enabled serving tax, measured interleaved.

    One warm engine serves the same batch with span recording off and on,
    alternating (so drift in machine load hits both arms equally), best-of
    per arm.  A span always takes its two ``perf_counter`` readings — the
    engine needs the elapsed time regardless — so the *enabled* delta is
    purely the ring write + interning at span exit.  Gate:
    ``ratio <= 1.03`` (tracing costs at most 3% of the disabled path).
    """
    from repro.obs import Tracer, set_tracer

    rng = np.random.default_rng(0)
    F, U = _fu("CAL", 400, scale)
    q_n = n_queries or 16
    qs = [int(q) for q in rng.integers(0, len(F), q_n)]
    eng = RkNNEngine(F, U, RkNNConfig(backend="grid"))
    eng.query_batch(qs, 10)  # jit + scene/prepared caches warm
    eng.query_batch(qs, 10)
    prev = set_tracer(Tracer())  # fresh rings; global state restored below
    best = {"off": np.inf, "on": np.inf}
    try:
        from repro.obs import get_tracer

        tracer = get_tracer()
        for _ in range(9):
            for mode in ("off", "on"):
                tracer.enabled = mode == "on"
                t0 = time.perf_counter()
                eng.query_batch(qs, 10)
                best[mode] = min(best[mode], time.perf_counter() - t0)
        n_spans = sum(1 for _ in tracer.records())
    finally:
        set_tracer(prev)
    ratio = best["on"] / max(best["off"], 1e-12)
    return [
        dict(
            name="obs_overhead",
            us_per_call=best["on"] / q_n * 1e6,
            derived=(
                f"ratio={ratio:.3f} ok={ratio <= 1.03} "
                f"off={best['off']*1e3:.2f}ms on={best['on']*1e3:.2f}ms "
                f"spans={n_spans} Q={q_n}"
            ),
        )
    ]


# ----------------------------------------------- health-layer overhead (ours)
def health_overhead(scale: float = DEFAULT_SCALE, n_queries: int = 0) -> list[dict]:
    """The full production-health tax over obs-only serving.

    Baseline arm: tracing-enabled serving (exactly what ``obs_overhead``
    measures as its "on" arm).  Health arm adds everything PR 9 bolts on
    in production: an armed :class:`FlightRecorder` on the engine, a live
    :class:`ObsServer`, and a concurrent scraper thread hitting
    ``/metrics`` + ``/healthz`` at a paced interval while the engine
    serves.  Interleaved best-of per arm, same batch.  Gate:
    ``ratio <= 1.05`` (the whole health layer costs at most 5% over
    obs-only serving).
    """
    import http.client
    import tempfile
    import threading

    from repro.obs import Tracer, get_tracer, set_tracer
    from repro.obs.flight import FlightRecorder

    rng = np.random.default_rng(0)
    F, U = _fu("CAL", 400, scale)
    q_n = n_queries or 16
    qs = [int(q) for q in rng.integers(0, len(F), q_n)]
    eng = RkNNEngine(F, U, RkNNConfig(backend="grid"))
    eng.query_batch(qs, 10)  # jit + scene/prepared caches warm
    eng.query_batch(qs, 10)
    prev = set_tracer(Tracer())  # fresh rings; global state restored below
    best = {"base": np.inf, "health": np.inf}
    counts = {"scrapes": 0, "errors": 0}
    srv = None
    try:
        tracer = get_tracer()
        tracer.enabled = True  # both arms serve with tracing on
        srv = eng.serve_obs(port=0)
        recorder = FlightRecorder(eng, dir=tempfile.mkdtemp(prefix="flight_"))
        scraping = threading.Event()
        stop = threading.Event()

        def _scraper() -> None:
            # Persistent keep-alive connection, like a real Prometheus
            # scraper — per-request TCP setup would otherwise dominate
            # the measured cost of the endpoints themselves.
            conn = http.client.HTTPConnection("127.0.0.1", srv.port, timeout=2)
            while not stop.is_set():
                if not scraping.is_set():
                    stop.wait(0.005)
                    continue
                for route in ("/metrics", "/healthz"):
                    try:
                        conn.request("GET", route)
                        r = conn.getresponse()
                        r.read()
                        # /healthz legitimately serves 503 when a rule
                        # trips; anything else non-200 is an error.
                        if r.status not in (200, 503):
                            counts["errors"] += 1
                    except Exception:
                        counts["errors"] += 1
                        conn.close()  # reconnect on next request
                counts["scrapes"] += 1
                # Paced at ~5 scrapes/s — nearly two orders of magnitude
                # hotter than a production Prometheus interval, without
                # turning the bench into a CPU-contention microbenchmark
                # on single-core runners.
                stop.wait(0.2)
            conn.close()

        th = threading.Thread(target=_scraper, daemon=True)
        th.start()
        for _ in range(9):
            for mode in ("base", "health"):
                if mode == "health":
                    eng.flight = recorder
                    scraping.set()
                else:
                    eng.flight = None
                    scraping.clear()
                    stop.wait(0.03)  # let an in-flight scrape drain
                t0 = time.perf_counter()
                eng.query_batch(qs, 10)
                best[mode] = min(best[mode], time.perf_counter() - t0)
        stop.set()
        th.join(timeout=2)
    finally:
        eng.flight = None
        if srv is not None:
            srv.close()
        set_tracer(prev)
    ratio = best["health"] / max(best["base"], 1e-12)
    return [
        dict(
            name="health_overhead",
            us_per_call=best["health"] / q_n * 1e6,
            derived=(
                f"ratio={ratio:.3f} ok={ratio <= 1.05} "
                f"base={best['base']*1e3:.2f}ms health={best['health']*1e3:.2f}ms "
                f"scrapes={counts['scrapes']} errors={counts['errors']} Q={q_n}"
            ),
        )
    ]


# ------------------------------------------------ zero-cold-start (PR 10)
def cold_start(scale: float = DEFAULT_SCALE, n_queries: int = 0) -> list[dict]:
    """Process start → first query wave served: cold build vs warm restore.

    Cold arm: construct an engine and serve a wave of distinct queries —
    every scene is pruned+built, every index packed, from nothing.  The
    engine then exports its state as an ``rknn-store/1`` step.  Warm arm:
    construct with ``warm_store=`` pointing at that step and serve the
    same wave — the working set is adopted, not rebuilt.  XLA compilation
    is pre-warmed on a throwaway engine before either arm: it is
    per-process, identical in both arms, and not persistable state; the
    contest is the amortized engine state (scenes, packed indexes, cell
    bucketing).  ``identical`` additionally folds in a small save/restore
    round-trip across **every** registered concrete backend.  Gates:
    ``speedup >= 3`` at CI scale (≥10x at full scale, BENCH_10),
    ``identical=True``.
    """
    import shutil
    import tempfile

    from repro.core.backends import concrete_backends

    rng = np.random.default_rng(0)
    F, U = _fu("USA", 800, scale)
    # the contest is amortized *construction* state, so keep the per-query
    # cast (pure compute, identical in both arms, never persisted) from
    # drowning the signal at full scale
    U = U[:40_000]
    q_n = n_queries or 32
    qs = [int(q) for q in rng.choice(len(F), size=min(q_n, len(F)), replace=False)]
    k = 16
    cfg = dict(backend="grid", grid_g=64)

    pre = RkNNEngine(F[:64], U[:512], RkNNConfig(**cfg))
    pre.query(0, k)

    def _wave(eng):
        return [eng.query(q, k) for q in qs]

    store = tempfile.mkdtemp(prefix="rknn_store_")
    try:
        def _cold():
            eng = RkNNEngine(F, U, RkNNConfig(**cfg))
            return eng, _wave(eng)

        (cold_eng, cold_res), t_cold = timed(_cold)
        _, t_save = timed(lambda: cold_eng.save_state(store))

        def _warm():
            eng = RkNNEngine(F, U, RkNNConfig(**cfg, warm_store=store))
            return eng, _wave(eng)

        (warm_eng, warm_res), t_warm = timed(_warm)
        restore_s = sum(
            c.get("seconds", 0.0)
            for c in warm_eng.persist_info["categories"].values()
        )
        rebuilt = warm_eng._snap.scene_cache.misses
        identical = all(
            np.array_equal(np.asarray(c.mask), np.asarray(w.mask))
            and np.array_equal(np.asarray(c.counts), np.asarray(w.counts))
            for c, w in zip(cold_res, warm_res)
        )

        # every registered concrete backend round-trips bit-identically
        n_backends = 0
        F2, U2 = F[:60], U[:400]
        for b in concrete_backends():
            bdir = tempfile.mkdtemp(prefix="rknn_bstore_")
            try:
                c = RkNNEngine(F2, U2, RkNNConfig(backend=b, grid_g=16))
                want = [c.query(q, 8) for q in (0, 3)]
                c.save_state(bdir)
                w = RkNNEngine(
                    F2, U2, RkNNConfig(backend=b, grid_g=16, warm_store=bdir)
                )
                got = [w.query(q, 8) for q in (0, 3)]
                identical &= all(
                    np.array_equal(np.asarray(a.mask), np.asarray(g.mask))
                    and np.array_equal(np.asarray(a.counts), np.asarray(g.counts))
                    for a, g in zip(want, got)
                )
                n_backends += 1
            finally:
                shutil.rmtree(bdir, ignore_errors=True)

        speedup = t_cold / max(t_warm, 1e-9)
        return [
            dict(
                name="cold_start",
                us_per_call=t_cold / len(qs) * 1e6,
                derived=(
                    f"speedup={speedup:.1f}x identical={identical} "
                    f"cold_s={t_cold:.3f} warm_s={t_warm:.3f} "
                    f"save_s={t_save:.3f} restore_s={restore_s:.3f} "
                    f"rebuilt={rebuilt} queries={len(qs)} "
                    f"backends={n_backends} F={len(F)} U={len(U)} k={k}"
                ),
            )
        ]
    finally:
        shutil.rmtree(store, ignore_errors=True)
