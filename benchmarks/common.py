"""Shared benchmark scaffolding: datasets, timing, CSV rows.

Every module reproduces one paper table/figure on generated road-network-
like data (DESIGN.md §6).  ``scale`` multiplies the paper's cardinalities
(default 0.05 keeps the full suite to minutes on CPU; ``--scale 1.0``
reproduces the published sizes).  The RT-RkNN method is timed with the
``dense-ref`` backend — the vectorized jnp execution of the ray-cast stage,
which is what the Pallas kernel computes on the TPU target (interpret-mode
Pallas is a correctness tool, not a timing tool; the registry encodes this
as ``Backend.interpret_mode_on_cpu``, and sweeps draw their contender sets
from ``repro.core.backends.timeable_backends``).
"""

from __future__ import annotations

import functools
import time

import numpy as np

from repro.core.baselines import STRTree, infzone_rknn, six_rknn, slice_rknn, tpl_rknn
from repro.core.rknn import rt_rknn_query, rt_rknn_query_batch
from repro.data.spatial import PAPER_DATASETS, facility_user_split, road_network_points

DEFAULT_SCALE = 0.05


@functools.lru_cache(maxsize=8)
def dataset(name: str, scale: float = DEFAULT_SCALE, seed: int = 0) -> np.ndarray:
    n = max(2000, int(PAPER_DATASETS[name] * scale))
    return road_network_points(n, seed=seed)


def timed(fn, *args, repeats: int = 1, **kw):
    best = np.inf
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def run_methods(F, U, q_indices, k, methods=("tpl", "inf", "slice", "rt"), tree=None):
    """Mean runtime per query (s) for each method over ``q_indices``.

    ``"rt-batch"`` dispatches the whole sweep as ONE
    :func:`rt_rknn_query_batch` call (the amortized engine) instead of a
    Python query loop; its per-query mean is directly comparable to
    ``"rt"``.
    """
    if tree is None and ("six" in methods or "tpl" in methods):
        tree = STRTree(F)
    acc = {m: 0.0 for m in methods}
    split = {m: [0.0, 0.0] for m in methods}
    n = len(q_indices)
    looped = [m for m in methods if m != "rt-batch"]
    if "rt-batch" in methods:
        qs = [int(q) for q in q_indices]
        # warm the jit cache at this batch shape so the timed call measures
        # steady-state dispatch, not compilation
        rt_rknn_query_batch(F, U, qs, k, backend="dense-ref")
        t0 = time.perf_counter()
        rb = rt_rknn_query_batch(F, U, qs, k, backend="dense-ref")
        acc["rt-batch"] = time.perf_counter() - t0
        split["rt-batch"] = [rb.t_filter_s, rb.t_verify_s]
    for qi in q_indices:
        for m in looped:
            t0 = time.perf_counter()
            if m == "six":
                _, info = six_rknn(F, U, qi, k, tree)
            elif m == "tpl":
                _, info = tpl_rknn(F, U, qi, k, tree)
            elif m == "inf":
                _, info = infzone_rknn(F, U, qi, k)
            elif m == "slice":
                _, info = slice_rknn(F, U, qi, k)
            elif m == "rt":
                r = rt_rknn_query(F, U, qi, k, backend="dense-ref")
                info = dict(t_filter_s=r.t_filter_s, t_verify_s=r.t_verify_s)
            else:
                raise ValueError(m)
            acc[m] += time.perf_counter() - t0
            split[m][0] += info.get("t_filter_s", 0.0)
            split[m][1] += info.get("t_verify_s", 0.0)
    return (
        {m: v / n for m, v in acc.items()},
        {m: (a / n, b / n) for m, (a, b) in split.items()},
    )


def rows_to_csv(rows: list[dict]) -> str:
    out = []
    for r in rows:
        out.append(f"{r['name']},{r['us_per_call']:.1f},{r.get('derived','')}")
    return "\n".join(out)
