"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--scale 0.05] [--only fig9] \
        [--json out.json] [--backend auto]

Prints ``name,us_per_call,derived`` CSV (one row per measured artefact).
``--scale 1.0`` reproduces the paper's dataset cardinalities (minutes to
hours on CPU); the default keeps CI fast while preserving every comparison.
``--json`` additionally writes the rows as machine-readable JSON
(``{"meta": {...}, "rows": [...]}``) so CI and future PRs can append
trajectory points (``BENCH_*.json``) without re-parsing CSV.
``--backend`` is forwarded to the benches that take one (currently the
planner's ``scenario_sweep``, which grades that backend against the fixed
set).
``--trend`` prints the committed ``benchmarks/results/BENCH_*.json``
trajectory (one CSV row per recorded measurement, tagged with its PR
number) instead of running anything — the cross-PR performance story in
one grep-able stream.
"""

from __future__ import annotations

import argparse
import glob
import inspect
import json
import os
import platform
import re
import sys
import time

from benchmarks import bench_rknn
from benchmarks.common import DEFAULT_SCALE

BENCHES = [
    ("table2", bench_rknn.table2_indexing),
    ("fig7_8", bench_rknn.fig7_8_vary_k),
    ("fig9", bench_rknn.fig9_large_k),
    ("fig10", bench_rknn.fig10_datasize),
    ("fig11_12", bench_rknn.fig11_12_facility),
    ("fig13_14", bench_rknn.fig13_14_user),
    ("fig15", bench_rknn.fig15_breakdown),
    ("table3_fig16", bench_rknn.table3_fig16_occluders),
    ("fig17", bench_rknn.fig17_no_rt),
    ("backends", bench_rknn.backends_ablation),
    ("batch", bench_rknn.batch_throughput),
    ("engine", bench_rknn.engine_amortization),
    ("scenario_sweep", bench_rknn.scenario_sweep),
    ("update_throughput", bench_rknn.update_throughput),
    ("mono", bench_rknn.mono_queries),
    ("sharded_scaling", bench_rknn.sharded_scaling),
    ("obs_overhead", bench_rknn.obs_overhead),
]


def print_trend() -> None:
    """The committed BENCH_*.json trajectory as one CSV stream.

    Each committed file is one PR's acceptance measurement; printing them
    in PR order makes per-artefact trajectories (`grep sharded_`,
    `grep scenario_aggregate`) readable across the repo's history."""
    results = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results")
    files = sorted(
        glob.glob(os.path.join(results, "BENCH_*.json")),
        key=lambda p: int(re.search(r"BENCH_(\d+)", p).group(1)),
    )
    if not files:
        print(f"# no committed BENCH_*.json under {results}", file=sys.stderr)
        return
    print("pr,bench,name,us_per_call,derived")
    for path in files:
        with open(path) as f:
            payload = json.load(f)
        pr = int(re.search(r"BENCH_(\d+)", path).group(1))
        meta = payload.get("meta", {})
        print(
            f"# BENCH_{pr}: scale={meta.get('scale')} "
            f"wall={meta.get('wall_s')}s only={meta.get('only')}",
            file=sys.stderr,
        )
        for r in payload.get("rows", []):
            derived = str(r.get("derived", "")).replace(",", ";")
            print(
                f"{pr},{r.get('bench', '?')},{r['name']},"
                f"{float(r['us_per_call']):.1f},{derived}"
            )
        for e in payload.get("errors", []):
            print(f"{pr},{e.get('bench', '?')}_ERROR,,0,{e.get('error')}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=DEFAULT_SCALE)
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated substring filters on bench name (any match runs)",
    )
    ap.add_argument(
        "--trace",
        default=None,
        metavar="OUT",
        help="record engine spans for the whole run and write a Chrome "
        "trace_event JSON (open in chrome://tracing or Perfetto)",
    )
    ap.add_argument(
        "--json",
        default=None,
        metavar="OUT",
        help="also write rows as machine-readable JSON to this path",
    )
    ap.add_argument(
        "--backend",
        default=None,
        help="backend name forwarded to benches that accept one",
    )
    ap.add_argument(
        "--concurrent",
        action="store_true",
        help="forwarded to benches that accept it (update_throughput: "
        "measure MVCC serving latency under a concurrent update stream)",
    )
    ap.add_argument(
        "--trend",
        action="store_true",
        help="print the committed benchmarks/results/BENCH_*.json "
        "trajectory as CSV and exit (runs nothing)",
    )
    args = ap.parse_args()
    if args.trend:
        print_trend()
        return

    if args.trace:
        from repro.obs import enable_tracing

        enable_tracing()

    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    all_rows: list[dict] = []
    errors: list[dict] = []
    only = [s for s in (args.only or "").split(",") if s]
    for name, fn in BENCHES:
        if only and not any(s in name for s in only):
            continue
        kw = {"scale": args.scale}
        if args.backend and "backend" in inspect.signature(fn).parameters:
            kw["backend"] = args.backend
        if args.concurrent and "concurrent" in inspect.signature(fn).parameters:
            kw["concurrent"] = True
        try:
            rows = fn(**kw)
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"{name}_ERROR,0,{type(e).__name__}: {e}", file=sys.stdout)
            errors.append(dict(bench=name, error=f"{type(e).__name__}: {e}"))
            continue
        for r in rows:
            derived = str(r.get("derived", "")).replace(",", ";")
            print(f"{r['name']},{r['us_per_call']:.1f},{derived}", flush=True)
            all_rows.append(
                dict(
                    bench=name,
                    name=r["name"],
                    us_per_call=float(r["us_per_call"]),
                    derived=str(r.get("derived", "")),
                )
            )
    wall = time.perf_counter() - t0
    if args.trace:
        from repro.obs import disable_tracing, write_chrome_trace

        disable_tracing()
        obj = write_chrome_trace(args.trace)
        n = sum(1 for e in obj["traceEvents"] if e.get("ph") == "X")
        print(f"# wrote {n} spans to {args.trace}", file=sys.stderr)
    if args.json:
        payload = dict(
            meta=dict(
                scale=args.scale,
                only=args.only,
                backend=args.backend,
                wall_s=round(wall, 3),
                python=platform.python_version(),
                platform=platform.platform(),
            ),
            rows=all_rows,
            errors=errors,
        )
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"# wrote {len(all_rows)} rows to {args.json}", file=sys.stderr)
    print(f"# total wall: {wall:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
