"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--scale 0.05] [--only fig9]

Prints ``name,us_per_call,derived`` CSV (one row per measured artefact).
``--scale 1.0`` reproduces the paper's dataset cardinalities (minutes to
hours on CPU); the default keeps CI fast while preserving every comparison.
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks import bench_rknn
from benchmarks.common import DEFAULT_SCALE

BENCHES = [
    ("table2", bench_rknn.table2_indexing),
    ("fig7_8", bench_rknn.fig7_8_vary_k),
    ("fig9", bench_rknn.fig9_large_k),
    ("fig10", bench_rknn.fig10_datasize),
    ("fig11_12", bench_rknn.fig11_12_facility),
    ("fig13_14", bench_rknn.fig13_14_user),
    ("fig15", bench_rknn.fig15_breakdown),
    ("table3_fig16", bench_rknn.table3_fig16_occluders),
    ("fig17", bench_rknn.fig17_no_rt),
    ("backends", bench_rknn.backends_ablation),
    ("batch", bench_rknn.batch_throughput),
    ("mono", bench_rknn.mono_queries),
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=DEFAULT_SCALE)
    ap.add_argument("--only", default=None, help="substring filter on bench name")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    for name, fn in BENCHES:
        if args.only and args.only not in name:
            continue
        try:
            rows = fn(scale=args.scale)
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"{name}_ERROR,0,{type(e).__name__}: {e}", file=sys.stdout)
            continue
        for r in rows:
            derived = str(r.get("derived", "")).replace(",", ";")
            print(f"{r['name']},{r['us_per_call']:.1f},{derived}", flush=True)
    print(f"# total wall: {time.perf_counter() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
