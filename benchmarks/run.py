"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--scale 0.05] [--only fig9] \
        [--json out.json] [--backend auto]

Prints ``name,us_per_call,derived`` CSV (one row per measured artefact).
``--scale 1.0`` reproduces the paper's dataset cardinalities (minutes to
hours on CPU); the default keeps CI fast while preserving every comparison.
``--json`` additionally writes the rows as machine-readable JSON
(``{"meta": {...}, "rows": [...]}``) so CI and future PRs can append
trajectory points (``BENCH_*.json``) without re-parsing CSV.
``--backend`` is forwarded to the benches that take one (currently the
planner's ``scenario_sweep``, which grades that backend against the fixed
set).
``--trend`` prints the committed ``benchmarks/results/BENCH_*.json``
trajectory (one CSV row per recorded measurement, tagged with its PR
number) instead of running anything — the cross-PR performance story in
one grep-able stream.
``--trend --gate`` additionally evaluates the trajectory against the
declared per-metric tolerances (``TREND_GATES``) and exits non-zero on
any regression — the bench trajectory as an enforced contract, not a
printout.  Each gate checks the *latest* committed point of every
matching artefact (history is context, not a verdict: a regression that
was already fixed stays visible in the trajectory without failing CI).
"""

from __future__ import annotations

import argparse
import glob
import inspect
import json
import os
import platform
import re
import sys
import time

from benchmarks import bench_rknn
from benchmarks.common import DEFAULT_SCALE

BENCHES = [
    ("table2", bench_rknn.table2_indexing),
    ("fig7_8", bench_rknn.fig7_8_vary_k),
    ("fig9", bench_rknn.fig9_large_k),
    ("fig10", bench_rknn.fig10_datasize),
    ("fig11_12", bench_rknn.fig11_12_facility),
    ("fig13_14", bench_rknn.fig13_14_user),
    ("fig15", bench_rknn.fig15_breakdown),
    ("table3_fig16", bench_rknn.table3_fig16_occluders),
    ("fig17", bench_rknn.fig17_no_rt),
    ("backends", bench_rknn.backends_ablation),
    ("batch", bench_rknn.batch_throughput),
    ("engine", bench_rknn.engine_amortization),
    ("scenario_sweep", bench_rknn.scenario_sweep),
    ("update_throughput", bench_rknn.update_throughput),
    ("mono", bench_rknn.mono_queries),
    ("sharded_scaling", bench_rknn.sharded_scaling),
    ("obs_overhead", bench_rknn.obs_overhead),
    ("health_overhead", bench_rknn.health_overhead),
    ("cold_start", bench_rknn.cold_start),
]

#: The declared cross-PR tolerances (``--trend --gate``).  ``row`` is a
#: substring filter on artefact names; ``key`` extracts a ``key=value``
#: KPI from the derived string (suffixes like ``x``/``ms`` stripped) and
#: is checked against ``min``/``max``; ``flag`` requires a literal token
#: in the derived string.  ``fallback_flag`` passes a row whose KPI is
#: absent (older artefact shapes).  Values mirror the per-bench CI
#: assertions so the trajectory gate and the fresh-run gates agree.
TREND_GATES = [
    dict(id="obs-overhead", row="obs_overhead", key="ratio", max=1.03),
    dict(id="health-overhead", row="health_overhead", key="ratio", max=1.05),
    dict(id="planner-drift", row="planner_drift", key="worst_abs_median", max=1.5),
    dict(
        id="scenario-aggregate",
        row="scenario_aggregate",
        key="agg_ratio",
        max=1.25,
        fallback_flag="beats_all=True",
    ),
    dict(id="mvcc-concurrent", row="update_concurrent", flag="within2x=True"),
    dict(id="mvcc-stale", row="update_concurrent", flag="stale_mix=0"),
    dict(id="shard-scaling-monotone", row="_scaling", flag="monotone=True"),
    dict(id="shard-scaling-speedup", row="_scaling", key="s1/s4", min=1.5),
    dict(id="refit-drift-win", row="update_drift", key="speedup", min=1.0),
    dict(id="cold-start", row="cold_start", key="speedup", min=3.0),
    dict(id="cold-start-identical", row="cold_start", flag="identical=True"),
]

_NUM_RE = re.compile(r"-?\d+(?:\.\d+)?(?:[eE][+-]?\d+)?")


def _kpi(derived: str, key: str) -> float | None:
    """Extract ``key=<number>`` from a derived string (unit suffixes like
    ``x`` / ``ms`` ignored); ``None`` when the key is absent."""
    for tok in derived.replace(";", " ").split():
        k, eq, v = tok.partition("=")
        if eq and k == key:
            m = _NUM_RE.match(v)
            return float(m.group(0)) if m else None
    return None


def _load_results(paths: list[str] | None = None) -> list[tuple[int, list[dict]]]:
    if paths is None:
        results = os.path.join(
            os.path.dirname(os.path.abspath(__file__)), "results"
        )
        paths = glob.glob(os.path.join(results, "BENCH_*.json"))
    out = []
    for path in sorted(
        paths, key=lambda p: int(re.search(r"BENCH_(\d+)", p).group(1))
    ):
        with open(path) as f:
            payload = json.load(f)
        pr = int(re.search(r"BENCH_(\d+)", path).group(1))
        out.append((pr, payload.get("rows", [])))
    return out


def evaluate_trend(paths: list[str] | None = None) -> dict:
    """Grade the committed trajectory against :data:`TREND_GATES`.

    Returns ``{"lines": [...], "failures": [...]}`` — one line per
    (gate, artefact) with the full cross-PR KPI trajectory and the
    verdict on the latest point; failures collect the lines that fail.
    Usable directly from tests (pass explicit paths for fixtures).
    """
    data = _load_results(paths)
    lines: list[str] = []
    failures: list[str] = []
    for gate in TREND_GATES:
        series: dict[str, list[tuple[int, str]]] = {}
        for pr, rows in data:
            for r in rows:
                if gate["row"] in r.get("name", ""):
                    series.setdefault(r["name"], []).append(
                        (pr, str(r.get("derived", "")))
                    )
        if not series:
            lines.append(f"SKIP {gate['id']}: no committed data")
            continue
        for name, pts in sorted(series.items()):
            pts.sort(key=lambda t: t[0])
            latest_pr, derived = pts[-1]
            verdict, shown = _grade(gate, derived)
            traj = " ".join(
                f"pr{pr}:{_kpi(d, gate['key']) if 'key' in gate else ('ok' if gate['flag'] in d else 'FAIL')}"
                for pr, d in pts
            )
            line = (
                f"{'PASS' if verdict else 'FAIL'} {gate['id']}: {name} "
                f"latest=pr{latest_pr} {shown} | {traj}"
            )
            lines.append(line)
            if not verdict:
                failures.append(line)
    return {"lines": lines, "failures": failures}


def _grade(gate: dict, derived: str) -> tuple[bool, str]:
    """Verdict for one artefact's latest derived string under one gate."""
    if "key" in gate:
        v = _kpi(derived, gate["key"])
        if v is None:
            fb = gate.get("fallback_flag")
            if fb is not None:
                ok = fb in derived
                return ok, f"{fb} {'present' if ok else 'ABSENT'}"
            return False, f"{gate['key']} missing"
        lo, hi = gate.get("min"), gate.get("max")
        if hi is not None and v > hi:
            return False, f"{gate['key']}={v:g} > max {hi:g}"
        if lo is not None and v < lo:
            return False, f"{gate['key']}={v:g} < min {lo:g}"
        bound = f"<= {hi:g}" if hi is not None else f">= {lo:g}"
        return True, f"{gate['key']}={v:g} ({bound})"
    flag = gate["flag"]
    ok = flag in derived
    return ok, f"{flag} {'present' if ok else 'ABSENT'}"


def print_trend() -> None:
    """The committed BENCH_*.json trajectory as one CSV stream.

    Each committed file is one PR's acceptance measurement; printing them
    in PR order makes per-artefact trajectories (`grep sharded_`,
    `grep scenario_aggregate`) readable across the repo's history."""
    results = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results")
    files = sorted(
        glob.glob(os.path.join(results, "BENCH_*.json")),
        key=lambda p: int(re.search(r"BENCH_(\d+)", p).group(1)),
    )
    if not files:
        print(f"# no committed BENCH_*.json under {results}", file=sys.stderr)
        return
    print("pr,bench,name,us_per_call,derived")
    for path in files:
        with open(path) as f:
            payload = json.load(f)
        pr = int(re.search(r"BENCH_(\d+)", path).group(1))
        meta = payload.get("meta", {})
        print(
            f"# BENCH_{pr}: scale={meta.get('scale')} "
            f"wall={meta.get('wall_s')}s only={meta.get('only')}",
            file=sys.stderr,
        )
        for r in payload.get("rows", []):
            derived = str(r.get("derived", "")).replace(",", ";")
            print(
                f"{pr},{r.get('bench', '?')},{r['name']},"
                f"{float(r['us_per_call']):.1f},{derived}"
            )
        for e in payload.get("errors", []):
            print(f"{pr},{e.get('bench', '?')}_ERROR,,0,{e.get('error')}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=DEFAULT_SCALE)
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated substring filters on bench name (any match runs)",
    )
    ap.add_argument(
        "--trace",
        default=None,
        metavar="OUT",
        help="record engine spans for the whole run and write a Chrome "
        "trace_event JSON (open in chrome://tracing or Perfetto)",
    )
    ap.add_argument(
        "--json",
        default=None,
        metavar="OUT",
        help="also write rows as machine-readable JSON to this path",
    )
    ap.add_argument(
        "--backend",
        default=None,
        help="backend name forwarded to benches that accept one",
    )
    ap.add_argument(
        "--concurrent",
        action="store_true",
        help="forwarded to benches that accept it (update_throughput: "
        "measure MVCC serving latency under a concurrent update stream)",
    )
    ap.add_argument(
        "--trend",
        action="store_true",
        help="print the committed benchmarks/results/BENCH_*.json "
        "trajectory as CSV and exit (runs nothing)",
    )
    ap.add_argument(
        "--gate",
        action="store_true",
        help="with --trend: grade the trajectory against the declared "
        "TREND_GATES tolerances and exit non-zero on any regression",
    )
    args = ap.parse_args()
    if args.trend:
        print_trend()
        if args.gate:
            report = evaluate_trend()
            print("\n# trend gate:", file=sys.stderr)
            for line in report["lines"]:
                print(f"# {line}", file=sys.stderr)
            if report["failures"]:
                print(
                    f"# trend gate: {len(report['failures'])} regression(s)",
                    file=sys.stderr,
                )
                sys.exit(1)
            print("# trend gate: green", file=sys.stderr)
        return

    if args.trace:
        from repro.obs import enable_tracing

        enable_tracing()

    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    all_rows: list[dict] = []
    errors: list[dict] = []
    only = [s for s in (args.only or "").split(",") if s]
    for name, fn in BENCHES:
        if only and not any(s in name for s in only):
            continue
        kw = {"scale": args.scale}
        if args.backend and "backend" in inspect.signature(fn).parameters:
            kw["backend"] = args.backend
        if args.concurrent and "concurrent" in inspect.signature(fn).parameters:
            kw["concurrent"] = True
        try:
            rows = fn(**kw)
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"{name}_ERROR,0,{type(e).__name__}: {e}", file=sys.stdout)
            errors.append(dict(bench=name, error=f"{type(e).__name__}: {e}"))
            continue
        for r in rows:
            derived = str(r.get("derived", "")).replace(",", ";")
            print(f"{r['name']},{r['us_per_call']:.1f},{derived}", flush=True)
            all_rows.append(
                dict(
                    bench=name,
                    name=r["name"],
                    us_per_call=float(r["us_per_call"]),
                    derived=str(r.get("derived", "")),
                )
            )
    wall = time.perf_counter() - t0
    if args.trace:
        from repro.obs import disable_tracing, write_chrome_trace

        disable_tracing()
        obj = write_chrome_trace(args.trace)
        n = sum(1 for e in obj["traceEvents"] if e.get("ph") == "X")
        print(f"# wrote {n} spans to {args.trace}", file=sys.stderr)
    if args.json:
        payload = dict(
            meta=dict(
                scale=args.scale,
                only=args.only,
                backend=args.backend,
                wall_s=round(wall, 3),
                python=platform.python_version(),
                platform=platform.platform(),
            ),
            rows=all_rows,
            errors=errors,
        )
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"# wrote {len(all_rows)} rows to {args.json}", file=sys.stderr)
    print(f"# total wall: {wall:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
