"""End-to-end training driver: a ~100M-class model for a few hundred steps
through the full production stack — data pipeline, AdamW, checkpointing,
fault-tolerant driver (with a mid-run simulated crash + restart).

    PYTHONPATH=src python examples/train_lm.py [--arch mamba2_130m] [--steps 200]
"""

import argparse
import json
import shutil
import tempfile

from repro.launch.train import train_main


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2_130m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--keep-ckpt", default=None, help="checkpoint dir to keep")
    args = ap.parse_args()

    ckpt = args.keep_ckpt or tempfile.mkdtemp(prefix="repro_train_")
    try:
        out = train_main(
            args.arch,
            steps=args.steps,
            batch=args.batch,
            seq=args.seq,
            reduced=True,
            reduced_overrides=dict(d_model=256, n_layers=4, vocab=2048, head_dim=64),
            ckpt_dir=ckpt,
            save_every=max(args.steps // 4, 10),
            lr=1e-3,
        )
        print(json.dumps(out, indent=1, default=str))
        assert out["last_loss"] < out["first_loss"], "loss did not decrease"
        print(
            f"\nloss {out['first_loss']:.3f} -> {out['last_loss']:.3f} over "
            f"{out['steps']} steps ({out['params']/1e6:.1f}M params, "
            f"{out['wall_s']:.1f}s) — checkpoints in {ckpt}"
        )
    finally:
        if args.keep_ckpt is None:
            shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
