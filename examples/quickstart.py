"""Quickstart: one RkNN query end-to-end, every backend, verified exact.

Builds a stateful :class:`RkNNEngine` once (users uploaded once, shared
domain rect, scene cache) and queries it per backend — the amortized path.
The legacy one-shot free functions (``rt_rknn_query`` …) remain available
as shims; see docs/API.md for the migration table.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import RkNNEngine, available_backends
from repro.core.brute import rknn_brute_np
from repro.data.spatial import facility_user_split, road_network_points


def main() -> None:
    # a road-network-like city: 100k points, 1000 facilities, rest users
    points = road_network_points(100_000, seed=7)
    facilities, users = facility_user_split(points, 1_000, seed=7)
    q, k = 42, 10

    print(f"|F|={len(facilities)}  |U|={len(users)}  query=facility#{q}  k={k}\n")

    engine = RkNNEngine(facilities, users)  # build once, query many
    truth = rknn_brute_np(users, facilities, q, k)
    for backend in available_backends():
        res = engine.query(q, k, backend=backend)
        ok = np.array_equal(res.mask, truth)
        extra = ""
        if res.scene is not None:
            extra = (f"  occluders={res.scene.n_occluders}/{len(facilities)} "
                     f"(InfZone-style pruning)")
        print(
            f"{backend:10s}  |RkNN|={res.mask.sum():5d}  exact={ok}  "
            f"filter={res.t_filter_s*1e3:7.1f}ms  verify={res.t_verify_s*1e3:7.1f}ms{extra}"
        )
        assert ok, backend

    # the scene cache makes the repeat query nearly free on the filter side
    repeat = engine.query(q, k, backend="dense-ref")
    print(
        f"\nrepeat query (scene cache hit): filter={repeat.t_filter_s*1e3:.2f}ms  "
        f"cache hits={engine.scene_cache.hits}"
    )

    # monochromatic variant (paper §2.1): facilities querying facilities
    mono = engine.query_mono(q, k)
    print(f"monochromatic RkNN of facility #{q}: {mono.mask.sum()} results")
    print("\nAll backends agree with the exact oracle — Lemma 3.4 in action.")


if __name__ == "__main__":
    main()
