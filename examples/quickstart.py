"""Quickstart: one RkNN query end-to-end, every backend, verified exact.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import rt_rknn_query, rknn_mono_query
from repro.core.brute import rknn_brute_np
from repro.data.spatial import facility_user_split, road_network_points


def main() -> None:
    # a road-network-like city: 100k points, 1000 facilities, rest users
    points = road_network_points(100_000, seed=7)
    facilities, users = facility_user_split(points, 1_000, seed=7)
    q, k = 42, 10

    print(f"|F|={len(facilities)}  |U|={len(users)}  query=facility#{q}  k={k}\n")

    truth = rknn_brute_np(users, facilities, q, k)
    for backend in ("dense", "dense-ref", "grid", "bvh", "brute"):
        res = rt_rknn_query(facilities, users, q, k, backend=backend)
        ok = np.array_equal(res.mask, truth)
        extra = ""
        if res.scene is not None:
            extra = (f"  occluders={res.scene.n_occluders}/{len(facilities)} "
                     f"(InfZone-style pruning)")
        print(
            f"{backend:10s}  |RkNN|={res.mask.sum():5d}  exact={ok}  "
            f"filter={res.t_filter_s*1e3:7.1f}ms  verify={res.t_verify_s*1e3:7.1f}ms{extra}"
        )
        assert ok, backend

    # monochromatic variant (paper §2.1): facilities querying facilities
    mono = rknn_mono_query(facilities, q, k)
    print(f"\nmonochromatic RkNN of facility #{q}: {mono.mask.sum()} results")
    print("\nAll backends agree with the exact oracle — Lemma 3.4 in action.")


if __name__ == "__main__":
    main()
