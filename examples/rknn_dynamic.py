"""Dynamic-data walkthrough: updates, scene refit, continuous RkNN.

Builds a :class:`repro.dynamic.DynamicEngine`, streams user drift and
facility churn through it, and shows the three things the subsystem
buys over rebuilding from scratch:

1. versioned snapshots — ``apply_updates`` returns per-update reports of
   what survived, was refit, or dropped;
2. scene-cache survival under churn (the filter phase collapses on
   repeat queries even as the data moves);
3. continuous queries — standing RkNN handles that re-evaluate only when
   an update can change them, streaming ``(version, result)`` events.

Every step is verified against a cold engine built from the same
snapshot.

    PYTHONPATH=src python examples/rknn_dynamic.py [--users 20000]
"""

import argparse
import time

import numpy as np

from repro.core import RkNNConfig, RkNNEngine
from repro.data.spatial import facility_user_split, road_network_points
from repro.dynamic import DynamicEngine, UpdateBatch
from repro.workloads import drifting_users, facility_churn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=20_000)
    ap.add_argument("--facilities", type=int, default=400)
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--steps", type=int, default=4)
    args = ap.parse_args()

    pts = road_network_points(args.users + args.facilities, seed=7)
    F, U = facility_user_split(pts, args.facilities, seed=7)
    rng = np.random.default_rng(0)
    qs = [int(q) for q in rng.integers(0, len(F), args.queries)]

    engine = DynamicEngine(F, U, RkNNConfig(backend="grid"))
    engine.query_batch(qs, args.k)  # warm: jit + scene cache
    handle = engine.register_continuous(qs[0], args.k)
    print(f"engine v{engine.version}: |F|={len(F)} |U|={len(U)} Q={len(qs)}")

    stream = drifting_users(U, steps=args.steps, frac=0.02, seed=1) + facility_churn(
        F, steps=1, rate=0.01, seed=2, protect=np.asarray(qs)
    )
    for batch in stream:
        rep = engine.apply_updates(batch)
        t0 = time.perf_counter()
        res = engine.query_batch(qs, args.k)
        t_q = time.perf_counter() - t0
        kind = "users" if batch.touches_users else "facilities"
        print(
            f"v{rep.version} [{kind:10s}] update={rep.t_update_s*1e3:6.1f}ms "
            f"query={t_q*1e3:6.1f}ms scenes: survived={rep.scenes_survived} "
            f"refit={rep.scenes_refit} dropped={rep.scenes_dropped} "
            f"scatter={rep.users_scattered}"
        )
        # verify against a cold engine built from the final snapshot
        cold = RkNNEngine(
            engine.facilities, engine.users, RkNNConfig(backend="grid")
        )
        assert np.array_equal(res.masks, cold.query_batch(qs, args.k).masks)

    events = handle.poll()
    # deletions shift rows: the handle tracks its facility through the
    # remap, so the cold comparison must use handle.q_idx, not the old id
    exact = np.array_equal(handle.mask, cold.query(handle.q_idx, args.k).mask)
    print(
        f"continuous q={qs[0]}->{handle.q_idx}: {len(events)} change event(s), "
        f"{handle.n_skipped} update(s) skipped outside the influence zone; "
        f"exact vs cold: {exact}"
    )
    assert exact
    st = engine.update_stats
    print(
        f"totals over {st.n_updates} updates: survived={st.scenes_survived} "
        f"refit={st.scenes_refit} dropped={st.scenes_dropped} "
        f"scatters={st.user_scatters} update_time={st.t_update_s*1e3:.0f}ms"
    )
    print("all steps verified against cold rebuilds: OK")


if __name__ == "__main__":
    main()
