"""End-to-end driver: serve batched RkNN queries against a user fleet.

The paper's deployment story (docs/API.md): users uploaded once, scenes
built per query on the host (double-buffered by ``RkNNEngine.stream``
against the device dispatch of the previous batch), and the ray-cast
executed as one batched device step.  Run with more hosts/devices and pass
a mesh — the same code shards users over the data axes and queries over
``'model'``.

    PYTHONPATH=src python examples/rknn_serving.py [--users 500000] [--queries 64]
"""

import argparse
import time

import numpy as np

from repro.core import RkNNConfig, RkNNEngine
from repro.core.brute import rknn_brute_np
from repro.data.spatial import facility_user_split, road_network_points


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=200_000)
    ap.add_argument("--facilities", type=int, default=1_000)
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    pts = road_network_points(args.users + args.facilities, seed=3)
    F, U = facility_user_split(pts, args.facilities, seed=3)

    t0 = time.perf_counter()
    # "plain GPU transfer" of Table 2 + scene cache for hot facilities
    engine = RkNNEngine(F, U, RkNNConfig(scene_cache=256))
    engine.xs  # materialize the device upload inside the timed window
    t_up = time.perf_counter() - t0
    print(f"user upload (+engine wiring): {t_up*1e3:.1f} ms for |U|={len(U)}")

    rng = np.random.default_rng(0)
    queries = rng.integers(0, len(F), args.queries)
    batches = [queries[i : i + args.batch] for i in range(0, len(queries), args.batch)]

    t0 = time.perf_counter()
    n_results = 0
    masks_by_query = {}
    for qbatch, masks in engine.stream(batches, args.k):
        n_results += int(masks.sum())
        for qi, m in zip(qbatch, masks):
            masks_by_query[int(qi)] = m
    wall = time.perf_counter() - t0

    s = engine.stats
    print(
        f"served {s.n_queries} queries in {wall*1e3:.1f} ms "
        f"({wall/s.n_queries*1e3:.2f} ms/query) — "
        f"scene(host,overlapped)={s.t_filter_s*1e3:.0f}ms "
        f"raycast(device)={s.t_verify_s*1e3:.0f}ms  max_occluders={s.m_max}"
    )
    print(f"total influence-set size: {n_results}")

    # spot-verify three queries against the exact oracle
    for qi in list(masks_by_query)[:3]:
        truth = rknn_brute_np(U, F, qi, args.k)
        assert np.array_equal(masks_by_query[qi], truth), qi
    print("spot-checked 3 queries against the exact oracle: OK")


if __name__ == "__main__":
    main()
