"""Store tooling: ``python -m repro.persist --inspect|--verify <dir>``.

``--inspect`` prints the manifest schema version, categories with their
fingerprints and sizes, and staleness of each category against a live
engine rebuilt from the store's own dataset (a hardware/code change
shows up here as a stale planner/kernel category before any restore is
attempted).

``--verify`` round-trips the store: builds a cold engine from the stored
dataset + config, a warm engine through ``warm_store=<dir>``, replays
the stored scene-cache queries on both, and diffs masks/counts.  Exit
code 0 only on bit-identity.
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if n < 1024 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1024
    return f"{n:.1f}GiB"


def _cat_bytes(entry: dict) -> int:
    return sum(
        int(np.prod(a["shape"])) * np.dtype(a["dtype"]).itemsize
        for a in entry.get("arrays", {}).values()
    )


def _engine_from_store(manifest: dict, folder: str, warm_store: str | None = None):
    from repro.checkpoint.store import load_arrays
    from repro.core.engine import RkNNConfig, RkNNEngine
    from repro.core.geometry import Rect

    extra = manifest.get("extra", {}).get("engine", {})
    dcat = manifest["categories"]["dataset"]
    data = load_arrays(folder, dcat)
    cfg = dict(extra.get("config", {}))
    cfg.pop("warm_store", None)
    cfg["warm_store"] = warm_store
    # flight/obs side-effects are irrelevant to a verification build
    cfg["flight_recorder"] = False
    kwargs = {}
    if dcat.get("meta", {}).get("explicit_rect"):
        kwargs["rect"] = Rect(*(float(v) for v in dcat["meta"]["rect"]))
    cls_name = extra.get("class", "RkNNEngine")
    if cls_name == "ShardedEngine":
        from repro.shard.engine import ShardedEngine

        return ShardedEngine(
            data["facilities"],
            data["users"],
            RkNNConfig(**cfg),
            n_shards=int(extra.get("n_shards", 1)),
            **kwargs,
        )
    if cls_name == "DynamicEngine":
        from repro.dynamic.engine import DynamicEngine

        return DynamicEngine(
            data["facilities"], data["users"], RkNNConfig(**cfg), **kwargs
        )
    return RkNNEngine(data["facilities"], data["users"], RkNNConfig(**cfg), **kwargs)


def _stored_queries(manifest: dict) -> list[tuple[object, int]]:
    """The (q, k) pairs the store's scene cache actually holds — the
    exact working set a warm restore claims to make free."""
    ents = manifest.get("categories", {}).get("scenes", {}).get("meta", {})
    out = []
    for ent in ents.get("entries", []):
        qk = ent["q_key"]
        q = int(qk) if isinstance(qk, int) else np.asarray(qk, np.float64)
        out.append((q, int(ent["k"])))
    return out


def inspect(directory: str, step: int | None) -> int:
    from repro.checkpoint.store import load_state
    from repro.persist.store import expected_fingerprints

    try:
        manifest, folder = load_state(directory, step)
    except (FileNotFoundError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    print(f"store:  {directory}")
    print(f"schema: {manifest.get('schema')}")
    print(f"step:   {manifest.get('step')}")
    extra = manifest.get("extra", {}).get("engine", {})
    if extra:
        print(
            f"engine: {extra.get('class')} backend="
            f"{extra.get('config', {}).get('backend')} "
            f"shards={extra.get('n_shards', 1)}"
        )
    live = {}
    try:
        eng = _engine_from_store(manifest, folder)
        live = expected_fingerprints(eng, eng._snap)
    except Exception as e:
        print(f"(live fingerprint check unavailable: {type(e).__name__}: {e})")
    print(f"{'category':<10} {'fingerprint':<18} {'arrays':>6} {'size':>10}  staleness")
    for name, entry in manifest.get("categories", {}).items():
        fp = entry.get("fingerprint", "")
        if not live:
            state = "?"
        elif live.get(name) == fp:
            state = "fresh"
        elif name in live:
            state = f"STALE (live {live[name]})"
        else:
            state = "unknown category"
        print(
            f"{name:<10} {fp:<18} {len(entry.get('arrays', {})):>6} "
            f"{_fmt_bytes(_cat_bytes(entry)):>10}  {state}"
        )
    return 0


def verify(directory: str, step: int | None) -> int:
    from repro.checkpoint.store import load_state

    try:
        manifest, folder = load_state(directory, step)
    except (FileNotFoundError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    queries = _stored_queries(manifest)
    if not queries:
        n = int(
            manifest["categories"]["dataset"]["meta"].get("n_facilities", 0)
        )
        queries = [(q, 8) for q in range(min(4, n))]
    print(f"verify: replaying {len(queries)} stored queries cold vs warm")
    cold = _engine_from_store(manifest, folder)
    warm = _engine_from_store(manifest, folder, warm_store=directory)
    restored = {
        name: st.get("status")
        for name, st in warm.persist_info.get("categories", {}).items()
    }
    print(f"warm restore: {restored}")
    bad = 0
    for q, k in queries:
        rc = cold.query(q, k)
        rw = warm.query(q, k)
        ok = bool(
            np.array_equal(np.asarray(rc.mask), np.asarray(rw.mask))
            and np.array_equal(np.asarray(rc.counts), np.asarray(rw.counts))
        )
        if not ok:
            bad += 1
            d = int(np.sum(np.asarray(rc.mask) != np.asarray(rw.mask)))
            print(f"  MISMATCH q={q} k={k}: {d} mask rows differ")
    if bad:
        print(f"FAIL: {bad}/{len(queries)} queries diverge from cold build")
        return 1
    print(f"OK: {len(queries)} queries bit-identical (masks and counts)")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.persist", description=__doc__)
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--inspect", metavar="DIR", help="print manifest + staleness")
    g.add_argument("--verify", metavar="DIR", help="round-trip and diff vs cold build")
    ap.add_argument("--step", type=int, default=None, help="store step (default newest)")
    ap.add_argument("--json", action="store_true", help="inspect: dump raw manifest JSON")
    args = ap.parse_args(argv)
    if args.inspect:
        if args.json:
            from repro.checkpoint.store import load_state

            manifest, _ = load_state(args.inspect, args.step)
            json.dump(manifest, sys.stdout, indent=2, default=str)
            print()
            return 0
        return inspect(args.inspect, args.step)
    return verify(args.verify, args.step)


if __name__ == "__main__":
    sys.exit(main())
