"""Versioned persistent engine state: the ``rknn-store/1`` codec.

A process restart used to throw away exactly the amortized state the
serving layers spend their lifetime accumulating: built scenes (InfZone
pruning + occluder construction), grid/BVH indexes with their packed
per-cell coefficient planes, the grid-pallas user cell bucketing, the
shard partition, and the calibrated planner profile.  This module gives
all of it a canonical serializable form and persists it through the
atomic-rename manifest machinery in :mod:`repro.checkpoint.store`.

Store layout (one ``step_<N>`` folder per save, newest complete wins)::

    <dir>/step_<N>/manifest.json        # schema, per-category fingerprints
    <dir>/step_<N>/<category>__<k>.npy  # array leaves

Categories and their **content fingerprints** (hashlib digests — the
in-process ``SceneCache.fingerprint`` uses salted ``hash()`` and is NOT
stable across processes, so it never appears in a manifest):

=========  ============================================================
dataset    facilities/users/rect.  fp(facilities, users, rect).
scenes     the SceneCache entries keyed under the snapshot's own
           fingerprint+rect, stored unpadded and re-padded/re-keyed on
           restore.  fp(facilities, rect, strategy, prune_grid).
indexes    per-scene backend index state via ``Backend.export_state``,
           deduplicated across registry entries that share one object
           (the grid family).  fp(scenes fp + grid_g).
kernel     the grid-pallas user cell bucketing (sorted coords, ranks,
           occupied cells).  fp(users, rect, grid_g).
shards     the spatial user partition (perm/pos/bounds); device views
           are re-``device_put`` on restore.  fp(users, rect, grid_g,
           n_shards).  ShardedEngine only.
planner    the active profile's versioned JSON (the existing
           ``planner/profiles.py`` schema — not a second format) plus
           its epoch.  fp(runner_class, PROFILE_VERSION).
=========  ============================================================

A mismatch invalidates only the stale category: a user-set change moves
the hull rect and so invalidates scenes/indexes/kernel/shards, while the
planner profile (hardware-keyed, data-independent) survives; a
hardware-class change invalidates only the planner.

Single-writer contract: concurrent :func:`save_engine_state` calls into
one directory are last-writer-wins per step number (each save is atomic
via rename); readers always see a complete step.  Restoring publishes a
new MVCC snapshot version through the engine's existing atomic swap, so
a *live* engine can hot-adopt a store without blocking readers.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os

import numpy as np

from repro.checkpoint.store import (
    latest_step,
    load_arrays,
    load_state,
    save_state,
)
from repro.core.geometry import Rect
from repro.core.pruning import PruneStats
from repro.core.scene import Scene, pad_scene_arrays

__all__ = [
    "SCHEMA",
    "content_digest",
    "expected_fingerprints",
    "export_categories",
    "save_engine_state",
    "warm_start",
    "restore_engine",
    "adopt_categories",
]

SCHEMA = "rknn-store/1"


# --------------------------------------------------------------------------
# content fingerprints (cross-process stable, unlike salted hash())
# --------------------------------------------------------------------------


def content_digest(*parts) -> str:
    """Stable short digest over arrays and JSON-able scalars."""
    h = hashlib.sha256()
    for p in parts:
        if isinstance(p, np.ndarray):
            a = np.ascontiguousarray(p)
            h.update(str(a.shape).encode())
            h.update(str(a.dtype).encode())
            h.update(a.tobytes())
        else:
            h.update(repr(p).encode())
        h.update(b"|")
    return h.hexdigest()[:16]


def _rect_parts(rect: Rect | None):
    if rect is None:
        return None
    return (float(rect.xmin), float(rect.ymin), float(rect.xmax), float(rect.ymax))


def expected_fingerprints(engine, snap) -> dict[str, str]:
    """What each category's fingerprint *should* be for this live engine
    — the restore path adopts a stored category only on an exact match,
    so a data/hardware/code change invalidates per category."""
    from repro.planner.profiles import PROFILE_VERSION, runner_class

    cfg = engine.config
    rect = _rect_parts(snap.rect)
    out = {
        "dataset": content_digest(
            "dataset", snap.facilities, snap.users, rect, snap.explicit_rect
        ),
        "scenes": content_digest(
            "scenes", snap.facilities, rect, cfg.strategy, cfg.prune_grid
        ),
        "indexes": content_digest(
            "indexes", snap.facilities, rect, cfg.strategy, cfg.prune_grid,
            int(cfg.grid_g),
        ),
        "kernel": content_digest("kernel", snap.users, rect, int(cfg.grid_g)),
        "planner": content_digest("planner", runner_class(), PROFILE_VERSION),
    }
    out.update(engine._persist_extra_fingerprints(snap))
    return out


# --------------------------------------------------------------------------
# export: engine snapshot -> named categories
# --------------------------------------------------------------------------


def _q_key_json(qk):
    return int(qk) if isinstance(qk, (int, np.integer)) else list(qk)


def _q_key_load(qk):
    if isinstance(qk, (int, np.integer)):
        return int(qk)
    return tuple(float(v) for v in qk)


def _export_dataset(engine, snap) -> tuple[dict, dict]:
    arrays = {"facilities": snap.facilities, "users": snap.users}
    meta = {
        "explicit_rect": bool(snap.explicit_rect),
        "rect": list(_rect_parts(snap.rect)),
        "n_facilities": int(len(snap.facilities)),
        "n_users": int(len(snap.users)),
    }
    return arrays, meta


def _persistable_scenes(snap) -> list[tuple[tuple, Scene]]:
    """The cache entries that belong to this snapshot: keyed under its
    own facility fingerprint and its shared rect (transient out-of-hull
    rects are per-call state, not engine state)."""
    sc = snap.scene_cache
    if sc is None:
        return []
    fp = snap.fingerprint()
    rect = snap.rect
    return [
        (key, scene)
        for key, scene in sc.items()
        if key[0] == fp and key[3] == rect
    ]


def _export_scenes(entries: list[tuple[tuple, Scene]]) -> tuple[dict, dict]:
    scenes = [scene for _key, scene in entries]
    offsets = np.zeros(len(scenes) + 1, np.int64)
    for i, s in enumerate(scenes):
        offsets[i + 1] = offsets[i] + s.n_tris
    t = int(offsets[-1])
    tris = np.zeros((t, 3, 2), np.float32)
    coeffs = np.zeros((t, 3, 3), np.float32)
    owner = np.zeros((t,), np.int32)
    for i, s in enumerate(scenes):
        sl = slice(int(offsets[i]), int(offsets[i + 1]))
        tris[sl] = s.tris[: s.n_tris]
        coeffs[sl] = s.coeffs[: s.n_tris]
        owner[sl] = s.owner[: s.n_tris]
    arrays = {
        "offsets": offsets,
        "tris": tris,
        "coeffs": coeffs,
        "owner": owner,
        "keep": np.stack([s.keep for s in scenes]) if scenes else np.zeros((0, 0), bool),
        "q": np.stack([np.asarray(s.q, np.float64) for s in scenes])
        if scenes
        else np.zeros((0, 2), np.float64),
    }
    meta = {
        "entries": [
            {
                "q_key": _q_key_json(key[1]),
                "k": int(key[2]),
                "n_occluders": int(scene.n_occluders),
                "stats": dataclasses.asdict(scene.stats),
            }
            for key, scene in entries
        ]
    }
    return arrays, meta


def _export_indexes(engine, snap, entries) -> tuple[dict, dict]:
    """Per-scene index stores, deduplicated: registry entries that share
    one built object (grid / grid-pallas / grid-pallas-ref share their
    ``OccluderGrid``) reference one serialized object."""
    from repro.core.backends import available_backends, get_backend

    arrays: dict = {}
    objects: list[dict] = []
    obj_of: dict[int, int] = {}  # id(index) -> object slot (-2 = unserializable)
    scene_keys: list[list] = []
    names = set(available_backends())
    for _key, scene in entries:
        store = snap.index_memo.peek(scene)
        keys = []
        for skey, index in (store or {}).items():
            if not (isinstance(skey, tuple) and len(skey) == 2 and skey[0] in names):
                continue
            bname, g = skey
            if index is None:
                keys.append([bname, int(g), -1])
                continue
            slot = obj_of.get(id(index))
            if slot is None:
                exported = get_backend(bname).export_state(index)
                if exported is None:
                    slot = -2
                else:
                    kind, obj_arrays, obj_meta = exported
                    slot = len(objects)
                    prefix = f"obj{slot}_"
                    objects.append(
                        {
                            "kind": kind,
                            "backend": bname,
                            "meta": obj_meta,
                            "array_keys": [prefix + a for a in obj_arrays],
                        }
                    )
                    for aname, arr in obj_arrays.items():
                        arrays[prefix + aname] = arr
                obj_of[id(index)] = slot
            if slot >= 0:
                keys.append([bname, int(g), slot])
            elif slot == -1:
                keys.append([bname, int(g), -1])
        scene_keys.append(keys)
    return arrays, {"objects": objects, "scene_keys": scene_keys}


def _export_kernel(snap) -> tuple[dict, dict]:
    """The grid-pallas cell bucketing memo entries pinned to this
    snapshot's own user array (identity-keyed; re-keyed on restore under
    the new process's array identity)."""
    arrays: dict = {}
    metas = []
    xs_live = snap._xs
    if xs_live is not None:
        for key, value in snap.kernel_memo.items():
            if not (isinstance(key, tuple) and key and key[0] == "gp-buckets"):
                continue
            if value[0] is not xs_live or key[3] != snap.rect:
                continue
            xs_s, ys_s, order, ranks, occ, block = value[1]
            i = len(metas)
            arrays[f"b{i}_xs_s"] = np.asarray(xs_s, np.float32)
            arrays[f"b{i}_ys_s"] = np.asarray(ys_s, np.float32)
            arrays[f"b{i}_order"] = np.asarray(order)
            arrays[f"b{i}_ranks"] = np.asarray(ranks, np.int32)
            arrays[f"b{i}_occ"] = np.asarray(occ)
            metas.append({"n": int(key[2]), "G": int(key[4]), "block": int(block)})
    return arrays, {"entries": metas}


def _export_planner() -> tuple[dict, dict] | None:
    from repro.planner.profiles import get_active_profile, profile_epoch

    prof = get_active_profile()
    if prof is None:
        return None
    return {}, {"profile": prof.to_json(), "epoch": int(profile_epoch())}


def export_categories(engine, snap) -> dict:
    """``{name: {"fingerprint", "meta", "arrays"}}`` for everything this
    engine can persist (empty/disabled layers are simply omitted)."""
    from repro.obs import span

    fps = expected_fingerprints(engine, snap)
    out: dict = {}

    with span("save", category="dataset"):
        arrays, meta = _export_dataset(engine, snap)
        out["dataset"] = {
            "fingerprint": fps["dataset"], "meta": meta, "arrays": arrays
        }

    entries = _persistable_scenes(snap)
    if entries:
        with span("save", category="scenes"):
            arrays, meta = _export_scenes(entries)
            out["scenes"] = {
                "fingerprint": fps["scenes"], "meta": meta, "arrays": arrays
            }
        with span("save", category="indexes"):
            arrays, meta = _export_indexes(engine, snap, entries)
        if meta["objects"] or any(meta["scene_keys"]):
            out["indexes"] = {
                "fingerprint": fps["indexes"], "meta": meta, "arrays": arrays
            }

    with span("save", category="kernel"):
        arrays, meta = _export_kernel(snap)
    if meta["entries"]:
        out["kernel"] = {"fingerprint": fps["kernel"], "meta": meta, "arrays": arrays}

    planner = _export_planner()
    if planner is not None:
        arrays, meta = planner
        out["planner"] = {
            "fingerprint": fps["planner"], "meta": meta, "arrays": arrays
        }

    for name, cat in engine._persist_extra_categories(snap).items():
        cat.setdefault("fingerprint", fps.get(name, ""))
        out[name] = cat
    return out


# --------------------------------------------------------------------------
# adopt: stored categories -> a live snapshot
# --------------------------------------------------------------------------


def _adopt_scenes(engine, snap, meta, arrays) -> list[Scene]:
    """Re-pad and re-key stored scenes into the snapshot's cache.  The
    restored arrays are the exact float32 arrays a cold build produces
    (stored post-cast, unpadded; the pad rule and heights are recomputed
    the same way ``build_scene`` does), so restored queries are
    bit-identical to cold ones."""
    sc = snap.scene_cache
    if sc is None:
        return []
    fp = snap.fingerprint()
    rect = snap.rect
    offsets = arrays["offsets"]
    restored = []
    for i, ent in enumerate(meta["entries"]):
        sl = slice(int(offsets[i]), int(offsets[i + 1]))
        tris_p, coeffs_p, owner_p, n = pad_scene_arrays(
            arrays["tris"][sl], arrays["coeffs"][sl], arrays["owner"][sl], None
        )
        heights = np.zeros((len(tris_p),), np.float32)
        heights[:n] = np.arange(1, n + 1, dtype=np.float32)
        scene = Scene(
            tris=tris_p,
            coeffs=coeffs_p,
            owner=owner_p,
            n_tris=n,
            n_occluders=int(ent["n_occluders"]),
            keep=np.ascontiguousarray(arrays["keep"][i], bool),
            q=np.ascontiguousarray(arrays["q"][i], np.float64),
            rect=rect,
            heights=heights,
            stats=PruneStats(**ent["stats"]),
        )
        sc.seed((fp, _q_key_load(ent["q_key"]), int(ent["k"]), rect), scene)
        restored.append(scene)
    return restored


def _adopt_indexes(engine, snap, meta, arrays, scenes: list[Scene]) -> int:
    from repro.core.backends import available_backends, get_backend

    names = set(available_backends())
    objects: list = []
    for slot, obj in enumerate(meta["objects"]):
        if obj["backend"] not in names:
            objects.append(None)
            continue
        prefix = f"obj{slot}_"
        try:
            objects.append(
                get_backend(obj["backend"]).import_state(
                    obj["kind"],
                    {k[len(prefix):]: arrays[k] for k in obj["array_keys"]},
                    obj["meta"],
                )
            )
        except (ValueError, KeyError):
            objects.append(None)
    adopted = 0
    for scene, keys in zip(scenes, meta["scene_keys"]):
        store: dict = {}
        for bname, g, slot in keys:
            if slot == -1:
                store[(bname, int(g))] = None
            elif 0 <= slot < len(objects) and objects[slot] is not None:
                store[(bname, int(g))] = objects[slot]
        if store:
            # the grid family's build memo key rides along so a restored
            # grid is shared exactly like a cold-built one
            for key in list(store):
                if store[key] is not None and key[0] in (
                    "grid", "grid-pallas", "grid-pallas-ref"
                ):
                    store.setdefault(("grid", int(key[1])), store[key])
            snap.index_memo.adopt(scene, store)
            adopted += 1
    return adopted


def _adopt_kernel(engine, snap, meta, arrays) -> int:
    import jax.numpy as jnp

    xs = snap.xs  # materializes the live device arrays the key pins
    n_adopted = 0
    for i, ent in enumerate(meta["entries"]):
        if int(ent["n"]) != int(xs.shape[0]):
            continue
        buckets = (
            jnp.asarray(arrays[f"b{i}_xs_s"]),
            jnp.asarray(arrays[f"b{i}_ys_s"]),
            np.ascontiguousarray(arrays[f"b{i}_order"]),
            np.ascontiguousarray(arrays[f"b{i}_ranks"], np.int32),
            np.ascontiguousarray(arrays[f"b{i}_occ"]),
            int(ent["block"]),
        )
        key = ("gp-buckets", id(xs), int(ent["n"]), snap.rect, int(ent["G"]))
        snap.kernel_memo.put(key, (xs, buckets))
        n_adopted += 1
    return n_adopted


def _adopt_planner(meta) -> str:
    from repro.planner.profiles import (
        PlannerProfile,
        get_active_profile,
        set_active_profile,
    )

    if get_active_profile() is not None:
        return "skipped"  # never clobber an operator-installed profile
    set_active_profile(PlannerProfile.from_json(meta["profile"]))
    return "restored"


def adopt_categories(engine, snap, manifest: dict, folder: str) -> dict:
    """Adopt every fingerprint-matching category from a loaded store into
    ``snap`` (which must not be published to readers yet, or be freshly
    constructed — adoption appends to the snapshot's caches in the same
    way a cold query would).  Returns per-category status records."""
    import time as _time

    from repro.obs import span

    fps = expected_fingerprints(engine, snap)
    cats = manifest.get("categories", {})
    status: dict = {}
    restored_scenes: list[Scene] = []
    order = ["dataset", "scenes", "indexes", "kernel", "planner"]
    order += [n for n in cats if n not in order]
    for name in order:
        entry = cats.get(name)
        if entry is None:
            status[name] = {"status": "absent"}
            continue
        nbytes = sum(
            int(np.prod(a["shape"])) * np.dtype(a["dtype"]).itemsize
            for a in entry.get("arrays", {}).values()
        )
        if fps.get(name) != entry.get("fingerprint"):
            status[name] = {"status": "stale", "bytes": nbytes}
            continue
        t0 = _time.perf_counter()
        try:
            with span("restore", category=name):
                arrays = load_arrays(folder, entry)
                if name == "dataset":
                    items = 2  # the arrays themselves; validated by fingerprint
                elif name == "scenes":
                    restored_scenes = _adopt_scenes(
                        engine, snap, entry["meta"], arrays
                    )
                    items = len(restored_scenes)
                elif name == "indexes":
                    items = _adopt_indexes(
                        engine, snap, entry["meta"], arrays, restored_scenes
                    )
                elif name == "kernel":
                    items = _adopt_kernel(engine, snap, entry["meta"], arrays)
                elif name == "planner":
                    state = _adopt_planner(entry["meta"])
                    status[name] = {
                        "status": state,
                        "bytes": nbytes,
                        "seconds": _time.perf_counter() - t0,
                    }
                    continue
                else:
                    state = engine._persist_adopt_extra(snap, name, entry, arrays)
                    if state is None:
                        status[name] = {"status": "ignored", "bytes": nbytes}
                        continue
                    items = int(state)
        except Exception as e:  # one broken category must not sink the rest
            status[name] = {"status": "error", "error": f"{type(e).__name__}: {e}"}
            continue
        dt = _time.perf_counter() - t0
        status[name] = {
            "status": "restored", "bytes": nbytes, "seconds": dt, "items": items
        }
        engine._persist_note("restore", name, nbytes, dt)
    return status


# --------------------------------------------------------------------------
# engine-level orchestration
# --------------------------------------------------------------------------


def _engine_extra(engine) -> dict:
    cfg = dataclasses.asdict(engine.config)
    cfg.pop("warm_store", None)  # a store never points at itself
    return {
        "engine": {
            "class": type(engine).__name__,
            "config": cfg,
            "n_shards": int(getattr(engine, "n_shards", 1)),
        }
    }


def save_engine_state(engine, directory: str, *, keep: int = 3) -> str:
    """Export the engine's served snapshot as the next store step.
    Returns the published step folder path."""
    import time as _time

    from repro.obs import span

    snap = engine._snap  # resolved once, like a reader
    t0 = _time.perf_counter()
    with span("save", category="all"):
        categories = export_categories(engine, snap)
        last = latest_step(directory)
        step = 0 if last is None else last + 1
        path = save_state(
            directory,
            step,
            categories,
            schema=SCHEMA,
            keep=keep,
            extra=_engine_extra(engine),
        )
    dt = _time.perf_counter() - t0
    cat_status = {}
    for name, cat in categories.items():
        nbytes = sum(np.asarray(a).nbytes for a in cat["arrays"].values())
        engine._persist_note("save", name, nbytes, None)
        cat_status[name] = {"status": "saved", "bytes": nbytes}
    engine.persist_info = {
        "store": os.path.abspath(directory),
        "schema": SCHEMA,
        "step": step,
        "mode": "save",
        "seconds": dt,
        "categories": cat_status,
    }
    return path


def warm_start(engine, directory: str) -> dict:
    """Construction-time warm restore (``RkNNConfig(warm_store=...)``):
    adopt every fingerprint-matching category into the freshly built
    version-0 snapshot in place.  Best-effort — a missing, foreign, or
    stale store leaves a fully functional cold engine."""
    try:
        manifest, folder = load_state(directory, schema=SCHEMA)
    except (FileNotFoundError, ValueError, OSError) as e:
        engine.persist_info = {
            "store": os.path.abspath(directory),
            "schema": None,
            "mode": "warm-construct",
            "error": f"{type(e).__name__}: {e}",
            "categories": {},
        }
        return engine.persist_info
    status = adopt_categories(engine, engine._snap, manifest, folder)
    engine.persist_info = {
        "store": os.path.abspath(directory),
        "schema": manifest.get("schema"),
        "step": manifest.get("step"),
        "mode": "warm-construct",
        "categories": status,
    }
    return engine.persist_info


def restore_engine(engine, directory: str) -> dict:
    """Hot-adopt a store into a LIVE engine: build snapshot N+1 around
    the store's dataset, adopt every matching category, publish via the
    engine's atomic swap (under the writer lock where one exists).
    In-flight readers keep serving version N throughout."""
    import contextlib

    manifest, folder = load_state(directory, schema=SCHEMA)
    cats = manifest.get("categories", {})
    if "dataset" not in cats:
        raise ValueError(f"store under {directory} has no dataset category")
    lock = getattr(engine, "_writer_lock", None)
    with (lock if lock is not None else contextlib.nullcontext()):
        old = engine._snap
        data = load_arrays(folder, cats["dataset"])
        dmeta = cats["dataset"].get("meta", {})
        explicit = bool(dmeta.get("explicit_rect"))
        rect = Rect(*(float(v) for v in dmeta["rect"])) if explicit else None
        snap = engine._make_snapshot(
            old.version + 1,
            np.ascontiguousarray(data["facilities"], np.float64),
            np.ascontiguousarray(data["users"], np.float64),
            rect=rect,
            explicit_rect=explicit,
        )
        status = adopt_categories(engine, snap, manifest, folder)
        if engine.mesh is not None:
            engine._init_mesh(snap, engine.mesh)
        engine._snap = snap  # the MVCC publish — readers flip atomically
    engine.persist_info = {
        "store": os.path.abspath(directory),
        "schema": manifest.get("schema"),
        "step": manifest.get("step"),
        "mode": "hot-adopt",
        "version": snap.version,
        "categories": status,
    }
    return engine.persist_info
