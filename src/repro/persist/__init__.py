"""Versioned persistent engine state (``rknn-store/1``).

Zero-cold-start serving: :func:`save_engine_state` exports the expensive
amortized state every layer accumulates (scenes, packed indexes, kernel
bucketing, shard partition, planner profile) through the atomic-rename
checkpoint machinery; :func:`warm_start` / :func:`restore_engine` bring
it back — at construction via ``RkNNConfig(warm_store=...)``, or into a
live engine as MVCC version N+1.

CLI: ``python -m repro.persist --inspect <dir>`` / ``--verify <dir>``.
"""

from repro.persist.store import (
    SCHEMA,
    adopt_categories,
    content_digest,
    expected_fingerprints,
    export_categories,
    restore_engine,
    save_engine_state,
    warm_start,
)

__all__ = [
    "SCHEMA",
    "adopt_categories",
    "content_digest",
    "expected_fingerprints",
    "export_categories",
    "restore_engine",
    "save_engine_state",
    "warm_start",
]
