"""Cross-entropy loss over (possibly vocab-sharded) logits.

Computed in fp32 with the max-shifted logsumexp; under the production mesh
the vocab axis is sharded over ``'model'`` so the reductions lower to
per-shard partials + a small all-reduce (visible in the collective
roofline term).  ``z_loss`` stabilises the softmax normaliser at scale
(PaLM-style) and is on by default with a tiny coefficient.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["softmax_xent"]


def softmax_xent(logits, labels, *, z_loss_coeff: float = 1e-4, mask=None):
    """logits: [B, S, V] (any float dtype); labels: [B, S] int32.

    Returns (mean loss, metrics dict).
    """
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]  # [B, S]
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = lse - gold
    zl = z_loss_coeff * jnp.square(lse)
    per_tok = nll + zl
    if mask is not None:
        denom = jnp.maximum(mask.sum(), 1.0)
        loss = jnp.sum(per_tok * mask) / denom
        acc_raw = (logits.argmax(-1) == labels) * mask
        acc = acc_raw.sum() / denom
    else:
        loss = per_tok.mean()
        acc = (logits.argmax(-1) == labels).mean()
    return loss, {"nll": (nll if mask is None else nll * mask).mean(), "accuracy": acc}
