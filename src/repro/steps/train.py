"""train_step / serve steps — the jit-boundary functions the launcher lowers.

``make_train_step`` builds a pure ``(state, batch) -> (state, metrics)``
with:

* **microbatch gradient accumulation** via ``lax.scan`` (the 405B train
  cell only fits 16 GB/chip because remat liveness is bounded to one
  microbatch — DESIGN.md §9),
* fp32 master params + bf16 compute (``Policy``),
* AdamW + global-norm clipping + schedule from :mod:`repro.optim.adamw`,
* optional int8 error-feedback gradient compression hook
  (:mod:`repro.runtime.compression`) applied to the accumulated grads
  before the optimizer — the DP all-reduce then moves 4x fewer bytes.

``make_prefill_step`` / ``make_decode_step`` are the serving halves
(``serve_step`` in the brief is the decode step).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.registry import Model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.steps.loss import softmax_xent

__all__ = ["TrainState", "init_train_state", "make_train_step", "make_prefill_step", "make_decode_step"]

TrainState = dict  # {"params": ..., "opt": ..., "step": int32}


def init_train_state(model: Model, key, opt_cfg: AdamWConfig) -> TrainState:
    params = model.init(key)
    return {"params": params, "opt": adamw_init(params)}


def make_train_step(
    model: Model,
    opt_cfg: AdamWConfig,
    *,
    n_microbatches: int = 1,
    compress_grads: Callable | None = None,
):
    cfg = model.cfg

    def loss_fn(params, tokens, labels, extras):
        logits, aux = model.forward(params, tokens, extras)
        loss, metrics = softmax_xent(logits, labels)
        loss = loss + 1e-2 * aux.get("aux_loss", 0.0)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: dict):
        params = state["params"]
        tokens, labels = batch["tokens"], batch["labels"]
        extras = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
        B = tokens.shape[0]
        assert B % n_microbatches == 0, (B, n_microbatches)
        mb = B // n_microbatches

        if n_microbatches == 1:
            (loss, metrics), grads = grad_fn(params, tokens, labels, extras)
        else:
            tok_mb = tokens.reshape(n_microbatches, mb, *tokens.shape[1:])
            lab_mb = labels.reshape(n_microbatches, mb, *labels.shape[1:])
            ex_mb = {
                k: v.reshape(n_microbatches, mb, *v.shape[1:]) for k, v in extras.items()
            }

            def micro(carry, xs):
                g_acc, l_acc = carry
                t, l, ex = xs
                (loss, _), grads = grad_fn(params, t, l, ex)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / n_microbatches, g_acc, grads
                )
                return (g_acc, l_acc + loss / n_microbatches), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = lax.scan(micro, (g0, jnp.zeros((), jnp.float32)), (tok_mb, lab_mb, ex_mb))
            metrics = {}

        if compress_grads is not None:
            grads, state = compress_grads(grads, state)

        new_params, new_opt, opt_metrics = adamw_update(params, grads, state["opt"], opt_cfg)
        out_state = dict(state, params=new_params, opt=new_opt)
        m = {"loss": loss, **{k: v for k, v in metrics.items()}, **opt_metrics}
        return out_state, m

    return train_step


def make_prefill_step(model: Model, pad_cache_to: int | None = None):
    def prefill_step(params, tokens, extras):
        return model.prefill(params, tokens, extras, pad_cache_to=pad_cache_to)

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, token, cache):
        return model.decode(params, token, cache)

    return decode_step
