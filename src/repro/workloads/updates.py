"""Update-stream scenario generators for the dynamic subsystem.

The dynamic engine's refit ladder only pays off on realistic churn
shapes, so these generators produce the regimes the update benchmarks
and tests drive:

* :func:`drifting_users` — a fraction of users random-walks each step
  (the ROADMAP's "millions of users move" serving regime).  Drift is
  confined to the interior of the initial hull and hull-extreme users
  are never moved, so the shared domain rect provably survives every
  step — the precondition for scenes surviving untouched.
* :func:`facility_churn` — facilities close and open each step (delete
  + insert at a fresh location), optionally away from a protected id
  set (the standing queries).
* :func:`facility_jitter` — small in-place facility perturbations, the
  scene/BVH *refit* showcase: kept sets stay stable, only occluder fans
  move.

All streams are deterministic by seed and return plain lists of
:class:`~repro.dynamic.updates.UpdateBatch`.
"""

from __future__ import annotations

import numpy as np

from repro.dynamic.updates import UpdateBatch

__all__ = ["drifting_users", "facility_churn", "facility_jitter"]


def _interior_candidates(points: np.ndarray, lo: np.ndarray, hi: np.ndarray):
    """Rows strictly inside the hull — moving one can never shrink it."""
    pts = np.asarray(points, np.float64)
    return np.flatnonzero(np.all((pts > lo) & (pts < hi), axis=1))


def drifting_users(
    users: np.ndarray,
    *,
    steps: int,
    frac: float = 0.05,
    sigma: float = 0.01,
    seed: int = 0,
    bounds: tuple[np.ndarray, np.ndarray] | None = None,
) -> list[UpdateBatch]:
    """``steps`` hull-preserving user random-walk deltas.

    Each step moves ``frac`` of the users by Gaussian noise of scale
    ``sigma`` (in domain units), clipped strictly inside ``bounds``
    (default: the initial user hull).  The stream is stateful — step
    ``i+1`` drifts from the positions step ``i`` produced.
    """
    users = np.asarray(users, dtype=np.float64).copy()
    rng = np.random.default_rng(seed)
    if bounds is None:
        lo, hi = users.min(axis=0), users.max(axis=0)
    else:
        lo, hi = (np.asarray(b, np.float64) for b in bounds)
    pad = 1e-9 * np.maximum(hi - lo, 1.0)
    out = []
    n_move = max(int(len(users) * frac), 1)
    for _ in range(steps):
        cand = _interior_candidates(users, lo, hi)
        if not len(cand):
            out.append(UpdateBatch())
            continue
        ids = rng.choice(cand, size=min(n_move, len(cand)), replace=False)
        pts = users[ids] + rng.normal(0.0, sigma, (len(ids), 2))
        pts = np.clip(pts, lo + pad, hi - pad)
        users[ids] = pts
        out.append(UpdateBatch(user_move=(ids, pts)))
    return out


def facility_churn(
    facilities: np.ndarray,
    *,
    steps: int,
    rate: float = 0.02,
    seed: int = 0,
    protect: np.ndarray | None = None,
) -> list[UpdateBatch]:
    """``steps`` facility open/close deltas at churn ``rate`` per step.

    Each step deletes ``rate·|F|`` random unprotected facilities and
    inserts the same number uniformly inside the initial facility hull,
    keeping ``|F|`` constant.  ``protect`` rows (e.g. standing query
    facilities) are never deleted; ids are tracked across steps as
    deletions shift rows.
    """
    facilities = np.asarray(facilities, dtype=np.float64).copy()
    rng = np.random.default_rng(seed)
    lo, hi = facilities.min(axis=0), facilities.max(axis=0)
    protected = (
        np.asarray(protect, np.int64).copy() if protect is not None else np.zeros(0, np.int64)
    )
    n_churn = max(int(len(facilities) * rate), 1)
    out = []
    for _ in range(steps):
        cand = np.setdiff1d(np.arange(len(facilities)), protected)
        dele = rng.choice(cand, size=min(n_churn, len(cand)), replace=False)
        ins = rng.uniform(lo, hi, (len(dele), 2))
        out.append(UpdateBatch(facility_delete=dele, facility_insert=ins))
        alive = np.ones(len(facilities), bool)
        alive[dele] = False
        index_map = np.cumsum(alive) - 1
        protected = index_map[protected]  # protected rows survive by choice
        facilities = np.concatenate([facilities[alive], ins])
    return out


def facility_jitter(
    facilities: np.ndarray,
    *,
    steps: int,
    frac: float = 0.05,
    sigma: float = 1e-4,
    seed: int = 0,
    protect: np.ndarray | None = None,
) -> list[UpdateBatch]:
    """``steps`` small in-place facility perturbations (the refit regime).

    ``sigma`` defaults tiny relative to typical facility spacing so kept
    occluder sets stay stable and the scene-refit fast path applies; the
    moves are hull-preserving like :func:`drifting_users`.
    """
    facilities = np.asarray(facilities, dtype=np.float64).copy()
    rng = np.random.default_rng(seed)
    lo, hi = facilities.min(axis=0), facilities.max(axis=0)
    pad = 1e-9 * np.maximum(hi - lo, 1.0)
    protected = set(
        int(i) for i in (protect if protect is not None else np.zeros(0, np.int64))
    )
    n_move = max(int(len(facilities) * frac), 1)
    out = []
    for _ in range(steps):
        cand = np.array(
            [i for i in _interior_candidates(facilities, lo, hi) if i not in protected],
            np.int64,
        )
        if not len(cand):
            out.append(UpdateBatch())
            continue
        ids = rng.choice(cand, size=min(n_move, len(cand)), replace=False)
        pts = np.clip(
            facilities[ids] + rng.normal(0.0, sigma, (len(ids), 2)),
            lo + pad,
            hi - pad,
        )
        facilities[ids] = pts
        out.append(UpdateBatch(facility_move=(ids, pts)))
    return out
