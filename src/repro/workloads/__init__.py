"""Scenario workload generators for calibration and regime benchmarks."""

from repro.workloads.scenarios import (
    SCENARIOS,
    Scenario,
    Workload,
    calibration_grid,
    get_scenario,
    scenario_names,
)
from repro.workloads.updates import (
    drifting_users,
    facility_churn,
    facility_jitter,
)

__all__ = [
    "Scenario",
    "Workload",
    "SCENARIOS",
    "get_scenario",
    "scenario_names",
    "calibration_grid",
    "drifting_users",
    "facility_churn",
    "facility_jitter",
]
