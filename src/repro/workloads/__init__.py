"""Scenario workload generators for calibration and regime benchmarks."""

from repro.workloads.scenarios import (
    SCENARIOS,
    Scenario,
    Workload,
    calibration_grid,
    get_scenario,
    scenario_names,
)

__all__ = [
    "Scenario",
    "Workload",
    "SCENARIOS",
    "get_scenario",
    "scenario_names",
    "calibration_grid",
]
