"""Scenario workload generators for calibration and regime benchmarks."""

from repro.workloads.scenarios import (
    SCENARIOS,
    SHARDING_REGIMES,
    Scenario,
    Workload,
    calibration_grid,
    get_scenario,
    scenario_names,
    sharding_scenarios,
)
from repro.workloads.updates import (
    drifting_users,
    facility_churn,
    facility_jitter,
)

__all__ = [
    "Scenario",
    "Workload",
    "SCENARIOS",
    "get_scenario",
    "scenario_names",
    "calibration_grid",
    "sharding_scenarios",
    "SHARDING_REGIMES",
    "drifting_users",
    "facility_churn",
    "facility_jitter",
]
