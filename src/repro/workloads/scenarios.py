"""Scenario workload generators — the paper's hard regimes as data.

The paper's performance claim is *regime-dependent* (Figs 7–14): the RT
formulation wins when facilities are sparse, users are dense, or ``k`` is
large; filter–refine baselines win at dense facilities and small ``k``.
A planner that cost-dispatches between backends therefore needs workloads
that actually span those regimes — both to *calibrate* its cost models
(:mod:`repro.planner.calibrate`) and to *grade* its decisions (the
``scenario_sweep`` benchmark).

A :class:`Scenario` is a declarative shape — cardinalities, ``k``, batch
size, and point distribution — and :meth:`Scenario.generate` materializes
it into a concrete :class:`Workload` (facilities, users, query indices),
deterministically by seed.  Distributions reuse the generators in
:mod:`repro.data.spatial` (road-network-like, uniform, Gaussian clusters)
plus a half-clustered/half-uniform mix that stresses grids whose cell
occupancy is skewed.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.spatial import (
    clustered_points,
    facility_user_split,
    road_network_points,
    uniform_points,
)

__all__ = [
    "Scenario",
    "Workload",
    "SCENARIOS",
    "get_scenario",
    "scenario_names",
    "calibration_grid",
    "sharding_scenarios",
]


@dataclasses.dataclass
class Workload:
    """A materialized scenario: everything one batched query call needs."""

    name: str
    facilities: np.ndarray  # [F, 2] f64
    users: np.ndarray  # [U, 2] f64
    qs: list[int]  # query facility indices, len Q
    k: int

    @property
    def shape(self) -> tuple[int, int, int, int]:
        """(|F|, |U|, k, Q) — the planner's workload-shape tuple."""
        return len(self.facilities), len(self.users), self.k, len(self.qs)


def _points(distribution: str, n: int, seed: int) -> np.ndarray:
    if distribution == "road":
        return road_network_points(n, seed=seed)
    if distribution == "uniform":
        return uniform_points(n, seed=seed)
    if distribution == "clustered":
        return clustered_points(n, seed=seed)
    if distribution == "gaussian":
        # one broad Gaussian blob centred in the unit square
        rng = np.random.default_rng(seed)
        return np.clip(rng.normal(0.5, 0.15, (n, 2)), 0.0, 1.0)
    if distribution == "mixed":
        # half tight clusters, half uniform background — skewed grid occupancy
        a = clustered_points(n - n // 2, seed=seed, n_clusters=8, spread=0.01)
        b = uniform_points(n // 2, seed=seed + 1)
        out = np.concatenate([a, b])
        return out[np.random.default_rng(seed + 2).permutation(len(out))]
    raise ValueError(
        f"distribution must be road|uniform|clustered|gaussian|mixed, got {distribution!r}"
    )


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Declarative workload shape; ``generate()`` materializes it."""

    name: str
    n_facilities: int
    n_users: int
    k: int
    q: int  # batch size (number of queries)
    distribution: str = "road"
    seed: int = 0

    def generate(self, scale: float = 1.0) -> Workload:
        """Materialize at ``scale`` (multiplies |U| only — the paper scales
        datasets, not facility density; |F|, k, Q define the regime)."""
        n_u = max(int(self.n_users * scale), 64)
        pts = _points(self.distribution, self.n_facilities + n_u, self.seed)
        f, u = facility_user_split(pts, self.n_facilities, seed=self.seed)
        rng = np.random.default_rng(self.seed + 1)
        qs = [int(i) for i in rng.integers(0, len(f), self.q)]
        return Workload(self.name, f, u, qs, self.k)

    def generate_users(self, n_users: int) -> Workload:
        """Materialize at an *absolute* user count (the sharded-serving
        sweeps are specified as "10^6 users", not as a multiple of the
        regime's baseline |U|)."""
        return self.generate(scale=n_users / max(self.n_users, 1))


#: The paper's hard regimes (Figs 7–14) plus distribution ablations.
#: Cardinalities are sized so the full sweep stays tractable on CPU at
#: ``scale=1.0``; the benchmark harness scales |U| down further for CI.
SCENARIOS: dict[str, Scenario] = {
    s.name: s
    for s in (
        # sparse facilities, many users — the paper's headline RT regime
        Scenario("sparse_facility", n_facilities=60, n_users=30_000, k=10, q=16),
        # dense users at default facility density (Fig 13/14)
        Scenario("dense_user", n_facilities=500, n_users=60_000, k=10, q=16),
        # large k at default density (Fig 9) — scenes grow with k
        Scenario("large_k", n_facilities=400, n_users=12_000, k=64, q=8),
        # dense facilities, small k — where filter–refine methods win
        Scenario("dense_facility", n_facilities=2_000, n_users=8_000, k=4, q=16),
        # distribution ablations at default shape
        Scenario("clustered", n_facilities=300, n_users=20_000, k=10, q=16,
                 distribution="clustered"),
        Scenario("gaussian", n_facilities=300, n_users=20_000, k=10, q=16,
                 distribution="gaussian"),
        Scenario("uniform_mix", n_facilities=300, n_users=20_000, k=10, q=16,
                 distribution="mixed"),
    )
}


def scenario_names() -> tuple[str, ...]:
    return tuple(SCENARIOS)


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise ValueError(
            f"scenario must be one of {scenario_names()}, got {name!r}"
        ) from None


#: The two regimes the million-user sharded sweep runs (ISSUE 7): the
#: paper's headline RT regime (few facilities, a flood of users) and the
#: dense-user regime — the ones whose cost is verify-dominated, i.e.
#: where the user axis is the thing worth partitioning.
SHARDING_REGIMES: tuple[str, ...] = ("dense_user", "sparse_facility")


def sharding_scenarios(n_users: int) -> list[Workload]:
    """The sharded-serving sweep workloads at an absolute user count."""
    return [SCENARIOS[name].generate_users(n_users) for name in SHARDING_REGIMES]


def calibration_grid(fast: bool = True, seed: int = 0) -> list[Scenario]:
    """Synthetic shape grid the calibration harness micro-benchmarks.

    Spans the planner's feature axes — |F|, |U|, k, Q — with small absolute
    sizes (calibration measures *scaling*, the fitted power laws
    extrapolate).  ``fast`` keeps it to a handful of shapes for CI.

    Point distributions are rotated across shapes on purpose: scene size
    ``m`` is measured per workload and used as a fit feature, and with a
    single distribution ``m`` would be a near-deterministic function of
    (|F|, k) — the distribution mix decorrelates it so its exponent is
    identifiable.
    """
    if fast:
        spec = [
            (40, 1_500, 4, 1),
            (40, 1_500, 16, 4),
            (40, 6_000, 8, 8),  # sparse F, larger U — the brute-vs-RT frontier
            (300, 4_000, 4, 4),
            (300, 4_000, 16, 1),
            (300, 4_000, 48, 4),  # large k — scene size overtakes |F|
            (120, 8_000, 8, 8),
            (500, 12_000, 8, 8),  # dense users — brute's |F|·|U| wall
            (1_000, 2_000, 4, 4),  # dense facilities, small k
            # serving-batch shapes: the scenario sweep runs Q=16 — without
            # Q>8 support points the fitted Q exponent extrapolates badly
            # exactly where the planner is graded
            (60, 8_000, 10, 16),
            (400, 10_000, 10, 16),
        ]
    else:
        spec = [
            (f, u, k, q)
            for f in (40, 300, 1_200)
            for u in (1_500, 8_000, 30_000)
            for k in (4, 16, 48)
            for q in (1, 8)
        ]
    dists = ("road", "clustered", "uniform")
    scens = [
        Scenario(
            f"cal_F{f}_U{u}_k{k}_Q{q}",
            f, u, k, q,
            distribution=dists[i % len(dists)],
            seed=seed + i,
        )
        for i, (f, u, k, q) in enumerate(spec)
    ]
    # pad-waste identification pairs: identical (F, U, k, Q); ONLY the
    # user distribution — hence the cell-bucketing pad waste — differs.
    # The rotation above varies pw only alongside the size features, so
    # without these pairs log_pw is collinear with log_u/log_m and the
    # non-negative fit pins the grid family's occupancy exponent to zero.
    for f, u, k, q in ((200, 6_000, 8, 8), (500, 12_000, 8, 4)):
        for d in ("uniform", "clustered"):
            scens.append(
                Scenario(
                    f"cal_pw_{d}_F{f}_U{u}", f, u, k, q,
                    distribution=d, seed=seed + 101,
                )
            )
    return scens
