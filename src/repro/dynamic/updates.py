"""Update deltas for the dynamic subsystem.

An :class:`UpdateBatch` is one atomic delta against a ``(facilities,
users)`` snapshot: facility inserts/deletes/moves and user
inserts/deletes/moves, all expressed against **pre-update row ids**.
:func:`apply_to_points` materializes the post-update array together with
an old→new index map, with deterministic layout rules so a cold engine
built from the final snapshot sees exactly the arrays the dynamic engine
maintains:

* moved rows are updated in place,
* deleted rows are removed with relative order preserved,
* inserted rows are appended in the order given.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "UpdateBatch",
    "apply_to_points",
    "changed_positions",
]


def _ids(a) -> np.ndarray:
    if a is None:
        return np.zeros(0, np.int64)
    out = np.asarray(a, dtype=np.int64).reshape(-1)
    return out


def _pts(a) -> np.ndarray:
    if a is None:
        return np.zeros((0, 2), np.float64)
    return np.asarray(a, dtype=np.float64).reshape(-1, 2)


@dataclasses.dataclass(frozen=True)
class UpdateBatch:
    """One atomic snapshot delta (all ids are pre-update row indices).

    ``*_move`` is a pair ``(ids, new_points)``; a row may appear in at
    most one of move/delete per side.  Empty/omitted components are fine —
    ``UpdateBatch(user_move=(ids, pts))`` expresses a pure drift step.
    """

    facility_insert: np.ndarray | None = None  # [A, 2]
    facility_delete: np.ndarray | None = None  # [B] ids
    facility_move: tuple[np.ndarray, np.ndarray] | None = None  # ([C], [C, 2])
    user_insert: np.ndarray | None = None
    user_delete: np.ndarray | None = None
    user_move: tuple[np.ndarray, np.ndarray] | None = None

    def __post_init__(self):
        object.__setattr__(self, "facility_insert", _pts(self.facility_insert))
        object.__setattr__(self, "facility_delete", _ids(self.facility_delete))
        object.__setattr__(self, "user_insert", _pts(self.user_insert))
        object.__setattr__(self, "user_delete", _ids(self.user_delete))
        for name in ("facility_move", "user_move"):
            mv = getattr(self, name)
            ids, pts = (mv[0], mv[1]) if mv is not None else (None, None)
            ids, pts = _ids(ids), _pts(pts)
            if len(ids) != len(pts):
                raise ValueError(f"{name}: {len(ids)} ids but {len(pts)} points")
            object.__setattr__(self, name, (ids, pts))

    # ------------------------------------------------------------------
    @property
    def touches_facilities(self) -> bool:
        return bool(
            len(self.facility_insert)
            or len(self.facility_delete)
            or len(self.facility_move[0])
        )

    @property
    def touches_users(self) -> bool:
        return bool(
            len(self.user_insert) or len(self.user_delete) or len(self.user_move[0])
        )

    @property
    def is_empty(self) -> bool:
        return not (self.touches_facilities or self.touches_users)

    def validate(self, n_facilities: int, n_users: int) -> None:
        """Bounds- and overlap-check all ids against the current snapshot."""
        for name, ids, mv, n in (
            ("facility", self.facility_delete, self.facility_move[0], n_facilities),
            ("user", self.user_delete, self.user_move[0], n_users),
        ):
            for what, arr in (("delete", ids), ("move", mv)):
                if len(arr) and (arr.min() < 0 or arr.max() >= n):
                    raise IndexError(
                        f"{name}_{what} id out of range for {n} rows: {arr}"
                    )
                if len(np.unique(arr)) != len(arr):
                    raise ValueError(f"duplicate ids in {name}_{what}")
            if len(np.intersect1d(ids, mv)):
                raise ValueError(f"{name} rows appear in both delete and move")


def apply_to_points(
    points: np.ndarray,
    insert: np.ndarray,
    delete: np.ndarray,
    move: tuple[np.ndarray, np.ndarray],
) -> tuple[np.ndarray, np.ndarray]:
    """Apply one side's delta.  Returns ``(new_points, index_map)`` where
    ``index_map[old_row]`` is the post-update row (``-1`` for deleted)."""
    points = np.asarray(points, dtype=np.float64)
    n = len(points)
    out = points.copy()
    mv_ids, mv_pts = move
    if len(mv_ids):
        out[mv_ids] = mv_pts
    alive = np.ones(n, dtype=bool)
    alive[delete] = False
    index_map = np.cumsum(alive) - 1
    index_map[~alive] = -1
    out = out[alive]
    if len(insert):
        out = np.concatenate([out, insert])
    return out, index_map.astype(np.int64)


def changed_positions(batch: UpdateBatch, facilities: np.ndarray) -> np.ndarray:
    """Every facility position an update touches — deleted rows, both
    endpoints of moves, and inserts — i.e. the dirty point set the scene
    survival test measures distances against.  ``[K, 2]`` float64."""
    facilities = np.asarray(facilities, dtype=np.float64)
    mv_ids, mv_pts = batch.facility_move
    parts = [
        facilities[batch.facility_delete],
        facilities[mv_ids],
        mv_pts,
        batch.facility_insert,
    ]
    return np.concatenate([p.reshape(-1, 2) for p in parts])
