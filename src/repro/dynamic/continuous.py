"""Standing RkNN queries maintained across snapshot updates.

A :class:`ContinuousQuery` is registered once on a
:class:`~repro.dynamic.engine.DynamicEngine` and re-evaluated **only**
when an update could change its result, streaming ``(version,
RkNNResult)`` pairs through :meth:`poll`.

Maintenance is exact and incremental, in brute (distance-rank) count
semantics — the one convention every backend's *mask* agrees with:

* the **influence zone** of query ``q`` is bounded by ``2·max_u d(u, q)``
  (a facility at ``p`` can steal a user ``u`` from ``q`` only if
  ``d(u, p) < d(u, q)``, and the triangle inequality gives
  ``d(p, q) < 2·d(u, q)``).  A facility change strictly outside that
  radius is *skipped* — no distances against the user set are computed;
* a facility change inside it touches exactly the users in the bisector
  half-plane ``{u : d(u, p) < d(u, q)}`` — counts are patched by ±1 on
  that dirty region (the same strict-``<`` expanded-form arithmetic as
  :func:`repro.core.brute.rank_counts_np`, so patched counts equal a
  cold recount bitwise);
* user moves/inserts recount only the touched rows against the facility
  set; deletes drop rows;
* moving or deleting the query's own facility falls back to a full
  recount (the influence geometry itself changed) — deletion kills the
  handle (``alive = False``).

An event is emitted only when the membership mask actually changed.
"""

from __future__ import annotations

import collections
import time

import numpy as np

from repro.core.brute import rank_counts_np
from repro.core.results import RkNNResult

__all__ = ["ContinuousQuery", "influence_dirty_mask"]


def influence_dirty_mask(handles, changed_pos: np.ndarray) -> np.ndarray:
    """One vectorized influence-zone dirty test across all live handles.

    ``changed_pos`` is the ``[C, 2]`` set of facility positions a
    facility-only update touches (both endpoints of moves, deleted rows,
    inserts).  Returns ``[H]`` bool: True when any changed position lies
    strictly inside the handle's influence radius — exactly the per-handle
    :meth:`ContinuousQuery._patch_facility` distance test, batched into
    one ``[H, C]`` distance matrix so thousands of standing queries pay
    one numpy pass per update instead of a Python loop each.
    """
    if not len(handles) or not len(changed_pos):
        return np.zeros(len(handles), bool)
    q_pts = np.stack([h.q_pt for h in handles])  # [H, 2]
    infl = np.array([h._influence for h in handles])  # [H]
    d = np.linalg.norm(
        np.asarray(changed_pos, np.float64)[None, :, :] - q_pts[:, None, :], axis=-1
    )  # [H, C]
    return (d < infl[:, None]).any(axis=1)


def _d2(users: np.ndarray, p: np.ndarray) -> np.ndarray:
    """Squared distances in the same expanded form as ``rank_counts_np``
    (bitwise-matching its comparisons matters more than elegance here)."""
    return (
        np.sum(users**2, axis=1) - 2.0 * (users @ np.asarray(p, np.float64)) + p @ p
    )


class ContinuousQuery:
    """A standing RkNN query; constructed via
    :meth:`repro.dynamic.engine.DynamicEngine.register_continuous`."""

    def __init__(
        self,
        facilities: np.ndarray,
        users: np.ndarray,
        q: int | np.ndarray,
        k: int,
        version: int,
    ):
        arr = np.asarray(q)
        if arr.ndim == 0 and np.issubdtype(arr.dtype, np.integer):
            self.q_idx: int | None = int(arr)
            self.q_pt = np.asarray(facilities, np.float64)[self.q_idx].copy()
        else:
            self.q_idx = None
            self.q_pt = np.asarray(q, np.float64).reshape(2)
        self.k = int(k)
        self.alive = True
        self.version = version
        self._events: "collections.deque[tuple[int, RkNNResult]]" = (
            collections.deque(maxlen=256)
        )
        self.n_skipped = 0  # updates provably outside the influence zone
        self.n_patched = 0  # incremental half-plane patches
        self.n_full = 0  # full recounts
        self.n_events = 0  # change events ever emitted (monotone)
        self.events_dropped = 0  # evicted unpolled events (slow consumer)
        self._recount(facilities, users)

    # ------------------------------------------------------------------
    # public surface
    # ------------------------------------------------------------------
    @property
    def mask(self) -> np.ndarray:
        """Current membership mask ``[N]`` (copy)."""
        return self._counts < self.k

    @property
    def counts(self) -> np.ndarray:
        """Current exact closer-facility counts ``[N]`` (copy)."""
        return self._counts.copy()

    def result(self) -> RkNNResult:
        return RkNNResult(
            mask=self.mask,
            counts=self._counts.astype(np.int32),
            scene=None,
            t_filter_s=0.0,
            t_verify_s=0.0,
            backend="continuous",
        )

    def poll(self) -> list[tuple[int, RkNNResult]]:
        """Drain the pending ``(version, RkNNResult)`` change events.

        The buffer holds the newest 256 events; a consumer that falls
        further behind loses the oldest transitions — ``events_dropped``
        counts them (the *current* result is always :attr:`mask`).
        """
        out = list(self._events)
        self._events.clear()
        return out

    def close(self) -> None:
        """Stop maintaining this handle; the engine drops it on the next
        update.  Abandoned handles otherwise patch counts forever."""
        self.alive = False
        self._events.clear()

    # ------------------------------------------------------------------
    # maintenance (driven by DynamicEngine.apply_updates)
    # ------------------------------------------------------------------
    def _set_users(self, users: np.ndarray) -> None:
        # d2q uses the same DIRECT form as rank_counts_np's reference
        # distance (not the expanded form) so patched comparisons are
        # bitwise-identical to a cold recount's
        users = np.asarray(users, np.float64)
        self._d2q = np.sum((users - self.q_pt) ** 2, axis=1)
        self._influence = (
            2.0 * float(np.sqrt(max(self._d2q.max(), 0.0))) if len(self._d2q) else 0.0
        )

    def _recount(self, facilities: np.ndarray, users: np.ndarray) -> None:
        self._counts = rank_counts_np(users, facilities, self.q_pt, exclude=self.q_idx)
        self._set_users(users)

    def _patch_facility(self, users: np.ndarray, p: np.ndarray, delta: int) -> bool:
        """±1 the counts of users strictly closer to ``p`` than to ``q``.
        Returns False when ``p`` is provably outside the influence zone."""
        p = np.asarray(p, np.float64)
        if float(np.linalg.norm(p - self.q_pt)) >= self._influence:
            return False
        aff = _d2(users, p) < self._d2q
        if aff.any():
            self._counts[aff] += delta
        return True

    def _on_update_clean(self, ctx, had_facility_changes: bool) -> None:
        """Close out an update the batched influence-zone test proved
        cannot touch this handle: remap the tracked facility row through
        the update's id map and count the skip — bit-identical to what
        :meth:`_on_update` would have done, minus the per-position
        distance loop.  Only valid for facility-only deltas where the
        handle's own facility neither moved nor died (the engine's
        batched test forces those handles onto the exact path).
        """
        if not self.alive:
            return
        if self.q_idx is not None:
            self.q_idx = int(ctx.map_f[self.q_idx])
        if had_facility_changes:
            self.n_skipped += 1
        self.version = ctx.version

    def _on_update(self, ctx) -> None:
        """Apply one update (ctx is the engine's ``_UpdateContext``)."""
        if not self.alive:
            return
        t0 = time.perf_counter()
        old_mask = self._counts < self.k
        batch = ctx.batch
        full = False

        if self.q_idx is not None:
            new_idx = int(ctx.map_f[self.q_idx])
            if new_idx < 0:
                self.alive = False
                self.version = ctx.version
                return
            if len(batch.facility_move[0]) and np.any(
                batch.facility_move[0] == self.q_idx
            ):
                full = True  # the query facility itself moved
                self.q_pt = np.asarray(ctx.new_facilities, np.float64)[new_idx].copy()
            self.q_idx = new_idx

        if not full:
            old_users = np.asarray(ctx.old_users, np.float64)
            # facility-side patches against the (unchanged) old user rows
            mv_ids, mv_pts = batch.facility_move
            touched = skipped = 0
            for pos, delta in (
                *(
                    (np.asarray(ctx.old_facilities, np.float64)[i], -1)
                    for i in np.concatenate([batch.facility_delete, mv_ids])
                ),
                *((p, +1) for p in np.concatenate([mv_pts, batch.facility_insert])),
            ):
                if self._patch_facility(old_users, pos, delta):
                    touched += 1
                else:
                    skipped += 1
            if touched:
                self.n_patched += 1
            if skipped and not touched:
                self.n_skipped += 1

            # user-side maintenance against the NEW facility set
            new_f = np.asarray(ctx.new_facilities, np.float64)
            u_mv_ids, _ = batch.user_move
            if len(u_mv_ids) or len(batch.user_delete) or len(batch.user_insert):
                new_users = np.asarray(ctx.new_users, np.float64)
                counts = self._counts
                if len(u_mv_ids):
                    counts = counts.copy()
                    moved_rows = ctx.map_u[u_mv_ids]
                    # recount moved users at their new positions
                    counts[u_mv_ids] = rank_counts_np(
                        new_users[moved_rows], new_f, self.q_pt, exclude=self.q_idx
                    )
                alive_u = ctx.map_u >= 0
                counts = counts[alive_u]
                if len(batch.user_insert):
                    fresh = rank_counts_np(
                        new_users[len(counts):], new_f, self.q_pt, exclude=self.q_idx
                    )
                    counts = np.concatenate([counts, fresh])
                self._counts = counts
                self._set_users(new_users)

        if full:
            self.n_full += 1
            self._recount(ctx.new_facilities, ctx.new_users)

        self.version = ctx.version
        new_mask = self._counts < self.k
        if len(new_mask) != len(old_mask) or not np.array_equal(new_mask, old_mask):
            self.n_events += 1
            if len(self._events) == self._events.maxlen:
                self.events_dropped += 1
            self._events.append(
                (
                    ctx.version,
                    RkNNResult(
                        mask=new_mask.copy(),
                        counts=self._counts.astype(np.int32),
                        scene=None,
                        t_filter_s=0.0,
                        t_verify_s=time.perf_counter() - t0,
                        backend="continuous",
                    ),
                )
            )
