"""Dynamic-data subsystem: versioned updates, scene refit, continuous RkNN.

Public surface:

* :class:`~repro.dynamic.engine.DynamicEngine` — a
  :class:`~repro.core.engine.RkNNEngine` whose ``(facilities, users)``
  snapshot evolves through :meth:`apply_updates`;
* :class:`~repro.dynamic.updates.UpdateBatch` — one atomic delta;
* :class:`~repro.dynamic.continuous.ContinuousQuery` — a standing query
  handle streaming ``(version, RkNNResult)`` change events;
* :class:`~repro.dynamic.policy.RefitPolicy` — the priced
  refit-vs-rebuild frontier.

See ``docs/API.md`` ("Dynamic data") for the lifecycle.
"""

from repro.dynamic.continuous import ContinuousQuery
from repro.dynamic.engine import DynamicEngine, DynamicStats, UpdateReport
from repro.dynamic.policy import RefitDecision, RefitPolicy
from repro.dynamic.refit import refit_scene, remap_scene, scene_update_safe
from repro.dynamic.updates import UpdateBatch, apply_to_points, changed_positions

__all__ = [
    "DynamicEngine",
    "DynamicStats",
    "UpdateReport",
    "UpdateBatch",
    "ContinuousQuery",
    "RefitPolicy",
    "RefitDecision",
    "apply_to_points",
    "changed_positions",
    "refit_scene",
    "remap_scene",
    "scene_update_safe",
]
