"""Priced refit-vs-rebuild decisions for dirtied scenes.

When an update lands inside a cached scene's pruning certificate the
dynamic engine has two honest options:

* **refit eagerly** during ``apply_updates`` — pay a re-prune plus
  occluder patches for the moved facilities plus per-backend index
  refits now, and keep the cache hot;
* **drop** the entry — pay a full scene build lazily on the next query
  that wants it (or nothing at all, if the query never repeats).

The decision is priced the same way the query planner prices backends:
the rebuild side comes from the active profile's *filter* cost model
(scene construction is exactly what that model measures), the refit side
scales it by the share of work a refit skips.  Observed refit/rebuild
times feed back as damped EMAs, so the prior only matters until the
first few updates have been measured — the same calibrate-then-trust
pattern as :mod:`repro.planner`.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.planner.models import WorkloadShape
from repro.planner.profiles import active_or_builtin

__all__ = ["RefitDecision", "RefitPolicy"]

#: Share of a cold scene build that a refit still pays (the re-prune);
#: the remainder (occluder fans + index build) scales with the touched
#: fraction.  A prior only — displaced by measured EMAs as updates land.
_PRUNE_SHARE = 0.55
_EMA_ALPHA = 0.3


@dataclasses.dataclass
class RefitDecision:
    """One priced decision (surfaced through ``DynamicEngine.explain_updates``)."""

    action: str  # "refit" | "rebuild"
    predicted_refit_s: float
    predicted_rebuild_s: float


class RefitPolicy:
    """EMA-corrected cost frontier between eager refit and lazy rebuild."""

    def __init__(self) -> None:
        self.ema_refit_s: float | None = None
        self.ema_rebuild_s: float | None = None
        self.n_refit = 0
        self.n_rebuild = 0

    # ------------------------------------------------------------------
    def _rebuild_cost_s(self, shape: WorkloadShape) -> float:
        if self.ema_rebuild_s is not None:
            return self.ema_rebuild_s
        prof = active_or_builtin()
        best = np.inf
        for name, model in prof.models.items():
            if name in ("brute", "slice"):
                continue  # geometry-free: no scene to rebuild
            best = min(best, model.filter.predict_s(shape))
        return best if np.isfinite(best) else 1e-3

    def price(
        self, shape: WorkloadShape, n_changed_tris: int, n_tris: int
    ) -> RefitDecision:
        """Price refitting one scene with ``n_changed_tris`` touched
        triangles against rebuilding it cold (``shape.m_tris == n_tris``)."""
        rebuild = self._rebuild_cost_s(shape)
        frac = n_changed_tris / max(n_tris, 1)
        if self.ema_refit_s is not None:
            refit = self.ema_refit_s
        else:
            refit = rebuild * (_PRUNE_SHARE + (1.0 - _PRUNE_SHARE) * frac)
        action = "refit" if refit < rebuild else "rebuild"
        return RefitDecision(action, refit, rebuild)

    def observe(self, action: str, dt_s: float) -> None:
        """Fold an observed refit/rebuild duration into the EMAs."""
        if action == "refit":
            self.n_refit += 1
            self.ema_refit_s = (
                dt_s
                if self.ema_refit_s is None
                else (1 - _EMA_ALPHA) * self.ema_refit_s + _EMA_ALPHA * dt_s
            )
        else:
            self.n_rebuild += 1
            self.ema_rebuild_s = (
                dt_s
                if self.ema_rebuild_s is None
                else (1 - _EMA_ALPHA) * self.ema_rebuild_s + _EMA_ALPHA * dt_s
            )
