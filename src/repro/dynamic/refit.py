"""Scene survival, remapping, and refit under snapshot deltas.

Three levels of reuse, cheapest first, all provably bit-identical to a
cold rebuild from the post-update snapshot:

1. **Survive** (:func:`scene_update_safe`): every touched facility
   position is strictly farther from the scene's query point than the
   pruning pass's :attr:`~repro.core.pruning.PruneStats.safe_radius`
   certificate — a cold re-prune would examine the identical chunked
   prefix and reject the rest, so the scene (triangles, coefficients,
   kept set) is unchanged.  Only row *ids* may have shifted (deletions
   compact the array); :func:`remap_scene` rewrites ``keep``/``owner``,
   and the caller adopts the memoized per-backend indexes into the next
   snapshot's index memo untouched.

2. **Refit** (:func:`refit_scene`): the update lands inside the
   certificate, but a re-prune confirms the kept facility set is
   unchanged and only some kept facilities *moved*.  Occluder fans are
   recomputed for the moved facilities only and spliced over the old
   triangle slots — the per-triangle construction is deterministic, so
   untouched slots stay bit-identical and the patched arrays equal a
   cold build's.  The caller then refits (or rebuilds, per backend
   quality gates) the memoized indexes via ``Backend.refit_index``.

3. **Rebuild**: anything else drops out of the cache and is rebuilt
   lazily by the next query that needs it.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.geometry import Rect, edge_coeffs
from repro.core.occluders import occluder_triangles
from repro.core.pruning import prune_facilities
from repro.core.scene import Scene

__all__ = ["scene_update_safe", "remap_scene", "refit_scene"]


def scene_update_safe(scene: Scene, changed_pos: np.ndarray) -> bool:
    """True when every changed facility position is strictly beyond the
    scene's pruning certificate — the update provably cannot alter it."""
    if not len(changed_pos):
        return True
    safe = scene.stats.safe_radius
    if not np.isfinite(safe):
        return False
    d = np.linalg.norm(np.asarray(changed_pos, np.float64) - scene.q, axis=1)
    return bool(np.all(d > safe))


def remap_scene(scene: Scene, index_map: np.ndarray, n_new: int) -> Scene:
    """Rewrite ``keep``/``owner`` row ids through ``index_map`` for a scene
    whose geometry survives an update unchanged.  Triangle arrays are
    shared, not copied; the memoized grid/BVH indexes are adopted into the
    new snapshot's :class:`~repro.core.snapshot.IndexMemo` by the caller.

    Every kept facility must survive the update — the survival test
    guarantees it (a deleted kept facility is within the certificate).
    """
    old_kept = np.flatnonzero(scene.keep)
    new_rows = index_map[old_kept]
    if len(new_rows) and new_rows.min() < 0:
        raise ValueError("remap_scene: a kept facility was deleted")
    keep = np.zeros(n_new, dtype=bool)
    keep[new_rows] = True
    owner = scene.owner.copy()
    real = owner >= 0
    owner[real] = index_map[owner[real]].astype(owner.dtype)
    new = Scene(
        tris=scene.tris,
        coeffs=scene.coeffs,
        owner=owner,
        n_tris=scene.n_tris,
        n_occluders=scene.n_occluders,
        keep=keep,
        q=scene.q,
        rect=scene.rect,
        heights=scene.heights,
        stats=dataclasses.replace(scene.stats, n_facilities=n_new),
    )
    return new


def refit_scene(
    scene: Scene,
    index_map: np.ndarray,
    facilities_new: np.ndarray,
    q_build: int | np.ndarray,
    k: int,
    rect: Rect,
    moved_new_ids: np.ndarray,
    *,
    strategy: str = "infzone",
    grid: int | None = None,
) -> tuple[Scene, np.ndarray] | None:
    """Patch a dirtied scene in place of a full rebuild, when sound.

    Re-runs pruning on the new facility set; bails (``None``) unless the
    kept set is exactly the old one carried through ``index_map`` — then
    recomputes occluder fans only for kept facilities in ``moved_new_ids``
    (post-update ids) and splices them over their old triangle slots.
    Bails as well when a moved facility's fan changes triangle count (its
    occluder case flipped — the splice would shift every later slot).

    Returns ``(new_scene, changed_tri_ids)``; the new scene equals what
    ``build_scene`` would produce from the new snapshot, while sharing no
    mutated state with the input.  The caller still owns index refit.
    """
    facilities_new = np.asarray(facilities_new, dtype=np.float64)
    if isinstance(q_build, (int, np.integer)):
        exclude: int | None = int(q_build)
        q_pt = facilities_new[exclude]
    else:
        exclude = None
        q_pt = np.asarray(q_build, np.float64)
    if not np.array_equal(q_pt, scene.q):
        return None  # the query point itself moved: every occluder changes
    keep_new, stats = prune_facilities(
        facilities_new, q_pt, k, rect, strategy=strategy, grid=grid, exclude=exclude
    )
    expected = np.zeros(len(facilities_new), dtype=bool)
    old_kept = np.flatnonzero(scene.keep)
    mapped = index_map[old_kept]
    if len(mapped) and mapped.min() < 0:
        return None  # a kept facility was deleted: geometry must change
    expected[mapped] = True
    if not np.array_equal(keep_new, expected):
        return None

    n = scene.n_tris
    owner = scene.owner.copy()
    real = owner >= 0
    owner[real] = index_map[owner[real]].astype(owner.dtype)
    tris = scene.tris.copy()
    coeffs = scene.coeffs.copy()
    changed: list[int] = []
    for fid in np.asarray(moved_new_ids, np.int64):
        if fid < 0 or not keep_new[fid]:
            continue
        slots = np.flatnonzero(owner[:n] == fid)
        t_new = occluder_triangles(facilities_new[fid], q_pt, rect)
        if len(t_new) != len(slots):
            return None  # occluder case flipped (1 vs 2 triangles)
        if len(slots):
            tris[slots] = t_new.astype(np.float32)
            coeffs[slots] = edge_coeffs(t_new).astype(np.float32)
            changed.extend(int(s) for s in slots)

    new = Scene(
        tris=tris,
        coeffs=coeffs,
        owner=owner,
        n_tris=n,
        n_occluders=int(keep_new.sum()),
        keep=keep_new,
        q=q_pt,
        rect=rect,
        heights=scene.heights,
        stats=stats,
    )
    return new, np.asarray(changed, np.int64)
