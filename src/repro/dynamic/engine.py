"""The versioned dynamic engine: incremental updates over ``RkNNEngine``.

Every query path in the static engine assumes a frozen ``(facilities,
users)`` snapshot; :class:`DynamicEngine` removes that assumption the way
graphics pipelines do — by *refitting* acceleration state instead of
rebuilding it:

* :meth:`apply_updates` takes an :class:`~repro.dynamic.updates.UpdateBatch`
  (facility insert/delete/move, user insert/delete/move), advances a
  monotonically increasing ``version``, and reconciles every piece of
  amortized engine state with the delta rather than dropping it all:

  - **device user arrays** — pure user *moves* scatter into the resident
    ``xs``/``ys`` (and the mesh-sharded copies) in place; only
    inserts/deletes force a re-upload;
  - **scene cache** — entries are migrated through the three-level
    survive / refit / rebuild ladder of :mod:`repro.dynamic.refit`: a
    scene whose pruning certificate the delta does not pierce is re-keyed
    (row ids remapped) and survives with its memoized grid/BVH indexes; a
    pierced scene whose kept set a re-prune confirms unchanged is patched
    (occluder fans of moved facilities respliced, indexes refit via
    ``Backend.refit_index``); everything else is dropped and rebuilt
    lazily.  Eager-refit vs lazy-rebuild is a priced decision
    (:class:`~repro.dynamic.policy.RefitPolicy`, fed by the planner's
    cost profile and its own observed EMAs);
  - **prepared-batch LRU / plan memos** — cleared (they alias user
    arrays and scene lists wholesale; per-entry surgery is not worth it);
  - **continuous queries** — one *vectorized* influence-zone dirty test
    runs across all live :class:`~repro.dynamic.continuous.ContinuousQuery`
    handles per update (:func:`~repro.dynamic.continuous.influence_dirty_mask`);
    only the handles it marks dirty fall into the exact per-handle patch,
    the rest take a remap-and-skip fast path.

Equivalence contract (property-tested): after any sequence of
``apply_updates``, every query path on this engine returns bit-identical
results to a cold ``RkNNEngine`` built from ``(self.facilities,
self.users)`` — for every registered backend.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax.numpy as jnp

from repro.core.backends import get_backend
from repro.core.engine import RkNNConfig, RkNNEngine
from repro.core.pruning import adaptive_grid
from repro.dynamic.continuous import ContinuousQuery, influence_dirty_mask
from repro.dynamic.policy import RefitPolicy
from repro.dynamic.refit import refit_scene, remap_scene, scene_update_safe
from repro.dynamic.updates import UpdateBatch, apply_to_points, changed_positions
from repro.planner.models import WorkloadShape

__all__ = ["DynamicEngine", "UpdateReport", "DynamicStats"]


@dataclasses.dataclass
class UpdateReport:
    """What one :meth:`DynamicEngine.apply_updates` call did."""

    version: int
    t_update_s: float
    rect_changed: bool
    scenes_survived: int = 0
    scenes_refit: int = 0
    scenes_dropped: int = 0
    indexes_refit: int = 0
    indexes_rebuilt: int = 0
    users_scattered: bool = False
    continuous_patched: int = 0
    continuous_skipped: int = 0
    continuous_events: int = 0


@dataclasses.dataclass
class DynamicStats:
    """Cumulative counters across the engine's update lifetime."""

    n_updates: int = 0
    t_update_s: float = 0.0
    scenes_survived: int = 0
    scenes_refit: int = 0
    scenes_dropped: int = 0
    indexes_refit: int = 0
    indexes_rebuilt: int = 0
    user_scatters: int = 0
    user_reuploads: int = 0


class DynamicEngine(RkNNEngine):
    """A :class:`RkNNEngine` whose snapshot can change underneath it.

    Construction matches the static engine; all query methods are
    inherited unchanged and always serve the **latest** snapshot
    (``self.version``).  See module docstring for the update semantics.

    **Single-writer contract**: :meth:`apply_updates` must not run
    concurrently with any query — including an active :meth:`stream`,
    whose producer thread builds scenes in the background.  An update
    racing a query would serve a mix of old and new snapshots with no
    error.  Serialize updates against queries (drain streams first); a
    reader-writer snapshot swap is a ROADMAP follow-on.
    """

    def __init__(self, facilities, users, config: RkNNConfig | None = None, **kw):
        super().__init__(facilities, users, config, **kw)
        self.version = 0
        self.update_stats = DynamicStats()
        self.refit_policy = RefitPolicy()
        self._continuous: list[ContinuousQuery] = []
        self._update_log: list[UpdateReport] = []

    # ------------------------------------------------------------------
    # continuous queries
    # ------------------------------------------------------------------
    def register_continuous(self, q, k: int) -> ContinuousQuery:
        """Register a standing RkNN query (facility index or ``[2]``
        point); it is re-evaluated on exactly the updates that can change
        it and streams ``(version, RkNNResult)`` via ``poll()``."""
        cq = ContinuousQuery(self.facilities, self.users, q, k, self.version)
        self._continuous.append(cq)
        return cq

    def explain_updates(self) -> list[UpdateReport]:
        """Per-update reports, oldest first (bounded to the last 128)."""
        return list(self._update_log)

    # ------------------------------------------------------------------
    # observed rebuild costs feed the refit-vs-rebuild frontier
    # ------------------------------------------------------------------
    def _build_scene(self, q, k: int, rect, *, pad_to: int | None = None):
        misses = self.scene_cache.misses if self.scene_cache is not None else None
        t0 = time.perf_counter()
        scene = super()._build_scene(q, k, rect, pad_to=pad_to)
        if misses is not None and self.scene_cache.misses > misses:
            self.refit_policy.observe("rebuild", time.perf_counter() - t0)
        return scene

    # ------------------------------------------------------------------
    # the update path
    # ------------------------------------------------------------------
    def apply_updates(self, batch: UpdateBatch | None = None, **deltas) -> UpdateReport:
        """Apply one atomic delta; returns the new-version report.

        Accepts either a prebuilt :class:`UpdateBatch` or its fields as
        keyword arguments (``apply_updates(user_move=(ids, pts))``).
        """
        if batch is None:
            batch = UpdateBatch(**deltas)
        elif deltas:
            raise TypeError("pass either an UpdateBatch or keyword deltas, not both")
        batch.validate(len(self.facilities), len(self.users))
        t0 = time.perf_counter()

        old_f, old_u = self.facilities, self.users
        old_rect = None if self._explicit_rect else self.rect
        old_fp = self._fingerprint()
        old_grid = adaptive_grid(len(old_f))  # pruning resolution regime

        new_f, map_f = apply_to_points(
            old_f, batch.facility_insert, batch.facility_delete, batch.facility_move
        )
        new_u, map_u = apply_to_points(
            old_u, batch.user_insert, batch.user_delete, batch.user_move
        )
        changed_pos = changed_positions(batch, old_f)

        # ---- swap in the new snapshot ---------------------------------
        self.facilities = new_f
        self.users = new_u
        self._hull = None
        if not self._explicit_rect:
            self._rect = None
        rect_changed = (not self._explicit_rect) and self.rect != old_rect
        if batch.touches_facilities:
            self._fp = None
        new_fp = self._fingerprint()

        report = UpdateReport(
            version=self.version + 1, t_update_s=0.0, rect_changed=rect_changed
        )

        # ---- device-resident user coordinates -------------------------
        if batch.touches_users:
            self._refit_user_arrays(batch, report)

        # ---- prepared-batch LRU + plan memos: alias the old snapshot --
        with self._batch_lock:
            self._batch_cache.clear()
        # the grid's mesh-sharded jitted step closes over the domain rect
        if rect_changed:
            for key in [k for k in self._mesh_steps if k[0] == "grid"]:
                del self._mesh_steps[key]
        # the mono sub-engine snapshots the facility set at construction
        self._mono = None
        self._is_mono = None

        # ---- scene cache: survive / refit / rebuild -------------------
        if self.scene_cache is not None:
            self._migrate_scene_cache(
                batch, old_fp, new_fp, old_rect, rect_changed,
                old_grid, map_f, changed_pos, report,
            )

        # ---- continuous queries ---------------------------------------
        self.version += 1
        ctx = _UpdateContext(
            batch=batch,
            old_facilities=old_f,
            new_facilities=new_f,
            old_users=old_u,
            new_users=new_u,
            map_f=map_f,
            map_u=map_u,
            version=self.version,
        )
        # closed/dead handles are dropped here, not at close() time — the
        # handle list is only ever touched on the update path (single-writer)
        self._continuous = [cq for cq in self._continuous if cq.alive]
        if self._continuous:
            dirty = self._dirty_continuous(batch, changed_pos)
            for cq, is_dirty in zip(self._continuous, dirty):
                before = (cq.n_patched, cq.n_skipped, cq.n_events)
                if is_dirty:
                    cq._on_update(ctx)
                else:
                    cq._on_update_clean(ctx, len(changed_pos) > 0)
                report.continuous_patched += cq.n_patched - before[0]
                report.continuous_skipped += cq.n_skipped - before[1]
                report.continuous_events += cq.n_events - before[2]

        report.t_update_s = time.perf_counter() - t0
        self.update_stats.n_updates += 1
        self.update_stats.t_update_s += report.t_update_s
        self.update_stats.scenes_survived += report.scenes_survived
        self.update_stats.scenes_refit += report.scenes_refit
        self.update_stats.scenes_dropped += report.scenes_dropped
        self.update_stats.indexes_refit += report.indexes_refit
        self.update_stats.indexes_rebuilt += report.indexes_rebuilt
        self._update_log.append(report)
        if len(self._update_log) > 128:
            del self._update_log[0]
        return report

    # ------------------------------------------------------------------
    def _dirty_continuous(self, batch: UpdateBatch, changed_pos: np.ndarray):
        """``[H]`` bool: which live handles this delta could actually touch.

        One vectorized influence-zone test across all standing queries
        (:func:`repro.dynamic.continuous.influence_dirty_mask`) replaces
        the per-handle Python loop; only handles marked dirty fall into
        the exact per-handle patch.  User-side deltas dirty every handle
        (rows/thresholds must be reconciled), and a handle whose own
        facility moved or died is always exact-patched (its influence
        geometry itself changes, which the distance test cannot certify).
        """
        n = len(self._continuous)
        if batch.touches_users:
            return np.ones(n, bool)
        dirty = influence_dirty_mask(self._continuous, changed_pos)
        own = np.concatenate([batch.facility_delete, batch.facility_move[0]])
        if len(own):
            q_idx = np.array(
                [-1 if cq.q_idx is None else cq.q_idx for cq in self._continuous]
            )
            dirty |= np.isin(q_idx, own)
        return dirty

    # ------------------------------------------------------------------
    def _refit_user_arrays(self, batch: UpdateBatch, report: UpdateReport) -> None:
        """Masked scatter into the resident device arrays for pure moves;
        re-upload (lazily) on any shape change."""
        mv_ids, mv_pts = batch.user_move
        moves_only = (
            len(mv_ids) > 0
            and not len(batch.user_insert)
            and not len(batch.user_delete)
        )
        if moves_only:
            if self._xs is not None:
                idx = jnp.asarray(mv_ids)
                self._xs = self._xs.at[idx].set(jnp.asarray(mv_pts[:, 0], jnp.float32))
                self._ys = self._ys.at[idx].set(jnp.asarray(mv_pts[:, 1], jnp.float32))
                report.users_scattered = True
                self.update_stats.user_scatters += 1
        else:
            self._xs = self._ys = None  # shape changed: lazy re-upload on next use
            self.update_stats.user_reuploads += 1
        if self.mesh is not None:
            if moves_only:
                idx = jnp.asarray(mv_ids)
                self._mesh_xs = self._mesh_xs.at[idx].set(
                    jnp.asarray(mv_pts[:, 0], jnp.float32)
                )
                self._mesh_ys = self._mesh_ys.at[idx].set(
                    jnp.asarray(mv_pts[:, 1], jnp.float32)
                )
            else:
                self._init_mesh(self.mesh)

    # ------------------------------------------------------------------
    def _migrate_scene_cache(
        self,
        batch: UpdateBatch,
        old_fp: int,
        new_fp: int,
        old_rect,
        rect_changed: bool,
        old_grid: int,
        map_f: np.ndarray,
        changed_pos: np.ndarray,
        report: UpdateReport,
    ) -> None:
        cache = self.scene_cache
        if rect_changed:
            # every cached scene was clipped against the old domain; a cold
            # engine would build different geometry — purge wholesale
            _, dropped = cache.migrate(lambda key: True, lambda key, s: None)
            report.scenes_dropped += dropped
            return
        if not batch.touches_facilities:
            # user-only delta with a stable hull: scenes depend on
            # (facilities, q, k, rect) alone — every entry survives as-is
            report.scenes_survived += len(cache)
            return
        # adaptive pruning-grid regime flip: a cold re-prune would run at a
        # different resolution — nothing survives
        if self.config.prune_grid is None and adaptive_grid(len(self.facilities)) != old_grid:
            _, dropped = cache.migrate(lambda key: True, lambda key, s: None)
            report.scenes_dropped += dropped
            return

        moved_ids_old = batch.facility_move[0]
        moved_new = map_f[moved_ids_old] if len(moved_ids_old) else np.zeros(0, np.int64)
        grid_param = self.config.prune_grid
        # Refit is only attempted for pure-move deltas: an insert/delete
        # that pierced a scene's certificate almost always changes its kept
        # set, so the attempt's re-prune (the expensive part) is a near-
        # certain write-off — measured to flip the churn regime from a win
        # to a 0.6x loss when attempted indiscriminately.
        moves_only = not len(batch.facility_insert) and not len(batch.facility_delete)

        def migrate(key, scene):
            _fp, q_key, k, rect = key
            if rect != self.rect:
                return None  # transient-rect entry (out-of-hull point query)
            if isinstance(q_key, (int, np.integer)):
                new_q = int(map_f[int(q_key)])
                if new_q < 0 or (len(moved_ids_old) and np.any(moved_ids_old == q_key)):
                    return None  # the query facility itself is gone / moved
                q_build: int | np.ndarray = new_q
                new_q_key: int | tuple = new_q
            else:
                q_build = np.asarray(q_key, np.float64)
                new_q_key = q_key
            if scene_update_safe(scene, changed_pos):
                report.scenes_survived += 1
                return (new_fp, new_q_key, k, rect), remap_scene(
                    scene, map_f, len(self.facilities)
                )
            # pierced certificate: priced eager-refit vs lazy-rebuild
            if not moves_only:
                return None
            n = scene.n_tris
            owner_new = map_f[scene.owner[:n][scene.owner[:n] >= 0]]
            n_changed = (
                int(np.isin(owner_new, moved_new).sum()) if len(moved_new) else 0
            )
            shape = WorkloadShape(
                len(self.facilities), len(self.users), k, 1, m_tris=max(n, 1)
            )
            decision = self.refit_policy.price(shape, n_changed, n)
            if decision.action != "refit":
                return None
            t0 = time.perf_counter()
            out = refit_scene(
                scene,
                map_f,
                self.facilities,
                q_build,
                k,
                rect,
                moved_new,
                strategy=self.config.strategy,
                grid=grid_param,
            )
            if out is None:
                # a bailed refit attempt is neither a refit nor a rebuild
                # observation — feeding its (small) cost into either EMA
                # would skew the frontier
                return None
            new_scene, changed_tris = out
            store = getattr(scene, "_engine_indexes", None)
            if store:
                new_store = {}
                for (bname, g), index in store.items():
                    if index is None:  # index-less backend (dense paths)
                        new_store[(bname, g)] = None
                        continue
                    idx, was_refit = get_backend(bname).refit_index(
                        index, scene, new_scene, changed_tris, grid_g=g
                    )
                    new_store[(bname, g)] = idx
                    if was_refit:
                        report.indexes_refit += 1
                    else:
                        report.indexes_rebuilt += 1
                object.__setattr__(new_scene, "_engine_indexes", new_store)
            self.refit_policy.observe("refit", time.perf_counter() - t0)
            report.scenes_refit += 1
            return (new_fp, new_q_key, k, rect), new_scene

        _, dropped = cache.migrate(lambda key: key[0] == old_fp, migrate)
        report.scenes_dropped += dropped


@dataclasses.dataclass
class _UpdateContext:
    """Everything a continuous query needs to reconcile one update."""

    batch: UpdateBatch
    old_facilities: np.ndarray
    new_facilities: np.ndarray
    old_users: np.ndarray
    new_users: np.ndarray
    map_f: np.ndarray
    map_u: np.ndarray
    version: int
