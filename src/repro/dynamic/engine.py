"""The versioned dynamic engine: incremental updates over ``RkNNEngine``.

Every query path in the static engine serves one immutable
:class:`~repro.core.snapshot.EngineSnapshot`; :class:`DynamicEngine`
advances that snapshot the way graphics pipelines do — by *refitting*
acceleration state instead of rebuilding it, copy-on-write:

* :meth:`apply_updates` takes an :class:`~repro.dynamic.updates.UpdateBatch`
  (facility insert/delete/move, user insert/delete/move), builds version
  N+1 **off to the side** with structural sharing against version N, and
  publishes it with a single atomic reference swap.  Every piece of
  amortized engine state is reconciled with the delta rather than
  dropped:

  - **device user arrays** — pure user *moves* scatter functionally
    (``.at[idx].set`` returns new arrays; version N's stay untouched)
    into the new snapshot's resident ``xs``/``ys`` (and the mesh-sharded
    copies); only inserts/deletes force a re-upload;
  - **scene cache** — entries are migrated through the three-level
    survive / refit / rebuild ladder of :mod:`repro.dynamic.refit` into
    the new snapshot's cache: a scene whose pruning certificate the
    delta does not pierce is re-keyed (row ids remapped) and survives
    with its memoized grid/BVH indexes; a pierced scene whose kept set a
    re-prune confirms unchanged is patched (occluder fans of moved
    facilities respliced, indexes refit via ``Backend.refit_index``);
    everything else is dropped and rebuilt lazily.  Eager-refit vs
    lazy-rebuild is a priced decision
    (:class:`~repro.dynamic.policy.RefitPolicy`, fed by the planner's
    cost profile and its own observed EMAs);
  - **prepared-batch LRU / plan memos** — carried across the swap for
    user-only *move* deltas (requests re-pointed at the scattered device
    arrays; backends whose prepared state bakes in user coordinates are
    rebuilt — ``Backend.prepared_carries_users``); any facility or
    shape-changing delta starts the new version's LRU cold;
  - **continuous queries** — one *vectorized* influence-zone dirty test
    runs across all live :class:`~repro.dynamic.continuous.ContinuousQuery`
    handles per update (:func:`~repro.dynamic.continuous.influence_dirty_mask`);
    only the handles it marks dirty fall into the exact per-handle patch,
    the rest take a remap-and-skip fast path.

Equivalence contract (property-tested): after any sequence of
``apply_updates``, every query path on this engine returns bit-identical
results to a cold ``RkNNEngine`` built from ``(self.facilities,
self.users)`` — for every registered backend.
"""

from __future__ import annotations

import dataclasses
import threading

import numpy as np

import jax.numpy as jnp

from repro.core.backends import get_backend
from repro.core.engine import RkNNConfig, RkNNEngine
from repro.core.grid import (
    build_sleep,
    build_slept_s,
    build_throttle,
    build_yield_ratio,
)
from repro.obs import span
from repro.core.pruning import adaptive_grid
from repro.core.snapshot import EngineSnapshot
from repro.dynamic.continuous import ContinuousQuery, influence_dirty_mask
from repro.dynamic.policy import RefitPolicy
from repro.dynamic.refit import refit_scene, remap_scene, scene_update_safe
from repro.dynamic.updates import UpdateBatch, apply_to_points, changed_positions
from repro.planner.models import WorkloadShape

__all__ = ["DynamicEngine", "UpdateReport", "DynamicStats"]

#: Writer-side scene prewarm budget per update: standing scenes the
#: migration dropped are rebuilt into the NEXT snapshot before it
#: publishes (readers keep serving the current version meanwhile), capped
#: so one pathological delta cannot stall the writer indefinitely.
PREWARM_SCENES_CAP = 64


@dataclasses.dataclass
class UpdateReport:
    """What one :meth:`DynamicEngine.apply_updates` call did."""

    version: int
    t_update_s: float
    rect_changed: bool
    scenes_survived: int = 0
    scenes_refit: int = 0
    scenes_dropped: int = 0
    scenes_prewarmed: int = 0
    indexes_refit: int = 0
    indexes_rebuilt: int = 0
    users_scattered: bool = False
    batches_carried: int = 0
    continuous_patched: int = 0
    continuous_skipped: int = 0
    continuous_events: int = 0


@dataclasses.dataclass
class DynamicStats:
    """Cumulative counters across the engine's update lifetime."""

    n_updates: int = 0
    t_update_s: float = 0.0
    scenes_survived: int = 0
    scenes_refit: int = 0
    scenes_dropped: int = 0
    scenes_prewarmed: int = 0
    indexes_refit: int = 0
    indexes_rebuilt: int = 0
    user_scatters: int = 0
    user_reuploads: int = 0
    batches_carried: int = 0


class DynamicEngine(RkNNEngine):
    """A :class:`RkNNEngine` whose snapshot can change underneath it.

    Construction matches the static engine; all query methods are
    inherited unchanged.  Each query call resolves the engine's current
    :class:`~repro.core.snapshot.EngineSnapshot` exactly once at entry
    and serves that version end-to-end, so **queries run concurrently
    with updates without any lock on the read path**: an
    :meth:`apply_updates` racing a query (or an active :meth:`stream`)
    never produces a mixed old/new view — in-flight work finishes on
    version N while the swap publishes N+1, and every result reports the
    snapshot ``version`` it is bit-identical to.  Concurrent *writers*
    are serialized against each other by an internal writer lock.
    """

    def __init__(self, facilities, users, config: RkNNConfig | None = None, **kw):
        super().__init__(facilities, users, config, **kw)
        self.update_stats = DynamicStats()
        self.refit_policy = RefitPolicy()
        self._writer_lock = threading.Lock()  # writer-writer only
        self._continuous: list[ContinuousQuery] = []
        self._update_log: list[UpdateReport] = []

    @property
    def version(self) -> int:
        """The currently published snapshot's version (monotonic)."""
        return self._snap.version

    # ------------------------------------------------------------------
    # continuous queries
    # ------------------------------------------------------------------
    def register_continuous(self, q, k: int) -> ContinuousQuery:
        """Register a standing RkNN query (facility index or ``[2]``
        point); it is re-evaluated on exactly the updates that can change
        it and streams ``(version, RkNNResult)`` via ``poll()``."""
        snap = self._snap
        cq = ContinuousQuery(snap.facilities, snap.users, q, k, snap.version)
        self._continuous.append(cq)
        return cq

    def explain_updates(self) -> list[UpdateReport]:
        """Per-update reports, oldest first (bounded to the last 128)."""
        return list(self._update_log)

    # ------------------------------------------------------------------
    # observed rebuild costs feed the refit-vs-rebuild frontier
    # ------------------------------------------------------------------
    def _build_scene(
        self, snap: EngineSnapshot, q, k: int, rect, *, pad_to: int | None = None
    ):
        misses = snap.scene_cache.misses if snap.scene_cache is not None else None
        with span("scene-build", version=snap.version) as sb:
            scene = super()._build_scene(snap, q, k, rect, pad_to=pad_to)
        if (
            misses is not None
            and snap.scene_cache.misses > misses
            and build_yield_ratio() == 0.0
        ):
            # throttled (deprioritized-prewarm) builds sleep ~2x their CPU
            # time — feeding that wall time into the frontier would teach
            # the policy that rebuilds cost 3x what they do
            self.refit_policy.observe("rebuild", sb.elapsed_s)
        return scene

    # ------------------------------------------------------------------
    # the update path (the writer side of the MVCC pair)
    # ------------------------------------------------------------------
    def apply_updates(self, batch: UpdateBatch | None = None, **deltas) -> UpdateReport:
        """Apply one atomic delta; returns the new-version report.

        Accepts either a prebuilt :class:`UpdateBatch` or its fields as
        keyword arguments (``apply_updates(user_move=(ids, pts))``).
        Builds the next snapshot copy-on-write and publishes it with one
        atomic reference swap — concurrent queries are never blocked and
        never observe a partial update.
        """
        if batch is None:
            batch = UpdateBatch(**deltas)
        elif deltas:
            raise TypeError("pass either an UpdateBatch or keyword deltas, not both")
        try:
            return self._apply_updates_guarded(batch)
        except Exception as e:
            # black box: a writer crash leaves the engine serving the old
            # (still consistent) snapshot — dump what it was doing first
            self._flight_exception("apply_updates", e)
            raise

    def _apply_updates_guarded(self, batch: UpdateBatch) -> UpdateReport:
        with self._writer_lock:
            # Deprioritize the whole writer pass *dynamically*: the ratio
            # flips from 0 to 2.0 the moment a concurrent reader bumps the
            # read clock, making migration/refit/prewarm hot loops yield —
            # an idle engine (batch ingest, the refit-vs-rebuild bench)
            # never sleeps because the clock never moves mid-update.
            read_mark = self._read_clock
            slept_before = build_slept_s()
            with build_throttle(
                lambda: 2.0 if self._read_clock != read_mark else 0.0
            ):
                report = self._apply_updates_locked(batch)
            # writer-throttle duty cycle: fraction of the update's wall
            # time spent in deprioritization sleeps (0 on an idle engine)
            slept = build_slept_s() - slept_before
            if report.t_update_s > 0.0:
                self.metrics.gauge("mvcc.writer_throttle_duty").set(
                    slept / report.t_update_s
                )
            return report

    def _apply_updates_locked(self, batch: UpdateBatch) -> UpdateReport:
        old = self._snap
        batch.validate(len(old.facilities), len(old.users))
        with span("update", version=old.version + 1) as su:
            return self._apply_updates_span(batch, old, su)

    def _apply_updates_span(
        self, batch: UpdateBatch, old: EngineSnapshot, su
    ) -> UpdateReport:
        read_mark = self._read_clock  # readers seen since here => contended

        old_f, old_u = old.facilities, old.users
        old_rect = None if old.explicit_rect else old.rect
        old_fp = old.fingerprint()
        old_grid = adaptive_grid(len(old_f))  # pruning resolution regime

        new_f, map_f = apply_to_points(
            old_f, batch.facility_insert, batch.facility_delete, batch.facility_move
        )
        new_u, map_u = apply_to_points(
            old_u, batch.user_insert, batch.user_delete, batch.user_move
        )
        changed_pos = changed_positions(batch, old_f)

        # ---- build version N+1 off to the side ------------------------
        new = self._make_snapshot(
            old.version + 1,
            new_f,
            new_u,
            rect=old._rect if old.explicit_rect else None,
            explicit_rect=old.explicit_rect,
            scene_cache=None,  # installed below (migrated COW)
        )
        rect_changed = (not old.explicit_rect) and new.rect != old_rect
        if not batch.touches_facilities:
            new._fp = old._fp  # same facility content → same fingerprint

        report = UpdateReport(
            version=new.version, t_update_s=0.0, rect_changed=rect_changed
        )

        # ---- device-resident user coordinates -------------------------
        if batch.touches_users:
            self._cow_user_arrays(old, new, batch, report)
        else:
            # untouched users: carry device arrays by reference
            new._ys = old._ys
            new._xs = old._xs
            new.mesh_xs, new.mesh_ys = old.mesh_xs, old.mesh_ys
            new.mesh_n = old.mesh_n
            # the bucketing memo is content-addressed by the identity of
            # the carried xs array — safe to share across versions
            new.kernel_memo = old.kernel_memo

        # ---- scene cache + index memo: survive / refit / rebuild ------
        prewarm: list[tuple] = []
        if old.scene_cache is not None:
            with span("migrate", version=new.version):
                new.scene_cache, prewarm = self._migrate_scene_cache(
                    old, new, batch, old_fp, rect_changed,
                    old_grid, map_f, changed_pos, report,
                )

        # ---- prepared-batch LRU + plan memos --------------------------
        self._cow_batch_cache(old, new, batch, rect_changed, report)

        # ---- writer-side prewarm: rebuild dropped standing scenes into
        # the unpublished snapshot so readers never pay the host rebuild
        if prewarm:
            with span("prewarm", version=new.version):
                self._prewarm_scenes(new, prewarm, report, read_mark)

        # ---- publish: one atomic reference swap -----------------------
        self._snap = new

        # ---- continuous queries (reconciled against the new version) --
        ctx = _UpdateContext(
            batch=batch,
            old_facilities=old_f,
            new_facilities=new_f,
            old_users=old_u,
            new_users=new_u,
            map_f=map_f,
            map_u=map_u,
            version=new.version,
        )
        # closed/dead handles are dropped here, not at close() time — the
        # handle list is only ever touched on the (serialized) update path
        n_before = len(self._continuous)
        self._continuous = [cq for cq in self._continuous if cq.alive]
        if n_before > len(self._continuous):
            self.metrics.counter("continuous.pruned").inc(
                n_before - len(self._continuous)
            )
        if self._continuous:
            with span("continuous", version=new.version):
                dirty = self._dirty_continuous(batch, changed_pos)
                for cq, is_dirty in zip(self._continuous, dirty):
                    before = (
                        cq.n_patched, cq.n_skipped, cq.n_events, cq.events_dropped,
                    )
                    if is_dirty:
                        cq._on_update(ctx)
                    else:
                        cq._on_update_clean(ctx, len(changed_pos) > 0)
                    report.continuous_patched += cq.n_patched - before[0]
                    report.continuous_skipped += cq.n_skipped - before[1]
                    report.continuous_events += cq.n_events - before[2]
                    if cq.events_dropped > before[3]:
                        self.metrics.counter("continuous.events_dropped").inc(
                            cq.events_dropped - before[3]
                        )

        report.t_update_s = su.elapsed_s
        self.update_stats.n_updates += 1
        self.update_stats.t_update_s += report.t_update_s
        self.update_stats.scenes_survived += report.scenes_survived
        self.update_stats.scenes_refit += report.scenes_refit
        self.update_stats.scenes_dropped += report.scenes_dropped
        self.update_stats.scenes_prewarmed += report.scenes_prewarmed
        self.update_stats.indexes_refit += report.indexes_refit
        self.update_stats.indexes_rebuilt += report.indexes_rebuilt
        self.update_stats.batches_carried += report.batches_carried
        self._update_log.append(report)
        if len(self._update_log) > 128:
            del self._update_log[0]
        return report

    # ------------------------------------------------------------------
    def _dirty_continuous(self, batch: UpdateBatch, changed_pos: np.ndarray):
        """``[H]`` bool: which live handles this delta could actually touch.

        One vectorized influence-zone test across all standing queries
        (:func:`repro.dynamic.continuous.influence_dirty_mask`) replaces
        the per-handle Python loop; only handles marked dirty fall into
        the exact per-handle patch.  User-side deltas dirty every handle
        (rows/thresholds must be reconciled), and a handle whose own
        facility moved or died is always exact-patched (its influence
        geometry itself changes, which the distance test cannot certify).
        """
        n = len(self._continuous)
        if batch.touches_users:
            return np.ones(n, bool)
        dirty = influence_dirty_mask(self._continuous, changed_pos)
        own = np.concatenate([batch.facility_delete, batch.facility_move[0]])
        if len(own):
            q_idx = np.array(
                [-1 if cq.q_idx is None else cq.q_idx for cq in self._continuous]
            )
            dirty |= np.isin(q_idx, own)
        return dirty

    # ------------------------------------------------------------------
    def _cow_user_arrays(
        self,
        old: EngineSnapshot,
        new: EngineSnapshot,
        batch: UpdateBatch,
        report: UpdateReport,
    ) -> None:
        """Functional scatter into the new snapshot's device arrays for
        pure moves (version N's arrays stay untouched — readers of the
        old snapshot keep serving them); re-upload (lazily) on any shape
        change."""
        mv_ids, mv_pts = batch.user_move
        moves_only = (
            len(mv_ids) > 0
            and not len(batch.user_insert)
            and not len(batch.user_delete)
        )
        if moves_only:
            if old._xs is not None:
                idx = jnp.asarray(mv_ids)
                # ys before xs: a racing reader keyed on _xs sees both
                new._ys = old._ys.at[idx].set(jnp.asarray(mv_pts[:, 1], jnp.float32))
                new._xs = old._xs.at[idx].set(jnp.asarray(mv_pts[:, 0], jnp.float32))
                report.users_scattered = True
                self.update_stats.user_scatters += 1
        else:
            self.update_stats.user_reuploads += 1  # lazy re-upload on next use
        if self.mesh is not None:
            if moves_only and old.mesh_xs is not None:
                idx = jnp.asarray(mv_ids)
                new.mesh_xs = old.mesh_xs.at[idx].set(
                    jnp.asarray(mv_pts[:, 0], jnp.float32)
                )
                new.mesh_ys = old.mesh_ys.at[idx].set(
                    jnp.asarray(mv_pts[:, 1], jnp.float32)
                )
                new.mesh_n = old.mesh_n
            else:
                self._init_mesh(new, self.mesh)

    # ------------------------------------------------------------------
    def _cow_batch_cache(
        self,
        old: EngineSnapshot,
        new: EngineSnapshot,
        batch: UpdateBatch,
        rect_changed: bool,
        report: UpdateReport,
    ) -> None:
        """Carry prepared batches into the new snapshot for user-only
        deltas (moves, inserts, and hull-stable deletes alike).

        The prepared state of the dense/grid/bvh families is a pure
        function of the scenes (which a user-only delta cannot touch), so
        the expensive stacking survives verbatim — only the request's
        user-side references are re-pointed at the new snapshot's device
        arrays: the scattered ones for a pure move, the lazily re-uploaded
        (grown or shrunk) ones for an insert/delete.  The count dispatch
        sizes its ``[Q, N]`` output from those arrays at call time, so a
        changed |U| flows through without touching the prepared stack.
        Backends that bake user coordinates into their prepared state
        (``prepared_carries_users`` — the grid-pallas cell sort) are
        rebuilt lazily.  Facility deltas and rect changes (which an
        out-of-hull insert triggers) still start the new version cold:
        their scenes or keys are stale wholesale.
        """
        if batch.touches_facilities or rect_changed:
            return
        if not batch.touches_users:
            # nothing moved the users either: the whole LRU is still valid
            for key, value in old.batch_cache.items():
                new.batch_cache.put(key, value)
                report.batches_carried += 1
            return
        for key, value in old.batch_cache.items():
            if key[0] == "auto-plan":
                # assignment + scenes are user-count-independent; prices
                # shift negligibly under an incremental user delta
                new.batch_cache.put(key, value)
                report.batches_carried += 1
                continue
            b = get_backend(key[1] if key[0] == "auto" else key[0])
            if b.prepared_carries_users:
                continue
            req, prepared, scenes = value
            if req.dispatch is not None:
                dispatch = self._mesh_dispatch_for(new, b, rect=req.rect, k=req.k)
                if dispatch is None:
                    continue
                req = dataclasses.replace(
                    req, dispatch=dispatch, users=new.users, memo=new.kernel_memo
                )
            else:
                req = dataclasses.replace(
                    req,
                    xs=new.xs,
                    ys=new.ys,
                    users=new.users,
                    memo=new.kernel_memo,
                )
            new.batch_cache.put(key, (req, prepared, scenes))
            report.batches_carried += 1

    # ------------------------------------------------------------------
    def _migrate_scene_cache(
        self,
        old: EngineSnapshot,
        new: EngineSnapshot,
        batch: UpdateBatch,
        old_fp: int,
        rect_changed: bool,
        old_grid: int,
        map_f: np.ndarray,
        changed_pos: np.ndarray,
        report: UpdateReport,
    ):
        """The new snapshot's scene cache (COW), with surviving / refit
        scenes' index stores adopted into ``new.index_memo``.

        Returns ``(cache, prewarm)`` where ``prewarm`` lists the
        ``(q, k)`` of dropped standing entries whose query still exists
        post-update — :meth:`_prewarm_scenes` rebuilds those into the
        unpublished snapshot so readers never pay the rebuild."""
        cache = old.scene_cache
        prewarm: list[tuple] = []
        # Prewarm only when facility identity is stable (no insert/delete,
        # i.e. map_f is the identity): churn remaps row indices, so a
        # rebuilt scene would sit under the remapped id while standing
        # index-addressed workloads keep asking for the raw one — all of
        # the eager work would miss (measured: flips fchurn from ~1x to
        # a 0.4x loss).  Same stability condition as the refit attempt.
        stable_ids = not len(batch.facility_insert) and not len(batch.facility_delete)

        def note_drop(q_key, k):
            if not stable_ids:
                return
            if isinstance(q_key, (int, np.integer)):
                new_q = int(map_f[int(q_key)])
                if new_q >= 0:  # the query facility still exists
                    prewarm.append((new_q, k))
            else:
                prewarm.append((np.asarray(q_key, np.float64), k))

        def drop_all(key, scene):
            if key[0] == old_fp and key[3] == old.rect:
                note_drop(key[1], key[2])
            return None

        if rect_changed:
            # every cached scene was clipped against the old domain; a cold
            # engine would build different geometry — start cold
            new_cache, _, dropped = cache.cow_migrate(lambda key: True, drop_all)
            report.scenes_dropped += dropped
            return new_cache, prewarm
        if not batch.touches_facilities:
            # user-only delta with a stable hull: scenes depend on
            # (facilities, q, k, rect) alone — the cache is shared by
            # reference (it is append-only and internally locked) and
            # every index survives with its scene
            report.scenes_survived += len(cache)
            new.index_memo = old.index_memo.clone()
            return cache, prewarm
        # adaptive pruning-grid regime flip: a cold re-prune would run at a
        # different resolution — nothing survives
        if self.config.prune_grid is None and adaptive_grid(len(new.facilities)) != old_grid:
            new_cache, _, dropped = cache.cow_migrate(lambda key: True, drop_all)
            report.scenes_dropped += dropped
            return new_cache, prewarm

        new_fp = new.fingerprint()
        moved_ids_old = batch.facility_move[0]
        moved_new = map_f[moved_ids_old] if len(moved_ids_old) else np.zeros(0, np.int64)
        grid_param = self.config.prune_grid
        # Refit is only attempted for pure-move deltas: an insert/delete
        # that pierced a scene's certificate almost always changes its kept
        # set, so the attempt's re-prune (the expensive part) is a near-
        # certain write-off — measured to flip the churn regime from a win
        # to a 0.6x loss when attempted indiscriminately.
        moves_only = stable_ids

        def migrate(key, scene):
            _fp, q_key, k, rect = key
            if rect != new.rect:
                return None  # transient-rect entry (out-of-hull point query)
            if isinstance(q_key, (int, np.integer)):
                new_q = int(map_f[int(q_key)])
                if new_q < 0:
                    return None  # the query facility itself is gone
                if len(moved_ids_old) and np.any(moved_ids_old == q_key):
                    note_drop(q_key, k)  # still standing, at a new position
                    return None
                q_build: int | np.ndarray = new_q
                new_q_key: int | tuple = new_q
            else:
                q_build = np.asarray(q_key, np.float64)
                new_q_key = q_key
            if scene_update_safe(scene, changed_pos):
                report.scenes_survived += 1
                new_scene = remap_scene(scene, map_f, len(new.facilities))
                store = old.index_memo.peek(scene)
                if store is not None:  # indexes ride the surviving geometry
                    new.index_memo.adopt(new_scene, dict(store))
                return (new_fp, new_q_key, k, rect), new_scene
            # pierced certificate: priced eager-refit vs lazy-rebuild
            if not moves_only:
                note_drop(q_key, k)
                return None
            n = scene.n_tris
            owner_new = map_f[scene.owner[:n][scene.owner[:n] >= 0]]
            n_changed = (
                int(np.isin(owner_new, moved_new).sum()) if len(moved_new) else 0
            )
            shape = WorkloadShape(
                len(new.facilities), len(new.users), k, 1, m_tris=max(n, 1)
            )
            decision = self.refit_policy.price(shape, n_changed, n)
            if decision.action != "refit":
                note_drop(q_key, k)
                return None
            sr = span("refit", version=new.version)
            sr.__enter__()
            try:
                out = refit_scene(
                    scene,
                    map_f,
                    new.facilities,
                    q_build,
                    k,
                    rect,
                    moved_new,
                    strategy=self.config.strategy,
                    grid=grid_param,
                )
                if out is None:
                    # a bailed refit attempt is neither a refit nor a rebuild
                    # observation — feeding its (small) cost into either EMA
                    # would skew the frontier
                    note_drop(q_key, k)
                    return None
                new_scene, changed_tris = out
                store = old.index_memo.peek(scene)
                if store:
                    new_store = {}
                    refitted: dict[int, tuple] = {}  # grid/grid-pallas share one build
                    for (bname, g), index in store.items():
                        if index is None:  # index-less backend (dense paths)
                            new_store[(bname, g)] = None
                            continue
                        hit = refitted.get(id(index))
                        if hit is None:
                            hit = get_backend(bname).refit_index(
                                index, scene, new_scene, changed_tris, grid_g=g
                            )
                            refitted[id(index)] = hit
                            if hit[1]:
                                report.indexes_refit += 1
                            else:
                                report.indexes_rebuilt += 1
                        new_store[(bname, g)] = hit[0]
                    new.index_memo.adopt(new_scene, new_store)
            finally:
                sr.__exit__(None, None, None)
            self.refit_policy.observe("refit", sr.elapsed_s)
            report.scenes_refit += 1
            return (new_fp, new_q_key, k, rect), new_scene

        new_cache, _, dropped = cache.cow_migrate(
            lambda key: key[0] == old_fp, migrate
        )
        report.scenes_dropped += dropped
        return new_cache, prewarm

    def _prewarm_scenes(
        self,
        new: EngineSnapshot,
        pending: list[tuple],
        report: UpdateReport,
        read_mark: int,
    ) -> None:
        """Writer-side prewarm (the writer pays, readers never do).

        Standing scenes the migration dropped are rebuilt into the NEXT
        snapshot before it publishes — concurrent readers keep serving
        the current version meanwhile, and the first queries on the new
        version find warm scenes (and, for the engine's configured
        concrete backend, warm indexes) instead of stalling on the host
        rebuild.  Bounded by :data:`PREWARM_SCENES_CAP`.

        Prewarm is background maintenance, so under *contention* it runs
        deprioritized: the writer-wide dynamic :func:`~repro.core.grid.
        build_throttle` makes the classify/prune hot loops yield the GIL
        ~2x their own CPU time, and each rebuilt scene is additionally
        followed by a half-length sleep.  On a contended core that keeps
        concurrent readers at well over half the CPU — the publish just
        lands a little later, which MVCC makes harmless.  Contention is
        detected from the lock-free read clock (queries bump
        ``_read_clock``; the writer samples it per scene): an idle engine
        — the refit-vs-rebuild benchmark, batch ingest jobs — prewarms
        at full speed instead of sleeping for absent readers.
        """
        backend = get_backend(self.config.backend)
        warm_index = backend.uses_scene and not backend.is_meta
        for q_build, k in pending[:PREWARM_SCENES_CAP]:
            contended = self._read_clock != read_mark
            read_mark = self._read_clock
            with span("prewarm-scene", k=k) as sp:
                scene = self._build_scene(new, q_build, k, new.rect)
                if warm_index:
                    self._index_for(new, backend, scene)
            report.scenes_prewarmed += 1
            if contended:
                # coarse backstop for the build work outside the yielding
                # hot loops (COW copies, occluder geometry, list packing)
                build_sleep(0.5 * sp.elapsed_s)


@dataclasses.dataclass
class _UpdateContext:
    """Everything a continuous query needs to reconcile one update."""

    batch: UpdateBatch
    old_facilities: np.ndarray
    new_facilities: np.ndarray
    old_users: np.ndarray
    new_users: np.ndarray
    map_f: np.ndarray
    map_u: np.ndarray
    version: int
