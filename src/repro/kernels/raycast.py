"""Pallas TPU kernel: dense occluder hit counting (the paper's hot spot).

This is the ray-casting stage of Algorithm 1 adapted to the TPU (DESIGN.md
§2): the vertical-ray / layered-triangle intersection collapses to a 2-D
edge-function test, so the kernel is a tiled ``[users x occluders]``
containment-count:

* users are tiled along the grid's first axis — each program instance holds
  a ``(BU,)`` block of x/y in VMEM,
* all three edge-coefficient planes (``A, B, C`` of shape ``[3, M]``) are
  tiled along the second grid axis in lane-aligned ``(3, BM)`` blocks,
* the body broadcasts to a ``[BU, BM]`` mask (6 FMA + 3 compares + 2 ANDs
  per pair on the VPU) and accumulates row sums into the int32 output
  block, which is revisited across the ``M`` grid axis (accumulator
  pattern; zeroed at ``j == 0``).

The batched variant (``raycast_count_batch_kernel_call``) prepends a
``[Q]`` query axis to the grid: each program instance additionally selects
one query's coefficient planes, so a whole multi-query batch is one kernel
dispatch over shared user blocks — the serving hot path
(``repro.core.rknn.rt_rknn_query_batch``).

Early ray termination (Alg. 2 line 16) has no SIMD analogue; after
InfZone-style pruning the scene is so small (``m`` ≈ 40–70) that the sweep
is *user-read bound*, not test bound — see EXPERIMENTS.md §Perf-RkNN for
the measured arithmetic-intensity argument.

VMEM budget at the default tiles (BU=1024, BM=512): x/y blocks 8 KiB,
coefficient blocks 3·2·6 KiB, the ``[BU, BM]`` f32 broadcast temps
~2 MiB×3 live — comfortably under the ~16 MiB/core budget with double
buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import tpu_compiler_params

__all__ = [
    "raycast_count_kernel_call",
    "raycast_count_batch_kernel_call",
    "DEFAULT_BU",
    "DEFAULT_BM",
]

DEFAULT_BU = 1024  # users per block (8·128 sublane-aligned once reshaped)
DEFAULT_BM = 512  # occluders per block (4 lanes of 128)


def _raycast_kernel(x_ref, y_ref, a_ref, b_ref, c_ref, o_ref):
    """One (user-block, occluder-block) tile of the containment count."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...][:, None]  # [BU, 1]
    y = y_ref[...][:, None]
    a = a_ref[...]  # [3, BM]
    b = b_ref[...]
    c = c_ref[...]
    inside = (x * a[0][None, :] + y * b[0][None, :] + c[0][None, :]) >= 0.0
    inside &= (x * a[1][None, :] + y * b[1][None, :] + c[1][None, :]) >= 0.0
    inside &= (x * a[2][None, :] + y * b[2][None, :] + c[2][None, :]) >= 0.0
    o_ref[...] += jnp.sum(inside, axis=1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("bu", "bm", "interpret"))
def raycast_count_kernel_call(
    xs, ys, A, B, C, *, bu: int = DEFAULT_BU, bm: int = DEFAULT_BM, interpret: bool = True
):
    """Invoke the kernel on pre-padded inputs.

    ``xs, ys``: ``[Np]`` (``Np % bu == 0``); ``A, B, C``: ``[3, Mp]``
    (``Mp % bm == 0``) edge coefficients; padding slots must be degenerate
    (all-zero with ``c = -1``) so they contribute no hits.  Returns ``[Np]``
    int32 counts.  Padding/unpadding lives in :mod:`repro.kernels.ops`.
    """
    n_p = xs.shape[0]
    m_p = A.shape[1]
    grid = (n_p // bu, m_p // bm)
    return pl.pallas_call(
        _raycast_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bu,), lambda i, j: (i,)),
            pl.BlockSpec((bu,), lambda i, j: (i,)),
            pl.BlockSpec((3, bm), lambda i, j: (0, j)),
            pl.BlockSpec((3, bm), lambda i, j: (0, j)),
            pl.BlockSpec((3, bm), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bu,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_p,), jnp.int32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(xs, ys, A, B, C)


def _raycast_batch_kernel(x_ref, y_ref, a_ref, b_ref, c_ref, o_ref):
    """One (query, user-block, occluder-block) tile of the batched count.

    Identical math to :func:`_raycast_kernel`; the leading grid axis selects
    the query's coefficient planes while the user blocks are shared across
    all queries (the serving layout: one resident user set, many scenes).
    """
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...][:, None]  # [BU, 1]
    y = y_ref[...][:, None]
    a = a_ref[0]  # [3, BM] — this query's coefficient planes
    b = b_ref[0]
    c = c_ref[0]
    inside = (x * a[0][None, :] + y * b[0][None, :] + c[0][None, :]) >= 0.0
    inside &= (x * a[1][None, :] + y * b[1][None, :] + c[1][None, :]) >= 0.0
    inside &= (x * a[2][None, :] + y * b[2][None, :] + c[2][None, :]) >= 0.0
    o_ref[...] += jnp.sum(inside, axis=1, dtype=jnp.int32)[None, :]


@functools.partial(jax.jit, static_argnames=("bu", "bm", "interpret"))
def raycast_count_batch_kernel_call(
    xs, ys, A, B, C, *, bu: int = DEFAULT_BU, bm: int = DEFAULT_BM, interpret: bool = True
):
    """Batched multi-query invoke on pre-padded inputs.

    ``xs, ys``: ``[Np]`` shared users (``Np % bu == 0``); ``A, B, C``:
    ``[Q, 3, Mp]`` per-query edge-coefficient planes (``Mp % bm == 0``,
    padding degenerate).  Returns ``[Q, Np]`` int32 counts — one kernel
    dispatch for the whole query batch instead of ``Q`` separate launches.
    """
    n_p = xs.shape[0]
    q_n, _, m_p = A.shape
    grid = (q_n, n_p // bu, m_p // bm)
    return pl.pallas_call(
        _raycast_batch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bu,), lambda q, i, j: (i,)),
            pl.BlockSpec((bu,), lambda q, i, j: (i,)),
            pl.BlockSpec((1, 3, bm), lambda q, i, j: (q, 0, j)),
            pl.BlockSpec((1, 3, bm), lambda q, i, j: (q, 0, j)),
            pl.BlockSpec((1, 3, bm), lambda q, i, j: (q, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bu), lambda q, i, j: (q, i)),
        out_shape=jax.ShapeDtypeStruct((q_n, n_p), jnp.int32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(xs, ys, A, B, C)
