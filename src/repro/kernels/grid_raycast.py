"""Pallas TPU kernel: grid-culled hit counting (the BVH-analogue path).

For NON-pruned or conservatively-pruned scenes (paper §4.8, Table 3) the
occluder count is large enough that the dense sweep wastes work; the
paper's BVH bounds per-ray cost at ``O(k log m)``.  The TPU-native
equivalent (DESIGN.md §2) buckets users by grid cell and tests only the
cell's *partial-overlap* list, with fully-covering triangles absorbed into
a per-cell ``base`` counter (``repro.core.grid``).

Kernel layout: the host sorts users by cell id and pads each cell's user
run to a multiple of the block size; the kernel's grid iterates user
blocks with a **scalar-prefetch map** selecting, per step, which cell's
(padded) triangle-coefficient planes to stage into VMEM — predictable
block gathers instead of the BVH's pointer chasing.  Each program
instance evaluates ``[BU x L]`` edge functions and adds ``base[cell]``.

Validated against the ``core.grid`` jnp oracle in ``tests/test_kernels.py``.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.grid import OccluderGrid
from repro.kernels.compat import tpu_compiler_params

__all__ = ["prepare_cell_buckets", "pack_cell_coeff_planes", "grid_raycast_cells"]


def prepare_cell_buckets(xs, ys, rect, G: int, block: int = 256):
    """Host-side bucketing: sort users by cell; pad each cell to ``block``.

    Returns ``(xs_s, ys_s, order, cell_map, n_blocks)`` where ``order``
    maps sorted rows back to original rows (−1 for padding) and
    ``cell_map[b]`` is the cell id of user block ``b``.
    """
    xs = np.asarray(xs, np.float32)
    ys = np.asarray(ys, np.float32)
    w = rect.width / G
    h = rect.height / G
    cx = np.clip(np.floor((xs - rect.xmin) / w), 0, G - 1).astype(np.int64)
    cy = np.clip(np.floor((ys - rect.ymin) / h), 0, G - 1).astype(np.int64)
    cell = cx * G + cy
    order = np.argsort(cell, kind="stable")
    xs_parts, ys_parts, ord_parts, cells = [], [], [], []
    for c in np.unique(cell):
        rows = order[cell[order] == c]
        pad = (-len(rows)) % block
        xs_parts.append(np.concatenate([xs[rows], np.full(pad, 2e9, np.float32)]))
        ys_parts.append(np.concatenate([ys[rows], np.full(pad, 2e9, np.float32)]))
        ord_parts.append(np.concatenate([rows, np.full(pad, -1, np.int64)]))
        cells.extend([int(c)] * ((len(rows) + pad) // block))
    return (
        np.concatenate(xs_parts),
        np.concatenate(ys_parts),
        np.concatenate(ord_parts),
        np.asarray(cells, np.int32),
        len(cells),
    )


def pack_cell_coeff_planes(grid: OccluderGrid, lane_pad: int = 128):
    """``[G*G, 3(edges), 3(a,b,c), L]`` per-cell padded coefficient planes.

    Padding entries use the never-inside degenerate row (a=b=0, c=-1).
    """
    GG, L = grid.lists.shape
    L = max(lane_pad, ((L + lane_pad - 1) // lane_pad) * lane_pad)
    planes = np.zeros((GG, 3, 3, L), np.float32)
    planes[:, :, 2, :] = -1.0  # degenerate default
    coeffs = grid.coeffs  # [M, 3, 3]
    for cell in range(GG):
        tri_ids = grid.lists[cell]
        tri_ids = tri_ids[tri_ids >= 0]
        if len(tri_ids):
            # [n, 3, 3] -> [3(edge), 3(coef), n]
            planes[cell, :, :, : len(tri_ids)] = np.transpose(
                coeffs[tri_ids], (1, 2, 0)
            )
    return planes


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def grid_raycast_cells(
    xs_sorted, ys_sorted, cell_map, base, planes, *, block: int = 256, interpret: bool = True
):
    """Bucketed grid hit counting.

    ``xs_sorted/ys_sorted``: ``[n_blocks*block]`` f32 (cell-sorted, padded);
    ``cell_map``: ``[n_blocks]`` int32; ``base``: ``[G*G]`` int32;
    ``planes``: ``[G*G, 3, 3, L]`` from :func:`pack_cell_coeff_planes`.
    Returns counts ``[n_blocks*block]`` int32 (sorted order).
    """
    n_blocks = int(cell_map.shape[0])
    L = planes.shape[-1]

    def kernel(cell_map_ref, base_ref, x_ref, y_ref, p_ref, o_ref):
        x = x_ref[...][:, None]  # [BU, 1]
        y = y_ref[...][:, None]
        p = p_ref[0]  # [3, 3, L] — (edge, coeff, tri)
        inside = (x * p[0, 0][None, :] + y * p[0, 1][None, :] + p[0, 2][None, :]) >= 0.0
        inside &= (x * p[1, 0][None, :] + y * p[1, 1][None, :] + p[1, 2][None, :]) >= 0.0
        inside &= (x * p[2, 0][None, :] + y * p[2, 1][None, :] + p[2, 2][None, :]) >= 0.0
        i = pl.program_id(0)
        o_ref[...] = jnp.sum(inside, axis=1, dtype=jnp.int32) + base_ref[cell_map_ref[i]]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # cell_map, base
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i, cm, bs: (i,)),
            pl.BlockSpec((block,), lambda i, cm, bs: (i,)),
            pl.BlockSpec((1, 3, 3, L), lambda i, cm, bs: (cm[i], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i, cm, bs: (i,)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_blocks * block,), jnp.int32),
        compiler_params=tpu_compiler_params(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(cell_map, base, xs_sorted, ys_sorted, planes)
