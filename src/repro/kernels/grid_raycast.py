"""Pallas TPU kernels: grid-culled hit counting (the BVH-analogue path).

For NON-pruned or conservatively-pruned scenes (paper §4.8, Table 3) the
occluder count is large enough that the dense sweep wastes work; the
paper's BVH bounds per-ray cost at ``O(k log m)``.  The TPU-native
equivalent (DESIGN.md §2) buckets users by grid cell and tests only the
cell's *partial-overlap* list, with fully-covering triangles absorbed into
a per-cell ``base`` counter (``repro.core.grid``).

Kernel layout: the host sorts users by cell id and pads each cell's user
run to a multiple of the block size; the kernel's grid iterates user
blocks with a **scalar-prefetch map** selecting, per step, which cell's
(padded) triangle-coefficient planes to stage into VMEM — predictable
block gathers instead of the BVH's pointer chasing.  Each program
instance evaluates ``[BU x L]`` edge functions (and, on the single-query
path, adds ``base[cell]``).

The batched form (:func:`grid_raycast_cells_batch`) extends the grid to
``(Q, user-block)``: the user→cell sort is computed ONCE per batch (all
stacked scenes share one domain rect) and each program stages one query's
planes for one cell — this replaces the batched jnp path's gather-bound
``[Q, N, L, 3, 3]`` temporary with ``[BU x L]`` edge evaluations plus a
``base[q, cell]`` add.

Validated against the ``core.grid`` jnp oracle in ``tests/test_kernels.py``
and ``tests/test_grid_pallas.py``.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.grid import OccluderGrid
from repro.kernels.compat import tpu_compiler_params

__all__ = [
    "auto_cell_block",
    "measured_pad_waste",
    "prepare_cell_buckets",
    "pack_cell_coeff_planes",
    "repack_cell_coeff_planes",
    "grid_raycast_cells",
    "grid_raycast_cells_batch",
    "unsort_cell_counts",
]

#: Coordinate filler for padded user slots: far outside every domain rect,
#: and the rows are dropped by :func:`unsort_cell_counts` regardless.
_PAD_COORD = np.float32(2e9)


def auto_cell_block(n_users: int, n_occupied_cells: int) -> int:
    """Pick the per-cell user block size for a bucketing.

    Every occupied cell pads its user run up to a block multiple, so the
    padded total is ~``n + occupied * block``: a block near the mean cell
    occupancy keeps the waste bounded at ~2x while staying sublane-aligned
    (multiples of 8) for the TPU layout.  Clamped to [8, 256].
    """
    occ = max(int(n_occupied_cells), 1)
    mean = max(int(np.ceil(n_users / occ)), 1)
    return int(min(256, max(8, 1 << int(np.ceil(np.log2(mean))))))


def measured_pad_waste(xs, ys, rect, G: int) -> float:
    """Exact pad-waste ratio of :func:`prepare_cell_buckets` at
    ``block=None``: padded user rows / real user rows (≥ 1).

    The cell-bucketed kernels' verify cost tracks the *padded* total
    (``~ n + occupied · block``), not the raw user count — this ratio is
    the planner's occupancy feature (``log_pw``).  Computed from the same
    cell classification and :func:`auto_cell_block` choice as the real
    bucketing, without the sort or the scatter.
    """
    xs = np.asarray(xs, np.float32)
    ys = np.asarray(ys, np.float32)
    n = len(xs)
    if n == 0:
        return 1.0
    w = rect.width / G
    h = rect.height / G
    cx = np.clip(np.floor((xs - rect.xmin) / w), 0, G - 1).astype(np.int64)
    cy = np.clip(np.floor((ys - rect.ymin) / h), 0, G - 1).astype(np.int64)
    _uniq, lens = np.unique(cx * G + cy, return_counts=True)
    block = auto_cell_block(n, len(lens))
    padded = ((lens + block - 1) // block) * block
    return float(max(int(padded.sum()) / n, 1.0))


def prepare_cell_buckets(xs, ys, rect, G: int, block: int | None = 256):
    """Host-side bucketing: sort users by cell; pad each cell to ``block``.

    Returns ``(xs_s, ys_s, order, cell_map, n_blocks)`` where ``order``
    maps sorted rows back to original rows (−1 for padding) and
    ``cell_map[b]`` is the cell id of user block ``b``.  ``block=None``
    picks :func:`auto_cell_block` from the measured cell occupancy.

    Fully vectorized: run boundaries come from ``np.searchsorted`` on the
    sorted cell ids and every padded destination index is computed in one
    shot — O(N log N) for the sort, O(N + cells) after, replacing the old
    per-unique-cell rescan of the full cell array (O(U · cells) host time
    inside ``t_filter_s``).
    """
    xs = np.asarray(xs, np.float32)
    ys = np.asarray(ys, np.float32)
    n = len(xs)
    if n == 0:
        return (
            np.zeros(0, np.float32),
            np.zeros(0, np.float32),
            np.zeros(0, np.int64),
            np.zeros(0, np.int32),
            0,
        )
    w = rect.width / G
    h = rect.height / G
    cx = np.clip(np.floor((xs - rect.xmin) / w), 0, G - 1).astype(np.int64)
    cy = np.clip(np.floor((ys - rect.ymin) / h), 0, G - 1).astype(np.int64)
    cell = cx * G + cy
    order = np.argsort(cell, kind="stable")
    cell_sorted = cell[order]
    uniq = np.unique(cell)
    starts = np.searchsorted(cell_sorted, uniq, side="left")
    ends = np.searchsorted(cell_sorted, uniq, side="right")
    lens = ends - starts
    if block is None:
        block = auto_cell_block(n, len(uniq))
    block = int(block)
    padded = ((lens + block - 1) // block) * block
    offsets = np.concatenate([[0], np.cumsum(padded)[:-1]])
    total = int(padded.sum())
    xs_s = np.full(total, _PAD_COORD, np.float32)
    ys_s = np.full(total, _PAD_COORD, np.float32)
    ord_s = np.full(total, -1, np.int64)
    run_id = np.repeat(np.arange(len(uniq)), lens)
    dest = offsets[run_id] + (np.arange(n) - starts[run_id])
    xs_s[dest] = xs[order]
    ys_s[dest] = ys[order]
    ord_s[dest] = order
    cell_map = np.repeat(uniq, padded // block).astype(np.int32)
    return xs_s, ys_s, ord_s, cell_map, len(cell_map)


def _fill_cell_planes(planes: np.ndarray, grid: OccluderGrid, cells) -> None:
    """Write the ``[3, 3, L]`` coefficient planes of ``cells`` in place.

    List-slot positions are preserved (a ``-1`` hole left by
    ``refit_grid`` stays a degenerate plane in place), so an incremental
    re-pack is bit-identical to a fresh :func:`pack_cell_coeff_planes`.
    """
    cells = np.asarray(cells, np.int64)
    if not len(cells) or not len(grid.coeffs):
        return
    lists = grid.lists[cells]  # [C, L]
    valid = lists >= 0
    gathered = np.transpose(
        grid.coeffs[np.maximum(lists, 0)], (0, 2, 3, 1)
    )  # [C, 3, 3, L]
    deg = np.zeros((3, 3, 1), np.float32)
    deg[:, 2, :] = -1.0
    planes[cells, :, :, : lists.shape[1]] = np.where(
        valid[:, None, None, :], gathered, deg
    )


def pack_cell_coeff_planes(grid: OccluderGrid, lane_pad: int = 128):
    """``[G*G, 3(edges), 3(a,b,c), L]`` per-cell padded coefficient planes.

    Padding entries use the never-inside degenerate row (a=b=0, c=-1).
    ``lane_pad`` rounds ``L`` up to the TPU lane width for the compiled
    Mosaic kernel; the jnp reference execution passes ``lane_pad=1`` so
    its edge evaluations stop at the real max list length.
    """
    GG, L = grid.lists.shape
    L = max(lane_pad, ((L + lane_pad - 1) // lane_pad) * lane_pad, 1)
    planes = np.zeros((GG, 3, 3, L), np.float32)
    planes[:, :, 2, :] = -1.0  # degenerate default
    occupied = np.flatnonzero((grid.lists >= 0).any(axis=1))
    _fill_cell_planes(planes, grid, occupied)
    return planes


def repack_cell_coeff_planes(
    planes: np.ndarray, grid: OccluderGrid, cells: np.ndarray
) -> np.ndarray:
    """Incrementally re-pack only ``cells`` of a packed plane array.

    ``planes`` must have been packed from a grid with the same list width
    and lane padding (the refit contract: ``refit_grid`` preserves the
    padded list shape).  Returns a new array; the input is not mutated
    (cached indexes may still alias it).
    """
    out = planes.copy()
    _fill_cell_planes(out, grid, np.asarray(cells, np.int64))
    return out


def unsort_cell_counts(counts: np.ndarray, order: np.ndarray, n: int) -> np.ndarray:
    """Scatter bucketed counts ``[..., Ns]`` back to user order ``[..., n]``,
    dropping the ``order == -1`` padding rows."""
    counts = np.asarray(counts)
    ok = order >= 0
    out = np.zeros(counts.shape[:-1] + (n,), np.int32)
    out[..., order[ok]] = counts[..., ok]
    return out


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _grid_raycast_cells_call(
    xs_sorted, ys_sorted, cell_map, base, planes, *, block: int, interpret: bool
):
    n_blocks = int(cell_map.shape[0])
    L = planes.shape[-1]

    def kernel(cell_map_ref, base_ref, x_ref, y_ref, p_ref, o_ref):
        x = x_ref[...][:, None]  # [BU, 1]
        y = y_ref[...][:, None]
        p = p_ref[0]  # [3, 3, L] — (edge, coeff, tri)
        inside = (x * p[0, 0][None, :] + y * p[0, 1][None, :] + p[0, 2][None, :]) >= 0.0
        inside &= (x * p[1, 0][None, :] + y * p[1, 1][None, :] + p[1, 2][None, :]) >= 0.0
        inside &= (x * p[2, 0][None, :] + y * p[2, 1][None, :] + p[2, 2][None, :]) >= 0.0
        i = pl.program_id(0)
        o_ref[...] = jnp.sum(inside, axis=1, dtype=jnp.int32) + base_ref[cell_map_ref[i]]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # cell_map, base
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i, cm, bs: (i,)),
            pl.BlockSpec((block,), lambda i, cm, bs: (i,)),
            pl.BlockSpec((1, 3, 3, L), lambda i, cm, bs: (cm[i], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i, cm, bs: (i,)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_blocks * block,), jnp.int32),
        compiler_params=tpu_compiler_params(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(cell_map, base, xs_sorted, ys_sorted, planes)


def grid_raycast_cells(
    xs_sorted,
    ys_sorted,
    cell_map,
    base,
    planes,
    *,
    block: int = 256,
    interpret: bool | None = None,
):
    """Bucketed grid hit counting (single query).

    ``xs_sorted/ys_sorted``: ``[n_blocks*block]`` f32 (cell-sorted, padded);
    ``cell_map``: ``[n_blocks]`` int32; ``base``: ``[G*G]`` int32;
    ``planes``: ``[G*G, 3, 3, L]`` from :func:`pack_cell_coeff_planes`.
    Returns counts ``[n_blocks*block]`` int32 (sorted order).
    ``interpret=None`` auto-detects like every wrapper in
    :mod:`repro.kernels.ops` — a real TPU runs the compiled Mosaic kernel.
    """
    if interpret is None:
        from repro.kernels.ops import pallas_interpret_default

        interpret = pallas_interpret_default()
    return _grid_raycast_cells_call(
        xs_sorted, ys_sorted, cell_map, base, planes,
        block=block, interpret=bool(interpret),
    )


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def grid_raycast_cells_batch(
    xs_sorted, ys_sorted, cell_map, planes, *, block: int, interpret: bool
):
    """Batched bucketed counting: one ``(q, user-block)`` grid dispatch.

    ``planes``: ``[Q, G*G, 3, 3, L]`` stacked per-query cell planes; the
    user sort (``xs_sorted``/``ys_sorted``/``cell_map``) is shared across
    queries (one domain rect per batch).  Each program instance stages one
    query's planes for one cell and evaluates ``[BU x L]`` edge functions.
    Returns partial-list hit counts ``[Q, n_blocks*block]`` int32 in
    sorted order — the caller adds ``base[q, cell]`` (kept out of SMEM:
    ``[Q, G*G]`` scalars would not fit the prefetch budget at serving Q).
    """
    n_blocks = int(cell_map.shape[0])
    q_n = int(planes.shape[0])
    L = planes.shape[-1]

    def kernel(cell_map_ref, x_ref, y_ref, p_ref, o_ref):
        x = x_ref[...][:, None]  # [BU, 1]
        y = y_ref[...][:, None]
        p = p_ref[0, 0]  # [3, 3, L]
        inside = (x * p[0, 0][None, :] + y * p[0, 1][None, :] + p[0, 2][None, :]) >= 0.0
        inside &= (x * p[1, 0][None, :] + y * p[1, 1][None, :] + p[1, 2][None, :]) >= 0.0
        inside &= (x * p[2, 0][None, :] + y * p[2, 1][None, :] + p[2, 2][None, :]) >= 0.0
        o_ref[0, :] = jnp.sum(inside, axis=1, dtype=jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # cell_map
        grid=(q_n, n_blocks),
        in_specs=[
            pl.BlockSpec((block,), lambda i, j, cm: (j,)),
            pl.BlockSpec((block,), lambda i, j, cm: (j,)),
            pl.BlockSpec((1, 1, 3, 3, L), lambda i, j, cm: (i, cm[j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i, j, cm: (i, j)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((q_n, n_blocks * block), jnp.int32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(cell_map, xs_sorted, ys_sorted, planes)
