"""JAX version-compat helpers shared by every Pallas kernel module."""

from __future__ import annotations

from jax.experimental.pallas import tpu as _pltpu

__all__ = ["tpu_compiler_params"]


def tpu_compiler_params(**kwargs):
    """Version-compat constructor for Pallas TPU compiler params.

    Newer JAX exposes ``pltpu.CompilerParams``; older releases (including
    the pinned 0.4.x here) only have ``pltpu.TPUCompilerParams``.  All
    kernel call sites go through this helper so the kernels load on both.
    """
    cls = getattr(_pltpu, "CompilerParams", None)
    if cls is None:
        cls = _pltpu.TPUCompilerParams
    return cls(**kwargs)
