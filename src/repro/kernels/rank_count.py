"""Pallas TPU kernel: distance-rank counting (brute / "InfZone-GPU" path).

The paper's Fig. 17 baseline offloads InfZone's verification to the GPU
without RT cores; the TPU equivalent is this dense rank count — for every
user, the number of facilities strictly closer than the query facility:

    count[u] = #{ f : (x_u - fx_f)^2 + (y_u - fy_f)^2 < thr_u },
    thr_u    = dist^2(u, q).

It shares the tiling scheme of :mod:`repro.kernels.raycast` (users on the
first grid axis, facilities lane-tiled on the second, int32 accumulator
revisited across facility blocks).  It doubles as the *exact* RkNN oracle
on device: ``count[u] < k ⇔ u ∈ RkNN(q)``, which makes it both the
correctness anchor for the ray-cast kernels and the measured no-RT baseline
in ``benchmarks/bench_no_rt.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import tpu_compiler_params

__all__ = ["rank_count_kernel_call", "DEFAULT_BU", "DEFAULT_BM"]

DEFAULT_BU = 1024
DEFAULT_BM = 512


def _rank_kernel(x_ref, y_ref, fx_ref, fy_ref, t_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...][:, None]  # [BU, 1]
    y = y_ref[...][:, None]
    t = t_ref[...][:, None]
    dx = x - fx_ref[...][None, :]  # [BU, BM]
    dy = y - fy_ref[...][None, :]
    closer = dx * dx + dy * dy < t
    o_ref[...] += jnp.sum(closer, axis=1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("bu", "bm", "interpret"))
def rank_count_kernel_call(
    xs, ys, fx, fy, thr, *, bu: int = DEFAULT_BU, bm: int = DEFAULT_BM, interpret: bool = True
):
    """Pre-padded invoke: ``xs, ys, thr`` are ``[Np]``; ``fx, fy`` are
    ``[Mp]`` with padding facilities pushed to +inf (never closer)."""
    n_p = xs.shape[0]
    m_p = fx.shape[0]
    grid = (n_p // bu, m_p // bm)
    return pl.pallas_call(
        _rank_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bu,), lambda i, j: (i,)),
            pl.BlockSpec((bu,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (j,)),
            pl.BlockSpec((bm,), lambda i, j: (j,)),
            pl.BlockSpec((bu,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((bu,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_p,), jnp.int32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(xs, ys, fx, fy, thr)
