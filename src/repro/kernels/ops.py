"""Public jit'd wrappers around the Pallas kernels.

Handles padding to tile multiples, layout packing (``[M,3,3]`` coeffs →
``A/B/C`` planes), backend selection and unpadding.  On this CPU container
the kernels execute in interpret mode (bit-faithful to the TPU lowering's
semantics); on a real TPU set ``REPRO_PALLAS_INTERPRET=0`` (or rely on the
auto-detection) to run the compiled Mosaic kernels.  ``backend="ref"``
routes to the pure-jnp oracle — the fast path on CPU and the baseline the
kernels are benchmarked against.
"""

from __future__ import annotations

import functools
import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.obs.jitmon import track_jit
from repro.kernels.raycast import (
    raycast_count_batch_kernel_call,
    raycast_count_kernel_call,
)
from repro.kernels.rank_count import rank_count_kernel_call

__all__ = [
    "raycast_count",
    "raycast_count_batch",
    "rank_count",
    "rank_count_batch",
    "grid_count_cells",
    "grid_count_cells_batch",
    "pallas_interpret_default",
]

_USER_CHUNK = 32_768  # bounds the [chunk, M, 3] broadcast temp (~40 MB f32)


@functools.partial(jax.jit, static_argnames=("chunk",))
def _raycast_ref_chunked(xs, ys, coeffs, chunk: int = _USER_CHUNK):
    """Jitted + user-chunked oracle path (the fast CPU execution)."""
    n = xs.shape[0]
    pad = (-n) % chunk
    xs_p = jnp.pad(xs, (0, pad))
    ys_p = jnp.pad(ys, (0, pad))
    xc = xs_p.reshape(-1, chunk)
    yc = ys_p.reshape(-1, chunk)
    out = jax.lax.map(lambda xy: _ref.raycast_count_ref(xy[0], xy[1], coeffs), (xc, yc))
    return out.reshape(-1)[:n]


@jax.jit
def _rank_ref_jit(xs, ys, fx, fy, thr):
    return _ref.rank_count_ref(xs, ys, fx, fy, thr)


def pallas_interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _pad1(x: jnp.ndarray, mult: int, value: float) -> jnp.ndarray:
    n = x.shape[0]
    p = (-n) % mult
    if p == 0:
        return x
    return jnp.concatenate([x, jnp.full((p,), value, x.dtype)])


def _effective_blocks(n: int, m: int, bu: int, bm: int) -> tuple[int, int]:
    """Shrink tile sizes to the pow2 envelope of the problem.

    Shared by the single-query and batched wrappers so their layouts can't
    drift apart."""
    bu_eff = min(bu, max(8, 1 << max(int(np.ceil(np.log2(max(n, 1)))), 3)))
    bm_eff = min(bm, max(128, 1 << max(int(np.ceil(np.log2(max(m, 1)))), 7)))
    return bu_eff, bm_eff


def _coeff_planes(coeffs, bm_eff: int):
    """``[..., M, 3, 3]`` coeffs → ``(A, B, C)`` ``[..., 3, Mp]`` planes,
    lane-padded with never-inside rows (``a = b = 0, c = -1``)."""
    A = jnp.swapaxes(coeffs[..., 0], -1, -2)
    B = jnp.swapaxes(coeffs[..., 1], -1, -2)
    C = jnp.swapaxes(coeffs[..., 2], -1, -2)
    pm = (-A.shape[-1]) % bm_eff
    if pm:
        pad = A.shape[:-1] + (pm,)
        A = jnp.concatenate([A, jnp.zeros(pad, A.dtype)], axis=-1)
        B = jnp.concatenate([B, jnp.zeros(pad, B.dtype)], axis=-1)
        C = jnp.concatenate([C, jnp.full(pad, -1.0, C.dtype)], axis=-1)
    return A, B, C


def raycast_count(
    xs,
    ys,
    coeffs,
    *,
    backend: str = "pallas",
    bu: int = 1024,
    bm: int = 512,
    interpret: bool | None = None,
):
    """Hit counts of users against occluder edge functions.

    ``xs, ys``: ``[N]``; ``coeffs``: ``[M, 3, 3]``.  Returns ``[N]`` int32.
    Padding slots are degenerate (``a=b=0, c=-1``) and contribute nothing.
    """
    xs = jnp.asarray(xs, jnp.float32)
    ys = jnp.asarray(ys, jnp.float32)
    coeffs = jnp.asarray(coeffs, jnp.float32)
    if backend == "ref":
        if xs.shape[0] > _USER_CHUNK:
            return _raycast_ref_chunked(xs, ys, coeffs)
        return _ref.raycast_count_ref(xs, ys, coeffs)
    if backend != "pallas":
        raise ValueError(f"unknown backend {backend!r}")
    if interpret is None:
        interpret = pallas_interpret_default()
    n = xs.shape[0]
    bu_eff, bm_eff = _effective_blocks(n, coeffs.shape[0], bu, bm)
    xs_p = _pad1(xs, bu_eff, 0.0)
    ys_p = _pad1(ys, bu_eff, 0.0)
    A, B, C = _coeff_planes(coeffs, bm_eff)
    out = raycast_count_kernel_call(
        xs_p, ys_p, A, B, C, bu=bu_eff, bm=bm_eff, interpret=bool(interpret)
    )
    return out[:n]


@jax.jit
def _raycast_batch_ref_jit(xs, ys, coeffs):
    return _ref.raycast_count_batch_ref(xs, ys, coeffs)


@functools.partial(jax.jit, static_argnames=("chunk",))
def _raycast_batch_ref_chunked(xs, ys, coeffs, chunk: int = _USER_CHUNK):
    """Jitted + user-chunked batched oracle: bounds the ``[Q, chunk, M, 3]``
    broadcast temp the same way the single-query path does, so large user
    sets don't blow the host heap under a big query batch."""
    n = xs.shape[0]
    pad = (-n) % chunk
    xs_p = jnp.pad(xs, (0, pad))
    ys_p = jnp.pad(ys, (0, pad))
    xc = xs_p.reshape(-1, chunk)
    yc = ys_p.reshape(-1, chunk)
    out = jax.lax.map(
        lambda xy: _ref.raycast_count_batch_ref(xy[0], xy[1], coeffs), (xc, yc)
    )  # [n_chunks, Q, chunk]
    return jnp.moveaxis(out, 1, 0).reshape(coeffs.shape[0], -1)[:, :n]


def raycast_count_batch(
    xs,
    ys,
    coeffs,
    *,
    backend: str = "pallas",
    bu: int = 1024,
    bm: int = 512,
    interpret: bool | None = None,
):
    """Batched multi-query hit counts: one dispatch for a whole query batch.

    ``xs, ys``: ``[N]`` shared users; ``coeffs``: ``[Q, Mp, 3, 3]`` stacked
    per-query edge functions (padded degenerate — see
    :func:`repro.core.scene.pad_scene_arrays`).  Returns ``[Q, N]`` int32.
    ``backend="ref"`` runs the jitted vmap oracle (the fast CPU path);
    ``backend="pallas"`` runs the ``[Q]``-grid-axis kernel.
    """
    xs = jnp.asarray(xs, jnp.float32)
    ys = jnp.asarray(ys, jnp.float32)
    coeffs = jnp.asarray(coeffs, jnp.float32)
    if coeffs.ndim != 4:
        raise ValueError(f"coeffs must be [Q, Mp, 3, 3], got {coeffs.shape}")
    if backend == "ref":
        # keep the [Q, chunk, M, 3] broadcast temp the same size as the
        # single-query path's [chunk, M, 3] by shrinking chunk with Q
        chunk = max(1024, _USER_CHUNK // max(int(coeffs.shape[0]), 1))
        if xs.shape[0] > chunk:
            return _raycast_batch_ref_chunked(xs, ys, coeffs, chunk=chunk)
        return _raycast_batch_ref_jit(xs, ys, coeffs)
    if backend != "pallas":
        raise ValueError(f"unknown backend {backend!r}")
    if interpret is None:
        interpret = pallas_interpret_default()
    n = xs.shape[0]
    bu_eff, bm_eff = _effective_blocks(n, coeffs.shape[1], bu, bm)
    xs_p = _pad1(xs, bu_eff, 0.0)
    ys_p = _pad1(ys, bu_eff, 0.0)
    A, B, C = _coeff_planes(coeffs, bm_eff)
    out = raycast_count_batch_kernel_call(
        xs_p, ys_p, A, B, C, bu=bu_eff, bm=bm_eff, interpret=bool(interpret)
    )
    return out[:, :n]


#: Element budget for one [Q, chunk, block, L] edge-evaluation temp of the
#: bucketed ref path (~16 MB f32) — mirrors _USER_CHUNK's role on the
#: dense path.
_CELL_CHUNK_ELEMS = 4_194_304


@functools.partial(jax.jit, static_argnames=("chunk",))
def _grid_cells_batch_ref_chunked(xs_b, ys_b, cell_map, planes, chunk: int):
    """Jitted + block-chunked bucketed oracle: bounds the
    ``[Q, chunk, block, L]`` edge-evaluation temp so large user sets don't
    blow the host heap under a big query batch (same convention as
    ``_raycast_batch_ref_chunked``)."""
    nb, block = xs_b.shape
    pad = (-nb) % chunk
    xs_p = jnp.pad(xs_b, ((0, pad), (0, 0)), constant_values=2e9)
    ys_p = jnp.pad(ys_b, ((0, pad), (0, 0)), constant_values=2e9)
    cm_p = jnp.pad(cell_map, (0, pad))
    xc = xs_p.reshape(-1, chunk, block)
    yc = ys_p.reshape(-1, chunk, block)
    cc = cm_p.reshape(-1, chunk)

    def one(args):
        x, y, cm = args
        return _ref.grid_cells_count_batch_ref(
            x.reshape(-1), y.reshape(-1), cm, planes
        )  # [Q, chunk*block]

    out = jax.lax.map(one, (xc, yc, cc))  # [n_chunks, Q, chunk*block]
    q_n = planes.shape[0]
    return jnp.moveaxis(out, 1, 0).reshape(q_n, -1)[:, : nb * block]


@jax.jit
def _grid_cells_batch_ref_jit(xs_s, ys_s, cell_map, planes):
    return _ref.grid_cells_count_batch_ref(xs_s, ys_s, cell_map, planes)


def grid_count_cells_batch(
    xs_sorted,
    ys_sorted,
    cell_map,
    base,
    planes,
    *,
    block: int,
    backend: str = "pallas",
    interpret: bool | None = None,
):
    """Batched cell-bucketed grid hit counts: ``[Q, n_sorted]`` int32.

    ``xs_sorted/ys_sorted``: ``[n_blocks*block]`` cell-sorted padded users
    (from :func:`repro.kernels.grid_raycast.prepare_cell_buckets` — the
    sort is shared across the batch's queries, one domain rect);
    ``cell_map``: ``[n_blocks]``; ``base``: ``[Q, G*G]``; ``planes``:
    ``[Q, G*G, 3, 3, L]`` stacked per-query cell coefficient planes.
    Counts stay in sorted order — unscatter with
    :func:`repro.kernels.grid_raycast.unsort_cell_counts`.
    """
    from repro.kernels.grid_raycast import grid_raycast_cells_batch

    xs_sorted = jnp.asarray(xs_sorted, jnp.float32)
    ys_sorted = jnp.asarray(ys_sorted, jnp.float32)
    base = jnp.asarray(base, jnp.int32)
    planes = jnp.asarray(planes, jnp.float32)
    q_n = planes.shape[0]
    nb = int(cell_map.shape[0])
    if nb == 0:
        return jnp.zeros((q_n, 0), jnp.int32)
    cell_map = jnp.asarray(cell_map, jnp.int32)
    if backend == "ref":
        L = int(planes.shape[-1])
        chunk = max(int(_CELL_CHUNK_ELEMS) // max(q_n * block * L, 1), 1)
        if chunk < nb:
            chunk = max(1 << int(np.log2(chunk)), 1)  # sticky pow2: fewer retraces
            counts = _grid_cells_batch_ref_chunked(
                xs_sorted.reshape(nb, block),
                ys_sorted.reshape(nb, block),
                cell_map,
                planes,
                chunk=chunk,
            )
        else:
            counts = _grid_cells_batch_ref_jit(xs_sorted, ys_sorted, cell_map, planes)
    elif backend == "pallas":
        if interpret is None:
            interpret = pallas_interpret_default()
        counts = grid_raycast_cells_batch(
            xs_sorted, ys_sorted, cell_map, planes,
            block=block, interpret=bool(interpret),
        )
    else:
        raise ValueError(f"unknown backend {backend!r}")
    # base[q, cell] is added outside the kernel: a [Q, G*G] scalar table
    # has no place in the prefetch SMEM budget at serving Q
    cells_u = jnp.repeat(cell_map, block)  # [n_sorted]
    return counts + base[:, cells_u]


def grid_count_cells(
    xs_sorted,
    ys_sorted,
    cell_map,
    base,
    planes,
    *,
    block: int,
    backend: str = "pallas",
    interpret: bool | None = None,
):
    """Single-query bucketed grid hit counts: ``[n_sorted]`` int32.

    ``base``: ``[G*G]``; ``planes``: ``[G*G, 3, 3, L]``.  Same contract as
    :func:`grid_count_cells_batch` at ``Q = 1``.
    """
    return grid_count_cells_batch(
        xs_sorted,
        ys_sorted,
        cell_map,
        jnp.asarray(base, jnp.int32)[None],
        jnp.asarray(planes, jnp.float32)[None],
        block=block,
        backend=backend,
        interpret=interpret,
    )[0]


def rank_count(
    users,
    facilities,
    q,
    *,
    exclude: int | None = None,
    backend: str = "pallas",
    bu: int = 1024,
    bm: int = 512,
    interpret: bool | None = None,
):
    """#facilities strictly closer than ``q`` per user (``[N]`` int32).

    ``users``: ``[N, 2]``; ``facilities``: ``[M, 2]``; ``q``: ``[2]``.
    ``exclude`` masks one facility row (the query itself for in-set
    queries) by pushing it to infinity.
    """
    users = jnp.asarray(users, jnp.float32)
    facilities = jnp.asarray(facilities, jnp.float32)
    q = jnp.asarray(q, jnp.float32)
    xs, ys = users[:, 0], users[:, 1]
    fx, fy = facilities[:, 0], facilities[:, 1]
    if exclude is not None:
        fx = fx.at[exclude].set(jnp.inf)
        fy = fy.at[exclude].set(jnp.inf)
    thr = (xs - q[0]) ** 2 + (ys - q[1]) ** 2
    if backend == "ref":
        return _rank_ref_jit(xs, ys, fx, fy, thr)
    if backend != "pallas":
        raise ValueError(f"unknown backend {backend!r}")
    if interpret is None:
        interpret = pallas_interpret_default()
    n = xs.shape[0]
    bu_eff, bm_eff = _effective_blocks(n, fx.shape[0], bu, bm)
    xs_p = _pad1(xs, bu_eff, 0.0)
    ys_p = _pad1(ys, bu_eff, 0.0)
    thr_p = _pad1(thr, bu_eff, 0.0)
    fx_p = _pad1(fx, bm_eff, jnp.inf)
    fy_p = _pad1(fy, bm_eff, jnp.inf)
    out = rank_count_kernel_call(
        xs_p, ys_p, fx_p, fy_p, thr_p, bu=bu_eff, bm=bm_eff, interpret=bool(interpret)
    )
    return out[:n]


@jax.jit
def _rank_batch_ref_jit(xs, ys, fx, fy, thr):
    return _ref.rank_count_batch_ref(xs, ys, fx, fy, thr)


def rank_count_batch(users, facilities, q_pts, *, exclude=None):
    """Batched distance-rank counting: ``[Q, N]`` int32 in one dispatch.

    ``users``: ``[N, 2]``; ``facilities``: ``[M, 2]``; ``q_pts``: ``[Q, 2]``
    query points.  ``exclude`` is an optional length-``Q`` sequence of
    facility rows to mask per query (``-1`` / ``None`` entries mask
    nothing) — the batched analogue of :func:`rank_count`'s ``exclude``.
    """
    users = jnp.asarray(users, jnp.float32)
    facilities = jnp.asarray(facilities, jnp.float32)
    q_pts = jnp.asarray(q_pts, jnp.float32)
    xs, ys = users[:, 0], users[:, 1]
    q_n = q_pts.shape[0]
    fx = jnp.broadcast_to(facilities[None, :, 0], (q_n, facilities.shape[0]))
    fy = jnp.broadcast_to(facilities[None, :, 1], (q_n, facilities.shape[0]))
    if exclude is not None:
        excl = np.asarray(
            [-1 if e is None else int(e) for e in exclude], dtype=np.int32
        )
        rows = np.flatnonzero(excl >= 0)
        if len(rows):
            fx = fx.at[rows, excl[rows]].set(jnp.inf)
            fy = fy.at[rows, excl[rows]].set(jnp.inf)
    thr = (xs[None, :] - q_pts[:, 0, None]) ** 2 + (ys[None, :] - q_pts[:, 1, None]) ** 2
    return _rank_batch_ref_jit(xs, ys, fx, fy, thr)


# ---------------------------------------------------------------------------
# compile accounting: every module-level jitted reference entry point is
# wrapped so an unexpected retrace (a pad-bucket miss storm reshaping the
# dense oracle, a chunk-size change) surfaces as ``compile.count{fn=...}``
# in the process metrics registry instead of a mystery latency spike.
# ---------------------------------------------------------------------------
_raycast_ref_chunked = track_jit(_raycast_ref_chunked, "raycast_ref")
_rank_ref_jit = track_jit(_rank_ref_jit, "rank_ref")
_raycast_batch_ref_jit = track_jit(_raycast_batch_ref_jit, "raycast_batch_ref")
_raycast_batch_ref_chunked = track_jit(
    _raycast_batch_ref_chunked, "raycast_batch_ref_chunked"
)
_grid_cells_batch_ref_chunked = track_jit(
    _grid_cells_batch_ref_chunked, "grid_cells_batch_ref_chunked"
)
_grid_cells_batch_ref_jit = track_jit(
    _grid_cells_batch_ref_jit, "grid_cells_batch_ref"
)
_rank_batch_ref_jit = track_jit(_rank_batch_ref_jit, "rank_batch_ref")
