"""Public jit'd wrappers around the Pallas kernels.

Handles padding to tile multiples, layout packing (``[M,3,3]`` coeffs →
``A/B/C`` planes), backend selection and unpadding.  On this CPU container
the kernels execute in interpret mode (bit-faithful to the TPU lowering's
semantics); on a real TPU set ``REPRO_PALLAS_INTERPRET=0`` (or rely on the
auto-detection) to run the compiled Mosaic kernels.  ``backend="ref"``
routes to the pure-jnp oracle — the fast path on CPU and the baseline the
kernels are benchmarked against.
"""

from __future__ import annotations

import functools
import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.raycast import raycast_count_kernel_call
from repro.kernels.rank_count import rank_count_kernel_call

__all__ = ["raycast_count", "rank_count", "pallas_interpret_default"]

_USER_CHUNK = 32_768  # bounds the [chunk, M, 3] broadcast temp (~40 MB f32)


@functools.partial(jax.jit, static_argnames=("chunk",))
def _raycast_ref_chunked(xs, ys, coeffs, chunk: int = _USER_CHUNK):
    """Jitted + user-chunked oracle path (the fast CPU execution)."""
    n = xs.shape[0]
    pad = (-n) % chunk
    xs_p = jnp.pad(xs, (0, pad))
    ys_p = jnp.pad(ys, (0, pad))
    xc = xs_p.reshape(-1, chunk)
    yc = ys_p.reshape(-1, chunk)
    out = jax.lax.map(lambda xy: _ref.raycast_count_ref(xy[0], xy[1], coeffs), (xc, yc))
    return out.reshape(-1)[:n]


@jax.jit
def _rank_ref_jit(xs, ys, fx, fy, thr):
    return _ref.rank_count_ref(xs, ys, fx, fy, thr)


def pallas_interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _pad1(x: jnp.ndarray, mult: int, value: float) -> jnp.ndarray:
    n = x.shape[0]
    p = (-n) % mult
    if p == 0:
        return x
    return jnp.concatenate([x, jnp.full((p,), value, x.dtype)])


def raycast_count(
    xs,
    ys,
    coeffs,
    *,
    backend: str = "pallas",
    bu: int = 1024,
    bm: int = 512,
    interpret: bool | None = None,
):
    """Hit counts of users against occluder edge functions.

    ``xs, ys``: ``[N]``; ``coeffs``: ``[M, 3, 3]``.  Returns ``[N]`` int32.
    Padding slots are degenerate (``a=b=0, c=-1``) and contribute nothing.
    """
    xs = jnp.asarray(xs, jnp.float32)
    ys = jnp.asarray(ys, jnp.float32)
    coeffs = jnp.asarray(coeffs, jnp.float32)
    if backend == "ref":
        if xs.shape[0] > _USER_CHUNK:
            return _raycast_ref_chunked(xs, ys, coeffs)
        return _ref.raycast_count_ref(xs, ys, coeffs)
    if backend != "pallas":
        raise ValueError(f"unknown backend {backend!r}")
    if interpret is None:
        interpret = pallas_interpret_default()
    n = xs.shape[0]
    m = coeffs.shape[0]
    bu_eff = min(bu, max(8, 1 << max(int(np.ceil(np.log2(max(n, 1)))), 3)))
    bm_eff = min(bm, max(128, 1 << max(int(np.ceil(np.log2(max(m, 1)))), 7)))
    xs_p = _pad1(xs, bu_eff, 0.0)
    ys_p = _pad1(ys, bu_eff, 0.0)
    # coeffs -> [3, M] planes, padded with never-inside rows (c = -1)
    A = coeffs[:, :, 0].T
    B = coeffs[:, :, 1].T
    C = coeffs[:, :, 2].T
    pm = (-m) % bm_eff
    if pm:
        A = jnp.concatenate([A, jnp.zeros((3, pm), A.dtype)], axis=1)
        B = jnp.concatenate([B, jnp.zeros((3, pm), B.dtype)], axis=1)
        C = jnp.concatenate([C, jnp.full((3, pm), -1.0, C.dtype)], axis=1)
    out = raycast_count_kernel_call(
        xs_p, ys_p, A, B, C, bu=bu_eff, bm=bm_eff, interpret=bool(interpret)
    )
    return out[:n]


def rank_count(
    users,
    facilities,
    q,
    *,
    exclude: int | None = None,
    backend: str = "pallas",
    bu: int = 1024,
    bm: int = 512,
    interpret: bool | None = None,
):
    """#facilities strictly closer than ``q`` per user (``[N]`` int32).

    ``users``: ``[N, 2]``; ``facilities``: ``[M, 2]``; ``q``: ``[2]``.
    ``exclude`` masks one facility row (the query itself for in-set
    queries) by pushing it to infinity.
    """
    users = jnp.asarray(users, jnp.float32)
    facilities = jnp.asarray(facilities, jnp.float32)
    q = jnp.asarray(q, jnp.float32)
    xs, ys = users[:, 0], users[:, 1]
    fx, fy = facilities[:, 0], facilities[:, 1]
    if exclude is not None:
        fx = fx.at[exclude].set(jnp.inf)
        fy = fy.at[exclude].set(jnp.inf)
    thr = (xs - q[0]) ** 2 + (ys - q[1]) ** 2
    if backend == "ref":
        return _rank_ref_jit(xs, ys, fx, fy, thr)
    if backend != "pallas":
        raise ValueError(f"unknown backend {backend!r}")
    if interpret is None:
        interpret = pallas_interpret_default()
    n = xs.shape[0]
    m = fx.shape[0]
    bu_eff = min(bu, max(8, 1 << max(int(np.ceil(np.log2(max(n, 1)))), 3)))
    bm_eff = min(bm, max(128, 1 << max(int(np.ceil(np.log2(max(m, 1)))), 7)))
    xs_p = _pad1(xs, bu_eff, 0.0)
    ys_p = _pad1(ys, bu_eff, 0.0)
    thr_p = _pad1(thr, bu_eff, 0.0)
    fx_p = _pad1(fx, bm_eff, jnp.inf)
    fy_p = _pad1(fy, bm_eff, jnp.inf)
    out = rank_count_kernel_call(
        xs_p, ys_p, fx_p, fy_p, thr_p, bu=bu_eff, bm=bm_eff, interpret=bool(interpret)
    )
    return out[:n]
