"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references: small, obviously-right, and used by
the shape/dtype sweep tests (``tests/test_kernels.py``) to validate the
kernels in interpret mode, and by the benchmarks as the non-kernel JAX
baseline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "raycast_count_ref",
    "raycast_count_batch_ref",
    "rank_count_ref",
    "rank_count_batch_ref",
    "grid_raycast_ref",
    "grid_cells_count_batch_ref",
]


def raycast_count_ref(xs, ys, coeffs):
    """Dense occluder hit counting.

    ``xs, ys``: ``[N]`` user coordinates; ``coeffs``: ``[M, 3, 3]`` triangle
    edge functions (rows ``(a, b, c)``; inside ⇔ all three
    ``a x + b y + c >= 0``).  Returns ``[N]`` int32 hit counts.
    """
    xs = jnp.asarray(xs, jnp.float32)
    ys = jnp.asarray(ys, jnp.float32)
    coeffs = jnp.asarray(coeffs, jnp.float32)
    e = (
        coeffs[None, :, :, 0] * xs[:, None, None]
        + coeffs[None, :, :, 1] * ys[:, None, None]
        + coeffs[None, :, :, 2]
    )  # [N, M, 3]
    inside = jnp.all(e >= 0.0, axis=-1)
    return inside.sum(axis=-1).astype(jnp.int32)


def raycast_count_batch_ref(xs, ys, coeffs):
    """Batched multi-query hit counting (oracle for the batched kernel).

    ``xs, ys``: ``[N]`` shared user coordinates; ``coeffs``: ``[Q, Mp, 3, 3]``
    stacked per-query edge functions (padded degenerate).  Returns ``[Q, N]``
    int32 — semantically ``vmap(raycast_count_ref)`` over the query axis,
    which is also exactly what ``launch/serve.py`` dispatches per batch.
    """
    xs = jnp.asarray(xs, jnp.float32)
    ys = jnp.asarray(ys, jnp.float32)
    coeffs = jnp.asarray(coeffs, jnp.float32)

    def one(cf):
        return raycast_count_ref(xs, ys, cf)

    return jax.vmap(one)(coeffs)


def rank_count_batch_ref(xs, ys, fx, fy, thr):
    """Batched distance-rank counting: ``fx, fy``: ``[Q, M]`` per-query
    facility coordinates (the query's own row pushed to +inf), ``thr``:
    ``[Q, N]`` per-(query, user) squared distance thresholds.  Returns
    ``[Q, N]`` int32."""
    xs = jnp.asarray(xs, jnp.float32)
    ys = jnp.asarray(ys, jnp.float32)
    fx = jnp.asarray(fx, jnp.float32)
    fy = jnp.asarray(fy, jnp.float32)
    thr = jnp.asarray(thr, jnp.float32)
    d2 = (
        (xs[None, :, None] - fx[:, None, :]) ** 2
        + (ys[None, :, None] - fy[:, None, :]) ** 2
    )  # [Q, N, M]
    return (d2 < thr[:, :, None]).sum(axis=-1).astype(jnp.int32)


def rank_count_ref(xs, ys, fx, fy, thr):
    """Distance-rank counting (the "InfZone-GPU" / brute verification op).

    Counts facilities with ``(x - fx)^2 + (y - fy)^2 < thr`` per user, where
    ``thr[u]`` is the user's squared distance to the query facility.
    Returns ``[N]`` int32.
    """
    xs = jnp.asarray(xs, jnp.float32)
    ys = jnp.asarray(ys, jnp.float32)
    fx = jnp.asarray(fx, jnp.float32)
    fy = jnp.asarray(fy, jnp.float32)
    thr = jnp.asarray(thr, jnp.float32)
    d2 = (xs[:, None] - fx[None, :]) ** 2 + (ys[:, None] - fy[None, :]) ** 2
    return (d2 < thr[:, None]).sum(axis=-1).astype(jnp.int32)


def grid_cells_count_batch_ref(xs_sorted, ys_sorted, cell_map, planes):
    """Batched cell-bucketed counting (oracle for the batched grid kernel).

    ``xs_sorted, ys_sorted``: ``[n_blocks*block]`` cell-sorted padded user
    coordinates; ``cell_map``: ``[n_blocks]`` cell id per user block;
    ``planes``: ``[Q, G*G, 3, 3, L]`` stacked per-query cell coefficient
    planes.  Returns partial-list hit counts ``[Q, n_blocks*block]`` int32
    in sorted order (the caller adds ``base[q, cell]``), mirroring the
    kernel: one ``[n_blocks, 3, 3, L]`` plane gather per query instead of
    the gather-bound per-user ``[Q, N, L, 3, 3]`` temporary.
    """
    xs_sorted = jnp.asarray(xs_sorted, jnp.float32)
    ys_sorted = jnp.asarray(ys_sorted, jnp.float32)
    planes = jnp.asarray(planes, jnp.float32)
    nb = cell_map.shape[0]
    block = xs_sorted.shape[0] // max(nb, 1)
    x = xs_sorted.reshape(nb, block)  # [NB, B]
    y = ys_sorted.reshape(nb, block)
    p = planes[:, cell_map]  # [Q, NB, 3, 3, L]

    def ev(e):
        return (
            x[None, :, :, None] * p[:, :, e, 0][:, :, None, :]
            + y[None, :, :, None] * p[:, :, e, 1][:, :, None, :]
            + p[:, :, e, 2][:, :, None, :]
        )  # [Q, NB, B, L]

    inside = (ev(0) >= 0.0) & (ev(1) >= 0.0) & (ev(2) >= 0.0)
    counts = inside.sum(axis=-1).astype(jnp.int32)  # [Q, NB, B]
    return counts.reshape(planes.shape[0], nb * block)


def grid_raycast_ref(xs, ys, base, lists, coeffs, rect_lo, rect_size, G: int):
    """Grid-culled hit counting (mirror of core.grid.grid_hit_counts_jnp,
    parameterised the way the Pallas kernel consumes the rect)."""
    xs = jnp.asarray(xs, jnp.float32)
    ys = jnp.asarray(ys, jnp.float32)
    w = rect_size[0] / G
    h = rect_size[1] / G
    cx = jnp.clip(jnp.floor((xs - rect_lo[0]) / w), 0, G - 1).astype(jnp.int32)
    cy = jnp.clip(jnp.floor((ys - rect_lo[1]) / h), 0, G - 1).astype(jnp.int32)
    cell = cx * G + cy
    cand = jnp.asarray(lists)[cell]
    safe = jnp.maximum(cand, 0)
    e = jnp.asarray(coeffs, jnp.float32)[safe]
    ev = e[..., 0] * xs[:, None, None] + e[..., 1] * ys[:, None, None] + e[..., 2]
    inside = jnp.all(ev >= 0.0, axis=-1) & (cand >= 0)
    return jnp.asarray(base)[cell] + inside.sum(axis=-1).astype(jnp.int32)
