"""Pallas TPU kernels for the paper's compute hot-spots (+ jnp oracles).

* ``raycast``      — dense occluder hit counting (the ray-casting stage),
                     single-query and batched (``[Q]`` grid axis) variants
* ``rank_count``   — distance-rank counting (brute / "InfZone-GPU" baseline)
* ``grid_raycast`` — grid-culled counting (the TPU BVH analogue):
                     cell-bucketed scalar-prefetch kernels, single-query
                     and batched ``(q, user-block)`` variants, plus the
                     host-side bucketing / plane-packing helpers
* ``ops``          — jit'd public wrappers (padding, backend selection,
                     batched multi-query dispatch)
* ``ref``          — pure-jnp oracles used by the allclose sweeps
"""

from repro.kernels.compat import tpu_compiler_params
from repro.kernels.ops import (
    grid_count_cells,
    grid_count_cells_batch,
    rank_count,
    raycast_count,
    raycast_count_batch,
)

__all__ = [
    "raycast_count",
    "rank_count",
    "raycast_count_batch",
    "grid_count_cells",
    "grid_count_cells_batch",
    "tpu_compiler_params",
]
