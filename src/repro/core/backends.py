"""Pluggable verification backends behind one registry.

Before this module existed, every query path in :mod:`repro.core.rknn`
carried its own if/elif ladder over the backend name — three copies
(`_build_index`, `_verify_counts`, and the batched dispatch) that each new
backend (the planned Pallas grid-batch kernel, hybrid auto-selection) would
have had to thread through.  Now a backend is ONE class implementing

* :meth:`Backend.build_index`    — host-side index build (filter phase),
* :meth:`Backend.count`          — single-query device count (verify phase),
* :meth:`Backend.prepare_batch`  — host-side batch stacking (filter phase),
* :meth:`Backend.count_batch`    — one batched device dispatch (verify phase),

registered with :func:`register_backend` and resolved with
:func:`get_backend`.  The split between ``prepare_batch`` and
``count_batch`` exists so callers can keep the paper's two-stage timing
convention honest: everything host-side lands in ``t_filter_s``, only the
device dispatch in ``t_verify_s``.

Built-in backends (all produce identical verdict sets — property-tested):

* ``"dense"``    — Pallas ray-cast kernel (interpret mode on CPU), the
                   TPU-native execution of the paper's ray-casting stage.
* ``"dense-ref"``— pure-jnp oracle (fast on CPU; same math).
* ``"grid"``     — uniform-grid culled counting (TPU BVH analogue).
* ``"grid-pallas"`` — cell-bucketed grid counting via the scalar-prefetch
                   Pallas kernel (``repro.kernels.grid_raycast``): users
                   sorted by cell once per batch, per-cell coefficient
                   planes staged into VMEM per program instance.
* ``"grid-pallas-ref"`` — pure-jnp execution of the same bucketed math
                   (the fast CPU path, mirroring dense/dense-ref).
* ``"bvh"``      — paper-faithful LBVH traversal with early termination.
* ``"brute"``    — exact distance-rank counting (no geometry; baseline).
* ``"auto"``     — the query planner (:mod:`repro.planner.backend`): a
                   *meta* backend (``is_meta = True``) that cost-dispatches
                   every request to the predicted-cheapest concrete backend
                   using the active calibration profile.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import weakref
from typing import Any, Callable, ClassVar

import numpy as np

import jax.numpy as jnp

from repro.core.bvh import (
    BVH,
    build_bvh,
    bvh_hit_counts,
    bvh_hit_counts_batch,
    refit_bvh,
    stack_bvhs,
)
from repro.core.geometry import Rect
from repro.core.grid import (
    OccluderGrid,
    build_grid,
    grid_hit_counts_batch_jnp,
    grid_hit_counts_jnp,
    refit_grid,
    stack_grids,
)
from repro.core.scene import Scene, _next_pad, pad_scene_arrays
from repro.kernels import ops as _ops
from repro.kernels.grid_raycast import (
    pack_cell_coeff_planes,
    prepare_cell_buckets,
    repack_cell_coeff_planes,
    unsort_cell_counts,
)

__all__ = [
    "Backend",
    "QueryRequest",
    "BatchRequest",
    "register_backend",
    "get_backend",
    "available_backends",
    "concrete_backends",
    "timeable_backends",
    "stack_cell_planes",
    "DenseBackend",
    "DenseRefBackend",
    "GridBackend",
    "GridPallasBackend",
    "GridPallasRefBackend",
    "BvhBackend",
    "BruteBackend",
    "PlannerBackend",
]


@dataclasses.dataclass
class QueryRequest:
    """Everything a backend may need for one single-query count.

    Geometric backends read ``xs/ys`` + ``scene`` (+ ``index``); the
    geometry-free brute backend reads ``users/facilities/q_pt/exclude``.
    """

    xs: jnp.ndarray  # [N] f32 user x
    ys: jnp.ndarray  # [N] f32 user y
    k: int
    grid_g: int = 64
    scene: Scene | None = None
    index: Any = None
    users: np.ndarray | None = None  # [N, 2] f64
    facilities: np.ndarray | None = None  # [M, 2] f64
    q_pt: np.ndarray | None = None  # [2]
    exclude: int | None = None
    #: Optional per-snapshot kernel memo (an ``LruCache``): the engine
    #: injects its snapshot's store so per-user-set state (the grid-pallas
    #: cell bucketing) is cached per *version*, not on the backend
    #: singleton.  ``None`` (raw protocol use) falls back to a small
    #: instance cache.
    memo: Any = None


@dataclasses.dataclass
class BatchRequest:
    """One batched multi-query count over a shared user set.

    ``mp`` is the static triangle pad target for stacked dense scenes
    (power-of-two bucketed by the engine so repeat workloads reuse one jit
    trace).  ``dispatch`` optionally overrides the device step: a callable
    taking the prepared batch state and returning ``[Q, N]`` counts — the
    engine injects its persistent mesh-sharded jitted dispatch here (for
    the dense-ref, grid, and bvh batched paths alike).
    """

    xs: jnp.ndarray  # [N] f32
    ys: jnp.ndarray  # [N] f32
    k: int
    rect: Rect | None = None
    grid_g: int = 64
    scenes: list[Scene] | None = None
    indexes: list | None = None
    users: np.ndarray | None = None
    facilities: np.ndarray | None = None
    q_pts: np.ndarray | None = None  # [Q, 2]
    excludes: list[int | None] | None = None
    mp: int | None = None
    dispatch: Callable | None = None
    #: Per-snapshot kernel memo — see :attr:`QueryRequest.memo`.
    memo: Any = None


class Backend:
    """Protocol + default implementations for a verification backend."""

    name: ClassVar[str]
    #: False for geometry-free backends (no scene construction at all);
    #: the engine skips the whole filter phase for them.
    uses_scene: ClassVar[bool] = True
    #: True for planning backends that only *route* to concrete backends
    #: (the engine resolves them before filtering; they are excluded from
    #: the concrete-backend lists like ``repro.core.rknn.BACKENDS``).
    is_meta: ClassVar[bool] = False
    #: True for Pallas-kernel backends whose CPU execution is interpret
    #: mode — a bit-faithful correctness tool, orders of magnitude off the
    #: compiled cost.  Timed harnesses (planner calibration, the scenario
    #: sweep) consult :func:`timeable_backends` and skip them while
    #: ``pallas_interpret_default()`` is on; on a real TPU they are
    #: measured like any other backend.  Correctness suites ignore this.
    interpret_mode_on_cpu: ClassVar[bool] = False
    #: True when :meth:`prepare_batch`'s returned object bakes in user
    #: *coordinates* (not just scene geometry).  The dynamic engine's
    #: copy-on-write batch-cache carry consults this: for a user-move-only
    #: delta, prepared state of backends where this is False stays valid
    #: (user arrays enter only at :meth:`count_batch` via the request) and
    #: is carried into the next snapshot; True forces a drop.
    prepared_carries_users: ClassVar[bool] = False

    # ---- filter phase (host) --------------------------------------------
    def build_index(self, scene: Scene, *, grid_g: int = 64, memo: dict | None = None):
        """Host-side per-scene index build (grid/BVH); ``None`` if unused.

        ``memo`` is the engine snapshot's per-scene index store (a plain
        dict scoped to ``scene``): backends that share one built structure
        across registry entries (the grid family) memoize it there under
        their own key, so the snapshot — not the scene object — owns the
        cached index state.  ``None`` builds fresh.
        """
        return None

    def refit_index(
        self,
        index,
        old_scene: Scene,
        new_scene: Scene,
        changed: np.ndarray,
        *,
        grid_g: int = 64,
    ) -> tuple[Any, bool]:
        """Adapt ``index`` (built for ``old_scene``) to ``new_scene``.

        ``changed`` lists the real-triangle ids whose geometry differs; all
        other triangles are bit-identical between the scenes (the dynamic
        subsystem's scene-refit contract).  Returns ``(new_index, refit)``
        where ``refit`` is True when the index was adapted in place rather
        than rebuilt.  The default — and the fallback of every override
        whose cheap path does not apply — is a fresh :meth:`build_index`.
        Either way the returned index must count exactly like a fresh
        build (grid counts are order-independent, BVH boxes stay
        conservative), so refit never changes query results.
        """
        return self.build_index(new_scene, grid_g=grid_g), False

    def prepare_batch(self, req: BatchRequest):
        """Host-side batch stacking; the returned object is what
        :meth:`count_batch` dispatches.  Runs inside ``t_filter_s``."""
        return None

    # ---- persistence (repro.persist) ------------------------------------
    def export_state(self, index) -> tuple[str, dict, dict] | None:
        """Serializable form of a built index: ``(kind, arrays, meta)``.

        ``arrays`` maps names to host numpy arrays; ``meta`` is JSON-safe.
        ``None`` means the backend keeps no persistable index state (the
        dense family stacks scene coefficients directly; brute has no
        geometry) — such backends rebuild for free on restore.  ``kind``
        tags the encoding so :meth:`import_state` can reject a payload it
        does not understand.
        """
        return None

    def import_state(self, kind: str, arrays: dict, meta: dict):
        """Inverse of :meth:`export_state`: rebuild the in-memory index
        object from its serialized form.  Raises ``ValueError`` on an
        unrecognized ``kind`` (a stale or foreign payload must fall back
        to a cold build, not be misread)."""
        raise ValueError(f"backend {self.name!r} cannot import state kind {kind!r}")

    # ---- verify phase (device) ------------------------------------------
    def count(self, req: QueryRequest) -> np.ndarray:
        """``[N]`` int32 hit counts for one query."""
        raise NotImplementedError

    def count_batch(self, req: BatchRequest, prepared) -> np.ndarray:
        """``[Q, N]`` int32 hit counts in one batched device dispatch."""
        raise NotImplementedError


_REGISTRY: dict[str, Backend] = {}


def register_backend(cls: type[Backend]) -> type[Backend]:
    """Class decorator: instantiate and register under ``cls.name``.

    Later registrations override earlier ones (so tests / downstream code
    can shadow a built-in with an instrumented variant).
    """
    _REGISTRY[cls.name] = cls()
    return cls


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"backend must be one of {available_backends()}, got {name!r}"
        ) from None


def available_backends() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def concrete_backends() -> tuple[str, ...]:
    """Registered names that do the counting themselves — meta backends
    (the ``auto`` planner) route to these and are excluded.  Single source
    of truth for every "all real backends" list."""
    return tuple(n for n, b in _REGISTRY.items() if not b.is_meta)


def timeable_backends() -> tuple[str, ...]:
    """Concrete backends whose wall time is meaningful on this runtime.

    Excludes backends flagged ``interpret_mode_on_cpu`` while the Pallas
    kernels would run in interpret mode (see :class:`Backend`) — the
    single source of truth the calibration harness and benchmark sweeps
    share, replacing per-name exclusion lists."""
    interp = _ops.pallas_interpret_default()
    return tuple(
        n
        for n, b in _REGISTRY.items()
        if not b.is_meta and not (interp and b.interpret_mode_on_cpu)
    )


# --------------------------------------------------------------------------
# Dense (stacked edge functions, no index)
# --------------------------------------------------------------------------


@register_backend
class DenseBackend(Backend):
    """Pallas ray-cast kernel over the full padded scene."""

    name = "dense"
    kernel_backend = "pallas"
    interpret_mode_on_cpu = True

    def count(self, req: QueryRequest) -> np.ndarray:
        return np.asarray(
            _ops.raycast_count(
                req.xs, req.ys, req.scene.coeffs, backend=self.kernel_backend
            )
        )

    def prepare_batch(self, req: BatchRequest) -> np.ndarray:
        scenes = req.scenes
        # size the stacked pad from the REAL triangle counts: scenes arrive
        # pre-padded (possibly to a much larger sticky bucket), and sizing
        # from tris.shape[0] over-pads the whole [Q, Mp, 3, 3] stack on the
        # one-shot shim path (req.mp None)
        mp = (
            req.mp
            if req.mp is not None
            else _next_pad(max(s.n_tris for s in scenes))
        )
        return np.stack(
            [
                pad_scene_arrays(
                    s.tris[: s.n_tris], s.coeffs[: s.n_tris], s.owner[: s.n_tris], mp
                )[1]
                for s in scenes
            ]
        ).astype(np.float32)  # [Q, Mp, 3, 3]

    def count_batch(self, req: BatchRequest, prepared) -> np.ndarray:
        if req.dispatch is not None:
            return np.asarray(req.dispatch(prepared))
        return np.asarray(
            _ops.raycast_count_batch(
                req.xs, req.ys, prepared, backend=self.kernel_backend
            )
        )


@register_backend
class DenseRefBackend(DenseBackend):
    """Pure-jnp oracle of the dense path (the fast CPU execution)."""

    name = "dense-ref"
    kernel_backend = "ref"
    interpret_mode_on_cpu = False


# --------------------------------------------------------------------------
# Grid (uniform-grid culling, the TPU BVH analogue)
# --------------------------------------------------------------------------


@register_backend
class GridBackend(Backend):
    name = "grid"

    def build_index(self, scene: Scene, *, grid_g: int = 64, memo: dict | None = None):
        # the grid, grid-pallas, and grid-pallas-ref backends all build the
        # identical index, so within one snapshot's per-scene store they
        # share it under ("grid", G) — a scene queried through more than
        # one of them pays one build (the pallas variants hang their packed
        # planes off the shared object, keyed by lane pad)
        key = ("grid", int(grid_g))
        if memo is not None:
            g = memo.get(key)
            if g is not None:
                return g
        g = build_grid(
            scene.tris[: scene.n_tris],
            scene.coeffs[: scene.n_tris],
            scene.rect,
            G=grid_g,
        )
        if memo is not None:
            memo[key] = g
        return g

    def refit_index(
        self,
        index,
        old_scene: Scene,
        new_scene: Scene,
        changed: np.ndarray,
        *,
        grid_g: int = 64,
    ):
        if index is not None and index.G == grid_g:
            n = old_scene.n_tris
            g = refit_grid(
                index,
                old_scene.tris[:n],
                old_scene.coeffs[:n],
                new_scene.tris[: new_scene.n_tris],
                new_scene.coeffs[: new_scene.n_tris],
                changed,
            )
            if g is not None:
                return g, True
        return self.build_index(new_scene, grid_g=grid_g), False

    def export_state(self, index) -> tuple[str, dict, dict] | None:
        if index is None:
            return None
        arrays = {
            "base": index.base,
            "lists": index.lists,
            "coeffs": index.coeffs,
        }
        r = index.rect
        meta = {
            "G": int(index.G),
            "rect": [float(r.xmin), float(r.ymin), float(r.xmax), float(r.ymax)],
            "plane_pads": [],
        }
        # the pallas variants hang packed per-cell coefficient planes off
        # the shared grid object, keyed by lane pad — persist them so a
        # warm restore skips the re-pack too
        planes = getattr(index, "_cell_planes", None) or {}
        for pad in sorted(planes):
            meta["plane_pads"].append(int(pad))
            arrays[f"planes_{int(pad)}"] = planes[pad]
        return "grid", arrays, meta

    def import_state(self, kind: str, arrays: dict, meta: dict):
        if kind != "grid":
            return super().import_state(kind, arrays, meta)
        g = OccluderGrid(
            base=np.ascontiguousarray(arrays["base"], np.int32),
            lists=np.ascontiguousarray(arrays["lists"], np.int32),
            coeffs=np.ascontiguousarray(arrays["coeffs"], np.float32),
            G=int(meta["G"]),
            rect=Rect(*(float(v) for v in meta["rect"])),
        )
        pads = meta.get("plane_pads") or []
        if pads:
            g._cell_planes = {
                int(p): np.ascontiguousarray(arrays[f"planes_{int(p)}"], np.float32)
                for p in pads
            }
        return g

    def count(self, req: QueryRequest) -> np.ndarray:
        g = req.index
        if g is None:
            g = self.build_index(req.scene, grid_g=req.grid_g)
        return np.asarray(
            grid_hit_counts_jnp(
                req.xs, req.ys, g.base, g.lists, g.coeffs, req.scene.rect, req.grid_g
            )
        )

    def prepare_batch(self, req: BatchRequest):
        indexes = req.indexes
        if indexes is None:
            indexes = [self.build_index(s, grid_g=req.grid_g) for s in req.scenes]
        return stack_grids(indexes)  # (base, lists, coeffs)

    def count_batch(self, req: BatchRequest, prepared) -> np.ndarray:
        if req.dispatch is not None:
            return np.asarray(req.dispatch(prepared))
        base, lists, coeffs = prepared
        return np.asarray(
            grid_hit_counts_batch_jnp(
                req.xs, req.ys, base, lists, coeffs, req.rect, req.grid_g
            )
        )


# --------------------------------------------------------------------------
# Grid-Pallas (cell-bucketed scalar-prefetch kernel over the grid index)
# --------------------------------------------------------------------------


def stack_cell_planes(
    planes: list[np.ndarray], *, lane_pad: int = 1, compact: bool = False
) -> np.ndarray:
    """Stack per-scene packed coefficient planes ``[n_cells, 3, 3, L_i]``
    into one ``[Q, n_cells, 3, 3, L]`` batch table.

    Per-scene lane widths ``L_i`` are heterogeneous (each scene pads to
    its own longest cell list); short planes degenerate-pad with the
    third coefficient row at ``-1`` — a plane no point is ever inside —
    so padding lanes can never contribute a hit.

    ``compact=True`` additionally trims dead lanes: ``L`` becomes the
    longest *live* lane across the stack (rounded up to ``lane_pad`` for
    the compiled kernel's tile constraint) instead of the longest padded
    width.  This is the user-axis shard win — a shard whose occupied
    cells carry short candidate lists ships and evaluates proportionally
    fewer ``[BU x L]`` edge tests.
    """
    if compact:
        L = 1
        for p in planes:
            live = np.flatnonzero(np.any(p[:, :, 2, :] != -1.0, axis=(0, 1)))
            if live.size:
                L = max(L, int(live[-1]) + 1)
        pad = max(int(lane_pad), 1)
        L = -(-L // pad) * pad
    else:
        L = max(p.shape[-1] for p in planes)
        if all(p.shape[-1] == L for p in planes):
            return np.stack(planes)
    out = np.zeros((len(planes),) + planes[0].shape[:-1] + (L,), np.float32)
    out[:, :, :, 2, :] = -1.0  # degenerate pad (never inside)
    for i, p in enumerate(planes):
        c = min(L, p.shape[-1])
        out[i, ..., :c] = p[..., :c]
    return out


@register_backend
class GridPallasBackend(GridBackend):
    """Cell-bucketed grid counting via the scalar-prefetch Pallas kernel.

    The jnp grid batch (:func:`repro.core.grid.grid_hit_counts_batch_jnp`)
    pays a gather-bound ``[Q, N, L, 3, 3]`` temporary — per user, per
    query, nine coefficient gathers per list slot.  This backend instead

    * sorts users by grid cell once per ``(users, rect, G)`` (all stacked
      scenes share one domain rect; the bucketing is LRU-cached on the
      backend so successive batches over the same user set reuse it),
    * packs each grid index's per-cell coefficient planes
      ``[G*G, 3, 3, L]`` once (memoized on the index; incrementally
      re-packed for the cells a :meth:`refit_index` touches),
    * compacts the stacked plane/base tables to the user-OCCUPIED cells
      (``cell_map`` becomes a rank into that compact axis — empty fringe
      cells never ship to the device), and
    * dispatches one ``(q, user-block)`` scalar-prefetch kernel where each
      program instance stages one query's planes for one cell into VMEM —
      ``[BU x L]`` edge evaluations plus ``base[q, cell]``.

    Everything host-side (bucketing, packing, stacking) runs in
    :meth:`prepare_batch` (``t_filter_s``); :meth:`count_batch` is the one
    device dispatch plus the unsort scatter that drops padding rows.
    Counts are bit-identical to the ``grid`` backend (property-tested in
    ``tests/test_grid_pallas.py``).
    """

    name = "grid-pallas"
    kernel_backend = "pallas"
    interpret_mode_on_cpu = True
    # prepare_batch's tuple embeds the cell-sorted user coordinates, so a
    # user-move delta invalidates it (the COW batch-cache carry drops it)
    prepared_carries_users = True
    _BUCKET_CACHE_CAP = 4

    @property
    def lane_pad(self) -> int:
        """Lane padding of the packed planes' list axis: the TPU lane
        width for the compiled Mosaic kernel; interpret mode (a
        correctness tool) has no lane constraint and a narrow pad keeps
        its per-step operand slicing cheap."""
        return 128 if not _ops.pallas_interpret_default() else 8

    def __init__(self) -> None:
        # raw-protocol fallback bucketing memo, used only when the request
        # carries no snapshot memo: (users identity, rect, G) -> sorted
        # arrays, with a weakref guard against id() reuse after gc.
        # Engine-routed requests inject their snapshot's kernel memo
        # instead (per-version ownership — see core/snapshot.py).
        self._bucket_cache: "collections.OrderedDict[tuple, tuple]" = (
            collections.OrderedDict()
        )
        self._bucket_lock = threading.Lock()

    # ---- packed per-cell planes (memoized on the grid index) ------------
    def _planes_for(self, grid) -> np.ndarray:
        store = getattr(grid, "_cell_planes", None)
        if store is None:
            store = {}
            grid._cell_planes = store
        planes = store.get(self.lane_pad)
        if planes is None:
            planes = pack_cell_coeff_planes(grid, lane_pad=self.lane_pad)
            store[self.lane_pad] = planes
        return planes

    # ---- user bucketing (shared across batches over one user set) -------
    def _buckets_for(self, xs, ys, rect, G: int, memo=None):
        """``(xs_s, ys_s, order, ranks, occ, block)`` for one user set.

        ``occ`` lists the user-occupied cell ids and ``ranks`` maps each
        user block into that compact axis — the plane/base tables shipped
        to the device carry only occupied cells.

        With a snapshot ``memo`` (engine-routed requests) the bucketing is
        cached per engine version: the memo pins a strong reference to
        ``xs`` so the identity key stays valid for the entry's lifetime,
        and lookups are lock-free.  Without one (raw protocol) a small
        weakref-guarded instance cache is used.
        """
        n = int(xs.shape[0])
        key = ("gp-buckets", id(xs), n, rect, int(G))
        if memo is not None:
            hit = memo.get(key)
            if hit is not None and hit[0] is xs:
                return hit[1]
        else:
            with self._bucket_lock:
                hit = self._bucket_cache.get(key)
                if hit is not None and hit[0]() is xs:
                    self._bucket_cache.move_to_end(key)
                    return hit[1]
        xs_np = np.asarray(xs, np.float32)
        ys_np = np.asarray(ys, np.float32)
        xs_s, ys_s, order, cell_map, nb = prepare_cell_buckets(
            xs_np, ys_np, rect, G, block=None
        )
        block = xs_s.shape[0] // nb if nb else 0
        occ = np.unique(cell_map)
        ranks = np.searchsorted(occ, cell_map).astype(np.int32)
        buckets = (jnp.asarray(xs_s), jnp.asarray(ys_s), order, ranks, occ, block)
        if memo is not None:
            memo.put(key, (xs, buckets))  # strong ref pins id(xs)
            return buckets
        try:
            ref = weakref.ref(xs)
        except TypeError:  # non-weakref-able array type: pin it instead
            ref = (lambda o: (lambda: o))(xs)
        with self._bucket_lock:
            self._bucket_cache[key] = (ref, buckets)
            while len(self._bucket_cache) > self._BUCKET_CACHE_CAP:
                self._bucket_cache.popitem(last=False)
        return buckets

    # ---- filter phase ----------------------------------------------------
    def build_index(self, scene: Scene, *, grid_g: int = 64, memo: dict | None = None):
        grid = super().build_index(scene, grid_g=grid_g, memo=memo)
        self._planes_for(grid)  # pack eagerly: host work belongs to filter
        return grid

    def refit_index(
        self,
        index,
        old_scene: Scene,
        new_scene: Scene,
        changed: np.ndarray,
        *,
        grid_g: int = 64,
    ):
        new_grid, was_refit = super().refit_index(
            index, old_scene, new_scene, changed, grid_g=grid_g
        )
        if was_refit:
            # incremental plane re-pack: refit_grid preserves the padded
            # list width, so only cells whose candidate list changed — or
            # that list a changed triangle (its coefficients moved) — need
            # their [3, 3, L] planes rewritten
            store = getattr(index, "_cell_planes", None) or {}
            old_planes = store.get(self.lane_pad)
            if old_planes is not None:
                touched = np.flatnonzero(
                    np.any(index.lists != new_grid.lists, axis=1)
                    | np.isin(new_grid.lists, np.asarray(changed)).any(axis=1)
                )
                new_grid._cell_planes = {
                    self.lane_pad: repack_cell_coeff_planes(
                        old_planes, new_grid, touched
                    )
                }
        return new_grid, was_refit

    def prepare_batch(self, req: BatchRequest):
        indexes = req.indexes
        if indexes is None:
            indexes = [self.build_index(s, grid_g=req.grid_g) for s in req.scenes]
        G = indexes[0].G
        rect = indexes[0].rect
        if any(g.G != G for g in indexes):
            raise ValueError("all grids in a batch must share G")
        if any(g.rect != rect for g in indexes):
            raise ValueError("all grids in a batch must share the domain rect")
        xs_s, ys_s, order, ranks, occ, block = self._buckets_for(
            req.xs, req.ys, rect, G, memo=req.memo
        )
        planes = [self._planes_for(g)[occ] for g in indexes]  # [n_occ, 3, 3, L]
        planes_q = stack_cell_planes(planes)
        base_q = np.stack([g.base[occ] for g in indexes]).astype(np.int32)
        return (xs_s, ys_s, order, ranks, block, base_q, planes_q)

    # ---- verify phase ----------------------------------------------------
    def count(self, req: QueryRequest) -> np.ndarray:
        grid = req.index
        if grid is None:
            grid = self.build_index(req.scene, grid_g=req.grid_g)
        xs_s, ys_s, order, ranks, occ, block = self._buckets_for(
            req.xs, req.ys, grid.rect, grid.G, memo=req.memo
        )
        counts = _ops.grid_count_cells(
            xs_s, ys_s, ranks, grid.base[occ], self._planes_for(grid)[occ],
            block=block, backend=self.kernel_backend,
        )
        return unsort_cell_counts(np.asarray(counts), order, int(req.xs.shape[0]))

    def count_batch(self, req: BatchRequest, prepared) -> np.ndarray:
        if req.dispatch is not None:
            return np.asarray(req.dispatch(prepared))
        xs_s, ys_s, order, ranks, block, base_q, planes_q = prepared
        counts = _ops.grid_count_cells_batch(
            xs_s, ys_s, ranks, base_q, planes_q,
            block=block, backend=self.kernel_backend,
        )
        return unsort_cell_counts(np.asarray(counts), order, int(req.xs.shape[0]))


@register_backend
class GridPallasRefBackend(GridPallasBackend):
    """Pure-jnp execution of the bucketed grid path (fast on CPU; same
    math — mirrors the dense/dense-ref pairing)."""

    name = "grid-pallas-ref"
    kernel_backend = "ref"
    interpret_mode_on_cpu = False
    lane_pad = 1  # no TPU lane constraint: stop at the real max list length


# --------------------------------------------------------------------------
# BVH (paper-faithful traversal with early termination at k)
# --------------------------------------------------------------------------


@register_backend
class BvhBackend(Backend):
    name = "bvh"

    def build_index(self, scene: Scene, *, grid_g: int = 64, memo: dict | None = None):
        return build_bvh(scene.tris[: scene.n_tris])

    def refit_index(
        self,
        index,
        old_scene: Scene,
        new_scene: Scene,
        changed: np.ndarray,
        *,
        grid_g: int = 64,
    ):
        if index is not None:
            bvh = refit_bvh(index, new_scene.tris[: new_scene.n_tris])
            if bvh is not None:
                return bvh, True
        return self.build_index(new_scene, grid_g=grid_g), False

    def export_state(self, index) -> tuple[str, dict, dict] | None:
        if index is None:
            return None
        arrays = {"left": index.left, "right": index.right, "bbox": index.bbox}
        return "bvh", arrays, {"n_tris": int(index.n_tris)}

    def import_state(self, kind: str, arrays: dict, meta: dict):
        if kind != "bvh":
            return super().import_state(kind, arrays, meta)
        return BVH(
            left=np.ascontiguousarray(arrays["left"], np.int32),
            right=np.ascontiguousarray(arrays["right"], np.int32),
            bbox=np.ascontiguousarray(arrays["bbox"], np.float32),
            n_tris=int(meta["n_tris"]),
        )

    def count(self, req: QueryRequest) -> np.ndarray:
        bvh = req.index
        if bvh is None:
            bvh = self.build_index(req.scene, grid_g=req.grid_g)
        return np.asarray(
            bvh_hit_counts(
                req.xs,
                req.ys,
                bvh.left,
                bvh.right,
                bvh.bbox,
                req.scene.coeffs[: req.scene.n_tris],
                k=req.k,
            )
        )

    def prepare_batch(self, req: BatchRequest):
        indexes = req.indexes
        if indexes is None:
            indexes = [self.build_index(s, grid_g=req.grid_g) for s in req.scenes]
        return stack_bvhs(indexes, [s.coeffs[: s.n_tris] for s in req.scenes])

    def count_batch(self, req: BatchRequest, prepared) -> np.ndarray:
        if req.dispatch is not None:
            return np.asarray(req.dispatch(prepared))
        left, right, bbox, coeffs = prepared
        return np.asarray(
            bvh_hit_counts_batch(req.xs, req.ys, left, right, bbox, coeffs, k=req.k)
        )


# --------------------------------------------------------------------------
# Brute (exact distance-rank counting; no geometry at all)
# --------------------------------------------------------------------------


@register_backend
class BruteBackend(Backend):
    name = "brute"
    uses_scene = False

    def count(self, req: QueryRequest) -> np.ndarray:
        return np.asarray(
            _ops.rank_count(
                req.users, req.facilities, req.q_pt, exclude=req.exclude, backend="ref"
            )
        )

    def count_batch(self, req: BatchRequest, prepared) -> np.ndarray:
        return np.asarray(
            _ops.rank_count_batch(
                req.users, req.facilities, req.q_pts, exclude=req.excludes
            )
        )


# --------------------------------------------------------------------------
# Auto (the query planner — registered last so concrete backends come first)
# --------------------------------------------------------------------------

from repro.planner.backend import PlannerBackend  # noqa: E402 — deliberate tail
                                                  # import; the planner module
                                                  # has no core imports at
                                                  # module level (acyclic)

register_backend(PlannerBackend)
