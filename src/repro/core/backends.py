"""Pluggable verification backends behind one registry.

Before this module existed, every query path in :mod:`repro.core.rknn`
carried its own if/elif ladder over the backend name — three copies
(`_build_index`, `_verify_counts`, and the batched dispatch) that each new
backend (the planned Pallas grid-batch kernel, hybrid auto-selection) would
have had to thread through.  Now a backend is ONE class implementing

* :meth:`Backend.build_index`    — host-side index build (filter phase),
* :meth:`Backend.count`          — single-query device count (verify phase),
* :meth:`Backend.prepare_batch`  — host-side batch stacking (filter phase),
* :meth:`Backend.count_batch`    — one batched device dispatch (verify phase),

registered with :func:`register_backend` and resolved with
:func:`get_backend`.  The split between ``prepare_batch`` and
``count_batch`` exists so callers can keep the paper's two-stage timing
convention honest: everything host-side lands in ``t_filter_s``, only the
device dispatch in ``t_verify_s``.

Built-in backends (all produce identical verdict sets — property-tested):

* ``"dense"``    — Pallas ray-cast kernel (interpret mode on CPU), the
                   TPU-native execution of the paper's ray-casting stage.
* ``"dense-ref"``— pure-jnp oracle (fast on CPU; same math).
* ``"grid"``     — uniform-grid culled counting (TPU BVH analogue).
* ``"bvh"``      — paper-faithful LBVH traversal with early termination.
* ``"brute"``    — exact distance-rank counting (no geometry; baseline).
* ``"auto"``     — the query planner (:mod:`repro.planner.backend`): a
                   *meta* backend (``is_meta = True``) that cost-dispatches
                   every request to the predicted-cheapest concrete backend
                   using the active calibration profile.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, ClassVar

import numpy as np

import jax.numpy as jnp

from repro.core.bvh import (
    build_bvh,
    bvh_hit_counts,
    bvh_hit_counts_batch,
    refit_bvh,
    stack_bvhs,
)
from repro.core.geometry import Rect
from repro.core.grid import (
    build_grid,
    grid_hit_counts_batch_jnp,
    grid_hit_counts_jnp,
    refit_grid,
    stack_grids,
)
from repro.core.scene import Scene, pad_scene_arrays
from repro.kernels import ops as _ops

__all__ = [
    "Backend",
    "QueryRequest",
    "BatchRequest",
    "register_backend",
    "get_backend",
    "available_backends",
    "concrete_backends",
    "DenseBackend",
    "DenseRefBackend",
    "GridBackend",
    "BvhBackend",
    "BruteBackend",
    "PlannerBackend",
]


@dataclasses.dataclass
class QueryRequest:
    """Everything a backend may need for one single-query count.

    Geometric backends read ``xs/ys`` + ``scene`` (+ ``index``); the
    geometry-free brute backend reads ``users/facilities/q_pt/exclude``.
    """

    xs: jnp.ndarray  # [N] f32 user x
    ys: jnp.ndarray  # [N] f32 user y
    k: int
    grid_g: int = 64
    scene: Scene | None = None
    index: Any = None
    users: np.ndarray | None = None  # [N, 2] f64
    facilities: np.ndarray | None = None  # [M, 2] f64
    q_pt: np.ndarray | None = None  # [2]
    exclude: int | None = None


@dataclasses.dataclass
class BatchRequest:
    """One batched multi-query count over a shared user set.

    ``mp`` is the static triangle pad target for stacked dense scenes
    (power-of-two bucketed by the engine so repeat workloads reuse one jit
    trace).  ``dispatch`` optionally overrides the device step: a callable
    taking the prepared batch state and returning ``[Q, N]`` counts — the
    engine injects its persistent mesh-sharded jitted dispatch here (for
    the dense-ref, grid, and bvh batched paths alike).
    """

    xs: jnp.ndarray  # [N] f32
    ys: jnp.ndarray  # [N] f32
    k: int
    rect: Rect | None = None
    grid_g: int = 64
    scenes: list[Scene] | None = None
    indexes: list | None = None
    users: np.ndarray | None = None
    facilities: np.ndarray | None = None
    q_pts: np.ndarray | None = None  # [Q, 2]
    excludes: list[int | None] | None = None
    mp: int | None = None
    dispatch: Callable | None = None


class Backend:
    """Protocol + default implementations for a verification backend."""

    name: ClassVar[str]
    #: False for geometry-free backends (no scene construction at all);
    #: the engine skips the whole filter phase for them.
    uses_scene: ClassVar[bool] = True
    #: True for planning backends that only *route* to concrete backends
    #: (the engine resolves them before filtering; they are excluded from
    #: the concrete-backend lists like ``repro.core.rknn.BACKENDS``).
    is_meta: ClassVar[bool] = False

    # ---- filter phase (host) --------------------------------------------
    def build_index(self, scene: Scene, *, grid_g: int = 64):
        """Host-side per-scene index build (grid/BVH); ``None`` if unused."""
        return None

    def refit_index(
        self,
        index,
        old_scene: Scene,
        new_scene: Scene,
        changed: np.ndarray,
        *,
        grid_g: int = 64,
    ) -> tuple[Any, bool]:
        """Adapt ``index`` (built for ``old_scene``) to ``new_scene``.

        ``changed`` lists the real-triangle ids whose geometry differs; all
        other triangles are bit-identical between the scenes (the dynamic
        subsystem's scene-refit contract).  Returns ``(new_index, refit)``
        where ``refit`` is True when the index was adapted in place rather
        than rebuilt.  The default — and the fallback of every override
        whose cheap path does not apply — is a fresh :meth:`build_index`.
        Either way the returned index must count exactly like a fresh
        build (grid counts are order-independent, BVH boxes stay
        conservative), so refit never changes query results.
        """
        return self.build_index(new_scene, grid_g=grid_g), False

    def prepare_batch(self, req: BatchRequest):
        """Host-side batch stacking; the returned object is what
        :meth:`count_batch` dispatches.  Runs inside ``t_filter_s``."""
        return None

    # ---- verify phase (device) ------------------------------------------
    def count(self, req: QueryRequest) -> np.ndarray:
        """``[N]`` int32 hit counts for one query."""
        raise NotImplementedError

    def count_batch(self, req: BatchRequest, prepared) -> np.ndarray:
        """``[Q, N]`` int32 hit counts in one batched device dispatch."""
        raise NotImplementedError


_REGISTRY: dict[str, Backend] = {}


def register_backend(cls: type[Backend]) -> type[Backend]:
    """Class decorator: instantiate and register under ``cls.name``.

    Later registrations override earlier ones (so tests / downstream code
    can shadow a built-in with an instrumented variant).
    """
    _REGISTRY[cls.name] = cls()
    return cls


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"backend must be one of {available_backends()}, got {name!r}"
        ) from None


def available_backends() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def concrete_backends() -> tuple[str, ...]:
    """Registered names that do the counting themselves — meta backends
    (the ``auto`` planner) route to these and are excluded.  Single source
    of truth for every "all real backends" list."""
    return tuple(n for n, b in _REGISTRY.items() if not b.is_meta)


# --------------------------------------------------------------------------
# Dense (stacked edge functions, no index)
# --------------------------------------------------------------------------


@register_backend
class DenseBackend(Backend):
    """Pallas ray-cast kernel over the full padded scene."""

    name = "dense"
    kernel_backend = "pallas"

    def count(self, req: QueryRequest) -> np.ndarray:
        return np.asarray(
            _ops.raycast_count(
                req.xs, req.ys, req.scene.coeffs, backend=self.kernel_backend
            )
        )

    def prepare_batch(self, req: BatchRequest) -> np.ndarray:
        scenes = req.scenes
        mp = req.mp if req.mp is not None else max(s.tris.shape[0] for s in scenes)
        return np.stack(
            [
                pad_scene_arrays(
                    s.tris[: s.n_tris], s.coeffs[: s.n_tris], s.owner[: s.n_tris], mp
                )[1]
                for s in scenes
            ]
        ).astype(np.float32)  # [Q, Mp, 3, 3]

    def count_batch(self, req: BatchRequest, prepared) -> np.ndarray:
        if req.dispatch is not None:
            return np.asarray(req.dispatch(prepared))
        return np.asarray(
            _ops.raycast_count_batch(
                req.xs, req.ys, prepared, backend=self.kernel_backend
            )
        )


@register_backend
class DenseRefBackend(DenseBackend):
    """Pure-jnp oracle of the dense path (the fast CPU execution)."""

    name = "dense-ref"
    kernel_backend = "ref"


# --------------------------------------------------------------------------
# Grid (uniform-grid culling, the TPU BVH analogue)
# --------------------------------------------------------------------------


@register_backend
class GridBackend(Backend):
    name = "grid"

    def build_index(self, scene: Scene, *, grid_g: int = 64):
        return build_grid(
            scene.tris[: scene.n_tris],
            scene.coeffs[: scene.n_tris],
            scene.rect,
            G=grid_g,
        )

    def refit_index(
        self,
        index,
        old_scene: Scene,
        new_scene: Scene,
        changed: np.ndarray,
        *,
        grid_g: int = 64,
    ):
        if index is not None and index.G == grid_g:
            n = old_scene.n_tris
            g = refit_grid(
                index,
                old_scene.tris[:n],
                old_scene.coeffs[:n],
                new_scene.tris[: new_scene.n_tris],
                new_scene.coeffs[: new_scene.n_tris],
                changed,
            )
            if g is not None:
                return g, True
        return self.build_index(new_scene, grid_g=grid_g), False

    def count(self, req: QueryRequest) -> np.ndarray:
        g = req.index
        if g is None:
            g = self.build_index(req.scene, grid_g=req.grid_g)
        return np.asarray(
            grid_hit_counts_jnp(
                req.xs, req.ys, g.base, g.lists, g.coeffs, req.scene.rect, req.grid_g
            )
        )

    def prepare_batch(self, req: BatchRequest):
        indexes = req.indexes
        if indexes is None:
            indexes = [self.build_index(s, grid_g=req.grid_g) for s in req.scenes]
        return stack_grids(indexes)  # (base, lists, coeffs)

    def count_batch(self, req: BatchRequest, prepared) -> np.ndarray:
        if req.dispatch is not None:
            return np.asarray(req.dispatch(prepared))
        base, lists, coeffs = prepared
        return np.asarray(
            grid_hit_counts_batch_jnp(
                req.xs, req.ys, base, lists, coeffs, req.rect, req.grid_g
            )
        )


# --------------------------------------------------------------------------
# BVH (paper-faithful traversal with early termination at k)
# --------------------------------------------------------------------------


@register_backend
class BvhBackend(Backend):
    name = "bvh"

    def build_index(self, scene: Scene, *, grid_g: int = 64):
        return build_bvh(scene.tris[: scene.n_tris])

    def refit_index(
        self,
        index,
        old_scene: Scene,
        new_scene: Scene,
        changed: np.ndarray,
        *,
        grid_g: int = 64,
    ):
        if index is not None:
            bvh = refit_bvh(index, new_scene.tris[: new_scene.n_tris])
            if bvh is not None:
                return bvh, True
        return self.build_index(new_scene, grid_g=grid_g), False

    def count(self, req: QueryRequest) -> np.ndarray:
        bvh = req.index
        if bvh is None:
            bvh = self.build_index(req.scene, grid_g=req.grid_g)
        return np.asarray(
            bvh_hit_counts(
                req.xs,
                req.ys,
                bvh.left,
                bvh.right,
                bvh.bbox,
                req.scene.coeffs[: req.scene.n_tris],
                k=req.k,
            )
        )

    def prepare_batch(self, req: BatchRequest):
        indexes = req.indexes
        if indexes is None:
            indexes = [self.build_index(s, grid_g=req.grid_g) for s in req.scenes]
        return stack_bvhs(indexes, [s.coeffs[: s.n_tris] for s in req.scenes])

    def count_batch(self, req: BatchRequest, prepared) -> np.ndarray:
        if req.dispatch is not None:
            return np.asarray(req.dispatch(prepared))
        left, right, bbox, coeffs = prepared
        return np.asarray(
            bvh_hit_counts_batch(req.xs, req.ys, left, right, bbox, coeffs, k=req.k)
        )


# --------------------------------------------------------------------------
# Brute (exact distance-rank counting; no geometry at all)
# --------------------------------------------------------------------------


@register_backend
class BruteBackend(Backend):
    name = "brute"
    uses_scene = False

    def count(self, req: QueryRequest) -> np.ndarray:
        return np.asarray(
            _ops.rank_count(
                req.users, req.facilities, req.q_pt, exclude=req.exclude, backend="ref"
            )
        )

    def count_batch(self, req: BatchRequest, prepared) -> np.ndarray:
        return np.asarray(
            _ops.rank_count_batch(
                req.users, req.facilities, req.q_pts, exclude=req.excludes
            )
        )


# --------------------------------------------------------------------------
# Auto (the query planner — registered last so concrete backends come first)
# --------------------------------------------------------------------------

from repro.planner.backend import PlannerBackend  # noqa: E402 — deliberate tail
                                                  # import; the planner module
                                                  # has no core imports at
                                                  # module level (acyclic)

register_backend(PlannerBackend)
