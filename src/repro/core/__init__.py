"""RT-RkNN core: the paper's contribution as a composable JAX module.

Public surface:
  * :func:`repro.core.rknn.rt_rknn_query` — one-call bichromatic RkNN
  * :func:`repro.core.rknn.rt_rknn_query_batch` — batched multi-query
    engine (one static-shape device dispatch per query batch)
  * :func:`repro.core.rknn.rknn_mono_query` — monochromatic variant
  * :mod:`repro.core.scene` — per-query occluder scene construction
  * :mod:`repro.core.baselines` — SIX / TPL / InfZone / SLICE comparators
"""

from repro.core.geometry import Rect
from repro.core.rknn import (
    BACKENDS,
    RkNNBatchResult,
    RkNNResult,
    rknn_mono_query,
    rt_rknn_query,
    rt_rknn_query_batch,
)
from repro.core.scene import Scene, build_scene

__all__ = [
    "Rect",
    "Scene",
    "build_scene",
    "rt_rknn_query",
    "rt_rknn_query_batch",
    "rknn_mono_query",
    "RkNNResult",
    "RkNNBatchResult",
    "BACKENDS",
]
