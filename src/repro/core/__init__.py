"""RT-RkNN core: the paper's contribution as a composable JAX module.

Public surface:
  * :class:`repro.core.engine.RkNNEngine` — stateful query engine (build
    once from ``(facilities, users, RkNNConfig)``; query/batch/mono/stream)
  * :mod:`repro.core.backends` — pluggable verification backend registry
    (including the ``"auto"`` planner backend; see :mod:`repro.planner`)
  * :func:`repro.core.rknn.rt_rknn_query` — one-shot bichromatic RkNN shim
  * :func:`repro.core.rknn.rt_rknn_query_batch` — one-shot batched shim
  * :func:`repro.core.rknn.rknn_mono_query` — monochromatic variant
  * :mod:`repro.core.scene` — per-query occluder scene construction
  * :mod:`repro.core.baselines` — SIX / TPL / InfZone / SLICE comparators

Lifecycle, config knobs, and the free-function migration table: docs/API.md.
"""

from repro.core.backends import (
    Backend,
    available_backends,
    concrete_backends,
    get_backend,
    register_backend,
)
from repro.core.engine import EngineStats, RkNNConfig, RkNNEngine
from repro.core.geometry import Rect
from repro.core.rknn import (
    BACKENDS,
    RkNNBatchResult,
    RkNNResult,
    rknn_mono_query,
    rt_rknn_query,
    rt_rknn_query_batch,
)
from repro.core.scene import Scene, build_scene

__all__ = [
    "Rect",
    "Scene",
    "build_scene",
    "RkNNEngine",
    "RkNNConfig",
    "EngineStats",
    "Backend",
    "register_backend",
    "get_backend",
    "available_backends",
    "concrete_backends",
    "rt_rknn_query",
    "rt_rknn_query_batch",
    "rknn_mono_query",
    "RkNNResult",
    "RkNNBatchResult",
    "BACKENDS",
]
