"""Result containers for the RkNN query surface.

Kept in a leaf module (no intra-``core`` imports) so the engine, the
backend registry, the hybrid dispatcher, and the legacy free functions can
all share them without import cycles.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.scene import Scene

__all__ = ["RkNNResult", "RkNNBatchResult"]


@dataclasses.dataclass
class RkNNResult:
    """Query result + phase timings (paper's filtering/verification split).

    Following §4.1 we report the two-stage convention of [62]: *filtering*
    = scene construction (pruning + occluders + grid/BVH index build),
    *verification* = the ray-cast / count stage only.

    ``counts`` convention: for bichromatic queries these are raw occluder
    hit counts (saturated at ``k`` for the bvh early-exit backend).  For
    monochromatic queries they are self-hit corrected — ``counts[p]`` is
    the number of *other* points strictly closer to ``p`` than ``q`` is,
    so ``mask == counts < k`` holds in both cases.

    ``version`` is the engine snapshot version the result was served
    from (0 for static engines and one-shot shims) — under concurrent
    updates it identifies exactly which ``(facilities, users)`` state the
    masks are bit-identical to.
    """

    mask: np.ndarray  # [N] bool — u ∈ RkNN(q)
    counts: np.ndarray  # [N] int32 hit counts (saturated for bvh early-exit)
    scene: Scene | None
    t_filter_s: float
    t_verify_s: float
    backend: str
    version: int = 0

    @property
    def result_indices(self) -> np.ndarray:
        return np.flatnonzero(self.mask)


@dataclasses.dataclass
class RkNNBatchResult:
    """Batched multi-query result: per-query masks + amortized timings.

    ``t_filter_s`` covers the whole batch's host work (scene builds,
    padding/stacking, index builds — or a scene-cache lookup when the
    engine has seen the workload before); ``t_verify_s`` is the single
    batched device dispatch.  Per-query attribution is therefore the mean:
    ``t_filter_s / len(qs)`` etc.

    ``scenes`` is ``None`` for the geometry-free brute backend and a
    (possibly empty) list for every geometric backend.
    """

    masks: np.ndarray  # [Q, N] bool — u ∈ RkNN(q_i)
    counts: np.ndarray  # [Q, N] int32 (saturated at k for bvh early-exit)
    scenes: list[Scene] | None  # None for the brute backend
    t_filter_s: float
    t_verify_s: float
    backend: str
    k: int
    #: Engine snapshot version served (see :class:`RkNNResult.version`).
    version: int = 0

    @property
    def n_queries(self) -> int:
        return len(self.masks)

    def result_indices(self, i: int) -> np.ndarray:
        return np.flatnonzero(self.masks[i])

    def per_query(self, i: int) -> RkNNResult:
        """View of query ``i`` with mean-amortized timings."""
        q_n = max(self.n_queries, 1)
        return RkNNResult(
            mask=self.masks[i],
            counts=self.counts[i],
            scene=None if self.scenes is None else self.scenes[i],
            t_filter_s=self.t_filter_s / q_n,
            t_verify_s=self.t_verify_s / q_n,
            backend=self.backend,
            version=self.version,
        )
