"""Immutable, versioned ownership of all per-dataset derived state.

:class:`EngineSnapshot` is the MVCC unit of the engine: one object owns
the ``(facilities, users)`` arrays *and* every piece of derived state the
query paths amortize against them — the domain rect/hull, the facility
fingerprint, the device-resident user coordinate arrays (plain and
mesh-sharded), the :class:`~repro.core.hybrid.SceneCache`, the per-scene
grid/BVH index memo, the grid-pallas user-bucketing memo, and the
prepared-batch LRU (including ``auto`` plan memos).

Concurrency model (reader side is lock-free):

* Every public query entry point resolves ``snap = engine._snap``
  exactly **once** — a single atomic attribute read — and serves that
  version end-to-end.  No lock is acquired anywhere on the read path:
  the per-snapshot caches below expose GIL-atomic lock-free ``get`` and
  take their internal lock only on *insertion* (eviction safety), so
  concurrent readers of one snapshot coordinate without blocking and a
  writer never touches a published snapshot's caches at all.
* ``DynamicEngine.apply_updates`` builds version N+1 **off to the side**
  (copy-on-write: unchanged scenes, indexes, packed planes, bucketing,
  and device arrays are carried by reference) and publishes it with one
  atomic reference swap of ``engine._snap``.  In-flight queries keep
  serving version N; the next query entry sees N+1.

Lazy fields (``rect``, ``xs``/``ys``, fingerprint, the mono sub-engine)
are computed idempotently from immutable inputs: a racing first touch may
compute the value twice, both results are equal, and the last assignment
wins — a benign race, not a correctness hazard.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any

import numpy as np

import jax.numpy as jnp

from repro.core.geometry import Rect

__all__ = ["LruCache", "IndexMemo", "EngineSnapshot"]


class LruCache:
    """Capacity-bounded mapping with a lock-free read path.

    ``get`` is a plain (GIL-atomic) dict read — no lock, no recency
    update, so concurrent readers never block; eviction is therefore
    insertion-ordered (FIFO) rather than strict LRU, which is
    indistinguishable at the small capacities the engine uses.  ``put``
    takes the internal lock only to keep eviction consistent under
    concurrent inserts.
    """

    __slots__ = ("capacity", "_store", "_lock")

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._store: "collections.OrderedDict[Any, Any]" = collections.OrderedDict()
        self._lock = threading.Lock()

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key) -> bool:
        return key in self._store

    def get(self, key, default=None):
        return self._store.get(key, default)

    def put(self, key, value) -> None:
        if self.capacity <= 0:
            return
        with self._lock:
            self._store[key] = value
            while len(self._store) > self.capacity:
                self._store.popitem(last=False)

    def keys(self) -> list:
        with self._lock:
            return list(self._store)

    def items(self) -> list:
        with self._lock:
            return list(self._store.items())


class IndexMemo:
    """Per-scene index store: ``id(scene) -> (scene, {key: index})``.

    Replaces the old practice of hanging ``_engine_indexes`` /
    ``_grid_index_memo`` dicts off :class:`~repro.core.scene.Scene`
    objects via ``object.__setattr__`` — index state now lives with the
    snapshot that owns the scenes, so an update can migrate or drop it
    per version without mutating scenes shared across versions.

    Entries hold a *strong* reference to the scene, which both keeps the
    ``id()`` key valid for the entry's lifetime and bounds memory via the
    capacity (scenes evicted here simply rebuild their index on next
    use).  Reads of an existing per-scene store are lock-free; creating
    or adopting an entry locks for eviction safety.
    """

    __slots__ = ("capacity", "_store", "_lock")

    def __init__(self, capacity: int = 256):
        self.capacity = max(int(capacity), 1)
        self._store: "collections.OrderedDict[int, tuple]" = collections.OrderedDict()
        self._lock = threading.Lock()

    def peek(self, scene) -> dict | None:
        """The scene's index store, or ``None`` — never creates."""
        hit = self._store.get(id(scene))
        if hit is not None and hit[0] is scene:
            return hit[1]
        return None

    def store_for(self, scene) -> dict:
        """The scene's index store, created (and capacity-evicted) if new."""
        key = id(scene)
        hit = self._store.get(key)
        if hit is not None and hit[0] is scene:
            return hit[1]
        with self._lock:
            hit = self._store.get(key)
            if hit is not None and hit[0] is scene:
                return hit[1]
            store: dict = {}
            self._store[key] = (scene, store)
            while len(self._store) > self.capacity:
                self._store.popitem(last=False)
            return store

    def adopt(self, scene, store: dict | None) -> None:
        """Install ``store`` as the scene's index store (COW carry: the
        update path moves a surviving scene's indexes into the next
        snapshot's memo without touching the old snapshot's)."""
        if store is None:
            return
        with self._lock:
            self._store[id(scene)] = (scene, store)
            while len(self._store) > self.capacity:
                self._store.popitem(last=False)

    def scenes(self) -> list:
        with self._lock:
            return [scene for scene, _store in self._store.values()]

    def clone(self) -> "IndexMemo":
        """Shallow copy — per-scene stores are copied (``dict(store)``) so
        the two versions stop sharing mutable dicts, while the indexes
        themselves are shared by reference (structural sharing)."""
        new = IndexMemo(self.capacity)
        with self._lock:
            for key, (scene, store) in self._store.items():
                new._store[key] = (scene, dict(store))
        return new


class EngineSnapshot:
    """One immutable version of the engine's dataset + derived state.

    Treated as frozen after publication except for the *lazy* fields
    (idempotent computations from immutable inputs — see module
    docstring) and the per-snapshot caches, which are append-only memos
    readers of this version share.
    """

    __slots__ = (
        "version",
        "facilities",
        "users",
        "explicit_rect",
        "scene_cache",
        "index_memo",
        "kernel_memo",
        "batch_cache",
        "mesh_xs",
        "mesh_ys",
        "mesh_n",
        "shard_state",
        "_rect",
        "_hull",
        "_fp",
        "_xs",
        "_ys",
        "_mono",
        "_is_mono",
        "_pad_waste",
    )

    def __init__(
        self,
        version: int,
        facilities: np.ndarray,
        users: np.ndarray,
        *,
        rect: Rect | None = None,
        explicit_rect: bool = False,
        scene_cache=None,
        index_capacity: int = 256,
        batch_capacity: int = 8,
        kernel_capacity: int = 4,
    ):
        self.version = int(version)
        self.facilities = facilities
        self.users = users
        self.explicit_rect = bool(explicit_rect)
        self.scene_cache = scene_cache
        self.index_memo = IndexMemo(index_capacity)
        self.kernel_memo = LruCache(kernel_capacity)
        self.batch_cache = LruCache(batch_capacity)
        self.mesh_xs = self.mesh_ys = None
        self.mesh_n = 0
        #: Per-shard replica views of this version's users (built lazily by
        #: ShardedEngine, swapped in as ONE object so a reader never sees a
        #: mixed-version shard set — the version-lockstep rule).
        self.shard_state = None
        self._rect = rect
        self._hull: tuple[np.ndarray, np.ndarray] | None = None
        self._fp: int | None = None
        self._xs = self._ys = None
        self._mono = None
        self._is_mono: bool | None = None
        self._pad_waste: dict = {}

    # ------------------------------------------------------------------
    # lazy derived state (idempotent; benign first-touch races)
    # ------------------------------------------------------------------
    @property
    def rect(self) -> Rect:
        if self._rect is None:
            self._rect = Rect.from_bounds(*self.hull_bounds())
        return self._rect

    def hull_bounds(self) -> tuple[np.ndarray, np.ndarray]:
        """Unpadded min/max of facilities ∪ users (lazy, cached)."""
        if self._hull is None:
            pts = np.concatenate([self.facilities, self.users])
            self._hull = (pts.min(axis=0), pts.max(axis=0))
        return self._hull

    def fingerprint(self) -> int:
        if self._fp is None:
            from repro.core.hybrid import SceneCache

            self._fp = SceneCache.fingerprint(self.facilities)
        return self._fp

    @property
    def xs(self) -> jnp.ndarray:
        if self._xs is None:
            # assign ys first: a racing reader that observes _xs non-None
            # must be able to read _ys without a second materialization
            ys = jnp.asarray(self.users[:, 1], jnp.float32)
            xs = jnp.asarray(self.users[:, 0], jnp.float32)
            self._ys = ys
            self._xs = xs
        return self._xs

    @property
    def ys(self) -> jnp.ndarray:
        self.xs  # noqa: B018 — materializes both
        return self._ys

    def pad_waste(self, rect: Rect, grid_g: int) -> float:
        """Measured cell-bucketing pad-waste ratio of this user set
        (``padded rows / n_users``, ≥ 1) at the engine's grid resolution —
        the planner's occupancy feature for the grid-pallas family
        (memoized per (rect, G); see
        :func:`repro.kernels.grid_raycast.measured_pad_waste`)."""
        key = (rect, int(grid_g))
        hit = self._pad_waste.get(key)
        if hit is None:
            from repro.kernels.grid_raycast import measured_pad_waste

            hit = measured_pad_waste(
                self.users[:, 0], self.users[:, 1], rect, int(grid_g)
            )
            self._pad_waste[key] = hit
        return hit

    def device_bytes(self) -> dict[str, int]:
        """Live array bytes owned by this snapshot version, by category.

        Walks the snapshot's caches/memos and sums ``nbytes`` of every
        reachable jax/numpy array exactly once (an id-based seen set is
        shared across categories, so structurally-shared arrays — COW
        carries, replicated planes — are charged to the first category
        that reaches them and the total never double counts).  Read-only
        over lock-free accessors; an update publishing mid-walk at worst
        skews one scrape, never tears it.
        """
        seen: set[int] = set()
        # order matters for attribution (not for the total): scenes walk
        # before the index memo so packed occluder geometry lands under
        # "scenes" and the memo contributes only the index-side arrays.
        out = {
            "users": _nbytes_walk(
                (self.users, self.facilities, self._xs, self._ys,
                 self.mesh_xs, self.mesh_ys),
                seen,
            ),
            "shards": _nbytes_walk(self.shard_state, seen),
            "scenes": _nbytes_walk(
                self.scene_cache.scenes() if self.scene_cache is not None else None,
                seen,
            ),
            "indexes": _nbytes_walk(list(self.index_memo._store.values()), seen),
            "kernel": _nbytes_walk(self.kernel_memo.items(), seen),
            "batches": _nbytes_walk(self.batch_cache.items(), seen),
        }
        out["total"] = sum(out.values())
        return out


_ATOMS = (str, bytes, int, float, bool, type(None))


def _nbytes_walk(obj, seen: set[int]) -> int:
    """Sum of ``nbytes`` over every array reachable from ``obj`` through
    dicts/sequences/dataclasses/``__slots__`` objects, deduplicated by
    identity."""
    if isinstance(obj, _ATOMS):
        return 0
    oid = id(obj)
    if oid in seen:
        return 0
    seen.add(oid)
    nb = getattr(obj, "nbytes", None)
    if nb is not None and isinstance(nb, (int, np.integer)):
        return int(nb)
    if isinstance(obj, dict):
        return sum(_nbytes_walk(v, seen) for v in obj.values())
    if isinstance(obj, (list, tuple, set, frozenset)):
        return sum(_nbytes_walk(v, seen) for v in obj)
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return sum(
            _nbytes_walk(getattr(obj, f.name, None), seen)
            for f in dataclasses.fields(obj)
        )
    slots = getattr(type(obj), "__slots__", None)
    if slots:
        return sum(_nbytes_walk(getattr(obj, s, None), seen) for s in slots)
    return 0
