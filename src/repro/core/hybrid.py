"""Paper future-work items implemented (Conclusions §5, directions 2–3).

* **Scene cache** (direction 2 — "batched query processing to amortize
  scene construction"): per-(facility-set, q, k, rect) LRU of built scenes.
  A repeated query skips InfZone pruning + occluder construction entirely —
  in serving workloads with hot facilities (the paper's motivating
  hospitals / delivery hubs) this amortizes the dominant per-query cost
  (EXPERIMENTS §Perf-RkNN: filter ≈ 20–100 ms vs sub-ms cast).  The
  long-lived owner of a cache is :class:`repro.core.engine.RkNNEngine`,
  which wires it into the single, batched, and streaming paths.

* **Hybrid dispatcher** (direction 3 — "dynamically select between
  RT-RkNN and traditional pruning based on data characteristics"): now a
  *shim over the query planner* (:mod:`repro.planner`).  The full
  realization of this direction is the ``auto`` backend — calibrated
  per-backend cost models, per-query dispatch, batch splitting — and
  :func:`choose_engine` prices its RT-vs-SLICE frontier from the same
  active profile.  Only when no profile is installed does it fall back
  (warning once) to the constants fitted offline to ``bench_output.txt``:

      cost_rt    ≈ c_scene(|F|, k)      +  c_cast · m(|F|, k) · |U|
      cost_slice ≈ c_filter(|F|)        +  c_verify · k · candidates(|U|, k)

  The paper's empirical law (Figs 7–13): SLICE wins at dense facilities /
  small k / small |U|; RT wins at sparse |F|, large k, large |U| —
  validated on both extremes in ``tests/test_hybrid.py``.
"""

from __future__ import annotations

import collections
import threading
import time

import numpy as np

import jax.numpy as jnp

from repro.core.baselines.slice import slice_rknn
from repro.core.results import RkNNResult
from repro.core.scene import Scene, build_scene

__all__ = ["SceneCache", "choose_engine", "hybrid_rknn_query"]


def _q_key(q):
    """Hashable cache key component for a query (index or [2] point)."""
    if np.isscalar(q) or isinstance(q, (int, np.integer)):
        return int(q)
    return tuple(np.asarray(q, np.float64).reshape(-1).tolist())


class SceneCache:
    """LRU of built scenes keyed by (facility-set fingerprint, q, k, rect).

    ``rect`` participates in the key because occluder triangles are clipped
    against the domain rectangle — the same query under a different rect is
    a different scene (the batched grid path additionally requires every
    stacked scene to share one rect).  Long-lived callers (the engine) pass
    a precomputed ``fp`` so the facility array is fingerprinted once, not
    per query.

    The *read* path (``contains`` / a ``get_or_build`` hit) is lock-free:
    a plain GIL-atomic dict read, no recency update — so concurrent
    readers of one engine snapshot never block each other.  Insertions
    take the internal lock for eviction safety, which makes eviction
    insertion-ordered (FIFO) rather than strict LRU.  The hit/miss
    counters are racy-increment statistics by design.
    """

    def __init__(self, capacity: int = 256):
        self.capacity = capacity
        self._store: "collections.OrderedDict[tuple, Scene]" = collections.OrderedDict()
        self._lock = threading.Lock()  # engine may build scenes from a pool
        self.hits = 0
        self.misses = 0
        self.delta_kept = 0
        self.delta_dropped = 0

    def __len__(self) -> int:
        return len(self._store)

    @staticmethod
    def fingerprint(facilities: np.ndarray) -> int:
        f = np.ascontiguousarray(facilities, dtype=np.float64)
        return hash((f.shape, f.tobytes()[:4096], float(f.sum())))

    def contains(self, facilities, q, k, rect=None, *, fp: int | None = None) -> bool:
        """Peek (no stats) — the planner prices a cache hit as "filter
        phase free" before deciding where to dispatch.  Lock-free."""
        if fp is None:
            fp = self.fingerprint(facilities)
        return (fp, _q_key(q), k, rect) in self._store

    def get_or_build(
        self, facilities, q, k, rect=None, *, fp: int | None = None, **kw
    ) -> tuple[Scene, bool]:
        if fp is None:
            fp = self.fingerprint(facilities)
        key = (fp, _q_key(q), k, rect)
        scene = self._store.get(key)  # lock-free hit path
        if scene is not None:
            self.hits += 1
            return scene, True
        scene = build_scene(facilities, q, k, rect, **kw)
        with self._lock:
            self._store[key] = scene
            if len(self._store) > self.capacity:
                self._store.popitem(last=False)
            self.misses += 1
        return scene, False

    def scenes(self) -> list[Scene]:
        """Snapshot of the cached scenes (migration iterates this)."""
        with self._lock:
            return list(self._store.values())

    def items(self) -> list[tuple[tuple, Scene]]:
        """Snapshot of ``(key, scene)`` pairs in insertion order — the
        persistence layer serializes these (key = ``(fp, q_key, k,
        rect)``)."""
        with self._lock:
            return list(self._store.items())

    def seed(self, key: tuple, scene: Scene) -> None:
        """Insert a restored entry without touching the miss counter.

        Used by warm restore (:mod:`repro.persist`): the entry is re-keyed
        under the *live* process's facility fingerprint — the ``hash()``
        in :meth:`fingerprint` is salted per process, so persisted keys
        are never reused verbatim."""
        with self._lock:
            self._store[key] = scene
            while len(self._store) > self.capacity:
                self._store.popitem(last=False)

    def cow_migrate(self, select, migrate) -> tuple["SceneCache", int, int]:
        """Copy-on-write delta migration: build the **next version's**
        cache without touching this one (readers of the current engine
        snapshot keep serving it unchanged).

        For every entry whose key satisfies ``select(key)``, ``migrate(key,
        scene)`` is called; a ``(new_key, new_scene)`` return carries the
        entry into the new cache under its post-update key, ``None`` drops
        it; non-selected entries are carried as-is.  This is how the
        dynamic subsystem keeps scenes that provably survive an update
        across the facility-fingerprint change that would otherwise strand
        them.  The cumulative hit/miss/delta counters carry into the new
        cache (they are engine-lifetime statistics, not per-version).
        Returns ``(new_cache, n_migrated, n_dropped)``.
        """
        kept = dropped = 0
        with self._lock:
            items = list(self._store.items())
        new = SceneCache(capacity=self.capacity)
        for key, scene in items:
            if not select(key):
                new._store[key] = scene
                continue
            res = migrate(key, scene)
            if res is None:
                dropped += 1
                continue
            new_key, new_scene = res
            new._store[new_key] = new_scene
            kept += 1
        new.hits, new.misses = self.hits, self.misses
        new.delta_kept = self.delta_kept + kept
        new.delta_dropped = self.delta_dropped + dropped
        return new, kept, dropped


_warned_no_profile = False


def choose_engine(n_facilities: int, n_users: int, k: int) -> str:
    """'rt' or 'slice' from the RT-vs-filter–refine cost frontier.

    With an *active* planner profile (:func:`repro.planner.profiles.
    set_active_profile`, typically installed after running
    :mod:`repro.planner.calibrate` on this hardware), the frontier is a
    live lookup: the cheapest registered RT-path backend vs. the
    profile's ``"slice"`` pseudo-backend model.

    With no profile, falls back — warning once — to the constants fitted
    offline to ``bench_output.txt`` (our CPU crossovers, not the paper's
    GPU ones):

        rt_ms    ≈ 30 + 1.5·k + 0.35·|U|/1e3            (scene + cast)
        slice_ms ≈ 0.002·|F| + 0.4·k^1.5·(|U|/|F|)/1e3  (filter + verify)

    Validation points for the fallback: fig9 k=25 → slice 60 (meas 128) /
    rt 487 (meas 910); k=200 → slice 1357 (meas 2230) / rt 900 (meas
    2553) — right ordering at both ends, crossover near the measured one.
    """
    if n_facilities <= 0:
        return "rt"

    from repro.planner.profiles import get_active_profile

    prof = get_active_profile()
    reason = None
    if prof is None:
        reason = "no active planner profile"
    elif "slice" not in prof.models:
        reason = (
            "the active profile has no 'slice' model (calibrated with "
            "--no-slice?)"
        )
    else:
        from repro.planner.models import WorkloadShape

        shape = WorkloadShape(n_facilities, n_users, k, 1)
        # price the 'rt' side with what the rt branch actually executes:
        # dense-ref when the profile knows it, else the cheapest scene-
        # using backend.  brute (no ray casting) and interpret-mode dense
        # (a correctness tool) are not the rt path and must not stand in
        # for its cost.
        if "dense-ref" in prof.models:
            rt_candidates: tuple[str, ...] = ("dense-ref",)
        else:
            from repro.core.backends import available_backends, get_backend

            rt_candidates = tuple(
                n
                for n in prof.models
                if n not in ("slice", "dense")
                and n in available_backends()
                and get_backend(n).uses_scene
            )
        if rt_candidates:
            _, rt_s = prof.best_backend(shape, rt_candidates)
            slice_s = prof.predict_s("slice", shape)
            return "rt" if rt_s < slice_s else "slice"
        reason = "the active profile has no usable RT-path backend model"

    global _warned_no_profile
    if not _warned_no_profile:
        _warned_no_profile = True
        import warnings

        warnings.warn(
            f"choose_engine: {reason} — falling back to hard-coded cost "
            "constants fitted offline (likely stale for this hardware). "
            "Run repro.planner.calibrate and set_active_profile() to use "
            "measured costs.",
            RuntimeWarning,
            stacklevel=2,
        )
    rt_ms = 30.0 + 1.5 * k + 0.35 * n_users / 1e3
    slice_ms = 0.002 * n_facilities + 0.4 * (k**1.5) * (n_users / max(n_facilities, 1)) / 1e3
    return "rt" if rt_ms < slice_ms else "slice"


def hybrid_rknn_query(
    facilities: np.ndarray,
    users: np.ndarray,
    q: int,
    k: int,
    *,
    cache: SceneCache | None = None,
    force: str | None = None,
) -> RkNNResult:
    """Dispatch to the predicted-faster engine (paper future-work 3),
    optionally amortizing scene construction through ``cache`` (future-
    work 2).  Returns an :class:`RkNNResult` either way."""
    engine = force or choose_engine(len(facilities), len(users), k)
    if engine == "slice":
        mask, info = slice_rknn(facilities, users, q, k)
        return RkNNResult(
            mask=mask,
            counts=np.where(mask, 0, k).astype(np.int32),  # verdicts only
            scene=None,
            t_filter_s=info["t_filter_s"],
            t_verify_s=info["t_verify_s"],
            backend="slice",
        )
    if cache is not None:
        from repro.core.backends import QueryRequest, get_backend

        t0 = time.perf_counter()
        scene, hit = cache.get_or_build(facilities, q, k, users_hint=users)
        t1 = time.perf_counter()
        backend = get_backend("dense-ref")
        users = np.asarray(users, np.float64)
        counts = backend.count(
            QueryRequest(
                xs=jnp.asarray(users[:, 0], jnp.float32),
                ys=jnp.asarray(users[:, 1], jnp.float32),
                k=k,
                scene=scene,
            )
        )
        t2 = time.perf_counter()
        return RkNNResult(counts < k, counts, scene, t1 - t0, t2 - t1, "dense-ref")
    from repro.core.rknn import rt_rknn_query

    return rt_rknn_query(facilities, users, q, k, backend="dense-ref")
