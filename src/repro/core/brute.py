"""Exact RkNN oracles (ground truth for every other path in this repo).

``u`` is an RkNN of ``q`` iff fewer than ``k`` competing facilities are
*strictly* closer to ``u`` than ``q`` is (paper §2.1).  Ties (equal
distance) therefore do **not** count against ``u`` — matching the open
half-plane "invalid side" convention used by the occluders.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

__all__ = ["rank_counts_np", "rknn_brute_np", "rknn_mono_brute_np", "rank_counts_jnp"]


def rank_counts_np(
    users: np.ndarray, facilities: np.ndarray, q: np.ndarray, exclude: int | None = None
) -> np.ndarray:
    """#competitors strictly closer than ``q`` per user — ``[N]`` int64."""
    users = np.asarray(users, dtype=np.float64)
    facilities = np.asarray(facilities, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    d2q = np.sum((users - q) ** 2, axis=1)
    counts = np.zeros(len(users), dtype=np.int64)
    # chunked to bound the [N, M] intermediate
    chunk = max(1, int(2**24 // max(len(facilities), 1)))
    mask_f = np.ones(len(facilities), dtype=bool)
    if exclude is not None:
        mask_f[exclude] = False
    fac = facilities[mask_f]
    for s in range(0, len(users), chunk):
        e = min(s + chunk, len(users))
        d2 = (
            np.sum(users[s:e] ** 2, axis=1)[:, None]
            - 2.0 * users[s:e] @ fac.T
            + np.sum(fac**2, axis=1)[None, :]
        )
        counts[s:e] = np.sum(d2 < d2q[s:e, None], axis=1)
    return counts


def rknn_brute_np(
    users: np.ndarray,
    facilities: np.ndarray,
    q: np.ndarray | int,
    k: int,
) -> np.ndarray:
    """Bichromatic RkNN membership mask ``[N]`` bool (exact)."""
    if isinstance(q, (int, np.integer)):
        q_pt = np.asarray(facilities, dtype=np.float64)[int(q)]
        exclude: int | None = int(q)
    else:
        q_pt = np.asarray(q, dtype=np.float64)
        exclude = None
    return rank_counts_np(users, facilities, q_pt, exclude=exclude) < k


def rknn_mono_brute_np(points: np.ndarray, q_idx: int, k: int) -> np.ndarray:
    """Monochromatic RkNN over one point set ``P`` (paper §2.1).

    ``p ∈ RkNN(q)`` iff fewer than ``k`` points of ``P \\ {p, q}`` are
    strictly closer to ``p`` than ``q`` is.  Row ``q_idx`` itself is False.
    """
    points = np.asarray(points, dtype=np.float64)
    q = points[q_idx]
    d2q = np.sum((points - q) ** 2, axis=1)
    d2 = (
        np.sum(points**2, axis=1)[:, None]
        - 2.0 * points @ points.T
        + np.sum(points**2, axis=1)[None, :]
    )
    closer = d2 < d2q[:, None]
    np.fill_diagonal(closer, False)  # a != p
    closer[:, q_idx] = False  # a != q
    counts = closer.sum(axis=1)
    out = counts < k
    out[q_idx] = False
    return out


def rank_counts_jnp(users, facilities, q):
    """jnp mirror of :func:`rank_counts_np` (used inside jitted baselines)."""
    d2q = jnp.sum((users - q) ** 2, axis=1)
    d2 = (
        jnp.sum(users**2, axis=1)[:, None]
        - 2.0 * users @ facilities.T
        + jnp.sum(facilities**2, axis=1)[None, :]
    )
    return jnp.sum(d2 < d2q[:, None], axis=1)
