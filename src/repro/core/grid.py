"""Uniform-grid occluder index — the TPU-native analogue of the BVH.

A BVH walk is pointer-chasing with per-ray divergence; a TPU wants static
shapes and predictable gathers.  This index replaces the hierarchy with a
flat ``G x G`` raster of the domain and splits every occluder's coverage of
each cell into two classes:

* **full coverage** — the triangle contains the entire (closed) cell.  These
  never need a per-user test: a per-cell ``base`` counter absorbs them.
  This is the grid-granular generalisation of the paper's early-ray
  termination: a cell with ``base >= k`` is *saturated* — every user in it
  is pruned with zero intersection tests.
* **partial coverage** — the triangle's boundary crosses the cell (exact
  SAT overlap minus full containment).  Only these go into the per-cell
  candidate list, which is padded to the max list length so a single gather
  + edge-function evaluation answers every user in the cell.

Exactness: for any user ``u`` in cell ``c``,
``hits(u) == base[c] + #{t in list[c] : u inside t}`` — fully-covering
triangles contain ``u`` by convexity, listed triangles are tested exactly,
and non-overlapping triangles cannot contain ``u``.  Property-tested against
the dense count in ``tests/test_core_rknn.py``.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.geometry import Rect

__all__ = [
    "OccluderGrid",
    "build_grid",
    "refit_grid",
    "grid_hit_counts_jnp",
    "stack_grids",
    "grid_hit_counts_batch_jnp",
]


@dataclasses.dataclass
class OccluderGrid:
    """Packed grid index (host arrays; move to device as needed).

    ``base``:  ``[G*G]`` int32 fully-covering triangle counts.
    ``lists``: ``[G*G, L]`` int32 partial-overlap triangle ids, -1 padded.
    ``coeffs``: ``[M, 3, 3]`` float32 edge functions of all triangles.
    """

    base: np.ndarray
    lists: np.ndarray
    coeffs: np.ndarray
    G: int
    rect: Rect

    @property
    def max_list(self) -> int:
        return self.lists.shape[1]

    def occupancy(self) -> float:
        """Mean real entries per cell list (diagnostics / bench_breakdown)."""
        return float((self.lists >= 0).sum() / max(len(self.lists), 1))


def _tri_cell_classify(
    tri: np.ndarray, coeff: np.ndarray, rect: Rect, G: int
) -> tuple[np.ndarray, np.ndarray]:
    """(full_cells, partial_cells) flat cell ids for one triangle.

    Vectorized SAT over the cells of the triangle's clamped AABB:
    separating axes = 2 box axes + 3 edge normals (closed-set test).
    Full containment = all 4 cell corners pass all 3 inclusive edge tests.
    """
    w = rect.width / G
    h = rect.height / G
    # cells are EXPANDED by a float-rounding guard when classifying: a user
    # whose f32 cell assignment lands one ulp across a boundary must still
    # see correct counts, so "fully covers the cell" is certified on the
    # slightly larger box (near-boundary triangles demote to the partial
    # list, where they are tested exactly).
    eps = 1e-5 * max(w, h)
    lo = tri.min(axis=0)
    hi = tri.max(axis=0)
    ix0 = int(np.clip(np.floor((lo[0] - eps - rect.xmin) / w), 0, G - 1))
    ix1 = int(np.clip(np.floor((hi[0] + eps - rect.xmin) / w - 1e-12), 0, G - 1))
    iy0 = int(np.clip(np.floor((lo[1] - eps - rect.ymin) / h), 0, G - 1))
    iy1 = int(np.clip(np.floor((hi[1] + eps - rect.ymin) / h - 1e-12), 0, G - 1))
    if hi[0] < rect.xmin or lo[0] > rect.xmax or hi[1] < rect.ymin or lo[1] > rect.ymax:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)

    gx = np.arange(ix0, ix1 + 1)
    gy = np.arange(iy0, iy1 + 1)
    cx0 = rect.xmin + gx * w - eps  # expanded cell x-lo  [nx]
    cy0 = rect.ymin + gy * h - eps  # expanded cell y-lo  [ny]
    CX0, CY0 = np.meshgrid(cx0, cy0, indexing="ij")  # [nx, ny]
    CX1, CY1 = CX0 + w + 2 * eps, CY0 + h + 2 * eps

    # --- full containment: 4 corners x 3 edges inclusive -----------------
    corners_x = np.stack([CX0, CX1, CX1, CX0], axis=-1)  # [nx, ny, 4]
    corners_y = np.stack([CY0, CY0, CY1, CY1], axis=-1)
    e = (
        coeff[None, None, None, :, 0] * corners_x[..., None]
        + coeff[None, None, None, :, 1] * corners_y[..., None]
        + coeff[None, None, None, :, 2]
    )  # [nx, ny, 4, 3]
    corner_inside = np.all(e >= 0.0, axis=-1)  # [nx, ny, 4]
    full = np.all(corner_inside, axis=-1)  # [nx, ny]
    any_corner = np.any(corner_inside, axis=-1)

    # --- SAT overlap ------------------------------------------------------
    # box axes: triangle AABB vs cell (already restricted to AABB range,
    # but cells at the fringe may still miss on the exact AABB):
    overlap = (
        (CX1 >= lo[0]) & (CX0 <= hi[0]) & (CY1 >= lo[1]) & (CY0 <= hi[1])
    )
    # triangle edge normals: cell overlaps iff its max corner projection
    # onto each inward edge normal is >= 0 (some corner not strictly outside)
    e_max = np.max(e, axis=2)  # [nx, ny, 3] best corner per edge
    overlap &= np.all(e_max >= 0.0, axis=-1)
    # cells whose every corner is inside but SAT failed cannot happen;
    # partial = overlap and not full
    partial = overlap & ~full
    # cheap tightening: a cell with no corner inside and no triangle vertex
    # inside the cell can still overlap via an edge crossing — SAT already
    # decided that exactly, so nothing more to do.
    del any_corner

    flat = (gx[:, None] * G + gy[None, :]).astype(np.int64)
    return flat[full], flat[partial]


def build_grid(
    tris: np.ndarray,
    coeffs: np.ndarray,
    rect: Rect,
    G: int = 64,
    pad_list_to: int | None = None,
) -> OccluderGrid:
    """Build the grid index over real (unpadded) triangles."""
    tris = np.asarray(tris, dtype=np.float64)
    coeffs64 = np.asarray(coeffs, dtype=np.float64)
    M = len(tris)
    base = np.zeros(G * G, np.int32)
    cell_lists: list[list[int]] = [[] for _ in range(G * G)]
    for t in range(M):
        full, partial = _tri_cell_classify(tris[t], coeffs64[t], rect, G)
        base[full] += 1
        for c in partial:
            cell_lists[int(c)].append(t)
    L = max((len(l) for l in cell_lists), default=0)
    L = max(L, 1)
    if pad_list_to is not None:
        L = max(L, pad_list_to)
    lists = np.full((G * G, L), -1, np.int32)
    for c, l in enumerate(cell_lists):
        if l:
            lists[c, : len(l)] = l
    return OccluderGrid(
        base=base,
        lists=lists,
        coeffs=np.asarray(coeffs, dtype=np.float32),
        G=G,
        rect=rect,
    )


def refit_grid(
    grid: OccluderGrid,
    tris_old: np.ndarray,
    coeffs_old: np.ndarray,
    tris_new: np.ndarray,
    coeffs_new: np.ndarray,
    changed: np.ndarray,
) -> OccluderGrid | None:
    """Refit a grid index for a perturbed triangle set without a full rebuild.

    ``changed`` lists triangle ids whose geometry differs between the old
    arrays (the ones ``grid`` was built from) and the new ones; all other
    triangles must be identical.  Each changed triangle's old cell
    classification is subtracted and its new one added — O(|changed|)
    classification work instead of O(M).  Counts are exact regardless of
    list order (``hits = base + #inside-of-listed``), so a refit grid is
    count-identical to a fresh :func:`build_grid`.

    Returns a new :class:`OccluderGrid` (the input is never mutated — cached
    scenes may still alias it), or ``None`` when the refit cannot be done in
    place (triangle count changed, or a cell's candidate list would overflow
    the padded width) — the caller falls back to :func:`build_grid`.
    """
    if len(tris_old) != len(tris_new):
        return None
    changed = np.asarray(changed, dtype=np.int64)
    base = grid.base.copy()
    lists = grid.lists.copy()
    coeffs = grid.coeffs.copy()
    G, rect = grid.G, grid.rect
    tris_old = np.asarray(tris_old, np.float64)
    tris_new = np.asarray(tris_new, np.float64)
    co_old = np.asarray(coeffs_old, np.float64)
    co_new = np.asarray(coeffs_new, np.float64)
    for t in changed:
        t = int(t)
        full_o, part_o = _tri_cell_classify(tris_old[t], co_old[t], rect, G)
        full_n, part_n = _tri_cell_classify(tris_new[t], co_new[t], rect, G)
        base[full_o] -= 1
        base[full_n] += 1
        for c in part_o:
            row = lists[int(c)]
            row[row == t] = -1
        for c in part_n:
            row = lists[int(c)]
            slots = np.flatnonzero(row < 0)
            if not len(slots):
                return None  # padded width exhausted: rebuild
            row[slots[0]] = t
        coeffs[t] = co_new[t].astype(np.float32)
    return OccluderGrid(base=base, lists=lists, coeffs=coeffs, G=G, rect=rect)


def stack_grids(grids: list[OccluderGrid]) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stack per-query grid indices to common static shapes for one batched
    dispatch.

    All grids must share ``G`` and ``rect`` (the serving setup: one domain,
    many query scenes).  Candidate lists are right-padded with ``-1`` to the
    max list length; triangle coefficient tables are padded with degenerate
    never-inside rows so gathers on padded ids contribute nothing.  Returns
    ``(base [Q, G*G] i32, lists [Q, G*G, L] i32, coeffs [Q, Mt, 3, 3] f32)``.
    """
    if not grids:
        raise ValueError("stack_grids needs at least one grid")
    G = grids[0].G
    if any(g.G != G for g in grids):
        raise ValueError("all grids in a batch must share G")
    rect = grids[0].rect
    if any(g.rect != rect for g in grids):
        raise ValueError("all grids in a batch must share the domain rect")
    L = max(g.lists.shape[1] for g in grids)
    Mt = max(max(len(g.coeffs), 1) for g in grids)
    Q = len(grids)
    base = np.stack([g.base for g in grids]).astype(np.int32)
    lists = np.full((Q, G * G, L), -1, np.int32)
    coeffs = np.zeros((Q, Mt, 3, 3), np.float32)
    coeffs[:, :, :, 2] = -1.0  # degenerate default (never inside)
    for i, g in enumerate(grids):
        lists[i, :, : g.lists.shape[1]] = g.lists
        if len(g.coeffs):
            coeffs[i, : len(g.coeffs)] = g.coeffs
    return base, lists, coeffs


@functools.partial(jax.jit, static_argnums=(5, 6))
def grid_hit_counts_batch_jnp(xs, ys, base, lists, coeffs, rect: Rect, G: int):
    """Batched multi-query grid counting: ``[Q, N]`` counts in one dispatch.

    ``base``: ``[Q, G*G]``; ``lists``: ``[Q, G*G, L]``; ``coeffs``:
    ``[Q, Mt, 3, 3]`` (from :func:`stack_grids`).  The user→cell assignment
    is shared across queries (one domain rect), so it is computed once and
    the per-query work is a pure gather + edge-function evaluation.

    Jitted (``rect``/``G`` static) like every other grid-family execution:
    all of them must round the ``a·x + b·y + c`` edge evaluation the same
    way (XLA fuses it into FMAs), so a knife-edge ``>= 0`` tie cannot
    decide differently between the oracle and the bucketed kernels.
    """
    xs = jnp.asarray(xs)
    ys = jnp.asarray(ys)
    base = jnp.asarray(base)
    lists = jnp.asarray(lists)
    coeffs = jnp.asarray(coeffs)
    if coeffs.shape[1] == 0:  # occluder-free scenes: keep the gather legal
        coeffs = jnp.broadcast_to(
            jnp.asarray([0.0, 0.0, -1.0], coeffs.dtype),  # degenerate edge
            (coeffs.shape[0], 1, 3, 3),
        )
    w = rect.width / G
    h = rect.height / G
    cx = jnp.clip(jnp.floor((xs - rect.xmin) / w), 0, G - 1).astype(jnp.int32)
    cy = jnp.clip(jnp.floor((ys - rect.ymin) / h), 0, G - 1).astype(jnp.int32)
    cell = cx * G + cy  # [N] shared across queries

    def one(base_q, lists_q, coeffs_q):
        cand = lists_q[cell]  # [N, L]
        safe = jnp.maximum(cand, 0)
        e = coeffs_q[safe]  # [N, L, 3, 3]
        ev = e[..., 0] * xs[:, None, None] + e[..., 1] * ys[:, None, None] + e[..., 2]
        inside = jnp.all(ev >= 0.0, axis=-1) & (cand >= 0)
        return base_q[cell] + inside.sum(axis=-1).astype(jnp.int32)

    return jax.vmap(one)(base, lists, coeffs)


@functools.partial(jax.jit, static_argnums=(5, 6))
def grid_hit_counts_jnp(xs, ys, base, lists, coeffs, rect: Rect, G: int):
    """Vectorized grid query (pure jnp; Pallas variant in kernels/).

    ``hits[u] = base[cell(u)] + sum_t in list[cell(u)] inside(u, t)``.
    Jitted with ``rect``/``G`` static — see the batched variant for why.
    """
    xs = jnp.asarray(xs)
    ys = jnp.asarray(ys)
    coeffs = jnp.asarray(coeffs)
    if coeffs.shape[0] == 0:  # occluder-free scenes: keep the gather legal
        coeffs = jnp.broadcast_to(
            jnp.asarray([0.0, 0.0, -1.0], coeffs.dtype), (1, 3, 3)
        )
    w = rect.width / G
    h = rect.height / G
    cx = jnp.clip(jnp.floor((xs - rect.xmin) / w), 0, G - 1).astype(jnp.int32)
    cy = jnp.clip(jnp.floor((ys - rect.ymin) / h), 0, G - 1).astype(jnp.int32)
    cell = cx * G + cy
    cand = jnp.asarray(lists)[cell]  # [N, L]
    safe = jnp.maximum(cand, 0)
    e = coeffs[safe]  # [N, L, 3, 3]
    ev = e[..., 0] * xs[:, None, None] + e[..., 1] * ys[:, None, None] + e[..., 2]
    inside = jnp.all(ev >= 0.0, axis=-1) & (cand >= 0)
    return jnp.asarray(base)[cell] + inside.sum(axis=-1).astype(jnp.int32)
