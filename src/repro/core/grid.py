"""Uniform-grid occluder index — the TPU-native analogue of the BVH.

A BVH walk is pointer-chasing with per-ray divergence; a TPU wants static
shapes and predictable gathers.  This index replaces the hierarchy with a
flat ``G x G`` raster of the domain and splits every occluder's coverage of
each cell into two classes:

* **full coverage** — the triangle contains the entire (closed) cell.  These
  never need a per-user test: a per-cell ``base`` counter absorbs them.
  This is the grid-granular generalisation of the paper's early-ray
  termination: a cell with ``base >= k`` is *saturated* — every user in it
  is pruned with zero intersection tests.
* **partial coverage** — the triangle's boundary crosses the cell (exact
  SAT overlap minus full containment).  Only these go into the per-cell
  candidate list, which is padded to the max list length so a single gather
  + edge-function evaluation answers every user in the cell.

Exactness: for any user ``u`` in cell ``c``,
``hits(u) == base[c] + #{t in list[c] : u inside t}`` — fully-covering
triangles contain ``u`` by convexity, listed triangles are tested exactly,
and non-overlapping triangles cannot contain ``u``.  Property-tested against
the dense count in ``tests/test_core_rknn.py``.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.geometry import Rect

__all__ = [
    "OccluderGrid",
    "build_grid",
    "refit_grid",
    "grid_hit_counts_jnp",
    "shape_bucket",
    "stack_grids",
    "grid_hit_counts_batch_jnp",
    "build_throttle",
    "build_sleep",
    "build_slept_s",
]

#: Per-thread cooperative deprioritization for heavy index builds.  A
#: background maintenance thread (the MVCC writer prewarming scenes) sets
#: a positive ratio; the classify chunk loop then sleeps ``ratio x`` the
#: time each chunk of C-level work took, handing the GIL to foreground
#: query threads.  Foreground builds leave it at 0 and pay nothing.
_build_priority = threading.local()


def build_yield_ratio() -> float:
    """Current thread's cooperative-yield ratio (0.0 = foreground).

    Re-sampled inside the hot loops (per chunk / per iteration), so a
    callable ratio can engage or release mid-build as contention changes.
    """
    v = getattr(_build_priority, "yield_ratio", 0.0)
    return float(v()) if callable(v) else v


@contextlib.contextmanager
def build_throttle(ratio):
    """Make grid builds on THIS thread yield ``ratio x`` their CPU time.

    ``ratio=2.0`` caps the building thread at ~1/3 of a contended core, so
    concurrent readers keep ~2/3 instead of the fair-scheduling half — the
    single-core analogue of running index maintenance at low priority.

    ``ratio`` may be a zero-arg callable returning the current ratio —
    the MVCC writer passes one that flips from 0 to 2.0 the moment a
    concurrent reader is observed, so an uncontended engine never sleeps.
    """
    prev = getattr(_build_priority, "yield_ratio", 0.0)
    _build_priority.yield_ratio = ratio if callable(ratio) else float(ratio)
    try:
        yield
    finally:
        _build_priority.yield_ratio = prev


def build_sleep(seconds: float) -> None:
    """Cooperative-yield sleep with duty-cycle accounting.

    Every deprioritization sleep (the classify chunk loop here, the
    pruning iteration loop, the prewarm backstop) routes through this so
    the MVCC writer can report its throttle duty cycle — slept wall time
    over total update time — as an obs gauge."""
    if seconds <= 0.0:
        return
    time.sleep(seconds)
    _build_priority.slept_total = (
        getattr(_build_priority, "slept_total", 0.0) + seconds
    )


def build_slept_s() -> float:
    """This thread's cumulative :func:`build_sleep` time (monotone —
    callers diff two readings around a throttled region)."""
    return getattr(_build_priority, "slept_total", 0.0)


@dataclasses.dataclass
class OccluderGrid:
    """Packed grid index (host arrays; move to device as needed).

    ``base``:  ``[G*G]`` int32 fully-covering triangle counts.
    ``lists``: ``[G*G, L]`` int32 partial-overlap triangle ids, -1 padded.
    ``coeffs``: ``[M, 3, 3]`` float32 edge functions of all triangles.
    """

    base: np.ndarray
    lists: np.ndarray
    coeffs: np.ndarray
    G: int
    rect: Rect

    @property
    def max_list(self) -> int:
        return self.lists.shape[1]

    def occupancy(self) -> float:
        """Mean real entries per cell list (diagnostics / bench_breakdown)."""
        return float((self.lists >= 0).sum() / max(len(self.lists), 1))


def _tri_cell_classify_many(
    tris: np.ndarray, coeffs: np.ndarray, rect: Rect, G: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized cell classification for ALL triangles in one pass.

    Expands each triangle's clamped-AABB cell range into flat
    (triangle, cell) candidate pairs and runs the SAT + full-containment
    tests over every pair at once — this is the index build's hot loop,
    and the per-triangle Python iteration it replaces dominated the
    dynamic writer's CPU share (scene prewarm rebuilds indexes inline).

    Separating axes = 2 box axes + 3 edge normals (closed-set test); full
    containment = all 4 cell corners pass all 3 inclusive edge tests.
    Cells are EXPANDED by a float-rounding guard when classifying: a user
    whose f32 cell assignment lands one ulp across a boundary must still
    see correct counts, so "fully covers the cell" is certified on the
    slightly larger box (near-boundary triangles demote to the partial
    list, where they are tested exactly).

    Returns ``(tri_idx [P], cell [P], full [P] bool, partial [P] bool)``.
    """
    M = len(tris)
    w = rect.width / G
    h = rect.height / G
    eps = 1e-5 * max(w, h)
    lo = tris.min(axis=1)  # [M, 2]
    hi = tris.max(axis=1)
    ix0 = np.clip(np.floor((lo[:, 0] - eps - rect.xmin) / w), 0, G - 1).astype(np.int64)
    ix1 = np.clip(np.floor((hi[:, 0] + eps - rect.xmin) / w - 1e-12), 0, G - 1).astype(np.int64)
    iy0 = np.clip(np.floor((lo[:, 1] - eps - rect.ymin) / h), 0, G - 1).astype(np.int64)
    iy1 = np.clip(np.floor((hi[:, 1] + eps - rect.ymin) / h - 1e-12), 0, G - 1).astype(np.int64)
    outside = (
        (hi[:, 0] < rect.xmin) | (lo[:, 0] > rect.xmax)
        | (hi[:, 1] < rect.ymin) | (lo[:, 1] > rect.ymax)
    )
    ny = iy1 - iy0 + 1
    counts = np.where(outside, 0, (ix1 - ix0 + 1) * ny)  # pairs per triangle
    tri_idx = np.repeat(np.arange(M), counts)  # [P]
    starts = np.concatenate([[0], np.cumsum(counts)])[:-1]
    local = np.arange(int(counts.sum())) - np.repeat(starts, counts)
    ny_r = ny[tri_idx]
    gx = ix0[tri_idx] + local // ny_r
    gy = iy0[tri_idx] + local % ny_r

    # Each edge function e(x, y) = a*x + b*y + c is affine, so its extrema
    # over the expanded cell's corners are exactly
    #     e(center) -/+ (|a| * hw + |b| * hh)
    # (hw/hh = expanded half-extents): the full-containment test is
    # min >= 0 on every edge, the SAT edge test is max >= 0 on every edge.
    # This prices 3 evaluations per pair instead of 12 corner ones, and the
    # per-triangle spread term is hoisted out of the pair loop entirely.
    hw = w / 2 + eps
    hh = h / 2 + eps
    spread_t = np.abs(coeffs[:, :, 0]) * hw + np.abs(coeffs[:, :, 1]) * hh  # [M, 3]

    # Chunked evaluation: bisector-strip triangles have AABBs spanning
    # thousands of cells, so P can reach millions — one monolithic ufunc
    # over that holds the GIL for ~100ms, which is exactly the latency
    # spike an MVCC *reader* thread would see while the writer prewarms
    # scenes.  Small chunks keep every C-level op a few ms.
    P = len(tri_idx)
    full = np.empty(P, bool)
    partial = np.empty(P, bool)
    chunk = 1 << 18
    for s in range(0, max(P, 1), chunk):
        yield_ratio = build_yield_ratio()  # per chunk: ratio may be dynamic
        t_chunk = time.perf_counter() if yield_ratio else 0.0
        sl = slice(s, min(s + chunk, P))
        ti = tri_idx[sl]
        cx = rect.xmin + (gx[sl] + 0.5) * w  # cell centers  [C]
        cy = rect.ymin + (gy[sl] + 0.5) * h
        co = coeffs[ti]  # [C, 3, 3]
        e_c = co[:, :, 0] * cx[:, None] + co[:, :, 1] * cy[:, None] + co[:, :, 2]
        sp = spread_t[ti]
        f = np.all(e_c - sp >= 0.0, axis=-1)  # every corner inside every edge
        ov = np.all(e_c + sp >= 0.0, axis=-1)  # SAT: some corner not outside
        # box axes: triangle AABB vs expanded cell (already restricted to
        # the AABB range, but fringe cells may still miss on the exact AABB)
        ov &= (
            (cx + hw >= lo[ti, 0]) & (cx - hw <= hi[ti, 0])
            & (cy + hh >= lo[ti, 1]) & (cy - hh <= hi[ti, 1])
        )
        full[sl] = f
        # a cell whose every corner is inside but SAT failed cannot happen
        partial[sl] = ov & ~f
        if yield_ratio:
            build_sleep((time.perf_counter() - t_chunk) * yield_ratio)
    return tri_idx, gx * G + gy, full, partial


def _tri_cell_classify(
    tri: np.ndarray, coeff: np.ndarray, rect: Rect, G: int
) -> tuple[np.ndarray, np.ndarray]:
    """(full_cells, partial_cells) flat cell ids for one triangle — the
    single-triangle view of :func:`_tri_cell_classify_many` (the refit
    path classifies only the changed triangles)."""
    _, cell, full, partial = _tri_cell_classify_many(
        tri[None], coeff[None], rect, G
    )
    return cell[full], cell[partial]


def build_grid(
    tris: np.ndarray,
    coeffs: np.ndarray,
    rect: Rect,
    G: int = 64,
    pad_list_to: int | None = None,
) -> OccluderGrid:
    """Build the grid index over real (unpadded) triangles."""
    tris = np.asarray(tris, dtype=np.float64).reshape(-1, 3, 2)
    coeffs64 = np.asarray(coeffs, dtype=np.float64).reshape(-1, 3, 3)
    tri_idx, cell, full, partial = _tri_cell_classify_many(
        tris, coeffs64, rect, G
    )
    base = np.bincount(cell[full], minlength=G * G).astype(np.int32)
    # group the partial pairs by cell (triangle ids ascending within each
    # cell, matching the order a per-triangle append loop would produce)
    pc, pt = cell[partial], tri_idx[partial]
    order = np.lexsort((pt, pc))
    pc, pt = pc[order], pt[order]
    cnts = np.bincount(pc, minlength=G * G)
    L = max(int(cnts.max()) if len(pc) else 0, 1)
    if pad_list_to is not None:
        L = max(L, pad_list_to)
    lists = np.full((G * G, L), -1, np.int32)
    if len(pc):
        starts = np.concatenate([[0], np.cumsum(cnts)])[:-1]
        rank = np.arange(len(pc)) - starts[pc]
        lists[pc, rank] = pt.astype(np.int32)
    return OccluderGrid(
        base=base,
        lists=lists,
        coeffs=np.asarray(coeffs, dtype=np.float32),
        G=G,
        rect=rect,
    )


def refit_grid(
    grid: OccluderGrid,
    tris_old: np.ndarray,
    coeffs_old: np.ndarray,
    tris_new: np.ndarray,
    coeffs_new: np.ndarray,
    changed: np.ndarray,
) -> OccluderGrid | None:
    """Refit a grid index for a perturbed triangle set without a full rebuild.

    ``changed`` lists triangle ids whose geometry differs between the old
    arrays (the ones ``grid`` was built from) and the new ones; all other
    triangles must be identical.  Each changed triangle's old cell
    classification is subtracted and its new one added — O(|changed|)
    classification work instead of O(M).  Counts are exact regardless of
    list order (``hits = base + #inside-of-listed``), so a refit grid is
    count-identical to a fresh :func:`build_grid`.

    Returns a new :class:`OccluderGrid` (the input is never mutated — cached
    scenes may still alias it), or ``None`` when the refit cannot be done in
    place (triangle count changed, or a cell's candidate list would overflow
    the padded width) — the caller falls back to :func:`build_grid`.
    """
    if len(tris_old) != len(tris_new):
        return None
    changed = np.asarray(changed, dtype=np.int64)
    base = grid.base.copy()
    lists = grid.lists.copy()
    coeffs = grid.coeffs.copy()
    G, rect = grid.G, grid.rect
    tris_old = np.asarray(tris_old, np.float64)
    tris_new = np.asarray(tris_new, np.float64)
    co_old = np.asarray(coeffs_old, np.float64)
    co_new = np.asarray(coeffs_new, np.float64)
    for t in changed:
        t = int(t)
        full_o, part_o = _tri_cell_classify(tris_old[t], co_old[t], rect, G)
        full_n, part_n = _tri_cell_classify(tris_new[t], co_new[t], rect, G)
        base[full_o] -= 1
        base[full_n] += 1
        for c in part_o:
            row = lists[int(c)]
            row[row == t] = -1
        for c in part_n:
            row = lists[int(c)]
            slots = np.flatnonzero(row < 0)
            if not len(slots):
                return None  # padded width exhausted: rebuild
            row[slots[0]] = t
        coeffs[t] = co_new[t].astype(np.float32)
    return OccluderGrid(base=base, lists=lists, coeffs=coeffs, G=G, rect=rect)


def shape_bucket(x: int, floor: int = 8) -> int:
    """Round ``x`` up to a quarter-octave shape bucket (>= ``floor``).

    Padded axes quantized through this stay stable under the small size
    drift dynamic updates produce, so the jitted batch dispatches reuse
    their compiled executables instead of recompiling every time a scene
    gains or loses a few triangles.  Overshoot is bounded by ~25% and the
    padding is semantically free (padded slots contribute nothing).
    """
    x = max(int(x), 1)
    if x <= floor:
        return floor
    step = 1 << max((x - 1).bit_length() - 3, 0)
    return -(-x // step) * step


def stack_grids(grids: list[OccluderGrid]) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stack per-query grid indices to common static shapes for one batched
    dispatch.

    All grids must share ``G`` and ``rect`` (the serving setup: one domain,
    many query scenes).  Candidate lists are right-padded with ``-1`` to the
    max list length; triangle coefficient tables are padded with degenerate
    never-inside rows so gathers on padded ids contribute nothing.  Both
    padded axes are :func:`shape_bucket`-quantized for executable reuse
    across update-churned batches.  Returns
    ``(base [Q, G*G] i32, lists [Q, G*G, L] i32, coeffs [Q, Mt, 3, 3] f32)``.
    """
    if not grids:
        raise ValueError("stack_grids needs at least one grid")
    G = grids[0].G
    if any(g.G != G for g in grids):
        raise ValueError("all grids in a batch must share G")
    rect = grids[0].rect
    if any(g.rect != rect for g in grids):
        raise ValueError("all grids in a batch must share the domain rect")
    L = shape_bucket(max(g.lists.shape[1] for g in grids), floor=1)
    Mt = shape_bucket(max(max(len(g.coeffs), 1) for g in grids), floor=1)
    Q = len(grids)
    base = np.stack([g.base for g in grids]).astype(np.int32)
    lists = np.full((Q, G * G, L), -1, np.int32)
    coeffs = np.zeros((Q, Mt, 3, 3), np.float32)
    coeffs[:, :, :, 2] = -1.0  # degenerate default (never inside)
    for i, g in enumerate(grids):
        lists[i, :, : g.lists.shape[1]] = g.lists
        if len(g.coeffs):
            coeffs[i, : len(g.coeffs)] = g.coeffs
    return base, lists, coeffs


@functools.partial(jax.jit, static_argnums=(5, 6))
def grid_hit_counts_batch_jnp(xs, ys, base, lists, coeffs, rect: Rect, G: int):
    """Batched multi-query grid counting: ``[Q, N]`` counts in one dispatch.

    ``base``: ``[Q, G*G]``; ``lists``: ``[Q, G*G, L]``; ``coeffs``:
    ``[Q, Mt, 3, 3]`` (from :func:`stack_grids`).  The user→cell assignment
    is shared across queries (one domain rect), so it is computed once and
    the per-query work is a pure gather + edge-function evaluation.

    Jitted (``rect``/``G`` static) like every other grid-family execution:
    all of them must round the ``a·x + b·y + c`` edge evaluation the same
    way (XLA fuses it into FMAs), so a knife-edge ``>= 0`` tie cannot
    decide differently between the oracle and the bucketed kernels.
    """
    xs = jnp.asarray(xs)
    ys = jnp.asarray(ys)
    base = jnp.asarray(base)
    lists = jnp.asarray(lists)
    coeffs = jnp.asarray(coeffs)
    if coeffs.shape[1] == 0:  # occluder-free scenes: keep the gather legal
        coeffs = jnp.broadcast_to(
            jnp.asarray([0.0, 0.0, -1.0], coeffs.dtype),  # degenerate edge
            (coeffs.shape[0], 1, 3, 3),
        )
    w = rect.width / G
    h = rect.height / G
    cx = jnp.clip(jnp.floor((xs - rect.xmin) / w), 0, G - 1).astype(jnp.int32)
    cy = jnp.clip(jnp.floor((ys - rect.ymin) / h), 0, G - 1).astype(jnp.int32)
    cell = cx * G + cy  # [N] shared across queries

    def one(base_q, lists_q, coeffs_q):
        cand = lists_q[cell]  # [N, L]
        safe = jnp.maximum(cand, 0)
        e = coeffs_q[safe]  # [N, L, 3, 3]
        ev = e[..., 0] * xs[:, None, None] + e[..., 1] * ys[:, None, None] + e[..., 2]
        inside = jnp.all(ev >= 0.0, axis=-1) & (cand >= 0)
        return base_q[cell] + inside.sum(axis=-1).astype(jnp.int32)

    return jax.vmap(one)(base, lists, coeffs)


@functools.partial(jax.jit, static_argnums=(5, 6))
def grid_hit_counts_jnp(xs, ys, base, lists, coeffs, rect: Rect, G: int):
    """Vectorized grid query (pure jnp; Pallas variant in kernels/).

    ``hits[u] = base[cell(u)] + sum_t in list[cell(u)] inside(u, t)``.
    Jitted with ``rect``/``G`` static — see the batched variant for why.
    """
    xs = jnp.asarray(xs)
    ys = jnp.asarray(ys)
    coeffs = jnp.asarray(coeffs)
    if coeffs.shape[0] == 0:  # occluder-free scenes: keep the gather legal
        coeffs = jnp.broadcast_to(
            jnp.asarray([0.0, 0.0, -1.0], coeffs.dtype), (1, 3, 3)
        )
    w = rect.width / G
    h = rect.height / G
    cx = jnp.clip(jnp.floor((xs - rect.xmin) / w), 0, G - 1).astype(jnp.int32)
    cy = jnp.clip(jnp.floor((ys - rect.ymin) / h), 0, G - 1).astype(jnp.int32)
    cell = cx * G + cy
    cand = jnp.asarray(lists)[cell]  # [N, L]
    safe = jnp.maximum(cand, 0)
    e = coeffs[safe]  # [N, L, 3, 3]
    ev = e[..., 0] * xs[:, None, None] + e[..., 1] * ys[:, None, None] + e[..., 2]
    inside = jnp.all(ev >= 0.0, axis=-1) & (cand >= 0)
    return jnp.asarray(base)[cell] + inside.sum(axis=-1).astype(jnp.int32)


# compile accounting (see repro.obs.jitmon): the grid jnp entries retrace
# per (rect, G) static combo — expected on updates that move the hull, a
# regression when a transient rect leaks into the hot path.  Wrapped at
# module bottom so every importer sees the counted version.
from repro.obs.jitmon import track_jit as _track_jit  # noqa: E402

grid_hit_counts_jnp = _track_jit(grid_hit_counts_jnp, "grid_jnp")
grid_hit_counts_batch_jnp = _track_jit(
    grid_hit_counts_batch_jnp, "grid_jnp_batch"
)
