"""Paper-faithful BVH path: LBVH build + any-hit traversal with early exit.

This is the *reference execution model* of Algorithm 1/2 — exactly what the
OptiX implementation does, minus the fixed-function hardware:

* an LBVH is built over the occluder triangles (Morton-ordered median
  splits; one primitive per leaf, as in paper Fig. 5),
* every user is a vertical ray; since the ray direction is ``(0,0,-1)`` the
  ray–AABB slab test degenerates to 2-D point-in-rectangle and the
  ray–triangle test to 2-D point-in-triangle (DESIGN.md §2),
* traversal keeps an explicit stack and terminates the ray as soon as the
  hit count reaches ``k`` (``optixTerminateRay`` in Alg. 2 line 16).

On a TPU this shape of computation (per-lane data-dependent control flow,
incoherent gathers) is exactly what the hardware punishes: under ``vmap`` the
``while_loop`` runs to the *longest* lane in a batch and every node fetch is
a gather.  We keep it as the faithful baseline that the dense/grid Pallas
kernels are measured against (EXPERIMENTS.md §Perf-RkNN).
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "BVH",
    "build_bvh",
    "refit_bvh",
    "bvh_hit_counts",
    "stack_bvhs",
    "bvh_hit_counts_batch",
    "MAX_STACK",
]

MAX_STACK = 64  # ample for median-split trees (depth == ceil(log2 M))


@dataclasses.dataclass
class BVH:
    """Array-encoded binary BVH.

    ``left``/``right``: child node ids; for leaves ``left = -(tri_idx + 1)``
    and ``right = -1``.  ``bbox``: ``[n_nodes, 4]`` as (xmin, ymin, xmax,
    ymax).  ``n_tris`` real triangles; root is node 0.
    """

    left: np.ndarray
    right: np.ndarray
    bbox: np.ndarray
    n_tris: int

    @property
    def n_nodes(self) -> int:
        return len(self.left)

    def depth(self) -> int:
        """Max depth (host-side sanity; traversal stack must exceed it)."""
        d = {0: 1}
        best = 1
        stack = [0]
        while stack:
            n = stack.pop()
            for ch in (self.left[n], self.right[n]):
                if ch >= 0:
                    d[ch] = d[n] + 1
                    best = max(best, d[ch])
                    stack.append(int(ch))
        return best


def _morton2d(xs: np.ndarray, ys: np.ndarray, bits: int = 16) -> np.ndarray:
    """Interleave quantized x/y into 2*bits Morton codes."""

    def _part(v: np.ndarray) -> np.ndarray:
        v = v.astype(np.uint64)
        v = (v | (v << 16)) & np.uint64(0x0000FFFF0000FFFF)
        v = (v | (v << 8)) & np.uint64(0x00FF00FF00FF00FF)
        v = (v | (v << 4)) & np.uint64(0x0F0F0F0F0F0F0F0F)
        v = (v | (v << 2)) & np.uint64(0x3333333333333333)
        v = (v | (v << 1)) & np.uint64(0x5555555555555555)
        return v

    q = (1 << bits) - 1
    xi = np.clip((xs * q).astype(np.int64), 0, q)
    yi = np.clip((ys * q).astype(np.int64), 0, q)
    return _part(xi) | (_part(yi) << np.uint64(1))


def build_bvh(tris: np.ndarray) -> BVH:
    """LBVH over ``[M, 3, 2]`` triangles (host, numpy).

    Morton-sorts centroids then median-splits the sorted range — the
    standard linear-BVH construction (paper refs [53–55]) which yields
    spatially coherent subtrees without a full SAH sweep.
    """
    tris = np.asarray(tris, dtype=np.float64)
    M = len(tris)
    if M == 0:
        return BVH(
            left=np.array([-1], np.int32),
            right=np.array([-1], np.int32),
            bbox=np.zeros((1, 4), np.float32),
            n_tris=0,
        )
    lo = tris.min(axis=1)  # [M, 2]
    hi = tris.max(axis=1)
    cent = (lo + hi) / 2.0
    cmin = cent.min(axis=0)
    cspan = np.maximum(cent.max(axis=0) - cmin, 1e-12)
    norm = (cent - cmin) / cspan
    order = np.argsort(_morton2d(norm[:, 0], norm[:, 1]), kind="stable")

    n_nodes = 2 * M - 1
    left = np.full(n_nodes, -1, np.int32)
    right = np.full(n_nodes, -1, np.int32)
    bbox = np.zeros((n_nodes, 4), np.float64)

    next_id = [0]

    def alloc() -> int:
        i = next_id[0]
        next_id[0] += 1
        return i

    # iterative build: stack of (node_id, lo, hi) ranges over `order`
    root = alloc()
    stack: list[tuple[int, int, int]] = [(root, 0, M)]
    while stack:
        node, s, e = stack.pop()
        idx = order[s:e]
        bbox[node, :2] = lo[idx].min(axis=0)
        bbox[node, 2:] = hi[idx].max(axis=0)
        if e - s == 1:
            left[node] = -(int(idx[0]) + 1)
            right[node] = -1
            continue
        mid = (s + e) // 2
        l_id, r_id = alloc(), alloc()
        left[node] = l_id
        right[node] = r_id
        stack.append((l_id, s, mid))
        stack.append((r_id, mid, e))

    return BVH(left=left, right=right, bbox=bbox.astype(np.float32), n_tris=M)


def refit_bvh(
    bvh: BVH, tris_new: np.ndarray, *, max_growth: float = 1.5
) -> BVH | None:
    """Refit node AABBs to perturbed triangles, keeping the topology.

    The graphics-pipeline *refit* operation: when primitives move slightly,
    the tree structure is reused and only the boxes are recomputed
    bottom-up (children are always allocated after their parent, so
    descending node ids are a valid child-before-parent order).  Traversal
    counts are unaffected by stale topology — boxes stay conservative, so
    a refit BVH is count-identical to a fresh Morton-ordered rebuild.

    Quality gate: Morton order goes stale as primitives drift, inflating
    the boxes and the traversal cost.  When the total internal-node box
    area grows past ``max_growth``× the pre-refit area, ``None`` is
    returned and the caller should rebuild.  Also returns ``None`` when
    the triangle count changed (topology no longer matches).
    """
    tris_new = np.asarray(tris_new, dtype=np.float64)
    if bvh.n_tris != len(tris_new):
        return None
    if bvh.n_tris == 0:
        return BVH(bvh.left.copy(), bvh.right.copy(), bvh.bbox.copy(), 0)
    lo = tris_new.min(axis=1)  # [M, 2]
    hi = tris_new.max(axis=1)
    n = bvh.n_nodes
    bbox = np.zeros((n, 4), np.float64)
    left, right = bvh.left, bvh.right
    for node in range(n - 1, -1, -1):
        l = int(left[node])
        if l < 0:  # leaf: one primitive
            tri = -l - 1
            bbox[node, :2] = lo[tri]
            bbox[node, 2:] = hi[tri]
        else:
            r = int(right[node])
            bbox[node, :2] = np.minimum(bbox[l, :2], bbox[r, :2])
            bbox[node, 2:] = np.maximum(bbox[l, 2:], bbox[r, 2:])
    internal = left >= 0
    if internal.any():
        old = bvh.bbox.astype(np.float64)
        area_old = float(
            ((old[internal, 2] - old[internal, 0])
             * (old[internal, 3] - old[internal, 1])).sum()
        )
        area_new = float(
            ((bbox[internal, 2] - bbox[internal, 0])
             * (bbox[internal, 3] - bbox[internal, 1])).sum()
        )
        if area_new > max_growth * area_old + 1e-12:
            return None
    return BVH(left.copy(), right.copy(), bbox.astype(np.float32), bvh.n_tris)


def bvh_hit_counts(
    xs,
    ys,
    left,
    right,
    bbox,
    coeffs,
    k: int | None = None,
    max_stack: int = MAX_STACK,
):
    """Per-user occluder hit counts via stack traversal (jit/vmap-able).

    ``xs, ys``: ``[N]`` user coordinates. ``coeffs``: ``[M, 3, 3]`` edge
    functions.  ``k``: early-termination threshold (``None`` counts all
    hits).  Returns ``[N]`` int32 counts saturated at ``k`` when early
    termination is active — exactly the information Alg. 2 extracts.
    """
    left = jnp.asarray(left)
    right = jnp.asarray(right)
    bbox = jnp.asarray(bbox)
    coeffs = jnp.asarray(coeffs)
    k_cap = int(k) if k is not None else int(coeffs.shape[0]) + 1

    def one(x, y):
        stack0 = jnp.zeros((max_stack,), jnp.int32)

        def cond(state):
            _, sp, cnt = state
            return (sp > 0) & (cnt < k_cap)

        def body(state):
            stack, sp, cnt = state
            node = stack[sp - 1]
            sp = sp - 1
            l = left[node]
            r = right[node]
            is_leaf = l < 0
            # --- leaf: point-in-triangle (any-hit program) ---------------
            tri = jnp.maximum(-l - 1, 0)
            e = coeffs[tri]  # [3, 3]
            ev = e[:, 0] * x + e[:, 1] * y + e[:, 2]
            inside = jnp.all(ev >= 0.0)
            cnt = cnt + jnp.where(is_leaf & inside, 1, 0).astype(jnp.int32)
            # --- internal: ray-AABB (vertical ray => 2-D point-in-box) --
            li = jnp.maximum(l, 0)
            ri = jnp.maximum(r, 0)

            def in_box(b):
                return (x >= b[0]) & (y >= b[1]) & (x <= b[2]) & (y <= b[3])

            push_l = (~is_leaf) & in_box(bbox[li])
            push_r = (~is_leaf) & (r >= 0) & in_box(bbox[ri])
            stack = stack.at[sp].set(li)
            sp = sp + push_l.astype(jnp.int32)
            stack = stack.at[sp].set(ri)
            sp = sp + push_r.astype(jnp.int32)
            return stack, sp, cnt

        has_tris = coeffs.shape[0] > 0
        init_sp = jnp.int32(1 if has_tris else 0)
        _, _, cnt = lax.while_loop(cond, body, (stack0, init_sp, jnp.int32(0)))
        return cnt

    return jax.vmap(one)(jnp.asarray(xs), jnp.asarray(ys))


def stack_bvhs(
    bvhs: list[BVH], coeffs_list: list[np.ndarray]
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Stack per-query BVHs + triangle coefficients to static batch shapes.

    Node arrays are right-padded to the max node count (padding nodes are
    unreachable from the root, so their contents never matter); coefficient
    tables are padded with degenerate never-inside rows.  Returns
    ``(left [Q, Nn], right [Q, Nn], bbox [Q, Nn, 4], coeffs [Q, Mt, 3, 3])``.
    """
    if not bvhs:
        raise ValueError("stack_bvhs needs at least one BVH")
    Q = len(bvhs)
    Nn = max(b.n_nodes for b in bvhs)
    Mt = max(max(len(c), 1) for c in coeffs_list)
    left = np.full((Q, Nn), -1, np.int32)
    right = np.full((Q, Nn), -1, np.int32)
    bbox = np.zeros((Q, Nn, 4), np.float32)
    coeffs = np.zeros((Q, Mt, 3, 3), np.float32)
    coeffs[:, :, :, 2] = -1.0  # degenerate default (never inside)
    for i, (b, cf) in enumerate(zip(bvhs, coeffs_list)):
        left[i, : b.n_nodes] = b.left
        right[i, : b.n_nodes] = b.right
        bbox[i, : b.n_nodes] = b.bbox
        if len(cf):
            coeffs[i, : len(cf)] = np.asarray(cf, np.float32)
    return left, right, bbox, coeffs


def bvh_hit_counts_batch(
    xs,
    ys,
    left,
    right,
    bbox,
    coeffs,
    k: int | None = None,
    max_stack: int = MAX_STACK,
):
    """Batched multi-query traversal: ``[Q, N]`` counts in one dispatch.

    ``left/right``: ``[Q, Nn]``; ``bbox``: ``[Q, Nn, 4]``; ``coeffs``:
    ``[Q, Mt, 3, 3]`` (from :func:`stack_bvhs`); users are shared across
    queries.  Early termination at ``k`` applies per (query, user) lane.

    An empty scene's BVH (what :func:`build_bvh` emits for ``M == 0``) is a
    single leaf root referencing triangle 0, which :func:`stack_bvhs` pads
    with a degenerate never-inside coefficient row — so it counts zero hits.
    """
    xs = jnp.asarray(xs)
    ys = jnp.asarray(ys)

    def one(l, r, bb, cf):
        return bvh_hit_counts(xs, ys, l, r, bb, cf, k=k, max_stack=max_stack)

    return jax.vmap(one)(
        jnp.asarray(left), jnp.asarray(right), jnp.asarray(bbox), jnp.asarray(coeffs)
    )
