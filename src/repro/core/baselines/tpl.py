"""TPL baseline [Tao, Papadias, Lian, VLDB'04] — half-space pruning.

Filtering (paper Fig. 1b): facilities are visited in increasing distance
from ``q`` via the R-tree's incremental nearest iterator.  Every *unpruned*
visited facility ``a`` contributes the half-plane ``H_{a:q}`` (its
bisector's invalid side); a facility or user lying in ``>= k`` contributed
half-planes is pruned.  Facilities that are themselves pruned contribute no
bisector (facility ``d`` in the paper's figure).  Refinement: surviving
candidate users are verified exactly (strictly-closer count ``< k``).

Fidelity note: full TPL also trims R-tree MBRs against the half-planes to
prune whole subtrees during the traversal; the pruning *logic* (which is
what defines TPL and what the paper's comparison exercises) is the
half-space membership count implemented here, with the R-tree supplying the
distance-ordered access pattern.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.baselines.rtree import STRTree
from repro.core.geometry import bisector

__all__ = ["tpl_rknn"]


def tpl_rknn(
    facilities: np.ndarray,
    users: np.ndarray,
    q_idx: int,
    k: int,
    tree: STRTree | None = None,
) -> tuple[np.ndarray, dict]:
    facilities = np.asarray(facilities, dtype=np.float64)
    users = np.asarray(users, dtype=np.float64)
    q = facilities[q_idx]
    if tree is None:
        tree = STRTree(facilities)

    t0 = time.perf_counter()
    # ---- filtering: distance-ordered half-space accumulation -------------
    normals: list[np.ndarray] = []
    offsets: list[float] = []
    contributors: list[int] = []
    for _, fi in tree.nearest_iter(q):
        if fi == q_idx:
            continue
        f = facilities[fi]
        if normals:
            N = np.asarray(normals)
            C = np.asarray(offsets)
            depth = int(np.sum(f @ N.T < C))
            if depth >= k:
                continue  # facility itself pruned -> no bisector (paper: d)
        n, c = bisector(f, q)
        normals.append(n)
        offsets.append(float(c))
        contributors.append(int(fi))
    N = np.asarray(normals) if normals else np.zeros((0, 2))
    C = np.asarray(offsets) if offsets else np.zeros((0,))

    if len(N):
        depth_u = (users @ N.T < C[None, :]).sum(axis=1)
    else:
        depth_u = np.zeros(len(users), dtype=int)
    candidates = depth_u < k
    t1 = time.perf_counter()

    # ---- refinement: exact verification of candidates --------------------
    mask = np.zeros(len(users), dtype=bool)
    cand_idx = np.flatnonzero(candidates)
    if len(cand_idx):
        cu = users[cand_idx]
        d2q = np.sum((cu - q) ** 2, axis=1)
        # exact strict-closer count against all facilities (vectorized)
        d2 = (
            np.sum(cu**2, axis=1)[:, None]
            - 2.0 * cu @ facilities.T
            + np.sum(facilities**2, axis=1)[None, :]
        )
        d2[:, q_idx] = np.inf
        mask[cand_idx] = np.sum(d2 < d2q[:, None], axis=1) < k
    t2 = time.perf_counter()
    info = dict(
        t_filter_s=t1 - t0,
        t_verify_s=t2 - t1,
        n_candidates=int(candidates.sum()),
        n_bisectors=len(N),
    )
    return mask, info
