"""From-scratch reimplementations of the paper's four baselines (§2.2).

All share the STR R-tree substrate (``rtree.py``) the way the paper's
baselines share R*-trees, and all are cross-validated against the exact
brute-force oracle in ``tests/test_baselines.py``.
"""

from repro.core.baselines.infzone import infzone_rknn
from repro.core.baselines.rtree import STRTree
from repro.core.baselines.six import six_rknn
from repro.core.baselines.slice import slice_rknn
from repro.core.baselines.tpl import tpl_rknn

__all__ = ["STRTree", "six_rknn", "tpl_rknn", "infzone_rknn", "slice_rknn"]
