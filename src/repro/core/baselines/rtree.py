"""STR-packed R*-tree stand-in shared by all CPU baselines (paper §4.1).

The paper gives every baseline an R*-tree over facilities (and users); we
bulk-load with Sort-Tile-Recursive packing, which matches or beats R*-tree
query quality for static point sets and is the standard choice for
preprocessing-free experiments.  Provides the three operations the
baselines need:

* ``nearest_iter(p)`` — incremental best-first nearest-facility iteration,
* ``knn(p, k)`` — k nearest entries,
* ``count_within(p, r)`` / ``count_within_strict`` — circle range counts,

plus ``build_time`` so Table 2 (amortized indexing cost) can be reproduced.
"""

from __future__ import annotations

import heapq
import time

import numpy as np

__all__ = ["STRTree"]


class STRTree:
    """STR bulk-loaded R-tree over ``[N, 2]`` points."""

    def __init__(self, points: np.ndarray, leaf_capacity: int = 32, fanout: int = 16):
        t0 = time.perf_counter()
        self.points = np.asarray(points, dtype=np.float64)
        n = len(self.points)
        self.leaf_capacity = leaf_capacity
        # ---- leaf level: STR packing ------------------------------------
        idx = np.arange(n)
        if n == 0:
            self.levels: list[dict] = []
            self.build_time = 0.0
            return
        n_leaves = max(1, int(np.ceil(n / leaf_capacity)))
        n_strips = max(1, int(np.ceil(np.sqrt(n_leaves))))
        per_strip = int(np.ceil(n / n_strips))
        order_x = idx[np.argsort(self.points[:, 0], kind="stable")]
        leaves: list[np.ndarray] = []
        for s in range(0, n, per_strip):
            strip = order_x[s : s + per_strip]
            strip = strip[np.argsort(self.points[strip, 1], kind="stable")]
            for l in range(0, len(strip), leaf_capacity):
                leaves.append(strip[l : l + leaf_capacity])
        # ---- internal levels --------------------------------------------
        # each level: dict(bbox [M,4], children: list of index arrays into
        # the level below, leaf: bool)
        def bbox_of(ids_points: np.ndarray) -> np.ndarray:
            return np.concatenate(
                [ids_points.min(axis=0), ids_points.max(axis=0)]
            )

        leaf_bbox = np.stack([bbox_of(self.points[l]) for l in leaves])
        self.levels = [dict(bbox=leaf_bbox, children=leaves, leaf=True)]
        cur_bbox = leaf_bbox
        while len(cur_bbox) > 1:
            m = len(cur_bbox)
            cent = (cur_bbox[:, :2] + cur_bbox[:, 2:]) / 2.0
            n_nodes = max(1, int(np.ceil(m / fanout)))
            n_strips = max(1, int(np.ceil(np.sqrt(n_nodes))))
            per_strip = int(np.ceil(m / n_strips))
            order_x = np.argsort(cent[:, 0], kind="stable")
            groups: list[np.ndarray] = []
            for s in range(0, m, per_strip):
                strip = order_x[s : s + per_strip]
                strip = strip[np.argsort(cent[strip, 1], kind="stable")]
                for l in range(0, len(strip), fanout):
                    groups.append(strip[l : l + fanout])
            up_bbox = np.stack(
                [
                    np.concatenate(
                        [cur_bbox[g, :2].min(axis=0), cur_bbox[g, 2:].max(axis=0)]
                    )
                    for g in groups
                ]
            )
            self.levels.append(dict(bbox=up_bbox, children=groups, leaf=False))
            cur_bbox = up_bbox
        self.build_time = time.perf_counter() - t0

    # ---- distance helpers ------------------------------------------------
    @staticmethod
    def _mindist2(p: np.ndarray, bbox: np.ndarray) -> np.ndarray:
        dx = np.maximum(np.maximum(bbox[..., 0] - p[0], p[0] - bbox[..., 2]), 0.0)
        dy = np.maximum(np.maximum(bbox[..., 1] - p[1], p[1] - bbox[..., 3]), 0.0)
        return dx * dx + dy * dy

    @staticmethod
    def _maxdist2(p: np.ndarray, bbox: np.ndarray) -> np.ndarray:
        dx = np.maximum(np.abs(p[0] - bbox[..., 0]), np.abs(p[0] - bbox[..., 2]))
        dy = np.maximum(np.abs(p[1] - bbox[..., 1]), np.abs(p[1] - bbox[..., 3]))
        return dx * dx + dy * dy

    # ---- queries -----------------------------------------------------------
    def nearest_iter(self, p: np.ndarray):
        """Yield ``(dist, point_index)`` in nondecreasing distance order."""
        if not self.levels:
            return
        p = np.asarray(p, dtype=np.float64)
        top = len(self.levels) - 1
        heap: list[tuple[float, int, int, int]] = []
        # entries: (mindist2, kind, level, node) kind 0 = node, 1 = point
        root_d = float(self._mindist2(p, self.levels[top]["bbox"][0]))
        heapq.heappush(heap, (root_d, 0, top, 0))
        counter = 0
        while heap:
            d, kind, level, node = heapq.heappop(heap)
            if kind == 1:
                yield np.sqrt(d), node
                continue
            lvl = self.levels[level]
            children = lvl["children"][node]
            if lvl["leaf"]:
                pts = self.points[children]
                d2 = np.sum((pts - p) ** 2, axis=1)
                for dd, ci in zip(d2, children):
                    counter += 1
                    heapq.heappush(heap, (float(dd), 1, -counter, int(ci)))
            else:
                below = self.levels[level - 1]["bbox"][children]
                d2 = self._mindist2(p, below)
                for dd, ci in zip(d2, children):
                    counter += 1
                    heapq.heappush(heap, (float(dd), 0, level - 1, int(ci)))

    def knn(self, p: np.ndarray, k: int, exclude: int | None = None):
        out: list[tuple[float, int]] = []
        for d, i in self.nearest_iter(p):
            if exclude is not None and i == exclude:
                continue
            out.append((d, i))
            if len(out) == k:
                break
        return out

    def count_within_strict(self, p: np.ndarray, r: float, exclude: int | None = None) -> int:
        """#points with ``dist(point, p) < r`` (strict), exact."""
        if not self.levels:
            return 0
        p = np.asarray(p, dtype=np.float64)
        r2 = r * r
        top = len(self.levels) - 1
        stack = [(top, 0)]
        count = 0
        while stack:
            level, node = stack.pop()
            lvl = self.levels[level]
            children = lvl["children"][node]
            if lvl["leaf"]:
                pts = self.points[children]
                d2 = np.sum((pts - p) ** 2, axis=1)
                inside = d2 < r2
                if exclude is not None:
                    inside &= children != exclude
                count += int(inside.sum())
            else:
                below = self.levels[level - 1]["bbox"][children]
                mind = self._mindist2(p, below)
                maxd = self._maxdist2(p, below)
                for j, ci in enumerate(children):
                    if mind[j] >= r2:
                        continue
                    if maxd[j] < r2 and exclude is None:
                        count += self._subtree_size(level - 1, int(ci))
                    else:
                        stack.append((level - 1, int(ci)))
        return count

    def _subtree_size(self, level: int, node: int) -> int:
        lvl = self.levels[level]
        children = lvl["children"][node]
        if lvl["leaf"]:
            return len(children)
        return sum(self._subtree_size(level - 1, int(c)) for c in children)
