"""SIX baseline [Stanoi et al., 2000] — six 60° regions-based pruning.

Filtering (paper Fig. 1a): the plane around ``q`` is divided into six 60°
sectors.  In each sector, the distance from ``q`` to its k-th nearest
facility *in that sector* is a pruning threshold: any user in the sector
strictly farther than the threshold has ``k`` same-sector facilities that
are provably at least as close to it as ``q`` (the 60°-sector lemma), so it
cannot be an RkNN.  Verification: a circular range count around each
surviving candidate (strictly-closer facilities < k), executed on the
shared facility R-tree — the per-candidate range query whose cost the
paper calls out as SIX's bottleneck.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.baselines.rtree import STRTree

__all__ = ["six_rknn"]


def six_rknn(
    facilities: np.ndarray,
    users: np.ndarray,
    q_idx: int,
    k: int,
    tree: STRTree | None = None,
) -> tuple[np.ndarray, dict]:
    """Returns ``(mask [N] bool, info)`` with phase timings and candidates."""
    facilities = np.asarray(facilities, dtype=np.float64)
    users = np.asarray(users, dtype=np.float64)
    q = facilities[q_idx]
    if tree is None:
        tree = STRTree(facilities)

    t0 = time.perf_counter()
    # ---- filtering -------------------------------------------------------
    fvec = facilities - q
    fdist = np.linalg.norm(fvec, axis=1)
    fang = np.arctan2(fvec[:, 1], fvec[:, 0])  # [-pi, pi)
    fsector = np.floor((fang + np.pi) / (np.pi / 3.0)).astype(int) % 6
    thresholds = np.full(6, np.inf)
    for s in range(6):
        m = (fsector == s) & (np.arange(len(facilities)) != q_idx)
        ds = np.sort(fdist[m])
        if len(ds) >= k:
            thresholds[s] = ds[k - 1]

    uvec = users - q
    udist = np.linalg.norm(uvec, axis=1)
    uang = np.arctan2(uvec[:, 1], uvec[:, 0])
    usector = np.floor((uang + np.pi) / (np.pi / 3.0)).astype(int) % 6
    candidates = udist <= thresholds[usector]
    t1 = time.perf_counter()

    # ---- verification (range query per candidate) ------------------------
    mask = np.zeros(len(users), dtype=bool)
    for u in np.flatnonzero(candidates):
        r = udist[u]
        # strictly-closer competitors (excluding q itself)
        c = tree.count_within_strict(users[u], float(np.linalg.norm(users[u] - q)), exclude=q_idx)
        mask[u] = c < k
        del r
    t2 = time.perf_counter()
    info = dict(
        t_filter_s=t1 - t0,
        t_verify_s=t2 - t1,
        n_candidates=int(candidates.sum()),
        thresholds=thresholds,
    )
    return mask, info
