"""SLICE baseline [Yang et al., ICDE'14] — 12-sector arc-based pruning.

Filtering (paper Fig. 1d): the plane around ``q`` is cut into 12 equal
sectors (the count SLICE determined to be optimal).  For each sector ``P``
and facility ``f``, the bisector ``B_{f:q}`` induces along every ray from
``q`` at angle ``θ`` a crossing distance ``t(θ) = (c − q·n) / (d̂(θ)·n)``
beyond which points are on ``f``'s invalid side (``∞`` when the ray never
crosses into it).  Over the sector:

* **upper arc** ``r^u = max_θ t(θ)`` — every sector point beyond ``r^u``
  is pruned by ``f``; the max is attained at a boundary ray (the paper's
  "intersection points with the two radial boundaries");
* **lower arc** ``r^l = min_θ t(θ)`` — no sector point below ``r^l`` is
  pruned by ``f``; the min is at the bisector-normal angle when that angle
  falls inside the sector, else at a boundary ray.

Per sector the k-th smallest upper arc is the *bounding arc* ``r^B``: users
beyond it are pruned by ≥ k facilities.  Verification walks each sector's
*significant list* (facilities with ``r^l < r^B``) — here as a vectorized
strict-closer count over exactly those facilities, which is exact because a
facility with ``r^l ≥ r^B`` cannot prune any candidate (all candidates sit
below its lower arc).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.geometry import bisector

__all__ = ["slice_rknn", "N_SECTORS"]

N_SECTORS = 12


def _arc_radii(facilities: np.ndarray, q: np.ndarray, q_idx: int) -> tuple[np.ndarray, np.ndarray]:
    """Upper/lower arc radii per (sector, facility): two ``[12, M]`` arrays."""
    M = len(facilities)
    n, c = bisector(facilities, q)  # invalid side: p.n < c
    # ray from q at angle θ crosses into invalid side at t = (c - q.n)/(d̂.n)
    # (q is always on the valid side: q.n - c = |q-a|^2/2 * ... > 0 check):
    qn = q @ n.T  # [M]
    num = c - qn  # < 0 always (q strictly valid); crossing needs d̂.n < 0
    sector_edges = -np.pi + np.arange(N_SECTORS + 1) * (2 * np.pi / N_SECTORS)
    upper = np.full((N_SECTORS, M), np.inf)
    lower = np.full((N_SECTORS, M), np.inf)

    def t_at(theta: np.ndarray) -> np.ndarray:
        d = np.stack([np.cos(theta), np.sin(theta)], axis=-1)  # [..., 2]
        dn = d @ n.T  # [..., M]
        with np.errstate(divide="ignore", invalid="ignore"):
            t = num[None, :] / dn
        t = np.where((dn < 0) & (t > 0), t, np.inf)
        return t

    t_edges = t_at(sector_edges)  # [13, M]
    phi = np.arctan2(-n[:, 1], -n[:, 0])  # angle of steepest approach (-n dir)
    with np.errstate(divide="ignore", invalid="ignore"):
        t_phi = -num / np.linalg.norm(n, axis=1)  # distance q→bisector (positive)
    t_phi = np.where(np.isfinite(t_phi), t_phi, np.inf)  # q's own zero bisector
    for s in range(N_SECTORS):
        th0, th1 = sector_edges[s], sector_edges[s + 1]
        t0, t1 = t_edges[s], t_edges[s + 1]
        upper[s] = np.maximum(t0, t1)  # inf-propagating: unbounded if either ray never crosses
        inside = ((phi - th0) % (2 * np.pi) < (th1 - th0))
        lower[s] = np.where(inside, t_phi, np.minimum(t0, t1))
    upper[:, q_idx] = np.inf
    lower[:, q_idx] = np.inf
    return upper, lower


def slice_rknn(
    facilities: np.ndarray,
    users: np.ndarray,
    q_idx: int,
    k: int,
) -> tuple[np.ndarray, dict]:
    facilities = np.asarray(facilities, dtype=np.float64)
    users = np.asarray(users, dtype=np.float64)
    q = facilities[q_idx]

    t0 = time.perf_counter()
    upper, lower = _arc_radii(facilities, q, q_idx)
    # bounding arc per sector = k-th smallest upper arc
    up_sorted = np.sort(upper, axis=1)
    rB = np.full(N_SECTORS, np.inf)
    if upper.shape[1] >= k:
        rB = up_sorted[:, k - 1]

    uvec = users - q
    udist = np.linalg.norm(uvec, axis=1)
    uang = np.arctan2(uvec[:, 1], uvec[:, 0])
    usector = np.floor((uang + np.pi) / (2 * np.pi / N_SECTORS)).astype(int) % N_SECTORS
    candidates = udist <= rB[usector]
    t1 = time.perf_counter()

    # ---- verification over per-sector significant lists -------------------
    mask = np.zeros(len(users), dtype=bool)
    d2_all_f = np.sum(facilities**2, axis=1)
    sig_sizes = []
    for s in range(N_SECTORS):
        urows = np.flatnonzero(candidates & (usector == s))
        if len(urows) == 0:
            sig_sizes.append(0)
            continue
        sig = np.flatnonzero(lower[s] < rB[s])
        sig = sig[sig != q_idx]
        sig_sizes.append(len(sig))
        cu = users[urows]
        d2q = np.sum((cu - q) ** 2, axis=1)
        if len(sig) == 0:
            mask[urows] = True  # nothing can prune them
            continue
        fs = facilities[sig]
        d2 = (
            np.sum(cu**2, axis=1)[:, None]
            - 2.0 * cu @ fs.T
            + d2_all_f[sig][None, :]
        )
        cnt = np.sum(d2 < d2q[:, None], axis=1)
        mask[urows] = cnt < k
    t2 = time.perf_counter()
    info = dict(
        t_filter_s=t1 - t0,
        t_verify_s=t2 - t1,
        n_candidates=int(candidates.sum()),
        sig_sizes=sig_sizes,
        bounding_arcs=rB,
    )
    return mask, info
