"""InfZone baseline [Cheema et al., ICDE'11] — influence-zone containment.

InfZone computes the *influence zone* ``Z_k(q)`` — the region where a user
is an RkNN of ``q`` iff it lies inside — by intersecting facility
half-planes and discarding facilities whose bisector provably cannot touch
the (shrinking) zone, using the star-shaped-zone vertex criterion plus the
two cheap distance filters (paper Eqs. (1)/(2)).

Our zone bookkeeping is the sound conservative coverage grid shared with
the RT-RkNN scene constructor (``repro.core.pruning``): a facility is
discarded only when its half-plane misses every possibly-zone cell, which
implies it misses the true zone.  As proved there, the surviving facility
set determines the zone *exactly*:  ``u ∈ Z  ⟺  #{kept a : dist(u,a) <
dist(u,q)} < k``.  Verification is therefore the paper's single
containment check (no false positives, no candidate refinement), here in
its algebraic form — a vectorized half-plane membership count over the
kept facilities only.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.geometry import Rect, bisector
from repro.core.pruning import prune_facilities

__all__ = ["infzone_rknn"]


def infzone_rknn(
    facilities: np.ndarray,
    users: np.ndarray,
    q_idx: int,
    k: int,
    rect: Rect | None = None,
    grid: int | None = None,
) -> tuple[np.ndarray, dict]:
    facilities = np.asarray(facilities, dtype=np.float64)
    users = np.asarray(users, dtype=np.float64)
    q = facilities[q_idx]
    if rect is None:
        rect = Rect.from_points(facilities, users)

    t0 = time.perf_counter()
    keep, stats = prune_facilities(
        facilities, q, k, rect, strategy="infzone", grid=grid, exclude=q_idx
    )
    kept = facilities[keep]
    n, c = bisector(kept, q) if len(kept) else (np.zeros((0, 2)), np.zeros((0,)))
    t1 = time.perf_counter()

    # containment check: u inside zone <=> kept-half-plane depth < k
    if len(kept):
        depth = (users @ n.T < c[None, :]).sum(axis=1)
    else:
        depth = np.zeros(len(users), dtype=int)
    mask = depth < k
    t2 = time.perf_counter()
    info = dict(
        t_filter_s=t1 - t0,
        t_verify_s=t2 - t1,
        n_kept=int(keep.sum()),
        stats=stats,
    )
    return mask, info
