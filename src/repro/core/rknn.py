"""Public RT-RkNN query API (Algorithm 1 end-to-end).

Backends (all produce identical verdict sets — property-tested):

* ``"dense"``    — Pallas ray-cast kernel (interpret mode on CPU), the
                   TPU-native execution of the paper's ray-casting stage.
* ``"dense-ref"``— pure-jnp oracle (fast on CPU; same math).
* ``"grid"``     — uniform-grid culled counting (TPU BVH analogue).
* ``"bvh"``      — paper-faithful LBVH traversal with early termination.
* ``"brute"``    — exact distance-rank counting (no geometry; baseline).

The scene-construction phase (host, numpy) matches paper Alg. 1 lines 1–8:
InfZone-style pruning → occluder triangles → index build.  The ray-casting
phase (device, JAX) is lines 9–24.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax.numpy as jnp

from repro.core import brute as _brute
from repro.core.bvh import build_bvh, bvh_hit_counts
from repro.core.geometry import Rect
from repro.core.grid import build_grid, grid_hit_counts_jnp
from repro.core.scene import Scene, build_scene
from repro.kernels import ops as _ops

__all__ = ["RkNNResult", "rt_rknn_query", "rknn_mono_query", "BACKENDS"]

BACKENDS = ("dense", "dense-ref", "grid", "bvh", "brute")


@dataclasses.dataclass
class RkNNResult:
    """Query result + phase timings (paper's filtering/verification split).

    Following §4.1 we report the two-stage convention of [62]: *filtering*
    = scene construction (pruning + occluders + index build), *verification*
    = the ray-cast / count stage.
    """

    mask: np.ndarray  # [N] bool — u ∈ RkNN(q)
    counts: np.ndarray  # [N] int32 hit counts (saturated for bvh early-exit)
    scene: Scene | None
    t_filter_s: float
    t_verify_s: float
    backend: str

    @property
    def result_indices(self) -> np.ndarray:
        return np.flatnonzero(self.mask)


def _verify_counts(
    users: np.ndarray, scene: Scene, k: int, backend: str, grid_g: int
) -> np.ndarray:
    xs = jnp.asarray(users[:, 0], jnp.float32)
    ys = jnp.asarray(users[:, 1], jnp.float32)
    if backend == "dense":
        return np.asarray(_ops.raycast_count(xs, ys, scene.coeffs))
    if backend == "dense-ref":
        return np.asarray(_ops.raycast_count(xs, ys, scene.coeffs, backend="ref"))
    if backend == "grid":
        g = build_grid(scene.tris[: scene.n_tris], scene.coeffs[: scene.n_tris], scene.rect, G=grid_g)
        return np.asarray(
            grid_hit_counts_jnp(xs, ys, g.base, g.lists, g.coeffs, scene.rect, grid_g)
        )
    if backend == "bvh":
        bvh = build_bvh(scene.tris[: scene.n_tris])
        return np.asarray(
            bvh_hit_counts(
                xs,
                ys,
                bvh.left,
                bvh.right,
                bvh.bbox,
                scene.coeffs[: scene.n_tris],
                k=k,
            )
        )
    raise ValueError(f"unknown backend {backend!r}")


def rt_rknn_query(
    facilities: np.ndarray,
    users: np.ndarray,
    q: int | np.ndarray,
    k: int,
    *,
    backend: str = "dense-ref",
    strategy: str = "infzone",
    grid_g: int = 64,
    prune_grid: int | None = None,
    rect: Rect | None = None,
    pad_to: int | None = None,
) -> RkNNResult:
    """Bichromatic RkNN of facility ``q`` (index into ``facilities`` or a
    ``[2]`` point).  Returns membership mask over ``users``."""
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}")
    facilities = np.asarray(facilities, dtype=np.float64)
    users = np.asarray(users, dtype=np.float64)

    if backend == "brute":
        t0 = time.perf_counter()
        if isinstance(q, (int, np.integer)):
            q_pt, excl = facilities[int(q)], int(q)
        else:
            q_pt, excl = np.asarray(q, np.float64), None
        counts = np.asarray(
            _ops.rank_count(users, facilities, q_pt, exclude=excl, backend="ref")
        )
        t1 = time.perf_counter()
        return RkNNResult(counts < k, counts, None, 0.0, t1 - t0, backend)

    t0 = time.perf_counter()
    scene = build_scene(
        facilities,
        q,
        k,
        rect,
        strategy=strategy,
        grid=prune_grid,
        pad_to=pad_to,
        users_hint=users,
    )
    t1 = time.perf_counter()
    counts = _verify_counts(users, scene, k, backend, grid_g)
    t2 = time.perf_counter()
    return RkNNResult(counts < k, counts, scene, t1 - t0, t2 - t1, backend)


def rknn_mono_query(
    points: np.ndarray,
    q_idx: int,
    k: int,
    *,
    backend: str = "dense-ref",
    strategy: str = "infzone",
    rect: Rect | None = None,
) -> RkNNResult:
    """Monochromatic RkNN (paper §2.1 / §4.5 discussion).

    Reduces exactly to the bichromatic machinery with ``F = U = P`` at
    threshold ``k + 1``: every point's ray hits its *own* occluder (a point
    is trivially closer to itself than to ``q``), so

        p ∈ RkNN_mono(q)  ⟺  #others-closer(p) < k
                           ⟺  hit-count(p) − 1 < k
                           ⟺  hit-count(p) < k + 1.

    Running scene pruning at ``k + 1`` keeps the influence-zone exactness
    argument aligned with the shifted threshold (a pruned own-occluder would
    already certify ``k + 1`` hits).  Validated against the mono brute
    oracle in ``tests/test_core_rknn.py``.
    """
    points = np.asarray(points, dtype=np.float64)
    res = rt_rknn_query(
        points, points, q_idx, k + 1, backend=backend, strategy=strategy, rect=rect
    )
    mask = res.mask.copy()
    mask[q_idx] = False
    return RkNNResult(mask, res.counts, res.scene, res.t_filter_s, res.t_verify_s, backend)
