"""Legacy free-function RkNN API — one-shot shims over :class:`RkNNEngine`.

The stateful engine (:mod:`repro.core.engine`) is the primary query
surface: it owns the shared domain rect, the scene cache, per-backend
prebuilt state, and persistent jitted dispatches, so repeated query waves
amortize everything the paper says should be amortized.  These functions
construct a throwaway engine per call (caches disabled — a one-shot call
cannot amortize anything) and therefore keep their historical semantics
bit-for-bit: same masks, same counts, same two-stage timing convention.

Backend names resolve through the registry in :mod:`repro.core.backends`
(``dense``, ``dense-ref``, ``grid``, ``grid-pallas``, ``grid-pallas-ref``,
``bvh``, ``brute`` built in; new backends register a class instead of
threading through dispatch ladders).

Timing semantics (§4.1 / [62] two-stage convention): *filtering*
(``t_filter_s``) covers everything on the host that prepares the query —
pruning, occluder construction, padding, AND the grid/BVH index build;
*verification* (``t_verify_s``) is only the device count dispatch.

Migration table old → new: docs/API.md.
"""

from __future__ import annotations

import numpy as np

from repro.core.backends import concrete_backends
from repro.core.engine import RkNNConfig, RkNNEngine
from repro.core.geometry import Rect
from repro.core.results import RkNNBatchResult, RkNNResult

__all__ = [
    "RkNNResult",
    "RkNNBatchResult",
    "rt_rknn_query",
    "rt_rknn_query_batch",
    "rknn_mono_query",
    "BACKENDS",
]

#: Registered *concrete* backend names, in registration order (kept as a
#: module attribute for backward compatibility; the registry is the source
#: of truth and late registrations won't be reflected here).  Meta
#: backends — the ``auto`` planner — route to these and are excluded.
BACKENDS = concrete_backends()


def _one_shot_engine(
    facilities,
    users,
    *,
    backend: str,
    strategy: str = "infzone",
    grid_g: int = 64,
    prune_grid: int | None = None,
    rect: Rect | None = None,
    pad_to: int | None = None,
    scene_workers: int = 0,
) -> RkNNEngine:
    return RkNNEngine(
        facilities,
        users,
        RkNNConfig(
            backend=backend,
            strategy=strategy,
            grid_g=grid_g,
            prune_grid=prune_grid,
            pad_to=pad_to,
            scene_workers=scene_workers,
            scene_cache=0,  # one-shot: nothing to amortize
            batch_cache=0,
        ),
        rect=rect,
    )


def rt_rknn_query(
    facilities: np.ndarray,
    users: np.ndarray,
    q: int | np.ndarray,
    k: int,
    *,
    backend: str = "dense-ref",
    strategy: str = "infzone",
    grid_g: int = 64,
    prune_grid: int | None = None,
    rect: Rect | None = None,
    pad_to: int | None = None,
) -> RkNNResult:
    """Bichromatic RkNN of facility ``q`` (index into ``facilities`` or a
    ``[2]`` point).  Returns membership mask over ``users``.

    One-shot shim; for repeated queries build an :class:`RkNNEngine` once
    and call :meth:`RkNNEngine.query`.
    """
    eng = _one_shot_engine(
        facilities,
        users,
        backend=backend,
        strategy=strategy,
        grid_g=grid_g,
        prune_grid=prune_grid,
        rect=rect,
        pad_to=pad_to,
    )
    return eng.query(q, k)


def rt_rknn_query_batch(
    facilities: np.ndarray,
    users: np.ndarray,
    qs,
    k: int,
    *,
    backend: str = "dense-ref",
    strategy: str = "infzone",
    grid_g: int = 64,
    prune_grid: int | None = None,
    rect: Rect | None = None,
    pad_to: int | None = None,
    scene_workers: int = 0,
) -> RkNNBatchResult:
    """Batched bichromatic RkNN: all of ``qs`` against one shared user set.

    ``qs`` is a sequence of facility indices and/or ``[2]`` points.  All
    per-query scenes are built on the host (with ``scene_workers`` threads
    when > 0), padded to one static ``Mp``, and counted in a **single**
    jitted batched dispatch.  Masks are bit-identical to looping
    :func:`rt_rknn_query` per query (equivalence-tested across all
    backends).

    One-shot shim; for repeated workloads build an :class:`RkNNEngine`
    once — its scene cache and prepared-batch LRU then amortize the host
    filter phase across calls.
    """
    eng = _one_shot_engine(
        facilities,
        users,
        backend=backend,
        strategy=strategy,
        grid_g=grid_g,
        prune_grid=prune_grid,
        rect=rect,
        pad_to=pad_to,
        scene_workers=scene_workers,
    )
    return eng.query_batch(qs, k)


def rknn_mono_query(
    points: np.ndarray,
    q_idx: int,
    k: int,
    *,
    backend: str = "dense-ref",
    strategy: str = "infzone",
    rect: Rect | None = None,
) -> RkNNResult:
    """Monochromatic RkNN (paper §2.1 / §4.5 discussion).

    Reduces exactly to the bichromatic machinery with ``F = U = P`` at
    threshold ``k + 1``: every point's ray hits its *own* occluder (a point
    is trivially closer to itself than to ``q``), so

        p ∈ RkNN_mono(q)  ⟺  #others-closer(p) < k
                           ⟺  hit-count(p) − 1 < k
                           ⟺  hit-count(p) < k + 1.

    Running scene pruning at ``k + 1`` keeps the influence-zone exactness
    argument aligned with the shifted threshold (a pruned own-occluder would
    already certify ``k + 1`` hits).  Validated against the mono brute
    oracle in ``tests/test_core_rknn.py``.

    The returned ``counts`` are **self-hit corrected**: raw hit counts
    include each point's own occluder, so one hit is subtracted for every
    point except ``q`` itself (whose occluder is excluded from the scene).
    ``counts[p]`` is therefore the number of *other* points strictly closer
    to ``p`` than ``q``, and ``mask == counts < k`` (with row ``q_idx``
    forced False).  For mask-True points this equals the mono brute rank
    exactly; for pruned-out points the count is a saturated lower bound
    ``>= k``.
    """
    points = np.asarray(points, dtype=np.float64)
    eng = _one_shot_engine(
        points, points, backend=backend, strategy=strategy, rect=rect
    )
    return eng.query_mono(q_idx, k)
