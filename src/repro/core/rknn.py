"""Public RT-RkNN query API (Algorithm 1 end-to-end), single and batched.

Backends (all produce identical verdict sets — property-tested):

* ``"dense"``    — Pallas ray-cast kernel (interpret mode on CPU), the
                   TPU-native execution of the paper's ray-casting stage.
* ``"dense-ref"``— pure-jnp oracle (fast on CPU; same math).
* ``"grid"``     — uniform-grid culled counting (TPU BVH analogue).
* ``"bvh"``      — paper-faithful LBVH traversal with early termination.
* ``"brute"``    — exact distance-rank counting (no geometry; baseline).

The scene-construction phase (host, numpy) matches paper Alg. 1 lines 1–8:
InfZone-style pruning → occluder triangles → index build.  The ray-casting
phase (device, JAX) is lines 9–24.

Timing semantics (§4.1 / [62] two-stage convention): *filtering*
(``t_filter_s``) covers everything on the host that prepares the query —
pruning, occluder construction, padding, AND the grid/BVH index build;
*verification* (``t_verify_s``) is only the device count dispatch.  (Before
the batched engine landed, index build was mis-attributed to verification.)

The batched engine (:func:`rt_rknn_query_batch`) amortizes per-query
overheads the way RT-kNNS Unbound amortizes BVH builds across query
batches: all ``Q`` scenes are built on the host (optionally via a thread
pool), padded to one static ``Mp``, stacked to ``[Q, Mp, 3, 3]``, and
counted in a single jitted batched dispatch per backend — one kernel
launch / one index-gather sweep instead of ``Q`` Python-loop iterations.
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import time

import numpy as np

import jax.numpy as jnp

from repro.core.bvh import build_bvh, bvh_hit_counts, bvh_hit_counts_batch, stack_bvhs
from repro.core.geometry import Rect
from repro.core.grid import (
    build_grid,
    grid_hit_counts_batch_jnp,
    grid_hit_counts_jnp,
    stack_grids,
)
from repro.core.scene import Scene, build_scene, pad_scene_arrays
from repro.kernels import ops as _ops

__all__ = [
    "RkNNResult",
    "RkNNBatchResult",
    "rt_rknn_query",
    "rt_rknn_query_batch",
    "rknn_mono_query",
    "BACKENDS",
]

BACKENDS = ("dense", "dense-ref", "grid", "bvh", "brute")


@dataclasses.dataclass
class RkNNResult:
    """Query result + phase timings (paper's filtering/verification split).

    Following §4.1 we report the two-stage convention of [62]: *filtering*
    = scene construction (pruning + occluders + grid/BVH index build),
    *verification* = the ray-cast / count stage only.

    ``counts`` convention: for bichromatic queries these are raw occluder
    hit counts (saturated at ``k`` for the bvh early-exit backend).  For
    monochromatic queries (:func:`rknn_mono_query`) they are self-hit
    corrected — ``counts[p]`` is the number of *other* points strictly
    closer to ``p`` than ``q`` is, so ``mask == counts < k`` holds in both
    cases.
    """

    mask: np.ndarray  # [N] bool — u ∈ RkNN(q)
    counts: np.ndarray  # [N] int32 hit counts (saturated for bvh early-exit)
    scene: Scene | None
    t_filter_s: float
    t_verify_s: float
    backend: str

    @property
    def result_indices(self) -> np.ndarray:
        return np.flatnonzero(self.mask)


@dataclasses.dataclass
class RkNNBatchResult:
    """Batched multi-query result: per-query masks + amortized timings.

    ``t_filter_s`` covers the whole batch's host work (scene builds,
    padding/stacking, index builds); ``t_verify_s`` is the single batched
    device dispatch.  Per-query attribution is therefore the mean:
    ``t_filter_s / len(qs)`` etc.
    """

    masks: np.ndarray  # [Q, N] bool — u ∈ RkNN(q_i)
    counts: np.ndarray  # [Q, N] int32 (saturated at k for bvh early-exit)
    scenes: list[Scene] | None  # None for the brute backend
    t_filter_s: float
    t_verify_s: float
    backend: str
    k: int

    @property
    def n_queries(self) -> int:
        return len(self.masks)

    def result_indices(self, i: int) -> np.ndarray:
        return np.flatnonzero(self.masks[i])

    def per_query(self, i: int) -> RkNNResult:
        """View of query ``i`` with mean-amortized timings."""
        q_n = max(self.n_queries, 1)
        return RkNNResult(
            mask=self.masks[i],
            counts=self.counts[i],
            scene=None if self.scenes is None else self.scenes[i],
            t_filter_s=self.t_filter_s / q_n,
            t_verify_s=self.t_verify_s / q_n,
            backend=self.backend,
        )


def _build_index(scene: Scene, backend: str, grid_g: int):
    """Host-side index build for the verification backend (filter phase)."""
    if backend == "grid":
        return build_grid(
            scene.tris[: scene.n_tris], scene.coeffs[: scene.n_tris], scene.rect, G=grid_g
        )
    if backend == "bvh":
        return build_bvh(scene.tris[: scene.n_tris])
    return None


def _verify_counts(
    users: np.ndarray, scene: Scene, k: int, backend: str, grid_g: int, index=None
) -> np.ndarray:
    """Device count stage.  ``index`` is the pre-built grid/BVH from
    :func:`_build_index`; building it here would misattribute host index
    construction to the verification phase."""
    xs = jnp.asarray(users[:, 0], jnp.float32)
    ys = jnp.asarray(users[:, 1], jnp.float32)
    if backend == "dense":
        return np.asarray(_ops.raycast_count(xs, ys, scene.coeffs))
    if backend == "dense-ref":
        return np.asarray(_ops.raycast_count(xs, ys, scene.coeffs, backend="ref"))
    if backend == "grid":
        g = index if index is not None else _build_index(scene, backend, grid_g)
        return np.asarray(
            grid_hit_counts_jnp(xs, ys, g.base, g.lists, g.coeffs, scene.rect, grid_g)
        )
    if backend == "bvh":
        bvh = index if index is not None else _build_index(scene, backend, grid_g)
        return np.asarray(
            bvh_hit_counts(
                xs,
                ys,
                bvh.left,
                bvh.right,
                bvh.bbox,
                scene.coeffs[: scene.n_tris],
                k=k,
            )
        )
    raise ValueError(f"unknown backend {backend!r}")


def rt_rknn_query(
    facilities: np.ndarray,
    users: np.ndarray,
    q: int | np.ndarray,
    k: int,
    *,
    backend: str = "dense-ref",
    strategy: str = "infzone",
    grid_g: int = 64,
    prune_grid: int | None = None,
    rect: Rect | None = None,
    pad_to: int | None = None,
) -> RkNNResult:
    """Bichromatic RkNN of facility ``q`` (index into ``facilities`` or a
    ``[2]`` point).  Returns membership mask over ``users``."""
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}")
    facilities = np.asarray(facilities, dtype=np.float64)
    users = np.asarray(users, dtype=np.float64)

    if backend == "brute":
        t0 = time.perf_counter()
        if isinstance(q, (int, np.integer)):
            q_pt, excl = facilities[int(q)], int(q)
        else:
            q_pt, excl = np.asarray(q, np.float64), None
        counts = np.asarray(
            _ops.rank_count(users, facilities, q_pt, exclude=excl, backend="ref")
        )
        t1 = time.perf_counter()
        return RkNNResult(counts < k, counts, None, 0.0, t1 - t0, backend)

    t0 = time.perf_counter()
    scene = build_scene(
        facilities,
        q,
        k,
        rect,
        strategy=strategy,
        grid=prune_grid,
        pad_to=pad_to,
        users_hint=users,
    )
    index = _build_index(scene, backend, grid_g)
    t1 = time.perf_counter()
    counts = _verify_counts(users, scene, k, backend, grid_g, index=index)
    t2 = time.perf_counter()
    return RkNNResult(counts < k, counts, scene, t1 - t0, t2 - t1, backend)


def _normalize_queries(
    facilities: np.ndarray, qs
) -> tuple[list[int | np.ndarray], np.ndarray, list[int | None]]:
    """Split a query batch into per-query build args, points, and excludes."""
    queries: list[int | np.ndarray] = []
    q_pts = np.zeros((len(qs), 2), np.float64)
    excludes: list[int | None] = []
    for i, q in enumerate(qs):
        arr = np.asarray(q)
        if arr.ndim == 0 and np.issubdtype(arr.dtype, np.integer):
            qi = int(arr)
            queries.append(qi)
            q_pts[i] = facilities[qi]
            excludes.append(qi)
        else:
            pt = np.asarray(q, np.float64).reshape(2)
            queries.append(pt)
            q_pts[i] = pt
            excludes.append(None)
    return queries, q_pts, excludes


def rt_rknn_query_batch(
    facilities: np.ndarray,
    users: np.ndarray,
    qs,
    k: int,
    *,
    backend: str = "dense-ref",
    strategy: str = "infzone",
    grid_g: int = 64,
    prune_grid: int | None = None,
    rect: Rect | None = None,
    pad_to: int | None = None,
    scene_workers: int = 0,
) -> RkNNBatchResult:
    """Batched bichromatic RkNN: all of ``qs`` against one shared user set.

    ``qs`` is a sequence of facility indices and/or ``[2]`` points.  All
    per-query scenes are built on the host (with ``scene_workers`` threads
    when > 0), padded to one static ``Mp``, and counted in a **single**
    jitted batched dispatch — the amortization that makes heavy query
    traffic viable (ROADMAP north star; cf. RT-kNNS Unbound's batched BVH
    reuse).  Masks are bit-identical to looping :func:`rt_rknn_query`
    per query (equivalence-tested across all backends).
    """
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}")
    facilities = np.asarray(facilities, dtype=np.float64)
    users = np.asarray(users, dtype=np.float64)
    qs = list(qs)
    if not qs:
        return RkNNBatchResult(
            masks=np.zeros((0, len(users)), bool),
            counts=np.zeros((0, len(users)), np.int32),
            scenes=[],
            t_filter_s=0.0,
            t_verify_s=0.0,
            backend=backend,
            k=k,
        )
    queries, q_pts, excludes = _normalize_queries(facilities, qs)

    if backend == "brute":
        t0 = time.perf_counter()
        counts = np.asarray(
            _ops.rank_count_batch(users, facilities, q_pts, exclude=excludes)
        )
        t1 = time.perf_counter()
        return RkNNBatchResult(
            counts < k, counts, None, 0.0, t1 - t0, backend, k
        )

    # ---- filter phase: Q scene builds + padding/stacking + index builds ----
    t0 = time.perf_counter()
    if rect is None:
        # one shared domain rect so scenes (and the grid cell map) align
        rect = Rect.from_points(facilities, q_pts, users)

    def _one_scene(q):
        return build_scene(
            facilities,
            q,
            k,
            rect,
            strategy=strategy,
            grid=prune_grid,
            users_hint=users,
        )

    if scene_workers > 0 and len(queries) > 1:
        with concurrent.futures.ThreadPoolExecutor(scene_workers) as pool:
            scenes = list(pool.map(_one_scene, queries))
    else:
        scenes = [_one_scene(q) for q in queries]

    xs = jnp.asarray(users[:, 0], jnp.float32)
    ys = jnp.asarray(users[:, 1], jnp.float32)

    if backend in ("dense", "dense-ref"):
        mp = pad_to if pad_to is not None else max(s.tris.shape[0] for s in scenes)
        coeffs = np.stack(
            [
                pad_scene_arrays(
                    s.tris[: s.n_tris], s.coeffs[: s.n_tris], s.owner[: s.n_tris], mp
                )[1]
                for s in scenes
            ]
        ).astype(np.float32)  # [Q, Mp, 3, 3]
        t1 = time.perf_counter()
        counts = np.asarray(
            _ops.raycast_count_batch(
                xs, ys, coeffs, backend="ref" if backend == "dense-ref" else "pallas"
            )
        )
    elif backend == "grid":
        grids = [_build_index(s, backend, grid_g) for s in scenes]
        base, lists, gcoeffs = stack_grids(grids)
        t1 = time.perf_counter()
        counts = np.asarray(
            grid_hit_counts_batch_jnp(xs, ys, base, lists, gcoeffs, rect, grid_g)
        )
    elif backend == "bvh":
        bvhs = [_build_index(s, backend, grid_g) for s in scenes]
        left, right, bbox, bcoeffs = stack_bvhs(
            bvhs, [s.coeffs[: s.n_tris] for s in scenes]
        )
        t1 = time.perf_counter()
        counts = np.asarray(
            bvh_hit_counts_batch(xs, ys, left, right, bbox, bcoeffs, k=k)
        )
    else:  # pragma: no cover — BACKENDS guard above
        raise ValueError(f"unknown backend {backend!r}")
    t2 = time.perf_counter()
    return RkNNBatchResult(counts < k, counts, scenes, t1 - t0, t2 - t1, backend, k)


def rknn_mono_query(
    points: np.ndarray,
    q_idx: int,
    k: int,
    *,
    backend: str = "dense-ref",
    strategy: str = "infzone",
    rect: Rect | None = None,
) -> RkNNResult:
    """Monochromatic RkNN (paper §2.1 / §4.5 discussion).

    Reduces exactly to the bichromatic machinery with ``F = U = P`` at
    threshold ``k + 1``: every point's ray hits its *own* occluder (a point
    is trivially closer to itself than to ``q``), so

        p ∈ RkNN_mono(q)  ⟺  #others-closer(p) < k
                           ⟺  hit-count(p) − 1 < k
                           ⟺  hit-count(p) < k + 1.

    Running scene pruning at ``k + 1`` keeps the influence-zone exactness
    argument aligned with the shifted threshold (a pruned own-occluder would
    already certify ``k + 1`` hits).  Validated against the mono brute
    oracle in ``tests/test_core_rknn.py``.

    The returned ``counts`` are **self-hit corrected**: raw hit counts
    include each point's own occluder, so one hit is subtracted for every
    point except ``q`` itself (whose occluder is excluded from the scene).
    ``counts[p]`` is therefore the number of *other* points strictly closer
    to ``p`` than ``q``, and ``mask == counts < k`` (with row ``q_idx``
    forced False).  For mask-True points this equals the mono brute rank
    exactly; for pruned-out points the count is a saturated lower bound
    ``>= k``.
    """
    points = np.asarray(points, dtype=np.float64)
    res = rt_rknn_query(
        points, points, q_idx, k + 1, backend=backend, strategy=strategy, rect=rect
    )
    counts = np.asarray(res.counts, np.int32).copy()
    # self-hit correction: every point except q hits its own occluder (q's
    # occluder is excluded from the scene, so its count is already "others")
    counts[np.arange(len(counts)) != q_idx] -= 1
    np.maximum(counts, 0, out=counts)
    mask = counts < k
    mask[q_idx] = False
    return RkNNResult(mask, counts, res.scene, res.t_filter_s, res.t_verify_s, backend)
