"""Stateful RkNN query engine: build once, serve many query waves.

The paper's performance story is amortization — construct geometry once,
cast many rays (RT-kNNS Unbound and RTNN make the same point for RT-core
kNN: the wins come from reusing the built acceleration structure across
query batches).  :class:`RkNNEngine` is the long-lived object that state
hangs off:

* the shared domain :class:`~repro.core.geometry.Rect` and the device-
  resident user coordinate arrays (uploaded once, like the paper's
  "plain GPU transfer" of Table 2);
* a :class:`~repro.core.hybrid.SceneCache` so hot queries skip InfZone
  pruning + occluder construction entirely (cache hits show up directly
  as a collapsed ``t_filter_s``);
* a batch-level LRU of prepared backend state (stacked coeffs / stacked
  grid / stacked BVH), so a repeated query workload skips the whole host
  filter phase;
* persistent jitted dispatches: scene pads are bucketed to sticky powers
  of two, so repeat workloads re-enter the same XLA executable instead of
  re-tracing;
* an optional ``jax.sharding.Mesh`` — the dense-ref batch dispatch is then
  pjit'd with users sharded over the data axes and queries over
  ``'model'`` (the serving layout previously trapped in ``launch/serve``).

Verification backends are pluggable via :mod:`repro.core.backends`; the
legacy free functions (``rt_rknn_query`` etc.) are one-shot shims over a
throwaway engine.  Lifecycle, config knobs, and the migration table from
the free functions live in ``docs/API.md``.
"""

from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import queue
import threading
import time
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.backends import (
    Backend,
    BatchRequest,
    QueryRequest,
    get_backend,
)
from repro.core.geometry import Rect
from repro.core.hybrid import SceneCache, _q_key
from repro.core.results import RkNNBatchResult, RkNNResult
from repro.core.scene import Scene, build_scene

__all__ = ["RkNNConfig", "EngineStats", "RkNNEngine", "serve_shardings"]


def serve_shardings(mesh):
    """The serving partition layout: ``(user_sh, scene_sh, out_sh)``.

    Users sharded over the data-parallel axes, per-query scenes replicated
    (they are tiny — ~64 triangles · 36 B), queries sharded over
    ``'model'``.  Single source of truth for the engine's live dispatch
    and ``launch.serve.lower_rknn_serve``'s dry-run lowering.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.meshctx import dp_axes

    dp = dp_axes(mesh)
    dp_spec = dp if len(dp) > 1 else dp[0]
    user_sh = NamedSharding(mesh, P(dp_spec))
    scene_sh = NamedSharding(mesh, P("model", None, None, None))
    out_sh = NamedSharding(mesh, P("model", dp_spec))
    return user_sh, scene_sh, out_sh


@dataclasses.dataclass(frozen=True)
class RkNNConfig:
    """Construction-time knobs of :class:`RkNNEngine` (see docs/API.md).

    ``scene_cache`` / ``batch_cache`` are LRU capacities (0 disables).
    ``pad_scene_to`` seeds the sticky power-of-two triangle pad bucket;
    ``pad_to`` pins it exactly (overriding bucketing) when not ``None``.
    """

    backend: str = "dense-ref"
    strategy: str = "infzone"
    grid_g: int = 64
    prune_grid: int | None = None
    pad_to: int | None = None
    scene_workers: int = 0
    scene_cache: int = 256
    batch_cache: int = 8
    pad_scene_to: int = 128


@dataclasses.dataclass
class EngineStats:
    """Cumulative counters over the engine's lifetime."""

    n_queries: int = 0
    n_batches: int = 0
    t_filter_s: float = 0.0
    t_verify_s: float = 0.0
    m_max: int = 0
    batch_cache_hits: int = 0


def _next_pow2(n: int) -> int:
    return 1 << max(int(np.ceil(np.log2(max(n, 1)))), 0)


def _normalize_queries(
    facilities: np.ndarray, qs
) -> tuple[list[int | np.ndarray], np.ndarray, list[int | None]]:
    """Split a query batch into per-query build args, points, and excludes."""
    queries: list[int | np.ndarray] = []
    q_pts = np.zeros((len(qs), 2), np.float64)
    excludes: list[int | None] = []
    for i, q in enumerate(qs):
        arr = np.asarray(q)
        if arr.ndim == 0 and np.issubdtype(arr.dtype, np.integer):
            qi = int(arr)
            queries.append(qi)
            q_pts[i] = facilities[qi]
            excludes.append(qi)
        else:
            pt = np.asarray(q, np.float64).reshape(2)
            queries.append(pt)
            q_pts[i] = pt
            excludes.append(None)
    return queries, q_pts, excludes


class RkNNEngine:
    """Build once from ``(facilities, users, RkNNConfig)``; query many times.

    Exposes :meth:`query`, :meth:`query_batch`, :meth:`query_mono`, and
    :meth:`stream` (double-buffered host scene builds overlapping device
    dispatch).  Backend selection defaults to ``config.backend`` and can be
    overridden per call with any name in the backend registry.
    """

    def __init__(
        self,
        facilities: np.ndarray,
        users: np.ndarray,
        config: RkNNConfig | None = None,
        *,
        mesh=None,
        rect: Rect | None = None,
        **overrides,
    ):
        config = config or RkNNConfig()
        if overrides:
            config = dataclasses.replace(config, **overrides)
        get_backend(config.backend)  # validate eagerly
        self.config = config
        self.facilities = np.asarray(facilities, dtype=np.float64)
        self.users = np.asarray(users, dtype=np.float64)
        self.mesh = mesh
        self.stats = EngineStats()
        self.scene_cache: SceneCache | None = (
            SceneCache(capacity=config.scene_cache) if config.scene_cache > 0 else None
        )
        self._fp: int | None = None  # facility fingerprint, computed once
        self._batch_cache: "collections.OrderedDict[tuple, tuple]" = (
            collections.OrderedDict()
        )
        self._batch_lock = threading.Lock()  # stream() mutates from producer
        self._pad_bucket = max(int(config.pad_scene_to), 1)
        self._explicit_rect = rect is not None
        self._rect = rect
        self._hull: tuple[np.ndarray, np.ndarray] | None = None
        self._xs = self._ys = None  # lazy device arrays
        self._mono: "RkNNEngine | None" = None
        self._is_mono: bool | None = None
        self._mesh_step = None
        if mesh is not None:
            self._init_mesh(mesh)

    # ------------------------------------------------------------------
    # lazy shared state
    # ------------------------------------------------------------------
    @property
    def rect(self) -> Rect:
        """The shared domain rectangle (facilities ∪ users, padded)."""
        if self._rect is None:
            self._rect = Rect.from_bounds(*self._hull_bounds())
        return self._rect

    def _hull_bounds(self) -> tuple[np.ndarray, np.ndarray]:
        """Unpadded min/max of facilities ∪ users (lazy, cached)."""
        if self._hull is None:
            pts = np.concatenate([self.facilities, self.users])
            self._hull = (pts.min(axis=0), pts.max(axis=0))
        return self._hull

    @property
    def xs(self) -> jnp.ndarray:
        if self._xs is None:
            self._xs = jnp.asarray(self.users[:, 0], jnp.float32)
            self._ys = jnp.asarray(self.users[:, 1], jnp.float32)
        return self._xs

    @property
    def ys(self) -> jnp.ndarray:
        self.xs  # noqa: B018 — materializes both
        return self._ys

    def _rect_for(self, q_pts: np.ndarray) -> Rect:
        """Shared rect, extended only when a query point falls outside the
        facility∪user hull (keeps one-shot shims bit-compatible with the
        old per-call ``Rect.from_points(F, q, U)``)."""
        if self._explicit_rect:
            return self.rect
        lo, hi = self._hull_bounds()
        if np.all(q_pts >= lo) and np.all(q_pts <= hi):
            return self.rect
        return Rect.from_points(self.facilities, q_pts, self.users)

    def _fingerprint(self) -> int:
        if self._fp is None:
            self._fp = SceneCache.fingerprint(self.facilities)
        return self._fp

    # ------------------------------------------------------------------
    # mesh-sharded dense dispatch (absorbed from launch/serve.py)
    # ------------------------------------------------------------------
    def _init_mesh(self, mesh) -> None:
        from repro.distributed.meshctx import dp_axes
        from repro.kernels.ref import raycast_count_batch_ref

        dp = dp_axes(mesh)
        user_sh, scene_sh, out_sh = serve_shardings(mesh)
        xs = self.users[:, 0].astype(np.float32)
        ys = self.users[:, 1].astype(np.float32)
        n = len(xs)
        dpn = int(np.prod([mesh.shape[a] for a in dp]))
        padn = (-n) % dpn
        if padn:  # sentinel users far outside every scene; sliced off below
            xs = np.concatenate([xs, np.full(padn, 2e9, np.float32)])
            ys = np.concatenate([ys, np.full(padn, 2e9, np.float32)])
        mesh_xs = jax.device_put(xs, user_sh)
        mesh_ys = jax.device_put(ys, user_sh)
        step = jax.jit(
            raycast_count_batch_ref,
            in_shardings=(user_sh, user_sh, scene_sh),
            out_shardings=out_sh,
        )

        def dispatch(_xs, _ys, coeffs):
            return np.asarray(step(mesh_xs, mesh_ys, jnp.asarray(coeffs)))[:, :n]

        self._mesh_step = dispatch

    def _dense_dispatch_for(self, backend: Backend):
        """Engine-held dispatch override: the mesh-sharded pjit step runs
        the ref math, so only the dense-ref backend routes through it."""
        if self._mesh_step is not None and backend.name == "dense-ref":
            return self._mesh_step
        return None

    # ------------------------------------------------------------------
    # filter phase helpers (host)
    # ------------------------------------------------------------------
    def _build_scene(self, q, k: int, rect: Rect, *, pad_to: int | None = None):
        if self.scene_cache is not None and pad_to is None:
            scene, _hit = self.scene_cache.get_or_build(
                self.facilities,
                q,
                k,
                rect,
                fp=self._fingerprint(),
                strategy=self.config.strategy,
                grid=self.config.prune_grid,
                users_hint=self.users,
            )
            return scene
        return build_scene(
            self.facilities,
            q,
            k,
            rect,
            strategy=self.config.strategy,
            grid=self.config.prune_grid,
            pad_to=pad_to,
            users_hint=self.users,
        )

    def _index_for(self, backend: Backend, scene: Scene) -> Any:
        """Per-scene index, memoized on the scene object so cached scenes
        carry their grid/BVH across repeated queries."""
        store = getattr(scene, "_engine_indexes", None)
        if store is None:
            store = {}
            object.__setattr__(scene, "_engine_indexes", store)
        key = (backend.name, self.config.grid_g)
        if key not in store:
            store[key] = backend.build_index(scene, grid_g=self.config.grid_g)
        return store[key]

    def _mp_bucket(self, scenes: list[Scene]) -> int:
        if self.config.pad_to is not None:
            return self.config.pad_to
        mmax = max(s.tris.shape[0] for s in scenes)
        with self._batch_lock:
            self._pad_bucket = max(self._pad_bucket, _next_pow2(mmax))
            return self._pad_bucket

    def _filter_batch(
        self,
        backend: Backend,
        queries: list,
        q_pts: np.ndarray,
        excludes: list,
        k: int,
        rect: Rect,
        scene_workers: int,
    ) -> tuple[BatchRequest, Any, list[Scene]]:
        """Host filter phase for one batch: scenes + stacked backend state,
        LRU-cached by (backend, k, queries, rect) so a repeated workload
        collapses to a dictionary lookup."""
        cache_key = None
        if self.config.batch_cache > 0:
            cache_key = (
                backend.name,
                k,
                tuple(_q_key(q) for q in queries),
                rect,
            )
            with self._batch_lock:
                hit = self._batch_cache.get(cache_key)
                if hit is not None:
                    self._batch_cache.move_to_end(cache_key)
                    self.stats.batch_cache_hits += 1
                    req, prepared, scenes = hit
                    return req, prepared, scenes

        def one(q):
            return self._build_scene(q, k, rect)

        if scene_workers > 0 and len(queries) > 1:
            with concurrent.futures.ThreadPoolExecutor(scene_workers) as pool:
                scenes = list(pool.map(one, queries))
        else:
            scenes = [one(q) for q in queries]
        dispatch = self._dense_dispatch_for(backend)
        # the mesh dispatch closes over its own sharded user arrays — don't
        # materialize a second, replicated device copy it would never read
        req = BatchRequest(
            xs=None if dispatch is not None else self.xs,
            ys=None if dispatch is not None else self.ys,
            k=k,
            rect=rect,
            grid_g=self.config.grid_g,
            scenes=scenes,
            # per-scene index memo: scene-cache hits reuse their grid/BVH
            # instead of rebuilding it on every new batch composition
            indexes=[self._index_for(backend, s) for s in scenes],
            users=self.users,
            facilities=self.facilities,
            q_pts=q_pts,
            excludes=excludes,
            mp=self._mp_bucket(scenes),
            dense_dispatch=dispatch,
        )
        prepared = backend.prepare_batch(req)
        if cache_key is not None:
            with self._batch_lock:
                self._batch_cache[cache_key] = (req, prepared, scenes)
                if len(self._batch_cache) > self.config.batch_cache:
                    self._batch_cache.popitem(last=False)
        return req, prepared, scenes

    # ------------------------------------------------------------------
    # public query surface
    # ------------------------------------------------------------------
    def query(self, q, k: int, *, backend: str | None = None) -> RkNNResult:
        """Bichromatic RkNN of one query (facility index or ``[2]`` point)."""
        b = get_backend(backend or self.config.backend)
        arr = np.asarray(q)
        if arr.ndim == 0 and np.issubdtype(arr.dtype, np.integer):
            q_build: int | np.ndarray = int(arr)
            q_pt, exclude = self.facilities[int(arr)], int(arr)
        else:
            q_pt = np.asarray(q, np.float64).reshape(2)
            q_build, exclude = q_pt, None

        if not b.uses_scene:
            # geometry-free: never materialize the device user arrays
            t0 = time.perf_counter()
            counts = b.count(
                QueryRequest(
                    xs=None,
                    ys=None,
                    k=k,
                    users=self.users,
                    facilities=self.facilities,
                    q_pt=q_pt,
                    exclude=exclude,
                )
            )
            t1 = time.perf_counter()
            self.stats.n_queries += 1
            self.stats.t_verify_s += t1 - t0
            return RkNNResult(counts < k, counts, None, 0.0, t1 - t0, b.name)

        t0 = time.perf_counter()
        rect = self._rect_for(q_pt[None])
        scene = self._build_scene(q_build, k, rect, pad_to=self.config.pad_to)
        index = self._index_for(b, scene)
        t1 = time.perf_counter()
        counts = b.count(
            QueryRequest(
                xs=self.xs,
                ys=self.ys,
                k=k,
                grid_g=self.config.grid_g,
                scene=scene,
                index=index,
            )
        )
        t2 = time.perf_counter()
        self.stats.n_queries += 1
        self.stats.t_filter_s += t1 - t0
        self.stats.t_verify_s += t2 - t1
        self.stats.m_max = max(self.stats.m_max, scene.n_tris)
        return RkNNResult(counts < k, counts, scene, t1 - t0, t2 - t1, b.name)

    def query_batch(
        self,
        qs,
        k: int,
        *,
        backend: str | None = None,
        scene_workers: int | None = None,
    ) -> RkNNBatchResult:
        """Batched bichromatic RkNN: all of ``qs`` against the shared users.

        One host filter phase (scene builds — cache-aware — plus backend
        stacking) and ONE batched device dispatch.  Masks are bit-identical
        to looping :meth:`query` per query (equivalence-tested across all
        backends).
        """
        b = get_backend(backend or self.config.backend)
        workers = (
            self.config.scene_workers if scene_workers is None else scene_workers
        )
        qs = list(qs)
        n_users = len(self.users)
        if not qs:
            return RkNNBatchResult(
                masks=np.zeros((0, n_users), bool),
                counts=np.zeros((0, n_users), np.int32),
                scenes=None if not b.uses_scene else [],
                t_filter_s=0.0,
                t_verify_s=0.0,
                backend=b.name,
                k=k,
            )
        queries, q_pts, excludes = _normalize_queries(self.facilities, qs)

        if not b.uses_scene:
            t0 = time.perf_counter()
            counts = b.count_batch(
                BatchRequest(
                    xs=None,
                    ys=None,
                    k=k,
                    users=self.users,
                    facilities=self.facilities,
                    q_pts=q_pts,
                    excludes=excludes,
                ),
                None,
            )
            t1 = time.perf_counter()
            self.stats.n_queries += len(qs)
            self.stats.n_batches += 1
            self.stats.t_verify_s += t1 - t0
            return RkNNBatchResult(counts < k, counts, None, 0.0, t1 - t0, b.name, k)

        t0 = time.perf_counter()
        rect = self._rect_for(q_pts)
        req, prepared, scenes = self._filter_batch(
            b, queries, q_pts, excludes, k, rect, workers
        )
        t1 = time.perf_counter()
        counts = b.count_batch(req, prepared)
        t2 = time.perf_counter()
        self.stats.n_queries += len(qs)
        self.stats.n_batches += 1
        self.stats.t_filter_s += t1 - t0
        self.stats.t_verify_s += t2 - t1
        self.stats.m_max = max(self.stats.m_max, max(s.n_tris for s in scenes))
        return RkNNBatchResult(counts < k, counts, scenes, t1 - t0, t2 - t1, b.name, k)

    def query_mono(self, q_idx: int, k: int, *, backend: str | None = None) -> RkNNResult:
        """Monochromatic RkNN over the facility set (paper §2.1 / §4.5).

        Reduces to the bichromatic machinery with ``F = U = facilities`` at
        threshold ``k + 1`` (every point's ray hits its own occluder), then
        self-hit-corrects the counts — see docs/API.md for the derivation.
        """
        if self._is_mono is None:
            self._is_mono = self.users is self.facilities or (
                self.users.shape == self.facilities.shape
                and np.array_equal(self.users, self.facilities)
            )
        eng = self
        if not self._is_mono:
            if self._mono is None:
                # mesh is deliberately not forwarded: the single-query path
                # never routes through the sharded batch dispatch
                self._mono = RkNNEngine(
                    self.facilities,
                    self.facilities,
                    self.config,
                    rect=self._rect if self._explicit_rect else None,
                )
            eng = self._mono
        res = eng.query(int(q_idx), k + 1, backend=backend)
        if eng is not self:  # mirror the sub-engine's work into our stats
            self.stats.n_queries += 1
            self.stats.t_filter_s += res.t_filter_s
            self.stats.t_verify_s += res.t_verify_s
        counts = np.asarray(res.counts, np.int32).copy()
        # self-hit correction: every point except q hits its own occluder
        # (q's occluder is excluded from the scene, so its count is already
        # "others")
        counts[np.arange(len(counts)) != q_idx] -= 1
        np.maximum(counts, 0, out=counts)
        mask = counts < k
        mask[q_idx] = False
        return RkNNResult(
            mask, counts, res.scene, res.t_filter_s, res.t_verify_s, res.backend
        )

    def stream(self, batches, k: int, *, backend: str | None = None):
        """Double-buffered batch stream: the host filter phase of batch
        ``i+1`` (scene builds + stacking, in a producer thread) overlaps the
        device dispatch of batch ``i``.  Yields ``(batch, masks[Q, N])``.

        Producer exceptions are re-raised in the consumer — the generator
        never hangs on a failed build.
        """
        b = get_backend(backend or self.config.backend)
        buf: "queue.Queue" = queue.Queue(maxsize=2)

        def producer():
            try:
                for batch in batches:
                    qs = list(batch)
                    t0 = time.perf_counter()
                    queries, q_pts, excludes = _normalize_queries(self.facilities, qs)
                    if b.uses_scene:
                        rect = self._rect_for(q_pts)
                        built = self._filter_batch(
                            b, queries, q_pts, excludes, k, rect,
                            self.config.scene_workers,
                        )
                    else:
                        req = BatchRequest(
                            xs=None,
                            ys=None,
                            k=k,
                            users=self.users,
                            facilities=self.facilities,
                            q_pts=q_pts,
                            excludes=excludes,
                        )
                        built = (req, None, None)
                    self.stats.t_filter_s += time.perf_counter() - t0
                    buf.put((batch, len(qs), built))
                buf.put(None)
            except BaseException as e:  # surface in the consumer, no deadlock
                buf.put(e)

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        while True:
            item = buf.get()
            if item is None:
                return
            if isinstance(item, BaseException):
                raise item
            batch, q_n, (req, prepared, scenes) = item
            t0 = time.perf_counter()
            counts = b.count_batch(req, prepared)
            self.stats.t_verify_s += time.perf_counter() - t0
            self.stats.n_queries += q_n
            self.stats.n_batches += 1
            if scenes:
                self.stats.m_max = max(
                    self.stats.m_max, max(s.n_tris for s in scenes)
                )
            yield batch, counts < k
