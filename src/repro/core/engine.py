"""Stateful RkNN query engine: build once, serve many query waves.

The paper's performance story is amortization — construct geometry once,
cast many rays (RT-kNNS Unbound and RTNN make the same point for RT-core
kNN: the wins come from reusing the built acceleration structure across
query batches).  :class:`RkNNEngine` is the long-lived object that state
hangs off:

* the shared domain :class:`~repro.core.geometry.Rect` and the device-
  resident user coordinate arrays (uploaded once, like the paper's
  "plain GPU transfer" of Table 2);
* a :class:`~repro.core.hybrid.SceneCache` so hot queries skip InfZone
  pruning + occluder construction entirely (cache hits show up directly
  as a collapsed ``t_filter_s``);
* a batch-level LRU of prepared backend state (stacked coeffs / stacked
  grid / stacked BVH), so a repeated query workload skips the whole host
  filter phase;
* persistent jitted dispatches: scene pads are bucketed to sticky powers
  of two, so repeat workloads re-enter the same XLA executable instead of
  re-tracing;
* an optional ``jax.sharding.Mesh`` — the dense-ref batch dispatch is then
  pjit'd with users sharded over the data axes and queries over
  ``'model'`` (the serving layout previously trapped in ``launch/serve``).

Verification backends are pluggable via :mod:`repro.core.backends`; the
legacy free functions (``rt_rknn_query`` etc.) are one-shot shims over a
throwaway engine.  Lifecycle, config knobs, and the migration table from
the free functions live in ``docs/API.md``.
"""

from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import math
import queue
import threading
import time
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.backends import (
    Backend,
    BatchRequest,
    QueryRequest,
    get_backend,
)
from repro.core.geometry import Rect
from repro.core.hybrid import SceneCache, _q_key
from repro.core.results import RkNNBatchResult, RkNNResult
from repro.core.scene import Scene, build_scene
from repro.core.snapshot import EngineSnapshot
from repro.obs import Histogram, MetricsRegistry, span, track_jit
from repro.planner.models import WorkloadShape

__all__ = ["RkNNConfig", "EngineStats", "RkNNEngine", "serve_shardings"]


def serve_shardings(mesh):
    """The serving partition layout: ``(user_sh, scene_sh, out_sh)``.

    Users sharded over the data-parallel axes, per-query scenes replicated
    (they are tiny — ~64 triangles · 36 B), queries sharded over
    ``'model'``.  Single source of truth for the engine's live dispatch
    and ``launch.serve.lower_rknn_serve``'s dry-run lowering.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.meshctx import dp_axes

    dp = dp_axes(mesh)
    dp_spec = dp if len(dp) > 1 else dp[0]
    user_sh = NamedSharding(mesh, P(dp_spec))
    scene_sh = NamedSharding(mesh, P("model", None, None, None))
    out_sh = NamedSharding(mesh, P("model", dp_spec))
    return user_sh, scene_sh, out_sh


@dataclasses.dataclass(frozen=True)
class RkNNConfig:
    """Construction-time knobs of :class:`RkNNEngine` (see docs/API.md).

    ``scene_cache`` / ``batch_cache`` are LRU capacities (0 disables).
    ``pad_scene_to`` seeds the sticky power-of-two triangle pad bucket;
    ``pad_to`` pins it exactly (overriding bucketing) when not ``None``.
    """

    backend: str = "dense-ref"
    strategy: str = "infzone"
    grid_g: int = 64
    prune_grid: int | None = None
    pad_to: int | None = None
    scene_workers: int = 0
    scene_cache: int = 256
    batch_cache: int = 8
    pad_scene_to: int = 128
    #: Feed the planner's observed-vs-predicted residuals back into the
    #: active profile's coefficients (damped; ``auto`` backend only).
    online_recalibration: bool = False
    #: Arm a :class:`repro.obs.FlightRecorder` at construction: any
    #: reader/writer exception (and sentinel trips) dumps a postmortem
    #: bundle under ``flight_dir``.
    flight_recorder: bool = False
    flight_dir: str = "flight"
    #: Warm-start from a ``rknn-store/1`` directory (:mod:`repro.persist`):
    #: at construction, every fingerprint-matching state category (scenes,
    #: indexes, kernel bucketing, shards, planner profile) is adopted into
    #: the fresh snapshot.  Best-effort — a missing or stale store leaves a
    #: fully functional cold engine.
    warm_store: str | None = None


class EngineStats:
    """The legacy cumulative-stats surface, as live **views** over the
    engine's :class:`~repro.obs.MetricsRegistry`.

    Every field that used to be a mutated dataclass attribute is now a
    property reading the underlying counters/gauges/histograms, so the
    public shape is unchanged while the same telemetry also carries full
    per-``(phase, backend, shard)`` distributions (``engine.metrics
    .snapshot()`` exposes those, including p50/p90/p99).

    The ``planner_*`` fields only move when queries route through the
    ``auto`` backend: per-backend dispatch counts and the running
    predicted-vs-observed cost totals (the planner's calibration error is
    ``planner_obs_s / planner_pred_s`` drifting from 1).

    The ``shard_*`` fields only move on a sharded engine
    (:class:`repro.shard.ShardedEngine`): cumulative per-shard filter
    (per-shard bucketing/stacking) and verify (per-shard dispatch) time,
    indexed by shard, and the lifetime imbalance ratio
    ``max(shard_verify) / mean(shard_verify)`` — 1.0 is perfectly
    balanced; clustered user distributions drift above it.

    ``events_dropped`` / ``continuous_pruned`` surface the dynamic
    engine's standing-query bookkeeping: events lost to saturated
    :class:`~repro.dynamic.continuous.ContinuousQuery` buffers and dead
    handles pruned on the update path.
    """

    def __init__(self, metrics: MetricsRegistry):
        self.metrics = metrics

    def _phase_sum(self, name: str, phase: str) -> float:
        return sum(
            h.sum
            for labels, h in self.metrics.find(name)
            if labels.get("phase") == phase
        )

    def _shard_list(self, phase: str) -> list[float]:
        per = {
            int(labels["shard"]): h.sum
            for labels, h in self.metrics.find("shard.phase_s")
            if labels.get("phase") == phase
        }
        if not per:
            return []
        return [per.get(i, 0.0) for i in range(max(per) + 1)]

    @property
    def n_queries(self) -> int:
        return self.metrics.counter("queries").value

    @property
    def n_batches(self) -> int:
        return self.metrics.counter("batches").value

    @property
    def t_filter_s(self) -> float:
        return self._phase_sum("phase_s", "filter")

    @property
    def t_verify_s(self) -> float:
        return self._phase_sum("phase_s", "verify")

    @property
    def m_max(self) -> int:
        return int(self.metrics.gauge("m_max").value)

    @property
    def batch_cache_hits(self) -> int:
        return self.metrics.counter("batch_cache.hits").value

    @property
    def planner_decisions(self) -> dict:
        return {
            labels["backend"]: c.value
            for labels, c in self.metrics.find("planner.decisions")
        }

    @property
    def planner_pred_s(self) -> float:
        return sum(
            h.sum
            for labels, h in self.metrics.find("planner.plan_s")
            if labels.get("kind") == "pred"
        )

    @property
    def planner_obs_s(self) -> float:
        return sum(
            h.sum
            for labels, h in self.metrics.find("planner.plan_s")
            if labels.get("kind") == "obs"
        )

    @property
    def planner_recal_nudges(self) -> int:
        return self.metrics.counter("planner.recal_nudges").value

    @property
    def shard_filter_s(self) -> list[float]:
        return self._shard_list("filter")

    @property
    def shard_verify_s(self) -> list[float]:
        return self._shard_list("verify")

    @property
    def shard_imbalance(self) -> float:
        found = self.metrics.find("shard.imbalance")
        return found[0][1].value if found else 1.0

    @property
    def events_dropped(self) -> int:
        return self.metrics.counter("continuous.events_dropped").value

    @property
    def continuous_pruned(self) -> int:
        return self.metrics.counter("continuous.pruned").value

    def __repr__(self) -> str:  # debugging parity with the old dataclass
        fields = (
            "n_queries", "n_batches", "t_filter_s", "t_verify_s", "m_max",
            "batch_cache_hits", "planner_decisions", "planner_pred_s",
            "planner_obs_s", "planner_recal_nudges", "shard_filter_s",
            "shard_verify_s", "shard_imbalance", "events_dropped",
            "continuous_pruned",
        )
        inner = ", ".join(f"{f}={getattr(self, f)!r}" for f in fields)
        return f"EngineStats({inner})"


def _next_pow2(n: int) -> int:
    return 1 << max(int(np.ceil(np.log2(max(n, 1)))), 0)


def _normalize_queries(
    facilities: np.ndarray, qs
) -> tuple[list[int | np.ndarray], np.ndarray, list[int | None]]:
    """Split a query batch into per-query build args, points, and excludes."""
    queries: list[int | np.ndarray] = []
    q_pts = np.zeros((len(qs), 2), np.float64)
    excludes: list[int | None] = []
    for i, q in enumerate(qs):
        arr = np.asarray(q)
        if arr.ndim == 0 and np.issubdtype(arr.dtype, np.integer):
            qi = int(arr)
            queries.append(qi)
            q_pts[i] = facilities[qi]
            excludes.append(qi)
        else:
            pt = np.asarray(q, np.float64).reshape(2)
            queries.append(pt)
            q_pts[i] = pt
            excludes.append(None)
    return queries, q_pts, excludes


class RkNNEngine:
    """Build once from ``(facilities, users, RkNNConfig)``; query many times.

    Exposes :meth:`query`, :meth:`query_batch`, :meth:`query_mono`, and
    :meth:`stream` (double-buffered host scene builds overlapping device
    dispatch).  Backend selection defaults to ``config.backend`` and can be
    overridden per call with any name in the backend registry.
    """

    def __init__(
        self,
        facilities: np.ndarray,
        users: np.ndarray,
        config: RkNNConfig | None = None,
        *,
        mesh=None,
        rect: Rect | None = None,
        **overrides,
    ):
        config = config or RkNNConfig()
        if overrides:
            config = dataclasses.replace(config, **overrides)
        get_backend(config.backend)  # validate eagerly
        self.config = config
        self.mesh = mesh
        self.metrics = MetricsRegistry()
        self.stats = EngineStats(self.metrics)
        self._init_metrics()
        self._snap = self._make_snapshot(
            0,
            np.asarray(facilities, dtype=np.float64),
            np.asarray(users, dtype=np.float64),
            rect=rect,
            explicit_rect=rect is not None,
        )
        self._pad_bucket = max(int(config.pad_scene_to), 1)
        #: Lock-free read-activity clock: query entry points bump it, the
        #: dynamic writer samples it to decide whether prewarm should run
        #: deprioritized.  Races just lose a tick — it is a heuristic, so
        #: no lock touches the read path.
        self._read_clock = 0
        self._mesh_steps: dict = {}  # (backend, statics) -> jitted dispatch
        self._plan_log: "collections.deque[dict]" = collections.deque(maxlen=128)
        #: Health layer (all optional, never on the hot path): a flight
        #: recorder armed by config, a lazily-built sentinel, any live
        #: introspection servers, and the device-bytes scrape memo.
        self.flight = None
        self._sentinel = None
        self._obs_servers: list = []
        self._devbytes_cache: tuple | None = None
        #: Last persist operation's report (:mod:`repro.persist`): store
        #: path, schema, and per-category restored/stale/absent statuses.
        self.persist_info: dict | None = None
        if config.flight_recorder:
            from repro.obs.flight import FlightRecorder

            self.flight = FlightRecorder(self, dir=config.flight_dir)
        if mesh is not None:
            self._init_mesh(self._snap, mesh)
        if config.warm_store:
            from repro.persist import warm_start

            warm_start(self, config.warm_store)

    def _make_snapshot(
        self,
        version: int,
        facilities: np.ndarray,
        users: np.ndarray,
        *,
        rect: Rect | None = None,
        explicit_rect: bool = False,
        scene_cache: SceneCache | None | str = "new",
    ) -> EngineSnapshot:
        """A fresh :class:`EngineSnapshot` sized from the engine config.
        ``scene_cache="new"`` allocates one (respecting the capacity
        knob); the COW update path passes its migrated cache instead."""
        if scene_cache == "new":
            scene_cache = (
                SceneCache(capacity=self.config.scene_cache)
                if self.config.scene_cache > 0
                else None
            )
        return EngineSnapshot(
            version,
            facilities,
            users,
            rect=rect,
            explicit_rect=explicit_rect,
            scene_cache=scene_cache,
            batch_capacity=self.config.batch_cache,
        )

    # ------------------------------------------------------------------
    # observability (the engine's metrics registry; EngineStats is a view)
    # ------------------------------------------------------------------
    def _init_metrics(self) -> None:
        """Eager scalar metrics + derived gauges.  Per-(phase, backend)
        histograms are created lazily through the handle cache so the
        steady-state query cost is one dict hit + one observe."""
        m = self.metrics
        self._m_queries = m.counter("queries")
        self._m_batches = m.counter("batches")
        self._m_cache_hits = m.counter("batch_cache.hits")
        self._m_mmax = m.gauge("m_max")
        self._m_lag = m.gauge("mvcc.version_lag")
        self._m_pred = m.histogram("planner.plan_s", kind="pred")
        self._m_obs = m.histogram("planner.plan_s", kind="obs")
        self._m_nudges = m.counter("planner.recal_nudges")
        self._metric_cache: dict = {}
        m.derived("scene_cache.hit_ratio", self._scene_cache_hit_ratio)
        m.derived("batch_cache.hit_ratio", self._batch_cache_hit_ratio)
        m.derived("mvcc.version", lambda: float(self._snap.version))
        m.derived("pad_waste", self._pad_waste_ratio)
        # Device-memory accounting of the *served* snapshot version, by
        # category (evaluated only at scrape/snapshot time; one memoized
        # walk serves all categories — see _device_bytes_cached).
        for cat in ("users", "shards", "indexes", "kernel", "batches",
                    "scenes", "total"):
            m.derived(
                "mem.bytes",
                (lambda cat=cat: float(
                    self._device_bytes_cached(self._snap).get(cat, 0)
                )),
                category=cat,
            )

    def _scene_cache_hit_ratio(self) -> float | None:
        sc = self._snap.scene_cache
        if sc is None:
            return None
        total = sc.hits + sc.misses
        return sc.hits / total if total else None

    def _batch_cache_hit_ratio(self) -> float | None:
        n = self._m_batches.value
        return self._m_cache_hits.value / n if n else None

    def _pad_waste_ratio(self) -> float | None:
        try:
            return float(self._snap.pad_waste(self._snap.rect, self.config.grid_g))
        except Exception:
            return None

    # ------------------------------------------------------------------
    # persistence (repro.persist — versioned warm-start state store)
    # ------------------------------------------------------------------
    def save_state(self, directory: str, *, keep: int = 3) -> str:
        """Export the served snapshot's amortized state (scenes, packed
        indexes, kernel bucketing, planner profile, shard partition) as
        the next ``rknn-store/1`` step under ``directory``.  Atomic:
        readers of the store always see a complete step.  Returns the
        published step folder."""
        from repro.persist import save_engine_state

        return save_engine_state(self, directory, keep=keep)

    def restore(self, directory: str) -> dict:
        """Hot-adopt a ``rknn-store/1`` store into this **live** engine:
        builds a snapshot around the store's dataset, adopts every
        fingerprint-matching category, and publishes it as MVCC version
        N+1 via the atomic swap — in-flight readers keep serving N.
        Returns the per-category status report (also on
        ``self.persist_info``)."""
        from repro.persist import restore_engine

        return restore_engine(self, directory)

    def _persist_note(self, op: str, category: str, nbytes: int, seconds) -> None:
        """Record one category's persist traffic (registry dedupes by
        label, so these are stable per-category instruments)."""
        self.metrics.gauge("persist.bytes", category=category, op=op).set(
            float(nbytes)
        )
        if seconds is not None:
            self.metrics.histogram(f"persist.{op}_s", category=category).observe(
                float(seconds)
            )

    def _persist_extra_fingerprints(self, snap: EngineSnapshot) -> dict:
        """Subclass hook: expected fingerprints for engine-specific
        categories (ShardedEngine adds ``shards``)."""
        return {}

    def _persist_extra_categories(self, snap: EngineSnapshot) -> dict:
        """Subclass hook: extra ``{name: {fingerprint, meta, arrays}}``
        categories to persist."""
        return {}

    def _persist_adopt_extra(self, snap: EngineSnapshot, name: str, entry, arrays):
        """Subclass hook: adopt one engine-specific category (fingerprint
        already matched).  Return the adopted item count, or ``None`` if
        the category is not recognized."""
        return None

    def _phase_hist(self, phase: str, backend: str) -> Histogram:
        key = (phase, backend)
        h = self._metric_cache.get(key)
        if h is None:
            h = self._metric_cache[key] = self.metrics.histogram(
                "phase_s", phase=phase, backend=backend
            )
        return h

    def _decision_counter(self, backend: str):
        key = ("dec", backend)
        c = self._metric_cache.get(key)
        if c is None:
            c = self._metric_cache[key] = self.metrics.counter(
                "planner.decisions", backend=backend
            )
        return c

    def _residual_hist(self, backend: str) -> Histogram:
        key = ("res", backend)
        h = self._metric_cache.get(key)
        if h is None:
            h = self._metric_cache[key] = self.metrics.histogram(
                "planner.residual", signed=True, backend=backend
            )
        return h

    # ------------------------------------------------------------------
    # health layer (live introspection, SLO sentinel, flight recorder)
    # ------------------------------------------------------------------
    def serve_obs(self, port: int = 0, host: str = "127.0.0.1"):
        """Boot the live introspection endpoint for this engine
        (``/metrics``, ``/spans``, ``/explain``, ``/snapshot``,
        ``/healthz``) on a daemon thread.  ``port=0`` binds an ephemeral
        port — read it back from the returned server's ``.port``/``.url``.
        Read-only and lock-free; see :mod:`repro.obs.health.server`."""
        from repro.obs.health import ObsServer

        srv = ObsServer(self, port=port, host=host)
        self._obs_servers.append(srv)
        return srv

    @property
    def sentinel(self):
        """The engine's SLO sentinel (built on first touch with the
        default rule families — see :func:`repro.obs.engine_rules`).
        Drives ``/healthz``; a sustained breach dumps a flight bundle
        when a recorder is armed."""
        s = self._sentinel
        if s is None:
            from repro.obs.sentinel import Sentinel, engine_rules

            rules, discover = engine_rules(self)

            def on_trip(st) -> None:
                fr = self.flight
                if fr is not None:
                    fr.dump(f"slo:{st.rule.name}")

            # benign first-touch race: two racing builders produce
            # equivalent sentinels, last assignment wins
            s = self._sentinel = Sentinel(
                rules, on_trip=on_trip, discover=discover
            )
        return s

    def _flight_exception(self, where: str, exc: BaseException) -> None:
        """Dump a postmortem bundle when a recorder is armed (never
        raises; never runs when flight is off — the common case costs
        one attribute read on the exception path only)."""
        fr = self.flight
        if fr is not None:
            fr.record_exception(where, exc)

    def _device_bytes_cached(self, snap: EngineSnapshot) -> dict[str, int]:
        """Memoized :meth:`EngineSnapshot.device_bytes` — one walk per
        snapshot version per ~250ms, so a scrape hitting all seven
        ``mem.bytes`` gauges (or `/snapshot` plus `/metrics`) pays once."""
        now = time.monotonic()
        hit = self._devbytes_cache
        if hit is not None and hit[0] is snap and now - hit[1] < 0.25:
            return hit[2]
        out = snap.device_bytes()
        self._devbytes_cache = (snap, now, out)
        return out

    # ------------------------------------------------------------------
    # snapshot delegation (compat surface; query paths resolve _snap once)
    # ------------------------------------------------------------------
    @property
    def facilities(self) -> np.ndarray:
        return self._snap.facilities

    @property
    def users(self) -> np.ndarray:
        return self._snap.users

    @property
    def scene_cache(self) -> SceneCache | None:
        return self._snap.scene_cache

    @property
    def rect(self) -> Rect:
        """The shared domain rectangle (facilities ∪ users, padded)."""
        return self._snap.rect

    @property
    def xs(self) -> jnp.ndarray:
        return self._snap.xs

    @property
    def ys(self) -> jnp.ndarray:
        return self._snap.ys

    def _hull_bounds(self) -> tuple[np.ndarray, np.ndarray]:
        return self._snap.hull_bounds()

    def _fingerprint(self) -> int:
        return self._snap.fingerprint()

    def _rect_for(self, snap: EngineSnapshot, q_pts: np.ndarray) -> Rect:
        """Snapshot rect, extended only when a query point falls outside
        the facility∪user hull (keeps one-shot shims bit-compatible with
        the old per-call ``Rect.from_points(F, q, U)``)."""
        if snap.explicit_rect:
            return snap.rect
        lo, hi = snap.hull_bounds()
        if np.all(q_pts >= lo) and np.all(q_pts <= hi):
            return snap.rect
        return Rect.from_points(snap.facilities, q_pts, snap.users)

    # ------------------------------------------------------------------
    # mesh-sharded batch dispatches (absorbed from launch/serve.py)
    # ------------------------------------------------------------------
    def _init_mesh(self, snap: EngineSnapshot, mesh) -> None:
        """Upload the snapshot's (DP-padded) user coordinates, sharded over
        the data axes; per-backend jitted dispatches are built lazily (the
        jitted steps are version-independent and stay on the engine)."""
        from repro.distributed.meshctx import dp_axes

        dp = dp_axes(mesh)
        user_sh, _scene_sh, _out_sh = serve_shardings(mesh)
        xs = snap.users[:, 0].astype(np.float32)
        ys = snap.users[:, 1].astype(np.float32)
        n = len(xs)
        dpn = int(np.prod([mesh.shape[a] for a in dp]))
        padn = (-n) % dpn
        if padn:  # sentinel users far outside every scene; sliced off below
            xs = np.concatenate([xs, np.full(padn, 2e9, np.float32)])
            ys = np.concatenate([ys, np.full(padn, 2e9, np.float32)])
        snap.mesh_xs = jax.device_put(xs, user_sh)
        snap.mesh_ys = jax.device_put(ys, user_sh)
        snap.mesh_n = n

    def _mesh_q_sharding(self, ndim: int):
        """NamedSharding for a per-query stacked array: queries over
        ``'model'``, trailing dims replicated."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        return NamedSharding(self.mesh, P("model", *([None] * (ndim - 1))))

    def _mesh_dispatch_for(
        self, snap: EngineSnapshot, backend: Backend, *, rect: Rect, k: int
    ):
        """Engine-held device-dispatch override for ``count_batch``.

        The dense-ref, grid, and bvh batched paths all shard the same way
        (users over the data axes, queries over ``'model'``; the per-query
        stacked index state is tiny).  The jitted step is cached per
        backend and per the statics its math closes over — the domain rect
        and G for the grid, ``k`` for the bvh early exit — while the
        returned dispatch closure captures the *snapshot's* sharded user
        arrays, so steps survive updates and only the cheap closure is
        rebuilt per version.  ``dense`` (interpret-mode Pallas) and
        ``brute`` stay single-device.
        Returns ``dispatch(prepared) -> [Q, N] np.int32`` or ``None``.
        """
        if self.mesh is None or snap.mesh_xs is None:
            return None
        user_sh, _scene_sh, out_sh = serve_shardings(self.mesh)
        mesh_xs, mesh_ys, n = snap.mesh_xs, snap.mesh_ys, snap.mesh_n

        if backend.name == "dense-ref":
            key = ("dense-ref",)
            step = self._mesh_steps.get(key)
            if step is None:
                from repro.kernels.ref import raycast_count_batch_ref

                step = track_jit(
                    jax.jit(
                        raycast_count_batch_ref,
                        in_shardings=(user_sh, user_sh, self._mesh_q_sharding(4)),
                        out_shardings=out_sh,
                    ),
                    "mesh.dense-ref",
                )
                self._mesh_steps[key] = step
            return lambda prepared: np.asarray(
                step(mesh_xs, mesh_ys, jnp.asarray(prepared))
            )[:, :n]

        if backend.name == "grid":
            from repro.core.grid import grid_hit_counts_batch_jnp

            # the grid math closes over the domain rect; only the
            # snapshot's shared rect gets a cached sharded step.  A
            # transient rect (out-of-hull point query) would mean one XLA
            # compile per batch and an ever-growing step cache — fall back
            # to the single-device dispatch for those instead.  The rect
            # participates in the key (updates can move the hull), capped
            # like the bvh k-cache below.
            if rect != snap.rect:
                return None
            key = ("grid", self.config.grid_g, rect)
            step = self._mesh_steps.get(key)
            if step is None:
                if sum(1 for kk in self._mesh_steps if kk[0] == "grid") >= 16:
                    return None  # pathological rect churn: stop compiling
                G = self.config.grid_g

                def _grid_fn(xs, ys, base, lists, coeffs, rect=rect, G=G):
                    return grid_hit_counts_batch_jnp(
                        xs, ys, base, lists, coeffs, rect, G
                    )

                step = track_jit(
                    jax.jit(
                        _grid_fn,
                        in_shardings=(
                            user_sh,
                            user_sh,
                            self._mesh_q_sharding(2),
                            self._mesh_q_sharding(3),
                            self._mesh_q_sharding(4),
                        ),
                        out_shardings=out_sh,
                    ),
                    "mesh.grid",
                )
                self._mesh_steps[key] = step
            return lambda prepared: np.asarray(
                step(mesh_xs, mesh_ys, *(jnp.asarray(p) for p in prepared))
            )[:, :n]

        if backend.name == "bvh":
            from repro.core.bvh import bvh_hit_counts_batch

            key = ("bvh", k)
            step = self._mesh_steps.get(key)
            if step is None:
                if sum(1 for kk in self._mesh_steps if kk[0] == "bvh") >= 16:
                    return None  # pathological many-k workload: stop compiling

                def _bvh_fn(xs, ys, left, right, bbox, coeffs, k=k):
                    return bvh_hit_counts_batch(
                        xs, ys, left, right, bbox, coeffs, k=k
                    )

                step = track_jit(
                    jax.jit(
                        _bvh_fn,
                        in_shardings=(
                            user_sh,
                            user_sh,
                            self._mesh_q_sharding(2),
                            self._mesh_q_sharding(2),
                            self._mesh_q_sharding(3),
                            self._mesh_q_sharding(4),
                        ),
                        out_shardings=out_sh,
                    ),
                    "mesh.bvh",
                )
                self._mesh_steps[key] = step
            return lambda prepared: np.asarray(
                step(mesh_xs, mesh_ys, *(jnp.asarray(p) for p in prepared))
            )[:, :n]

        return None

    # ------------------------------------------------------------------
    # filter phase helpers (host)
    # ------------------------------------------------------------------
    def _build_scene(
        self, snap: EngineSnapshot, q, k: int, rect: Rect, *, pad_to: int | None = None
    ):
        if snap.scene_cache is not None and pad_to is None:
            scene, _hit = snap.scene_cache.get_or_build(
                snap.facilities,
                q,
                k,
                rect,
                fp=snap.fingerprint(),
                strategy=self.config.strategy,
                grid=self.config.prune_grid,
                users_hint=snap.users,
            )
            return scene
        return build_scene(
            snap.facilities,
            q,
            k,
            rect,
            strategy=self.config.strategy,
            grid=self.config.prune_grid,
            pad_to=pad_to,
            users_hint=snap.users,
        )

    def _index_for(self, snap: EngineSnapshot, backend: Backend, scene: Scene) -> Any:
        """Per-scene index from the snapshot's memo, so cached scenes carry
        their grid/BVH across repeated queries (and across updates, via
        the COW migration)."""
        store = snap.index_memo.store_for(scene)
        key = (backend.name, self.config.grid_g)
        if key not in store:
            # the backend's own build memo shares the store: grid and
            # grid-pallas dedupe their underlying grid build through it
            store[key] = backend.build_index(
                scene, grid_g=self.config.grid_g, memo=store
            )
        return store[key]

    def _workload_shards(self) -> int:
        """Shard count the planner prices workloads at (the ``log_s``
        feature).  1 on single-process engines; ``ShardedEngine``
        overrides with its mesh size."""
        return 1

    def _prepare_batch(self, backend: Backend, req: BatchRequest):
        """Backend stacking for one batch, honoring a dispatch that owns
        its own prepare step (``req.dispatch.prepare``): the sharded
        dispatch builds *per-shard* prepared state (cell buckets, lane-
        compacted planes) that the plain ``Backend.prepare_batch`` —
        which sees no partition — cannot."""
        prep = getattr(req.dispatch, "prepare", None)
        if prep is not None:
            return prep(backend, req)
        return backend.prepare_batch(req)

    def _batch_cache_get(self, snap: EngineSnapshot, key):
        """Prepared-batch lookup (None key → miss); counts a hit in the
        stats.  Lock-free — see :class:`~repro.core.snapshot.LruCache`."""
        if key is None:
            return None
        hit = snap.batch_cache.get(key)
        if hit is not None:
            self._m_cache_hits.inc()
        return hit

    def _batch_cache_put(self, snap: EngineSnapshot, key, value) -> None:
        if key is None:
            return
        snap.batch_cache.put(key, value)

    def _build_scenes(
        self, snap: EngineSnapshot, queries: list, k: int, rect: Rect, workers: int
    ):
        """Cache-aware host scene builds, optionally thread-pooled."""

        def one(q):
            return self._build_scene(snap, q, k, rect)

        if workers > 0 and len(queries) > 1:
            with concurrent.futures.ThreadPoolExecutor(workers) as pool:
                return list(pool.map(one, queries))
        return [one(q) for q in queries]

    def _mp_bucket(self, scenes: list[Scene]) -> int:
        if self.config.pad_to is not None:
            return self.config.pad_to
        mmax = max(s.tris.shape[0] for s in scenes)
        # lock-free monotone max: concurrent batches may briefly lose an
        # update, costing at most one extra retrace — never a wrong pad
        bucket = max(self._pad_bucket, _next_pow2(mmax))
        self._pad_bucket = bucket
        return bucket

    def _filter_batch(
        self,
        snap: EngineSnapshot,
        backend: Backend,
        queries: list,
        q_pts: np.ndarray,
        excludes: list,
        k: int,
        rect: Rect,
        scene_workers: int,
    ) -> tuple[BatchRequest, Any, list[Scene]]:
        """Host filter phase for one batch: scenes + stacked backend state,
        LRU-cached by (backend, k, queries, rect) so a repeated workload
        collapses to a dictionary lookup."""
        cache_key = None
        if self.config.batch_cache > 0:
            cache_key = (
                backend.name,
                k,
                tuple(_q_key(q) for q in queries),
                rect,
            )
            hit = self._batch_cache_get(snap, cache_key)
            if hit is not None:
                req, prepared, scenes = hit
                return req, prepared, scenes

        scenes = self._build_scenes(snap, queries, k, rect, scene_workers)
        dispatch = self._mesh_dispatch_for(snap, backend, rect=rect, k=k)
        # the mesh dispatch closes over its own sharded user arrays — don't
        # materialize a second, replicated device copy it would never read
        req = BatchRequest(
            xs=None if dispatch is not None else snap.xs,
            ys=None if dispatch is not None else snap.ys,
            k=k,
            rect=rect,
            grid_g=self.config.grid_g,
            scenes=scenes,
            # per-scene index memo: scene-cache hits reuse their grid/BVH
            # instead of rebuilding it on every new batch composition
            indexes=[self._index_for(snap, backend, s) for s in scenes],
            users=snap.users,
            facilities=snap.facilities,
            q_pts=q_pts,
            excludes=excludes,
            mp=self._mp_bucket(scenes),
            dispatch=dispatch,
            memo=snap.kernel_memo,
        )
        prepared = self._prepare_batch(backend, req)
        self._batch_cache_put(snap, cache_key, (req, prepared, scenes))
        return req, prepared, scenes

    # ------------------------------------------------------------------
    # planner (the "auto" meta-backend)
    # ------------------------------------------------------------------
    def _scene_cached(self, snap: EngineSnapshot, q, k: int, rect: Rect) -> bool:
        if snap.scene_cache is None:
            return False
        return snap.scene_cache.contains(
            snap.facilities, q, k, rect, fp=snap.fingerprint()
        )

    def _record_plan(self, planner, plan: dict, observed_s: float) -> None:
        """Close out one plan: observed cost, engine log, metrics, planner.

        ``observed_s`` comes from the query path's spans (filter + verify
        elapsed), so the planner's recalibration signal and the exported
        trace are the same measurement.  Per-dispatched-backend log-
        residuals ``log(obs/pred)`` land in signed histograms — the drift
        gate's raw material."""
        plan["observed_s"] = observed_s
        self._plan_log.append(plan)
        for name, n in plan.get("decisions", {}).items():
            self._decision_counter(name).inc(n)
        self._m_pred.observe(plan.get("predicted_s", 0.0))
        self._m_obs.observe(observed_s)
        planner.record(plan)
        for name, pred, obs, _verify_only in planner._pred_obs_pairs(plan):
            if pred > 0.0 and obs > 0.0:
                self._residual_hist(name).observe(math.log(obs / pred))
        if self.config.online_recalibration:
            self._m_nudges.inc(planner.observe(plan))

    def explain(self) -> list[dict]:
        """Recent ``auto`` plans, oldest first: each entry carries the
        chosen backend(s), predicted cost, candidate costs, and — once the
        dispatch ran — observed cost."""
        return list(self._plan_log)

    def _plan_amortized(self, snap: EngineSnapshot) -> bool:
        """Whether the planner prices geometric backends at steady-state
        (verify-only) cost.  True on engines with a scene cache: they are
        long-lived serving objects, so a scene build is an *investment*
        the cache repays on every repeat — the planner should pick the
        backend that is cheapest once hot, not the one that is cheapest
        for exactly one cold call.  One-shot shims disable the cache and
        get the strict per-call comparison.
        """
        return snap.scene_cache is not None

    def _plan_single(
        self, snap: EngineSnapshot, planner, q_build, k: int, q_pt: np.ndarray
    ):
        """Pre-scene routing of one query.  Returns (backend, plan)."""
        rect = self._rect_for(snap, q_pt[None])
        amortized = self._plan_amortized(snap)
        shape = WorkloadShape(
            len(snap.facilities),
            len(snap.users),
            k,
            1,
            cache_hit=amortized or self._scene_cached(snap, q_build, k, rect),
            pad_waste=snap.pad_waste(rect, self.config.grid_g),
            shards=self._workload_shards(),
        )
        choice, pred, costs = planner.select(shape)
        plan = {
            "mode": "single",
            "backend": choice,
            "predicted_s": pred,
            "candidates": costs,
            "cache_hit": shape.cache_hit,
            "amortized": amortized,
            "decisions": {choice: 1},
        }
        return get_backend(choice), plan

    # ------------------------------------------------------------------
    # public query surface
    # ------------------------------------------------------------------
    def query(self, q, k: int, *, backend: str | None = None) -> RkNNResult:
        """Bichromatic RkNN of one query (facility index or ``[2]`` point).

        With the ``auto`` backend the planner picks the concrete backend
        *before* any scene is built (a brute decision skips the filter
        phase entirely); the result's ``backend`` field reports the
        concrete choice and :meth:`explain` the full plan.
        """
        self._read_clock += 1
        try:
            return self._query(self._snap, q, k, backend=backend)
        except Exception as e:
            self._flight_exception("query", e)
            raise

    def _query(
        self, snap: EngineSnapshot, q, k: int, *, backend: str | None = None
    ) -> RkNNResult:
        b = get_backend(backend or self.config.backend)
        arr = np.asarray(q)
        if arr.ndim == 0 and np.issubdtype(arr.dtype, np.integer):
            q_build: int | np.ndarray = int(arr)
            q_pt, exclude = snap.facilities[int(arr)], int(arr)
        else:
            q_pt = np.asarray(q, np.float64).reshape(2)
            q_build, exclude = q_pt, None

        plan = planner = None
        if b.is_meta:
            planner = b
            b, plan = self._plan_single(snap, planner, q_build, k, q_pt)

        if not b.uses_scene:
            # geometry-free: never materialize the device user arrays
            with span("query", backend=b.name, version=snap.version):
                with span("verify", backend=b.name) as sv:
                    counts = b.count(
                        QueryRequest(
                            xs=None,
                            ys=None,
                            k=k,
                            users=snap.users,
                            facilities=snap.facilities,
                            q_pt=q_pt,
                            exclude=exclude,
                        )
                    )
            t_verify = sv.elapsed_s
            self._m_queries.inc()
            self._phase_hist("verify", b.name).observe(t_verify)
            self._m_lag.set(float(self._snap.version - snap.version))
            if plan is not None:
                self._record_plan(planner, plan, t_verify)
            return RkNNResult(
                counts < k, counts, None, 0.0, t_verify, b.name, snap.version
            )

        with span("query", backend=b.name, version=snap.version):
            with span("filter", backend=b.name) as sf:
                rect = self._rect_for(snap, q_pt[None])
                scene = self._build_scene(
                    snap, q_build, k, rect, pad_to=self.config.pad_to
                )
                index = self._index_for(snap, b, scene)
            with span("verify", backend=b.name) as sv:
                counts = b.count(
                    QueryRequest(
                        xs=snap.xs,
                        ys=snap.ys,
                        k=k,
                        grid_g=self.config.grid_g,
                        scene=scene,
                        index=index,
                        memo=snap.kernel_memo,
                    )
                )
        t_filter, t_verify = sf.elapsed_s, sv.elapsed_s
        self._m_queries.inc()
        self._phase_hist("filter", b.name).observe(t_filter)
        self._phase_hist("verify", b.name).observe(t_verify)
        self._m_mmax.set_max(scene.n_tris)
        self._m_lag.set(float(self._snap.version - snap.version))
        if plan is not None:
            self._record_plan(planner, plan, t_filter + t_verify)
        return RkNNResult(
            counts < k, counts, scene, t_filter, t_verify, b.name, snap.version
        )

    def query_batch(
        self,
        qs,
        k: int,
        *,
        backend: str | None = None,
        scene_workers: int | None = None,
    ) -> RkNNBatchResult:
        """Batched bichromatic RkNN: all of ``qs`` against the shared users.

        One host filter phase (scene builds — cache-aware — plus backend
        stacking) and ONE batched device dispatch.  Masks are bit-identical
        to looping :meth:`query` per query (equivalence-tested across all
        backends).
        """
        self._read_clock += 1
        try:
            return self._query_batch(
                self._snap, qs, k, backend=backend, scene_workers=scene_workers
            )
        except Exception as e:
            self._flight_exception("query_batch", e)
            raise

    def _query_batch(
        self,
        snap: EngineSnapshot,
        qs,
        k: int,
        *,
        backend: str | None = None,
        scene_workers: int | None = None,
    ) -> RkNNBatchResult:
        b = get_backend(backend or self.config.backend)
        workers = (
            self.config.scene_workers if scene_workers is None else scene_workers
        )
        qs = list(qs)
        n_users = len(snap.users)
        if not qs:
            return RkNNBatchResult(
                masks=np.zeros((0, n_users), bool),
                counts=np.zeros((0, n_users), np.int32),
                scenes=None if not b.uses_scene else [],
                t_filter_s=0.0,
                t_verify_s=0.0,
                backend=b.name,
                k=k,
                version=snap.version,
            )
        if b.is_meta:
            return self._query_batch_planner(snap, b, qs, k, workers)
        queries, q_pts, excludes = _normalize_queries(snap.facilities, qs)

        if not b.uses_scene:
            with span("batch", backend=b.name, q=len(qs), version=snap.version):
                with span("verify", backend=b.name) as sv:
                    counts = b.count_batch(
                        BatchRequest(
                            xs=None,
                            ys=None,
                            k=k,
                            users=snap.users,
                            facilities=snap.facilities,
                            q_pts=q_pts,
                            excludes=excludes,
                        ),
                        None,
                    )
            t_verify = sv.elapsed_s
            self._m_queries.inc(len(qs))
            self._m_batches.inc()
            self._phase_hist("verify", b.name).observe(t_verify)
            self._m_lag.set(float(self._snap.version - snap.version))
            return RkNNBatchResult(
                counts < k, counts, None, 0.0, t_verify, b.name, k, snap.version
            )

        with span("batch", backend=b.name, q=len(qs), version=snap.version):
            with span("filter", backend=b.name) as sf:
                rect = self._rect_for(snap, q_pts)
                req, prepared, scenes = self._filter_batch(
                    snap, b, queries, q_pts, excludes, k, rect, workers
                )
            with span("verify", backend=b.name) as sv:
                counts = b.count_batch(req, prepared)
        t_filter, t_verify = sf.elapsed_s, sv.elapsed_s
        self._m_queries.inc(len(qs))
        self._m_batches.inc()
        self._phase_hist("filter", b.name).observe(t_filter)
        self._phase_hist("verify", b.name).observe(t_verify)
        self._m_mmax.set_max(max(s.n_tris for s in scenes))
        self._m_lag.set(float(self._snap.version - snap.version))
        return RkNNBatchResult(
            counts < k, counts, scenes, t_filter, t_verify, b.name, k, snap.version
        )

    def _dispatch_group(
        self,
        snap: EngineSnapshot,
        b: Backend,
        idxs: list[int],
        scenes: list[Scene] | None,
        q_pts: np.ndarray,
        excludes: list,
        k: int,
        rect: Rect | None,
    ) -> tuple[np.ndarray, float, float]:
        """Prepare + count one planner group.  Returns ``(counts [|idxs|, N],
        t_prepare_s, t_count_s)`` — prepare is host (filter), count device
        (verify).  Prepared geometric groups are LRU-cached alongside the
        fixed-backend batches, so a repeated ``auto`` workload skips the
        re-stacking just like a repeated fixed-backend one.
        """
        sf = span("filter", backend=b.name, group=1)
        with sf:
            if not b.uses_scene:
                req = BatchRequest(
                    xs=None,
                    ys=None,
                    k=k,
                    users=snap.users,
                    facilities=snap.facilities,
                    q_pts=q_pts[idxs],
                    excludes=[excludes[i] for i in idxs],
                )
                prepared = None
            else:
                cache_key = None
                if self.config.batch_cache > 0:
                    # excludes participate in the key: a facility-index query
                    # (exclude=i) and a point query at that facility's exact
                    # coordinates (exclude=None) build different scenes
                    cache_key = (
                        "auto",
                        b.name,
                        k,
                        tuple((_q_key(q_pts[i]), excludes[i]) for i in idxs),
                        rect,
                    )
                    hit = self._batch_cache_get(snap, cache_key)
                    if hit is not None:
                        req, prepared, _sub = hit
                        sf.__exit__(None, None, None)
                        with span("verify", backend=b.name, group=1) as sv:
                            counts = b.count_batch(req, prepared)
                        return np.asarray(counts), sf.elapsed_s, sv.elapsed_s
                sub = [scenes[i] for i in idxs]
                dispatch = self._mesh_dispatch_for(snap, b, rect=rect, k=k)
                req = BatchRequest(
                    xs=None if dispatch is not None else snap.xs,
                    ys=None if dispatch is not None else snap.ys,
                    k=k,
                    rect=rect,
                    grid_g=self.config.grid_g,
                    scenes=sub,
                    indexes=[self._index_for(snap, b, s) for s in sub],
                    users=snap.users,
                    facilities=snap.facilities,
                    q_pts=q_pts[idxs],
                    excludes=[excludes[i] for i in idxs],
                    mp=self._mp_bucket(sub),
                    dispatch=dispatch,
                    memo=snap.kernel_memo,
                )
                prepared = self._prepare_batch(b, req)
                self._batch_cache_put(snap, cache_key, (req, prepared, sub))
        with span("verify", backend=b.name, group=1) as sv:
            counts = b.count_batch(req, prepared)
        return np.asarray(counts), sf.elapsed_s, sv.elapsed_s

    def _query_batch_planner(
        self, snap: EngineSnapshot, planner, qs: list, k: int, workers: int
    ) -> RkNNBatchResult:
        """The ``auto`` batched path: price, (maybe) filter, split, recombine.

        Two-stage decision:

        1. *Pre-scene*: the whole batch is priced with the estimated scene
           size.  If brute wins outright, no scene is ever built.
        2. *Post-scene*: scenes are built (cache-aware), each query is
           re-priced with its **actual** triangle count (filter cost now
           sunk → ``cache_hit=True``), and the batch is partitioned into
           per-backend groups dispatched independently; counts recombine
           in query order.  Count *semantics* may differ per row (bvh
           saturates at ``k``, brute counts distance ranks) — masks are
           the invariant, as everywhere else.

        The whole decision (assignments + scenes) is memoized in the batch
        LRU: a repeated workload goes straight to its group dispatches
        (which hit their own prepared-group LRU) without re-planning.
        """
        queries, q_pts, excludes = _normalize_queries(snap.facilities, qs)
        n_f, n_u, q_n = len(snap.facilities), len(snap.users), len(qs)
        sb = span("batch", backend="auto", q=q_n, version=snap.version)
        with sb:
            counts, plan, per_q, groups, scenes, t_count_total = (
                self._plan_and_dispatch(
                    snap, planner, queries, q_pts, excludes, k, rect_workers=workers,
                    n_f=n_f, n_u=n_u, q_n=q_n,
                )
            )
        # filter = everything in the batch wall that was not a group's
        # device count dispatch (planning, scene builds, group stacking) —
        # same accounting as the old inline perf_counter arithmetic
        t_filter = sb.elapsed_s - t_count_total

        self._m_queries.inc(q_n)
        self._m_batches.inc()
        self._phase_hist("filter", "auto").observe(t_filter)
        self._m_lag.set(float(self._snap.version - snap.version))
        if scenes:
            self._m_mmax.set_max(max(s.n_tris for s in scenes))
        self._record_plan(planner, plan, sb.elapsed_s)
        return RkNNBatchResult(
            counts < k,
            counts,
            scenes,
            t_filter,
            t_count_total,
            "auto",
            k,
            snap.version,
        )

    def _plan_and_dispatch(
        self, snap, planner, queries, q_pts, excludes, k,
        *, rect_workers, n_f, n_u, q_n,
    ):
        """Body of the ``auto`` batch (inside its ``batch`` span): plan
        (or reuse a memoized decision), build scenes, dispatch groups."""
        workers = rect_workers
        rect = self._rect_for(snap, q_pts)
        pad_w = snap.pad_waste(rect, self.config.grid_g)

        plan_key = cached_decision = None
        if self.config.batch_cache > 0:
            from repro.planner.profiles import profile_epoch

            # the epoch invalidates memoized decisions when the operator
            # activates a new (re)calibrated profile
            plan_key = (
                "auto-plan",
                profile_epoch(),
                k,
                tuple(_q_key(q) for q in queries),
                rect,
            )
            cached_decision = self._batch_cache_get(snap, plan_key)

        if cached_decision is not None:
            per_q, groups, scenes = cached_decision
            plan: dict = {
                "mode": "batch",
                "predicted_s": sum(cost for _, cost in per_q),
                "plan_cache_hit": True,
                "k": k,
                "q": q_n,
            }
        else:
            # price geometric backends at verify-only cost when the filter
            # phase is already amortized (scenes cached) — or *will* be (see
            # _plan_amortized: a cache-carrying engine invests in scene
            # builds because every repeat of a hot query rides them for free)
            amortized = self._plan_amortized(snap) or all(
                self._scene_cached(snap, q, k, rect) for q in queries
            )
            batch_shape = WorkloadShape(
                n_f, n_u, k, q_n, cache_hit=amortized, pad_waste=pad_w,
                shards=self._workload_shards(),
            )
            ranked = planner.rank(batch_shape)
            plan = {
                "mode": "batch",
                "predicted_s": ranked[0][1],
                "candidates": dict(ranked),
                "amortized": amortized,
                "k": k,
                "q": q_n,
            }
            if not get_backend(ranked[0][0]).uses_scene:
                # brute wins on the estimate: never build a scene
                name = ranked[0][0]
                per_q = [(name, ranked[0][1] / max(q_n, 1))] * q_n
                groups = {name: list(range(q_n))}
                scenes = None
            else:
                scenes = self._build_scenes(snap, queries, k, rect, workers)
                # re-price per query with the actual scene size; the filter
                # cost is sunk now
                per_q = planner.assign_batch(
                    [
                        WorkloadShape(
                            n_f,
                            n_u,
                            k,
                            1,
                            m_tris=s.n_tris,
                            cache_hit=True,
                            pad_waste=pad_w,
                            shards=self._workload_shards(),
                        )
                        for s in scenes
                    ]
                )
                groups = {}
                for i, (name, _cost) in enumerate(per_q):
                    groups.setdefault(name, []).append(i)
            self._batch_cache_put(snap, plan_key, (per_q, groups, scenes))

        counts = np.zeros((q_n, n_u), np.int32)
        t_count_total = 0.0
        observed_group: dict[str, float] = {}
        for name, idxs in groups.items():
            gcounts, t_prep, t_count = self._dispatch_group(
                snap, get_backend(name), idxs, scenes, q_pts, excludes, k, rect
            )
            counts[idxs] = gcounts
            t_count_total += t_count
            # the group's device count time lands under ITS backend; the
            # host-side remainder lands under "auto" in the caller
            self._phase_hist("verify", name).observe(t_count)
            observed_group[name] = t_prep + t_count

        plan.update(
            assignments=[name for name, _ in per_q],
            predicted_per_query=[cost for _, cost in per_q],
            split=len(groups) > 1,
            groups={name: len(idxs) for name, idxs in groups.items()},
            observed_group_s=observed_group,
            decisions={name: len(idxs) for name, idxs in groups.items()},
        )
        return counts, plan, per_q, groups, scenes, t_count_total

    def query_mono(self, q_idx: int, k: int, *, backend: str | None = None) -> RkNNResult:
        """Monochromatic RkNN over the facility set (paper §2.1 / §4.5).

        Reduces to the bichromatic machinery with ``F = U = facilities`` at
        threshold ``k + 1`` (every point's ray hits its own occluder), then
        self-hit-corrects the counts — see docs/API.md for the derivation.
        """
        self._read_clock += 1
        try:
            return self._query_mono(int(q_idx), k, backend=backend)
        except Exception as e:
            self._flight_exception("query_mono", e)
            raise

    def _query_mono(self, q_idx: int, k: int, *, backend: str | None) -> RkNNResult:
        snap = self._snap
        if snap._is_mono is None:
            snap._is_mono = snap.users is snap.facilities or (
                snap.users.shape == snap.facilities.shape
                and np.array_equal(snap.users, snap.facilities)
            )
        if snap._is_mono:
            res = self._query(snap, int(q_idx), k + 1, backend=backend)
        else:
            if snap._mono is None:
                # mesh is deliberately not forwarded: the single-query path
                # never routes through the sharded batch dispatch.  The
                # sub-engine is pinned to this snapshot's facilities, so it
                # rides the snapshot (benign first-touch race: two racing
                # builders produce equal engines, last assignment wins).
                snap._mono = RkNNEngine(
                    snap.facilities,
                    snap.facilities,
                    self.config,
                    rect=snap._rect if snap.explicit_rect else None,
                )
            res = snap._mono.query(int(q_idx), k + 1, backend=backend)
            # mirror the sub-engine's work into our metrics
            self._m_queries.inc()
            self._phase_hist("filter", res.backend).observe(res.t_filter_s)
            self._phase_hist("verify", res.backend).observe(res.t_verify_s)
        counts = np.asarray(res.counts, np.int32).copy()
        # self-hit correction: every point except q hits its own occluder
        # (q's occluder is excluded from the scene, so its count is already
        # "others")
        counts[np.arange(len(counts)) != q_idx] -= 1
        np.maximum(counts, 0, out=counts)
        mask = counts < k
        mask[q_idx] = False
        return RkNNResult(
            mask,
            counts,
            res.scene,
            res.t_filter_s,
            res.t_verify_s,
            res.backend,
            snap.version,
        )

    def stream(self, batches, k: int, *, backend: str | None = None):
        """Double-buffered batch stream: the host filter phase of batch
        ``i+1`` (scene builds + stacking, in a producer thread) overlaps the
        device dispatch of batch ``i``.  Yields ``(batch, masks[Q, N])``.

        Producer exceptions are re-raised in the consumer — the generator
        never hangs on a failed build.

        With the ``auto`` backend the planner re-routes each batch as a
        whole (pre-scene, estimated cost — no per-query splitting on the
        streaming path, which would defeat the double buffering).
        """
        b = get_backend(backend or self.config.backend)
        buf: "queue.Queue" = queue.Queue(maxsize=2)

        def producer():
            try:
                for batch in batches:
                    # one snapshot per batch: each yielded mask set is a
                    # consistent view of exactly one version, and a stream
                    # naturally picks up concurrent updates batch to batch
                    snap = self._snap
                    qs = list(batch)
                    sf = span("filter", backend=b.name, stream=1,
                              version=snap.version)
                    sf.__enter__()
                    queries, q_pts, excludes = _normalize_queries(
                        snap.facilities, qs
                    )
                    b_eff, plan = b, None
                    if b.is_meta:
                        shape = WorkloadShape(
                            len(snap.facilities),
                            len(snap.users),
                            k,
                            len(qs),
                            cache_hit=self._plan_amortized(snap),
                            pad_waste=snap.pad_waste(
                                snap.rect, self.config.grid_g
                            ),
                            shards=self._workload_shards(),
                        )
                        choice, pred, costs = b.select(shape)
                        plan = {
                            "mode": "stream-batch",
                            "backend": choice,
                            "predicted_s": pred,
                            "candidates": costs,
                            "cache_hit": shape.cache_hit,
                            "decisions": {choice: len(qs)},
                        }
                        b_eff = get_backend(choice)
                    if b_eff.uses_scene:
                        rect = self._rect_for(snap, q_pts)
                        built = self._filter_batch(
                            snap, b_eff, queries, q_pts, excludes, k, rect,
                            self.config.scene_workers,
                        )
                    else:
                        req = BatchRequest(
                            xs=None,
                            ys=None,
                            k=k,
                            users=snap.users,
                            facilities=snap.facilities,
                            q_pts=q_pts,
                            excludes=excludes,
                        )
                        built = (req, None, None)
                    sf.__exit__(None, None, None)
                    t_filter = sf.elapsed_s
                    self._phase_hist("filter", b.name).observe(t_filter)
                    buf.put((batch, len(qs), b_eff, plan, t_filter, built))
                buf.put(None)
            except BaseException as e:  # surface in the consumer, no deadlock
                buf.put(e)

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        while True:
            item = buf.get()
            if item is None:
                return
            if isinstance(item, BaseException):
                if isinstance(item, Exception):
                    self._flight_exception("stream", item)
                raise item
            batch, q_n, b_eff, plan, t_filter, (req, prepared, scenes) = item
            with span("verify", backend=b_eff.name, stream=1) as sv:
                counts = b_eff.count_batch(req, prepared)
            t_verify = sv.elapsed_s
            self._phase_hist("verify", b_eff.name).observe(t_verify)
            self._m_queries.inc(q_n)
            self._m_batches.inc()
            if scenes:
                self._m_mmax.set_max(max(s.n_tris for s in scenes))
            if plan is not None:
                # observed = this batch's own filter + verify work — NOT the
                # wall-clock since the producer started, which would include
                # time spent waiting in the double buffer and corrupt the
                # planner's pred-vs-obs calibration signal
                self._record_plan(b, plan, t_filter + t_verify)
            yield batch, counts < k
