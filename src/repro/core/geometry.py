"""Planar geometry primitives underpinning the RT-RkNN formulation.

Everything here is exact-ish float geometry on the host (numpy, float64) plus
mirrored jnp helpers used on device.  The central objects:

* a rectangular domain ``Rect`` (the paper's bounded space ``R``),
* perpendicular bisectors in *normal form*: the bisector of facilities
  ``a`` (competitor) and ``q`` (query) is ``{p : p.n == c}`` with
  ``n = q - a`` and ``c = (|q|^2 - |a|^2) / 2``; the *invalid side*
  (``a`` strictly closer than ``q``) is the open half-plane ``p.n < c``,
* triangles in **edge-function form**: a CCW triangle is the set
  ``{p : e_i(p) >= 0 for i in 0..2}`` with ``e_i(p) = a_i x + b_i y + c_i``.
  A vertical ray through a layered 3-D occluder (paper Def. 3.1/3.3) hits it
  iff the 2-D point passes all three edge tests — this *dimension collapse*
  is the key TPU adaptation (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "Rect",
    "bisector",
    "signed_area",
    "ensure_ccw",
    "edge_coeffs",
    "points_in_tris_np",
    "line_rect_intersections",
    "clip_polygon_halfplane",
    "polygon_area",
    "DEGENERATE_EDGE",
]

# Edge coefficients of a triangle that no point can ever be inside of
# (e(p) = -1 < 0 for every edge).  Used to pad scenes to static shapes.
DEGENERATE_EDGE = np.array([[0.0, 0.0, -1.0]] * 3, dtype=np.float64)


@dataclasses.dataclass(frozen=True)
class Rect:
    """Axis-aligned rectangular domain ``R`` (paper Def. 3.1)."""

    xmin: float
    ymin: float
    xmax: float
    ymax: float

    # ---- constructors -------------------------------------------------
    @staticmethod
    def from_bounds(lo: np.ndarray, hi: np.ndarray, pad_frac: float = 0.01) -> "Rect":
        """Padded rectangle from precomputed ``[2]`` min/max bounds.

        The pad keeps users strictly interior so boundary-degenerate
        occluder cases (bisector through a corner) have measure ~zero.
        """
        span = np.maximum(np.asarray(hi, np.float64) - np.asarray(lo, np.float64), 1e-9)
        pad = pad_frac * span
        return Rect(
            float(lo[0] - pad[0]),
            float(lo[1] - pad[1]),
            float(hi[0] + pad[0]),
            float(hi[1] + pad[1]),
        )

    @staticmethod
    def from_points(*point_sets: np.ndarray, pad_frac: float = 0.01) -> "Rect":
        """Bounding rectangle of one or more ``[N, 2]`` point sets, padded."""
        pts = np.concatenate([np.asarray(p, dtype=np.float64) for p in point_sets])
        return Rect.from_bounds(pts.min(axis=0), pts.max(axis=0), pad_frac)

    # ---- basic queries -------------------------------------------------
    @property
    def width(self) -> float:
        return self.xmax - self.xmin

    @property
    def height(self) -> float:
        return self.ymax - self.ymin

    @property
    def diagonal(self) -> float:
        return float(np.hypot(self.width, self.height))

    def corners(self) -> np.ndarray:
        """The four corners, CCW starting from (xmin, ymin): ``[4, 2]``."""
        return np.array(
            [
                [self.xmin, self.ymin],
                [self.xmax, self.ymin],
                [self.xmax, self.ymax],
                [self.xmin, self.ymax],
            ],
            dtype=np.float64,
        )

    def contains(self, pts: np.ndarray, atol: float = 0.0) -> np.ndarray:
        pts = np.asarray(pts, dtype=np.float64)
        return (
            (pts[..., 0] >= self.xmin - atol)
            & (pts[..., 0] <= self.xmax + atol)
            & (pts[..., 1] >= self.ymin - atol)
            & (pts[..., 1] <= self.ymax + atol)
        )

    def as_polygon(self) -> np.ndarray:
        return self.corners()

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        xy = rng.random((n, 2))
        xy[:, 0] = self.xmin + xy[:, 0] * self.width
        xy[:, 1] = self.ymin + xy[:, 1] * self.height
        return xy


# --------------------------------------------------------------------------
# Bisectors
# --------------------------------------------------------------------------

def bisector(a: np.ndarray, q: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Normal form of the perpendicular bisector between ``a`` and ``q``.

    Supports batched ``a``: ``a`` may be ``[2]`` or ``[M, 2]``; ``q`` is
    ``[2]``.  Returns ``(n, c)`` with ``n = q - a`` (shape like ``a``) and
    ``c = (|q|^2 - |a|^2)/2`` such that:

    * invalid side (``a`` strictly closer):  ``p.n < c``
    * valid side   (``q`` closer or tied):   ``p.n >= c``.
    """
    a = np.asarray(a, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    n = q - a
    c = (np.sum(q * q, axis=-1) - np.sum(a * a, axis=-1)) / 2.0
    return n, c


def halfplane_signed(pts: np.ndarray, n: np.ndarray, c: float) -> np.ndarray:
    """``pts.n - c``; negative = strictly invalid side."""
    return pts @ np.asarray(n, dtype=np.float64) - c


# --------------------------------------------------------------------------
# Triangles / edge functions
# --------------------------------------------------------------------------

def signed_area(tris: np.ndarray) -> np.ndarray:
    """Twice the signed area of ``[..., 3, 2]`` triangles (CCW positive)."""
    v0, v1, v2 = tris[..., 0, :], tris[..., 1, :], tris[..., 2, :]
    return (v1[..., 0] - v0[..., 0]) * (v2[..., 1] - v0[..., 1]) - (
        v1[..., 1] - v0[..., 1]
    ) * (v2[..., 0] - v0[..., 0])


def ensure_ccw(tris: np.ndarray) -> np.ndarray:
    """Flip vertex order where needed so all triangles are CCW."""
    tris = np.asarray(tris, dtype=np.float64).copy()
    flip = signed_area(tris) < 0.0
    if np.any(flip):
        tris[flip] = tris[flip][:, ::-1, :]
    return tris


def edge_coeffs(tris: np.ndarray) -> np.ndarray:
    """Edge-function coefficients for CCW ``[..., 3, 2]`` triangles.

    Returns ``[..., 3, 3]`` where row ``i`` holds ``(a, b, c)`` of edge
    ``v_i -> v_{i+1}`` with ``e(p) = a x + b y + c`` and the triangle
    interior satisfying ``e >= 0`` on all rows.  Degenerate (zero-area)
    triangles produce coefficient rows that are all-zero with ``c = -1``
    so that nothing is ever "inside" them — this makes padding safe.
    """
    tris = np.asarray(tris, dtype=np.float64)
    v = tris
    vn = np.roll(tris, -1, axis=-2)  # v_{i+1}
    a = -(vn[..., 1] - v[..., 1])
    b = vn[..., 0] - v[..., 0]
    c = -(a * v[..., 0] + b * v[..., 1])
    coeffs = np.stack([a, b, c], axis=-1)
    # kill degenerate triangles (zero signed area)
    degen = np.abs(signed_area(tris)) < 1e-30
    if np.any(degen):
        coeffs = coeffs.copy()
        coeffs[degen] = DEGENERATE_EDGE
    return coeffs


def points_in_tris_np(pts: np.ndarray, coeffs: np.ndarray) -> np.ndarray:
    """``[N, M]`` bool containment matrix from points and edge coeffs.

    ``pts``: ``[N, 2]``; ``coeffs``: ``[M, 3, 3]``.  Inclusive (>= 0)
    boundary convention — ties on the bisector edge are measure-zero for
    continuous data and are excluded in property tests via margins.
    """
    pts = np.asarray(pts, dtype=np.float64)
    x = pts[:, 0][:, None, None]
    y = pts[:, 1][:, None, None]
    e = coeffs[None, :, :, 0] * x + coeffs[None, :, :, 1] * y + coeffs[None, :, :, 2]
    return np.all(e >= 0.0, axis=-1)


# --------------------------------------------------------------------------
# Line / rectangle intersections & polygon clipping
# --------------------------------------------------------------------------

def line_rect_intersections(n: np.ndarray, c: float, rect: Rect) -> np.ndarray:
    """Intersection points of the line ``{p.n == c}`` with ``rect``'s boundary.

    Returns the (up to 2, typically exactly 2) distinct intersection points
    as ``[K, 2]``.  Raises if the line misses the rectangle entirely.
    """
    nx, ny = float(n[0]), float(n[1])
    pts: list[tuple[float, float]] = []
    # vertical domain edges x = xmin / xmax  ->  y = (c - nx*x)/ny
    if abs(ny) > 0.0:
        for x in (rect.xmin, rect.xmax):
            y = (c - nx * x) / ny
            if rect.ymin - 1e-12 <= y <= rect.ymax + 1e-12:
                pts.append((x, float(np.clip(y, rect.ymin, rect.ymax))))
    # horizontal domain edges y = ymin / ymax -> x = (c - ny*y)/nx
    if abs(nx) > 0.0:
        for y in (rect.ymin, rect.ymax):
            x = (c - ny * y) / nx
            if rect.xmin - 1e-12 <= x <= rect.xmax + 1e-12:
                pts.append((float(np.clip(x, rect.xmin, rect.xmax)), y))
    if not pts:
        raise ValueError("line does not intersect the domain rectangle")
    # dedupe near-identical corner hits
    out: list[tuple[float, float]] = []
    for p in pts:
        if all(abs(p[0] - o[0]) + abs(p[1] - o[1]) > 1e-9 * (1.0 + rect.diagonal) for o in out):
            out.append(p)
    return np.asarray(out, dtype=np.float64)


def clip_polygon_halfplane(poly: np.ndarray, n: np.ndarray, c: float) -> np.ndarray:
    """Sutherland–Hodgman clip of ``poly`` to the closed half-plane ``p.n <= c``.

    ``poly``: ``[V, 2]`` CCW.  Returns the clipped polygon (possibly empty
    ``[0, 2]``).  Used to compute exact invalid regions in tests and in the
    InfZone-style zone bookkeeping.
    """
    poly = np.asarray(poly, dtype=np.float64)
    if len(poly) == 0:
        return poly
    n = np.asarray(n, dtype=np.float64)
    d = poly @ n - c  # <= 0 is inside (kept)
    out: list[np.ndarray] = []
    V = len(poly)
    for i in range(V):
        j = (i + 1) % V
        pi, pj = poly[i], poly[j]
        di, dj = d[i], d[j]
        if di <= 0.0:
            out.append(pi)
        if (di < 0.0 < dj) or (dj < 0.0 < di):
            t = di / (di - dj)
            out.append(pi + t * (pj - pi))
    if not out:
        return np.zeros((0, 2), dtype=np.float64)
    return np.asarray(out, dtype=np.float64)


def polygon_area(poly: np.ndarray) -> float:
    """Shoelace area of a simple polygon ``[V, 2]`` (positive if CCW)."""
    if len(poly) < 3:
        return 0.0
    x, y = poly[:, 0], poly[:, 1]
    return 0.5 * float(np.sum(x * np.roll(y, -1) - np.roll(x, -1) * y))
