"""Scene-construction pruning — the paper's InfZone-style facility filter.

Algorithm 1 line 2: a facility's occluder is discarded when it is *fully
covered* by ``k`` previously-kept occluders — no ray can then change its
verdict by hitting it (any user inside it already counts >= k hits).  The
paper drives this with InfZone's influence-zone machinery; we implement a
**sound conservative variant** on a coverage grid:

* the domain is divided into ``G x G`` cells; for every kept occluder
  (an invalid half-plane) we track which cells it *fully strictly* contains
  (all 4 cell corners strictly invalid ⇒ the whole convex cell is strictly
  invalid — linear functionals attain extrema at corners);
* a cell whose full-containment count is ``>= k`` provably contains no point
  of the influence zone (every point in it has >= k closer facilities);
* a new facility is discarded iff **every** possibly-zone cell lies entirely
  on its valid side (all 4 corners ``p.n >= c`` ⇒ no strictly-invalid point
  in the cell).  Discarding is therefore never wrong; coarse grids only keep
  extra occluders (performance, not correctness).

The cheap InfZone filters are kept verbatim:
* Eq. (1) bulk reject:  ``dist(f, q) > 2 * max_{v in Z} dist(v, q)`` — with
  the max taken over corners of possibly-zone cells (a superset of the zone,
  so the rejection stays sound);
* facilities are processed in increasing distance from ``q`` (as in both
  InfZone and TPL), which shrinks the zone fastest.

Three strategies from paper §4.8 are exposed: ``"infzone"``,
``"conservative"`` (full test for the first ``warmup`` facilities, Eq. (1)
only afterwards) and ``"none"``.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.geometry import Rect, bisector
from repro.core.grid import build_sleep, build_yield_ratio

__all__ = ["PruneStats", "prune_facilities", "STRATEGIES", "adaptive_grid"]

STRATEGIES = ("infzone", "conservative", "none")

#: Adaptive coverage-grid resolution: facility sets below the threshold
#: prune at the coarse resolution, denser ones at the fine one (measured:
#: G=256 halves kept occluders at |F|=10^4).  The dynamic subsystem's
#: cold-equivalence contract depends on detecting when an update crosses
#: the threshold — always read it from here.
ADAPTIVE_GRID_THRESHOLD = 2000
ADAPTIVE_GRID_COARSE = 128
ADAPTIVE_GRID_FINE = 256


def adaptive_grid(n_facilities: int) -> int:
    """The coverage-grid resolution ``prune_facilities`` picks for
    ``grid=None`` at this facility count."""
    return (
        ADAPTIVE_GRID_COARSE
        if n_facilities < ADAPTIVE_GRID_THRESHOLD
        else ADAPTIVE_GRID_FINE
    )


@dataclasses.dataclass
class PruneStats:
    """Bookkeeping for benchmarks (paper Table 3 / Fig 16).

    ``safe_radius`` is the *update-stability certificate* consumed by the
    dynamic subsystem (:mod:`repro.dynamic`): any facility change (insert,
    delete, or either endpoint of a move) strictly farther than this from
    the query point provably leaves a cold re-prune — and therefore the
    whole scene — bit-identical.  It is ``max(2·radius_final, d_max)``
    where ``radius_final`` is the final influence-zone radius bound and
    ``d_max`` the farthest facility the chunked pass ever examined: a
    strictly-farther row sorts after every examined one (chunk boundaries
    are unchanged) and is Eq. (1)-rejected by the final radius before it
    can be processed.  ``inf`` means no change is provably safe (strategy
    ``"none"`` keeps everything; an empty kept set never bounded the zone).
    """

    n_facilities: int
    n_kept: int
    n_eq1_rejected: int
    n_cover_rejected: int
    strategy: str
    safe_radius: float = float("inf")


class _CoverageGrid:
    """Full-containment coverage counts over a G x G cell grid."""

    def __init__(self, rect: Rect, grid: int):
        self.rect = rect
        self.G = grid
        xs = np.linspace(rect.xmin, rect.xmax, grid + 1)
        ys = np.linspace(rect.ymin, rect.ymax, grid + 1)
        cx, cy = np.meshgrid(xs, ys, indexing="ij")  # corner lattice [G+1, G+1]
        self._corners = np.stack([cx, cy], axis=-1)
        self.counts = np.zeros((grid, grid), dtype=np.int32)

    def _corner_signed(self, n: np.ndarray, c: float) -> np.ndarray:
        return self._corners @ np.asarray(n, dtype=np.float64) - c

    def corner_signed_batch(self, n: np.ndarray, c: np.ndarray) -> np.ndarray:
        """[B, G+1, G+1] signed values for a batch of half-planes."""
        v = np.einsum("xyk,bk->bxy", self._corners, np.asarray(n, dtype=np.float64))
        return v - np.asarray(c, dtype=np.float64)[:, None, None]

    def _cell_all(self, corner_mask: np.ndarray) -> np.ndarray:
        """AND of the 4 corner flags per cell: ``[G, G]``."""
        return (
            corner_mask[:-1, :-1]
            & corner_mask[1:, :-1]
            & corner_mask[:-1, 1:]
            & corner_mask[1:, 1:]
        )

    def add_halfplane(self, n: np.ndarray, c: float) -> None:
        """Register a kept occluder's invalid half-plane ``p.n < c``."""
        strictly_invalid = self._corner_signed(n, c) < 0.0
        self.counts += self._cell_all(strictly_invalid).astype(np.int32)

    def possibly_zone(self, k: int) -> np.ndarray:
        """Cells that may still contain influence-zone points: ``[G, G]``."""
        return self.counts < k

    def fully_valid_for(self, n: np.ndarray, c: float) -> np.ndarray:
        """Cells with no strictly-invalid point for this bisector."""
        valid = self._corner_signed(n, c) >= 0.0
        return self._cell_all(valid)

    def zone_radius(self, k: int, q: np.ndarray) -> float:
        """max over possibly-zone cell corners of dist(corner, q).

        dist(., q) is convex so the per-cell max is attained at a corner;
        taking all corners of possibly-zone cells upper-bounds the zone's
        max distance (Eq. (1) soundness).
        """
        pz = self.possibly_zone(k)
        if not pz.any():
            return 0.0
        mask = np.zeros((self.G + 1, self.G + 1), dtype=bool)
        mask[:-1, :-1] |= pz
        mask[1:, :-1] |= pz
        mask[:-1, 1:] |= pz
        mask[1:, 1:] |= pz
        d = np.linalg.norm(self._corners - np.asarray(q, dtype=np.float64), axis=-1)
        return float(d[mask].max())


def prune_facilities(
    facilities: np.ndarray,
    q: np.ndarray,
    k: int,
    rect: Rect,
    *,
    strategy: str = "infzone",
    grid: int | None = None,
    warmup: int = 20,
    exclude: int | None = None,
) -> tuple[np.ndarray, PruneStats]:
    """Keep-mask over ``facilities`` for query point ``q``.

    ``exclude`` optionally names a facility row to skip entirely (the query
    itself for in-set queries).  Returns ``(keep_mask [M] bool, stats)``.
    ``grid=None`` picks the resolution adaptively: dense facility sets have
    tiny influence zones, so the coverage grid must be finer to certify
    coverage (measured: G=256 halves kept occluders at |F|=10^4).
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown pruning strategy {strategy!r}")
    if grid is None:
        grid = adaptive_grid(len(facilities))
    facilities = np.asarray(facilities, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    M = len(facilities)
    keep = np.zeros(M, dtype=bool)
    alive = np.ones(M, dtype=bool)
    if exclude is not None:
        alive[exclude] = False
    # facilities coincident with q carry no bisector: drop them
    coincident = np.linalg.norm(facilities - q, axis=1) < 1e-12
    alive &= ~coincident

    if strategy == "none":
        keep = alive.copy()
        return keep, PruneStats(M, int(keep.sum()), 0, 0, strategy)

    dist_q = np.linalg.norm(facilities - q, axis=1)
    order = order_all = np.argsort(dist_q, kind="stable")
    order = order[alive[order]]
    cov = _CoverageGrid(rect, grid)
    n_eq1 = 0
    n_cover = 0
    radius = np.inf  # zone radius upper bound; tightened as occluders land
    processed = 0
    max_processed = 0.0  # farthest facility any chunk examined

    # Facilities are processed in distance order in CHUNKS: the discard test
    # for a chunk is evaluated against the current kept set only, and every
    # survivor of the chunk is kept at once.  Keeping an occluder that a
    # strictly sequential pass would have discarded is always SOUND (hit
    # counts only move toward the true closer-facility counts; see module
    # docstring) — the chunk width trades a few extra occluders for a ~64x
    # smaller host loop.  Near ``q`` pruning quality matters most (those
    # facilities define the zone), so chunks start small and grow.
    pos = 0
    # background maintenance threads (MVCC prewarm) run this loop
    # deprioritized: each iteration is a few ms of solid C-level work, so
    # yielding ratio x the iteration's own time keeps foreground readers
    # at well over the fair-scheduling half of a contended core
    while pos < len(order):
        yield_ratio = build_yield_ratio()  # per iteration: may be dynamic
        t_iter = time.perf_counter() if yield_ratio else 0.0
        chunk = 8 if keep.sum() < 4 * k + 8 else 64
        # ---- Eq. (1) bulk reject of everything beyond 2*radius ----------
        if radius < np.inf:
            cut = np.searchsorted(dist_q[order], 2.0 * radius, side="right")
            if cut <= pos:
                n_eq1 += len(order) - pos
                break
            if cut < len(order):
                n_eq1 += len(order) - cut
                order = order[:cut]
        batch = order[pos : pos + chunk]
        pos += len(batch)
        processed_batch = processed
        processed += len(batch)
        max_processed = max(max_processed, float(dist_q[batch[-1]]))
        n_b, c_b = bisector(facilities[batch], q)  # [B, 2], [B]
        full_test = strategy == "infzone" or processed_batch < warmup
        if full_test:
            pz = cov.possibly_zone(k)
            if not pz.any():
                n_cover += len(batch) + (len(order) - pos)
                break
            # vectorized: cell fully-valid per batch facility  [B, G, G]
            sgn = cov.corner_signed_batch(n_b, c_b) >= 0.0  # [B, G+1, G+1]
            fv = sgn[:, :-1, :-1] & sgn[:, 1:, :-1] & sgn[:, :-1, 1:] & sgn[:, 1:, 1:]
            covered = (~pz[None] | fv).all(axis=(1, 2))  # [B]
            survivors = batch[~covered]
            n_cover += int(covered.sum())
        else:
            survivors = batch
        if len(survivors):
            keep[survivors] = True
            ns, cs = bisector(facilities[survivors], q)
            inv = cov.corner_signed_batch(ns, cs) < 0.0
            full_inv = (
                inv[:, :-1, :-1] & inv[:, 1:, :-1] & inv[:, :-1, 1:] & inv[:, 1:, 1:]
            )
            cov.counts += full_inv.sum(axis=0).astype(np.int32)
            radius = cov.zone_radius(k, q)
        if yield_ratio:
            build_sleep((time.perf_counter() - t_iter) * yield_ratio)

    safe_radius = (
        max(2.0 * float(radius), max_processed) if np.isfinite(radius) else np.inf
    )
    stats = PruneStats(M, int(keep.sum()), n_eq1, n_cover, strategy, safe_radius)
    return keep, stats
