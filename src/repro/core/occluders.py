"""Occluder construction — paper Definition 3.1, all four scenarios.

For a competitor facility ``a`` and query facility ``q`` inside the domain
rectangle ``R``, the *invalid region* is ``{p in R : dist(p, a) < dist(p, q)}``
(the open half-plane ``p.n < c`` of the bisector, clipped to ``R``).  The
occluder is a set of one or two triangles whose union, **restricted to R**,
equals that invalid region:

(a) *normal*:   the invalid region contains exactly one corner of ``R`` →
                a single triangle ``(v, p1, p2)`` where ``p1, p2`` are the
                bisector's hits on the two boundary edges incident to ``v``;
(b) *extended*: the invalid region contains two or three corners (a quad or
                pentagon) → a single **covering triangle** with one edge on
                the bisector line, extended so far beyond ``R`` that inside
                ``R`` its coverage equals the half-plane exactly;
(c) *vertical bisector* (``n_y == 0``):   the invalid region is a rectangle →
                two triangles ``(v1, p1, p2)`` and ``(v1, v2, p2)``;
(d) *horizontal bisector* (``n_x == 0``): symmetric to (c).

The paper lifts each occluder to a distinct height ``z``; because every user
ray is vertical, the lift never changes hit outcomes and we keep occluders in
2-D (DESIGN.md §2, changed assumption 1).  ``z`` is retained as metadata only
so the faithful BVH path can report paper-consistent layered scenes.
"""

from __future__ import annotations

import numpy as np

from repro.core.geometry import (
    Rect,
    bisector,
    edge_coeffs,
    ensure_ccw,
    line_rect_intersections,
)

__all__ = ["occluder_triangles", "occluders_for_facilities", "OCCLUDER_MAX_TRIS"]

# Any single occluder needs at most 2 triangles (cases c/d).
OCCLUDER_MAX_TRIS = 2

_EPS = 1e-12


def _covering_triangle(n: np.ndarray, c: float, rect: Rect) -> np.ndarray:
    """Case (b): one big triangle with an edge on the bisector line.

    Construction: take the bisector's chord through ``R`` (endpoints
    ``p1, p2``), extend it by 4 diagonals on both ends (so the two slanted
    triangle edges pass far outside ``R``) and place the apex 4 diagonals
    deep on the invalid side.  Inside ``R`` the triangle's boundary is then
    exactly the bisector line, so triangle ∩ R == invalid half-plane ∩ R.
    """
    pts = line_rect_intersections(n, c, rect)
    if len(pts) < 2:
        # Line grazes a corner: invalid region is (almost) all or none of R.
        # Fall back to a triangle covering the whole invalid side around R.
        pts = np.asarray(
            [pts[0] if len(pts) else [rect.xmin, rect.ymin], [rect.xmax, rect.ymax]],
            dtype=np.float64,
        )
    p1, p2 = pts[0], pts[1]
    d = rect.diagonal
    t = p2 - p1
    tn = np.linalg.norm(t)
    if tn < _EPS:  # degenerate chord; treat as covering nothing
        return np.zeros((0, 3, 2), dtype=np.float64)
    t = t / tn
    nn = np.asarray(n, dtype=np.float64)
    nn = nn / np.linalg.norm(nn)
    e1 = p1 - t * (4.0 * d)
    e2 = p2 + t * (4.0 * d)
    apex = (p1 + p2) / 2.0 - nn * (4.0 * d)  # -n direction = invalid side
    return ensure_ccw(np.asarray([[e1, e2, apex]], dtype=np.float64))


def _axis_aligned_occluder(n: np.ndarray, c: float, rect: Rect, axis: int) -> np.ndarray:
    """Cases (c)/(d): bisector parallel to an axis → rectangular invalid region.

    ``axis == 0``: vertical bisector ``x == c/n_x`` (n_y == 0).
    ``axis == 1``: horizontal bisector ``y == c/n_y`` (n_x == 0).
    Returns two triangles tiling the invalid rectangle.
    """
    if axis == 0:
        xb = c / n[0]
        xb = float(np.clip(xb, rect.xmin, rect.xmax))
        # invalid side: x * n_x < c
        if n[0] > 0:
            x0, x1 = rect.xmin, xb
        else:
            x0, x1 = xb, rect.xmax
        quad = np.array(
            [[x0, rect.ymin], [x1, rect.ymin], [x1, rect.ymax], [x0, rect.ymax]]
        )
    else:
        yb = c / n[1]
        yb = float(np.clip(yb, rect.ymin, rect.ymax))
        if n[1] > 0:
            y0, y1 = rect.ymin, yb
        else:
            y0, y1 = yb, rect.ymax
        quad = np.array(
            [[rect.xmin, y0], [rect.xmax, y0], [rect.xmax, y1], [rect.xmin, y1]]
        )
    if abs(quad[0, 0] - quad[1, 0]) < _EPS and abs(quad[0, 1] - quad[3, 1]) < _EPS:
        return np.zeros((0, 3, 2), dtype=np.float64)
    tris = np.asarray(
        [[quad[0], quad[1], quad[2]], [quad[0], quad[2], quad[3]]], dtype=np.float64
    )
    return ensure_ccw(tris)


def occluder_triangles(a: np.ndarray, q: np.ndarray, rect: Rect) -> np.ndarray:
    """Triangles (``[T, 3, 2]``, T in {0, 1, 2}) of the occluder ``O_{a:q}``.

    The union of the returned triangles, intersected with ``rect``, equals
    the invalid region of the bisector ``B_{a:q}`` (property-tested in
    ``tests/test_geometry.py``).
    """
    a = np.asarray(a, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    n, c = bisector(a, q)
    nrm = float(np.linalg.norm(n))
    if nrm < _EPS:
        # a == q: no competitor information; empty occluder.
        return np.zeros((0, 3, 2), dtype=np.float64)

    scale = max(1.0, abs(c), nrm)
    if abs(n[1]) < _EPS * scale:  # bisector vertical (case c)
        return _axis_aligned_occluder(n, c, rect, axis=0)
    if abs(n[0]) < _EPS * scale:  # bisector horizontal (case d)
        return _axis_aligned_occluder(n, c, rect, axis=1)

    corners = rect.corners()
    d = corners @ n - c  # < 0 strictly invalid
    tol = 1e-12 * scale * rect.diagonal
    invalid = d < -tol
    n_inv = int(invalid.sum())

    if n_inv == 0:
        # Bisector passes outside (or grazes) R on the invalid side.
        # If *any* interior point is invalid the region is a sliver with no
        # corner; cover it with the covering triangle, else empty.
        try:
            pts = line_rect_intersections(n, c, rect)
        except ValueError:
            return np.zeros((0, 3, 2), dtype=np.float64)
        if len(pts) < 2:
            return np.zeros((0, 3, 2), dtype=np.float64)
        return _covering_triangle(n, c, rect)

    if n_inv == 1:
        # Case (a): single corner v; bisector crosses both incident edges.
        vi = int(np.argmax(invalid))
        v = corners[vi]
        try:
            pts = line_rect_intersections(n, c, rect)
        except ValueError:
            return np.zeros((0, 3, 2), dtype=np.float64)
        if len(pts) < 2:
            return np.zeros((0, 3, 2), dtype=np.float64)
        # The two chord endpoints must lie on the edges incident to v; when
        # the chord clips a different corner (numerical grazing) fall back to
        # the covering triangle, which is always exact inside R.
        p1, p2 = pts[0], pts[1]
        on_incident = (
            (abs(p1[0] - v[0]) < 1e-9 * scale or abs(p1[1] - v[1]) < 1e-9 * scale)
            and (abs(p2[0] - v[0]) < 1e-9 * scale or abs(p2[1] - v[1]) < 1e-9 * scale)
        )
        if not on_incident:
            return _covering_triangle(n, c, rect)
        return ensure_ccw(np.asarray([[v, p1, p2]], dtype=np.float64))

    if n_inv >= 3:
        # Pentagon (3 corners invalid): Def 3.1 does not enumerate this case
        # explicitly; the paper's "extended" covering construction applies
        # verbatim and stays exact inside R.
        return _covering_triangle(n, c, rect)

    # n_inv == 2 — Case (b), quad region -> single covering triangle.
    return _covering_triangle(n, c, rect)


def occluders_for_facilities(
    facilities: np.ndarray,
    q: np.ndarray,
    rect: Rect,
    keep: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Build occluders for every kept facility.

    Returns ``(tris [T, 3, 2], coeffs [T, 3, 3], owner [T])`` where
    ``owner[t]`` is the facility row index that produced triangle ``t``
    (cases c/d contribute two triangles with the same owner — hit *counting*
    must deduplicate per owner only for points exactly on the shared
    diagonal, which is measure-zero; the two triangles partition the
    rectangle so interior double-hits cannot occur).
    """
    facilities = np.asarray(facilities, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    if keep is None:
        keep = np.ones(len(facilities), dtype=bool)
    tris: list[np.ndarray] = []
    owners: list[int] = []
    for i in np.flatnonzero(keep):
        t = occluder_triangles(facilities[i], q, rect)
        for tri in t:
            tris.append(tri)
            owners.append(int(i))
    if not tris:
        tris_arr = np.zeros((0, 3, 2), dtype=np.float64)
    else:
        tris_arr = np.asarray(tris, dtype=np.float64)
    coeffs = edge_coeffs(tris_arr) if len(tris_arr) else np.zeros((0, 3, 3))
    return tris_arr, coeffs, np.asarray(owners, dtype=np.int32)
