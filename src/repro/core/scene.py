"""Scene construction (Algorithm 1, lines 1–8) and static-shape packing.

A ``Scene`` is the device-ready encoding of all occluders for one query
facility: triangles in edge-function form, padded to a static size so the
jitted/pjitted ray-cast step never re-traces across queries.  Padding uses
``DEGENERATE_EDGE`` rows (never satisfied), so padded slots contribute zero
hits by construction.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import occluders as occ
from repro.core.geometry import DEGENERATE_EDGE, Rect
from repro.core.pruning import PruneStats, prune_facilities

__all__ = ["Scene", "build_scene", "pad_scene_arrays"]


def _next_pad(n: int, multiple: int = 128, minimum: int = 128) -> int:
    return max(minimum, ((n + multiple - 1) // multiple) * multiple)


@dataclasses.dataclass
class Scene:
    """Packed per-query occluder scene.

    Attributes:
      tris:    ``[Mp, 3, 2]`` float32 triangle vertices (padded, CCW).
      coeffs:  ``[Mp, 3, 3]`` float32 edge functions (padded degenerate).
      owner:   ``[Mp]`` int32 facility row per triangle, ``-1`` for padding.
      n_tris:  number of real triangles (<= Mp).
      n_occluders: number of kept facilities (paper's ``m``).
      keep:    ``[|F|]`` bool mask of kept facilities.
      q:       ``[2]`` query point.
      rect:    the domain rectangle.
      heights: ``[Mp]`` float32 paper-faithful layer heights ``z`` (metadata;
               the 2-D formulation never reads them — DESIGN.md §2).
      stats:   pruning statistics.
    """

    tris: np.ndarray
    coeffs: np.ndarray
    owner: np.ndarray
    n_tris: int
    n_occluders: int
    keep: np.ndarray
    q: np.ndarray
    rect: Rect
    heights: np.ndarray
    stats: PruneStats

    @property
    def m(self) -> int:  # paper notation
        return self.n_occluders


def pad_scene_arrays(
    tris: np.ndarray, coeffs: np.ndarray, owner: np.ndarray, pad_to: int | None = None
) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Pad triangle arrays to a static, lane-aligned size."""
    n = len(tris)
    mp = pad_to if pad_to is not None else _next_pad(n)
    if mp < n:
        raise ValueError(f"pad_to={mp} smaller than triangle count {n}")
    tris_p = np.zeros((mp, 3, 2), dtype=np.float32)
    coeffs_p = np.tile(
        np.asarray(DEGENERATE_EDGE, dtype=np.float32)[None], (mp, 1, 1)
    )
    owner_p = np.full((mp,), -1, dtype=np.int32)
    if n:
        tris_p[:n] = tris.astype(np.float32)
        coeffs_p[:n] = coeffs.astype(np.float32)
        owner_p[:n] = owner
    return tris_p, coeffs_p, owner_p, n


def build_scene(
    facilities: np.ndarray,
    q: np.ndarray | int,
    k: int,
    rect: Rect | None = None,
    *,
    strategy: str = "infzone",
    grid: int | None = None,
    pad_to: int | None = None,
    users_hint: np.ndarray | None = None,
) -> Scene:
    """Construct the occluder scene for query facility ``q``.

    ``q`` may be an index into ``facilities`` (the common case — the query
    is one of the facilities and is excluded from competitors) or an
    explicit ``[2]`` point.  ``users_hint`` optionally extends the domain
    rectangle so every user is interior.
    """
    facilities = np.asarray(facilities, dtype=np.float64)
    if isinstance(q, (int, np.integer)):
        q_idx: int | None = int(q)
        q_pt = facilities[q_idx]
    else:
        q_idx = None
        q_pt = np.asarray(q, dtype=np.float64)
    if rect is None:
        sets = [facilities, q_pt[None]]
        if users_hint is not None:
            sets.append(np.asarray(users_hint, dtype=np.float64))
        rect = Rect.from_points(*sets)

    keep, stats = prune_facilities(
        facilities, q_pt, k, rect, strategy=strategy, grid=grid, exclude=q_idx
    )
    tris, coeffs, owner = occ.occluders_for_facilities(facilities, q_pt, rect, keep)
    tris_p, coeffs_p, owner_p, n = pad_scene_arrays(tris, coeffs, owner, pad_to)
    # paper-faithful distinct layer heights z = 1..T for the kept triangles
    heights = np.zeros((len(tris_p),), dtype=np.float32)
    heights[:n] = np.arange(1, n + 1, dtype=np.float32)
    return Scene(
        tris=tris_p,
        coeffs=coeffs_p,
        owner=owner_p,
        n_tris=n,
        n_occluders=int(keep.sum()),
        keep=keep,
        q=q_pt,
        rect=rect,
        heights=heights,
        stats=stats,
    )
