"""Versioned JSON store for fitted planner profiles + the active profile.

A :class:`PlannerProfile` bundles one :class:`BackendCostModel` per
backend name (including the ``"slice"`` pseudo-backend used by the hybrid
RT-vs-SLICE frontier in :func:`repro.core.hybrid.choose_engine`), stamped
with a schema version and a hardware fingerprint so a profile calibrated
on one machine is never silently trusted on another kind of hardware.

Process-wide state: :func:`set_active_profile` / :func:`get_active_profile`
install the profile the ``auto`` backend and ``choose_engine`` consult.
With no active (or stored) profile, :func:`builtin_profile` supplies a
prior — the old hard-coded cost constants generalized to every built-in
backend by fitting the power-law models to analytic formulas over a shape
grid — so the planner always has *an* opinion, just a less trustworthy
one than calibration.
"""

from __future__ import annotations

import dataclasses
import json
import os
import platform
import threading

import numpy as np

from repro.planner.models import BackendCostModel, WorkloadShape, est_scene_tris

__all__ = [
    "PROFILE_VERSION",
    "PlannerProfile",
    "builtin_profile",
    "get_active_profile",
    "set_active_profile",
    "profile_epoch",
    "note_recalibrated",
    "load_profile",
    "default_profile_path",
    "runner_class",
    "runner_profile_path",
    "load_runner_profile",
]

#: v2: the feature vector gained ``log_pw`` (cell-bucketing pad-waste
#: ratio) and fits constrain exponents non-negative — v1 coefficient
#: vectors neither parse nor price correctly, so they are rejected.
PROFILE_VERSION = 2

#: Environment override for where profiles live by default.
_PROFILE_ENV = "REPRO_PLANNER_PROFILE"


def default_profile_path() -> str:
    env = os.environ.get(_PROFILE_ENV)
    if env:
        return env
    cache = os.environ.get("XDG_CACHE_HOME", os.path.expanduser("~/.cache"))
    return os.path.join(cache, "repro", "planner_profile.json")


def hardware_fingerprint() -> dict:
    """Coarse machine identity recorded alongside fitted coefficients."""
    try:
        import jax

        dev = jax.devices()[0]
        accel = {"platform": dev.platform, "device_kind": dev.device_kind,
                 "n_devices": jax.device_count()}
    except Exception:  # noqa: BLE001 — profile IO must not require a device
        accel = {"platform": "unknown", "device_kind": "unknown", "n_devices": 0}
    return {
        "machine": platform.machine(),
        "system": platform.system(),
        "python": platform.python_version(),
        **accel,
    }


@dataclasses.dataclass
class PlannerProfile:
    """One calibrated (or prior) set of per-backend cost models."""

    models: dict[str, BackendCostModel]
    version: int = PROFILE_VERSION
    created_at: float = 0.0  # unix seconds; 0 for the built-in prior
    hardware: dict = dataclasses.field(default_factory=dict)
    source: str = "calibrated"  # "calibrated" | "builtin-prior"
    meta: dict = dataclasses.field(default_factory=dict)

    # ---- prediction ------------------------------------------------------
    def backends(self) -> tuple[str, ...]:
        return tuple(self.models)

    def predict_s(self, backend: str, shape: WorkloadShape) -> float:
        return self.models[backend].predict_total_s(shape)

    def rank(
        self, shape: WorkloadShape, candidates: tuple[str, ...] | None = None
    ) -> list[tuple[str, float]]:
        """Candidates sorted cheapest-first as ``(name, predicted_s)``."""
        names = candidates if candidates is not None else self.backends()
        scored = [(n, self.predict_s(n, shape)) for n in names if n in self.models]
        if not scored:
            raise ValueError(
                f"profile has no models for any of {names!r} "
                f"(knows {self.backends()!r})"
            )
        return sorted(scored, key=lambda t: t[1])

    def best_backend(
        self, shape: WorkloadShape, candidates: tuple[str, ...] | None = None
    ) -> tuple[str, float]:
        return self.rank(shape, candidates)[0]

    # ---- persistence -----------------------------------------------------
    def to_json(self) -> dict:
        return {
            "version": self.version,
            "created_at": self.created_at,
            "hardware": self.hardware,
            "source": self.source,
            "meta": self.meta,
            "models": {n: m.to_json() for n, m in self.models.items()},
        }

    def save(self, path: str) -> str:
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")
        return path

    @classmethod
    def from_json(cls, obj: dict) -> "PlannerProfile":
        version = int(obj.get("version", -1))
        if version != PROFILE_VERSION:
            raise ValueError(
                f"planner profile version {version} is not supported "
                f"(expected {PROFILE_VERSION}); re-run calibration"
            )
        return cls(
            models={
                n: BackendCostModel.from_json(m)
                for n, m in obj.get("models", {}).items()
            },
            version=version,
            created_at=float(obj.get("created_at", 0.0)),
            hardware=dict(obj.get("hardware", {})),
            source=str(obj.get("source", "calibrated")),
            meta=dict(obj.get("meta", {})),
        )


def load_profile(path: str | None = None) -> PlannerProfile:
    """Load a stored profile, warning when its hardware fingerprint does
    not match this machine (fitted constants are hardware-specific — a
    foreign profile still loads, but never silently)."""
    with open(path or default_profile_path()) as f:
        prof = PlannerProfile.from_json(json.load(f))
    if prof.hardware:
        here = hardware_fingerprint()
        mismatched = {
            key: (prof.hardware.get(key), here.get(key))
            for key in ("platform", "device_kind", "machine")
            if key in prof.hardware and prof.hardware.get(key) != here.get(key)
        }
        if mismatched:
            import warnings

            warnings.warn(
                f"planner profile was calibrated on different hardware "
                f"({mismatched}); its cost constants are likely wrong here "
                f"— re-run repro.planner.calibrate",
                RuntimeWarning,
                stacklevel=2,
            )
    return prof


# --------------------------------------------------------------------------
# active profile (process-wide)
# --------------------------------------------------------------------------

_active_lock = threading.Lock()
_active: PlannerProfile | None = None
_epoch = 0


def set_active_profile(profile: PlannerProfile | None) -> None:
    global _active, _epoch
    with _active_lock:
        _active = profile
        _epoch += 1


def get_active_profile() -> PlannerProfile | None:
    """The installed profile, or ``None`` (callers fall back to the prior)."""
    with _active_lock:
        return _active


def profile_epoch() -> int:
    """Bumped on every :func:`set_active_profile` — cached planner
    decisions key on it so recalibration invalidates them."""
    with _active_lock:
        return _epoch


def note_recalibrated() -> None:
    """Bump the epoch without swapping the profile object — the online
    re-calibration path mutates the active profile's coefficients in
    place and calls this once the cumulative drift is large enough that
    memoized plans should be re-priced."""
    global _epoch
    with _active_lock:
        _epoch += 1


# --------------------------------------------------------------------------
# per-runner-class committed profiles (benchmarks/profiles/<class>.json)
# --------------------------------------------------------------------------


def runner_class(hw: dict | None = None) -> str:
    """A filesystem-safe identity for "machines like this one" — the key
    under which CI runner classes commit calibrated profiles."""
    hw = hw or hardware_fingerprint()
    raw = "-".join(
        str(hw.get(k, "unknown"))
        for k in ("system", "machine", "platform", "device_kind")
    ).lower()
    return "".join(c if c.isalnum() or c in "-_." else "_" for c in raw)


def runner_profile_path(root: str) -> str:
    return os.path.join(root, runner_class() + ".json")


def load_runner_profile(root: str) -> PlannerProfile | None:
    """Load this runner class's committed profile, or ``None`` when the
    file is missing, unreadable, schema-stale, or was calibrated on a
    different hardware class (strict match — unlike :func:`load_profile`,
    which warns and proceeds, a *committed* profile must never silently
    misprice a different machine)."""
    path = runner_profile_path(root)
    try:
        with open(path) as f:
            prof = PlannerProfile.from_json(json.load(f))
    except (OSError, ValueError, KeyError, json.JSONDecodeError):
        return None
    here = hardware_fingerprint()
    for key in ("system", "machine", "platform", "device_kind"):
        if prof.hardware.get(key) != here.get(key):
            return None
    return prof


# --------------------------------------------------------------------------
# built-in prior
# --------------------------------------------------------------------------

_builtin: PlannerProfile | None = None


def _prior_times(name: str, s: WorkloadShape) -> tuple[float, float]:
    """Analytic (filter_s, verify_s) priors per batch on CPU-class hardware.

    Shapes (not absolute values) are what matter: they encode which terms
    dominate each backend — scene builds for geometric paths, the |F|·|U|
    distance matrix for brute, interpret-mode overhead for the Pallas
    kernel, the SLICE arc filter for the hybrid frontier.  The constants
    descend from the measured ``bench_output.txt`` frontier that used to
    live hard-coded in ``choose_engine``; calibration replaces all of this
    with on-hardware fits.
    """
    f, u, k, q = float(s.n_facilities), float(s.n_users), float(s.k), float(s.q)
    m = s.m() if s.m_tris is not None else est_scene_tris(s.n_facilities, s.k)
    scene = 2e-4 + 1.0e-6 * f + 1.5e-5 * m  # prune + occluder fan, per query
    if name in ("dense", "dense-ref"):
        slow = 40.0 if name == "dense" else 1.0  # interpret-mode penalty
        return q * scene, slow * (3e-4 + 4e-9 * q * u * m)
    if name == "grid":
        # gather-bound kernel: every user pays the PADDED max list width
        # (the [Q, N, L, 3, 3] gather), so verify scales with u·pw like
        # the bucketed family — only the constant differs
        return (
            q * (scene + 2e-3 + 4e-5 * m),
            5e-4 + 1.2e-8 * q * (u * s.pw()) * max(m / 6.0, 4.0),
        )
    if name in ("grid-pallas", "grid-pallas-ref"):
        # cell-bucketed kernel: the user->cell sort is shared across the
        # batch (u-term outside q), plane packing rides the index build;
        # verify drops the per-user gather to per-cell plane staging but
        # pays for PADDED rows — u·pw, not u (occupancy feature)
        slow = 40.0 if name == "grid-pallas" else 1.0  # interpret-mode penalty
        return (
            q * (scene + 2e-3 + 5e-5 * m) + 3e-8 * u,
            slow * (5e-4 + 4e-9 * q * (u * s.pw()) * max(m / 6.0, 4.0)),
        )
    if name == "bvh":
        # per-lane while_loop under vmap: SIMD-hostile, pays ~O(m) per user
        return q * (scene + 5e-4 + 1.2e-5 * m), 1e-3 + 1.5e-7 * q * u * m
    if name == "brute":
        return 1e-5, 3e-4 + 5e-9 * q * u * f
    if name == "slice":
        # old choose_engine constants: 0.002·F filter, 0.4·k^1.5·(U/F) verify (ms)
        return q * (1e-3 + 2e-6 * f), q * 4e-7 * (k**1.5) * (u / max(f, 1.0))
    raise KeyError(name)


_PRIOR_BACKENDS = (
    "dense",
    "dense-ref",
    "grid",
    "grid-pallas",
    "grid-pallas-ref",
    "bvh",
    "brute",
    "slice",
)


def builtin_profile() -> PlannerProfile:
    """The no-calibration fallback: power-law models fitted to the analytic
    priors over a shape grid (cached; deterministic)."""
    global _builtin
    if _builtin is not None:
        return _builtin
    # pad_waste varies independently of u (clustered regimes) so the
    # grid-pallas family's log_pw exponent is identifiable; None exercises
    # the uniform-density fallback the planner uses pre-measurement
    shapes = [
        WorkloadShape(f, u, k, q, m_tris=mt, pad_waste=pw)
        for f in (30, 100, 1_000, 10_000)
        for u in (1_000, 20_000, 1_000_000)
        for k in (1, 10, 100)
        for q in (1, 16, 128)
        for mt in (None, est_scene_tris(f, k) * 2.0)
        for pw in (None, 4.0, 16.0)
    ]
    models = {}
    for name in _PRIOR_BACKENDS:
        times = np.array([_prior_times(name, s) for s in shapes])
        # only the grid family pays pad waste; everyone else pins the
        # exponent to zero instead of aliasing it against log_u
        drop = (
            ()
            if name in ("grid", "grid-pallas", "grid-pallas-ref")
            else ("log_pw",)
        )
        models[name] = BackendCostModel.fit(
            name, shapes, times[:, 0], times[:, 1], drop=drop
        )
    _builtin = PlannerProfile(
        models=models,
        created_at=0.0,
        hardware={},
        source="builtin-prior",
        meta={"note": "analytic priors; run repro.planner.calibrate to replace"},
    )
    return _builtin


_disk_checked = False


def active_or_builtin() -> PlannerProfile:
    """The profile the planner actually uses: the active one, else (once
    per process) a ``REPRO_PLANNER_PROFILE`` file if the operator pointed
    the env var at one, else the analytic built-in prior."""
    prof = get_active_profile()
    if prof is not None:
        return prof
    global _disk_checked
    if not _disk_checked:
        _disk_checked = True
        if os.environ.get(_PROFILE_ENV):
            prof = activate_from_disk()
            if prof is not None:
                return prof
    return builtin_profile()


def activate_from_disk(path: str | None = None) -> PlannerProfile | None:
    """Best-effort load-and-activate (missing/stale files return ``None``)."""
    try:
        prof = load_profile(path)
    except (OSError, ValueError, KeyError, json.JSONDecodeError):
        return None
    set_active_profile(prof)
    return prof
