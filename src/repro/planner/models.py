"""Parametric per-backend cost models over the workload shape.

Each backend gets two fitted models — one for the host *filter* phase
(scene construction + index build + batch stacking) and one for the
device *verify* phase (the counting dispatch) — mirroring the paper's
two-stage timing convention, so a scene-cache hit can be priced as
"verify only".

The model family is a **power law**: ``t ≈ exp(w · φ(shape))`` with
``φ`` a fixed vector of log-features of (|F|, |U|, k, Q, m).  Fitting is
ridge-regularized least squares on ``log t``, which is robust to the
orders-of-magnitude spread between backends, always predicts positive
times, and extrapolates scaling laws measured on small calibration shapes
to production cardinalities (the k-distance-approximation line of work
shows fitted models stand in well for exact index decisions).

``m`` is the occluder-scene triangle count — the verify phase's true size
driver for geometric backends.  When the planner prices a query *before*
building its scene, ``m`` is estimated from (|F|, k) via
:func:`est_scene_tris`; once scenes exist, the actual ``n_tris`` is used
(this per-query variation is what lets the planner split one batch across
backends).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "WorkloadShape",
    "est_scene_tris",
    "est_pad_waste",
    "FEATURE_NAMES",
    "SIGN_FREE_FEATURES",
    "featurize",
    "CostModel",
    "BackendCostModel",
]


def est_scene_tris(n_facilities: int, k: int) -> float:
    """Expected occluder-triangle count of an InfZone-pruned scene.

    Pruning retains ~O(k) influencing facilities (each contributing a
    constant number of fan triangles after clipping); the scene can never
    exceed one occluder fan per competitor facility.
    """
    return float(min(max(n_facilities - 1, 1) * 3.0, 6.0 * k + 24.0))


def est_pad_waste(n_users: int, grid_g: int = 64) -> float:
    """Pre-measurement estimate of the cell-bucketing pad-waste ratio.

    Assumes uniformly spread users: ``min(G², |U|)`` occupied cells, so
    the mean occupancy and :func:`repro.kernels.grid_raycast.
    auto_cell_block`'s [8, 256] power-of-two clamp give ``padded ≈
    occupied · block``.  A pure function of |U| (perfectly collinear with
    ``log_u``), so fits that only ever see this fallback must ``drop``
    the ``log_pw`` feature; calibration passes the *measured* ratio of
    the actual workload instead."""
    u = max(int(n_users), 1)
    occ = min(int(grid_g) * int(grid_g), u)
    mean = max(int(np.ceil(u / occ)), 1)
    block = int(min(256, max(8, 1 << int(np.ceil(np.log2(mean))))))
    return max(occ * block / u, 1.0)


@dataclasses.dataclass(frozen=True)
class WorkloadShape:
    """The planner's view of one (possibly batched) query workload.

    ``m_tris`` is the per-query scene triangle count when known (scenes
    already built); ``None`` prices the pre-scene estimate.  ``cache_hit``
    marks the filter phase as already amortized (scene cache / prepared-
    batch LRU), so only verify cost is charged.  ``pad_waste`` is the
    measured cell-bucketing occupancy ratio (padded user rows / real
    rows, ≥ 1) when the caller knows it — the verify cost of the
    grid-pallas family tracks the padded total, not raw |U|; ``None``
    prices the uniform-density estimate.
    """

    n_facilities: int
    n_users: int
    k: int
    q: int = 1
    m_tris: float | None = None
    cache_hit: bool = False
    pad_waste: float | None = None
    #: User-axis shard count the workload is served at
    #: (:class:`repro.shard.ShardedEngine`); 1 = single-process.
    shards: int = 1

    def m(self) -> float:
        if self.m_tris is not None:
            return max(float(self.m_tris), 1.0)
        return est_scene_tris(self.n_facilities, self.k)

    def pw(self) -> float:
        if self.pad_waste is not None:
            return max(float(self.pad_waste), 1.0)
        return est_pad_waste(self.n_users)


#: Deliberately minimal: in log space any product term (Q·U, Q·U·m, …) is
#: an exact linear combination of these base features, so adding products
#: only introduces collinearity — the ridge then splits exponent weight
#: arbitrarily between aliases and extrapolation beyond the calibration
#: grid goes wrong.  Power laws compose products for free: a backend whose
#: cost is c·Q·U·m fits as exponents (1, 1, 1) on (log_q, log_u, log_m).
FEATURE_NAMES: tuple[str, ...] = (
    "const",
    "log_f",
    "log_u",
    "log_k",
    "log_q",
    "log_m",
    "log_pw",
    "log_s",
)

#: Features whose fitted exponent may legitimately be negative.  The
#: non-negativity active set below encodes "no backend gets cheaper as
#: the workload grows" — but ``log_s`` is a *resource* feature, not a
#: size feature: more shards is supposed to make verify cheaper, so its
#: honest exponent is ≤ 0 and pinning it to zero would erase exactly the
#: scaling the feature exists to price.
SIGN_FREE_FEATURES: frozenset = frozenset({"log_s"})


def featurize(shape: WorkloadShape) -> np.ndarray:
    f = float(max(shape.n_facilities, 1))
    u = float(max(shape.n_users, 1))
    k = float(max(shape.k, 1))
    q = float(max(shape.q, 1))
    m = shape.m()
    pw = shape.pw()
    s = float(max(shape.shards, 1))
    return np.array(
        [
            1.0,
            np.log(f),
            np.log(u),
            np.log(k),
            np.log(q),
            np.log(m),
            np.log(pw),
            np.log(s),
        ],
        dtype=np.float64,
    )


@dataclasses.dataclass
class CostModel:
    """One fitted power-law phase model: ``t_s = exp(coef · φ(shape))``."""

    coef: np.ndarray  # [len(FEATURE_NAMES)]

    def predict_s(self, shape: WorkloadShape) -> float:
        return float(np.exp(np.clip(featurize(shape) @ self.coef, -50.0, 50.0)))

    def predict_many_s(self, features: np.ndarray) -> np.ndarray:
        """Vectorized prediction over a ``[Q, n_features]`` matrix (the
        batch-split hot path prices every query against every candidate)."""
        return np.exp(np.clip(features @ self.coef, -50.0, 50.0))

    @classmethod
    def fit(
        cls,
        shapes: list[WorkloadShape],
        times_s: np.ndarray,
        ridge: float = 1e-3,
        drop: tuple[str, ...] = (),
    ) -> "CostModel":
        """Ridge least squares on ``log t`` (times floored at 1 µs so a
        measured ~0 filter phase doesn't blow up the log target).

        ``drop`` names features forced to exponent 0 — physics the fit
        should not have to discover (a geometry-free backend cannot depend
        on the scene size ``m``; leaving the column in lets it steal
        correlated weight from |F| and wreck extrapolation).

        All non-``const`` exponents are constrained **non-negative**: no
        backend gets cheaper as the workload grows, so a negative exponent
        is always a collinearity artifact of the calibration grid (e.g.
        ``log_u`` stealing weight from the padded-occupancy term), and it
        extrapolates catastrophically — the PR-5 bench misrouted
        steady-state verify away from ``grid-pallas-ref`` exactly this
        way.  Enforced by an active-set loop: refit with every negative
        exponent pinned to 0 until none remain (NNLS on this feature
        count in ≤ ``len(FEATURE_NAMES)`` solves).

        ``log_q`` is additionally capped at **1**: a batched dispatch can
        always fall back to looping the single-query path ``q`` times, so
        per-batch cost is at most linear in ``q`` — a fitted exponent
        above 1 is the same kind of collinearity artifact (it makes the
        planner punish exactly the backends whose batch economies it
        should be exploiting).  Capped features contribute a fixed offset
        of ``1.0 x log_q`` to the target and leave the active set.
        """
        y = np.log(np.maximum(np.asarray(times_s, np.float64), 1e-6))
        A = np.stack([featurize(s) for s in shapes])
        pinned = set(drop)
        capped: set[str] = set()
        while True:
            keep = np.array(
                [name not in pinned and name not in capped for name in FEATURE_NAMES]
            )
            y_eff = y
            for name in capped:
                y_eff = y_eff - A[:, FEATURE_NAMES.index(name)]
            Ak = A[:, keep]
            n = Ak.shape[1]
            ck = np.linalg.solve(Ak.T @ Ak + ridge * np.eye(n), Ak.T @ y_eff)
            coef = np.zeros(len(FEATURE_NAMES))
            coef[keep] = ck
            for name in capped:
                coef[FEATURE_NAMES.index(name)] = 1.0
            negative = [
                name
                for name, c in zip(FEATURE_NAMES, coef)
                if name != "const" and name not in SIGN_FREE_FEATURES and c < 0.0
            ]
            superlinear_q = coef[FEATURE_NAMES.index("log_q")] > 1.0
            if not negative and not superlinear_q:
                return cls(coef=coef)
            pinned.update(negative)
            if superlinear_q:
                capped.add("log_q")

    def to_json(self) -> dict:
        return {"coef": [float(c) for c in self.coef]}

    @classmethod
    def from_json(cls, obj: dict) -> "CostModel":
        coef = np.asarray(obj["coef"], np.float64)
        if coef.ndim == 1 and coef.shape[0] == len(FEATURE_NAMES) - 1:
            # profile fitted before the newest trailing feature existed
            # (``log_s`` landed after the committed runner profiles):
            # exponent 0 on the missing feature prices it as neutral,
            # which is exactly what a fit with no variation would return.
            # Only the one-feature-behind schema is migrated — anything
            # shorter is a genuinely stale/corrupt profile and still
            # rejected below.
            coef = np.concatenate([coef, np.zeros(1)])
        if coef.shape != (len(FEATURE_NAMES),):
            raise ValueError(
                f"cost-model coefficient vector has shape {coef.shape}, "
                f"expected ({len(FEATURE_NAMES)},) — stale profile?"
            )
        return cls(coef=coef)


@dataclasses.dataclass
class BackendCostModel:
    """Filter + verify models for one backend name."""

    name: str
    filter: CostModel
    verify: CostModel

    def predict_total_s(self, shape: WorkloadShape) -> float:
        """Predicted wall time; a cache hit skips the filter phase."""
        t = self.verify.predict_s(shape)
        if not shape.cache_hit:
            t += self.filter.predict_s(shape)
        return t

    def predict_total_many_s(
        self, features: np.ndarray, cache_hit: np.ndarray
    ) -> np.ndarray:
        """Vectorized :meth:`predict_total_s` over pre-featurized shapes."""
        t = self.verify.predict_many_s(features)
        miss = ~np.asarray(cache_hit, bool)
        if miss.any():
            t = t + miss * self.filter.predict_many_s(features)
        return t

    @classmethod
    def fit(
        cls,
        name: str,
        shapes: list[WorkloadShape],
        t_filter_s: np.ndarray,
        t_verify_s: np.ndarray,
        drop: tuple[str, ...] = (),
    ) -> "BackendCostModel":
        return cls(
            name=name,
            filter=CostModel.fit(shapes, t_filter_s, drop=drop),
            verify=CostModel.fit(shapes, t_verify_s, drop=drop),
        )

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "filter": self.filter.to_json(),
            "verify": self.verify.to_json(),
        }

    @classmethod
    def from_json(cls, obj: dict) -> "BackendCostModel":
        return cls(
            name=obj["name"],
            filter=CostModel.from_json(obj["filter"]),
            verify=CostModel.from_json(obj["verify"]),
        )
