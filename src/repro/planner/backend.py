"""``auto``: the planner as a registered backend.

:class:`PlannerBackend` never counts anything itself — it prices every
registered concrete backend with the active (or built-in prior) profile
and delegates to the predicted-cheapest one.  The
:class:`~repro.core.engine.RkNNEngine` integrates it at the *planning*
level (``is_meta = True``): single queries are re-routed before any scene
is built (so a brute decision skips the filter phase entirely), and
batches are optionally **split** — once scenes exist, each query is
re-priced with its actual scene size and the batch is partitioned into
per-backend groups whose counts are recombined in order.

Used directly through the raw ``Backend`` protocol (no engine), it still
works: ``count``/``count_batch`` select among the backends the request
can actually feed and delegate, without splitting.

``explain()`` returns the most recent plan; the engine keeps a rolling
log of plans (``RkNNEngine.explain()``) and accumulates predicted vs.
observed cost in ``EngineStats``.
"""

from __future__ import annotations

import threading

import numpy as np

from repro.planner.models import WorkloadShape
from repro.planner.profiles import (
    PlannerProfile,
    active_or_builtin,
    get_active_profile,
    note_recalibrated,
    set_active_profile,
)

__all__ = ["PlannerBackend"]

#: Online re-calibration constants: damped step size on the log-cost
#: residual, per-observation residual clip, and the cumulative absolute
#: drift past which memoized plans are re-priced (epoch bump).
RECAL_LR = 0.2
RECAL_CLIP = 2.0
RECAL_EPOCH_DRIFT = 0.5


class PlannerBackend:
    """Cost-dispatching meta-backend (registered as ``"auto"``).

    Duck-types the :class:`repro.core.backends.Backend` protocol instead
    of subclassing it: ``core.backends`` imports this module to register
    it, so this module must not import ``core.backends`` at import time
    (all core imports live inside methods, keeping the edge acyclic in
    either import order).
    """

    name = "auto"
    is_meta = True
    uses_scene = True  # may route to geometric backends

    #: A heterogeneous batch is split across backends only when the
    #: predicted per-query total undercuts the best single-backend total
    #: by at least this factor — splitting costs an extra dispatch per
    #: group and per-query predictions near the frontier are the model's
    #: least certain, so close calls consolidate to one backend.
    split_margin = 0.8

    def __init__(self) -> None:
        self._last_plan: dict | None = None
        self._lock = threading.Lock()
        self._recal_drift = 0.0
        self.n_recal_nudges = 0

    # ------------------------------------------------------------------
    # pricing
    # ------------------------------------------------------------------
    def profile(self) -> PlannerProfile:
        return active_or_builtin()

    def candidates(self, profile: PlannerProfile | None = None) -> tuple[str, ...]:
        """Concrete registered backends the profile can price."""
        from repro.core.backends import concrete_backends

        prof = profile or self.profile()
        return tuple(n for n in concrete_backends() if n in prof.models)

    def rank(
        self, shape: WorkloadShape, candidates: tuple[str, ...] | None = None
    ) -> list[tuple[str, float]]:
        """Candidates sorted cheapest-first for ``shape``."""
        prof = self.profile()
        return prof.rank(shape, candidates or self.candidates(prof))

    def select(
        self, shape: WorkloadShape, candidates: tuple[str, ...] | None = None
    ) -> tuple[str, float, dict[str, float]]:
        """(chosen backend, predicted seconds, all candidate costs)."""
        ranked = self.rank(shape, candidates)
        return ranked[0][0], ranked[0][1], dict(ranked)

    def assign_batch(
        self,
        shapes: list[WorkloadShape],
        candidates: tuple[str, ...] | None = None,
    ) -> list[tuple[str, float]]:
        """Per-query (backend, predicted seconds) for an already-filtered
        batch — shapes carry actual scene sizes and ``cache_hit=True`` so
        only verify-side cost differentiates the candidates.

        Splitting is *conservative*: the free-choice per-query assignment
        is kept only when its predicted total beats the best single
        backend's total by more than ``split_margin``; otherwise the whole
        batch consolidates onto that single backend (all costs compared at
        the same per-query granularity, so the margin is apples-to-apples).

        Queries are priced at the backend's **average per-query cost at
        this batch's scale** — ``predict(q=Q) / Q`` — not its standalone
        ``q=1`` cost: a backend with batch economies (the bucketed grid
        shares one user sort across the dispatch; its fitted q-exponent is
        well below 1) serves a query inside a Q-batch far cheaper than
        alone.  This keeps the per-query partition consistent with the
        batch-level rank (a single-backend assignment sums to exactly the
        batch prediction) instead of systematically flipping batch-economy
        backends onto their unamortized q=1 cost.
        """
        import dataclasses

        import numpy as np

        from repro.planner.models import featurize

        prof = self.profile()
        cands = candidates or self.candidates(prof)
        Q = max(len(shapes), 1)
        feats = np.stack(
            [featurize(dataclasses.replace(s, q=Q)) for s in shapes]
        )  # [Q, n_features], each priced at full-batch scale
        hits = np.array([s.cache_hit for s in shapes], bool)
        costs = np.stack(
            [prof.models[c].predict_total_many_s(feats, hits) / Q for c in cands]
        )  # [C, Q] average per-query cost within this batch
        totals = costs.sum(axis=1)
        best_single = int(np.argmin(totals))
        winner = np.argmin(costs, axis=0)  # [Q]
        split_total = float(costs[winner, np.arange(len(shapes))].sum())
        if split_total < self.split_margin * float(totals[best_single]):
            return [
                (cands[int(w)], float(costs[int(w), i]))
                for i, w in enumerate(winner)
            ]
        return [
            (cands[best_single], float(costs[best_single, i]))
            for i in range(len(shapes))
        ]

    # ------------------------------------------------------------------
    # online re-calibration
    # ------------------------------------------------------------------
    def _pred_obs_pairs(self, plan: dict):
        """(backend, predicted_s, observed_s, verify_only) per dispatch
        the plan actually ran — the live residual signal."""
        mode = plan.get("mode", "")
        if mode in ("single", "stream-batch"):
            name, pred, obs = (
                plan.get("backend"),
                plan.get("predicted_s"),
                plan.get("observed_s"),
            )
            if name is None or pred is None or obs is None:
                return
            yield name, pred, obs, bool(plan.get("cache_hit") or plan.get("amortized"))
            return
        if mode == "batch":
            assigned = plan.get("assignments") or []
            per_q = plan.get("predicted_per_query") or []
            observed = plan.get("observed_group_s") or {}
            for name, obs in observed.items():
                pred = sum(c for a, c in zip(assigned, per_q) if a == name)
                # batch groups are priced post-scene (filter cost sunk)
                yield name, pred, obs, True

    def observe(self, plan: dict) -> int:
        """Damped online re-calibration from one closed-out plan.

        Each dispatched backend's log-cost residual ``log(obs / pred)``
        (clipped) nudges the **active** profile's constant coefficients —
        verify only when the filter phase was amortized away, both phases
        otherwise.  The built-in prior is never mutated in place: if no
        profile is active, a private copy is activated first.  Cumulative
        drift past ``RECAL_EPOCH_DRIFT`` bumps the profile epoch so
        memoized batch plans are re-priced.  Returns the nudge count.
        """
        import copy

        prof = get_active_profile()
        if prof is None:
            prof = copy.deepcopy(active_or_builtin())
            prof.source = prof.source + "+online"
            set_active_profile(prof)
        n = 0
        with self._lock:
            for name, pred, obs, verify_only in self._pred_obs_pairs(plan):
                model = prof.models.get(name)
                if model is None:
                    continue
                r = float(
                    np.clip(np.log(max(obs, 1e-7) / max(pred, 1e-7)),
                            -RECAL_CLIP, RECAL_CLIP)
                )
                delta = RECAL_LR * r
                model.verify.coef[0] += delta
                if not verify_only:
                    model.filter.coef[0] += delta
                self._recal_drift += abs(delta)
                n += 1
                self.n_recal_nudges += 1
            if self._recal_drift >= RECAL_EPOCH_DRIFT:
                self._recal_drift = 0.0
                note_recalibrated()
        return n

    # ------------------------------------------------------------------
    # explain
    # ------------------------------------------------------------------
    def record(self, plan: dict) -> None:
        with self._lock:
            self._last_plan = plan

    def explain(self) -> dict | None:
        """The most recent plan routed through this planner instance."""
        with self._lock:
            return self._last_plan

    # ------------------------------------------------------------------
    # raw Backend protocol (direct use, no engine): delegate, no split
    # ------------------------------------------------------------------
    def build_index(self, scene, *, grid_g: int = 64, memo: dict | None = None):
        return None

    def prepare_batch(self, req):
        return None

    def _direct_candidates(self, *, has_scene: bool, has_points: bool):
        from repro.core.backends import get_backend

        names = []
        for n in self.candidates():
            b = get_backend(n)
            if b.uses_scene and has_scene:
                names.append(n)
            elif not b.uses_scene and has_points:
                names.append(n)
        if not names:
            raise ValueError(
                "auto backend: request carries neither a scene nor raw "
                "facility/user points any priced backend can consume"
            )
        return tuple(names)

    def count(self, req):
        from repro.core.backends import get_backend

        n_u = int(req.xs.shape[0]) if req.xs is not None else len(req.users)
        n_f = len(req.facilities) if req.facilities is not None else req.k
        shape = WorkloadShape(
            n_f, n_u, req.k, 1,
            m_tris=None if req.scene is None else req.scene.n_tris,
            cache_hit=req.scene is not None,  # scene already built: verify only
        )
        cands = self._direct_candidates(
            has_scene=req.scene is not None,
            has_points=req.users is not None and req.q_pt is not None,
        )
        choice, pred, costs = self.select(shape, cands)
        self.record(
            {"mode": "direct-single", "backend": choice, "predicted_s": pred,
             "candidates": costs}
        )
        b = get_backend(choice)
        if b.uses_scene and req.index is None:
            req.index = b.build_index(req.scene, grid_g=req.grid_g)
        return b.count(req)

    def count_batch(self, req, prepared):
        from repro.core.backends import get_backend

        n_u = int(req.xs.shape[0]) if req.xs is not None else len(req.users)
        n_f = len(req.facilities) if req.facilities is not None else req.k
        q = len(req.q_pts) if req.q_pts is not None else len(req.scenes or ())
        has_scenes = bool(req.scenes)
        shape = WorkloadShape(
            n_f, n_u, req.k, max(q, 1),
            m_tris=max(s.n_tris for s in req.scenes) if has_scenes else None,
            cache_hit=has_scenes,
        )
        cands = self._direct_candidates(
            has_scene=has_scenes,
            has_points=req.users is not None and req.q_pts is not None,
        )
        choice, pred, costs = self.select(shape, cands)
        self.record(
            {"mode": "direct-batch", "backend": choice, "predicted_s": pred,
             "candidates": costs}
        )
        b = get_backend(choice)
        return b.count_batch(req, b.prepare_batch(req))
