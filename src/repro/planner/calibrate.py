"""On-hardware calibration: micro-benchmark every backend, fit cost models.

For each shape in the synthetic calibration grid
(:func:`repro.workloads.calibration_grid`) and each registered concrete
backend, a throwaway one-shot engine runs the batched query and reports
the paper's two-phase split — host filter time and device verify time —
which become the fit targets for that backend's
:class:`~repro.planner.models.BackendCostModel`.  SLICE (not a registered
``Backend`` — it is the filter–refine baseline the hybrid frontier
compares against) is measured alongside so
:func:`repro.core.hybrid.choose_engine` can price it from the same
profile.

Each (shape, backend) cell is warmed once before timing so XLA
compilation does not land in the fit, and the best of ``repeats`` runs is
kept (micro-benchmark convention; scheduler noise only ever adds time).

CLI::

    PYTHONPATH=src python -m repro.planner.calibrate \
        --out planner_profile.json [--full] [--repeats 2] [--activate]

With no ``--out`` the profile is written to the default store
(:func:`repro.planner.profiles.default_profile_path`).
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.planner.models import BackendCostModel, WorkloadShape
from repro.planner.profiles import (
    PROFILE_VERSION,
    PlannerProfile,
    default_profile_path,
    hardware_fingerprint,
    runner_profile_path,
    set_active_profile,
)
from repro.workloads import Scenario, Workload, calibration_grid

__all__ = ["calibrate", "measure_backend", "measure_slice", "main"]


def _mean_scene_tris(w: Workload) -> float:
    """Mean occluder-scene triangle count over the workload's queries."""
    from repro.core.geometry import Rect
    from repro.core.scene import build_scene

    rect = Rect.from_points(w.facilities, w.users)
    sizes = [
        build_scene(w.facilities, qi, w.k, rect, users_hint=w.users).n_tris
        for qi in w.qs
    ]
    return float(max(np.mean(sizes), 1.0))


def _measured_pw(w: Workload, grid_g: int = 64) -> float:
    """Exact cell-bucketing pad-waste ratio of the workload's users."""
    from repro.core.geometry import Rect
    from repro.kernels.grid_raycast import measured_pad_waste

    rect = Rect.from_points(w.facilities, w.users)
    return measured_pad_waste(w.users[:, 0], w.users[:, 1], rect, grid_g)


def measure_backend(
    w: Workload, backend: str, repeats: int = 2
) -> tuple[float, float]:
    """(t_filter_s, t_verify_s) for one batched call of ``backend``."""
    from repro.core.rknn import rt_rknn_query_batch

    rt_rknn_query_batch(w.facilities, w.users, w.qs, w.k, backend=backend)  # warm
    best = (np.inf, np.inf)
    for _ in range(max(repeats, 1)):
        r = rt_rknn_query_batch(w.facilities, w.users, w.qs, w.k, backend=backend)
        if r.t_filter_s + r.t_verify_s < sum(best):
            best = (r.t_filter_s, r.t_verify_s)
    return best


def measure_slice(w: Workload, repeats: int = 2) -> tuple[float, float]:
    """(t_filter_s, t_verify_s) of SLICE looped over the batch's queries."""
    from repro.core.baselines.slice import slice_rknn

    best = (np.inf, np.inf)
    for _ in range(max(repeats, 1)):
        tf = tv = 0.0
        for qi in w.qs:
            _, info = slice_rknn(w.facilities, w.users, qi, w.k)
            tf += info.get("t_filter_s", 0.0)
            tv += info.get("t_verify_s", 0.0)
        if tf + tv < sum(best):
            best = (tf, tv)
    return best


def calibrate(
    backends: tuple[str, ...] | None = None,
    *,
    scenarios: list[Scenario] | None = None,
    fast: bool = True,
    repeats: int = 2,
    include_slice: bool = True,
    seed: int = 0,
    verbose: bool = False,
) -> PlannerProfile:
    """Micro-benchmark ``backends`` over the shape grid and fit a profile.

    ``scenarios`` overrides the grid (tests pass tiny shapes); ``fast``
    selects the CI-sized grid.  Returns the fitted, versioned profile —
    the caller decides whether to save and/or activate it.

    The default backend set is :func:`repro.core.backends.timeable_backends`:
    any registered backend is calibratable, but kernel backends running in
    interpret mode on this host (``interpret_mode_on_cpu``) are skipped by
    default — their interpret wall time is a property of the simulator, not
    of the backend, and a profile fitted to it would misprice a real TPU.
    Pass ``backends=`` explicitly to measure them anyway.
    """
    if backends is None:
        from repro.core.backends import timeable_backends

        backends = timeable_backends()
    if scenarios is None:
        scenarios = calibration_grid(fast=fast, seed=seed)
    workloads = [sc.generate() for sc in scenarios]
    # fit with the MEASURED mean scene size, not the (F, k)-derived
    # estimate: an estimated m is an exact function of the other features,
    # and fitting on it aliases the m exponent against F and k — the model
    # then misprices any query whose actual scene size is substituted
    # pad_waste is likewise MEASURED (exact bucketing ratio of the actual
    # user set), so clustered regimes teach the occupancy exponent instead
    # of the uniform-density fallback, which is a pure function of u
    shapes = [
        WorkloadShape(
            len(w.facilities), len(w.users), w.k, len(w.qs),
            m_tris=_mean_scene_tris(w),
            pad_waste=_measured_pw(w),
        )
        for w in workloads
    ]

    from repro.core.backends import get_backend

    models: dict[str, BackendCostModel] = {}
    targets = list(backends) + (["slice"] if include_slice else [])
    for name in targets:
        tf = np.zeros(len(workloads))
        tv = np.zeros(len(workloads))
        for i, w in enumerate(workloads):
            if name == "slice":
                tf[i], tv[i] = measure_slice(w, repeats=repeats)
            else:
                tf[i], tv[i] = measure_backend(w, name, repeats=repeats)
            if verbose:
                print(
                    f"  {name:10s} {w.name:24s} filter={tf[i]*1e3:8.2f}ms "
                    f"verify={tv[i]*1e3:8.2f}ms",
                    file=sys.stderr,
                )
        # geometry-free methods cannot depend on the scene size — pin that
        # exponent to zero instead of letting it alias against |F|; only
        # the grid family pays pad waste (the bucketed kernel stages padded
        # cell rows, the gather kernel pays the max list width L per user),
        # so every other backend pins log_pw rather than letting it alias
        # against log_u
        scene_free = name == "slice" or not get_backend(name).uses_scene
        drop: tuple[str, ...] = ("log_m",) if scene_free else ()
        if name not in ("grid", "grid-pallas", "grid-pallas-ref"):
            drop = drop + ("log_pw",)
        models[name] = BackendCostModel.fit(name, shapes, tf, tv, drop=drop)

    return PlannerProfile(
        models=models,
        version=PROFILE_VERSION,
        created_at=time.time(),
        hardware=hardware_fingerprint(),
        source="calibrated",
        meta={
            "n_shapes": len(workloads),
            "repeats": repeats,
            "fast": fast,
            "backends": list(targets),
        },
    )


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--out", default=None, help="profile path (default: store)")
    ap.add_argument("--full", action="store_true", help="full shape grid")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--no-slice", action="store_true")
    ap.add_argument(
        "--activate", action="store_true",
        help="install as the process-wide active profile after saving",
    )
    ap.add_argument(
        "--runner-store", default=None, metavar="DIR",
        help="also write the profile to DIR/<runner-class>.json (the "
        "committed per-runner-class store, e.g. benchmarks/profiles)",
    )
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    prof = calibrate(
        fast=not args.full,
        repeats=args.repeats,
        include_slice=not args.no_slice,
        verbose=args.verbose,
    )
    path = prof.save(args.out or default_profile_path())
    if args.runner_store:
        rpath = prof.save(runner_profile_path(args.runner_store))
        print(f"runner-class profile -> {rpath}", file=sys.stderr)
    if args.activate:
        set_active_profile(prof)
    print(
        f"calibrated {len(prof.models)} backends on "
        f"{prof.meta['n_shapes']} shapes in {time.perf_counter() - t0:.1f}s "
        f"-> {path}",
        file=sys.stderr,
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
