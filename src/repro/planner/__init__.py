"""Adaptive query planner: calibrated per-backend cost models + dispatch.

The paper's core claim is regime-dependent — ray casting wins at sparse
facilities / dense users / large ``k``, filter–refine methods elsewhere —
so a production engine must pick the right execution path *per query*.
This package turns that frontier into data:

* :mod:`repro.planner.models` — parametric (power-law) cost models over
  the workload shape (|F|, |U|, k, Q, scene size, cache hit/miss);
* :mod:`repro.planner.calibrate` — an on-hardware harness that
  micro-benchmarks every registered backend on synthetic shape grids and
  fits the models;
* :mod:`repro.planner.profiles` — a versioned JSON store for fitted
  profiles, plus the process-wide *active* profile and a built-in prior
  fallback;
* :mod:`repro.planner.backend` — :class:`PlannerBackend`, registered as
  ``"auto"`` in the backend registry: cost-dispatches each request to the
  predicted-cheapest concrete backend, splitting heterogeneous batches.
"""

from repro.planner.models import BackendCostModel, CostModel, WorkloadShape, est_scene_tris
from repro.planner.profiles import (
    PROFILE_VERSION,
    PlannerProfile,
    builtin_profile,
    get_active_profile,
    load_profile,
    set_active_profile,
)

__all__ = [
    "WorkloadShape",
    "CostModel",
    "BackendCostModel",
    "est_scene_tris",
    "PlannerProfile",
    "PROFILE_VERSION",
    "builtin_profile",
    "get_active_profile",
    "set_active_profile",
    "load_profile",
]
