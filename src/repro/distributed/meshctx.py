"""Process-global mesh context for sharding constraints inside model code.

Model code calls ``constrain(x, ("data", None, "model"))`` with *logical*
axis tuples; when a mesh is active the constraint becomes a
``with_sharding_constraint`` with the corresponding ``NamedSharding`` and
the special logical name ``"data"`` expands to the full data-parallel axis
group (``("pod", "data")`` on multi-pod meshes).  With no active mesh
(smoke tests, single-device) it is a no-op, so models stay mesh-agnostic.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "set_mesh",
    "get_mesh",
    "active_mesh",
    "constrain",
    "dp_axes",
    "user_axes",
    "logical_to_spec",
]

_state = threading.local()


def set_mesh(mesh: Mesh | None) -> None:
    _state.mesh = mesh


def get_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def active_mesh(mesh: Mesh):
    prev = get_mesh()
    set_mesh(mesh)
    try:
        yield mesh
    finally:
        set_mesh(prev)


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """All data-parallel mesh axes (includes 'pod' when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def user_axes(mesh: Mesh) -> tuple[str, ...]:
    """The axes the RkNN *user* population shards over: a dedicated
    ``'users'`` axis when the mesh has one (the serving mesh of
    :mod:`repro.shard`), else the data-parallel group (training-style
    meshes reuse their DP axes for the user rows)."""
    if "users" in mesh.axis_names:
        return ("users",)
    return dp_axes(mesh)


def logical_to_spec(mesh: Mesh, logical: tuple) -> P:
    """Map logical axis names to a PartitionSpec on the active mesh."""
    out = []
    for ax in logical:
        if ax is None:
            out.append(None)
        elif ax == "data":
            axes = dp_axes(mesh)
            out.append(axes if len(axes) > 1 else (axes[0] if axes else None))
        elif ax == "users":
            axes = user_axes(mesh)
            out.append(axes if len(axes) > 1 else (axes[0] if axes else None))
        elif ax == "batch_all":  # every mesh axis as one DP group (dp_only)
            axes = dp_axes(mesh) + tuple(a for a in ("model",) if a in mesh.axis_names)
            out.append(axes if len(axes) > 1 else (axes[0] if axes else None))
        elif ax in mesh.axis_names:
            out.append(ax)
        else:  # axis not on this mesh -> replicate
            out.append(None)
    return P(*out)


def constrain(x, logical: tuple):
    """Apply a sharding constraint if a mesh is active (else identity)."""
    mesh = get_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(mesh, logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
