"""GPipe-style pipeline parallelism over a 'pipe' mesh axis.

The mesh gains a leading ``pipe`` axis of P stages; the layer stack
``[L, ...]`` is sharded over it (each stage owns ``L/P`` contiguous
layers).  Microbatches stream through the classic GPipe schedule inside a
``shard_map``: at tick ``t`` stage ``s`` processes microbatch ``t - s``
(bubble at the ends), and activations hop stages with ``lax.ppermute`` —
which is differentiable (its transpose is the reverse permutation), so the
same loop trains: JAX AD replays the schedule backwards, giving the GPipe
backward with per-stage remat.

Scope: a self-contained pipeline runner for *homogeneous* layer stacks
(one ``BlockGroup`` — every assigned dense arch qualifies), used as the
``pp`` layout variant in the dry-run (§Perf: DP×PP×TP llama3 cell) and
numerically validated against sequential execution in
``tests/test_pipeline.py``.

Schedule cost model: ``T = (M + P − 1)/M`` of the per-microbatch work
(pipeline bubble); activations crossing stages are ``[mb, S, d]`` per tick
on one ICI hop — visible as ``collective-permute`` bytes in the dry-run
HLO, where the baseline has all-gathers instead.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = ["pipeline_forward", "make_pp_mesh"]


def make_pp_mesh(pipe: int = 4, data: int = 4, model: int = 16):
    """Alternative single-pod layout: 'pipe' x 'data' x 'model' (= 256)."""
    return jax.make_mesh((pipe, data, model), ("pipe", "data", "model"))


def pipeline_forward(
    layer_fn: Callable,
    stacked_params,
    x,
    *,
    mesh: Mesh,
    n_microbatches: int,
    axis: str = "pipe",
):
    """Run ``x`` through the pipelined layer stack.

    ``layer_fn(params_slice, h) -> h`` applies ONE layer.
    ``stacked_params``: pytree with leading layer dim ``L`` (sharded over
    ``axis`` by the caller's in_shardings; inside the shard_map each stage
    sees its local ``[L/P, ...]`` slice).
    ``x``: ``[B, ...]`` activations; ``B % n_microbatches == 0``.

    Returns ``y`` with the same shape as ``x``.  Degenerate P=1 meshes fall
    back to a plain scan (keeps tests runnable on 1 device).
    """
    p_stages = mesh.shape[axis]
    B = x.shape[0]
    assert B % n_microbatches == 0, (B, n_microbatches)
    mb = B // n_microbatches
    xm = x.reshape(n_microbatches, mb, *x.shape[1:])

    def stage_scan(local_params, h):
        def body(c, pslice):
            return layer_fn(pslice, c), None

        out, _ = lax.scan(body, h, local_params)
        return out

    if p_stages == 1:
        def run1(local_params, xm_):
            def mb_body(_, xb):
                return None, stage_scan(local_params, xb)

            _, ym = lax.scan(mb_body, None, xm_)
            return ym

        ym = run1(stacked_params, xm)
        return ym.reshape(B, *x.shape[1:])

    n_ticks = n_microbatches + p_stages - 1
    fwd_perm = [(i, (i + 1) % p_stages) for i in range(p_stages)]

    # everything except the pipe-sharded params is replicated across the
    # pipe axis; data/model sharding is untouched (specs below only name
    # the pipe axis; other axes stay open via unreduced dims)
    param_spec = jax.tree.map(lambda _: P(axis), stacked_params)

    @jax.checkpoint
    def _stage_step(local_params, h):
        return stage_scan(local_params, h)

    def run(local_params, xm_):
        stage = lax.axis_index(axis)
        state = jnp.zeros_like(xm_[0])  # in-flight activation of this stage
        outs0 = jnp.zeros_like(xm_)

        def tick(carry, t):
            state, outs = carry
            # stage 0 injects microbatch t (while t < M); other stages use
            # the activation handed over from the previous stage
            inject_idx = jnp.clip(t, 0, n_microbatches - 1)
            h_in = jnp.where(
                (stage == 0) & (t < n_microbatches),
                xm_[inject_idx],
                state,
            )
            h_out = _stage_step(local_params, h_in)
            # last stage emits microbatch t - (P-1) when valid
            emit_idx = jnp.clip(t - (p_stages - 1), 0, n_microbatches - 1)
            emit = (stage == p_stages - 1) & (t >= p_stages - 1)
            outs = lax.dynamic_update_index_in_dim(
                outs,
                jnp.where(emit, h_out, outs[emit_idx]),
                emit_idx,
                axis=0,
            )
            # hand activations to the next stage
            state_next = lax.ppermute(h_out, axis, fwd_perm)
            return (state_next, outs), None

        (state, outs), _ = lax.scan(tick, (state, outs0), jnp.arange(n_ticks))
        # result lives on the last stage; broadcast it around the ring so
        # every stage returns the same (out_specs reduce over 'pipe')
        outs = lax.psum(
            jnp.where(stage == p_stages - 1, outs, jnp.zeros_like(outs)), axis
        )
        return outs

    sm = shard_map(
        run,
        mesh=mesh,
        in_specs=(param_spec, P()),
        out_specs=P(),
        check_rep=False,
    )
    ym = jax.jit(sm)(stacked_params, xm)  # shard_map requires jit context
    return ym.reshape(B, *x.shape[1:])
