"""Parameter/activation sharding rules (FSDP x TP on the production mesh).

The scheme (DESIGN.md §9):

* ``'model'`` (TP, 16-way): attention head projections, FFN hidden, vocab,
  MoE expert axis (expert parallelism), RG-LRU width.
* ``'data'`` (FSDP, 16-way per pod; joined with ``'pod'`` across pods):
  the *other* big matrix dimension of every weight — parameters, gradients
  and Adam moments are all fully sharded (ZeRO-3); XLA inserts the
  per-layer all-gathers inside the layer scan.
* Dims that don't divide by the mesh axis fall back to replication —
  decided per-leaf against the actual mesh (``maybe``-rules), so e.g.
  mamba2's 50280 vocab simply stays unsharded over 'model' instead of
  forcing uneven partitions.

KV caches shard batch over data and heads over model when divisible, else
head_dim over model (contraction-dim sharding costs one small all-reduce in
the attention einsum; head sharding costs nothing — preference encoded in
``kv_cache_spec``).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.meshctx import dp_axes, logical_to_spec

__all__ = [
    "axis_size",
    "param_logical_spec",
    "params_shardings",
    "batch_shardings",
    "cache_shardings",
    "replicated",
    "tree_shardings",
    "user_shard_bounds",
]


def user_shard_bounds(n_users: int, n_shards: int) -> np.ndarray:
    """``[S+1]`` int64 balanced contiguous cut points of ``n_users`` rows.

    The canonical user-axis partition shared by :mod:`repro.shard` and
    its equivalence tests: shard ``s`` owns rows ``[bounds[s],
    bounds[s+1])`` of whatever ordering the caller shards (the sharded
    engine applies it to the *spatially sorted* permutation, so each
    shard covers a contiguous region of grid cells).  Balanced to within
    one row, monotone, ``bounds[0] == 0`` and ``bounds[S] == n_users``.
    """
    s = max(int(n_shards), 1)
    return (np.arange(s + 1, dtype=np.int64) * int(n_users)) // s


def axis_size(mesh: Mesh, logical: Any) -> int:
    if logical is None:
        return 1
    if logical == "data":
        return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))
    if logical == "batch_all":
        n = int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))
        return n * (int(mesh.shape["model"]) if "model" in mesh.axis_names else 1)
    return int(mesh.shape[logical]) if logical in mesh.axis_names else 1


def _fit(mesh: Mesh, shape: tuple[int, ...], logical: tuple) -> tuple:
    """Drop logical axes that don't divide the corresponding dim."""
    out = []
    for dim, ax in zip(shape, logical):
        out.append(ax if ax is not None and dim % axis_size(mesh, ax) == 0 else None)
    return tuple(out)


def param_logical_spec(
    path: tuple[str, ...], shape: tuple[int, ...], style: str = "baseline"
) -> tuple:
    """Logical sharding for a parameter leaf, by path name + rank.

    Stacked per-layer leaves (inside ``groups``) carry a leading repeat dim
    that is never sharded.

    ``style="fsdp_out"`` (§Perf hillclimb iteration 2): the baseline rules
    put the FSDP ('data') shard on the dim the FORWARD matmul contracts —
    GSPMD then resolves the contraction by partial-summing over 'data' and
    all-reducing *activations* (measured: 60 all-reduces of [B,S,d] f32 per
    train step).  Moving the FSDP shard to the *output* dim (combined with
    'model' -> 'batch_all') makes the cheap resolution — per-layer weight
    all-gather — the only option, which is textbook FSDP.
    """
    name = path[-1]
    stacked = "groups" in path
    lead: tuple = (None,) if stacked else ()
    base_rank = len(shape) - len(lead)
    fsdp_out = style == "fsdp_out"

    def spec(*axes):
        return lead + tuple(axes)

    if name == "embed":
        return ("model", "data")
    if name == "unembed":
        return (None, "batch_all") if fsdp_out else ("data", "model")
    if name in ("scale", "bias", "norm_scale", "conv_b", "A_log", "D", "dt_bias", "lambda"):
        return lead + (None,) * base_rank
    if name in ("wq", "wk", "wv", "w_in", "w_gate", "w_gate_in", "w_x_in", "in_proj"):
        if base_rank == 3:  # MoE expert stack [E, d, f]
            return spec("model", None, "data") if fsdp_out else spec("model", "data", None)
        return spec(None, "batch_all") if fsdp_out else spec("data", "model")
    if name in ("wo", "w_out", "out_proj"):
        if base_rank == 3:  # MoE expert stack [E, f, d]
            return spec("model", None, "data")
        return spec("model", "data")
    if name in ("bq", "bk", "bv"):
        return spec("model")
    if name == "router":
        return spec(None, None) if fsdp_out else spec("data", None)
    if name == "conv_w":
        return spec(None, "model")
    if name in ("w_a", "w_i"):  # RG-LRU block-diagonal gates [nb, bs, bs]
        return spec("model", None, None)
    # default: shard the two largest dims data x model when 2-D
    if base_rank == 2:
        return spec(None, "batch_all") if fsdp_out else spec("data", "model")
    return lead + (None,) * base_rank


def params_shardings(mesh: Mesh, params_shapes, style: str = "baseline") -> Any:
    """NamedSharding pytree congruent with an eval_shape of the params."""

    def one(path, leaf):
        keys = tuple(
            k.key if hasattr(k, "key") else str(getattr(k, "idx", k)) for k in path
        )
        logical = param_logical_spec(keys, leaf.shape, style=style)
        logical = _fit(mesh, leaf.shape, logical)
        return NamedSharding(mesh, logical_to_spec(mesh, logical))

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def tree_shardings(mesh: Mesh, shapes, logical_fn) -> Any:
    def one(path, leaf):
        logical = logical_fn(path, leaf.shape)
        logical = _fit(mesh, leaf.shape, logical)
        return NamedSharding(mesh, logical_to_spec(mesh, logical))

    return jax.tree_util.tree_map_with_path(one, shapes)


def batch_shardings(mesh: Mesh, batch_shapes) -> Any:
    """tokens/labels [B, S] -> batch over all DP axes; extras [B, ...]."""

    def logical(path, shape):
        return ("data",) + (None,) * (len(shape) - 1)

    return tree_shardings(mesh, batch_shapes, logical)


def _kv_cache_logical(shape: tuple[int, ...], mesh: Mesh, style: str = "baseline") -> tuple:
    """[R, B, S, K, hd]: batch->data; K->model if divisible else hd->model.

    ``style="seq_kv"`` (§Perf decode iteration): shard the SEQUENCE dim over
    'model' instead.  With heads that don't divide the TP axis the baseline
    head-dim sharding forces full-cache all-gathers every layer (the
    attention einsum contracts the sharded hd, and the cache-update scatter
    wants yet another layout — XLA warns "involuntary full
    rematerialization"); with S sharded, scores/softmax/out are shard-local
    up to two tiny partial reductions and the position update is a
    shard-local dynamic slice."""
    R, B, S, K, hd = shape
    k_divides = K % axis_size(mesh, "model") == 0
    if style == "seq_kv" and not k_divides and S % axis_size(mesh, "model") == 0:
        # head sharding is communication-free when K divides the TP axis
        # (deepseek/whisper, K=16) — keep it; sequence sharding is the fix
        # for the non-divisible cases only.
        return (None, "data", "model", None, None)
    head_ax = "model" if K % axis_size(mesh, "model") == 0 else None
    hd_ax = None
    if head_ax is None and hd % axis_size(mesh, "model") == 0:
        hd_ax = "model"
    return (None, "data", None, head_ax, hd_ax)


def cache_shardings(mesh: Mesh, cache_shapes, style: str = "baseline") -> Any:
    """Serving-cache sharding: KV time-major tensors + recurrent states."""

    def logical(path, shape):
        name = path[-1] if path else ""
        key = name.key if hasattr(name, "key") else str(name)
        if key in ("k", "v", "xk", "xv") and len(shape) == 5:
            return _kv_cache_logical(shape, mesh, style=style)
        if key == "state" and len(shape) == 5:  # ssd state [R, B, H, N, P]
            return (None, "data", "model", None, None)
        if key == "state" and len(shape) == 3:  # rglru [R, B, w]
            return (None, "data", "model")
        if key == "conv" and len(shape) == 4:  # [R, B, W, C]
            return (None, "data", None, "model")
        if key == "pos":
            return ("data",)
        return (None, "data") + (None,) * (len(shape) - 2)

    def one(path, leaf):
        keys = tuple(path)
        lg = logical(keys, leaf.shape)
        lg = _fit(mesh, leaf.shape, lg)
        return NamedSharding(mesh, logical_to_spec(mesh, lg))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)
