"""Training launcher: config -> mesh -> data -> fault-tolerant driver.

CPU-scale entry point (the examples use it to train the ~100M model); on a
real fleet the same wiring runs under the production mesh — the driver,
checkpointing, watchdog and elastic pieces are mesh-agnostic.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2_130m \
        --steps 200 --batch 8 --seq 256 --reduced --ckpt /tmp/run1
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

import jax

from repro.configs.registry import get_config, get_reduced
from repro.data.tokens import ShardedTokenPipeline, TokenPipelineConfig
from repro.models.registry import build_model
from repro.optim.adamw import AdamWConfig
from repro.runtime.driver import DriverConfig, TrainDriver
from repro.steps.train import init_train_state, make_train_step

__all__ = ["train_main", "main"]


def train_main(
    arch: str,
    *,
    steps: int = 100,
    batch: int = 8,
    seq: int = 256,
    reduced: bool = True,
    reduced_overrides: dict | None = None,
    ckpt_dir: str = "/tmp/repro_ckpt",
    save_every: int = 50,
    lr: float = 3e-4,
    n_microbatches: int = 1,
    seed: int = 0,
    log_every: int = 10,
) -> dict:
    cfg = get_reduced(arch, **(reduced_overrides or {})) if reduced else get_config(arch)
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=max(steps // 20, 1), total_steps=steps)
    pipe = ShardedTokenPipeline(
        TokenPipelineConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch, seed=seed)
    )

    def init_state():
        return init_train_state(model, jax.random.PRNGKey(seed), opt_cfg)

    step_jit = jax.jit(
        make_train_step(model, opt_cfg, n_microbatches=n_microbatches),
        donate_argnums=(0,),
    )

    extras = {}
    for k, (shp, dt) in model.extras_shapes(batch).items():
        extras[k] = np.zeros(shp, dtype=np.float32)

    def batch_fn(step):
        b = pipe.batch_at(step)
        return {**b, **extras}

    losses = []

    def step_fn(state, b):
        state, metrics = step_jit(state, b)
        return state, metrics

    drv = TrainDriver(
        ckpt_dir,
        DriverConfig(total_steps=steps, save_every=save_every),
        init_state=init_state,
        step_fn=step_fn,
        batch_fn=batch_fn,
    )
    t0 = time.perf_counter()
    state, done = drv.run()
    wall = time.perf_counter() - t0
    losses = [m["loss"] for m in drv.metrics_log]
    out = dict(
        arch=cfg.name,
        steps=done,
        wall_s=wall,
        first_loss=losses[0] if losses else None,
        last_loss=losses[-1] if losses else None,
        min_loss=min(losses) if losses else None,
        params=int(sum(np.prod(l.shape) for l in jax.tree.leaves(state["params"]))),
        events=drv.events,
    )
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    out = train_main(
        args.arch,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        reduced=args.reduced,
        ckpt_dir=args.ckpt,
        lr=args.lr,
        n_microbatches=args.microbatches,
    )
    print(json.dumps(out, indent=1, default=str))


if __name__ == "__main__":
    main()
