import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract memory / cost / collective evidence.

The two lines above MUST precede any jax import (jax locks the device
count at first init) — hence this module's unconventional layout.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out results/dryrun.json

Per cell it records:
  * ``compiled.memory_analysis()``  — bytes/device (proves HBM fit),
  * ``compiled.cost_analysis()``    — XLA's own (loop-unaware) counters,
  * loop-aware HLO cost (:mod:`repro.launch.hlo_cost`) — flops, HBM bytes,
    per-kind collective bytes,
  * the roofline terms (:mod:`repro.launch.roofline`).
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs.registry import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.distributed import sharding as shd
from repro.distributed.meshctx import active_mesh
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline_terms
from repro.launch.specs import make_cell

__all__ = ["run_cell", "main"]


def _cell_shardings(mesh, cell, style: str = "baseline"):
    """In-shardings pytree matching the cell's positional args."""
    if cell.kind == "train":
        state_shapes, batch_shapes = cell.args_shapes
        ps = shd.params_shardings(mesh, state_shapes["params"], style=style)
        state_sh = {
            "params": ps,
            "opt": {
                "m": shd.params_shardings(mesh, state_shapes["opt"]["m"], style=style),
                "v": shd.params_shardings(mesh, state_shapes["opt"]["v"], style=style),
                "step": shd.replicated(mesh),
            },
        }
        return (state_sh, shd.batch_shardings(mesh, batch_shapes))
    if cell.kind == "prefill":
        params_shapes, tokens_shapes, extras_shapes = cell.args_shapes
        return (
            shd.params_shardings(mesh, params_shapes, style=style),
            shd.batch_shardings(mesh, tokens_shapes),
            shd.batch_shardings(mesh, extras_shapes),
        )
    params_shapes, token_shapes, cache_shapes = cell.args_shapes
    kv_style = "seq_kv" if cell.cfg.flash_vjp else "baseline"  # opt>=1 marker
    return (
        shd.params_shardings(mesh, params_shapes, style=style),
        shd.batch_shardings(mesh, token_shapes),
        shd.cache_shardings(mesh, cache_shapes, style=kv_style),
    )


def run_cell(arch: str, shape: str, *, multi_pod: bool, verbose: bool = True,
             opt: int = 0) -> dict:
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return dict(arch=arch, shape=shape, mesh="multi" if multi_pod else "single",
                    status="skipped", reason=why)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.perf_counter()
    with active_mesh(mesh):
        cell = make_cell(arch, shape, cfg, opt=opt)
        # storage stays on the baseline rules; the gathered-compute layout
        # is enforced in-model via fsdp_gather (§Perf iteration 3 — the
        # "fsdp_out" storage experiment of iteration 2 was refuted).
        style = os.environ.get("REPRO_SHARDING_STYLE", "baseline")
        in_sh = _cell_shardings(mesh, cell, style=style)
        jitted = jax.jit(cell.step_fn, in_shardings=in_sh, donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args_shapes)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis()
    hlo = analyze_hlo(compiled.as_text())
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    rep = roofline_terms(arch, shape, cell.kind, cfg, hlo, chips, tokens)
    mem_row = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_row[attr] = int(v)
    # memory_analysis reports per-device bytes already
    per_dev_bytes = mem_row.get("argument_size_in_bytes", 0) + mem_row.get(
        "temp_size_in_bytes", 0
    )
    row = dict(
        arch=arch,
        shape=shape,
        mesh="multi" if multi_pod else "single",
        status="ok",
        opt=opt,
        chips=chips,
        kind=cell.kind,
        n_microbatches=cell.plan.n_microbatches,
        remat=cell.plan.remat,
        t_lower_s=round(t_lower, 1),
        t_compile_s=round(t_compile, 1),
        memory=mem_row,
        bytes_per_device=per_dev_bytes,
        xla_flops_loop_unaware=xla_cost.get("flops"),
        roofline=rep.row(),
        collective_count=hlo.collective_count,
        unknown_trip_whiles=hlo.unknown_trip_whiles,
    )
    if verbose:
        print(
            f"[{row['mesh']}] {arch:18s} {shape:12s} OK "
            f"compile={t_compile:6.1f}s bytes/dev={per_dev_bytes/2**30:7.2f}GiB "
            f"Tc={rep.t_compute*1e3:9.2f}ms Tm={rep.t_memory*1e3:9.2f}ms "
            f"Tx={rep.t_collective*1e3:9.2f}ms dom={rep.dominant:10s} "
            f"useful={rep.useful_ratio:5.2f} roofline={rep.roofline_fraction:5.2f}",
            flush=True,
        )
        print(f"  memory_analysis: {mem_row}", flush=True)
        print(f"  collectives: {({k: f'{v/2**20:.1f}MiB' for k, v in rep.collective_by_kind.items()})}", flush=True)
    return row


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--opt", type=int, default=0, help="0=baseline, 1=hillclimbed")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    rows = []
    failures = 0
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    rows.append(run_cell(arch, shape, multi_pod=multi, opt=args.opt))
                except Exception as e:  # noqa: BLE001 — record and continue
                    failures += 1
                    traceback.print_exc()
                    rows.append(dict(arch=arch, shape=shape,
                                     mesh="multi" if multi else "single",
                                     status="error", error=f"{type(e).__name__}: {e}"))
                    if args.fail_fast:
                        raise
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    n_ok = sum(r.get("status") == "ok" for r in rows)
    n_skip = sum(r.get("status") == "skipped" for r in rows)
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped, {failures} failed -> {args.out}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
