"""Loop-aware cost model over optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` visits every ``while`` body exactly ONCE
(verified empirically — a scan of 10 matmuls reports 1 matmul of FLOPs),
which under-counts scanned-layer models by the layer count.  This parser
fixes that: it walks the HLO computation graph, multiplies ``while`` bodies
by their ``known_trip_count`` backend config, recurses through fusions /
calls / conditionals, and produces three loop-correct totals:

* ``flops``       — 2·M·N·K for dots (+1/elem for everything else),
* ``hbm_bytes``   — a write-traffic model: result bytes of every
                    *materializing* top-level op (fused interiors are free;
                    tuple/GTE/bitcast are aliases; loop-carry parameters
                    count as per-iteration reads).  Total HBM traffic is
                    read+write ≈ 2x this, bounded below by it — adequate
                    for a first-order memory roofline term,
* ``collective_bytes`` — per-kind result bytes of all-gather / all-reduce /
                    reduce-scatter / all-to-all / collective-permute.

These feed the roofline terms in EXPERIMENTS.md §Roofline.  The model is a
first-order static analysis — exact for dot FLOPs and collective schedules,
approximate (±) for elementwise counts, which is the right fidelity for a
compile-time roofline.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

__all__ = ["HloCost", "analyze_hlo"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0, "tuple": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_bytes_elems(shape_text: str) -> tuple[int, int]:
    """Total (bytes, elements) over every array shape in the text."""
    total_b = 0
    total_e = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES[dt]
    return total_b, total_e


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    collective_count: int = 0
    transcendental: float = 0.0
    unknown_trip_whiles: int = 0

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        self.transcendental += other.transcendental * mult
        self.collective_count += int(other.collective_count * mult)
        self.unknown_trip_whiles += other.unknown_trip_whiles
        for k, v in other.collective_by_kind.items():
            self.collective_by_kind[k] += v * mult


@dataclasses.dataclass
class _Op:
    name: str
    kind: str
    result_shape: str
    args: list[str]
    attrs: str
    line: str


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*([^=]+?)\s+([\w\-]+)\((.*)$"
)


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    body: list[str] = []
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                body = []
        else:
            if line.strip() == "}":
                comps[cur] = body
                cur = None
            else:
                body.append(line)
    return comps


_COMMENT = re.compile(r"/\*.*?\*/")


def _parse_ops(lines: list[str]) -> list[_Op]:
    ops = []
    for raw in lines:
        raw = _COMMENT.sub("", raw)  # XLA writes /*index=N*/ inside tuples
        m = _OP_RE.match(raw)
        if not m:
            continue
        name, shape_text, kind, rest = m.groups()
        # args = everything in the top-level parens; attrs follow
        depth = 1
        i = 0
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        args_text = rest[: i - 1] if depth == 0 else rest
        attrs = rest[i:] if depth == 0 else ""
        args = re.findall(r"%([\w.\-]+)", args_text)
        ops.append(_Op(name, kind, shape_text.strip(), args, attrs, raw))
    return ops


_CALL_ATTR = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r'known_trip_count[^0-9]*(\d+)')
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _dims_of(shape_text: str) -> list[int]:
    m = _SHAPE_RE.search(shape_text)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


def _dot_flops(op: _Op, tbl: dict[str, str]) -> float:
    """2 * |result| * prod(contracted dims of the lhs)."""
    _, res_elems = _shape_bytes_elems(op.result_shape)
    lhs_shape = tbl.get(op.args[0]) if op.args else None
    m = _LHS_CDIMS.search(op.attrs) or _LHS_CDIMS.search(op.line)
    k = 1
    if lhs_shape and m and m.group(1):
        dims = _dims_of(lhs_shape)
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(dims):
                k *= dims[i]
    return 2.0 * res_elems * k


def analyze_hlo(text: str) -> HloCost:
    comps = _split_computations(text)
    parsed = {name: _parse_ops(lines) for name, lines in comps.items()}
    shape_table: dict[str, dict[str, str]] = {}
    for name, ops in parsed.items():
        tbl = {op.name: op.result_shape for op in ops}
        # parameters: "%name (p: f32[..], q: (s32[], ...)) -> ..."
        shape_table[name] = tbl

    memo: dict[str, HloCost] = {}
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line)
            if m:
                entry = m.group(1)
    if entry is None:  # fall back: biggest computation
        entry = max(parsed, key=lambda n: len(parsed[n]))

    def comp_cost(name: str, top_level: bool, is_entry: bool = False) -> HloCost:
        key = f"{name}:{top_level}:{is_entry}"
        if key in memo:
            return memo[key]
        memo[key] = HloCost()  # cycle guard
        cost = HloCost()
        tbl = shape_table.get(name, {})
        for op in parsed.get(name, []):
            res_bytes, res_elems = _shape_bytes_elems(op.result_shape)
            kind = op.kind
            if kind in _COLLECTIVES:
                cost.collective_bytes += res_bytes
                cost.collective_by_kind[kind] += res_bytes
                cost.collective_count += 1
                cost.hbm_bytes += res_bytes
                continue
            if kind == "while":
                m = _TRIP.search(op.attrs) or _TRIP.search(op.line)
                trips = int(m.group(1)) if m else 1
                if not m:
                    cost.unknown_trip_whiles += 1
                calls = _CALL_ATTR.findall(op.attrs) or _CALL_ATTR.findall(op.line)
                for sub in calls:
                    cost.add(comp_cost(sub, top_level=True, is_entry=False), mult=trips)
                continue
            if kind in ("call", "async-start", "custom-call") or kind == "conditional":
                subs = _CALL_ATTR.findall(op.attrs) or _CALL_ATTR.findall(op.line)
                mb = _BRANCHES.search(op.attrs or op.line)
                if mb:
                    subs += re.findall(r"%([\w.\-]+)", mb.group(1))
                for sub in subs:
                    cost.add(comp_cost(sub, top_level=True))
                continue
            if kind == "fusion":
                subs = _CALL_ATTR.findall(op.attrs) or _CALL_ATTR.findall(op.line)
                for sub in subs:
                    inner = comp_cost(sub, top_level=False)
                    c2 = HloCost(flops=inner.flops, transcendental=inner.transcendental)
                    c2.collective_bytes = inner.collective_bytes
                    for k, v in inner.collective_by_kind.items():
                        c2.collective_by_kind[k] += v
                    cost.add(c2)
                if top_level:
                    cost.hbm_bytes += res_bytes  # fusion writes its result once
                continue
            if kind == "dot":
                flops = _dot_flops(op, shape_table.get(name, {}))
                cost.flops += flops
            elif kind == "convolution":
                cost.flops += 2.0 * res_elems  # rough; convs are stubs here
            elif kind in ("exponential", "tanh", "logistic", "log", "rsqrt", "sqrt", "power", "cosine", "sine"):
                cost.transcendental += res_elems
                cost.flops += res_elems
            elif kind in ("constant", "get-tuple-element", "tuple", "bitcast"):
                continue  # aliases/metadata: no HBM traffic, no flops
            elif kind == "parameter":
                # entry params = weight/batch reads (once).  Loop-carry
                # params alias in place; per-iteration reads show up as the
                # dynamic-slice results inside the body instead.
                if is_entry:
                    cost.hbm_bytes += res_bytes
                continue
            elif kind == "dynamic-update-slice":
                # functional result aliases the buffer; only the update
                # slice (operand 1) is written
                upd = op.args[1] if len(op.args) > 1 else None
                if top_level and upd and upd in tbl:
                    cost.hbm_bytes += _shape_bytes_elems(tbl[upd])[0]
                continue
            elif kind in ("copy", "reshape", "transpose", "broadcast", "iota", "convert", "slice", "dynamic-slice", "concatenate", "pad", "reverse", "gather", "scatter"):
                pass  # data movement: result bytes below, no flops
            else:
                cost.flops += res_elems  # 1 flop / element
            if top_level:
                cost.hbm_bytes += res_bytes
        memo[key] = cost
        return cost

    return comp_cost(entry, top_level=True, is_entry=True)
