"""Regenerate the EXPERIMENTS.md roofline tables from results/*.json.

    PYTHONPATH=src python -m repro.launch.report [--doc EXPERIMENTS.md]

Reads the three sweeps (baseline single/multi + merged optimized) and
rewrites the block between the ``TABLES:BEGIN/END`` markers.
"""

from __future__ import annotations

import argparse
import json

ARCHS = (
    "mamba2_130m", "whisper_medium", "recurrentgemma_9b", "chameleon_34b",
    "nemotron4_15b", "starcoder2_3b", "qwen2_7b", "llama3_405b",
    "dbrx_132b", "deepseek_moe_16b",
)
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
ORDER = [(a, s) for a in ARCHS for s in SHAPES]


def _load(path):
    return {(r["arch"], r["shape"]): r for r in json.load(open(path))}


def _fmt_table(data, title):
    out = [f"### {title}", "",
           "| arch | shape | kind | T_c (ms) | T_m (ms) | T_x (ms) | dominant | useful | roofline | args GiB/dev | temp GiB/dev | coll GiB (AG/AR/A2A/CP) |",
           "|---|---|---|---:|---:|---:|---|---:|---:|---:|---:|---|"]
    for key in ORDER:
        r = data.get(key)
        if r is None:
            continue
        a, s = key
        if r["status"] == "skipped":
            out.append(f"| {a} | {s} | — | | | | | | | | | *skipped: full-attention @500k* |")
            continue
        rf = r["roofline"]
        c = rf.get("collectives", {})
        mem = r["memory"]
        g = lambda k: c.get(k, 0) / 2**30
        out.append(
            f"| {a} | {s} | {rf['kind']} | {rf['t_compute_ms']:.0f} | {rf['t_memory_ms']:.0f} | {rf['t_collective_ms']:.0f} | "
            f"{rf['dominant']} | {rf['useful_ratio']:.2f} | {rf['roofline_fraction']:.3f} | "
            f"{mem.get('argument_size_in_bytes', 0)/2**30:.2f} | {mem.get('temp_size_in_bytes', 0)/2**30:.2f} | "
            f"{g('all-gather'):.0f}/{g('all-reduce'):.0f}/{g('all-to-all'):.1f}/{g('collective-permute'):.1f} |"
        )
    return "\n".join(out)


def _bound(r):
    rf = r["roofline"]
    return max(rf["t_compute_ms"], rf["t_memory_ms"], rf["t_collective_ms"]) / 1e3


def render() -> str:
    base = _load("results/dryrun_single.json")
    multi = _load("results/dryrun_multi.json")
    opt = _load("results/dryrun_single_opt_final.json")
    parts = [
        _fmt_table(base, "A. Single-pod (16×16 = 256 chips) — BASELINE (paper-faithful/naive)"), "",
        _fmt_table(multi, "B. Multi-pod (2×16×16 = 512 chips) — BASELINE"), "",
        _fmt_table(opt, "C. Single-pod — OPTIMIZED (`--opt 1`, best-measured per-arch config)"), "",
        "### D. Baseline → optimized deltas (single-pod; `T_bound = max(T_c, T_m, T_x)`)", "",
        "| arch | shape | T_bound base→opt (s) | speedup | roofline base→opt | temp GiB base→opt |",
        "|---|---|---|---:|---|---|",
    ]
    for key in ORDER:
        b, o = base.get(key), opt.get(key)
        if not b or b["status"] != "ok" or not o or o["status"] != "ok":
            continue
        tb, to = _bound(b), _bound(o)
        parts.append(
            f"| {key[0]} | {key[1]} | {tb:.2f} → {to:.2f} | {tb/max(to, 1e-9):.2f}× | "
            f"{b['roofline']['roofline_fraction']:.3f} → {o['roofline']['roofline_fraction']:.3f} | "
            f"{b['memory'].get('temp_size_in_bytes', 0)/2**30:.1f} → {o['memory'].get('temp_size_in_bytes', 0)/2**30:.1f} |"
        )
    return "\n".join(parts)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--doc", default="EXPERIMENTS.md")
    args = ap.parse_args()
    doc = open(args.doc).read()
    begin, end = "<!-- TABLES:BEGIN -->", "<!-- TABLES:END -->"
    s, e = doc.index(begin), doc.index(end)
    doc = doc[: s + len(begin)] + "\n" + render() + "\n" + doc[e:]
    open(args.doc, "w").write(doc)
    print(f"tables regenerated into {args.doc}")


if __name__ == "__main__":
    main()
