"""Roofline-term computation from compiled dry-run artifacts.

TPU v5e hardware model (per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  All parsed HLO costs are per-device (post-SPMD), so:

    compute    T_c = flops_per_device / 197e12          [s]
    memory     T_m = hbm_bytes_per_device / 819e9       [s]
    collective T_x = coll_bytes_per_device / 50e9       [s]

MODEL_FLOPS uses the 6·N·D convention for training (2·N·D for inference
steps), with N = active parameters for MoE; the ratio MODEL/HLO flags
remat recompute, causal-mask waste and dispatch overheads.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig
from repro.launch.hlo_cost import HloCost

__all__ = ["HW", "RooflineReport", "roofline_terms"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12  # bf16 / chip
    hbm_bw: float = 819e9  # B/s / chip
    ici_bw: float = 50e9  # B/s / link


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    kind: str
    chips: int
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops_global: float
    hlo_flops_global: float
    collective_by_kind: dict
    bytes_per_device: float
    flops_per_device: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline lower bound on step time (terms overlap perfectly)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        if self.hlo_flops_global <= 0:
            return 0.0
        return self.model_flops_global / self.hlo_flops_global

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the peak-compute roofline the *useful* model flops
        achieve if the step runs exactly at the dominant-term bound."""
        if self.t_bound <= 0:
            return 0.0
        achieved = self.model_flops_global / self.t_bound  # flop/s across fleet
        return achieved / (self.chips * HW.peak_flops)

    def row(self) -> dict:
        return dict(
            arch=self.arch,
            shape=self.shape,
            kind=self.kind,
            chips=self.chips,
            t_compute_ms=self.t_compute * 1e3,
            t_memory_ms=self.t_memory * 1e3,
            t_collective_ms=self.t_collective * 1e3,
            dominant=self.dominant,
            model_flops=self.model_flops_global,
            hlo_flops=self.hlo_flops_global,
            useful_ratio=self.useful_ratio,
            roofline_fraction=self.roofline_fraction,
            collectives={k: v for k, v in self.collective_by_kind.items()},
        )


def model_flops(cfg: ArchConfig, kind: str, tokens: int) -> float:
    n = cfg.param_count(active_only=True)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens


def roofline_terms(
    arch: str,
    shape: str,
    kind: str,
    cfg: ArchConfig,
    cost: HloCost,
    chips: int,
    tokens: int,
    hw: HW = HW(),
) -> RooflineReport:
    return RooflineReport(
        arch=arch,
        shape=shape,
        kind=kind,
        chips=chips,
        t_compute=cost.flops / hw.peak_flops,
        t_memory=cost.hbm_bytes / hw.hbm_bw,
        t_collective=cost.collective_bytes / hw.ici_bw,
        model_flops_global=model_flops(cfg, kind, tokens),
        hlo_flops_global=cost.flops * chips,
        collective_by_kind=dict(cost.collective_by_kind),
        bytes_per_device=cost.hbm_bytes,
        flops_per_device=cost.flops,
    )
