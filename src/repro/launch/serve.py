"""Distributed RkNN serving — deprecated alias over the stateful engine.

The serving pipeline (users uploaded once and sharded over the mesh's
data axes, per-query scenes built on the host and double-buffered against
the device ray-cast, queries sharded over ``'model'``) now lives in
:class:`repro.core.engine.RkNNEngine` — see docs/API.md for the engine
lifecycle and the migration table.  :class:`RkNNServer` is kept as a thin
compatibility wrapper so existing callers keep working; new code should
construct an engine directly:

    eng = RkNNEngine(F, U, RkNNConfig(scene_cache=256), mesh=mesh)
    for batch, masks in eng.stream(batches, k=10):
        ...

Queries are idempotent, so fault tolerance is re-execution: a lost pod's
user shard is re-issued on the surviving mesh (runtime/elastic.py).
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.engine import RkNNConfig, RkNNEngine, serve_shardings
from repro.kernels.ref import raycast_count_batch_ref

__all__ = ["RkNNServer", "ServeStats", "batched_raycast_counts", "lower_rknn_serve"]


def batched_raycast_counts(xs, ys, coeffs):
    """counts[q, u] for stacked scenes.  xs/ys: [N]; coeffs: [Q, M, 3, 3].

    Delegates to the shared batched oracle in :mod:`repro.kernels.ref` —
    the same math every dense dispatch in the engine runs, so the serving
    path and the query engine cannot drift apart.  Kept as a named function
    because :func:`lower_rknn_serve` jits it with mesh shardings.
    """
    return raycast_count_batch_ref(xs, ys, coeffs)


@dataclasses.dataclass
class ServeStats:
    n_queries: int = 0
    t_scene_s: float = 0.0
    t_device_s: float = 0.0
    m_max: int = 0


_deprecation_warned = False


def _warn_deprecated_once() -> None:
    """One ``DeprecationWarning`` per process — a serving loop constructs
    servers in bulk and must not flood its logs."""
    global _deprecation_warned
    if _deprecation_warned:
        return
    _deprecation_warned = True
    import warnings

    warnings.warn(
        "RkNNServer is deprecated: construct repro.core.engine.RkNNEngine "
        "(or repro.dynamic.DynamicEngine for mutable snapshots) directly — "
        "see docs/API.md for the migration table.",
        DeprecationWarning,
        stacklevel=3,
    )


class RkNNServer:
    """DEPRECATED: thin alias over :class:`RkNNEngine` (docs/API.md).

    Preserved surface: ``query_batch(q_indices, k) -> masks [Q, N]``,
    ``serve_stream(batches, k)`` (double-buffered generator), and
    ``stats``.  All state and scheduling live in the engine — including
    the versioned dynamic entry points (``repro.dynamic``), which this
    alias deliberately does not grow.
    """

    def __init__(
        self,
        facilities: np.ndarray,
        users: np.ndarray,
        *,
        mesh: Mesh | None = None,
        pad_scene_to: int = 128,
        strategy: str = "infzone",
        scene_cache: int = 0,
    ):
        _warn_deprecated_once()
        self.engine = RkNNEngine(
            facilities,
            users,
            RkNNConfig(
                backend="dense-ref",
                strategy=strategy,
                scene_cache=scene_cache,
                pad_scene_to=pad_scene_to,
            ),
            mesh=mesh,
        )

    # engine state passthroughs (legacy attribute surface)
    @property
    def facilities(self) -> np.ndarray:
        return self.engine.facilities

    @property
    def users(self) -> np.ndarray:
        return self.engine.users

    @property
    def rect(self):
        return self.engine.rect

    @property
    def mesh(self):
        return self.engine.mesh

    @property
    def strategy(self) -> str:
        return self.engine.config.strategy

    @property
    def pad(self) -> int:
        return self.engine._pad_bucket

    @property
    def stats(self) -> ServeStats:
        s = self.engine.stats
        return ServeStats(
            n_queries=s.n_queries,
            t_scene_s=s.t_filter_s,
            t_device_s=s.t_verify_s,
            m_max=s.m_max,
        )

    def query_batch(self, q_indices, k: int) -> np.ndarray:
        """Masks [Q, N] for a batch of facility-index queries."""
        return self.engine.query_batch([int(q) for q in q_indices], k).masks

    def serve_stream(self, batches, k: int):
        """Double-buffered stream: scene build for batch i+1 overlaps the
        device ray-cast of batch i (generator of [Q, N] masks).  Producer
        exceptions re-raise in the consumer."""
        return self.engine.stream(batches, k)


def lower_rknn_serve(mesh: Mesh, n_users: int, q_batch: int, m_pad: int = 128):
    """Dry-run lowering of the serve step on a production mesh (the RkNN
    analogue of the LM cells; exercised in tests + EXPERIMENTS §Dry-run).
    Uses the same partition layout the live engine dispatches with."""
    user_sh, scene_sh, out_sh = serve_shardings(mesh)
    xs = jax.ShapeDtypeStruct((n_users,), jnp.float32)
    cf = jax.ShapeDtypeStruct((q_batch, m_pad, 3, 3), jnp.float32)
    return (
        jax.jit(
            batched_raycast_counts,
            in_shardings=(user_sh, user_sh, scene_sh),
            out_shardings=out_sh,
        )
        .lower(xs, xs, cf)
        .compile()
    )
