"""Distributed RkNN serving — the paper's workload as a production service.

Design (DESIGN.md §4):
* the user set is uploaded ONCE, sharded over every data-parallel mesh axis
  (the paper's "no user index, plain GPU transfer" — Table 2 — generalised
  to a fleet);
* queries arrive in batches of ``Q``; scene construction (InfZone-style
  pruning + occluders, host numpy) runs in a worker thread and is
  double-buffered against the device ray-cast of the previous batch;
* the device step is a single pjit'd batched hit-count: users sharded
  ``P(('pod','data'))``, per-query scenes replicated (they are tiny —
  ~64 triangles · 36 B), queries sharded over ``'model'`` — zero
  communication until the final result gather;
* queries are idempotent, so fault tolerance is re-execution: a lost pod's
  user shard is re-issued on the surviving mesh (runtime/elastic.py).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.geometry import Rect
from repro.core.scene import build_scene, pad_scene_arrays
from repro.distributed.meshctx import dp_axes
from repro.kernels.ref import raycast_count_batch_ref

__all__ = ["RkNNServer", "batched_raycast_counts", "lower_rknn_serve"]


def batched_raycast_counts(xs, ys, coeffs):
    """counts[q, u] for stacked scenes.  xs/ys: [N]; coeffs: [Q, M, 3, 3].

    Delegates to the shared batched oracle in :mod:`repro.kernels.ref` —
    the same math :func:`repro.core.rknn.rt_rknn_query_batch` dispatches,
    so the serving path and the query engine cannot drift apart.  Kept as a
    named function because the server jits it with mesh shardings.
    """
    return raycast_count_batch_ref(xs, ys, coeffs)


@dataclasses.dataclass
class ServeStats:
    n_queries: int = 0
    t_scene_s: float = 0.0
    t_device_s: float = 0.0
    m_max: int = 0


class RkNNServer:
    """Batched RkNN query server over a (possibly multi-pod) mesh."""

    def __init__(
        self,
        facilities: np.ndarray,
        users: np.ndarray,
        *,
        mesh: Mesh | None = None,
        pad_scene_to: int = 128,
        strategy: str = "infzone",
        scene_cache: int = 0,
    ):
        self.facilities = np.asarray(facilities, dtype=np.float64)
        self.users = np.asarray(users, dtype=np.float64)
        self.rect = Rect.from_points(self.facilities, self.users)
        self.mesh = mesh
        self.pad = pad_scene_to
        self.strategy = strategy
        self.stats = ServeStats()
        self._cache = None
        if scene_cache:  # paper future-work 2: amortize repeated queries
            from repro.core.hybrid import SceneCache

            self._cache = SceneCache(capacity=scene_cache)

        xs = self.users[:, 0].astype(np.float32)
        ys = self.users[:, 1].astype(np.float32)
        if mesh is not None:
            dp = dp_axes(mesh)
            user_sh = NamedSharding(mesh, P(dp if len(dp) > 1 else dp[0]))
            scene_sh = NamedSharding(mesh, P("model", None, None, None))
            out_sh = NamedSharding(mesh, P("model", dp if len(dp) > 1 else dp[0]))
            # pad user count to the DP degree
            n = len(xs)
            dpn = int(np.prod([mesh.shape[a] for a in dp]))
            padn = (-n) % dpn
            if padn:
                xs = np.concatenate([xs, np.full(padn, 2e9, np.float32)])
                ys = np.concatenate([ys, np.full(padn, 2e9, np.float32)])
            self._n_real = n
            self.xs = jax.device_put(xs, user_sh)
            self.ys = jax.device_put(ys, user_sh)
            self._step = jax.jit(
                batched_raycast_counts,
                in_shardings=(user_sh, user_sh, scene_sh),
                out_shardings=out_sh,
            )
        else:
            self._n_real = len(xs)
            self.xs = jnp.asarray(xs)
            self.ys = jnp.asarray(ys)
            self._step = jax.jit(batched_raycast_counts)

    # -- scene construction (host side, overlappable) ----------------------
    def _one_scene(self, q: int, k: int):
        if self._cache is not None:
            scene, _ = self._cache.get_or_build(
                self.facilities, int(q), k, self.rect, strategy=self.strategy
            )
            return scene
        return build_scene(self.facilities, int(q), k, self.rect, strategy=self.strategy)

    def _build_batch(self, q_indices, k: int) -> tuple[np.ndarray, list]:
        scenes = [self._one_scene(int(q), k) for q in q_indices]
        mmax = max(s.n_tris for s in scenes)
        if mmax > self.pad:  # grow the static pad (rare; re-jit once)
            self.pad = 1 << int(np.ceil(np.log2(mmax)))
        coeffs = np.stack(
            [pad_scene_arrays(s.tris[: s.n_tris], s.coeffs[: s.n_tris], s.owner[: s.n_tris], self.pad)[1] for s in scenes]
        )  # [Q, pad, 3, 3]
        return coeffs.astype(np.float32), scenes

    # -- serving -------------------------------------------------------------
    def query_batch(self, q_indices, k: int) -> np.ndarray:
        """Masks [Q, N] for a batch of facility-index queries."""
        t0 = time.perf_counter()
        coeffs, scenes = self._build_batch(q_indices, k)
        t1 = time.perf_counter()
        counts = np.asarray(self._step(self.xs, self.ys, jnp.asarray(coeffs)))
        t2 = time.perf_counter()
        self.stats.n_queries += len(q_indices)
        self.stats.t_scene_s += t1 - t0
        self.stats.t_device_s += t2 - t1
        self.stats.m_max = max(self.stats.m_max, max(s.n_tris for s in scenes))
        return counts[:, : self._n_real] < k

    def serve_stream(self, batches, k: int):
        """Double-buffered stream: scene build for batch i+1 overlaps the
        device ray-cast of batch i (generator of [Q, N] masks)."""
        q: "queue.Queue" = queue.Queue(maxsize=2)

        def producer():
            try:
                for b in batches:
                    t0 = time.perf_counter()
                    built = self._build_batch(b, k)
                    self.stats.t_scene_s += time.perf_counter() - t0
                    q.put((b, built))
                q.put(None)
            except BaseException as e:  # surface in the consumer, no deadlock
                q.put(e)

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        while True:
            item = q.get()
            if item is None:
                return
            if isinstance(item, BaseException):
                raise item
            b, (coeffs, scenes) = item
            t0 = time.perf_counter()
            counts = np.asarray(self._step(self.xs, self.ys, jnp.asarray(coeffs)))
            self.stats.t_device_s += time.perf_counter() - t0
            self.stats.n_queries += len(b)
            self.stats.m_max = max(self.stats.m_max, max(s.n_tris for s in scenes))
            yield b, counts[:, : self._n_real] < k


def lower_rknn_serve(mesh: Mesh, n_users: int, q_batch: int, m_pad: int = 128):
    """Dry-run lowering of the serve step on a production mesh (the RkNN
    analogue of the LM cells; exercised in tests + EXPERIMENTS §Dry-run)."""
    dp = dp_axes(mesh)
    dp_spec = dp if len(dp) > 1 else dp[0]
    user_sh = NamedSharding(mesh, P(dp_spec))
    scene_sh = NamedSharding(mesh, P("model", None, None, None))
    out_sh = NamedSharding(mesh, P("model", dp_spec))
    xs = jax.ShapeDtypeStruct((n_users,), jnp.float32)
    cf = jax.ShapeDtypeStruct((q_batch, m_pad, 3, 3), jnp.float32)
    return (
        jax.jit(
            batched_raycast_counts,
            in_shardings=(user_sh, user_sh, scene_sh),
            out_shardings=out_sh,
        )
        .lower(xs, xs, cf)
        .compile()
    )
