"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

No device allocation ever happens here — params, optimizer state, batches
and serving caches are all ``jax.eval_shape`` / ``ShapeDtypeStruct``
skeletons that the dry-run lowers against (the shannon/kernels pattern the
brief references).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.registry import SHAPES
from repro.launch.plans import ExecPlan, exec_plan
from repro.models.registry import Model, build_model
from repro.optim.adamw import AdamWConfig, adamw_init

__all__ = ["CellSpec", "make_cell", "input_specs"]


def input_specs(arch: str, shape: str = "train_4k", opt: int = 0):
    """ShapeDtypeStruct stand-ins for every input of the (arch x shape)
    step — the brief's entry point; returns the positional args tuple the
    jitted step is lowered against (weak-type-correct, shardable, zero
    device allocation)."""
    from repro.configs.registry import get_config

    return make_cell(arch, shape, get_config(arch), opt=opt).args_shapes


@dataclasses.dataclass
class CellSpec:
    """Everything the dry-run needs for one (arch x shape) cell."""

    arch: str
    shape: str
    kind: str  # train | prefill | decode
    cfg: ArchConfig
    model: Model
    plan: ExecPlan
    step_fn: Any  # the function to jit
    args_shapes: tuple  # ShapeDtypeStructs, positional
    donate: tuple[int, ...]
    seq_len: int
    global_batch: int


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _extras_sds(model: Model, batch: int) -> dict:
    return {
        k: _sds(shp, dt) for k, (shp, dt) in model.extras_shapes(batch).items()
    }


def make_cell(
    arch: str, shape: str, cfg: ArchConfig, opt_cfg: AdamWConfig | None = None,
    opt: int = 0,
) -> CellSpec:
    from repro.steps.train import make_decode_step, make_prefill_step, make_train_step

    seq, gb, kind = SHAPES[shape]
    plan = exec_plan(cfg, shape, opt=opt)
    cfg = dataclasses.replace(
        cfg,
        remat=plan.remat,
        q_block=plan.q_block,
        kv_block=plan.kv_block,
        flash_vjp=plan.flash_vjp,
        q_parallel=plan.q_parallel,
        moe_gather=plan.moe_gather,
        layout=plan.layout,
        fsdp_gather=plan.fsdp_gather,
    )
    model = build_model(cfg)
    opt_cfg = opt_cfg or AdamWConfig()

    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    if kind == "train":
        opt_shapes = jax.eval_shape(adamw_init, params_shapes)
        state_shapes = {"params": params_shapes, "opt": opt_shapes}
        batch_shapes = {
            "tokens": _sds((gb, seq), jnp.int32),
            "labels": _sds((gb, seq), jnp.int32),
            **_extras_sds(model, gb),
        }
        step = make_train_step(model, opt_cfg, n_microbatches=plan.n_microbatches)
        return CellSpec(
            arch, shape, kind, cfg, model, plan, step,
            (state_shapes, batch_shapes), donate=(0,), seq_len=seq, global_batch=gb,
        )

    if kind == "prefill":
        batch_shapes = _sds((gb, seq), jnp.int32)
        step = make_prefill_step(model, pad_cache_to=seq)
        return CellSpec(
            arch, shape, kind, cfg, model, plan, step,
            (params_shapes, batch_shapes, _extras_sds(model, gb)),
            donate=(), seq_len=seq, global_batch=gb,
        )

    # decode: one new token against a cache of seq_len
    cache_len = plan.decode_cache_len or seq
    cache_shapes = jax.eval_shape(lambda: model.init_cache(gb, cache_len))
    token_shapes = _sds((gb, 1), jnp.int32)
    step = make_decode_step(model)
    return CellSpec(
        arch, shape, kind, cfg, model, plan, step,
        (params_shapes, token_shapes, cache_shapes), donate=(2,),
        seq_len=seq, global_batch=gb,
    )
