"""Per-(arch x shape) execution plans: microbatching, remat, block sizes.

These are the launch-time policy knobs that make every cell fit the 16 GB
v5e HBM budget at the production mesh — chosen by napkin math (activation
bytes per microbatch x layers / shards) and verified by the dry-run's
``memory_analysis`` (EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig

__all__ = ["ExecPlan", "exec_plan"]


@dataclasses.dataclass(frozen=True)
class ExecPlan:
    n_microbatches: int = 1
    remat: str = "full"  # none | dots | full
    q_block: int = 512
    kv_block: int = 1024
    decode_cache_len: int | None = None  # defaults to shape seq_len
    # beyond-paper optimization switches (opt level 1; see configs/base.py)
    flash_vjp: bool = False
    q_parallel: bool = False
    moe_gather: bool = False
    layout: str = "tp"
    fsdp_gather: bool = False


def exec_plan(cfg: ArchConfig, shape: str, opt: int = 0) -> ExecPlan:
    """opt=0: paper-faithful/naive baseline.  opt=1: best-measured §Perf
    config per arch family (see EXPERIMENTS.md §Perf for the iteration log
    that selected these — including the refuted variants)."""
    big = cfg.d_model >= 8192 or cfg.n_layers >= 48
    o: dict = {}
    if opt >= 1:
        # moe_gather removed the bogus dispatch FLOPs (useful 0.11->0.49 on
        # dbrx with fsdp_out storage) but the collective term worsened more
        # than compute improved -> net-negative, OFF at opt=1 (see §Perf
        # iterations 2/3 in EXPERIMENTS.md; code kept for future EP work).
        # flash_vjp: off for the enc-dec family — its S=4k/1.5k attention
        # never hit the residual pathology, and the fused bwd's dk/dv scan
        # carries re-shard per block (T_x 3.3s -> 30s on whisper train,
        # refuted; §Perf iteration 4).
        o = dict(flash_vjp=cfg.encdec is None)
        # tiny models: TP=16 is pure overhead -> run all 256 chips as DP.
        # Gated by PARAM COUNT: the trade is (TP activation all-reduces
        # saved) vs (full weight-grad all-reduces incurred) — whisper at
        # 0.8B params lost 6x to the latter, mamba2 at 0.13B wins 4.4x
        # (refuted/confirmed pair, EXPERIMENTS §Perf iteration 4).  Also
        # requires the global batch to fill the mesh (gb=256 at train_4k);
        # the attn-free SSM keeps it on prefill too (seq shards instead).
        if cfg.moe is None and cfg.param_count() < 3e8:
            if shape == "train_4k" or cfg.ssm is not None:
                o["layout"] = "dp_only"
        # heads that don't divide the TP axis: shard attention over the
        # q-block dim instead of heads (vmap'd flash, H3) + explicit weight
        # gathers (helped qwen2; neutral for llama3; hurt MoE -> per-family)
        if cfg.n_heads % 16 and shape in ("train_4k", "prefill_32k"):
            o["q_parallel"] = True
            o["fsdp_gather"] = True
    if shape == "train_4k":
        if cfg.name == "llama3-405b":
            # 256 x 4k tokens; 1 µbatch of 32 rows => layer input
            # 32·4096·16384·2B = 4 GiB global, /512 shards + full remat
            return ExecPlan(n_microbatches=8, remat="full", **o)
        if big:
            return ExecPlan(n_microbatches=8, remat="full", **o)
        return ExecPlan(n_microbatches=4, remat="full", **o)
    if shape == "prefill_32k":
        return ExecPlan(n_microbatches=1, remat="full", q_block=1024, kv_block=2048, **o)
    # decode shapes: no remat (no backward), cache length = seq_len
    o.pop("q_parallel", None)
    return ExecPlan(n_microbatches=1, remat="none", **o)
