"""Production mesh construction (the exact shape required by the brief).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; only calling it does.  The single-pod mesh is
16x16 = 256 chips (data x model); the multi-pod mesh adds a leading pod
axis: 2 x 16 x 16 = 512 chips.  The pod axis joins 'data' for gradient /
batch parallelism (hierarchical all-reduce across the DCI).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh_for_devices"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for_devices(n_devices: int | None = None, model_axis: int | None = None):
    """Small-mesh helper for CI / the 8-device dry-run integration test."""
    n = n_devices or len(jax.devices())
    model = model_axis or (2 if n % 2 == 0 else 1)
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
