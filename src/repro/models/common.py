"""Shared building blocks: init helpers, norms, activations, RoPE, dtype policy.

The module system is deliberately minimal and functional: parameters are
nested dicts of ``jnp.ndarray`` created by ``init_*`` functions and consumed
by pure ``apply`` functions.  No framework dependency; every array's
position in the tree is meaningful to the sharding rules
(``repro/distributed/sharding.py``), which match on path names.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "Policy",
    "dense_init",
    "rmsnorm",
    "layernorm",
    "norm_apply",
    "activation",
    "rope_freqs",
    "apply_rope",
    "take_embedding",
]


class Policy:
    """Mixed-precision policy: fp32 master params, bf16 compute."""

    param_dtype = jnp.float32
    compute_dtype = jnp.bfloat16

    @classmethod
    def cast(cls, x):
        return jax.tree.map(
            lambda a: a.astype(cls.compute_dtype)
            if a.dtype in (jnp.float32, jnp.bfloat16)
            else a,
            x,
        )


def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (LeCun-ish; exact law is irrelevant to
    the systems claims, stability is)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in**-0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(
        dtype
    )


def rmsnorm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def layernorm(x, weight, bias=None, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    x = x * (1.0 + weight.astype(jnp.float32))
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dtype)


def norm_apply(kind: str, x, params):
    if kind == "layernorm":
        return layernorm(x, params["scale"], params.get("bias"))
    return rmsnorm(x, params["scale"])


def activation(kind: str, x):
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":  # squared ReLU (Primer / Nemotron-4)
        r = jax.nn.relu(x)
        return r * r
    if kind == "silu":
        return jax.nn.silu(x)
    raise ValueError(f"unknown activation {kind!r}")


def rope_freqs(head_dim: int, theta: float):
    """Inverse frequencies for rotary embeddings: ``[head_dim // 2]``."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x, positions, theta: float):
    """Rotate ``x [..., S, ..., D]``-like arrays given per-token positions.

    ``x``: ``[B, S, H, D]`` (or KV-shaped); ``positions``: ``[B, S]`` int32.
    """
    d = x.shape[-1]
    inv = rope_freqs(d, theta)  # [D/2]
    ang = positions.astype(jnp.float32)[..., None] * inv  # [B, S, D/2]
    cos = jnp.cos(ang)[:, :, None, :]  # [B, S, 1, D/2]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def take_embedding(embed, tokens):
    """Token embedding lookup, compute-dtype output."""
    return embed[tokens].astype(Policy.compute_dtype)
