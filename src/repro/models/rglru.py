"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Structure (recurrent block of the paper): two input branches —
``gate = GeLU(W_g x)`` and ``h = conv1d(W_x x)`` fed to the RG-LRU —
merged multiplicatively and projected out.  The RG-LRU recurrence:

    r_t = σ(W_a' h_t)            (recurrence gate, block-diagonal)
    i_t = σ(W_i' h_t)            (input gate, block-diagonal)
    a_t = a^(c·r_t),  a = σ(Λ)   (per-channel learned decay, c = 8)
    y_t = a_t ⊙ y_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ h_t)

Training/prefill evaluates the linear recurrence with an associative scan
(log-depth, TPU-friendly); decode is the O(1) per-token step — this plus
the bounded local-attention window is why the hybrid family runs the
``long_500k`` cell (DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.common import dense_init

__all__ = [
    "init_rglru_block",
    "rglru_block_forward",
    "rglru_block_decode",
    "init_rglru_cache",
]

_C = 8.0


def _n_blocks(cfg: ArchConfig) -> int:
    return max(1, cfg.n_heads)


def init_rglru_block(key, cfg: ArchConfig):
    d = cfg.d_model
    w = cfg.hybrid.lru_width or d
    nb = _n_blocks(cfg)
    bs = w // nb
    ks = jax.random.split(key, 7)
    # Λ init so that a = σ(Λ) ∈ (0.9, 0.999) roughly (Griffin appendix)
    lam = jax.random.uniform(ks[4], (w,), jnp.float32, 2.2, 6.9)
    return {
        "w_gate_in": dense_init(ks[0], (d, w)),
        "w_x_in": dense_init(ks[1], (d, w)),
        "conv_w": dense_init(ks[2], (4, w), scale=0.5),
        "conv_b": jnp.zeros((w,), jnp.float32),
        # block-diagonal gate projections [nb, bs, bs]
        "w_a": dense_init(ks[3], (nb, bs, bs)),
        "w_i": dense_init(ks[5], (nb, bs, bs)),
        "lambda": lam,
        "w_out": dense_init(ks[6], (w, d)),
    }


def _causal_conv(x, w, b):
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + pad[:, i : i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
    return out + b[None, None, :].astype(x.dtype)


def _gates(params, h, nb: int):
    """Block-diagonal gate projections.  h: [..., w] -> (r, i)."""
    shp = h.shape
    hb = h.reshape(*shp[:-1], nb, shp[-1] // nb)
    r = jax.nn.sigmoid(
        jnp.einsum("...nb,nbc->...nc", hb.astype(jnp.float32), params["w_a"])
    )
    i = jax.nn.sigmoid(
        jnp.einsum("...nb,nbc->...nc", hb.astype(jnp.float32), params["w_i"])
    )
    return r.reshape(shp), i.reshape(shp)


def _rglru_scan(params, h, nb: int, init_state=None):
    """h: [B, S, w] -> (y [B, S, w] f32, final_state [B, w] f32)."""
    r, i = _gates(params, h, nb)
    log_a0 = jax.nn.log_sigmoid(params["lambda"])[None, None, :]  # log a
    log_at = _C * r * log_a0  # [B, S, w], <= 0
    at = jnp.exp(log_at)
    beta = jnp.sqrt(jnp.maximum(1.0 - at * at, 1e-12))
    xin = beta * i * h.astype(jnp.float32)
    if init_state is not None:
        xin = xin.at[:, 0, :].add(at[:, 0, :] * init_state)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, y = lax.associative_scan(combine, (at, xin), axis=1)
    return y, y[:, -1, :]


def rglru_block_forward(params, x, cfg: ArchConfig, init_state=None):
    """x: [B, S, d] -> ([B, S, d], final_state [B, w])."""
    nb = _n_blocks(cfg)
    gate = jax.nn.gelu(x @ params["w_gate_in"].astype(x.dtype))
    h = _causal_conv(x @ params["w_x_in"].astype(x.dtype), params["conv_w"], params["conv_b"])
    y, state = _rglru_scan(params, h, nb, init_state)
    out = (y.astype(x.dtype) * gate) @ params["w_out"].astype(x.dtype)
    return out, state


def init_rglru_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    w = cfg.hybrid.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, 3, w), dtype),
        "state": jnp.zeros((batch, w), jnp.float32),
    }


def rglru_block_decode(params, x, cache, cfg: ArchConfig):
    """One-token step.  x: [B, 1, d]."""
    nb = _n_blocks(cfg)
    xt = x[:, 0]
    gate = jax.nn.gelu(xt @ params["w_gate_in"].astype(x.dtype))
    hx = xt @ params["w_x_in"].astype(x.dtype)  # [B, w]
    conv_in = jnp.concatenate([cache["conv"], hx[:, None, :]], axis=1)  # [B, 4, w]
    w = params["conv_w"].astype(x.dtype)
    h = jnp.einsum("bwc,wc->bc", conv_in, w) + params["conv_b"].astype(x.dtype)
    r, i = _gates(params, h, nb)
    log_a0 = jax.nn.log_sigmoid(params["lambda"])[None, :]
    at = jnp.exp(_C * r * log_a0)
    beta = jnp.sqrt(jnp.maximum(1.0 - at * at, 1e-12))
    state = at * cache["state"] + beta * i * h.astype(jnp.float32)
    out = (state.astype(x.dtype) * gate) @ params["w_out"].astype(x.dtype)
    return out[:, None, :], {"conv": conv_in[:, 1:, :], "state": state}
