"""Feed-forward layers: dense (GLU / squared-ReLU variants) and MoE.

The MoE uses GShard-style dense dispatch: tokens are split into groups,
top-k routing builds a ``[group, experts, capacity]`` combine tensor, and
dispatch/return are einsums.  Under the production mesh the expert axis is
sharded over ``'model'`` (expert parallelism) so the dispatch einsum lowers
to the all-to-all that dominates the MoE roofline (see EXPERIMENTS.md —
dbrx/deepseek cells).  DeepSeekMoE-style shared experts run densely beside
the routed ones.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoECfg
from repro.distributed.meshctx import constrain
from repro.models.common import Policy, activation, dense_init

__all__ = [
    "init_dense_ffn",
    "dense_ffn",
    "init_moe",
    "moe_ffn",
]


def init_dense_ffn(key, d_model: int, d_ff: int, act: str):
    ks = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(ks[0], (d_model, d_ff)),
        "w_out": dense_init(ks[1], (d_ff, d_model)),
    }
    if act in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff))
    return p


def _w(params, key, dtype, cfg: ArchConfig | None, logical):
    w = params[key].astype(dtype)
    if cfg is not None and getattr(cfg, "fsdp_gather", False):
        w = constrain(w, logical)
    return w


def dense_ffn(params, x, act: str, cfg: ArchConfig | None = None):
    """x: [..., d]."""
    ff_sp = "model" if (cfg is None or cfg.layout != "dp_only") else None
    h = x @ _w(params, "w_in", x.dtype, cfg, (None, ff_sp))
    if act in ("swiglu", "geglu"):
        g = x @ _w(params, "w_gate", x.dtype, cfg, (None, ff_sp))
        h = h * (jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g))
    else:
        h = activation(act, h)
    return h @ _w(params, "w_out", x.dtype, cfg, (ff_sp, None))


def init_moe(key, d_model: int, cfg: MoECfg, act: str):
    ks = jax.random.split(key, 6)
    glu = act in ("swiglu", "geglu")
    p = {
        "router": dense_init(ks[0], (d_model, cfg.n_experts), scale=0.02),
        "w_in": dense_init(ks[1], (cfg.n_experts, d_model, cfg.d_ff_expert)),
        "w_out": dense_init(ks[2], (cfg.n_experts, cfg.d_ff_expert, d_model)),
    }
    if glu:
        p["w_gate"] = dense_init(ks[3], (cfg.n_experts, d_model, cfg.d_ff_expert))
    if cfg.n_shared:
        ff_sh = (cfg.d_ff_shared or cfg.d_ff_expert) * cfg.n_shared
        p["shared"] = init_dense_ffn(ks[4], d_model, ff_sh, act)
    return p


def moe_ffn(
    params,
    x,
    cfg: MoECfg,
    act: str,
    group_size: int = 4096,
    no_drop: bool = False,
    gather_dispatch: bool = False,
    arch_cfg: ArchConfig | None = None,
):
    """Top-k routed experts with capacity-bounded dispatch.

    ``x``: [B, S, d].  Returns [B, S, d] plus aux losses dict.

    ``no_drop=True`` sets capacity to the worst case (``gs * top_k``) so no
    token is ever dropped — used by the decode path, where capacity drops
    would silently skip the FFN for live requests.  Training keeps the
    GShard capacity-factor semantics (drops are part of the algorithm and
    of the roofline).

    ``gather_dispatch=True`` (§Perf hillclimb H2) replaces the classic
    GShard one-hot dispatch/combine einsums — which cost
    ``O(tokens · E · C · d)`` real MXU FLOPs, 9x the *useful* expert FLOPs
    for dbrx — with gather/scatter indexing (0 FLOPs in the cost model and
    on hardware: data movement only).  Identical routing semantics,
    validated against the einsum path in tests/test_models.py.
    """
    B, S, d = x.shape
    G = B * S
    gs = min(group_size, G)
    # pad token count to a multiple of the group size
    n_groups = -(-G // gs)
    pad = n_groups * gs - G
    xf = x.reshape(G, d)
    if pad:
        xf = jnp.concatenate([xf, jnp.zeros((pad, d), x.dtype)])
    xg = xf.reshape(n_groups, gs, d)

    logits = (xg @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # [n, g, E]
    topv, topi = jax.lax.top_k(probs, cfg.top_k)  # [n, g, k]
    if cfg.router_norm_topk:
        topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    if no_drop:
        capacity = gs * cfg.top_k
    else:
        capacity = max(1, int(cfg.capacity_factor * gs * cfg.top_k / cfg.n_experts))
    # one-hot expert assignment [n, g, k, E]
    assign = jax.nn.one_hot(topi, cfg.n_experts, dtype=jnp.float32)
    # position of each (token, slot) within its expert queue
    pos_in_expert = jnp.cumsum(assign.reshape(n_groups, gs * cfg.top_k, cfg.n_experts), axis=1)
    pos_in_expert = (pos_in_expert - 1).reshape(n_groups, gs, cfg.top_k, cfg.n_experts)
    keep = (pos_in_expert < capacity) & (assign > 0)
    pos_clamped = jnp.clip(pos_in_expert, 0, capacity - 1).astype(jnp.int32)

    def _expert_mlp(xe):
        h = jnp.einsum("necd,edf->necf", xe, _w(params, "w_in", x.dtype, arch_cfg, ("model", None, None)))
        if "w_gate" in params:
            g = jnp.einsum("necd,edf->necf", xe, _w(params, "w_gate", x.dtype, arch_cfg, ("model", None, None)))
            h = h * (jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g))
        else:
            h = activation(act, h)
        return jnp.einsum("necf,efd->necd", h, _w(params, "w_out", x.dtype, arch_cfg, ("model", None, None)))

    if gather_dispatch:
        E, C, kk = cfg.n_experts, capacity, cfg.top_k
        # slot id of each (token, choice): e*C + pos  (dropped -> dump slot)
        keep_k = jnp.take_along_axis(keep, topi[..., None], axis=-1)[..., 0]  # [n,g,k]
        pos_k = jnp.take_along_axis(pos_clamped, topi[..., None], axis=-1)[..., 0]
        slot = topi * C + pos_k  # [n, g, k]
        slot = jnp.where(keep_k, slot, E * C)  # dump slot
        gidx = jnp.arange(n_groups)[:, None, None]
        tok = jnp.broadcast_to(jnp.arange(gs)[None, :, None], slot.shape)
        # slot -> token index table (+1 dump row), and slot validity
        slot_tok = jnp.zeros((n_groups, E * C + 1), jnp.int32).at[gidx, slot].set(tok)
        slot_ok = jnp.zeros((n_groups, E * C + 1), jnp.float32).at[gidx, slot].set(1.0)
        # dispatch: pure gather (0 FLOPs)
        xe = jnp.take_along_axis(xg, slot_tok[:, : E * C, None], axis=1)  # [n, E*C, d]
        xe = xe * slot_ok[:, : E * C, None].astype(x.dtype)
        xe = constrain(xe.reshape(n_groups, E, C, d), (None, "model", None, None))
        ye = constrain(_expert_mlp(xe), (None, "model", None, None))
        # combine: gather each (token, choice)'s expert output back
        yf = ye.reshape(n_groups, E * C, d)
        slot_g = jnp.minimum(slot, E * C - 1)  # dropped slots masked via w
        picked = jnp.take_along_axis(
            yf, slot_g.reshape(n_groups, gs * kk)[..., None], axis=1
        ).reshape(n_groups, gs, kk, d)
        w = (topv * keep_k.astype(jnp.float32)).astype(x.dtype)
        y = jnp.einsum("ngk,ngkd->ngd", w, picked)
    else:
        # combine tensor [n, g, E, C] (classic GShard one-hot einsums)
        cap_onehot = jax.nn.one_hot(pos_clamped, capacity, dtype=jnp.float32)  # [n,g,k,E,C]
        combine = jnp.einsum(
            "ngk,ngke,ngkec->ngec",
            topv,
            assign * keep.astype(jnp.float32),
            cap_onehot,
        )
        dispatch = (combine > 0).astype(x.dtype)  # [n, g, E, C]
        # dispatch -> expert batches [n, E, C, d]; the E-axis constraint is
        # the expert-parallel all-to-all under the production mesh
        xe = jnp.einsum("ngec,ngd->necd", dispatch, xg)
        xe = constrain(xe, (None, "model", None, None))
        ye = constrain(_expert_mlp(xe), (None, "model", None, None))
        y = jnp.einsum("ngec,necd->ngd", combine.astype(x.dtype), ye)
    y = constrain(y, ("data", None, None))

    y = y.reshape(n_groups * gs, d)[:G].reshape(B, S, d)
    if cfg.n_shared and "shared" in params:
        y = y + dense_ffn(params["shared"], x, act, cfg=arch_cfg)

    # load-balance aux loss (Switch-style): mean prob * mean assignment
    me = probs.mean(axis=(0, 1))  # [E]
    ce = assign.sum(2).mean(axis=(0, 1))  # fraction routed per expert
    aux = cfg.n_experts * jnp.sum(me * ce)
    return y, {"moe_aux": aux}
