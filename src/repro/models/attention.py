"""Attention: GQA with blockwise-streaming softmax (flash-style), local
windowed attention, and single-token decode against a KV cache.

Training/prefill attention is a ``lax.scan`` over query blocks with an
inner scan over KV blocks carrying the running ``(max, denom, acc)`` —
the standard IO-aware streaming-softmax formulation in pure JAX.  Memory is
``O(S · block)`` instead of ``O(S²)``, which is what lets the 32k-prefill
cells compile inside 16 GB/chip.  The causal variant masks block pairs
above the diagonal; the baseline counts those wasted FLOPs honestly in the
roofline (§Perf iterates on it — see ``causal_skip`` below).

Grouped-query layout: ``q`` is ``[B, S, K, G, D]`` (K = kv heads, G =
queries per kv head), ``k``/``v`` are ``[B, S, K, D]``; scores contract
directly against the shared kv head without materialising repeated K/V.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["flash_attention", "flash_attention_fused", "local_attention", "decode_attention"]

_NEG = -1e30


def _pick_block(S: int, pref: int) -> int:
    """Largest divisor of S that is <= pref (keeps block scans exact for
    awkward lengths like Whisper's 1500 encoder frames)."""
    b = min(pref, S)
    while S % b:
        b -= 1
    return max(b, 1)


def _stream_softmax_block(q_blk, k_blk, v_blk, m, l, acc, mask):
    """One KV block update of the streaming softmax.

    q_blk: [B, bq, K, G, D]; k_blk/v_blk: [B, bk, K, D];
    m, l: [B, K, G, bq]; acc: [B, K, G, bq, D]; mask: [bq, bk] or None.
    """
    scale = q_blk.shape[-1] ** -0.5
    s = jnp.einsum(
        "bqkgd,bskd->bkgqs", q_blk.astype(jnp.float32), k_blk.astype(jnp.float32)
    ) * scale  # [B, K, G, bq, bk]
    if mask is not None:
        s = jnp.where(mask[None, None, None], s, _NEG)
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bkgqs,bskd->bkgqd", p, v_blk.astype(jnp.float32)
    )
    return m_new, l_new, acc_new


def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    q_block: int = 512,
    kv_block: int = 1024,
    causal_skip: bool = False,
):
    """Blockwise attention.  ``q``: [B, S, K, G, D]; ``k``/``v``: [B, S, K, D].

    ``causal_skip=True`` enables the beyond-baseline schedule that skips
    fully-masked KV blocks (unrolled per-q-block inner scans of exactly
    ``i+1`` blocks) — used by the §Perf hillclimb; the baseline keeps the
    uniform masked scan.
    """
    B, S, K, G, D = q.shape
    Skv = k.shape[1]
    bq = _pick_block(S, q_block)
    bk = _pick_block(Skv, kv_block)
    nq, nk = S // bq, Skv // bk
    qb = q.reshape(B, nq, bq, K, G, D)

    q_pos_base = jnp.arange(nq) * bq

    def q_step(_, qi):
        q_blk, q0 = qi  # [B, bq, K, G, D], scalar block start
        m0 = jnp.full((B, K, G, bq), _NEG, jnp.float32)
        l0 = jnp.zeros((B, K, G, bq), jnp.float32)
        a0 = jnp.zeros((B, K, G, bq, D), jnp.float32)

        def kv_step(carry, ki):
            m, l, acc = carry
            k0 = ki * bk
            k_blk = lax.dynamic_slice_in_dim(k, k0, bk, axis=1)
            v_blk = lax.dynamic_slice_in_dim(v, k0, bk, axis=1)
            if causal:
                qpos = q0 + jnp.arange(bq)
                kpos = k0 + jnp.arange(bk)
                mask = qpos[:, None] >= kpos[None, :]
            else:
                mask = None
            m, l, acc = _stream_softmax_block(q_blk, k_blk, v_blk, m, l, acc, mask)
            return (m, l, acc), None

        if causal and causal_skip:
            # process only blocks with any unmasked entry:
            # number of live kv blocks for q block i is ceil((q0+bq)/bk)
            n_live = (q0 + bq + bk - 1) // bk

            def guarded(carry, ki):
                do = ki < n_live

                def run(c):
                    return kv_step(c, ki)[0]

                carry = lax.cond(do, run, lambda c: c, carry)
                return carry, None

            (m, l, acc), _ = lax.scan(guarded, (m0, l0, a0), jnp.arange(nk))
        else:
            (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)  # [B, K, G, bq, D]

    _, outs = lax.scan(q_step, None, (jnp.moveaxis(qb, 1, 0), q_pos_base))
    # outs: [nq, B, K, G, bq, D] -> [B, S, K, G, D]
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 1, 4, 2, 3, 5).reshape(B, nq * bq, K, G, D)
    return out


# ---------------------------------------------------------------------------
# Fused-VJP flash attention (§Perf hillclimb H1)
#
# The naive scan formulation above is numerically fine but its BACKWARD is
# memory-catastrophic under jax AD: the per-(q-block, kv-block) probability
# tensors become scan residuals, stacked to a full [nq, nk, B, K, G, bq, bk]
# f32 buffer — the S^2 matrix flash exists to avoid (measured: 107 GiB/dev
# temp for llama3-405b train_4k).  ``flash_attention_fused`` implements the
# standard flash backward as a custom VJP: residuals are (q, k, v, out, lse)
# — O(S) — and the bwd recomputes score blocks on the fly, accumulating
# dk/dv across query blocks.
# ---------------------------------------------------------------------------

import functools as _functools


def _flash_fwd_loop(q, k, v, causal, bq, bk, parallel_q=False):
    """Returns (out [B,S,K,G,D] f32, lse [B,K,G,S] f32).

    ``parallel_q=True`` maps over query blocks with ``vmap`` instead of
    ``scan`` — the block dim then stays a *parallel* HLO dimension that
    GSPMD can shard over 'model' (hillclimb H3: shards attention FLOPs for
    archs whose head counts don't divide the TP axis, e.g. qwen2's 28).
    """
    B, S, K, G, D = q.shape
    Skv = k.shape[1]
    nq, nk = S // bq, Skv // bk
    qb = jnp.moveaxis(q.reshape(B, nq, bq, K, G, D), 1, 0)  # [nq, B, bq, K, G, D]
    if parallel_q:
        from repro.distributed.meshctx import constrain as _constrain

        qb = _constrain(qb, ("model", "data", None, None, None, None))

    def q_step(q_blk, q0):
        m0 = jnp.full((B, K, G, bq), _NEG, jnp.float32)
        l0 = jnp.zeros((B, K, G, bq), jnp.float32)
        a0 = jnp.zeros((B, K, G, bq, D), jnp.float32)

        def kv_step(carry, ki):
            m, l, acc = carry
            k0 = ki * bk
            k_blk = lax.dynamic_slice_in_dim(k, k0, bk, axis=1)
            v_blk = lax.dynamic_slice_in_dim(v, k0, bk, axis=1)
            mask = None
            if causal:
                qpos = q0 + jnp.arange(bq)
                kpos = k0 + jnp.arange(bk)
                mask = qpos[:, None] >= kpos[None, :]
            m, l, acc = _stream_softmax_block(q_blk, k_blk, v_blk, m, l, acc, mask)
            return (m, l, acc), None

        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out, lse

    q_pos = jnp.arange(nq) * bq
    if parallel_q:
        outs, lses = jax.vmap(q_step)(qb, q_pos)
    else:
        _, (outs, lses) = lax.scan(lambda _, xs: (None, q_step(*xs)), None, (qb, q_pos))
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 1, 4, 2, 3, 5).reshape(B, S, K, G, D)
    lse = jnp.moveaxis(lses, 0, 1)  # [B, nq, K, G, bq]
    lse = lse.transpose(0, 2, 3, 1, 4).reshape(B, K, G, S)
    return out, lse


@_functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_fused(
    q, k, v, causal: bool = True, q_block: int = 512, kv_block: int = 1024,
    parallel_q: bool = False,
):
    """Flash attention with O(S) residuals (fused custom VJP).

    Inputs stay in their compute dtype (bf16); only the per-block score /
    accumulator math runs in f32 inside ``_stream_softmax_block`` — an
    upfront f32 cast of q/k/v doubled the memory-roofline term 7x on the
    whisper cells (§Perf iteration log).
    """
    bq = _pick_block(q.shape[1], q_block)
    bk = _pick_block(k.shape[1], kv_block)
    out, _ = _flash_fwd_loop(q, k, v, causal, bq, bk, parallel_q)
    return out.astype(q.dtype)


def _flash_fused_fwd(q, k, v, causal, q_block, kv_block, parallel_q):
    bq = _pick_block(q.shape[1], q_block)
    bk = _pick_block(k.shape[1], kv_block)
    out, lse = _flash_fwd_loop(q, k, v, causal, bq, bk, parallel_q)
    return out.astype(q.dtype), (q, k, v, out.astype(q.dtype), lse)


def _flash_fused_bwd(causal, q_block, kv_block, parallel_q, res, do):
    q, k, v, out, lse = res
    B, S, K, G, D = q.shape
    Skv = k.shape[1]
    bq = _pick_block(S, q_block)
    bk = _pick_block(Skv, kv_block)
    nq, nk = S // bq, Skv // bk
    scale = D**-0.5
    qf = q.astype(jnp.float32).reshape(B, nq, bq, K, G, D)
    dof = do.astype(jnp.float32).reshape(B, nq, bq, K, G, D)
    of = out.astype(jnp.float32).reshape(B, nq, bq, K, G, D)
    lseb = lse.reshape(B, K, G, nq, bq)
    # delta_i = rowsum(do * o)
    delta = jnp.einsum("bnqkgd,bnqkgd->bkgnq", dof, of)  # [B,K,G,nq,bq]

    def q_block_bwd(q_blk, do_blk, lse_blk, delta_blk, q0):
        """Returns (dq_blk, dk_partial, dv_partial) for one query block."""

        def kv_step(carry2, ki):
            dq_blk, dk_acc, dv_acc = carry2
            k0 = ki * bk
            # per-block f32 casts only (full-tensor casts double HBM traffic)
            k_blk = lax.dynamic_slice_in_dim(k, k0, bk, axis=1).astype(jnp.float32)
            v_blk = lax.dynamic_slice_in_dim(v, k0, bk, axis=1).astype(jnp.float32)
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, k_blk) * scale
            if causal:
                qpos = q0 + jnp.arange(bq)
                kpos = k0 + jnp.arange(bk)
                mask = (qpos[:, None] >= kpos[None, :])[None, None, None]
                s = jnp.where(mask, s, _NEG)
            p = jnp.exp(s - lse_blk[..., None])  # [B,K,G,bq,bk]
            dv_upd = jnp.einsum("bkgqs,bqkgd->bskd", p, do_blk)
            dp = jnp.einsum("bqkgd,bskd->bkgqs", do_blk, v_blk)
            ds = p * (dp - delta_blk[..., None])
            dq_upd = jnp.einsum("bkgqs,bskd->bqkgd", ds, k_blk) * scale
            dk_upd = jnp.einsum("bkgqs,bqkgd->bskd", ds, q_blk) * scale
            dq_blk = dq_blk + dq_upd
            dk_acc = lax.dynamic_update_slice_in_dim(
                dk_acc, lax.dynamic_slice_in_dim(dk_acc, k0, bk, 1) + dk_upd, k0, 1
            )
            dv_acc = lax.dynamic_update_slice_in_dim(
                dv_acc, lax.dynamic_slice_in_dim(dv_acc, k0, bk, 1) + dv_upd, k0, 1
            )
            return (dq_blk, dk_acc, dv_acc), None

        dq0 = jnp.zeros((B, bq, K, G, D), jnp.float32)
        dk0 = jnp.zeros((B, Skv, K, D), jnp.float32)
        dv0 = jnp.zeros((B, Skv, K, D), jnp.float32)
        (dq_blk, dk_p, dv_p), _ = lax.scan(kv_step, (dq0, dk0, dv0), jnp.arange(nk))
        return dq_blk, dk_p, dv_p

    q_pos = jnp.arange(nq) * bq
    xs = (
        jnp.moveaxis(qf, 1, 0),
        jnp.moveaxis(dof, 1, 0),
        jnp.moveaxis(lseb, 3, 0),
        jnp.moveaxis(delta, 3, 0),
        q_pos,
    )
    if parallel_q:
        dqs, dk_p, dv_p = jax.vmap(q_block_bwd)(*xs)
        dk, dv = dk_p.sum(axis=0), dv_p.sum(axis=0)
    else:
        def q_step(carry, x):
            dk_acc, dv_acc = carry
            dq_blk, dk_p, dv_p = q_block_bwd(*x)
            return (dk_acc + dk_p, dv_acc + dv_p), dq_blk

        dk0 = jnp.zeros((B, Skv, K, D), jnp.float32)
        dv0 = jnp.zeros((B, Skv, K, D), jnp.float32)
        (dk, dv), dqs = lax.scan(q_step, (dk0, dv0), xs)
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, S, K, G, D)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_fused.defvjp(_flash_fused_fwd, _flash_fused_bwd)


def local_attention(q, k, v, *, window: int):
    """Sliding-window causal attention (RecurrentGemma local layers).

    Query block ``i`` (block size = window) attends to blocks ``i-1, i``
    with the exact mask ``0 <= qpos - kpos < window`` — static structure,
    ``O(S · 2w)`` memory and FLOPs.
    """
    B, S, K, G, D = q.shape
    w = min(window, S)
    pad = (-S) % w
    if pad:  # end-pad: padded keys sit at future positions -> masked out
        zf = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        q, k, v = zf(q), zf(k), zf(v)
        S_out, S = S, S + pad
    else:
        S_out = S
    n = S // w
    qb = q.reshape(B, n, w, K, G, D)
    kb = k.reshape(B, n, w, K, D)
    vb = v.reshape(B, n, w, K, D)
    # previous block (zero-padded at i=0)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([kprev, kb], axis=2)  # [B, n, 2w, K, D]
    v2 = jnp.concatenate([vprev, vb], axis=2)
    scale = D**-0.5
    s = jnp.einsum(
        "bnqkgd,bnskd->bnkgqs", qb.astype(jnp.float32), k2.astype(jnp.float32)
    ) * scale  # [B, n, K, G, w, 2w]
    qpos = jnp.arange(w)[:, None]
    kpos = jnp.arange(2 * w)[None, :] - w
    delta = qpos - kpos
    mask = (delta >= 0) & (delta < w)
    first_block = jnp.arange(n) == 0
    kvalid = (jnp.arange(2 * w) >= w)[None, :] | (~first_block)[:, None]
    full_mask = mask[None] & kvalid[:, None, :]  # [n, w, 2w]
    s = jnp.where(full_mask[None, :, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnkgqs,bnskd->bnqkgd", p, v2.astype(jnp.float32))
    out = out.reshape(B, S, K, G, D).astype(q.dtype)
    return out[:, :S_out]


def decode_attention(q, k_cache, v_cache, pos):
    """Single-token attention against a cache.

    ``q``: [B, 1, K, G, D]; caches: [B, Smax, K, D]; ``pos``: [B] current
    lengths (new token goes at index ``pos``; caller already inserted it).
    """
    Smax = k_cache.shape[1]
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum(
        "bqkgd,bskd->bkgqs", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale  # [B, K, G, 1, Smax]
    valid = jnp.arange(Smax)[None, :] <= pos[:, None]  # [B, Smax]
    s = jnp.where(valid[:, None, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)
