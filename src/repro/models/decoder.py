"""Unified decoder-only LM covering all assigned families.

One model assembly handles dense / MoE / SSM / hybrid / early-fusion-VLM
(and the decoder half of the enc-dec family): the config's
``layer_groups()`` describe the layer stack as repeating groups of
``LayerSpec``s, and the assembly ``lax.scan``s over each group's repeats
with stacked parameters — HLO size stays bounded at 126 layers, remat
wraps the scan body, and FSDP-style parameter gathers happen per layer
inside the scan (DESIGN.md §9).

Three entry points per model:
  * ``forward``  — training forward, full logits ``[B, S, V]``;
  * ``prefill``  — forward that also emits the serving cache;
  * ``decode``   — one-token step against the cache (``serve_step``).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, BlockGroup, LayerSpec
from repro.distributed.meshctx import constrain
from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import rglru as rglru_mod
from repro.models import ssd as ssd_mod
from repro.models.common import Policy, dense_init, norm_apply, take_embedding, apply_rope

__all__ = ["init_decoder", "decoder_forward", "decoder_prefill", "decoder_decode", "init_cache"]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_norm(cfg: ArchConfig):
    p = {"scale": jnp.zeros((cfg.d_model,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return p


def _init_attn(key, cfg: ArchConfig, cross: bool = False):
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * hd)),
        "wk": dense_init(ks[1], (d, K * hd)),
        "wv": dense_init(ks[2], (d, K * hd)),
        "wo": dense_init(ks[3], (H * hd, d), scale=(H * hd) ** -0.5 / (2 * cfg.n_layers) ** 0.5),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * hd,), jnp.float32)
        p["bk"] = jnp.zeros((K * hd,), jnp.float32)
        p["bv"] = jnp.zeros((K * hd,), jnp.float32)
    return p


def _init_layer(key, spec: LayerSpec, cfg: ArchConfig):
    ks = jax.random.split(key, 4)
    p: dict = {"norm1": _init_norm(cfg)}
    if spec.mixer in ("attn", "local"):
        p["attn"] = _init_attn(ks[0], cfg)
    elif spec.mixer == "xattn":
        p["attn"] = _init_attn(ks[0], cfg)
        p["xnorm"] = _init_norm(cfg)
        p["xatt"] = _init_attn(ks[3], cfg, cross=True)
    elif spec.mixer == "ssd":
        p["ssd"] = ssd_mod.init_ssd_block(ks[0], cfg)
    elif spec.mixer == "rglru":
        p["rglru"] = rglru_mod.init_rglru_block(ks[0], cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn != "none":
        p["norm2"] = _init_norm(cfg)
        if spec.ffn == "dense":
            p["ffn"] = ffn_mod.init_dense_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_act)
        else:
            p["moe"] = ffn_mod.init_moe(ks[1], cfg.d_model, cfg.moe, cfg.ffn_act)
    return p


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_group(key, group: BlockGroup, cfg: ArchConfig):
    """Params for one BlockGroup: per-spec-position stacks of `repeat`."""
    out = {}
    for i, spec in enumerate(group.specs):
        ks = jax.random.split(key, group.repeat + 1)
        key = ks[0]
        out[f"p{i}"] = _stack([_init_layer(k, spec, cfg) for k in ks[1:]])
    return out


def init_decoder(key, cfg: ArchConfig):
    ks = jax.random.split(key, 3 + len(cfg.layer_groups()))
    params = {
        "embed": dense_init(ks[0], (cfg.vocab, cfg.d_model), scale=0.02),
        "final_norm": _init_norm(cfg),
        "groups": [
            init_group(ks[3 + gi], g, cfg) for gi, g in enumerate(cfg.layer_groups())
        ],
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ks[1], (cfg.d_model, cfg.vocab), scale=0.02)
    return params


# --------------------------------------------------------------------------
# layer application
# --------------------------------------------------------------------------

def _batch_axis(cfg: ArchConfig) -> str:
    return "batch_all" if cfg.layout == "dp_only" else "data"


def _w(p, key, dtype, cfg: ArchConfig, logical):
    """Weight in compute dtype, optionally constrained to its gathered
    (TP-only) layout so GSPMD must all-gather the WEIGHT over the FSDP axis
    rather than partial-summing / gathering activations."""
    w = p[key].astype(dtype)
    if cfg.fsdp_gather:
        w = constrain(w, logical)
    return w


def _qkv(p, x, positions, cfg: ArchConfig, rope: bool = True):
    B, S, _ = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // K
    head_sp = None if (cfg.layout == "dp_only" or cfg.q_parallel) else "model"
    q = x @ _w(p, "wq", x.dtype, cfg, (None, head_sp))
    k = x @ _w(p, "wk", x.dtype, cfg, (None, head_sp))
    v = x @ _w(p, "wv", x.dtype, cfg, (None, head_sp))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    # constrain on the flattened head dim (always divisible by the TP axis;
    # GSPMD propagates through the [B,S,K,G,hd] reshape).  In dp_only
    # layout (or with parallel-q attention, which shards the q-block dim
    # instead) the head dim stays unsharded.
    ba = _batch_axis(cfg)
    head_ax = None if (cfg.layout == "dp_only" or cfg.q_parallel) else "model"
    q = constrain(q, (ba, None, head_ax))
    k = constrain(k, (ba, None, head_ax))
    v = constrain(v, (ba, None, head_ax))
    q = q.reshape(B, S, K, G, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    if rope:
        qf = q.reshape(B, S, K * G, hd)
        qf = apply_rope(qf, positions, cfg.rope_theta)
        q = qf.reshape(B, S, K, G, hd)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attn_out(p, o, cfg: ArchConfig):
    # o: [B, S, K, G, hd]; head h = k*G + g matches the _qkv packing
    B, S = o.shape[0], o.shape[1]
    head_sp = None if (cfg.layout == "dp_only" or cfg.q_parallel) else "model"
    o = o.reshape(B, S, cfg.n_heads * cfg.hd)
    return o @ _w(p, "wo", o.dtype, cfg, (head_sp, None))


def _mixer_forward(spec, p, x, positions, cfg: ArchConfig, enc_out, want_cache: bool):
    """Returns (out, cache_or_None)."""
    if spec.mixer in ("attn", "local"):
        q, k, v = _qkv(p["attn"], x, positions, cfg)
        if spec.mixer == "attn":
            if cfg.flash_vjp:
                o = attn.flash_attention_fused(
                    q, k, v, True, cfg.q_block, cfg.kv_block, cfg.q_parallel
                )
            else:
                o = attn.flash_attention(
                    q, k, v, causal=True, q_block=cfg.q_block, kv_block=cfg.kv_block
                )
        else:
            o = attn.local_attention(q, k, v, window=cfg.hybrid.window)
        out = _attn_out(p["attn"], o, cfg)
        cache = None
        if want_cache:
            if spec.mixer == "local":
                w = cfg.hybrid.window
                S = k.shape[1]
                keep = min(w, S)
                cache = {"k": k[:, S - keep :], "v": v[:, S - keep :]}
            else:
                cache = {"k": k, "v": v}
        return out, cache
    if spec.mixer == "xattn":
        q, k, v = _qkv(p["attn"], x, positions, cfg)
        if cfg.flash_vjp:
            o = attn.flash_attention_fused(q, k, v, True, cfg.q_block, cfg.kv_block, cfg.q_parallel)
        else:
            o = attn.flash_attention(q, k, v, causal=True, q_block=cfg.q_block, kv_block=cfg.kv_block)
        out = _attn_out(p["attn"], o, cfg)
        cache = {"k": k, "v": v} if want_cache else None
        return out, cache  # cross-attn handled by caller (needs its own norm)
    if spec.mixer == "ssd":
        out = ssd_mod.ssd_block_forward(p["ssd"], x, cfg)
        cache = None
        if want_cache:
            # rebuild decode-ready state by replaying the tail: cheap exact
            # approach — run a one-step decode cache from full forward is
            # complex; instead recompute final state via chunked scan
            cache = _ssd_state_from_forward(p["ssd"], x, cfg)
        return out, cache
    if spec.mixer == "rglru":
        out, state = rglru_mod.rglru_block_forward(p["rglru"], x, cfg)
        cache = None
        if want_cache:
            hx = x @ p["rglru"]["w_x_in"].astype(x.dtype)
            cache = {"conv": hx[:, -3:, :], "state": state}
        return out, cache
    raise ValueError(spec.mixer)


def _ssd_state_from_forward(p, x, cfg: ArchConfig):
    """Final (conv, ssm) state after consuming ``x`` — for prefill→decode."""
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    proj = x @ p["in_proj"].astype(x.dtype)
    _, xi, Bm, Cm, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + s.d_state, 2 * di + 2 * s.d_state], axis=-1
    )
    xbc = jnp.concatenate([xi, Bm, Cm], axis=-1)
    conv_tail = xbc[:, -(s.conv_width - 1) :, :]
    xbc_c = ssd_mod._causal_conv(xbc, p["conv_w"], p["conv_b"])
    xi, Bm, Cm = jnp.split(xbc_c, [di, di + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    lA = dt * A[None, None, :]  # [B, S, H]
    cum = jnp.cumsum(lA, axis=1)
    total = cum[:, -1][:, None, :]  # [B, 1, H]
    w = jnp.exp(total - cum) * dt  # decay from t..S times dt
    xh = xi.reshape(*xi.shape[:2], nh, s.head_dim).astype(jnp.float32)
    state = jnp.einsum("bth,btn,bthp->bhnp", w, Bm.astype(jnp.float32), xh)
    return {"conv": conv_tail, "state": state}


def _layer_forward(spec, p, x, positions, cfg, enc_out, want_cache):
    h = norm_apply(cfg.norm, x, p["norm1"])
    mix, cache = _mixer_forward(spec, p, h, positions, cfg, enc_out, want_cache)
    x = x + mix
    if spec.mixer == "xattn":
        hx = norm_apply(cfg.norm, x, p["xnorm"])
        B, S, _ = hx.shape
        K, G, hd = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.hd
        q = (hx @ p["xatt"]["wq"].astype(hx.dtype)).reshape(B, S, K, G, hd)
        ek = (enc_out @ p["xatt"]["wk"].astype(hx.dtype)).reshape(B, -1, K, hd)
        ev = (enc_out @ p["xatt"]["wv"].astype(hx.dtype)).reshape(B, -1, K, hd)
        o = attn.flash_attention(q, ek, ev, causal=False, q_block=cfg.q_block, kv_block=cfg.kv_block)
        x = x + _attn_out(p["xatt"], o, cfg)
        if want_cache and cache is not None:
            cache = dict(cache, xk=ek, xv=ev)
    aux = {}
    if spec.ffn != "none":
        h2 = norm_apply(cfg.norm, x, p["norm2"])
        if spec.ffn == "dense":
            y = ffn_mod.dense_ffn(p["ffn"], h2, cfg.ffn_act, cfg=cfg)
        else:
            y, aux = ffn_mod.moe_ffn(
                p["moe"], h2, cfg.moe, cfg.ffn_act,
                gather_dispatch=cfg.moe_gather, arch_cfg=cfg,
            )
        x = x + y
    x = constrain(x, (_batch_axis(cfg), None, None))
    return x, cache, aux


# --------------------------------------------------------------------------
# model-level forward / prefill
# --------------------------------------------------------------------------

def _remat_policy(cfg: ArchConfig):
    if cfg.remat == "none":
        return None
    if cfg.remat == "dots":
        return jax.checkpoint_policies.dots_saveable
    return jax.checkpoint_policies.nothing_saveable


def _run_groups(params, x, positions, cfg: ArchConfig, enc_out, want_cache: bool,
                groups: list[BlockGroup] | None = None):
    groups = groups if groups is not None else cfg.layer_groups()
    caches = []
    aux_total = jnp.zeros((), jnp.float32)

    for gi, group in enumerate(groups):
        gp = params["groups"][gi]

        def body(carry, layer_p):
            x, aux_acc = carry
            layer_caches = {}
            for i, spec in enumerate(group.specs):
                x, cache, aux = _layer_forward(
                    spec, layer_p[f"p{i}"], x, positions, cfg, enc_out, want_cache
                )
                if want_cache:
                    layer_caches[f"p{i}"] = cache
                for v in aux.values():
                    aux_acc = aux_acc + v
            return (x, aux_acc), (layer_caches if want_cache else None)

        policy = _remat_policy(cfg)
        if policy is not None:
            body = jax.checkpoint(body, policy=policy)
        (x, aux_total), ys = lax.scan(body, (x, aux_total), gp)
        if want_cache:
            caches.append(ys)
    return x, caches, aux_total


def decoder_forward(params, tokens, cfg: ArchConfig, enc_out=None):
    """Training forward: tokens [B, S] -> logits [B, S, V] (f32), aux."""
    B, S = tokens.shape
    ba = _batch_axis(cfg)
    x = take_embedding(params["embed"], tokens)
    x = constrain(x, (ba, None, None))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x, _, aux = _run_groups(params, x, positions, cfg, enc_out, want_cache=False)
    x = norm_apply(cfg.norm, x, params["final_norm"])
    unemb = params.get("unembed")
    w = (unemb if unemb is not None else params["embed"].T).astype(x.dtype)
    vocab_sp = "model" if cfg.layout == "tp" else None
    if cfg.fsdp_gather:
        w = constrain(w, (None, vocab_sp))
    logits = (x @ w).astype(jnp.float32)
    logits = constrain(logits, (ba, None, vocab_sp))
    return logits, {"aux_loss": aux}


def decoder_prefill(params, tokens, cfg: ArchConfig, enc_out=None, pad_cache_to: int | None = None):
    """Prefill: returns (last-position logits [B, V], cache pytree)."""
    B, S = tokens.shape
    x = take_embedding(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x, caches, _ = _run_groups(params, x, positions, cfg, enc_out, want_cache=True)
    x = norm_apply(cfg.norm, x, params["final_norm"])
    last = x[:, -1, :]
    unemb = params.get("unembed")
    w = unemb if unemb is not None else params["embed"].T
    logits = (last @ w.astype(x.dtype)).astype(jnp.float32)
    if pad_cache_to is not None:
        caches = _pad_kv_caches(caches, cfg, pad_cache_to)
    pos = jnp.full((B,), S, jnp.int32)  # next token's index
    return logits, {"groups": caches, "pos": pos}


def _pad_kv_caches(caches, cfg: ArchConfig, smax: int):
    """Pad KV time axes (axis 2 of [R, B, S, K, hd]) to ``smax`` slots."""
    out = []
    for group_cache in caches:
        new_group = {}
        for key, c in group_cache.items():
            if c is None:
                new_group[key] = None
                continue
            nc = dict(c)
            for name in ("k", "v"):
                if name in nc:
                    arr = nc[name]
                    S = arr.shape[2]
                    if S < smax:
                        padw = [(0, 0)] * arr.ndim
                        padw[2] = (0, smax - S)
                        nc[name] = jnp.pad(arr, padw)
                    elif S > smax:
                        nc[name] = arr[:, :, -smax:]
            new_group[key] = nc
        out.append(new_group)
    return out


# --------------------------------------------------------------------------
# cache init + decode
# --------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int, enc_len: int | None = None,
               dtype=None):
    """Zeroed serving cache matching the decode step's expectations."""
    dtype = dtype or Policy.compute_dtype
    K, hd = cfg.n_kv_heads, cfg.hd
    groups = []
    for group in cfg.layer_groups():
        g = {}
        for i, spec in enumerate(group.specs):
            R = group.repeat
            if spec.mixer == "attn":
                c = {
                    "k": jnp.zeros((R, batch, max_len, K, hd), dtype),
                    "v": jnp.zeros((R, batch, max_len, K, hd), dtype),
                }
            elif spec.mixer == "local":
                w = min(cfg.hybrid.window, max_len)
                c = {
                    "k": jnp.zeros((R, batch, w, K, hd), dtype),
                    "v": jnp.zeros((R, batch, w, K, hd), dtype),
                }
            elif spec.mixer == "xattn":
                assert enc_len is not None
                c = {
                    "k": jnp.zeros((R, batch, max_len, K, hd), dtype),
                    "v": jnp.zeros((R, batch, max_len, K, hd), dtype),
                    "xk": jnp.zeros((R, batch, enc_len, K, hd), dtype),
                    "xv": jnp.zeros((R, batch, enc_len, K, hd), dtype),
                }
            elif spec.mixer == "ssd":
                c = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (R, *a.shape)),
                    ssd_mod.init_ssd_cache(cfg, batch),
                )
            elif spec.mixer == "rglru":
                c = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (R, *a.shape)),
                    rglru_mod.init_rglru_cache(cfg, batch),
                )
            else:
                raise ValueError(spec.mixer)
            g[f"p{i}"] = c
        groups.append(g)
    return {"groups": groups, "pos": jnp.zeros((batch,), jnp.int32)}


def _layer_decode(spec, p, x, cache, pos, cfg: ArchConfig):
    """x: [B, 1, d]; returns (x, new_cache)."""
    h = norm_apply(cfg.norm, x, p["norm1"])
    B = x.shape[0]
    K, G, hd = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.hd
    if spec.mixer in ("attn", "local", "xattn"):
        q, k, v = _qkv(p["attn"], h, pos[:, None], cfg)
        if spec.mixer == "local":
            w = cache["k"].shape[1]
            slot = (pos % w).astype(jnp.int32)
            kc = _scatter_time(cache["k"], k[:, 0], slot)
            vc = _scatter_time(cache["v"], v[:, 0], slot)
            # ring buffer holds the last `w` tokens; all slots written so
            # far are valid (pos+1 >= w ⇒ all w)
            valid_upto = jnp.minimum(pos, w - 1)
            o = attn.decode_attention(q, kc, vc, valid_upto)
        else:
            slot = pos.astype(jnp.int32)
            kc = _scatter_time(cache["k"], k[:, 0], slot)
            vc = _scatter_time(cache["v"], v[:, 0], slot)
            o = attn.decode_attention(q, kc, vc, pos)
        x = x + _attn_out(p["attn"], o, cfg)
        new_cache = dict(cache, k=kc, v=vc)
        if spec.mixer == "xattn":
            hx = norm_apply(cfg.norm, x, p["xnorm"])
            q2 = (hx @ p["xatt"]["wq"].astype(hx.dtype)).reshape(B, 1, K, G, hd)
            enc_len = cache["xk"].shape[1]
            full = jnp.full((B,), enc_len - 1, jnp.int32)
            o2 = attn.decode_attention(q2, cache["xk"], cache["xv"], full)
            x = x + _attn_out(p["xatt"], o2, cfg)
    elif spec.mixer == "ssd":
        y, nc = ssd_mod.ssd_block_decode(p["ssd"], h, cache, cfg)
        x = x + y
        new_cache = nc
    elif spec.mixer == "rglru":
        y, nc = rglru_mod.rglru_block_decode(p["rglru"], h, cache, cfg)
        x = x + y
        new_cache = nc
    else:
        raise ValueError(spec.mixer)
    if spec.ffn != "none":
        h2 = norm_apply(cfg.norm, x, p["norm2"])
        if spec.ffn == "dense":
            x = x + ffn_mod.dense_ffn(p["ffn"], h2, cfg.ffn_act)
        else:
            y, _ = ffn_mod.moe_ffn(p["moe"], h2, cfg.moe, cfg.ffn_act, no_drop=True)
            x = x + y
    return x, new_cache


def _scatter_time(cache_kv, new_kv, slot):
    """cache_kv: [B, S, K, hd]; new_kv: [B, K, hd]; slot: [B]."""
    B = cache_kv.shape[0]
    return cache_kv.at[jnp.arange(B), slot].set(new_kv.astype(cache_kv.dtype))


def decoder_decode(params, token, cache, cfg: ArchConfig):
    """serve_step: one new token.  token [B, 1] int32 -> (logits [B, V], cache)."""
    B = token.shape[0]
    pos = cache["pos"]  # index of the new token
    x = take_embedding(params["embed"], token)
    new_groups = []
    for gi, group in enumerate(cfg.layer_groups()):
        gp = params["groups"][gi]
        gc = cache["groups"][gi]

        def body(x, inp):
            layer_p, layer_c = inp
            new_c = {}
            for i, spec in enumerate(group.specs):
                x, nc = _layer_decode(spec, layer_p[f"p{i}"], x, layer_c[f"p{i}"], pos, cfg)
                new_c[f"p{i}"] = nc
            return x, new_c

        x, ys = lax.scan(body, x, (gp, gc))
        new_groups.append(ys)
    x = norm_apply(cfg.norm, x, params["final_norm"])
    unemb = params.get("unembed")
    w = unemb if unemb is not None else params["embed"].T
    logits = (x[:, 0] @ w.astype(x.dtype)).astype(jnp.float32)
    return logits, {"groups": new_groups, "pos": pos + 1}
