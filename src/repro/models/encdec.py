"""Whisper-style encoder–decoder (audio family).

The conv frontend is a STUB per the assignment brief: ``input_specs()``
supplies precomputed frame embeddings ``[B, n_frames, d]`` (what the two
strided conv1d layers would produce).  The encoder is a stack of
bidirectional attention blocks; the decoder is the unified decoder with
``xattn`` layers (causal self-attention + cross-attention to the encoder
output).  Decode caches both the growing self-attn KV and the static
cross-attn KV (computed once from the encoder output).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, BlockGroup, LayerSpec
from repro.models import attention as attn
from repro.models import decoder as dec
from repro.models.common import Policy, norm_apply

__all__ = ["init_encdec", "encdec_forward", "encode", "encdec_prefill", "encdec_decode"]


def init_encdec(key, cfg: ArchConfig):
    k_enc, k_dec = jax.random.split(key)
    params = dec.init_decoder(k_dec, cfg)
    enc_groups = cfg.encoder_groups()
    ks = jax.random.split(k_enc, len(enc_groups) + 1)
    params["encoder"] = {
        "groups": [dec.init_group(ks[1 + gi], g, cfg) for gi, g in enumerate(enc_groups)],
        "final_norm": {"scale": jnp.zeros((cfg.d_model,), jnp.float32)},
    }
    return params


def encode(params, frames, cfg: ArchConfig):
    """frames: [B, T, d] precomputed frame embeddings -> [B, T, d]."""
    x = frames.astype(Policy.compute_dtype)
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    groups = cfg.encoder_groups()

    for gi, group in enumerate(groups):
        gp = params["encoder"]["groups"][gi]

        def body(carry, layer_p):
            x = carry
            for i, spec in enumerate(group.specs):
                p = layer_p[f"p{i}"]
                h = norm_apply(cfg.norm, x, p["norm1"])
                q, k, v = dec._qkv(p["attn"], h, positions, cfg, rope=False)
                o = attn.flash_attention(
                    q, k, v, causal=False, q_block=cfg.q_block, kv_block=cfg.kv_block
                )
                x = x + dec._attn_out(p["attn"], o, cfg)
                h2 = norm_apply(cfg.norm, x, p["norm2"])
                from repro.models import ffn as ffn_mod

                x = x + ffn_mod.dense_ffn(p["ffn"], h2, cfg.ffn_act)
            return x, None

        policy = dec._remat_policy(cfg)
        if policy is not None:
            body = jax.checkpoint(body, policy=policy)
        x, _ = lax.scan(body, x, gp)
    return norm_apply(cfg.norm, x, params["encoder"]["final_norm"])


def encdec_forward(params, tokens, frames, cfg: ArchConfig):
    enc_out = encode(params, frames, cfg)
    return dec.decoder_forward(params, tokens, cfg, enc_out=enc_out)


def encdec_prefill(params, tokens, frames, cfg: ArchConfig, pad_cache_to=None):
    enc_out = encode(params, frames, cfg)
    return dec.decoder_prefill(params, tokens, cfg, enc_out=enc_out, pad_cache_to=pad_cache_to)


def encdec_decode(params, token, cache, cfg: ArchConfig):
    """Cross-attn KV lives in the cache; no encoder pass per token."""
    return dec.decoder_decode(params, token, cache, cfg)
