"""Model registry: ArchConfig -> Model (init / forward / prefill / decode).

``Model`` is a thin namespace of pure functions so jit/pjit boundaries stay
at the launcher level.  ``forward``/``prefill`` take ``extras`` — the
modality-stub inputs (Whisper frame embeddings) — uniformly, so the
launcher treats every arch identically.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import decoder as dec
from repro.models import encdec as ed

__all__ = ["Model", "build_model"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[..., Any]
    forward: Callable[..., Any]  # (params, tokens, extras) -> (logits, aux)
    prefill: Callable[..., Any]  # (params, tokens, extras, pad_cache_to) -> (logits, cache)
    decode: Callable[..., Any]  # (params, token, cache) -> (logits, cache)
    init_cache: Callable[..., Any]  # (batch, max_len) -> cache

    def extras_shapes(self, batch: int) -> dict:
        """ShapeDtypeStruct-compatible spec of modality-stub inputs."""
        if self.cfg.is_encdec:
            return {
                "frames": (
                    (batch, self.cfg.encdec.n_frames, self.cfg.d_model),
                    jnp.bfloat16,
                )
            }
        return {}


def build_model(cfg: ArchConfig) -> Model:
    if cfg.is_encdec:
        def forward(params, tokens, extras):
            return ed.encdec_forward(params, tokens, extras["frames"], cfg)

        def prefill(params, tokens, extras, pad_cache_to=None):
            return ed.encdec_prefill(
                params, tokens, extras["frames"], cfg, pad_cache_to=pad_cache_to
            )

        def decode(params, token, cache):
            return ed.encdec_decode(params, token, cache, cfg)

        def init_cache(batch, max_len):
            return dec.init_cache(cfg, batch, max_len, enc_len=cfg.encdec.n_frames)

        return Model(cfg, lambda key: ed.init_encdec(key, cfg), forward, prefill, decode, init_cache)

    def forward(params, tokens, extras):
        return dec.decoder_forward(params, tokens, cfg)

    def prefill(params, tokens, extras, pad_cache_to=None):
        return dec.decoder_prefill(params, tokens, cfg, pad_cache_to=pad_cache_to)

    def decode(params, token, cache):
        return dec.decoder_decode(params, token, cache, cfg)

    def init_cache(batch, max_len):
        return dec.init_cache(cfg, batch, max_len)

    return Model(cfg, lambda key: dec.init_decoder(key, cfg), forward, prefill, decode, init_cache)
