"""Mamba-2 SSD mixer (state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked dual form: within a chunk the quadratic
"attention-like" branch, across chunks a linear state recurrence —
``O(S·Q·P + S·N·P)`` FLOPs with chunk length ``Q``, never materialising the
``S×S`` kernel.  Decode is the O(1)-state recurrent step, which is what
makes the ``long_500k`` cell runnable for this family (DESIGN.md §5).

Layout: heads ``H = d_inner / head_dim``; per head a scalar decay ``a_t =
exp(Δt·A)``; shared single-group ``B, C ∈ [S, N]`` (Mamba-2 default
n_groups=1).  The block follows the published structure: in_proj →
short causal conv over (x, B, C) → SSD → gated RMSNorm → out_proj.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.common import dense_init, rmsnorm

__all__ = ["init_ssd_block", "ssd_block_forward", "ssd_block_decode", "init_ssd_cache"]


def init_ssd_block(key, cfg: ArchConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    conv_dim = di + 2 * s.d_state
    ks = jax.random.split(key, 5)
    lo, hi = s.a_init_range
    a = jnp.exp(
        jax.random.uniform(ks[3], (nh,), jnp.float32, jnp.log(lo), jnp.log(hi))
    )
    return {
        # projections for [z (gate), x, B, C, dt]
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * s.d_state + nh)),
        "conv_w": dense_init(ks[1], (s.conv_width, conv_dim), scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(a),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_scale": jnp.zeros((di,), jnp.float32),
        "out_proj": dense_init(ks[2], (di, d)),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv1d.  x: [B, S, C]; w: [W, C]."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):  # W == 4: unrolled taps, XLA fuses
        out = out + pad[:, i : i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
    return jax.nn.silu(out + b[None, None, :].astype(x.dtype))


def _ssd_chunked(x, dt, A, Bm, Cm, D, chunk: int):
    """Chunked SSD scan.

    x: [B, S, H, P]; dt: [B, S, H]; A: [H] (negative); Bm, Cm: [B, S, N].
    Returns y: [B, S, H, P].
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:  # causal: end-padding with zero dt/B/x never leaks backwards
        zf = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, dt, Bm, Cm = zf(x), zf(dt), zf(Bm), zf(Cm)
        S = S + pad
    nC = S // Q
    # per-step log decay  l_t = dt_t * A  (<= 0)
    lA = dt * A[None, None, :]  # [B, S, H]
    xc = x.reshape(Bsz, nC, Q, H, P)
    dtc = dt.reshape(Bsz, nC, Q, H)
    lc = lA.reshape(Bsz, nC, Q, H)
    Bc = Bm.reshape(Bsz, nC, Q, N)
    Cc = Cm.reshape(Bsz, nC, Q, N)
    cum = jnp.cumsum(lc, axis=2)  # [B, nC, Q, H] inclusive
    total = cum[:, :, -1]  # [B, nC, H]

    # ---- intra-chunk (quadratic within chunk) ---------------------------
    # L[i, j] = exp(cum_i - cum_j) for i >= j  (decay from j+1..i).
    # Mask the exponent BEFORE exp: above-diagonal diffs are large positive
    # (cum is decreasing), exp overflows to inf, and `where(mask, inf, 0)`
    # still back-propagates 0*inf = NaN through the discarded branch.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nC,Q(i),Q(j),H]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    diff = jnp.where(causal[None, None, :, :, None], diff, -1e30)
    Lmat = jnp.exp(diff)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # [B,nC,Q,Q]
    gated = scores[..., None] * Lmat  # [B,nC,Q,Q,H]
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", gated, dtc, xc)

    # ---- chunk states and inter-chunk recurrence ------------------------
    # state_c = sum_j exp(total - cum_j) * dt_j * B_j ⊗ x_j
    w = jnp.exp(total[:, :, None, :] - cum) * dtc  # [B,nC,Q,H]
    states = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", w, Bc, xc)  # [B,nC,H,N,P]
    decay_chunk = jnp.exp(total)  # [B, nC, H]

    def scan_fn(h, inp):
        s_c, d_c = inp  # [B,H,N,P], [B,H]
        h_new = h * d_c[:, :, None, None] + s_c
        return h_new, h

    h0 = jnp.zeros((Bsz, H, N, P), x.dtype)
    _, h_prefix = lax.scan(
        scan_fn,
        h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(decay_chunk, 1, 0)),
    )
    h_prefix = jnp.moveaxis(h_prefix, 0, 1)  # [B, nC, H, N, P] state before chunk

    # contribution of carried-in state: y += C_i · (exp(cum_i) * h_in)
    y_inter = jnp.einsum("bcin,bchnp->bcihp", Cc, h_prefix) * jnp.exp(cum)[..., None]
    y = y_intra + y_inter + x.reshape(Bsz, nC, Q, H, P) * D[None, None, None, :, None]
    y = y.reshape(Bsz, S, H, P)
    return y[:, : S - pad] if pad else y


def ssd_block_forward(params, x, cfg: ArchConfig):
    """Full block: x [B, S, d] -> [B, S, d]."""
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    proj = x @ params["in_proj"].astype(x.dtype)
    z, xi, Bm, Cm, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + s.d_state, 2 * di + 2 * s.d_state], axis=-1
    )
    xbc = jnp.concatenate([xi, Bm, Cm], axis=-1)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xi, Bm, Cm = jnp.split(xbc, [di, di + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])  # [H], negative
    xh = xi.reshape(*xi.shape[:2], nh, s.head_dim)
    y = _ssd_chunked(
        xh.astype(jnp.float32), dt, A, Bm.astype(jnp.float32), Cm.astype(jnp.float32),
        params["D"], s.chunk,
    )
    y = y.reshape(*x.shape[:2], di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_scale"])
    return y @ params["out_proj"].astype(x.dtype)


def init_ssd_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    conv_dim = di + 2 * s.d_state
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, nh, s.d_state, s.head_dim), dtype),
    }


def ssd_block_decode(params, x, cache, cfg: ArchConfig):
    """One-token step.  x: [B, 1, d]; returns (y [B, 1, d], new cache)."""
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    proj = x[:, 0] @ params["in_proj"].astype(x.dtype)  # [B, ...]
    z, xi, Bm, Cm, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + s.d_state, 2 * di + 2 * s.d_state], axis=-1
    )
    xbc = jnp.concatenate([xi, Bm, Cm], axis=-1)  # [B, conv_dim]
    conv_in = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # [B, W, C]
    w = params["conv_w"].astype(x.dtype)
    conv_out = jnp.einsum("bwc,wc->bc", conv_in, w) + params["conv_b"].astype(x.dtype)
    conv_out = jax.nn.silu(conv_out)
    new_conv = conv_in[:, 1:, :]
    xi, Bm, Cm = jnp.split(conv_out, [di, di + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, :])  # [B, H]
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt * A[None, :])  # [B, H]
    xh = xi.reshape(-1, nh, s.head_dim).astype(jnp.float32)
    # h' = a h + dt * B ⊗ x ; y = C · h' + D x
    upd = jnp.einsum("bh,bn,bhp->bhnp", dt, Bm.astype(jnp.float32), xh)
    h_new = cache["state"] * a[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), h_new)
    y = y + xh * params["D"][None, :, None]
    y = y.reshape(-1, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_scale"])
    y = (y @ params["out_proj"].astype(x.dtype))[:, None, :]
    return y, {"conv": new_conv, "state": h_new}
