"""Architecture configuration system.

Every assigned architecture gets one module in this package defining a
``CONFIG`` (the exact published dims) plus a ``reduced()`` smoke-test
variant.  ``ArchConfig.layer_groups()`` canonicalises the layer stack into
repeating groups so the model assembly can ``lax.scan`` over repeats
(bounded HLO size even at 126 layers) while still expressing mixed-layer
patterns (RecurrentGemma's 2×RG-LRU + 1×local-attn, DeepSeekMoE's dense
first layer, ...).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

__all__ = [
    "MoECfg",
    "SSMCfg",
    "HybridCfg",
    "EncDecCfg",
    "ArchConfig",
    "LayerSpec",
    "BlockGroup",
]


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0  # shared (always-on) experts, DeepSeekMoE-style
    d_ff_shared: int = 0
    first_k_dense: int = 0  # leading dense layers (DeepSeekMoE layer 0)
    capacity_factor: float = 1.25
    router_norm_topk: bool = True


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256
    a_init_range: tuple[float, float] = (1.0, 16.0)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class HybridCfg:
    lru_width: int = 0  # 0 -> d_model
    window: int = 2048  # local attention window
    pattern_recurrent: int = 2  # recurrent layers per local-attn layer
    rglru_c: float = 8.0


@dataclasses.dataclass(frozen=True)
class EncDecCfg:
    n_enc_layers: int = 24
    n_frames: int = 1500  # precomputed frame embeddings (conv stem stubbed)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str  # "attn" | "local" | "ssd" | "rglru" | "xattn" (enc-dec)
    ffn: str  # "dense" | "moe" | "none"


@dataclasses.dataclass(frozen=True)
class BlockGroup:
    specs: tuple[LayerSpec, ...]
    repeat: int

    @property
    def n_layers(self) -> int:
        return len(self.specs) * self.repeat


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    ffn_act: str = "swiglu"  # swiglu | geglu | gelu | relu2
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = False
    moe: MoECfg | None = None
    ssm: SSMCfg | None = None
    hybrid: HybridCfg | None = None
    encdec: EncDecCfg | None = None
    # execution policy knobs (overridable per shape at launch)
    remat: str = "full"  # none | dots | full
    q_block: int = 512
    kv_block: int = 1024
    sub_quadratic: bool = False  # can run long_500k decode
    # ---- beyond-paper optimization switches (§Perf hillclimb; default off
    # so the baseline stays the paper-faithful/naive implementation) -------
    flash_vjp: bool = False  # fused flash backward (O(S) residuals)
    q_parallel: bool = False  # vmap (shardable) q-blocks instead of scan
    moe_gather: bool = False  # gather/scatter MoE dispatch (no one-hot flops)
    layout: str = "tp"  # tp | dp_only  (activation layout strategy)
    fsdp_gather: bool = False  # constrain weights to gathered TP layout at
    # use — forces per-layer weight all-gather (textbook FSDP) instead of
    # GSPMD's activation-side resolutions (§Perf iteration 3)

    # ---- derived -------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def is_encdec(self) -> bool:
        return self.encdec is not None

    def layer_groups(self) -> list[BlockGroup]:
        """Decoder layer stack as scan-able repeating groups."""
        if self.ssm is not None:
            return [BlockGroup((LayerSpec("ssd", "none"),), self.n_layers)]
        if self.hybrid is not None:
            p = self.hybrid.pattern_recurrent
            block = tuple([LayerSpec("rglru", "dense")] * p + [LayerSpec("local", "dense")])
            reps = self.n_layers // (p + 1)
            tail = self.n_layers - reps * (p + 1)
            groups = [BlockGroup(block, reps)]
            if tail:
                groups.append(BlockGroup((LayerSpec("rglru", "dense"),), tail))
            return groups
        if self.moe is not None:
            groups = []
            if self.moe.first_k_dense:
                groups.append(
                    BlockGroup((LayerSpec("attn", "dense"),), self.moe.first_k_dense)
                )
            groups.append(
                BlockGroup(
                    (LayerSpec("attn", "moe"),), self.n_layers - self.moe.first_k_dense
                )
            )
            return groups
        if self.is_encdec:
            return [BlockGroup((LayerSpec("xattn", "dense"),), self.n_layers)]
        return [BlockGroup((LayerSpec("attn", "dense"),), self.n_layers)]

    def encoder_groups(self) -> list[BlockGroup]:
        assert self.encdec is not None
        return [BlockGroup((LayerSpec("attn", "dense"),), self.encdec.n_enc_layers)]

    # ---- parameter counting (for MODEL_FLOPS = 6·N·D) -------------------
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.hd
        n = 0
        # embeddings (+ untied unembed)
        n += self.vocab * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            b = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
            return q + kv + o + b

        def dense_ffn_params(ff: int) -> int:
            mult = 3 if self.ffn_act in ("swiglu", "geglu") else 2
            return mult * d * ff

        for group in self.layer_groups():
            for spec in group.specs:
                per = 2 * d  # two norms
                if spec.mixer in ("attn", "local"):
                    per += attn_params()
                elif spec.mixer == "xattn":
                    per += 2 * attn_params() + d  # self + cross + extra norm
                elif spec.mixer == "ssd":
                    assert self.ssm is not None
                    di = self.ssm.d_inner(d)
                    nh = self.ssm.n_heads(d)
                    conv_dim = di + 2 * self.ssm.d_state
                    per += d * (2 * di + 2 * self.ssm.d_state + nh)  # in_proj
                    per += conv_dim * self.ssm.conv_width
                    per += di * d  # out_proj
                    per += 2 * nh + di  # A_log, D, gated-norm
                elif spec.mixer == "rglru":
                    assert self.hybrid is not None
                    w = self.hybrid.lru_width or d
                    per += 2 * d * w + self.ssm_conv(w) + 2 * w * w // 1  # in projs + conv
                    per += 2 * w + 2 * w  # gates a/x diag params + Lambda
                    per += w * d  # out proj
                if spec.ffn == "dense":
                    per += dense_ffn_params(self.d_ff)
                elif spec.ffn == "moe":
                    assert self.moe is not None
                    e_all = self.moe.n_experts
                    e_act = self.moe.top_k
                    per_expert = dense_ffn_params(self.moe.d_ff_expert)
                    shared = self.moe.n_shared * (
                        dense_ffn_params(self.moe.d_ff_shared or self.moe.d_ff_expert)
                    )
                    router = d * e_all
                    if active_only:
                        per += e_act * per_expert + shared + router
                    else:
                        per += e_all * per_expert + shared + router
                n += per * group.repeat
        if self.is_encdec:
            for group in self.encoder_groups():
                per_l = 2 * d + attn_params() + dense_ffn_params(self.d_ff)
                n += per_l * group.repeat
        n += d  # final norm
        return n

    def ssm_conv(self, w: int) -> int:
        return 4 * w  # conv width 4 over lru width

    def describe(self) -> str:
        return (
            f"{self.name} [{self.family}] {self.n_layers}L d={self.d_model} "
            f"H={self.n_heads}/kv{self.n_kv_heads} ff={self.d_ff} V={self.vocab} "
            f"params≈{self.param_count() / 1e9:.2f}B"
        )


def reduced_config(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Tiny same-family variant for CPU smoke tests."""
    small: dict = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads > 1 else 1,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        head_dim=32,
        q_block=64,
        kv_block=64,
        remat="none",
    )
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(
            cfg.moe,
            n_experts=4,
            top_k=2,
            d_ff_expert=64,
            d_ff_shared=64 if cfg.moe.n_shared else 0,
            first_k_dense=min(cfg.moe.first_k_dense, 1),
        )
    if cfg.ssm is not None:
        small["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=16, chunk=32
        )
        small["d_ff"] = 0
    if cfg.hybrid is not None:
        small["hybrid"] = dataclasses.replace(cfg.hybrid, lru_width=128, window=64)
        small["n_layers"] = 4  # 3-block group + 1 tail
        small["n_kv_heads"] = 1
    if cfg.encdec is not None:
        small["encdec"] = dataclasses.replace(cfg.encdec, n_enc_layers=2, n_frames=16)
        small["n_layers"] = 2
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
