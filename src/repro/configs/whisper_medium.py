"""whisper-medium [audio] — enc-dec, conv frontend stubbed [arXiv:2212.04356]."""

from repro.configs.base import ArchConfig, EncDecCfg

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,          # decoder layers (encoder listed separately)
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,        # MHA (GQA kv=16)
    d_ff=4096,
    vocab=51_865,
    ffn_act="gelu",
    norm="layernorm",
    encdec=EncDecCfg(n_enc_layers=24, n_frames=1500),
    sub_quadratic=False,  # full-attention decoder -> long_500k skipped
)
