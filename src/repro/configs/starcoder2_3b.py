"""starcoder2-3b [dense] — GQA kv=2, RoPE [arXiv:2402.19173]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12_288,
    vocab=49_152,
    ffn_act="gelu",
    norm="layernorm",
    rope_theta=1e5,
    sub_quadratic=False,
)
