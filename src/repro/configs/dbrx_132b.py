"""dbrx-132b [moe] — 16 experts top-4, fine-grained [hf:databricks/dbrx-base]."""

from repro.configs.base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10_752,          # per-expert FFN width
    vocab=100_352,
    ffn_act="swiglu",
    rope_theta=5e5,
    moe=MoECfg(n_experts=16, top_k=4, d_ff_expert=10_752),
    sub_quadratic=False,
)
