"""chameleon-34b [vlm] — early fusion; VQ image tokens live in the vocab
(the modality frontend is the VQ tokenizer, stubbed: inputs are token ids)
[arXiv:2405.09818]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22_016,
    vocab=65_536,
    ffn_act="swiglu",
    sub_quadratic=False,
)
