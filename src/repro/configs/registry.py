"""Config registry: ``--arch <id>`` -> ArchConfig.

Each arch module defines ``CONFIG``; ``get_config(name)`` resolves it and
``get_reduced(name)`` gives the smoke-test variant.  Input-shape sets
(assigned per the brief) live here too.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ArchConfig, reduced_config

__all__ = ["ARCH_IDS", "SHAPES", "get_config", "get_reduced", "shape_applicable"]

ARCH_IDS = (
    "mamba2_130m",
    "whisper_medium",
    "recurrentgemma_9b",
    "chameleon_34b",
    "nemotron4_15b",
    "starcoder2_3b",
    "qwen2_7b",
    "llama3_405b",
    "dbrx_132b",
    "deepseek_moe_16b",
)

# assigned LM shape set: name -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


def get_config(name: str) -> ArchConfig:
    name = name.replace("-", "_")
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def get_reduced(name: str, **overrides) -> ArchConfig:
    return reduced_config(get_config(name), **overrides)


def shape_applicable(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    """long_500k requires sub-quadratic decode memory (DESIGN.md §5)."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k KV cache is O(S) per layer x 126L -> skipped per brief"
    return True, ""
