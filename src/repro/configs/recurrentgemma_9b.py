"""recurrentgemma-9b [hybrid] — RG-LRU + local attn 1:2 [arXiv:2402.19427]."""

from repro.configs.base import ArchConfig, HybridCfg

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,          # 12 x (2 RG-LRU + 1 local-attn) + 2 RG-LRU tail
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,         # MQA on the local-attention layers
    d_ff=12_288,
    vocab=256_000,
    ffn_act="geglu",
    hybrid=HybridCfg(lru_width=4096, window=2048, pattern_recurrent=2),
    tie_embeddings=True,
    sub_quadratic=True,   # bounded window + recurrent state -> long_500k runs
)
