"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, layer-0 dense
[arXiv:2401.06066]."""

from repro.configs.base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10_944,          # the dense first layer's FFN width
    vocab=102_400,
    ffn_act="swiglu",
    moe=MoECfg(
        n_experts=64,
        top_k=6,
        d_ff_expert=1408,
        n_shared=2,
        d_ff_shared=1408,
        first_k_dense=1,
    ),
    sub_quadratic=False,
)
