"""mamba2-130m [ssm] — SSD state-space duality [arXiv:2405.21060]."""

from repro.configs.base import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=24,           # = d_inner / head_dim (derived; attn-free)
    n_kv_heads=24,
    d_ff=0,               # attn-free block, no separate FFN
    vocab=50_280,
    ssm=SSMCfg(d_state=128, head_dim=64, expand=2, conv_width=4, chunk=256),
    tie_embeddings=True,
    sub_quadratic=True,   # O(1) decode state -> long_500k runs
)
