"""qwen2-7b [dense] — GQA kv=4, QKV bias [arXiv:2407.10671]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18_944,
    vocab=152_064,
    qkv_bias=True,
    ffn_act="swiglu",
    rope_theta=1e6,
    sub_quadratic=False,
)
