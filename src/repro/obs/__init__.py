"""Unified observability: spans, metrics, and trace export.

Two layers with different cost contracts:

* **Metrics** (:mod:`repro.obs.metrics`) are always on — every engine
  owns a :class:`MetricsRegistry` and its legacy ``EngineStats`` fields
  are views over it.
* **Span tracing** (:mod:`repro.obs.trace`) is off by default; a
  :func:`span` still *times* its block (the engine consumes the elapsed
  time), but recording into the per-thread ring costs one branch until
  :func:`enable_tracing` flips it on.  Export the recording with
  :func:`write_chrome_trace` and open it in ``chrome://tracing``.

Quickstart::

    from repro import obs
    obs.enable_tracing()
    eng.query_batch(qs, k=8)
    obs.write_chrome_trace("trace.json")
    print(obs.metrics_snapshot(eng.metrics))
"""

from .metrics import Counter, Gauge, Histogram, MetricsRegistry, process_registry
from .trace import (
    Span,
    SpanRing,
    Tracer,
    disable_tracing,
    enable_tracing,
    get_tracer,
    set_tracer,
    span,
)
from .export import (
    chrome_trace,
    metrics_snapshot,
    spans,
    summarize,
    write_chrome_trace,
)
from .flight import FlightRecorder
from .jitmon import track_jit
from .promtext import render_registries, render_snapshot
from .sentinel import Rule, Sentinel, engine_rules

# Process-wide obs self-telemetry: ring saturation and intern-table
# saturation of the *current* global tracer, visible on every /metrics
# scrape and in every flight bundle (derived → zero hot-path cost).
process_registry().derived(
    "obs.intern_overflow", lambda: float(get_tracer().intern_overflows)
)
process_registry().derived(
    "obs.spans_dropped", lambda: float(get_tracer().dropped)
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "SpanRing",
    "Tracer",
    "disable_tracing",
    "enable_tracing",
    "get_tracer",
    "set_tracer",
    "span",
    "chrome_trace",
    "metrics_snapshot",
    "spans",
    "summarize",
    "write_chrome_trace",
    "process_registry",
    "FlightRecorder",
    "track_jit",
    "render_registries",
    "render_snapshot",
    "Rule",
    "Sentinel",
    "engine_rules",
]
