"""Low-overhead nestable span tracing over preallocated ring buffers.

The serving engine's timing story used to be ~20 scattered
``time.perf_counter()`` pairs whose sums landed in ad-hoc ``EngineStats``
fields.  This module replaces the *measurement* half of that: a
:func:`span` context manager times one phase (it is the perf-counter
pair, so the engine's stats and the planner's observed costs keep their
exact semantics) and — only when tracing is enabled — appends one
fixed-size record to a per-thread ring buffer that
:mod:`repro.obs.export` can serialize as a Chrome ``trace_event`` JSON.

Design constraints, in order:

* **Hot-path overhead is one branch when disabled.**  A span always
  takes its two ``perf_counter`` readings (the engine needs the elapsed
  time regardless — that cost predates this module); everything else
  (string interning, ring write) sits behind a single
  ``if tracer.enabled`` test at span exit.
* **Lock-free under the MVCC read path.**  Each thread owns exactly one
  :class:`SpanRing` (single writer); record columns are preallocated
  numpy arrays, so a write is a handful of scalar stores with no
  allocation and no lock.  Readers (the exporter) never block writers:
  they snapshot the columns and use a seqlock-style double read of the
  monotone ``total`` counter to discard any slot a concurrent wrap
  may have been overwriting — a torn record is *unobservable*, not
  merely unlikely.
* **Never blocks when full.**  The ring wraps: the newest ``capacity``
  records are kept, the overwritten ones are counted in the ring's
  monotone ``dropped`` counter (exact, because the writer is single).

Span *attribution* (which backend, which shard, which snapshot version)
travels as keyword attrs, interned process-wide into small integer ids
so the record stays fixed-size; nesting is recorded explicitly
(per-thread parent seq + depth) rather than inferred from timestamps.
"""

from __future__ import annotations

import threading
import time
from typing import Iterator

import numpy as np

__all__ = [
    "Span",
    "SpanRing",
    "Tracer",
    "get_tracer",
    "enable_tracing",
    "disable_tracing",
    "span",
]

#: Default per-thread ring capacity (records).  At ~6 spans per served
#: batch this holds tens of thousands of batches; a long recording wraps
#: and keeps the newest window, which is what a trace viewer wants.
DEFAULT_CAPACITY = 1 << 16

#: Intern-table safety cap: attr combinations beyond this map to id 0
#: ("overflow") instead of growing the table without bound (e.g. a
#: version= attr on an engine applying millions of updates).
MAX_INTERNED = 1 << 16


class _Interner:
    """Process-wide value → small-int id table (insert-locked reads-free).

    Saturation is *counted*, not silent: once the table is full every
    novel value maps to the pre-seeded sentinel id 0 and bumps
    ``overflows``, which the obs layer surfaces as the
    ``obs.intern_overflow`` gauge so a postmortem can tell "these spans
    all collapsed to <overflow>" from "the workload really was uniform".
    """

    def __init__(self, cap: int = MAX_INTERNED):
        self._lock = threading.Lock()
        self._ids: dict = {}
        self._values: list = []
        self._cap = cap
        self.overflows = 0  # novel values refused after saturation

    def intern(self, value) -> int:
        hit = self._ids.get(value)  # GIL-atomic read, no lock
        if hit is not None:
            return hit
        with self._lock:
            hit = self._ids.get(value)
            if hit is not None:
                return hit
            if len(self._values) >= self._cap:
                self.overflows += 1
                return 0  # overflow sentinel (id 0 is always pre-seeded)
            idx = len(self._values)
            self._values.append(value)
            self._ids[value] = idx
            return idx

    def value(self, idx: int):
        try:
            return self._values[idx]
        except IndexError:
            return self._values[0]


class SpanRing:
    """One thread's preallocated span-record ring (single writer).

    Columns are plain numpy arrays; slot ``i`` of record ``seq`` is
    ``seq % capacity``.  ``total`` (a monotone Python int, assigned
    *after* the record's columns) doubles as the seqlock publication
    point for concurrent readers.
    """

    __slots__ = (
        "tid", "capacity", "total",
        "name_id", "attr_id", "t0", "t1", "depth", "parent",
    )

    def __init__(self, tid: int, capacity: int):
        self.tid = int(tid)
        self.capacity = int(capacity)
        self.total = 0  # records ever written (monotone)
        self.name_id = np.zeros(capacity, np.int32)
        self.attr_id = np.zeros(capacity, np.int32)
        self.t0 = np.zeros(capacity, np.float64)
        self.t1 = np.zeros(capacity, np.float64)
        self.depth = np.zeros(capacity, np.int16)
        self.parent = np.full(capacity, -1, np.int64)

    @property
    def dropped(self) -> int:
        """Records overwritten by wraparound (exact; single writer)."""
        return max(self.total - self.capacity, 0)

    def write(self, name_id: int, attr_id: int, t0: float, t1: float,
              depth: int, parent: int) -> int:
        """Append one record; returns its seq.  Never blocks: a full
        ring wraps, dropping the oldest record (counted via ``total``)."""
        seq = self.total
        i = seq % self.capacity
        self.name_id[i] = name_id
        self.attr_id[i] = attr_id
        self.t0[i] = t0
        self.t1[i] = t1
        self.depth[i] = depth
        self.parent[i] = parent
        self.total = seq + 1  # publish last (seqlock point)
        return seq

    def stable_records(self) -> tuple[dict, int, int]:
        """Seqlock read: snapshot the columns and the seq window
        ``[lo, hi)`` guaranteed torn-free (slots a concurrent wrap may
        have touched during the copy are excluded)."""
        before = self.total
        cols = dict(
            name_id=self.name_id.copy(),
            attr_id=self.attr_id.copy(),
            t0=self.t0.copy(),
            t1=self.t1.copy(),
            depth=self.depth.copy(),
            parent=self.parent.copy(),
        )
        after = self.total
        lo = max(after - self.capacity, 0)
        return cols, lo, before


class Span:
    """One timed phase.  Always measures (``elapsed_s`` is the replaced
    ``perf_counter`` pair); records into the thread's ring only when the
    owning tracer is enabled at exit."""

    __slots__ = ("tracer", "name", "attrs", "t0", "t1", "seq", "_parent", "_depth")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict | None):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs
        self.t0 = 0.0
        self.t1 = 0.0
        self.seq = -1

    @property
    def elapsed_s(self) -> float:
        return (self.t1 if self.t1 else time.perf_counter()) - self.t0

    def __enter__(self) -> "Span":
        stack = self.tracer._stack()
        self._depth = len(stack)
        self._parent = stack[-1].seq if stack else -1
        stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if self.t1:
            return  # idempotent: a manually closed span exits its `with` too
        self.t1 = time.perf_counter()
        tracer = self.tracer
        stack = tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:  # tolerate exception-skewed exits
            stack.remove(self)
        if tracer.enabled:  # the one hot-path branch
            self.seq = tracer._record(self)


class Tracer:
    """Process-wide span collector: one :class:`SpanRing` per thread.

    Disabled by default — :func:`span` still times, nothing is recorded.
    ``enable()`` / ``disable()`` flip recording; rings persist across
    flips so a recording can be inspected after disabling.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 max_interned: int = MAX_INTERNED):
        self.capacity = int(capacity)
        self.enabled = False
        self.names = _Interner(max_interned)
        self.attrs = _Interner(max_interned)
        self.names.intern("<overflow>")  # seed id 0 for both tables
        self.attrs.intern(())
        self._local = threading.local()
        self._rings: dict[int, SpanRing] = {}
        self._rings_lock = threading.Lock()

    # ---- per-thread state -------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _ring(self) -> SpanRing:
        ring = getattr(self._local, "ring", None)
        if ring is None:
            tid = threading.get_ident()
            ring = SpanRing(tid, self.capacity)
            self._local.ring = ring
            with self._rings_lock:
                self._rings[tid] = ring
        return ring

    def _record(self, sp: Span) -> int:
        name_id = self.names.intern(sp.name)
        attr_id = (
            self.attrs.intern(tuple(sorted(sp.attrs.items())))
            if sp.attrs
            else 0
        )
        return self._ring().write(
            name_id, attr_id, sp.t0, sp.t1, sp._depth, sp._parent
        )

    # ---- control ----------------------------------------------------------
    def enable(self) -> "Tracer":
        self.enabled = True
        return self

    def disable(self) -> "Tracer":
        self.enabled = False
        return self

    def clear(self) -> None:
        """Drop all recorded rings (not the intern tables)."""
        with self._rings_lock:
            self._rings.clear()
        self._local = threading.local()

    # ---- read side --------------------------------------------------------
    @property
    def intern_overflows(self) -> int:
        """Novel names/attr-tuples refused since the intern tables
        saturated (their spans carry the sentinel id 0)."""
        return self.names.overflows + self.attrs.overflows

    @property
    def dropped(self) -> int:
        with self._rings_lock:
            rings = list(self._rings.values())
        return sum(r.dropped for r in rings)

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs or None)

    def records(self) -> Iterator[dict]:
        """Decoded stable records across all rings (oldest-first per
        thread).  Safe to call while writers are live — see
        :meth:`SpanRing.stable_records`."""
        with self._rings_lock:
            rings = list(self._rings.values())
        for ring in rings:
            cols, lo, hi = ring.stable_records()
            for seq in range(lo, hi):
                i = seq % ring.capacity
                yield dict(
                    tid=ring.tid,
                    seq=seq,
                    name=self.names.value(int(cols["name_id"][i])),
                    attrs=dict(self.attrs.value(int(cols["attr_id"][i]))),
                    t0=float(cols["t0"][i]),
                    t1=float(cols["t1"][i]),
                    depth=int(cols["depth"][i]),
                    parent=int(cols["parent"][i]),
                )


#: The global tracer every engine span routes through.  Swappable for
#: test isolation via :func:`set_tracer`.
_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def set_tracer(tracer: Tracer) -> Tracer:
    """Install a fresh tracer (tests; returns the previous one)."""
    global _TRACER
    prev = _TRACER
    _TRACER = tracer
    return prev


def enable_tracing(capacity: int | None = None) -> Tracer:
    """Enable span recording on the global tracer.  ``capacity`` replaces
    the tracer (fresh rings) when given."""
    if capacity is not None:
        set_tracer(Tracer(capacity))
    return get_tracer().enable()


def disable_tracing() -> Tracer:
    return get_tracer().disable()


def span(name: str, **attrs) -> Span:
    """A nestable timed span on the global tracer.

    Always measures (use ``sp.elapsed_s`` after the block — this *is*
    the engine's perf-counter pair); records into the per-thread ring
    only while tracing is enabled.
    """
    return Span(_TRACER, name, attrs or None)
